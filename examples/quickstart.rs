//! Quickstart: fit a VIF GP to simulated spatial data and predict.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use vifgp::data;
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::VifConfig;

fn main() {
    // Use the AOT/PJRT covariance path when artifacts are present.
    vifgp::runtime::init_from_artifacts(&vifgp::runtime::default_artifact_dir());

    // 1. Simulate 2-D spatial data from a known GP (paper §7 setup).
    let mut rng = Rng::seed_from(7);
    let n = 2000;
    let x = data::uniform_inputs(&mut rng, n, 2);
    let true_kernel = ArdMatern::new(1.0, vec![0.10, 0.22], Smoothness::ThreeHalves);
    let latent = data::simulate_latent_gp(&mut rng, &x, &true_kernel);
    let y = data::simulate_response(
        &mut rng,
        &latent,
        &Likelihood::Gaussian { variance: 0.05 },
    );
    let xp = data::uniform_inputs(&mut rng, 500, 2);
    let latent_p = exact_conditional_mean(&x, &latent, &xp, &true_kernel);

    // 2. Configure a VIF approximation: m inducing points for the
    //    large-scale structure + m_v Vecchia neighbors for the residual.
    let config = VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 50,
        num_neighbors: 10,
        seed: 1,
        ..Default::default()
    };
    let init = GaussianParams {
        kernel: ArdMatern::isotropic(0.5, 0.4, 2, Smoothness::ThreeHalves),
        noise: 0.2,
    };

    // 3. Fit by L-BFGS on the VIF marginal likelihood.
    let t0 = std::time::Instant::now();
    let mut model = VifRegression::new(x, y, config, init);
    let nll = model.fit(40);
    println!("fitted in {:.1}s, NLL = {nll:.2}", t0.elapsed().as_secs_f64());
    println!(
        "estimated: σ₁² = {:.3} (true 1.0), λ = {:?} (true [0.10, 0.22]), σ² = {:.4} (true 0.05)",
        model.params.kernel.variance,
        model
            .params
            .kernel
            .length_scales
            .iter()
            .map(|l| (l * 1e3).round() / 1e3)
            .collect::<Vec<_>>(),
        model.params.noise
    );

    // 4. Predict at held-out locations (Proposition 2.1).
    let (mean, var) = model.predict(&xp);
    println!(
        "prediction vs truth: RMSE(latent) = {:.4}, mean predictive sd = {:.4}",
        metrics::rmse(&mean, &latent_p),
        var.iter().map(|v| v.sqrt()).sum::<f64>() / var.len() as f64
    );
}

/// Exact conditional mean of the latent field at xp (ground truth for the
/// quickstart's RMSE — feasible because n is small here).
fn exact_conditional_mean(
    x: &vifgp::linalg::Mat,
    latent: &[f64],
    xp: &vifgp::linalg::Mat,
    kernel: &ArdMatern,
) -> Vec<f64> {
    let mut cov = kernel.sym_cov(x, 1e-8);
    cov.add_diag(1e-8);
    let chol = vifgp::linalg::CholeskyFactor::new_with_jitter(&cov, 1e-8).unwrap();
    let alpha = chol.solve(latent);
    (0..xp.rows())
        .map(|p| {
            (0..x.rows())
                .map(|i| kernel.cov(x.row(i), xp.row(p)) * alpha[i])
                .sum()
        })
        .collect()
}
