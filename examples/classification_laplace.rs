//! Binary GP classification with the VIF-Laplace approximation and the
//! paper's iterative methods (preconditioned CG + SLQ + SBPV).
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example classification_laplace
//! ```

use vifgp::data;
use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vif::laplace::{PredVarMethod, SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

fn main() {
    vifgp::runtime::init_from_artifacts(&vifgp::runtime::default_artifact_dir());

    // Simulate a Bernoulli-logit GP classification problem (paper §7).
    let mut rng = Rng::seed_from(11);
    let n = 2000;
    let n_test = 500;
    let x = data::uniform_inputs(&mut rng, n + n_test, 2);
    let true_kernel = ArdMatern::new(1.0, vec![0.15, 0.25], Smoothness::ThreeHalves);
    let latent = data::simulate_latent_gp(&mut rng, &x, &true_kernel);
    let y = data::simulate_response(&mut rng, &latent, &Likelihood::BernoulliLogit);

    let idx: Vec<usize> = (0..n + n_test).collect();
    let (tr, te) = idx.split_at(n);
    let (xtr, ytr) = (data::subset_rows(&x, tr), data::subset_vec(&y, tr));
    let (xte, yte) = (data::subset_rows(&x, te), data::subset_vec(&y, te));

    // VIF-Laplace with the FITC preconditioner (paper default §7).
    let config = VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 60,
        num_neighbors: 10,
        seed: 3,
        ..Default::default()
    };
    let mode = SolveMode::Iterative(IterConfig {
        precond: PrecondType::Fitc,
        ell: 30,
        fitc_k: 60,
        ..Default::default()
    });
    let init_kernel = ArdMatern::isotropic(0.5, 0.4, 2, Smoothness::ThreeHalves);
    let mut model = VifLaplaceModel::new(
        xtr,
        ytr,
        config,
        mode,
        init_kernel,
        Likelihood::BernoulliLogit,
    );

    let t0 = std::time::Instant::now();
    let nll = model.fit(30);
    println!(
        "VIFLA fit in {:.1}s (L^VIFLA = {nll:.2}); σ₁² = {:.3}, λ = {:?}",
        t0.elapsed().as_secs_f64(),
        model.kernel.variance,
        model
            .kernel
            .length_scales
            .iter()
            .map(|l| (l * 1e3).round() / 1e3)
            .collect::<Vec<_>>()
    );

    // Predict class probabilities with simulation-based variances (Alg 1).
    let pred = model.predict(&xte, PredVarMethod::Sbpv, 50);
    let labels: Vec<bool> = yte.iter().map(|&v| v > 0.5).collect();
    println!(
        "test AUC = {:.4}, accuracy = {:.4}, Brier-RMSE = {:.4}, LS = {:.4}",
        metrics::auc(&pred.response_mean, &labels),
        metrics::accuracy(&pred.response_mean, &labels),
        metrics::brier_rmse(&pred.response_mean, &labels),
        metrics::log_score_bernoulli(&pred.response_mean, &labels),
    );
}
