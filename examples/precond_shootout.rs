//! Preconditioner shootout (paper §7.2 in miniature): compare VIFDU vs
//! FITC preconditioned CG on the same VIF-Laplace system — iteration
//! counts, wall time, and the accuracy of SLQ log-likelihoods against the
//! Cholesky reference.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example precond_shootout
//! ```

use std::time::Instant;

use vifgp::data;
use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{nll, SolveMode};
use vifgp::vif::{select_inducing, select_neighbors, VifStructure};

fn main() {
    vifgp::runtime::init_from_artifacts(&vifgp::runtime::default_artifact_dir());

    let mut rng = Rng::seed_from(5);
    let n = 1200;
    let x = data::uniform_inputs(&mut rng, n, 5);
    let kernel = ArdMatern::new(
        1.0,
        vec![0.15, 0.30, 0.45, 0.60, 0.75],
        Smoothness::Gaussian,
    );
    let latent = data::simulate_latent_gp(&mut rng, &x, &kernel);
    let y = data::simulate_response(&mut rng, &latent, &Likelihood::BernoulliLogit);

    // Assemble one VIF structure (m = 100, m_v = 15).
    let z = select_inducing(&x, &kernel, 100, 3, &mut rng, None);
    let lr = z
        .clone()
        .map(|z| vifgp::vif::LowRank::build(&x, &kernel, z, 1e-8));
    let nb = select_neighbors(
        &x,
        &kernel,
        lr.as_ref(),
        15,
        NeighborSelection::CorrelationCoverTree,
    );
    let s = VifStructure::assemble(&x, &kernel, z, nb, 0.0, 1e-8, 0);
    let lik = Likelihood::BernoulliLogit;

    // Reference: dense Cholesky.
    let t0 = Instant::now();
    let (ref_nll, _) = nll(&s, &x, &kernel, &lik, &y, &SolveMode::Cholesky, &mut rng);
    let t_chol = t0.elapsed().as_secs_f64();
    println!("Cholesky reference: L = {ref_nll:.4}  ({t_chol:.2}s)");
    println!("{:<10} {:>6} {:>12} {:>12} {:>10}", "precond", "ell", "L^VIFLA", "|err|", "time(s)");

    for precond in [PrecondType::Vifdu, PrecondType::Fitc, PrecondType::None] {
        for ell in [10usize, 50] {
            let cfg = IterConfig {
                precond,
                ell,
                cg_tol: 1e-2,
                max_cg: 400,
                fitc_k: 100,
                slq_min_iter: 25,
                seed: 9,
            };
            let t0 = Instant::now();
            let (got, state) = nll(
                &s,
                &x,
                &kernel,
                &lik,
                &y,
                &SolveMode::Iterative(cfg),
                &mut rng,
            );
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "{:<10} {:>6} {:>12.4} {:>12.4} {:>10.2}   (newton iters {})",
                format!("{precond:?}"),
                ell,
                got,
                (got - ref_nll).abs(),
                dt,
                state.newton_iters
            );
        }
    }
    println!("\nExpected (paper Fig. 4): FITC beats VIFDU in accuracy and time;\nboth beat unpreconditioned CG; all are far cheaper than Cholesky at scale.");
}
