//! End-to-end driver (the repo's mandated full-system workload): proves
//! all three layers compose on a real small workload.
//!
//! 1. Loads the AOT artifacts (Layer-1 Pallas kernel inside the Layer-2
//!    JAX graphs) into the PJRT runtime — covariance panels on the Rust
//!    request path run through them;
//! 2. Simulates the paper's §7 setup (d = 5, ARD kernel);
//! 3. Trains VIF, standalone Vecchia, FITC and SGPR models on the same
//!    data (Gaussian likelihood), logging the optimization trace;
//! 4. Trains a VIF-Laplace classifier with iterative methods;
//! 5. Reports the comparison table the paper's headline claims predict
//!    (VIF ≥ {Vecchia, FITC, SGPR}) plus runtime and engine statistics.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example end_to_end
//! ```
//! Results are recorded in EXPERIMENTS.md.

use std::time::Instant;

use vifgp::baselines::{self, SgprModel};
use vifgp::coordinator::ResultsTable;
use vifgp::data;
use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::laplace::{PredVarMethod, SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

fn main() {
    let used_pjrt =
        vifgp::runtime::init_from_artifacts(&vifgp::runtime::default_artifact_dir());
    println!("PJRT engine: {}", if used_pjrt { "ACTIVE (AOT artifacts on the hot path)" } else { "unavailable — native fallback" });

    // ------------------------------------------------------------------
    // Workload: §7 simulation, d = 5 ARD 3/2-Matérn, n_train/n_test.
    // ------------------------------------------------------------------
    let (n_train, n_test) = (3000usize, 1000usize);
    let d = 5;
    let mut rng = Rng::seed_from(2026);
    let x_all = data::uniform_inputs(&mut rng, n_train + n_test, d);
    let true_ls = data::paper_length_scales(d, Smoothness::ThreeHalves);
    let true_kernel = ArdMatern::new(1.0, true_ls.clone(), Smoothness::ThreeHalves);
    let latent = data::simulate_latent_gp(&mut rng, &x_all, &true_kernel);
    let noise = 0.05;
    let y_all = data::simulate_response(
        &mut rng,
        &latent,
        &Likelihood::Gaussian { variance: noise },
    );
    let idx: Vec<usize> = (0..n_train + n_test).collect();
    let (tr, te) = idx.split_at(n_train);
    let (xtr, ytr) = (data::subset_rows(&x_all, tr), data::subset_vec(&y_all, tr));
    let (xte, yte) = (data::subset_rows(&x_all, te), data::subset_vec(&y_all, te));
    println!(
        "workload: n_train={n_train} n_test={n_test} d={d} (ARD 3/2-Matérn, σ²={noise})"
    );

    let mut table = ResultsTable::new("End-to-end: Gaussian regression (paper-headline shape)");
    let smoothness = Smoothness::ThreeHalves;
    let init_kernel = ArdMatern::isotropic(0.5, 0.5, d, smoothness);
    let (m, m_v) = (100usize, 15usize);
    let iters = 30;

    // --- VIF ---
    let config = VifConfig { smoothness, num_inducing: m, num_neighbors: m_v, seed: 1, ..Default::default() };
    let t0 = Instant::now();
    let mut vif = VifRegression::new(
        xtr.clone(),
        ytr.clone(),
        config.clone(),
        GaussianParams { kernel: init_kernel.clone(), noise: 0.2 },
    );
    let vif_nll = vif.fit(iters);
    let vif_time = t0.elapsed().as_secs_f64();
    println!(
        "VIF fit: {:.1}s, NLL {:.2}, trace[0] {:.2} → trace[last] {:.2} ({} evals)",
        vif_time,
        vif_nll,
        vif.fit_trace.first().unwrap_or(&f64::NAN),
        vif.fit_trace.last().unwrap_or(&f64::NAN),
        vif.fit_trace.len()
    );
    let (mean, var) = vif.predict(&xte);
    record(&mut table, "VIF(m=100,mv=15)", &mean, &var, &yte, vif_time);

    // --- Standalone Vecchia ---
    let t0 = Instant::now();
    let mut vec_model = VifRegression::new(
        xtr.clone(),
        ytr.clone(),
        baselines::vecchia_config(m_v, &config),
        GaussianParams { kernel: init_kernel.clone(), noise: 0.2 },
    );
    vec_model.fit(iters);
    let vec_time = t0.elapsed().as_secs_f64();
    let (mean, var) = vec_model.predict(&xte);
    record(&mut table, "Vecchia(mv=15)", &mean, &var, &yte, vec_time);

    // --- FITC ---
    let t0 = Instant::now();
    let mut fitc_model = VifRegression::new(
        xtr.clone(),
        ytr.clone(),
        baselines::fitc_config(m, &config),
        GaussianParams { kernel: init_kernel.clone(), noise: 0.2 },
    );
    fitc_model.fit(iters);
    let fitc_time = t0.elapsed().as_secs_f64();
    let (mean, var) = fitc_model.predict(&xte);
    record(&mut table, "FITC(m=100)", &mean, &var, &yte, fitc_time);

    // --- SGPR ---
    let t0 = Instant::now();
    let sgpr = SgprModel::fit(&xtr, &ytr, m, smoothness, init_kernel.clone(), 0.2, iters, 1);
    let sgpr_time = t0.elapsed().as_secs_f64();
    let (mean, var) = sgpr.predict(&xte);
    record(&mut table, "SGPR(m=100)", &mean, &var, &yte, sgpr_time);

    println!("\n{}", table.render());

    // ------------------------------------------------------------------
    // Non-Gaussian leg: Bernoulli VIFLA with iterative methods (Alg 1).
    // ------------------------------------------------------------------
    println!("--- VIF-Laplace classification (iterative, FITC preconditioner) ---");
    let yb_all = data::simulate_response(&mut rng, &latent, &Likelihood::BernoulliLogit);
    let (ybtr, ybte) = (data::subset_vec(&yb_all, tr), data::subset_vec(&yb_all, te));
    let mode = SolveMode::Iterative(IterConfig {
        precond: PrecondType::Fitc,
        ell: 20,
        fitc_k: m,
        ..Default::default()
    });
    let t0 = Instant::now();
    let mut clf = VifLaplaceModel::new(
        xtr.clone(),
        ybtr,
        config.clone(),
        mode,
        init_kernel,
        Likelihood::BernoulliLogit,
    );
    let clf_nll = clf.fit(20);
    let clf_time = t0.elapsed().as_secs_f64();
    let pred = clf.predict(&xte, PredVarMethod::Sbpv, 30);
    let labels: Vec<bool> = ybte.iter().map(|&v| v > 0.5).collect();
    println!(
        "VIFLA fit {:.1}s (L {:.2}); test AUC {:.4} ACC {:.4} LS {:.4}",
        clf_time,
        clf_nll,
        metrics::auc(&pred.response_mean, &labels),
        metrics::accuracy(&pred.response_mean, &labels),
        metrics::log_score_bernoulli(&pred.response_mean, &labels),
    );

    if let Some(engine) = vifgp::runtime::engine() {
        let stats = *engine.stats.lock().unwrap();
        println!(
            "\nPJRT engine stats: {} panel executions served by the AOT artifacts, {} native fallbacks",
            stats.pjrt_panels, stats.native_panels
        );
    }
    println!("(record these numbers in EXPERIMENTS.md §End-to-end)");
}

fn record(
    table: &mut ResultsTable,
    name: &str,
    mean: &[f64],
    var: &[f64],
    yte: &[f64],
    time_s: f64,
) {
    table.record(name, "RMSE", metrics::rmse(mean, yte));
    table.record(name, "LS", metrics::log_score_gaussian(mean, var, yte));
    table.record(name, "CRPS", metrics::crps_gaussian(mean, var, yte));
    table.record(name, "time_s", time_s);
}
