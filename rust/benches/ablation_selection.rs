//! Ablation (paper §6 claims + Fig 16):
//! 1. correlation-distance Vecchia neighbors vs plain Euclidean neighbors
//!    for the residual process — the paper's cover-tree contribution
//!    should improve accuracy for anisotropic ARD kernels;
//! 2. prediction-path runtime scaling in n_p (Fig 16's shape).

#[path = "common.rs"]
mod common;

use vifgp::coordinator::ResultsTable;
use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure};

fn main() {
    common::init_runtime();
    common::header("Ablation: neighbor-selection strategy + prediction runtime (Fig 16)");
    let n_train = common::scaled(1500);
    let n_test = common::scaled(600);
    let noise = 0.001;
    let (m, m_v) = (48usize, 8usize);

    // -- part 1: selection strategy across dimensions --
    let mut t = ResultsTable::new("RMSE by neighbor-selection strategy");
    for d in [2usize, 10, 20] {
        for rep in 0..3u64 {
            let w = common::simulate(
                500 + rep,
                n_train,
                n_test,
                d,
                Smoothness::ThreeHalves,
                &Likelihood::Gaussian { variance: noise },
            );
            for (name, sel) in [
                ("correlation", NeighborSelection::CorrelationCoverTree),
                ("euclidean", NeighborSelection::EuclideanTransformed),
            ] {
                let mut rng = Rng::seed_from(5);
                let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
                let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
                let nb = select_neighbors(&w.xtr, &w.kernel, lr.as_ref(), m_v, sel);
                let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, noise, 1e-10, 1);
                let (mean, _) = gaussian::predict(&s, &w.xtr, &w.kernel, &w.ytr, &w.xte, m_v, sel);
                t.record(&format!("d={d}"), name, metrics::rmse(&mean, &w.yte));
            }
        }
    }
    println!("{}", t.render());

    // -- part 2: prediction runtime vs n_p (Fig 16 shape) --
    let w = common::simulate(
        9,
        n_train,
        common::scaled(2400),
        5,
        Smoothness::ThreeHalves,
        &Likelihood::Gaussian { variance: noise },
    );
    let mut rng = Rng::seed_from(5);
    let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
    let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
    let nb = select_neighbors(
        &w.xtr,
        &w.kernel,
        lr.as_ref(),
        m_v,
        NeighborSelection::CorrelationCoverTree,
    );
    let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, noise, 1e-10, 1);
    println!("prediction runtime vs n_p (m={m}, mv={m_v}):");
    for frac in [4usize, 2, 1] {
        let np = w.xte.rows() / frac;
        let xp = vifgp::data::subset_rows(&w.xte, &(0..np).collect::<Vec<_>>());
        let (_, secs) = common::timed(|| {
            gaussian::predict(
                &s,
                &w.xtr,
                &w.kernel,
                &w.ytr,
                &xp,
                m_v,
                NeighborSelection::CorrelationCoverTree,
            )
        });
        println!("  n_p={np:<8} {secs:>8.2}s");
    }
}
