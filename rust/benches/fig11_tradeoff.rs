//! Figures 11/12: accuracy vs (training + prediction) runtime across
//! approximation budgets (m, m_v), with the VIF's two inducing-to-
//! neighbor ratios, at d = 10. Expected shape: VIF traces the best
//! frontier; larger budgets help until saturation.

#[path = "common.rs"]
mod common;

use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure};

fn main() {
    common::init_runtime();
    common::header("Fig 11: accuracy-vs-runtime frontier across budgets (d=10)");
    let n_train = common::scaled(1500);
    let n_test = common::scaled(600);
    let noise = 0.001;
    let w = common::simulate(
        77,
        n_train,
        n_test,
        10,
        Smoothness::ThreeHalves,
        &Likelihood::Gaussian { variance: noise },
    );

    println!(
        "{:<22} {:>10} {:>10} {:>10}",
        "config", "RMSE", "LS", "time(s)"
    );
    // VIF at ratio m/mv = 5 and 10, plus pure baselines.
    let budgets: &[(&str, usize, usize)] = &[
        ("VIF m=20,mv=4", 20, 4),
        ("VIF m=50,mv=10", 50, 10),
        ("VIF m=100,mv=20", 100, 20),
        ("VIF m=40,mv=4", 40, 4),
        ("VIF m=100,mv=10", 100, 10),
        ("FITC m=50", 50, 0),
        ("FITC m=150", 150, 0),
        ("Vecchia mv=10", 0, 10),
        ("Vecchia mv=30", 0, 30),
    ];
    for &(name, m, m_v) in budgets {
        let ((rmse, ls), secs) = common::timed(|| {
            let mut rng = Rng::seed_from(5);
            let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
            let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
            let nb = select_neighbors(
                &w.xtr,
                &w.kernel,
                lr.as_ref(),
                m_v,
                NeighborSelection::CorrelationCoverTree,
            );
            let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, noise, 1e-10, 1);
            let (mean, var) = gaussian::predict(
                &s,
                &w.xtr,
                &w.kernel,
                &w.ytr,
                &w.xte,
                m_v.max(10),
                NeighborSelection::CorrelationCoverTree,
            );
            (
                metrics::rmse(&mean, &w.yte),
                metrics::log_score_gaussian(&mean, &var, &w.yte),
            )
        });
        println!("{name:<22} {rmse:>10.4} {ls:>10.3} {secs:>10.2}");
    }
}
