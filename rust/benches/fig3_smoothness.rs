//! Figure 3 (+ Fig 13): prediction accuracy of VIF vs FITC vs Vecchia
//! across Matérn smoothness ν ∈ {1/2, 3/2, 5/2, ∞} at d = 10 (and d = 2).
//! Expected shape: all improve with smoothness; Vecchia's gap to
//! VIF/FITC widens as the kernel gets smoother; at d = 2 the gap closes.

#[path = "common.rs"]
mod common;

use vifgp::coordinator::ResultsTable;
use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure};

fn main() {
    common::init_runtime();
    common::header("Fig 3/13: accuracy vs smoothness ν (d = 10 and d = 2)");
    let n_train = common::scaled(1500);
    let n_test = common::scaled(800);
    let noise = 0.001;
    let (m, m_v) = (64usize, 10usize);
    let reps = 3;

    for d in [10usize, 2] {
        let mut rmse_t = ResultsTable::new(&format!("RMSE (d={d})"));
        let mut ls_t = ResultsTable::new(&format!("LS (d={d})"));
        for (label, smoothness) in [
            ("nu=1/2", Smoothness::Half),
            ("nu=3/2", Smoothness::ThreeHalves),
            ("nu=5/2", Smoothness::FiveHalves),
            ("nu=inf", Smoothness::Gaussian),
        ] {
            for rep in 0..reps {
                let w = common::simulate(
                    2000 + rep,
                    n_train,
                    n_test,
                    d,
                    smoothness,
                    &Likelihood::Gaussian { variance: noise },
                );
                for (name, mm, mv) in [("VIF", m, m_v), ("FITC", m, 0), ("Vecchia", 0, m_v)] {
                    let (mean, var) = predict(&w, noise, mm, mv);
                    rmse_t.record(label, name, metrics::rmse(&mean, &w.yte));
                    ls_t.record(label, name, metrics::log_score_gaussian(&mean, &var, &w.yte));
                }
            }
            eprintln!("[fig3] d={d} {label} done");
        }
        println!("{}", rmse_t.render());
        println!("{}", ls_t.render());
    }
}

fn predict(w: &common::Workload, noise: f64, m: usize, m_v: usize) -> (Vec<f64>, Vec<f64>) {
    let mut rng = vifgp::rng::Rng::seed_from(5);
    let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
    let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
    let nb = select_neighbors(
        &w.xtr,
        &w.kernel,
        lr.as_ref(),
        m_v,
        NeighborSelection::CorrelationCoverTree,
    );
    let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, noise, 1e-10, 1);
    gaussian::predict(
        &s,
        &w.xtr,
        &w.kernel,
        &w.ytr,
        &w.xte,
        m_v.max(10),
        NeighborSelection::CorrelationCoverTree,
    )
}
