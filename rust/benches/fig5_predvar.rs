//! Figure 5: accuracy-vs-runtime of the simulation-based predictive
//! variance estimators — SBPV (Alg. 1) and SPV (Alg. 2) with the FITC
//! and VIFDU preconditioners, against the exact (dense) variances.
//! Expected shape: SBPV more accurate than SPV at equal ℓ; FITC faster
//! than VIFDU.

#[path = "common.rs"]
mod common;

use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{nll, predict, PredVarMethod, SolveMode};
use vifgp::vif::{select_inducing, select_neighbors, LowRank, VifStructure};

fn main() {
    common::init_runtime();
    common::header("Fig 5: SBPV vs SPV predictive-variance accuracy-vs-runtime");
    let n = common::scaled(900);
    let n_p = common::scaled(400);
    let (m, m_v) = (48usize, 8usize);
    let lik = Likelihood::BernoulliLogit;
    let w = common::simulate(3, n, n_p, 5, Smoothness::Gaussian, &lik);

    let mut rng = Rng::seed_from(23);
    let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
    let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
    let nb = select_neighbors(
        &w.xtr,
        &w.kernel,
        lr.as_ref(),
        m_v,
        NeighborSelection::CorrelationCoverTree,
    );
    let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, 0.0, 1e-10, 0);
    let (_, state) = nll(&s, &w.xtr, &w.kernel, &lik, &w.ytr, &SolveMode::Cholesky, &mut rng);

    // exact variances (dense)
    let (exact, t_exact) = common::timed(|| {
        predict(
            &s, &w.xtr, &w.kernel, &lik, &state, &w.xte, m_v,
            NeighborSelection::CorrelationCoverTree,
            &SolveMode::Cholesky, PredVarMethod::Exact, 0, &mut rng,
        )
    });
    println!("exact (dense) variances computed in {t_exact:.2}s");
    println!(
        "{:<8} {:<8} {:>4} {:>14} {:>10}",
        "method", "precond", "ell", "RMSE(var)", "time(s)"
    );
    for method in [PredVarMethod::Sbpv, PredVarMethod::Spv] {
        for precond in [PrecondType::Fitc, PrecondType::Vifdu] {
            for ell in [10usize, 50, 100] {
                let cfg = IterConfig {
                    precond,
                    ell: 30,
                    cg_tol: 1e-2,
                    max_cg: 300,
                    fitc_k: m,
                    slq_min_iter: 25,
                    seed: 7,
                };
                let (got, dt) = common::timed(|| {
                    predict(
                        &s, &w.xtr, &w.kernel, &lik, &state, &w.xte, m_v,
                        NeighborSelection::CorrelationCoverTree,
                        &SolveMode::Iterative(cfg.clone()), method, ell, &mut rng,
                    )
                });
                println!(
                    "{:<8} {:<8} {:>4} {:>14.5} {:>10.2}",
                    format!("{method:?}"),
                    format!("{precond:?}"),
                    ell,
                    metrics::rmse(&got.latent_var, &exact.latent_var),
                    dt
                );
            }
        }
    }
}
