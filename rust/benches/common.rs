//! Shared helpers for the per-table/figure bench harnesses.
//!
//! Every bench prints the same rows/series the paper's artifact reports,
//! at sizes scaled for this single-core testbed (DESIGN.md §3). Bench
//! scale can be bumped with `VIFGP_BENCH_SCALE` (default 1.0).

#![allow(dead_code)]

use std::time::Instant;

use vifgp::data;
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::linalg::Mat;
use vifgp::rng::Rng;

pub fn scale() -> f64 {
    std::env::var("VIFGP_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(1.0)
}

pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(64)
}

pub fn init_runtime() {
    let dir = vifgp::runtime::default_artifact_dir();
    vifgp::runtime::init_from_artifacts(&dir);
}

/// Simulated §7 workload: uniform inputs, Table-5 ARD scales, latent GP.
pub struct Workload {
    pub xtr: Mat,
    pub ytr: Vec<f64>,
    pub latent_tr: Vec<f64>,
    pub xte: Mat,
    pub yte: Vec<f64>,
    pub latent_te: Vec<f64>,
    pub kernel: ArdMatern,
}

pub fn simulate(
    seed: u64,
    n_train: usize,
    n_test: usize,
    d: usize,
    smoothness: Smoothness,
    lik: &Likelihood,
) -> Workload {
    let mut rng = Rng::seed_from(seed);
    let x = data::uniform_inputs(&mut rng, n_train + n_test, d);
    let kernel = ArdMatern::new(1.0, data::paper_length_scales(d, smoothness), smoothness);
    let latent = data::simulate_latent_gp(&mut rng, &x, &kernel);
    let y = data::simulate_response(&mut rng, &latent, lik);
    let idx: Vec<usize> = (0..n_train + n_test).collect();
    let (tr, te) = idx.split_at(n_train);
    Workload {
        xtr: data::subset_rows(&x, tr),
        ytr: data::subset_vec(&y, tr),
        latent_tr: data::subset_vec(&latent, tr),
        xte: data::subset_rows(&x, te),
        yte: data::subset_vec(&y, te),
        latent_te: data::subset_vec(&latent, te),
        kernel,
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "(scaled workload for this testbed; shapes/rankings are what the paper reports — see EXPERIMENTS.md)"
    );
}
