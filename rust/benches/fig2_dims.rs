//! Figure 2 (+ Figs 10/14, Table 4): prediction accuracy of VIF vs FITC
//! vs Vecchia across input dimensions d for the ARD 3/2-Matérn kernel.
//! Expected shape: Vecchia excels at small d and degrades with d; FITC
//! is stronger at large d; VIF matches or beats both everywhere.

#[path = "common.rs"]
mod common;

use vifgp::coordinator::ResultsTable;
use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure};

fn main() {
    common::init_runtime();
    common::header("Fig 2: accuracy vs input dimension d (ARD 3/2-Matérn)");
    let n_train = common::scaled(1500);
    let n_test = common::scaled(800);
    let noise = 0.001; // paper §7
    let (m, m_v) = (64usize, 10usize);
    let reps = 3;

    let mut rmse_t = ResultsTable::new("RMSE");
    let mut ls_t = ResultsTable::new("log-score (LS)");
    let mut crps_t = ResultsTable::new("CRPS");
    let mut time_t = ResultsTable::new("predict-path seconds");

    for d in [2usize, 5, 10, 20] {
        for rep in 0..reps {
            let w = common::simulate(
                1000 + rep,
                n_train,
                n_test,
                d,
                Smoothness::ThreeHalves,
                &Likelihood::Gaussian { variance: noise },
            );
            for (name, mm, mv) in [("VIF", m, m_v), ("FITC", m, 0), ("Vecchia", 0, m_v)] {
                let (scores, secs) = common::timed(|| run(&w, noise, mm, mv));
                let row = format!("d={d}");
                let col = name.to_string();
                rmse_t.record(&row, &col, scores.0);
                ls_t.record(&row, &col, scores.1);
                crps_t.record(&row, &col, scores.2);
                time_t.record(&row, &col, secs);
            }
        }
        // stream partial output so long runs show progress
        eprintln!("[fig2] d={d} done");
    }
    println!("{}", rmse_t.render());
    println!("{}", ls_t.render());
    println!("{}", crps_t.render());
    println!("{}", time_t.render());
}

/// Evaluate the approximation at the data-generating parameters (the
/// paper fits; at this scale the accuracy ranking is identical and the
/// run completes on one core — see EXPERIMENTS.md note).
fn run(w: &common::Workload, noise: f64, m: usize, m_v: usize) -> (f64, f64, f64) {
    let mut rng = vifgp::rng::Rng::seed_from(5);
    let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
    let lr = z
        .clone()
        .map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
    let nb = select_neighbors(
        &w.xtr,
        &w.kernel,
        lr.as_ref(),
        m_v,
        NeighborSelection::CorrelationCoverTree,
    );
    let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, noise, 1e-10, 1);
    let (mean, var) = gaussian::predict(
        &s,
        &w.xtr,
        &w.kernel,
        &w.ytr,
        &w.xte,
        m_v.max(10),
        NeighborSelection::CorrelationCoverTree,
    );
    (
        metrics::rmse(&mean, &w.yte),
        metrics::log_score_gaussian(&mean, &var, &w.yte),
        metrics::crps_gaussian(&mean, &var, &w.yte),
    )
}
