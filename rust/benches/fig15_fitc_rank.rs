//! Figure 15: FITC-preconditioner rank k sweep — log-marginal-likelihood
//! error vs the Cholesky reference and runtime, for three VIF configs.
//! Expected shape: accuracy improves with k; runtime is minimized at an
//! intermediate k (the paper finds k ≈ 200 at its scale).

#[path = "common.rs"]
mod common;

use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{nll, SolveMode};
use vifgp::vif::{select_inducing, select_neighbors, LowRank, VifStructure};

fn main() {
    common::init_runtime();
    common::header("Fig 15: FITC-preconditioner rank k sweep");
    let n = common::scaled(1500);
    let lik = Likelihood::BernoulliLogit;
    let w = common::simulate(3, n, 8, 5, Smoothness::Gaussian, &lik);

    println!(
        "{:<18} {:>6} {:>14} {:>10} {:>10}",
        "VIF config", "k", "|loglik err|", "time(s)", "avg CG its"
    );
    for (cfg_name, m, m_v) in [("m=64,mv=10", 64usize, 10usize), ("m=32,mv=20", 32, 20), ("m=64,mv=4", 64, 4)] {
        let mut rng = Rng::seed_from(31);
        let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
        let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
        let nb = select_neighbors(
            &w.xtr,
            &w.kernel,
            lr.as_ref(),
            m_v,
            NeighborSelection::CorrelationCoverTree,
        );
        let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, 0.0, 1e-10, 0);
        let (reference, _) =
            nll(&s, &w.xtr, &w.kernel, &lik, &w.ytr, &SolveMode::Cholesky, &mut rng);
        for k in [8usize, 24, 64, 128, 256] {
            let cfg = IterConfig {
                precond: PrecondType::Fitc,
                ell: 25,
                cg_tol: 1e-2,
                max_cg: 400,
                fitc_k: k,
                slq_min_iter: 25,
                seed: 9,
            };
            let ((got, _), dt) = common::timed(|| {
                nll(
                    &s,
                    &w.xtr,
                    &w.kernel,
                    &lik,
                    &w.ytr,
                    &SolveMode::Iterative(cfg),
                    &mut rng,
                )
            });
            println!(
                "{:<18} {:>6} {:>14.4} {:>10.2} {:>10}",
                cfg_name,
                k,
                (got - reference).abs(),
                dt,
                "-"
            );
        }
    }
}
