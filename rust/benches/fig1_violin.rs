//! Figure 1: distribution of the estimated marginal variance σ₁² under
//! VIF-Laplace (binary data, iterative methods) for growing sample sizes.
//! Expected shape: downward bias that shrinks as n grows.

#[path = "common.rs"]
mod common;

use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::vif::laplace::{SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

fn main() {
    common::init_runtime();
    common::header("Fig 1: σ₁² estimates vs n (Bernoulli, VIFLA iterative)");
    let reps = 5usize;
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10}  (true σ₁² = 1)",
        "n", "min", "mean", "max", "|bias|"
    );
    for n in [common::scaled(400), common::scaled(800), common::scaled(1600)] {
        let mut est = Vec::new();
        for rep in 0..reps {
            let w = common::simulate(
                42 + rep as u64,
                n,
                8,
                2,
                Smoothness::ThreeHalves,
                &Likelihood::BernoulliLogit,
            );
            let config = VifConfig {
                smoothness: Smoothness::ThreeHalves,
                num_inducing: 24,
                num_neighbors: 6,
                seed: rep as u64,
                ..Default::default()
            };
            let mode = SolveMode::Iterative(IterConfig {
                precond: PrecondType::Fitc,
                ell: 20,
                fitc_k: 24,
                ..Default::default()
            });
            let init = ArdMatern::isotropic(1.0, 0.2, 2, Smoothness::ThreeHalves);
            let mut model =
                VifLaplaceModel::new(w.xtr, w.ytr, config, mode, init, Likelihood::BernoulliLogit);
            model.fit(12);
            est.push(model.kernel.variance);
        }
        let mean = est.iter().sum::<f64>() / est.len() as f64;
        let min = est.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = est.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        println!(
            "{:<8} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            n,
            min,
            mean,
            max,
            (1.0 - mean).abs()
        );
    }
}
