//! Figure 4: accuracy-vs-runtime of SLQ log-marginal-likelihoods under
//! the VIFDU and FITC preconditioners against the Cholesky reference,
//! for three VIF configurations and varying probe counts ℓ.
//! Expected shape: FITC dominates VIFDU on both axes; both are orders of
//! magnitude cheaper than Cholesky at scale.

#[path = "common.rs"]
mod common;

use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{nll, SolveMode};
use vifgp::vif::{select_inducing, select_neighbors, LowRank, VifStructure};

fn main() {
    common::init_runtime();
    common::header("Fig 4: preconditioner accuracy-vs-runtime (binary likelihood)");
    let n = common::scaled(1500);
    let reps = 8;

    let w = common::simulate(
        7,
        n,
        16,
        5,
        Smoothness::Gaussian,
        &Likelihood::BernoulliLogit,
    );
    let lik = Likelihood::BernoulliLogit;

    println!(
        "{:<22} {:<8} {:>4} {:>14} {:>12} {:>10}",
        "VIF config", "precond", "ell", "RMSE(loglik)", "mean |err|", "time(s)"
    );
    for (cfg_name, m, m_v) in [("m=64,mv=10", 64usize, 10usize), ("m=32,mv=20", 32, 20), ("m=64,mv=4", 64, 4)] {
        let mut rng = Rng::seed_from(17);
        let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
        let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
        let nb = select_neighbors(
            &w.xtr,
            &w.kernel,
            lr.as_ref(),
            m_v,
            NeighborSelection::CorrelationCoverTree,
        );
        let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, 0.0, 1e-10, 0);
        // Cholesky reference (timed once).
        let ((reference, _), t_chol) = common::timed(|| {
            nll(&s, &w.xtr, &w.kernel, &lik, &w.ytr, &SolveMode::Cholesky, &mut rng)
        });
        println!(
            "{:<22} {:<8} {:>4} {:>14} {:>12} {:>10.2}   <- reference",
            cfg_name, "Cholesky", "-", "-", "-", t_chol
        );
        for precond in [PrecondType::Vifdu, PrecondType::Fitc] {
            for ell in [10usize, 50] {
                let mut sq = 0.0;
                let mut abs = 0.0;
                let mut secs = 0.0;
                for rep in 0..reps {
                    let cfg = IterConfig {
                        precond,
                        ell,
                        cg_tol: 1e-2,
                        max_cg: 400,
                        fitc_k: 64,
                        slq_min_iter: 25,
                        seed: 100 + rep,
                    };
                    let ((got, _), dt) = common::timed(|| {
                        nll(
                            &s,
                            &w.xtr,
                            &w.kernel,
                            &lik,
                            &w.ytr,
                            &SolveMode::Iterative(cfg),
                            &mut rng,
                        )
                    });
                    sq += (got - reference) * (got - reference);
                    abs += (got - reference).abs();
                    secs += dt;
                }
                println!(
                    "{:<22} {:<8} {:>4} {:>14.4} {:>12.4} {:>10.2}",
                    cfg_name,
                    format!("{precond:?}"),
                    ell,
                    (sq / reps as f64).sqrt(),
                    abs / reps as f64,
                    secs / reps as f64
                );
            }
        }
    }
}
