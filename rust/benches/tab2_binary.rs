//! Table 2 (+ Fig 8 right, Table 9): binary-classification suite — VIF
//! vs Vecchia vs FITC Laplace approximations with iterative methods on
//! the synthetic substitutes. Expected shape: small differences between
//! methods (binary data is weakly informative), VIF fastest/most stable.

#[path = "common.rs"]
mod common;

use vifgp::baselines;
use vifgp::coordinator::ResultsTable;
use vifgp::data;
use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vif::laplace::{PredVarMethod, SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

fn main() {
    common::init_runtime();
    common::header("Table 2: binary classification suite (synthetic substitutes)");
    let (m, m_v, iters) = (32usize, 6usize, 8usize);
    let mut auc_t = ResultsTable::new("AUC");
    let mut brier_t = ResultsTable::new("RMSE (Brier)");
    let mut acc_t = ResultsTable::new("ACC");
    let mut ls_t = ResultsTable::new("LS");
    let mut time_t = ResultsTable::new("train+predict seconds");

    for spec in data::binary_suite() {
        let spec = data::SuiteSpec { n: (spec.n / 2).min(common::scaled(1400)), ..spec };
        let mut rng = Rng::seed_from(417);
        let (x, y, lik) = data::generate_suite_data(&spec, &mut rng);
        let n_test = spec.n / 4;
        let (tr, te) = data::train_test_split(&mut rng, spec.n, n_test);
        let (xtr, ytr) = (data::subset_rows(&x, &tr), data::subset_vec(&y, &tr));
        let (xte, yte) = (data::subset_rows(&x, &te), data::subset_vec(&y, &te));
        let labels: Vec<bool> = yte.iter().map(|&v| v > 0.5).collect();
        let d = x.cols();
        let smoothness = Smoothness::ThreeHalves;
        let base = VifConfig {
            smoothness,
            num_inducing: m,
            num_neighbors: m_v,
            seed: 1,
            ..Default::default()
        };
        for (name, cfg, precond) in [
            ("VIF", base.clone(), PrecondType::Fitc),
            ("Vecchia", baselines::vecchia_config(m_v, &base), PrecondType::Vifdu), // VADU
            ("FITC", baselines::fitc_config(m, &base), PrecondType::Fitc),
        ] {
            let mode = SolveMode::Iterative(IterConfig {
                precond,
                ell: 15,
                fitc_k: m,
                ..Default::default()
            });
            let init = ArdMatern::isotropic(1.0, 0.5, d, smoothness);
            let (pred, secs) = common::timed(|| {
                let mut model = VifLaplaceModel::new(
                    xtr.clone(),
                    ytr.clone(),
                    cfg,
                    mode,
                    init,
                    lik.clone(),
                );
                model.fit(iters);
                model.predict(&xte, PredVarMethod::Sbpv, 20)
            });
            auc_t.record(spec.name, name, metrics::auc(&pred.response_mean, &labels));
            brier_t.record(spec.name, name, metrics::brier_rmse(&pred.response_mean, &labels));
            acc_t.record(spec.name, name, metrics::accuracy(&pred.response_mean, &labels));
            ls_t.record(
                spec.name,
                name,
                metrics::log_score_bernoulli(&pred.response_mean, &labels),
            );
            time_t.record(spec.name, name, secs);
        }
        eprintln!("[tab2] {} done", spec.name);
    }
    println!("{}", auc_t.render());
    println!("{}", brier_t.render());
    println!("{}", acc_t.render());
    println!("{}", ls_t.render());
    println!("{}", time_t.render());
}
