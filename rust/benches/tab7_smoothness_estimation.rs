//! Table 7 / Fig 9 (left): estimating the Matérn smoothness ν instead of
//! fixing ν = 3/2, via golden-section search over the VIF profile
//! likelihood (general-ν kernels use the library's Bessel-K path).
//! Expected shape: estimating ν improves the log-score when the true
//! smoothness differs from 3/2, at extra runtime.

#[path = "common.rs"]
mod common;

use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::optim::golden_section;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::VifConfig;

fn main() {
    common::init_runtime();
    common::header("Table 7: Matérn smoothness estimation");
    let n_train = common::scaled(900);
    let n_test = common::scaled(400);
    let noise = 0.01;
    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "true nu", "LS(fix1.5)", "LS(est)", "nu_hat", "t_fix(s)", "t_est(s)"
    );
    for (label, true_nu) in [("1/2", Smoothness::Half), ("5/2", Smoothness::FiveHalves), ("inf", Smoothness::Gaussian)] {
        let w = common::simulate(
            99,
            n_train,
            n_test,
            2,
            true_nu,
            &Likelihood::Gaussian { variance: noise },
        );
        let config = |s: Smoothness| VifConfig {
            smoothness: s,
            num_inducing: 32,
            num_neighbors: 6,
            seed: 1,
            ..Default::default()
        };
        let fit_ls = |s: Smoothness| -> (f64, f64) {
            let init = GaussianParams {
                kernel: ArdMatern::isotropic(0.8, 0.3, 2, s),
                noise: 0.1,
            };
            let mut model = VifRegression::new(w.xtr.clone(), w.ytr.clone(), config(s), init);
            let nll = model.fit(12);
            let (mean, var) = model.predict(&w.xte);
            (metrics::log_score_gaussian(&mean, &var, &w.yte), nll)
        };
        // fixed ν = 3/2
        let ((ls_fixed, _), t_fixed) = common::timed(|| fit_ls(Smoothness::ThreeHalves));
        // estimate ν: profile the fitted NLL over log ν ∈ [log 0.3, log 4]
        let (nu_hat, t_est) = common::timed(|| {
            let obj = |log_nu: f64| -> f64 {
                let s = Smoothness::canonical(log_nu.exp());
                fit_ls(s).1
            };
            let (log_nu, _) = golden_section(&obj, (0.3f64).ln(), (4.0f64).ln(), 8);
            log_nu.exp()
        });
        let (ls_est, _) = fit_ls(Smoothness::canonical(nu_hat));
        println!(
            "{:<14} {:>10.4} {:>10.4} {:>10.3} {:>10.1} {:>10.1}",
            label, ls_fixed, ls_est, nu_hat, t_fixed, t_est
        );
    }
}
