//! Figure 7: cover-tree construction + m_v-nearest-neighbor search time
//! under the correlation distance, for varying n, d, m, and m_v.
//! Expected shape: dominated by n and d; ~linear in m (the O(m)
//! correlation evaluations); weak dependence on m_v.

#[path = "common.rs"]
mod common;

use vifgp::data;
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::{select_inducing, select_neighbors, LowRank};

fn run(n: usize, d: usize, m: usize, m_v: usize) -> f64 {
    let mut rng = Rng::seed_from(4);
    let x = data::uniform_inputs(&mut rng, n, d);
    let kernel = ArdMatern::new(
        1.0,
        data::paper_length_scales(d, Smoothness::ThreeHalves),
        Smoothness::ThreeHalves,
    );
    let z = select_inducing(&x, &kernel, m, 2, &mut rng, None);
    let lr = z.map(|z| LowRank::build(&x, &kernel, z, 1e-10));
    let (_, secs) = common::timed(|| {
        select_neighbors(
            &x,
            &kernel,
            lr.as_ref(),
            m_v,
            NeighborSelection::CorrelationCoverTree,
        )
    });
    secs
}

fn main() {
    common::init_runtime();
    common::header("Fig 7: cover-tree construction + correlation kNN search time");
    let base_n = common::scaled(8000);
    let (base_d, base_m, base_mv) = (5usize, 64usize, 10usize);

    println!("--- vary n (d={base_d}, m={base_m}, mv={base_mv}) ---");
    for n in [base_n / 8, base_n / 4, base_n / 2, base_n] {
        println!("n={n:<8} {:>8.2}s", run(n, base_d, base_m, base_mv));
    }
    println!("--- vary d (n={}) ---", base_n / 2);
    for d in [2usize, 5, 10, 20] {
        println!("d={d:<8} {:>8.2}s", run(base_n / 2, d, base_m, base_mv));
    }
    println!("--- vary m (n={}) ---", base_n / 2);
    for m in [8usize, 32, 64, 128] {
        println!("m={m:<8} {:>8.2}s", run(base_n / 2, base_d, m, base_mv));
    }
    println!("--- vary mv (n={}) ---", base_n / 2);
    for mv in [2usize, 5, 10, 20, 30] {
        println!("mv={mv:<7} {:>8.2}s", run(base_n / 2, base_d, base_m, mv));
    }
}
