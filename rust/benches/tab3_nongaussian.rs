//! Table 3: Poisson / Student-t / Gamma regression suite — VIF-Laplace
//! vs Vecchia-Laplace vs FITC-Laplace with iterative methods on the
//! synthetic substitutes. Expected shape: VIF best or tied on accuracy.

#[path = "common.rs"]
mod common;

use vifgp::baselines;
use vifgp::coordinator::ResultsTable;
use vifgp::data;
use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vif::laplace::{PredVarMethod, SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

fn main() {
    common::init_runtime();
    common::header("Table 3: non-Gaussian regression suite (synthetic substitutes)");
    let (m, m_v, iters) = (32usize, 6usize, 8usize);
    let mut rmse_t = ResultsTable::new("RMSE (response)");
    let mut ls_t = ResultsTable::new("LS (predictive log-score)");
    let mut time_t = ResultsTable::new("train+predict seconds");

    for spec in data::nongaussian_suite() {
        let spec = data::SuiteSpec { n: (spec.n / 2).min(common::scaled(1200)), ..spec };
        let mut rng = Rng::seed_from(1213);
        let (x, y, lik) = data::generate_suite_data(&spec, &mut rng);
        let n_test = spec.n / 4;
        let (tr, te) = data::train_test_split(&mut rng, spec.n, n_test);
        let (xtr, ytr) = (data::subset_rows(&x, &tr), data::subset_vec(&y, &tr));
        let (xte, yte) = (data::subset_rows(&x, &te), data::subset_vec(&y, &te));
        let d = x.cols();
        let smoothness = Smoothness::ThreeHalves;
        let base = VifConfig {
            smoothness,
            num_inducing: m,
            num_neighbors: m_v,
            seed: 1,
            ..Default::default()
        };
        for (name, cfg, precond) in [
            ("VIF", base.clone(), PrecondType::Fitc),
            ("Vecchia", baselines::vecchia_config(m_v, &base), PrecondType::Vifdu),
            ("FITC", baselines::fitc_config(m, &base), PrecondType::Fitc),
        ] {
            let mode = SolveMode::Iterative(IterConfig {
                precond,
                ell: 15,
                fitc_k: m,
                ..Default::default()
            });
            let init = ArdMatern::isotropic(1.0, 0.5, d, smoothness);
            let ((pred, fitted_lik), secs) = common::timed(|| {
                let mut model = VifLaplaceModel::new(
                    xtr.clone(),
                    ytr.clone(),
                    cfg,
                    mode,
                    init,
                    lik.clone(),
                );
                model.fit(iters);
                (model.predict(&xte, PredVarMethod::Sbpv, 20), model.lik.clone())
            });
            rmse_t.record(spec.name, name, metrics::rmse(&pred.response_mean, &yte));
            ls_t.record(
                spec.name,
                name,
                fitted_lik.log_score(&yte, &pred.latent_mean, &pred.latent_var),
            );
            time_t.record(spec.name, name, secs);
        }
        eprintln!("[tab3] {} done", spec.name);
    }
    println!("{}", rmse_t.render());
    println!("{}", ls_t.render());
    println!("{}", time_t.render());
}
