//! Table 1 (+ Fig 8 left, Table 8): Gaussian-likelihood regression suite
//! — VIF vs SGPR vs FITC vs Vecchia on the synthetic substitutes for the
//! UCI/OpenML data sets (DESIGN.md §Substitutions).
//! Expected shape: VIF best or tied everywhere; Vecchia strong at low d,
//! inducing-point methods stronger at high d.

#[path = "common.rs"]
mod common;

use vifgp::baselines::{self, SgprModel};
use vifgp::coordinator::ResultsTable;
use vifgp::data;
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::VifConfig;

fn main() {
    common::init_runtime();
    common::header("Table 1: regression suite (synthetic UCI substitutes)");
    let (m, m_v, iters) = (48usize, 8usize, 12usize);
    let mut rmse_t = ResultsTable::new("RMSE");
    let mut ls_t = ResultsTable::new("log-score (LS)");
    let mut crps_t = ResultsTable::new("CRPS");
    let mut time_t = ResultsTable::new("train+predict seconds");

    for spec in data::regression_suite() {
        // scale down further for the bench budget
        let spec = data::SuiteSpec { n: (spec.n / 2).min(common::scaled(2000)), ..spec };
        let mut rng = Rng::seed_from(911);
        let (x, y, _) = data::generate_suite_data(&spec, &mut rng);
        let n_test = spec.n / 4;
        let (tr, te) = data::train_test_split(&mut rng, spec.n, n_test);
        let (xtr, ytr) = (data::subset_rows(&x, &tr), data::subset_vec(&y, &tr));
        let (xte, yte) = (data::subset_rows(&x, &te), data::subset_vec(&y, &te));
        let d = x.cols();
        let smoothness = Smoothness::ThreeHalves;
        let init = GaussianParams {
            kernel: ArdMatern::isotropic(1.0, 0.5, d, smoothness),
            noise: 0.3,
        };
        let base = VifConfig {
            smoothness,
            num_inducing: m,
            num_neighbors: m_v,
            seed: 1,
            ..Default::default()
        };
        let configs: Vec<(&str, VifConfig)> = vec![
            ("VIF", base.clone()),
            ("Vecchia", baselines::vecchia_config(m_v, &base)),
            ("FITC", baselines::fitc_config(m, &base)),
        ];
        for (name, cfg) in configs {
            let ((mean, var), secs) = common::timed(|| {
                let mut model = VifRegression::new(xtr.clone(), ytr.clone(), cfg, init.clone());
                model.fit(iters);
                model.predict(&xte)
            });
            rmse_t.record(spec.name, name, metrics::rmse(&mean, &yte));
            ls_t.record(spec.name, name, metrics::log_score_gaussian(&mean, &var, &yte));
            crps_t.record(spec.name, name, metrics::crps_gaussian(&mean, &var, &yte));
            time_t.record(spec.name, name, secs);
        }
        // SGPR baseline
        let ((mean, var), secs) = common::timed(|| {
            let model = SgprModel::fit(&xtr, &ytr, m, smoothness, init.kernel.clone(), 0.3, iters, 1);
            model.predict(&xte)
        });
        rmse_t.record(spec.name, "SGPR", metrics::rmse(&mean, &yte));
        ls_t.record(spec.name, "SGPR", metrics::log_score_gaussian(&mean, &var, &yte));
        crps_t.record(spec.name, "SGPR", metrics::crps_gaussian(&mean, &var, &yte));
        time_t.record(spec.name, "SGPR", secs);
        eprintln!("[tab1] {} done", spec.name);
    }
    println!("{}", rmse_t.render());
    println!("{}", ls_t.render());
    println!("{}", crps_t.render());
    println!("{}", time_t.render());
}
