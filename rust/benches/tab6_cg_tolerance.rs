//! Table 6: SLQ log-marginal-likelihood accuracy and runtime across the
//! CG convergence tolerance δ and the number of probe vectors ℓ, for
//! both preconditioners. Expected shape: δ below 0.01 buys nothing;
//! ℓ drives accuracy more than δ.

#[path = "common.rs"]
mod common;

use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{nll, SolveMode};
use vifgp::vif::{select_inducing, select_neighbors, LowRank, VifStructure};

fn main() {
    common::init_runtime();
    common::header("Table 6: CG tolerance δ × probes ℓ grid");
    let n = common::scaled(1200);
    let (m, m_v) = (48usize, 8usize);
    let lik = Likelihood::BernoulliLogit;
    let w = common::simulate(5, n, 8, 5, Smoothness::Gaussian, &lik);
    let reps = 5;

    let mut rng = Rng::seed_from(61);
    let z = select_inducing(&w.xtr, &w.kernel, m, 3, &mut rng, None);
    let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
    let nb = select_neighbors(
        &w.xtr,
        &w.kernel,
        lr.as_ref(),
        m_v,
        NeighborSelection::CorrelationCoverTree,
    );
    let s = VifStructure::assemble(&w.xtr, &w.kernel, z, nb, 0.0, 1e-10, 0);
    let (reference, _) = nll(&s, &w.xtr, &w.kernel, &lik, &w.ytr, &SolveMode::Cholesky, &mut rng);
    println!("Cholesky reference L = {reference:.4}");
    println!(
        "{:<8} {:<10} {:>6} {:>9} {:>14} {:>10}",
        "precond", "delta", "ell", "min_iter", "RMSE(loglik)", "time(s)"
    );
    for precond in [PrecondType::Fitc, PrecondType::Vifdu] {
        for delta in [1.0f64, 0.1, 0.01, 0.001] {
            for ell in [10usize, 50] {
                // Sweep the Lanczos-degree floor: a loose δ with a small
                // floor biases the log quadrature (EXPERIMENTS.md §Fig 4
                // note); the default 25 removes that bias.
                for min_iter in [5usize, 25] {
                    let mut sq = 0.0;
                    let mut secs = 0.0;
                    for rep in 0..reps {
                        let cfg = IterConfig {
                            precond,
                            ell,
                            cg_tol: delta,
                            max_cg: 500,
                            fitc_k: m,
                            slq_min_iter: min_iter,
                            seed: 500 + rep,
                        };
                        let ((got, _), dt) = common::timed(|| {
                            nll(
                                &s,
                                &w.xtr,
                                &w.kernel,
                                &lik,
                                &w.ytr,
                                &SolveMode::Iterative(cfg),
                                &mut rng,
                            )
                        });
                        sq += (got - reference) * (got - reference);
                        secs += dt;
                    }
                    println!(
                        "{:<8} {:<10} {:>6} {:>9} {:>14.4} {:>10.2}",
                        format!("{precond:?}"),
                        delta,
                        ell,
                        min_iter,
                        (sq / reps as f64).sqrt(),
                        secs / reps as f64
                    );
                }
            }
        }
    }
}
