//! Figure 6: marginal-likelihood evaluation time vs sample size n,
//! inducing points m, and Vecchia neighbors m_v — Gaussian (top row) and
//! Bernoulli (bottom row) likelihoods; VIF(FITC-precond), VIF(VIFDU),
//! FITC, and Vecchia(VADU).
//! Expected shape: ~linear in n; FITC-precond ≤ VIFDU; VIF ≈ Vecchia.

#[path = "common.rs"]
mod common;

use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::Smoothness;
use vifgp::likelihoods::Likelihood;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{nll as laplace_nll, SolveMode};
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure};

struct Config {
    name: &'static str,
    m: usize,
    m_v: usize,
    precond: PrecondType,
}

fn main() {
    common::init_runtime();
    common::header("Fig 6: log-likelihood evaluation time scaling");
    let base_n = common::scaled(4000);
    let (base_m, base_mv) = (64usize, 10usize);

    println!("--- vary n (m={base_m}, mv={base_mv}) ---");
    print_header();
    for n in [base_n / 4, base_n / 2, base_n, base_n * 2] {
        run_row(&format!("n={n}"), n, base_m, base_mv);
    }
    println!("--- vary m (n={base_n}, mv={base_mv}) ---");
    print_header();
    for m in [8usize, 32, 64, 128] {
        run_row(&format!("m={m}"), base_n, m, base_mv);
    }
    println!("--- vary mv (n={base_n}, m={base_m}) ---");
    print_header();
    for mv in [2usize, 5, 10, 20] {
        run_row(&format!("mv={mv}"), base_n, base_m, mv);
    }
}

fn print_header() {
    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>14} | {:>14} {:>14}",
        "", "VIF-G(s)", "FITC-G(s)", "Vecchia-G(s)", "", "VIF-FITCp(s)", "VIF-VIFDUp(s)"
    );
}

fn run_row(label: &str, n: usize, m: usize, m_v: usize) {
    let lik_g = Likelihood::Gaussian { variance: 0.05 };
    let w = common::simulate(9, n, 8, 5, Smoothness::Gaussian, &lik_g);
    let configs = [
        Config { name: "VIF", m, m_v, precond: PrecondType::Fitc },
        Config { name: "FITC", m, m_v: 0, precond: PrecondType::Fitc },
        Config { name: "Vecchia", m: 0, m_v, precond: PrecondType::Vifdu }, // VADU
    ];
    // Gaussian likelihood: exact (Cholesky-free) VIF evaluation.
    let mut gauss_times = Vec::new();
    let mut structures = Vec::new();
    for c in &configs {
        let mut rng = Rng::seed_from(3);
        let z = select_inducing(&w.xtr, &w.kernel, c.m, 2, &mut rng, None);
        let lr = z.clone().map(|z| LowRank::build(&w.xtr, &w.kernel, z, 1e-10));
        let nb = select_neighbors(
            &w.xtr,
            &w.kernel,
            lr.as_ref(),
            c.m_v,
            NeighborSelection::CorrelationCoverTree,
        );
        // time the structure assembly + evaluation (neighbor search excluded
        // as in the paper)
        let (s, t_build) = common::timed(|| {
            VifStructure::assemble(&w.xtr, &w.kernel, z.clone(), nb.clone(), 0.05, 1e-10, 1)
        });
        let (_, t_eval) = common::timed(|| gaussian::nll(&s, &w.ytr));
        gauss_times.push(t_build + t_eval);
        // latent structure for the Bernoulli leg
        let (sl, _) = common::timed(|| {
            VifStructure::assemble(&w.xtr, &w.kernel, z, nb, 0.0, 1e-10, 0)
        });
        structures.push(sl);
        let _ = s;
    }
    // Bernoulli: iterative VIFLA with FITC and VIFDU preconditioners on
    // the VIF structure.
    let lik_b = Likelihood::BernoulliLogit;
    let yb: Vec<f64> = {
        let mut rng = Rng::seed_from(77);
        vifgp::data::simulate_response(&mut rng, &w.latent_tr, &lik_b)
    };
    let mut iter_times = Vec::new();
    for precond in [PrecondType::Fitc, PrecondType::Vifdu] {
        let cfg = IterConfig {
            precond,
            ell: 20,
            cg_tol: 1e-2,
            max_cg: 300,
            fitc_k: m.max(8),
            slq_min_iter: 25,
            seed: 5,
        };
        let mut rng = Rng::seed_from(11);
        let (_, dt) = common::timed(|| {
            laplace_nll(
                &structures[0],
                &w.xtr,
                &w.kernel,
                &lik_b,
                &yb,
                &SolveMode::Iterative(cfg),
                &mut rng,
            )
        });
        iter_times.push(dt);
    }
    println!(
        "{:<10} {:>14.2} {:>14.2} {:>14.2} {:>14} | {:>14.2} {:>14.2}",
        label, gauss_times[0], gauss_times[1], gauss_times[2], "", iter_times[0], iter_times[1]
    );
}
