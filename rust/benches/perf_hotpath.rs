//! §Perf: hot-path micro/macro profile used by the performance pass
//! (EXPERIMENTS.md §Perf). Times the pipeline stages that dominate a
//! marginal-likelihood evaluation:
//!   covariance panels (PJRT vs native), low-rank solves, residual B/D
//!   construction, CG matvec, and the full Gaussian NLL at scale.

#[path = "common.rs"]
mod common;

use vifgp::data;
use vifgp::iterative::{
    pcg_with_min, slq_logdet, FitcPrecond, LinOp, Preconditioner, VifduPrecond,
};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::linalg::dot;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{OpWPlusPrec, OpWinvPlusCov};
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure, VifResidualOracle};
use vifgp::vecchia::ResidualFactor;

/// The seed's per-probe SLQ loop (one sequential `pcg_with_min` per
/// probe), kept as the baseline the batched engine is measured against.
fn slq_sequential(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    ell: usize,
    rng: &mut Rng,
    cg_tol: f64,
    max_cg: usize,
) -> f64 {
    let mut acc = 0.0;
    for _ in 0..ell {
        let z = pre.sample(rng);
        let pinv_z = pre.solve(&z);
        let norm2 = dot(&z, &pinv_z);
        let min_iter = 25.min(op.n());
        let res = pcg_with_min(op, pre, &z, cg_tol, min_iter, max_cg, true);
        let t = res.tridiag.expect("tridiag requested");
        acc += norm2 * t.quadrature(|lam| lam.max(1e-300).ln());
    }
    acc / ell as f64 + pre.logdet()
}

fn main() {
    common::header("§Perf: hot-path stage timings");
    let n = common::scaled(10_000);
    let (d, m, m_v) = (5usize, 100usize, 15usize);
    let mut rng = Rng::seed_from(1);
    let x = data::uniform_inputs(&mut rng, n, d);
    let kernel = ArdMatern::new(
        1.0,
        data::paper_length_scales(d, Smoothness::ThreeHalves),
        Smoothness::ThreeHalves,
    );
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    // 1. covariance panel: native vs PJRT
    let z = select_inducing(&x, &kernel, m, 3, &mut rng, None).unwrap();
    vifgp::runtime::init_from_artifacts(&vifgp::runtime::default_artifact_dir());
    let (_, t_native) = common::timed(|| kernel.cross_cov(&x, &z));
    println!("cov panel {n}x{m} native:        {t_native:.3}s");
    if let Some(engine) = vifgp::runtime::engine() {
        let (res, t_pjrt) = common::timed(|| engine.cross_cov(&x, &z, &kernel));
        let _ = res;
        println!("cov panel {n}x{m} PJRT/artifact: {t_pjrt:.3}s");
    }

    // 2. low-rank build (panel + triangular solves)
    let (lr, t_lr) = common::timed(|| LowRank::build(&x, &kernel, z.clone(), 1e-10));
    println!("LowRank::build (m={m}):          {t_lr:.3}s");

    // 3. neighbor search (cover tree, correlation metric)
    let (nb, t_nb) = common::timed(|| {
        select_neighbors(&x, &kernel, Some(&lr), m_v, NeighborSelection::CorrelationCoverTree)
    });
    println!("cover-tree neighbors (mv={m_v}):   {t_nb:.3}s");

    // 4. residual B/D construction
    let oracle = VifResidualOracle { kernel: &kernel, x: &x, lr: Some(&lr), grad_aux: None, extra_params: 0 };
    let (resid, t_bd) = common::timed(|| ResidualFactor::build(&oracle, nb.clone(), 0.05, 1e-10));
    println!("residual B/D build:              {t_bd:.3}s");

    // 5. full structure + NLL
    let (s, t_asm) = common::timed(|| {
        VifStructure::assemble(&x, &kernel, Some(z.clone()), nb.clone(), 0.05, 1e-10, 1)
    });
    println!("VifStructure::assemble:          {t_asm:.3}s");
    let (nll_v, t_nll) = common::timed(|| gaussian::nll(&s, &y));
    println!("gaussian::nll (apply+logdet):    {t_nll:.3}s  (value {nll_v:.1})");

    // 6. Σ_†⁻¹ matvec (the CG hot op)
    let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
    let reps = 50;
    let (_, t_mv) = common::timed(|| {
        let mut acc = 0.0;
        for _ in 0..reps {
            let w = s.apply_sigma_dagger_inv(&v);
            acc += w[0];
        }
        acc
    });
    println!(
        "Σ_†⁻¹ matvec: {:.3} ms/op ({} reps)",
        1e3 * t_mv / reps as f64,
        reps
    );

    // 7. gradient evaluation (the optimizer hot path)
    let (_, t_grad) = common::timed(|| gaussian::nll_and_grad(&s, &x, &kernel, &y));
    println!("gaussian::nll_and_grad:          {t_grad:.3}s");

    // 8. SLQ log-determinant: batched multi-probe engine vs the seed's
    // sequential per-probe loop, on the same probe seeds (ℓ = 20).
    let ell = 20usize;
    let wvec: Vec<f64> = (0..n)
        .map(|i| 0.2 + 0.05 * ((i as f64 * 0.13).sin().abs()))
        .collect();
    {
        let op = OpWPlusPrec { s: &s, w: &wvec };
        let pre = VifduPrecond::new(&s, &wvec);
        let (ld_seq, t_seq) = common::timed(|| {
            let mut r = Rng::seed_from(42);
            slq_sequential(&op, &pre, ell, &mut r, 1e-2, 200)
        });
        let (run, t_bat) = common::timed(|| {
            let mut r = Rng::seed_from(42);
            slq_logdet(&op, &pre, ell, &mut r, 1e-2, 200)
        });
        println!(
            "SLQ logdet VIFDU (l={ell}): seq {t_seq:.3}s ({ld_seq:.1})  batched {t_bat:.3}s ({:.1})  speedup {:.2}x",
            run.logdet,
            t_seq / t_bat.max(1e-9)
        );
    }
    {
        let op = OpWinvPlusCov { s: &s, w: &wvec };
        let pre = FitcPrecond::new(&x, &kernel, m, &wvec, 7);
        let (ld_seq, t_seq) = common::timed(|| {
            let mut r = Rng::seed_from(42);
            slq_sequential(&op, &pre, ell, &mut r, 1e-2, 200)
        });
        let (run, t_bat) = common::timed(|| {
            let mut r = Rng::seed_from(42);
            slq_logdet(&op, &pre, ell, &mut r, 1e-2, 200)
        });
        println!(
            "SLQ logdet FITC  (l={ell}): seq {t_seq:.3}s ({ld_seq:.1})  batched {t_bat:.3}s ({:.1})  speedup {:.2}x",
            run.logdet,
            t_seq / t_bat.max(1e-9)
        );
    }

    // 9. Vecchia B sweeps: level-scheduled vs sequential (the innermost
    // loop of every operator apply and of both preconditioners). One
    // vector round trip (BᵀB product + B⁻ᵀB⁻¹ solve) and one 16-column
    // block round trip per rep; results are bit-identical, so only time
    // should differ.
    {
        use vifgp::linalg::Mat;
        use vifgp::vecchia::SweepExec;
        let pool = vifgp::coordinator::global_pool();
        let workers = vifgp::coordinator::num_threads();
        println!(
            "B level schedule: {} levels (max width {}) for n={n}, mv={m_v}, {workers} workers",
            resid.schedule().num_levels(),
            resid.schedule().max_width()
        );
        let vv: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let x16 = Mat::from_fn(n, 16, |i, j| ((i * 3 + j * 11) as f64 * 0.19).sin());
        let reps = 30;
        let time_vec = |exec: SweepExec<'_>| {
            common::timed(|| {
                let mut acc = 0.0;
                for _ in 0..reps {
                    let w = resid.mul_bt_with(&resid.mul_b_with(&vv, exec), exec);
                    let u = resid.solve_b_with(&resid.solve_bt_with(&w, exec), exec);
                    acc += u[n - 1];
                }
                acc
            })
        };
        let time_mat = |exec: SweepExec<'_>| {
            common::timed(|| {
                let mut acc = 0.0;
                for _ in 0..reps / 5 {
                    let w = resid.mul_bt_mat_with(&resid.mul_b_mat_with(&x16, exec), exec);
                    let u = resid.solve_b_mat_with(&resid.solve_bt_mat_with(&w, exec), exec);
                    acc += u.get(n - 1, 0);
                }
                acc
            })
        };
        let (a_seq, t_vec_seq) = time_vec(SweepExec::Seq);
        let (a_sch, t_vec_sch) = time_vec(SweepExec::Pool(pool, workers));
        assert_eq!(a_seq.to_bits(), a_sch.to_bits(), "scheduled vec sweep diverged");
        let (b_seq, t_mat_seq) = time_mat(SweepExec::Seq);
        let (b_sch, t_mat_sch) = time_mat(SweepExec::Pool(pool, workers));
        assert_eq!(b_seq.to_bits(), b_sch.to_bits(), "scheduled mat sweep diverged");
        println!(
            "B sweeps vec:   seq {:.3} ms/op  scheduled {:.3} ms/op  speedup {:.2}x",
            1e3 * t_vec_seq / reps as f64,
            1e3 * t_vec_sch / reps as f64,
            t_vec_seq / t_vec_sch.max(1e-9)
        );
        println!(
            "B sweeps mat16: seq {:.3} ms/op  scheduled {:.3} ms/op  speedup {:.2}x",
            1e3 * t_mat_seq / (reps / 5) as f64,
            1e3 * t_mat_sch / (reps / 5) as f64,
            t_mat_seq / t_mat_sch.max(1e-9)
        );
    }
}
