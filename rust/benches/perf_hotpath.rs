//! §Perf: hot-path micro/macro profile used by the performance pass
//! (EXPERIMENTS.md §Perf). Times the pipeline stages that dominate a
//! marginal-likelihood evaluation:
//!   covariance panels (PJRT vs native), low-rank solves, residual B/D
//!   construction, CG matvec, and the full Gaussian NLL at scale.

#[path = "common.rs"]
mod common;

use vifgp::data;
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure, VifResidualOracle};
use vifgp::vecchia::ResidualFactor;

fn main() {
    common::header("§Perf: hot-path stage timings");
    let n = common::scaled(10_000);
    let (d, m, m_v) = (5usize, 100usize, 15usize);
    let mut rng = Rng::seed_from(1);
    let x = data::uniform_inputs(&mut rng, n, d);
    let kernel = ArdMatern::new(
        1.0,
        data::paper_length_scales(d, Smoothness::ThreeHalves),
        Smoothness::ThreeHalves,
    );
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    // 1. covariance panel: native vs PJRT
    let z = select_inducing(&x, &kernel, m, 3, &mut rng, None).unwrap();
    vifgp::runtime::init_from_artifacts(&vifgp::runtime::default_artifact_dir());
    let (_, t_native) = common::timed(|| kernel.cross_cov(&x, &z));
    println!("cov panel {n}x{m} native:        {t_native:.3}s");
    if let Some(engine) = vifgp::runtime::engine() {
        let (res, t_pjrt) = common::timed(|| engine.cross_cov(&x, &z, &kernel));
        let _ = res;
        println!("cov panel {n}x{m} PJRT/artifact: {t_pjrt:.3}s");
    }

    // 2. low-rank build (panel + triangular solves)
    let (lr, t_lr) = common::timed(|| LowRank::build(&x, &kernel, z.clone(), 1e-10));
    println!("LowRank::build (m={m}):          {t_lr:.3}s");

    // 3. neighbor search (cover tree, correlation metric)
    let (nb, t_nb) = common::timed(|| {
        select_neighbors(&x, &kernel, Some(&lr), m_v, NeighborSelection::CorrelationCoverTree)
    });
    println!("cover-tree neighbors (mv={m_v}):   {t_nb:.3}s");

    // 4. residual B/D construction
    let oracle = VifResidualOracle { kernel: &kernel, x: &x, lr: Some(&lr), grad_aux: None, extra_params: 0 };
    let (resid, t_bd) = common::timed(|| ResidualFactor::build(&oracle, nb.clone(), 0.05, 1e-10));
    println!("residual B/D build:              {t_bd:.3}s");
    let _ = resid;

    // 5. full structure + NLL
    let (s, t_asm) = common::timed(|| {
        VifStructure::assemble(&x, &kernel, Some(z.clone()), nb.clone(), 0.05, 1e-10, 1)
    });
    println!("VifStructure::assemble:          {t_asm:.3}s");
    let (nll_v, t_nll) = common::timed(|| gaussian::nll(&s, &y));
    println!("gaussian::nll (apply+logdet):    {t_nll:.3}s  (value {nll_v:.1})");

    // 6. Σ_†⁻¹ matvec (the CG hot op)
    let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
    let reps = 50;
    let (_, t_mv) = common::timed(|| {
        let mut acc = 0.0;
        for _ in 0..reps {
            let w = s.apply_sigma_dagger_inv(&v);
            acc += w[0];
        }
        acc
    });
    println!(
        "Σ_†⁻¹ matvec: {:.3} ms/op ({} reps)",
        1e3 * t_mv / reps as f64,
        reps
    );

    // 7. gradient evaluation (the optimizer hot path)
    let (_, t_grad) = common::timed(|| gaussian::nll_and_grad(&s, &x, &kernel, &y));
    println!("gaussian::nll_and_grad:          {t_grad:.3}s");
}
