//! §Perf: hot-path micro/macro profile used by the performance pass
//! (EXPERIMENTS.md §Perf). Times the pipeline stages that dominate a
//! marginal-likelihood evaluation:
//!   covariance panels (PJRT vs native), low-rank solves, residual B/D
//!   construction, CG matvec, and the full Gaussian NLL at scale.
//! Also covers the serving-side pipelines: plan/refresh trajectories,
//! panelized batched prediction, streaming append ingestion vs
//! assemble-from-scratch (stage 13, BENCH_append.json), and the
//! concurrent serving engine's latency/throughput sweep with generation
//! swaps under load (stage 14, BENCH_serving.json), the per-kernel
//! GFLOP/s trajectory of the SIMD lane backend vs the scalar oracle
//! (stage 16, BENCH_kernels.json), and the warm-started fit trajectory —
//! cold vs warm `FitSession` over 20 objective evaluations (stage 17,
//! BENCH_fit.json).

#[path = "common.rs"]
mod common;

use vifgp::data;
use vifgp::iterative::{
    pcg_with_min, slq_logdet, FitcPrecond, LinOp, Preconditioner, VifduPrecond,
};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::linalg::dot;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{OpWPlusPrec, OpWinvPlusCov};
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure, VifResidualOracle};
use vifgp::vecchia::ResidualFactor;

/// The seed's per-probe SLQ loop (one sequential `pcg_with_min` per
/// probe), kept as the baseline the batched engine is measured against.
fn slq_sequential(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    ell: usize,
    rng: &mut Rng,
    cg_tol: f64,
    max_cg: usize,
) -> f64 {
    let mut acc = 0.0;
    for _ in 0..ell {
        let z = pre.sample(rng);
        let pinv_z = pre.solve(&z);
        let norm2 = dot(&z, &pinv_z);
        let min_iter = 25.min(op.n());
        let res = pcg_with_min(op, pre, &z, cg_tol, min_iter, max_cg, true);
        let t = res.tridiag.expect("tridiag requested");
        acc += norm2 * t.quadrature(|lam| lam.max(1e-300).ln());
    }
    acc / ell as f64 + pre.logdet()
}

fn main() {
    common::header("§Perf: hot-path stage timings");
    let n = common::scaled(10_000);
    let (d, m, m_v) = (5usize, 100usize, 15usize);
    let mut rng = Rng::seed_from(1);
    let x = data::uniform_inputs(&mut rng, n, d);
    let kernel = ArdMatern::new(
        1.0,
        data::paper_length_scales(d, Smoothness::ThreeHalves),
        Smoothness::ThreeHalves,
    );
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    // 1. covariance panel: native vs PJRT
    let z = select_inducing(&x, &kernel, m, 3, &mut rng, None).unwrap();
    vifgp::runtime::init_from_artifacts(&vifgp::runtime::default_artifact_dir());
    let (_, t_native) = common::timed(|| kernel.cross_cov(&x, &z));
    println!("cov panel {n}x{m} native:        {t_native:.3}s");
    if let Some(engine) = vifgp::runtime::engine() {
        let (res, t_pjrt) = common::timed(|| engine.cross_cov(&x, &z, &kernel));
        let _ = res;
        println!("cov panel {n}x{m} PJRT/artifact: {t_pjrt:.3}s");
    }

    // 2. low-rank build (panel + triangular solves)
    let (lr, t_lr) = common::timed(|| LowRank::build(&x, &kernel, z.clone(), 1e-10));
    println!("LowRank::build (m={m}):          {t_lr:.3}s");

    // 3. neighbor search (cover tree, correlation metric)
    let (nb, t_nb) = common::timed(|| {
        select_neighbors(&x, &kernel, Some(&lr), m_v, NeighborSelection::CorrelationCoverTree)
    });
    println!("cover-tree neighbors (mv={m_v}):   {t_nb:.3}s");

    // 4. residual B/D construction
    let oracle = VifResidualOracle {
        kernel: &kernel,
        x: &x,
        lr: Some(&lr),
        grad_aux: None,
        extra_params: 0,
        x_panels: None,
    };
    let (resid, t_bd) = common::timed(|| ResidualFactor::build(&oracle, nb.clone(), 0.05, 1e-10));
    println!("residual B/D build:              {t_bd:.3}s");

    // 5. full structure + NLL
    let (s, t_asm) = common::timed(|| {
        VifStructure::assemble(&x, &kernel, Some(z.clone()), nb.clone(), 0.05, 1e-10, 1)
    });
    println!("VifStructure::assemble:          {t_asm:.3}s");
    let (nll_v, t_nll) = common::timed(|| gaussian::nll(&s, &y));
    println!("gaussian::nll (apply+logdet):    {t_nll:.3}s  (value {nll_v:.1})");

    // 6. Σ_†⁻¹ matvec (the CG hot op)
    let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
    let reps = 50;
    let (_, t_mv) = common::timed(|| {
        let mut acc = 0.0;
        for _ in 0..reps {
            let w = s.apply_sigma_dagger_inv(&v);
            acc += w[0];
        }
        acc
    });
    println!(
        "Σ_†⁻¹ matvec: {:.3} ms/op ({} reps)",
        1e3 * t_mv / reps as f64,
        reps
    );

    // 7. gradient evaluation (the optimizer hot path)
    let (_, t_grad) = common::timed(|| gaussian::nll_and_grad(&s, &x, &kernel, &y));
    println!("gaussian::nll_and_grad:          {t_grad:.3}s");

    // 8. SLQ log-determinant: batched multi-probe engine vs the seed's
    // sequential per-probe loop, on the same probe seeds (ℓ = 20).
    let ell = 20usize;
    let wvec: Vec<f64> = (0..n)
        .map(|i| 0.2 + 0.05 * ((i as f64 * 0.13).sin().abs()))
        .collect();
    {
        let op = OpWPlusPrec { s: &s, w: &wvec };
        let pre = VifduPrecond::new(&s, &wvec);
        let (ld_seq, t_seq) = common::timed(|| {
            let mut r = Rng::seed_from(42);
            slq_sequential(&op, &pre, ell, &mut r, 1e-2, 200)
        });
        let (run, t_bat) = common::timed(|| {
            let mut r = Rng::seed_from(42);
            slq_logdet(&op, &pre, ell, &mut r, 1e-2, 200)
        });
        println!(
            "SLQ logdet VIFDU (l={ell}): seq {t_seq:.3}s ({ld_seq:.1})  batched {t_bat:.3}s ({:.1})  speedup {:.2}x",
            run.logdet,
            t_seq / t_bat.max(1e-9)
        );
    }
    {
        let op = OpWinvPlusCov { s: &s, w: &wvec };
        let pre = FitcPrecond::new(&x, &kernel, m, &wvec, 7);
        let (ld_seq, t_seq) = common::timed(|| {
            let mut r = Rng::seed_from(42);
            slq_sequential(&op, &pre, ell, &mut r, 1e-2, 200)
        });
        let (run, t_bat) = common::timed(|| {
            let mut r = Rng::seed_from(42);
            slq_logdet(&op, &pre, ell, &mut r, 1e-2, 200)
        });
        println!(
            "SLQ logdet FITC  (l={ell}): seq {t_seq:.3}s ({ld_seq:.1})  batched {t_bat:.3}s ({:.1})  speedup {:.2}x",
            run.logdet,
            t_seq / t_bat.max(1e-9)
        );
    }

    // 9. Vecchia B sweeps: level-scheduled vs sequential (the innermost
    // loop of every operator apply and of both preconditioners). One
    // vector round trip (BᵀB product + B⁻ᵀB⁻¹ solve) and one 16-column
    // block round trip per rep; results are bit-identical, so only time
    // should differ.
    {
        use vifgp::linalg::Mat;
        use vifgp::vecchia::SweepExec;
        let pool = vifgp::coordinator::global_pool();
        let workers = vifgp::coordinator::num_threads();
        println!(
            "B level schedule: {} levels (max width {}) for n={n}, mv={m_v}, {workers} workers",
            resid.schedule().num_levels(),
            resid.schedule().max_width()
        );
        let vv: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let x16 = Mat::from_fn(n, 16, |i, j| ((i * 3 + j * 11) as f64 * 0.19).sin());
        let reps = 30;
        let time_vec = |exec: SweepExec<'_>| {
            common::timed(|| {
                let mut acc = 0.0;
                for _ in 0..reps {
                    let w = resid.mul_bt_with(&resid.mul_b_with(&vv, exec), exec);
                    let u = resid.solve_b_with(&resid.solve_bt_with(&w, exec), exec);
                    acc += u[n - 1];
                }
                acc
            })
        };
        let time_mat = |exec: SweepExec<'_>| {
            common::timed(|| {
                let mut acc = 0.0;
                for _ in 0..reps / 5 {
                    let w = resid.mul_bt_mat_with(&resid.mul_b_mat_with(&x16, exec), exec);
                    let u = resid.solve_b_mat_with(&resid.solve_bt_mat_with(&w, exec), exec);
                    acc += u.get(n - 1, 0);
                }
                acc
            })
        };
        let (a_seq, t_vec_seq) = time_vec(SweepExec::Seq);
        let (a_sch, t_vec_sch) = time_vec(SweepExec::Pool(pool, workers));
        assert_eq!(a_seq.to_bits(), a_sch.to_bits(), "scheduled vec sweep diverged");
        let (b_seq, t_mat_seq) = time_mat(SweepExec::Seq);
        let (b_sch, t_mat_sch) = time_mat(SweepExec::Pool(pool, workers));
        assert_eq!(b_seq.to_bits(), b_sch.to_bits(), "scheduled mat sweep diverged");
        println!(
            "B sweeps vec:   seq {:.3} ms/op  scheduled {:.3} ms/op  speedup {:.2}x",
            1e3 * t_vec_seq / reps as f64,
            1e3 * t_vec_sch / reps as f64,
            t_vec_seq / t_vec_sch.max(1e-9)
        );
        println!(
            "B sweeps mat16: seq {:.3} ms/op  scheduled {:.3} ms/op  speedup {:.2}x",
            1e3 * t_mat_seq / (reps / 5) as f64,
            1e3 * t_mat_sch / (reps / 5) as f64,
            t_mat_seq / t_mat_sch.max(1e-9)
        );
    }

    // 10. Panelized vs scalar residual-covariance assembly: B/D build,
    // Appendix-A gradient pass, and cover-tree neighbor search, each
    // against the scalar per-pair baseline (the `ResidualCov`/`Metric`
    // trait default impls, forced through the Scalarized wrappers).
    // Results must agree to ≤1e-12; writes machine-readable
    // BENCH_assembly.json (override the path with VIFGP_BENCH_JSON).
    {
        use std::sync::Mutex;
        use vifgp::testing::{ScalarizedMetric, ScalarizedOracle};
        use vifgp::vecchia::neighbors::covertree_ordered_knn;
        use vifgp::vecchia::ResidualCov;
        use vifgp::vif::{CorrelationMetric, GradAux};

        // Residual B/D build.
        let scalar_oracle = ScalarizedOracle(&oracle);
        let (f_sc, t_build_sc) =
            common::timed(|| ResidualFactor::build(&scalar_oracle, nb.clone(), 0.05, 1e-10));
        let (f_pn, t_build_pn) =
            common::timed(|| ResidualFactor::build(&oracle, nb.clone(), 0.05, 1e-10));
        let mut build_diff = 0.0f64;
        for i in 0..n {
            build_diff = build_diff.max((f_pn.d[i] - f_sc.d[i]).abs());
            for (a, b) in f_pn.a[i].iter().zip(&f_sc.a[i]) {
                build_diff = build_diff.max((a - b).abs());
            }
        }
        assert!(build_diff <= 1e-12, "panelized build diverged: {build_diff:.3e}");

        // Appendix-A gradient pass.
        let aux = GradAux::build(&x, &kernel, &lr);
        let goracle = VifResidualOracle {
            kernel: &kernel,
            x: &x,
            lr: Some(&lr),
            grad_aux: Some(&aux),
            extra_params: 1,
            x_panels: None,
        };
        let gscalar = ScalarizedOracle(&goracle);
        let np = goracle.num_params();
        let mvx = nb.iter().map(Vec::len).max().unwrap_or(0);
        let run_grads = |orc: &dyn ResidualCov| -> (Vec<f64>, Vec<f64>) {
            let dd = Mutex::new(vec![0.0; n * np]);
            let da = Mutex::new(vec![0.0; n * np * mvx]);
            f_pn.grads(orc, 0.05, Some(np - 1), 1e-10, &|i, ddi, dai| {
                dd.lock().unwrap()[i * np..(i + 1) * np].copy_from_slice(ddi);
                let mut a = da.lock().unwrap();
                for (p, row) in dai.iter().enumerate() {
                    let base = (i * np + p) * mvx;
                    a[base..base + row.len()].copy_from_slice(row);
                }
            });
            (dd.into_inner().unwrap(), da.into_inner().unwrap())
        };
        let ((dd_sc, da_sc), t_grad_sc) = common::timed(|| run_grads(&gscalar));
        let ((dd_pn, da_pn), t_grad_pn) = common::timed(|| run_grads(&goracle));
        let mut grad_diff = 0.0f64;
        for (a, b) in dd_pn.iter().zip(&dd_sc).chain(da_pn.iter().zip(&da_sc)) {
            grad_diff = grad_diff.max((a - b).abs());
        }
        assert!(grad_diff <= 1e-12, "panelized gradients diverged: {grad_diff:.3e}");

        // Cover-tree neighbor search (build + all queries).
        let metric = CorrelationMetric::new(&kernel, &x, Some(&lr));
        let smetric = ScalarizedMetric(&metric);
        let (nb_sc, t_nb_sc) = common::timed(|| covertree_ordered_knn(n, m_v, &smetric));
        let (nb_pn, t_nb_pn) = common::timed(|| covertree_ordered_knn(n, m_v, &metric));
        assert_eq!(nb_pn, nb_sc, "batched metric changed the neighbor sets");

        let sp_build = t_build_sc / t_build_pn.max(1e-9);
        let sp_grad = t_grad_sc / t_grad_pn.max(1e-9);
        let sp_nb = t_nb_sc / t_nb_pn.max(1e-9);
        let sp_asm = (t_build_sc + t_grad_sc) / (t_build_pn + t_grad_pn).max(1e-9);
        println!(
            "panel B/D build:   scalar {t_build_sc:.3}s  panel {t_build_pn:.3}s  speedup {sp_build:.2}x  (max diff {build_diff:.2e})"
        );
        println!(
            "panel grad pass:   scalar {t_grad_sc:.3}s  panel {t_grad_pn:.3}s  speedup {sp_grad:.2}x  (max diff {grad_diff:.2e})"
        );
        println!(
            "panel kNN search:  scalar {t_nb_sc:.3}s  panel {t_nb_pn:.3}s  speedup {sp_nb:.2}x"
        );
        println!("assembly+gradient speedup: {sp_asm:.2}x");

        let json = format!(
            concat!(
                "{{\n",
                "  \"bench\": \"perf_hotpath stage 10: panelized residual-covariance assembly\",\n",
                "  \"config\": {{\"n\": {n}, \"d\": {d}, \"m\": {m}, \"m_v\": {m_v}}},\n",
                "  \"stages\": {{\n",
                "    \"residual_build\": {{\"scalar_s\": {bs:.6}, \"panel_s\": {bp:.6}, ",
                "\"speedup\": {sb:.3}, \"max_abs_diff\": {bd:.3e}}},\n",
                "    \"gradient_pass\": {{\"scalar_s\": {gs:.6}, \"panel_s\": {gp:.6}, ",
                "\"speedup\": {sg:.3}, \"max_abs_diff\": {gd:.3e}}},\n",
                "    \"neighbor_search\": {{\"scalar_s\": {ns:.6}, \"panel_s\": {npn:.6}, ",
                "\"speedup\": {sn:.3}}}\n",
                "  }},\n",
                "  \"assembly_plus_gradient_speedup\": {sa:.3}\n",
                "}}\n"
            ),
            n = n,
            d = d,
            m = m,
            m_v = m_v,
            bs = t_build_sc,
            bp = t_build_pn,
            sb = sp_build,
            bd = build_diff,
            gs = t_grad_sc,
            gp = t_grad_pn,
            sg = sp_grad,
            gd = grad_diff,
            ns = t_nb_sc,
            npn = t_nb_pn,
            sn = sp_nb,
            sa = sp_asm,
        );
        let path =
            std::env::var("VIFGP_BENCH_JSON").unwrap_or_else(|_| "BENCH_assembly.json".into());
        match std::fs::write(&path, json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }

    // 11. Plan/refresh split vs assemble-from-scratch over a simulated
    // L-BFGS trajectory: 20 objective evaluations at perturbed θ with
    // frozen structure choices (the exact regime of a fit round). The
    // baseline is the pre-refactor fit-closure path — clone z and the
    // neighbor graph, assemble a structure, evaluate — while the plan
    // path builds one `VifPlan` + one structure and refreshes in place.
    // Per-evaluation NLLs and the final-θ structures must agree to
    // ≤1e-12; writes machine-readable BENCH_refresh.json (override the
    // path with VIFGP_BENCH_REFRESH_JSON).
    {
        use vifgp::testing::structures_max_abs_diff;
        use vifgp::vif::VifPlan;

        let evals = 20usize;
        let nugget = 0.05;
        let thetas: Vec<ArdMatern> = (0..evals)
            .map(|t| {
                let mut p = kernel.log_params();
                for (j, pj) in p.iter_mut().enumerate() {
                    *pj += 0.05 * ((t * (j + 2)) as f64 * 0.61).sin();
                }
                ArdMatern::from_log_params(&p, kernel.smoothness)
            })
            .collect();

        let (plan, t_plan) = common::timed(|| VifPlan::build(&x, Some(z.clone()), nb.clone()));

        // Baseline: assemble from scratch per evaluation (clones included,
        // exactly what the old objective closures did per line-search step).
        let (nll_scratch, t_scratch) = common::timed(|| {
            thetas
                .iter()
                .map(|kt| {
                    let s = VifStructure::assemble(
                        &x,
                        kt,
                        Some(z.clone()),
                        nb.clone(),
                        nugget,
                        1e-10,
                        1,
                    );
                    gaussian::nll(&s, &y)
                })
                .collect::<Vec<f64>>()
        });

        // Plan path: one symbolic build, then in-place numeric refreshes.
        let (nll_refresh, t_refresh) = common::timed(|| {
            let mut s = VifStructure::from_plan(&x, &thetas[0], &plan, nugget, 1e-10, 1);
            let mut out = Vec::with_capacity(evals);
            out.push(gaussian::nll(&s, &y));
            for kt in &thetas[1..] {
                s.refresh(&plan, &x, kt, nugget, 1e-10);
                out.push(gaussian::nll(&s, &y));
            }
            out
        });

        let mut nll_diff = 0.0f64;
        for (t, (a, b)) in nll_refresh.iter().zip(&nll_scratch).enumerate() {
            let rel = (a - b).abs() / (1.0 + b.abs());
            assert!(
                rel <= 1e-12,
                "eval {t}: refresh NLL {a} vs scratch {b} (rel {rel:.3e})"
            );
            nll_diff = nll_diff.max(rel);
        }
        // Final-θ structures agree entry-wise too.
        let kt = &thetas[evals - 1];
        let s_fresh =
            VifStructure::assemble(&x, kt, Some(z.clone()), nb.clone(), nugget, 1e-10, 1);
        let mut s_ref = VifStructure::from_plan(&x, &thetas[0], &plan, nugget, 1e-10, 1);
        s_ref.refresh(&plan, &x, kt, nugget, 1e-10);
        let struct_diff = structures_max_abs_diff(&s_ref, &s_fresh);
        assert!(struct_diff <= 1e-12, "refresh structure diverged: {struct_diff:.3e}");

        let per_scratch = t_scratch / evals as f64;
        let per_refresh = t_refresh / evals as f64;
        let speedup = t_scratch / t_refresh.max(1e-9);
        println!(
            "plan/refresh trajectory ({evals} evals): scratch {:.3} ms/eval  refresh {:.3} ms/eval  speedup {speedup:.2}x  (plan build {:.3} ms, max rel NLL diff {nll_diff:.2e}, struct diff {struct_diff:.2e})",
            1e3 * per_scratch,
            1e3 * per_refresh,
            1e3 * t_plan,
        );

        let json = format!(
            concat!(
                "{{\n",
                "  \"bench\": \"perf_hotpath stage 11: plan/refresh vs assemble-from-scratch\",\n",
                "  \"config\": {{\"n\": {n}, \"d\": {d}, \"m\": {m}, \"m_v\": {m_v}, \"evals\": {ev}}},\n",
                "  \"plan_build_s\": {tp:.6},\n",
                "  \"assemble_scratch_s_per_eval\": {psc:.6},\n",
                "  \"refresh_s_per_eval\": {prf:.6},\n",
                "  \"trajectory_speedup\": {sp:.3},\n",
                "  \"max_rel_nll_diff\": {nd:.3e},\n",
                "  \"final_structure_max_abs_diff\": {sd:.3e}\n",
                "}}\n"
            ),
            n = n,
            d = d,
            m = m,
            m_v = m_v,
            ev = evals,
            tp = t_plan,
            psc = per_scratch,
            prf = per_refresh,
            sp = speedup,
            nd = nll_diff,
            sd = struct_diff,
        );
        let path = std::env::var("VIFGP_BENCH_REFRESH_JSON")
            .unwrap_or_else(|_| "BENCH_refresh.json".into());
        match std::fs::write(&path, json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }

    // 12. Prediction serving throughput: the scalar per-point path (the
    // pre-refactor per-point loops, `testing::scalar_predict_reference`)
    // vs the shared panelized/batched pipeline (`vif::predict`:
    // plan-frozen neighbor panels, blocked Σ_m solves, per-block Woodbury
    // GEMMs + one M⁻¹ block solve). Mean/variance must agree to ≤1e-12;
    // writes machine-readable BENCH_predict.json (override the path with
    // VIFGP_BENCH_PREDICT_JSON).
    {
        use vifgp::testing::scalar_predict_reference;
        use vifgp::vif::predict::{posterior_mean, PredictBlocks, PredictPlan};

        let n_pred = common::scaled(2_000);
        let xp = data::uniform_inputs(&mut rng, n_pred, d);
        let (plan, t_plan) = common::timed(|| {
            PredictPlan::build(
                &s,
                &x,
                &kernel,
                &xp,
                m_v,
                NeighborSelection::CorrelationCoverTree,
            )
        });
        // Batched pipeline per serving call at fixed θ (plan reused).
        let ((mean_b, var_b), t_batched) = common::timed(|| {
            let blocks = PredictBlocks::compute(&s, &kernel, &xp, &plan, 1e-10);
            let mean = posterior_mean(&s, &plan, &blocks, &y);
            (mean, blocks.var_det)
        });
        let (want, t_scalar) = common::timed(|| {
            scalar_predict_reference(&s, &x, &kernel, &y, &xp, &plan.neighbors, 1e-10)
        });
        let mut pred_diff = 0.0f64;
        for (a, b) in mean_b.iter().zip(&want.mean).chain(var_b.iter().zip(&want.var_det)) {
            pred_diff = pred_diff.max((a - b).abs() / (1.0 + b.abs()));
        }
        assert!(
            pred_diff <= 1e-12,
            "batched prediction diverged: {pred_diff:.3e}"
        );
        let pts_scalar = n_pred as f64 / t_scalar.max(1e-9);
        let pts_batched = n_pred as f64 / t_batched.max(1e-9);
        let sp_pred = t_scalar / t_batched.max(1e-9);
        println!(
            "predict ({n_pred} pts): scalar {t_scalar:.3}s ({pts_scalar:.0} pts/s)  batched {t_batched:.3}s ({pts_batched:.0} pts/s)  speedup {sp_pred:.2}x  (plan build {:.3}s, max rel diff {pred_diff:.2e})",
            t_plan,
        );
        let json = format!(
            concat!(
                "{{\n",
                "  \"bench\": \"perf_hotpath stage 12: scalar vs panelized-batched prediction\",\n",
                "  \"config\": {{\"n\": {n}, \"d\": {d}, \"m\": {m}, \"m_v\": {m_v}, \"n_pred\": {npred}}},\n",
                "  \"plan_build_s\": {tp:.6},\n",
                "  \"scalar_s\": {ts:.6},\n",
                "  \"batched_s\": {tb:.6},\n",
                "  \"scalar_points_per_sec\": {ps:.1},\n",
                "  \"batched_points_per_sec\": {pb:.1},\n",
                "  \"speedup\": {sp:.3},\n",
                "  \"max_rel_diff\": {pd:.3e}\n",
                "}}\n"
            ),
            n = n,
            d = d,
            m = m,
            m_v = m_v,
            npred = n_pred,
            tp = t_plan,
            ts = t_scalar,
            tb = t_batched,
            ps = pts_scalar,
            pb = pts_batched,
            sp = sp_pred,
            pd = pred_diff,
        );
        let path = std::env::var("VIFGP_BENCH_PREDICT_JSON")
            .unwrap_or_else(|_| "BENCH_predict.json".into());
        match std::fs::write(&path, json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }

    // 13. Streaming append ingestion: incremental `VifStructure::append`
    // (low-rank column growth + leaf conditioning sets + panelized factor
    // rows + blocked rank-k Woodbury update) vs the assemble-from-scratch
    // rebuild a non-incremental server would run on every arriving batch.
    // The final appended structure must agree with the last rebuild to
    // ≤1e-12; writes machine-readable BENCH_append.json (override the
    // path with VIFGP_BENCH_APPEND_JSON).
    {
        use vifgp::linalg::Mat;
        use vifgp::testing::structures_max_abs_diff;
        use vifgp::vif::VifPlan;

        let nugget = 0.05;
        let batch = 64usize;
        let n_app = common::scaled(640).max(batch).min(n / 2);
        let n_base = n - n_app;
        let mut x_cur = Mat::from_fn(n_base, d, |i, j| x.get(i, j));
        // Prefix neighbor sets are self-contained: row i conditions only
        // on earlier rows, so truncating the full-data selection is a
        // valid base graph.
        let nb_base: Vec<Vec<u32>> = nb[..n_base].to_vec();
        let (mut plan, t_plan) =
            common::timed(|| VifPlan::build(&x_cur, Some(z.clone()), nb_base));
        let mut s_inc = VifStructure::from_plan(&x_cur, &kernel, &plan, nugget, 1e-10, 1);

        let mut t_append = 0.0f64;
        let mut t_rebuild = 0.0f64;
        let mut batches = 0usize;
        let mut s_rebuilt = None;
        let mut done = n_base;
        while done < n {
            let k = batch.min(n - done);
            let xb = Mat::from_fn(k, d, |i, j| x.get(done + i, j));
            x_cur.append_rows(&xb);
            let (_, ta) = common::timed(|| {
                s_inc.append(
                    &mut plan,
                    &x_cur,
                    &kernel,
                    &xb,
                    m_v,
                    NeighborSelection::CorrelationCoverTree,
                    1e-10,
                )
            });
            t_append += ta;
            // What a non-incremental server pays per arrival: a full
            // numeric re-assembly over the grown plan.
            let (sb, tb) = common::timed(|| {
                VifStructure::from_plan(&x_cur, &kernel, &plan, nugget, 1e-10, 1)
            });
            t_rebuild += tb;
            s_rebuilt = Some(sb);
            done += k;
            batches += 1;
        }
        let app_diff = structures_max_abs_diff(&s_inc, s_rebuilt.as_ref().unwrap());
        assert!(app_diff <= 1e-12, "appended structure diverged: {app_diff:.3e}");
        let pts_append = n_app as f64 / t_append.max(1e-9);
        let pts_rebuild = n_app as f64 / t_rebuild.max(1e-9);
        let sp_app = t_rebuild / t_append.max(1e-9);
        println!(
            "append ingest ({n_app} pts, {batches} batches of <={batch}): incremental {t_append:.3}s ({pts_append:.0} pts/s)  rebuild {t_rebuild:.3}s ({pts_rebuild:.0} pts/s)  speedup {sp_app:.2}x  (base plan {:.3}s, struct diff {app_diff:.2e})",
            t_plan,
        );
        let json = format!(
            concat!(
                "{{\n",
                "  \"bench\": \"perf_hotpath stage 13: streaming append vs assemble-from-scratch\",\n",
                "  \"config\": {{\"n\": {n}, \"d\": {d}, \"m\": {m}, \"m_v\": {m_v}, \"n_base\": {nbase}, \"n_appended\": {na}, \"batch\": {bs}, \"batches\": {nbatch}}},\n",
                "  \"base_plan_build_s\": {tp:.6},\n",
                "  \"append_s_total\": {tap:.6},\n",
                "  \"rebuild_s_total\": {trb:.6},\n",
                "  \"append_points_per_sec\": {pa:.1},\n",
                "  \"rebuild_points_per_sec\": {pr:.1},\n",
                "  \"speedup\": {sp:.3},\n",
                "  \"final_structure_max_abs_diff\": {ad:.3e}\n",
                "}}\n"
            ),
            n = n,
            d = d,
            m = m,
            m_v = m_v,
            nbase = n_base,
            na = n_app,
            bs = batch,
            nbatch = batches,
            tp = t_plan,
            tap = t_append,
            trb = t_rebuild,
            pa = pts_append,
            pr = pts_rebuild,
            sp = sp_app,
            ad = app_diff,
        );
        let path = std::env::var("VIFGP_BENCH_APPEND_JSON")
            .unwrap_or_else(|_| "BENCH_append.json".into());
        match std::fs::write(&path, json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }

    // 14. Concurrent serving engine (ROADMAP item 1): micro-batched point
    // queries against a published `FittedGaussian` snapshot, swept over
    // client concurrency 1→64 with p50/p99 latency and points/sec per
    // sweep, plus a generation-swap-under-load phase (writer ingests +
    // publishes while readers hammer the engine). Served results must
    // match the single-threaded `predict_with_plan` reference to ≤1e-12;
    // writes machine-readable BENCH_serving.json (override the path with
    // VIFGP_BENCH_SERVING_JSON).
    {
        use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
        use std::sync::{Arc, Mutex};
        use vifgp::serve::{ServeEngine, ServeOptions};
        use vifgp::vif::gaussian::{GaussianParams, VifRegression};
        use vifgp::vif::VifConfig;

        let n_srv = common::scaled(4_000).max(64);
        let x_srv = data::uniform_inputs(&mut rng, n_srv, d);
        let y_srv: Vec<f64> = (0..n_srv).map(|_| rng.normal()).collect();
        let config = VifConfig {
            smoothness: Smoothness::ThreeHalves,
            num_inducing: m.min(n_srv),
            num_neighbors: m_v,
            selection: NeighborSelection::CorrelationCoverTree,
            seed: 1,
            ..Default::default()
        };
        let mut model = VifRegression::new(
            x_srv,
            y_srv,
            config,
            GaussianParams { kernel: kernel.clone(), noise: 0.05 },
        );
        let (_, t_assemble) = common::timed(|| model.assemble());
        let n_query = common::scaled(2_000).max(128);
        let xq = data::uniform_inputs(&mut rng, n_query, d);

        // Single-threaded reference: the oracle every served reply is
        // checked against, and the throughput baseline.
        let plan = model.build_predict_plan(&xq);
        let ((mean_ref, var_ref), t_ref) = common::timed(|| model.predict_with_plan(&xq, &plan));
        let ref_pts = n_query as f64 / t_ref.max(1e-9);

        let mut opts = ServeOptions::from_env();
        if std::env::var("VIFGP_SERVE_BATCH_WINDOW_US").is_err() {
            // Bench default: a tighter window than the serving default so
            // the concurrency-1 leg isn't dominated by coalescing waits.
            opts.batch_window = std::time::Duration::from_micros(50);
        }
        let window_us = opts.batch_window.as_micros() as u64;
        let max_batch = opts.max_batch;
        let engine = ServeEngine::start(Arc::new(model.snapshot()), opts);

        println!(
            "serving sweep ({n_query} queries/leg, max_batch {max_batch}, window {window_us}µs; \
             assemble {t_assemble:.3}s, single-thread ref {t_ref:.3}s = {ref_pts:.0} pts/s):"
        );
        let sweep = [1usize, 2, 4, 8, 16, 32, 64];
        let mut rows: Vec<String> = Vec::new();
        let mut c8_pts = 0.0f64;
        for &clients in &sweep {
            let _ = engine.metrics().drain();
            let (_, t_sweep) = common::timed(|| {
                std::thread::scope(|scope| {
                    for t in 0..clients {
                        let engine = &engine;
                        let xq = &xq;
                        let mean_ref = &mean_ref;
                        let var_ref = &var_ref;
                        scope.spawn(move || {
                            let mut i = t;
                            while i < xq.rows() {
                                let p = engine.predict(xq.row(i)).expect("serve request failed");
                                let dm =
                                    (p.mean - mean_ref[i]).abs() / (1.0 + mean_ref[i].abs());
                                let dv = (p.var - var_ref[i]).abs() / (1.0 + var_ref[i].abs());
                                assert!(
                                    dm <= 1e-12 && dv <= 1e-12,
                                    "served prediction diverged at {i}: {dm:.3e}/{dv:.3e}"
                                );
                                i += clients;
                            }
                        });
                    }
                })
            });
            let rep = engine.metrics().drain();
            if clients == 8 {
                c8_pts = rep.points_per_sec;
            }
            println!(
                "  c={clients:>2}: p50 {:>8.0}µs  p99 {:>8.0}µs  {:>9.0} pts/s  mean batch {:>5.1}  ({t_sweep:.3}s)",
                rep.p50_latency_us, rep.p99_latency_us, rep.points_per_sec, rep.mean_batch
            );
            rows.push(format!(
                concat!(
                    "    {{\"concurrency\": {}, \"requests\": {}, \"p50_latency_us\": {:.2}, ",
                    "\"p99_latency_us\": {:.2}, \"mean_latency_us\": {:.2}, ",
                    "\"points_per_sec\": {:.1}, \"batches\": {}, \"mean_batch\": {:.2}, ",
                    "\"wall_s\": {:.6}}}"
                ),
                clients,
                rep.requests,
                rep.p50_latency_us,
                rep.p99_latency_us,
                rep.mean_latency_us,
                rep.points_per_sec,
                rep.batches,
                rep.mean_batch,
                t_sweep,
            ));
        }

        // Generation swap under load: 8 readers keep the queue full while
        // the writer appends three batches and publishes each new
        // generation. Every reply must carry a published generation.
        let published: Mutex<std::collections::HashSet<u64>> = Mutex::new(Default::default());
        published.lock().unwrap().insert(engine.current_generation());
        let swap_requests = AtomicUsize::new(0);
        let done = AtomicBool::new(false);
        let publishes = 3usize;
        std::thread::scope(|scope| {
            let engine = &engine;
            let xq = &xq;
            let done = &done;
            let published = &published;
            let swap_requests = &swap_requests;
            for t in 0..8usize {
                scope.spawn(move || {
                    let mut i = t;
                    while !done.load(Ordering::Acquire) {
                        let p = engine
                            .predict(xq.row(i % xq.rows()))
                            .expect("reader failed during swap");
                        assert!(
                            published.lock().unwrap().contains(&p.generation),
                            "served unpublished generation {}",
                            p.generation
                        );
                        swap_requests.fetch_add(1, Ordering::Relaxed);
                        i += 8;
                    }
                });
            }
            for _ in 0..publishes {
                let xa = data::uniform_inputs(&mut rng, 32, d);
                let ya: Vec<f64> = (0..32).map(|_| rng.normal()).collect();
                model.append_points(&xa, &ya).expect("append failed");
                let snap = Arc::new(model.snapshot());
                published.lock().unwrap().insert(snap.generation());
                engine.publish(snap);
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
            done.store(true, Ordering::Release);
        });
        // After the last publish, serving must match the final model.
        let plan_f = model.build_predict_plan(&xq);
        let (mean_f, var_f) = model.predict_with_plan(&xq, &plan_f);
        let mut swap_diff = 0.0f64;
        for i in 0..xq.rows() {
            let p = engine.predict(xq.row(i)).expect("post-swap request failed");
            swap_diff = swap_diff
                .max((p.mean - mean_f[i]).abs() / (1.0 + mean_f[i].abs()))
                .max((p.var - var_f[i]).abs() / (1.0 + var_f[i].abs()));
        }
        assert!(swap_diff <= 1e-12, "post-swap serving diverged: {swap_diff:.3e}");
        let swap_served = swap_requests.load(Ordering::Relaxed);
        println!(
            "  swap under load: {publishes} publishes, {swap_served} concurrent requests, \
             post-swap max rel diff {swap_diff:.2e}"
        );

        // 15. Disabled-faults hot path: the containment hooks (fault
        // checks, quarantine plumbing, deadline handling) compile into
        // the serving path unconditionally and must cost nothing
        // measurable with no fault plan armed. Re-run the concurrency-8
        // leg against the final snapshot and compare points/sec with
        // the in-sweep c=8 result.
        assert!(
            !vifgp::faults::enabled(),
            "perf_hotpath must run with fault injection disarmed"
        );
        let _ = engine.metrics().drain();
        let (_, t_hot) = common::timed(|| {
            std::thread::scope(|scope| {
                for t in 0..8usize {
                    let engine = &engine;
                    let xq = &xq;
                    let mean_f = &mean_f;
                    let var_f = &var_f;
                    scope.spawn(move || {
                        let mut i = t;
                        while i < xq.rows() {
                            let p = engine.predict(xq.row(i)).expect("hot-path request failed");
                            let dm = (p.mean - mean_f[i]).abs() / (1.0 + mean_f[i].abs());
                            let dv = (p.var - var_f[i]).abs() / (1.0 + var_f[i].abs());
                            assert!(
                                dm <= 1e-12 && dv <= 1e-12,
                                "hot-path prediction diverged at {i}: {dm:.3e}/{dv:.3e}"
                            );
                            i += 8;
                        }
                    });
                }
            })
        });
        let hot_rep = engine.metrics().drain();
        let hot_pts = hot_rep.points_per_sec;
        let overhead_ratio = hot_pts / c8_pts.max(1e-9);
        // Generous floor: this guards against a structural slowdown (a
        // lock or fault check on the per-point path), not scheduler noise.
        assert!(
            overhead_ratio >= 0.5,
            "disabled-faults hot path regressed: {hot_pts:.0} pts/s vs sweep c=8 {c8_pts:.0} pts/s"
        );
        assert_eq!(
            hot_rep.panics_caught + hot_rep.quarantined_requests + hot_rep.nonfinite_replies,
            0,
            "containment events fired during a clean bench run"
        );
        println!(
            "  faults-disabled hot path (c=8): {hot_pts:.0} pts/s vs sweep {c8_pts:.0} pts/s \
             (ratio {overhead_ratio:.2}, {t_hot:.3}s)"
        );

        let json = format!(
            concat!(
                "{{\n",
                "  \"bench\": \"perf_hotpath stage 14: concurrent serving engine sweep\",\n",
                "  \"config\": {{\"n\": {ns}, \"d\": {d}, \"m\": {m}, \"m_v\": {m_v}, ",
                "\"n_query\": {nq}, \"max_batch\": {mb}, \"batch_window_us\": {bw}}},\n",
                "  \"assemble_s\": {ta:.6},\n",
                "  \"single_thread_ref_s\": {tr:.6},\n",
                "  \"single_thread_points_per_sec\": {rp:.1},\n",
                "  \"sweep\": [\n{rows}\n  ],\n",
                "  \"swap\": {{\"publishes\": {pb}, \"requests_under_swap\": {sr}, ",
                "\"post_swap_max_rel_diff\": {sd:.3e}}},\n",
                "  \"faults_overhead\": {{\"faults_enabled\": false, ",
                "\"sweep_c8_points_per_sec\": {c8:.1}, ",
                "\"recheck_c8_points_per_sec\": {hp:.1}, \"ratio\": {orr:.3}}}\n",
                "}}\n"
            ),
            ns = n_srv,
            d = d,
            m = m.min(n_srv),
            m_v = m_v,
            nq = n_query,
            mb = max_batch,
            bw = window_us,
            ta = t_assemble,
            tr = t_ref,
            rp = ref_pts,
            rows = rows.join(",\n"),
            pb = publishes,
            sr = swap_served,
            sd = swap_diff,
            c8 = c8_pts,
            hp = hot_pts,
            orr = overhead_ratio,
        );
        let path = std::env::var("VIFGP_BENCH_SERVING_JSON")
            .unwrap_or_else(|_| "BENCH_serving.json".into());
        match std::fs::write(&path, json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }

    // 16. Kernel micro-benchmarks: per-kernel GFLOP/s for the scalar
    // oracle vs the 4-lane backend at production shapes (k = m ≈ 100
    // low-rank panels, 64-point prediction blocks, nb-sized conditioning
    // sets). Calls the backend-pinned `*_scalar`/`*_simd` variants
    // directly, so the measured ratio is independent of `VIFGP_SIMD` and
    // the assertions hold on both CI legs. Writes BENCH_kernels.json
    // (override the path with VIFGP_BENCH_KERNELS_JSON).
    {
        use vifgp::linalg::{CholeskyFactor, Mat};

        println!("\nstage 16: kernel micro-benchmarks (scalar oracle vs lane backend)");

        fn filled(r: usize, c: usize, seed: usize) -> Mat {
            Mat::from_fn(r, c, |i, j| ((i * 31 + j * 17 + seed * 7 + 3) as f64 * 0.37).sin())
        }
        fn spd_mat(n: usize, seed: usize) -> Mat {
            let g = filled(n, n, seed);
            let mut a = g.matmul_nt_scalar(&g);
            a.add_diag(n as f64 + 1.0);
            a
        }
        fn max_diff(a: &[f64], b: &[f64]) -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
        }
        /// Best wall-clock of `trials` runs; the closure returns a
        /// checksum so the compiler cannot elide the kernel calls.
        fn best_of(trials: usize, mut f: impl FnMut() -> f64) -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..trials {
                let start = std::time::Instant::now();
                let acc = f();
                let t = start.elapsed().as_secs_f64();
                assert!(acc.is_finite(), "kernel bench produced a non-finite checksum");
                best = best.min(t);
            }
            best
        }

        let trials = 3usize;
        // Repeat each kernel until ~2e8 nominal flops per timed region,
        // scaled down with the global bench scale for CI smoke runs.
        let reps_for =
            |flops: f64| (((2.0e8 / flops) * common::scale()).ceil() as usize).max(1);

        let mut rows: Vec<String> = Vec::new();
        let mut record = |name: &str,
                          shape: &str,
                          flops: f64,
                          reps: usize,
                          diff: f64,
                          t_s: f64,
                          t_v: f64|
         -> f64 {
            let gf_s = flops * reps as f64 / t_s / 1e9;
            let gf_v = flops * reps as f64 / t_v / 1e9;
            let sp = t_s / t_v;
            println!(
                "  {name:<11} {shape:<22} scalar {gf_s:7.2} GF/s | simd {gf_v:7.2} GF/s | \
                 x{sp:5.2} | diff {diff:.2e}"
            );
            rows.push(format!(
                "    {{\"kernel\": \"{name}\", \"shape\": \"{shape}\", \
                 \"flops_per_call\": {flops:.0}, \"reps\": {reps}, \
                 \"scalar_s\": {t_s:.6}, \"simd_s\": {t_v:.6}, \
                 \"scalar_gflops\": {gf_s:.3}, \"simd_gflops\": {gf_v:.3}, \
                 \"speedup\": {sp:.3}, \"max_abs_diff\": {diff:.3e}}}"
            ));
            sp
        };
        let mut diffs: Vec<(&str, f64)> = Vec::new();

        // GEMM NN — Woodbury side block times an m×m core.
        let a_nn = filled(512, 100, 1);
        let b_nn = filled(100, 100, 2);
        let d_nn = a_nn.matmul_simd(&b_nn).max_abs_diff(&a_nn.matmul_scalar(&b_nn));
        diffs.push(("gemm_nn", d_nn));
        let fl = 2.0 * 512.0 * 100.0 * 100.0;
        let reps = reps_for(fl);
        let t_s = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += a_nn.matmul_scalar(&b_nn).get(0, 0);
            }
            acc
        });
        let t_v = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += a_nn.matmul_simd(&b_nn).get(0, 0);
            }
            acc
        });
        let sp_nn = record("gemm_nn", "512x100 * 100x100", fl, reps, d_nn, t_s, t_v);

        // GEMM TN — panel-transpose contraction (Uᵀ·V accumulation).
        let a_tn = filled(2048, 100, 3);
        let b_tn = filled(2048, 64, 4);
        let mut out = Mat::zeros(100, 64);
        let mut out_ref = Mat::zeros(100, 64);
        a_tn.matmul_tn_into_scalar(&b_tn, &mut out_ref);
        a_tn.matmul_tn_into_simd(&b_tn, &mut out);
        let d_tn = out.max_abs_diff(&out_ref);
        diffs.push(("gemm_tn", d_tn));
        let fl = 2.0 * 2048.0 * 100.0 * 64.0;
        let reps = reps_for(fl);
        let t_s = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                a_tn.matmul_tn_into_scalar(&b_tn, &mut out);
                acc += out.get(0, 0);
            }
            acc
        });
        let t_v = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                a_tn.matmul_tn_into_simd(&b_tn, &mut out);
                acc += out.get(0, 0);
            }
            acc
        });
        record("gemm_tn", "2048x100^T * 2048x64", fl, reps, d_tn, t_s, t_v);

        // GEMM NT — prediction-block cross term V·Vᵀ shape.
        let v_nt = filled(64, 100, 5);
        let d_nt = v_nt.matmul_nt_simd(&v_nt).max_abs_diff(&v_nt.matmul_nt_scalar(&v_nt));
        diffs.push(("gemm_nt", d_nt));
        let fl = 2.0 * 64.0 * 100.0 * 64.0;
        let reps = reps_for(fl);
        let t_s = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += v_nt.matmul_nt_scalar(&v_nt).get(0, 0);
            }
            acc
        });
        let t_v = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += v_nt.matmul_nt_simd(&v_nt).get(0, 0);
            }
            acc
        });
        record("gemm_nt", "64x100 * (64x100)^T", fl, reps, d_nt, t_s, t_v);

        // SYRK — ρ_NN correction on a 64-point prediction block. The
        // update mutates its target, so the timed copies drift linearly;
        // that keeps every rep doing real work while staying finite.
        let base = spd_mat(64, 6);
        let vp = filled(64, 100, 7);
        let mut got = base.clone();
        got.syrk_sub_panel_simd(vp.data(), 100);
        let mut want = base.clone();
        want.syrk_sub_panel_scalar(vp.data(), 100);
        let d_syrk = got.max_abs_diff(&want);
        diffs.push(("syrk", d_syrk));
        let fl = 64.0 * 65.0 * 100.0;
        let reps = reps_for(fl);
        let mut work = base.clone();
        let t_s = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                work.syrk_sub_panel_scalar(vp.data(), 100);
                acc += work.get(0, 0);
            }
            acc
        });
        let mut work = base.clone();
        let t_v = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                work.syrk_sub_panel_simd(vp.data(), 100);
                acc += work.get(0, 0);
            }
            acc
        });
        record("syrk", "64x64 -= 64x100 panel", fl, reps, d_syrk, t_s, t_v);

        // TRSM — multi-RHS forward substitution against the m×m inducing
        // factor (the low-rank build's dominant triangular solve).
        let f = CholeskyFactor::new(&spd_mat(100, 8)).expect("spd factorizes");
        let rhs = filled(100, 512, 9);
        let d_trsm = f.solve_lower_mat_simd(&rhs).max_abs_diff(&f.solve_lower_mat_scalar(&rhs));
        diffs.push(("trsm", d_trsm));
        let fl = 100.0 * 100.0 * 512.0;
        let reps = reps_for(fl);
        let t_s = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += f.solve_lower_mat_scalar(&rhs).get(0, 0);
            }
            acc
        });
        let t_v = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += f.solve_lower_mat_simd(&rhs).get(0, 0);
            }
            acc
        });
        record("trsm", "L 100x100, B 100x512", fl, reps, d_trsm, t_s, t_v);

        // dist-panel — one query against a gathered 4096×5 panel
        // (nominal 3d+1 flops per entry: d subs, d muls, d−1 adds, sqrt).
        let pd = 5usize;
        let kn = ArdMatern::new(
            1.3,
            (0..pd).map(|j| 0.4 + 0.1 * j as f64).collect(),
            Smoothness::ThreeHalves,
        );
        let q: Vec<f64> = (0..pd).map(|j| (j as f64 * 0.41).cos()).collect();
        let panel = filled(4096, pd, 10);
        let mut out_s = vec![0.0; 4096];
        let mut out_v = vec![0.0; 4096];
        kn.scaled_dist_panel_scalar(&q, panel.data(), &mut out_s);
        kn.scaled_dist_panel_simd(&q, panel.data(), &mut out_v);
        let d_dist = max_diff(&out_v, &out_s);
        diffs.push(("dist_panel", d_dist));
        let fl = 4096.0 * (3.0 * pd as f64 + 1.0);
        let reps = reps_for(fl);
        let t_s = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                kn.scaled_dist_panel_scalar(&q, panel.data(), &mut out_s);
                acc += out_s[0];
            }
            acc
        });
        let t_v = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                kn.scaled_dist_panel_simd(&q, panel.data(), &mut out_v);
                acc += out_v[0];
            }
            acc
        });
        let sp_dist = record("dist_panel", "len 4096, d 5", fl, reps, d_dist, t_s, t_v);

        // grad-panel — fused covariance + 1+d log-parameter gradients
        // (nominal 7d+10 flops per entry: dist, corr, d gradient chains).
        let gpanel = filled(1024, pd, 11);
        let mut cov_s = vec![0.0; 1024];
        let mut cov_v = vec![0.0; 1024];
        let mut g_s = vec![0.0; (1 + pd) * 1024];
        let mut g_v = vec![0.0; (1 + pd) * 1024];
        kn.cov_and_grad_panel_scalar(&q, gpanel.data(), &mut cov_s, &mut g_s);
        kn.cov_and_grad_panel_simd(&q, gpanel.data(), &mut cov_v, &mut g_v);
        let d_grad = max_diff(&g_v, &g_s).max(max_diff(&cov_v, &cov_s));
        diffs.push(("grad_panel", d_grad));
        let fl = 1024.0 * (7.0 * pd as f64 + 10.0);
        let reps = reps_for(fl);
        let t_s = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                kn.cov_and_grad_panel_scalar(&q, gpanel.data(), &mut cov_s, &mut g_s);
                acc += g_s[0];
            }
            acc
        });
        let t_v = best_of(trials, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                kn.cov_and_grad_panel_simd(&q, gpanel.data(), &mut cov_v, &mut g_v);
                acc += g_v[0];
            }
            acc
        });
        record("grad_panel", "len 1024, d 5", fl, reps, d_grad, t_s, t_v);

        let json = format!(
            concat!(
                "{{\n",
                "  \"bench\": \"perf_hotpath stage 16: kernel micro-benchmarks \
                 (scalar oracle vs lane backend)\",\n",
                "  \"lanes\": 4,\n",
                "  \"bench_scale\": {scale},\n",
                "  \"trials\": {trials},\n",
                "  \"kernels\": [\n{rows}\n  ],\n",
                "  \"asserts\": {{\"gemm_nn_min_speedup\": 1.2, \
                 \"dist_panel_min_speedup\": 1.2, \"max_abs_diff_tol\": 1e-12}}\n",
                "}}\n"
            ),
            scale = common::scale(),
            trials = trials,
            rows = rows.join(",\n"),
        );
        let path = std::env::var("VIFGP_BENCH_KERNELS_JSON")
            .unwrap_or_else(|_| "BENCH_kernels.json".into());
        match std::fs::write(&path, json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => println!("could not write {path}: {e}"),
        }

        // Acceptance gates, checked after the JSON lands so the artifact
        // records the trajectory even when a gate trips.
        for (name, diff) in &diffs {
            assert!(
                *diff <= 1e-12,
                "{name}: lane backend deviates from scalar oracle by {diff:.3e} > 1e-12"
            );
        }
        assert!(
            sp_nn >= 1.2,
            "gemm_nn lane-backend speedup {sp_nn:.2}x < 1.2x over the scalar oracle"
        );
        assert!(
            sp_dist >= 1.2,
            "dist_panel lane-backend speedup {sp_dist:.2}x < 1.2x over the scalar oracle"
        );
    }

    // 17. Warm-started fit trajectory: 20 Laplace objective evaluations
    // along a simulated L-BFGS θ walk (frozen plan, in-place refresh —
    // the exact regime of a fit round), cold session vs warm
    // `FitSession`. The warm leg carries the Newton mode, CG initial
    // guesses, and the in-place-refreshed preconditioner across
    // evaluations; the SLQ probe solves stay cold in both legs (their
    // Lanczos recurrence forbids warm starts), so the savings measured
    // here are the mode-finding and gradient-helper solves. Final NLLs
    // must agree to ≤1e-6 and the warm leg must spend ≥20% fewer
    // cumulative CG iterations; writes machine-readable BENCH_fit.json
    // (override the path with VIFGP_BENCH_FIT_JSON).
    {
        use vifgp::iterative::{solve_stats, IterConfig, PrecondType};
        use vifgp::likelihoods::Likelihood;
        use vifgp::vif::laplace::{SolveMode, VifLaplaceModel};
        use vifgp::vif::{FitModel, FitSession, VifConfig};

        let n_fit = common::scaled(400);
        let (d_fit, m_fit, mv_fit) = (2usize, 12usize, 6usize);
        let evals = 20usize;
        let lik = Likelihood::BernoulliLogit;
        let wl = common::simulate(211, n_fit, 1, d_fit, Smoothness::ThreeHalves, &lik);
        let cfg = IterConfig {
            precond: PrecondType::Vifdu,
            ell: 8,
            cg_tol: 1e-8,
            slq_min_iter: 15,
            ..Default::default()
        };
        let config = VifConfig {
            num_inducing: m_fit,
            num_neighbors: mv_fit,
            selection: NeighborSelection::EuclideanTransformed,
            lloyd_iters: 2,
            seed: 17,
            ..Default::default()
        };
        let mut model = VifLaplaceModel::new(
            wl.xtr.clone(),
            wl.ytr.clone(),
            config,
            SolveMode::Iterative(cfg),
            wl.kernel.clone(),
            lik,
        );
        model.reselect();
        let plan = model.take_plan();
        let mut s = model.take_structure();
        let p0 = model.pack_params();
        // The same line-search-sized θ walk for both legs: consecutive
        // evaluations are near each other, like an optimizer's.
        let thetas: Vec<Vec<f64>> = (0..evals)
            .map(|t| {
                p0.iter()
                    .enumerate()
                    .map(|(j, pj)| pj + 0.05 * ((t * (j + 2)) as f64 * 0.61).sin())
                    .collect()
            })
            .collect();

        let mut run_leg = |warm: bool| -> (Vec<f64>, u64, f64) {
            let mut session = FitSession::new(warm);
            let before = solve_stats().snapshot().cg_iters;
            let (nlls, t) = common::timed(|| {
                thetas
                    .iter()
                    .map(|p| model.eval(&plan, &mut s, p, &mut session).0)
                    .collect::<Vec<f64>>()
            });
            let cg = solve_stats().snapshot().cg_iters - before;
            (nlls, cg, t)
        };
        let (nll_cold, cg_cold, t_cold) = run_leg(false);
        let (nll_warm, cg_warm, t_warm) = run_leg(true);

        let final_cold = nll_cold[evals - 1];
        let final_warm = nll_warm[evals - 1];
        let final_diff = (final_warm - final_cold).abs();
        let max_diff = nll_cold
            .iter()
            .zip(&nll_warm)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let cg_ratio = cg_warm as f64 / cg_cold.max(1) as f64;
        let speedup = t_cold / t_warm.max(1e-12);
        println!(
            "fit trajectory n={n_fit} ({evals} evals): cold {t_cold:.3}s / {cg_cold} CG iters, \
             warm {t_warm:.3}s / {cg_warm} CG iters (ratio {cg_ratio:.2}, speedup {speedup:.2}x, \
             max |ΔNLL| {max_diff:.3e})"
        );

        let json = format!(
            concat!(
                "{{\n",
                "  \"bench\": \"perf_hotpath stage 17: warm-started fit trajectory \
                 (cold vs warm FitSession)\",\n",
                "  \"config\": {{\"n\": {n}, \"d\": {d}, \"m\": {m}, \"m_v\": {mv}, \
                 \"evals\": {ev}, \"ell\": 8, \"cg_tol\": 1e-8, \"precond\": \"vifdu\"}},\n",
                "  \"cold_s\": {tc:.6},\n",
                "  \"warm_s\": {tw:.6},\n",
                "  \"time_speedup\": {sp:.3},\n",
                "  \"cold_cg_iters\": {cc},\n",
                "  \"warm_cg_iters\": {cw},\n",
                "  \"cg_iters_ratio\": {cr:.4},\n",
                "  \"final_nll_cold\": {fc:.9},\n",
                "  \"final_nll_warm\": {fw:.9},\n",
                "  \"final_nll_abs_diff\": {fd:.3e},\n",
                "  \"max_nll_abs_diff\": {md:.3e},\n",
                "  \"asserts\": {{\"max_cg_iters_ratio\": 0.8, \"final_nll_tol\": 1e-6}}\n",
                "}}\n"
            ),
            n = n_fit,
            d = d_fit,
            m = m_fit,
            mv = mv_fit,
            ev = evals,
            tc = t_cold,
            tw = t_warm,
            sp = speedup,
            cc = cg_cold,
            cw = cg_warm,
            cr = cg_ratio,
            fc = final_cold,
            fw = final_warm,
            fd = final_diff,
            md = max_diff,
        );
        let path =
            std::env::var("VIFGP_BENCH_FIT_JSON").unwrap_or_else(|_| "BENCH_fit.json".into());
        match std::fs::write(&path, json) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => println!("could not write {path}: {e}"),
        }

        // Acceptance gates, checked after the JSON lands so the artifact
        // records the trajectory even when a gate trips.
        assert!(
            cg_ratio <= 0.8,
            "warm fit spent {cg_warm} CG iterations vs cold {cg_cold} \
             (ratio {cg_ratio:.2} > 0.8): warm starts are not saving work"
        );
        assert!(
            final_diff <= 1e-6 * (1.0 + final_cold.abs()),
            "warm final NLL {final_warm} deviates from cold {final_cold} by {final_diff:.3e}"
        );
    }
}
