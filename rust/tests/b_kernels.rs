//! Dense-oracle harness for the eight Vecchia `B` kernels and the
//! determinism contract of the level-scheduled sweeps: every kernel must
//! match a dense matrix product / unit-triangular solve on randomized
//! neighbor graphs (empty, chain, saturated, irregular), `_mat` variants
//! included, and the scheduled path must be bit-identical for worker
//! pools of size 1, 2, and 8.

use vifgp::rng::Rng;
use vifgp::testing::{
    assert_b_kernels_match_dense, assert_b_kernels_pool_size_invariant, random_neighbor_graph,
    random_residual_factor,
};
use vifgp::vecchia::{LevelSchedule, ResidualFactor};

/// `k = 0`: no conditioning at all (B = I).
fn graph_empty(n: usize) -> Vec<Vec<u32>> {
    vec![vec![]; n]
}

/// `k = 1` chain: `N(i) = {i−1}` — the worst case for the schedule
/// (n levels of one row each).
fn graph_chain(n: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| if i == 0 { vec![] } else { vec![i as u32 - 1] })
        .collect()
}

/// Saturated `k = i`: `N(i) = {0..i−1}` (dense lower triangle).
fn graph_saturated(n: usize) -> Vec<Vec<u32>> {
    (0..n).map(|i| (0..i as u32).collect()).collect()
}

/// Irregular: a random-size random subset of earlier rows per row
/// (the shared `testing` generator with degree ≤ 7).
fn graph_irregular(n: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    random_neighbor_graph(rng, n, 7)
}

/// Blocked graph: rows come in blocks of `width`, each row conditioning
/// on up to 6 random rows of the previous block — the schedule is
/// exactly `n / width` levels of `width` rows each, wide enough for the
/// pool path to fan genuinely concurrent jobs per level.
fn graph_blocked(n: usize, width: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| {
            let block = i / width;
            if block == 0 {
                return vec![];
            }
            let lo = (block - 1) * width;
            let mut picked = std::collections::BTreeSet::new();
            for _ in 0..6 {
                picked.insert((lo + rng.below(width)) as u32);
            }
            picked.into_iter().collect()
        })
        .collect()
}

/// Ragged blocked graph: like [`graph_blocked`] but with one level per
/// entry of `widths`, so the schedule mixes levels just above, just
/// below, and far from the fan-out work gate — chunk boundaries land at
/// irregular offsets.
fn graph_ragged(widths: &[usize], rng: &mut Rng) -> Vec<Vec<u32>> {
    let mut out: Vec<Vec<u32>> = Vec::new();
    let mut prev_lo = 0usize;
    let mut prev_w = 0usize;
    for &w in widths {
        let lo = out.len();
        for _ in 0..w {
            if prev_w == 0 {
                out.push(vec![]);
                continue;
            }
            let mut picked = std::collections::BTreeSet::new();
            for _ in 0..6 {
                picked.insert((prev_lo + rng.below(prev_w)) as u32);
            }
            out.push(picked.into_iter().collect());
        }
        prev_lo = lo;
        prev_w = w;
    }
    out
}

const MAT_COLS: [usize; 3] = [1, 3, 17];

#[test]
fn dense_oracle_empty_graph() {
    let mut rng = Rng::seed_from(11);
    for n in [1usize, 13, 47] {
        let f = random_residual_factor(&mut rng, graph_empty(n));
        assert_b_kernels_match_dense(&f, &mut rng, &MAT_COLS, 1e-11);
    }
}

#[test]
fn dense_oracle_chain_graph() {
    let mut rng = Rng::seed_from(12);
    for n in [1usize, 13, 47] {
        let f = random_residual_factor(&mut rng, graph_chain(n));
        assert_b_kernels_match_dense(&f, &mut rng, &MAT_COLS, 1e-11);
    }
}

#[test]
fn dense_oracle_saturated_graph() {
    let mut rng = Rng::seed_from(13);
    for n in [1usize, 13, 47] {
        let f = random_residual_factor(&mut rng, graph_saturated(n));
        assert_b_kernels_match_dense(&f, &mut rng, &MAT_COLS, 1e-10);
    }
}

#[test]
fn dense_oracle_irregular_graphs() {
    let mut rng = Rng::seed_from(14);
    for n in [1usize, 13, 30, 61] {
        let nb = graph_irregular(n, &mut rng);
        let f = random_residual_factor(&mut rng, nb);
        assert_b_kernels_match_dense(&f, &mut rng, &MAT_COLS, 1e-10);
    }
}

#[test]
fn dense_oracle_with_forced_scheduling_threshold() {
    // sched_min_rows = 0 routes the *plain* kernel entry points through
    // the scheduled path even for tiny factors; they must still match.
    let mut rng = Rng::seed_from(15);
    let nb = graph_irregular(40, &mut rng);
    let mut f = random_residual_factor(&mut rng, nb);
    f.sched_min_rows = 0;
    let b = f.dense_b();
    let v = rng.normal_vec(40);
    let got = f.mul_b(&v);
    let want = b.matvec(&v);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-11 * (1.0 + w.abs()), "{g} vs {w}");
    }
    let got = f.solve_b(&f.mul_b(&v));
    for (g, w) in got.iter().zip(&v) {
        assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()), "{g} vs {w}");
    }
}

#[test]
fn scheduled_kernels_bit_identical_across_pool_sizes() {
    let mut rng = Rng::seed_from(16);
    for n in [33usize, 80] {
        for nb in [
            graph_chain(n),
            graph_saturated(n),
            graph_irregular(n, &mut rng),
        ] {
            let f = random_residual_factor(&mut rng, nb);
            assert_b_kernels_pool_size_invariant(&f, &mut rng, &[1, 2, 8], 5);
        }
    }
}

#[test]
fn scheduled_kernels_bit_identical_on_wide_levels() {
    // 3 levels of 4096 rows each: wide enough that every sweep clears
    // the fan-out work gate and each level splits into multiple jobs.
    let mut rng = Rng::seed_from(17);
    let nb = graph_blocked(3 * 4096, 4096, &mut rng);
    let f = random_residual_factor(&mut rng, nb);
    assert_b_kernels_pool_size_invariant(&f, &mut rng, &[1, 2, 8], 3);
}

#[test]
fn scheduled_kernels_bit_identical_on_ragged_levels() {
    // Level widths straddle the fan-out work gate (4096) and leave
    // remainder chunks at irregular offsets: 4500 fans with a ragged
    // tail chunk, 300/700 run inline, 4099 is barely past the gate.
    let mut rng = Rng::seed_from(18);
    let nb = graph_ragged(&[4500, 300, 4099, 700], &mut rng);
    let f = random_residual_factor(&mut rng, nb);
    assert_b_kernels_pool_size_invariant(&f, &mut rng, &[1, 2, 8], 5);
}

#[test]
fn mul_bt_keeps_signed_zero_semantics_of_dense_product() {
    // The seed's `vi == 0.0` early-continue skipped −0.0 inputs, leaving
    // out[0] at −0.0 where the dense Bᵀ product yields +0.0. The gather
    // through the transposed index must match the dense result bitwise.
    let f = ResidualFactor::from_parts(
        vec![vec![], vec![0u32]],
        vec![vec![], vec![1.0]],
        vec![1.0, 1.0],
    );
    let v = [-0.0f64, -0.0];
    let got = f.mul_bt(&v);
    let want = f.dense_b().t().matvec(&v);
    assert_eq!(
        got[0].to_bits(),
        want[0].to_bits(),
        "mul_bt signed zero: {} vs dense {}",
        got[0],
        want[0]
    );
    assert!(got[0] == 0.0 && got[0].is_sign_positive());
}

#[test]
fn schedule_depth_matches_graph_family() {
    let n = 24;
    assert_eq!(LevelSchedule::from_neighbors(&graph_empty(n)).num_levels(), 1);
    assert_eq!(LevelSchedule::from_neighbors(&graph_chain(n)).num_levels(), n);
    assert_eq!(
        LevelSchedule::from_neighbors(&graph_saturated(n)).num_levels(),
        n
    );
}
