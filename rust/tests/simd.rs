//! Remainder-lane property suite for the SIMD lane backend.
//!
//! Every vectorized kernel is exercised at sizes 1..=17 — crossing the
//! 4-lane width with every remainder phase — and at production shapes
//! (k = m ≈ 100 low-rank panels, ≤64-point prediction blocks, nb-sized
//! conditioning sets), pinning the backend-pinned `*_simd` variants to
//! their `*_scalar` oracles at ≤1e-12. The public dispatching entry
//! points are additionally pinned bit-identical to the scalar oracle
//! below the work threshold (so the existing ≤1e-14 panel suites hold
//! on both `VIFGP_SIMD` legs), and the fault-injection NaN-panel hook
//! is asserted to fire on the pinned SIMD path.

use vifgp::faults::{self, FaultPlan};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::linalg::{CholeskyFactor, Mat};

const TOL: f64 = 1e-12;

fn mat(r: usize, c: usize, seed: usize) -> Mat {
    Mat::from_fn(r, c, |i, j| ((i * 31 + j * 17 + seed * 7 + 3) as f64 * 0.37).sin())
}

fn spd(n: usize, seed: usize) -> Mat {
    let g = mat(n, n, seed);
    let mut a = g.matmul_nt_scalar(&g);
    a.add_diag(n as f64 + 1.0);
    a
}

fn assert_close(got: &Mat, want: &Mat, tol: f64, what: &str) {
    let d = got.max_abs_diff(want);
    assert!(d <= tol, "{what}: max abs diff {d:.3e} > {tol:.1e}");
}

#[test]
fn gemm_variants_match_scalar_at_remainder_sizes() {
    for m in 1..=17usize {
        for k in [1usize, 2, 3, 4, 5, 7, 8, 11, 16, 17] {
            for n in 1..=17usize {
                let a = mat(m, k, 1);
                let b = mat(k, n, 2);
                let tag = format!("m={m} k={k} n={n}");
                assert_close(&a.matmul_simd(&b), &a.matmul_scalar(&b), TOL, &format!("nn {tag}"));
                let at = mat(k, m, 3);
                let mut out_s = Mat::zeros(m, n);
                let mut out_v = Mat::zeros(m, n);
                at.matmul_tn_into_scalar(&b, &mut out_s);
                at.matmul_tn_into_simd(&b, &mut out_v);
                assert_close(&out_v, &out_s, TOL, &format!("tn {tag}"));
                let bt = mat(n, k, 4);
                assert_close(
                    &a.matmul_nt_simd(&bt),
                    &a.matmul_nt_scalar(&bt),
                    TOL,
                    &format!("nt {tag}"),
                );
            }
        }
    }
}

#[test]
fn gram_and_syrk_match_scalar_at_remainder_sizes() {
    for n in 1..=17usize {
        for k in [1usize, 3, 4, 5, 8, 16, 17] {
            let tag = format!("n={n} k={k}");
            let g = mat(k, n, 5);
            assert_close(&g.gram_t_simd(), &g.gram_t_scalar(), TOL, &format!("gram {tag}"));

            let v = mat(n, k, 6);
            let base = spd(n, 7);
            let mut got = base.clone();
            got.syrk_sub_panel_simd(v.data(), k);
            let mut want = base.clone();
            want.syrk_sub_panel_scalar(v.data(), k);
            assert_close(&got, &want, TOL, &format!("syrk {tag}"));

            let b = mat(n, k, 8);
            let mut got2 = base.clone();
            got2.syr2k_sub_panel_simd(v.data(), b.data(), k);
            let mut want2 = base.clone();
            want2.syr2k_sub_panel_scalar(v.data(), b.data(), k);
            assert_close(&got2, &want2, TOL, &format!("syr2k {tag}"));

            // weighted SYRK: the panel has `n` rows of length `k`, the
            // target is k×k (the Woodbury core orientation).
            let w: Vec<f64> = (0..n).map(|t| 0.4 + 0.1 * t as f64).collect();
            let basek = spd(k, 9);
            let mut got3 = basek.clone();
            got3.syrk_add_panel_weighted_simd(v.data(), k, &w);
            let mut want3 = basek.clone();
            want3.syrk_add_panel_weighted_scalar(v.data(), k, &w);
            assert_close(&got3, &want3, TOL, &format!("wsyrk {tag}"));
        }
    }
}

#[test]
fn trsm_matches_scalar_at_remainder_sizes() {
    for n in (1..=17usize).chain([64]) {
        let f = CholeskyFactor::new(&spd(n, 10)).expect("spd factorizes");
        for w in [1usize, 3, 4, 8, 17] {
            let b = mat(n, w, 11);
            let tag = format!("n={n} w={w}");
            assert_close(
                &f.solve_lower_mat_simd(&b),
                &f.solve_lower_mat_scalar(&b),
                TOL,
                &format!("trsm-lower {tag}"),
            );
            assert_close(
                &f.solve_upper_mat_simd(&b),
                &f.solve_upper_mat_scalar(&b),
                TOL,
                &format!("trsm-upper {tag}"),
            );
            assert_close(
                &f.solve_mat_simd(&b),
                &f.solve_mat_scalar(&b),
                TOL,
                &format!("trsm-full {tag}"),
            );
        }
    }
}

fn kernel(d: usize) -> ArdMatern {
    let ls: Vec<f64> = (0..d).map(|j| 0.4 + 0.15 * j as f64).collect();
    ArdMatern::new(1.7, ls, Smoothness::ThreeHalves)
}

/// Row-major pseudo-random `len×d` panel; row `dup` (if in range)
/// duplicates `q` so the r = 0 gradient branch is crossed.
fn panel(len: usize, d: usize, q: &[f64], dup: usize) -> Vec<f64> {
    let mut p = Vec::with_capacity(len * d);
    for t in 0..len {
        if t == dup {
            p.extend_from_slice(q);
        } else {
            for j in 0..d {
                p.push(((t * 13 + j * 5 + 1) as f64 * 0.29).sin());
            }
        }
    }
    p
}

fn assert_slices_close(got: &[f64], want: &[f64], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() <= tol, "{what}[{i}]: {g} vs {w}");
    }
}

#[test]
fn panel_kernels_match_scalar_at_remainder_sizes() {
    for d in [1usize, 2, 3, 5, 8, 17] {
        let k = kernel(d);
        let q: Vec<f64> = (0..d).map(|j| (j as f64 * 0.41).cos()).collect();
        for len in (1..=17usize).chain([100]) {
            let p = panel(len, d, &q, len / 2);
            let tag = format!("d={d} len={len}");

            let mut rs = vec![0.0; len];
            let mut rv = vec![0.0; len];
            k.scaled_dist_panel_scalar(&q, &p, &mut rs);
            k.scaled_dist_panel_simd(&q, &p, &mut rv);
            assert_slices_close(&rv, &rs, TOL, &format!("dist {tag}"));

            let mut cs = vec![0.0; len];
            let mut cv = vec![0.0; len];
            k.corr_panel_scalar(&q, &p, &mut cs);
            k.corr_panel_simd(&q, &p, &mut cv);
            assert_slices_close(&cv, &cs, TOL, &format!("corr {tag}"));

            let mut cov_s = vec![0.0; len];
            let mut cov_v = vec![0.0; len];
            let mut g_s = vec![0.0; (1 + d) * len];
            let mut g_v = vec![0.0; (1 + d) * len];
            k.cov_and_grad_panel_scalar(&q, &p, &mut cov_s, &mut g_s);
            k.cov_and_grad_panel_simd(&q, &p, &mut cov_v, &mut g_v);
            assert_slices_close(&cov_v, &cov_s, TOL, &format!("grad-cov {tag}"));
            assert_slices_close(&g_v, &g_s, TOL, &format!("grad {tag}"));
        }
    }
}

#[test]
fn sym_cov_panel_matches_scalar() {
    let d = 3;
    let k = kernel(d);
    for q in [1usize, 2, 5, 13, 16, 17, 40, 64] {
        let p = panel(q, d, &[0.1, 0.2, 0.3], q + 1);
        let mut out_s = Mat::zeros(q, q);
        let mut out_v = Mat::zeros(q, q);
        k.sym_cov_panel_scalar(&p, &mut out_s);
        k.sym_cov_panel_simd(&p, &mut out_v);
        assert_close(&out_v, &out_s, TOL, &format!("sym_cov_panel q={q}"));
    }
}

#[test]
fn gemm_and_trsm_match_scalar_at_production_shapes() {
    // Woodbury side blocks: (n-ish × m) panels against m×m cores.
    let a = mat(512, 100, 20);
    let b = mat(100, 100, 21);
    assert_close(&a.matmul_simd(&b), &a.matmul_scalar(&b), TOL, "nn 512x100x100");

    let at = mat(600, 100, 22);
    let bt = mat(600, 64, 23);
    let mut out_s = Mat::zeros(100, 64);
    let mut out_v = Mat::zeros(100, 64);
    at.matmul_tn_into_scalar(&bt, &mut out_s);
    at.matmul_tn_into_simd(&bt, &mut out_v);
    assert_close(&out_v, &out_s, TOL, "tn 600x100x64");

    // Prediction-block ρ_NN correction: 64-point block, k = m = 100.
    let v = mat(64, 100, 24);
    assert_close(&v.matmul_nt_simd(&v), &v.matmul_nt_scalar(&v), TOL, "nt 64x100x64");
    let base = spd(64, 25);
    let mut got = base.clone();
    got.syrk_sub_panel_simd(v.data(), 100);
    let mut want = base.clone();
    want.syrk_sub_panel_scalar(v.data(), 100);
    assert_close(&got, &want, TOL, "syrk 64x100");

    assert_close(&at.gram_t_simd(), &at.gram_t_scalar(), TOL, "gram 600x100");

    let f = CholeskyFactor::new(&spd(100, 26)).expect("spd factorizes");
    let rhs = mat(100, 64, 27);
    assert_close(
        &f.solve_lower_mat_simd(&rhs),
        &f.solve_lower_mat_scalar(&rhs),
        TOL,
        "trsm 100x64",
    );
    assert_close(&f.solve_mat_simd(&rhs), &f.solve_mat_scalar(&rhs), TOL, "solve 100x64");
}

/// Below the work threshold the public entry points must route to the
/// scalar path — bit-identical on both `VIFGP_SIMD` legs, which is what
/// keeps the pre-existing ≤1e-14 small-panel suites backend-independent.
#[test]
fn public_dispatch_is_bitwise_scalar_below_threshold() {
    let a = mat(3, 4, 30);
    let b = mat(4, 3, 31);
    assert_eq!(a.matmul(&b).data(), a.matmul_scalar(&b).data());
    let k = kernel(3);
    let q = [0.2, -0.1, 0.4];
    let p = panel(5, 3, &q, 2);
    let mut pub_out = vec![0.0; 5];
    let mut sc_out = vec![0.0; 5];
    k.corr_panel(&q, &p, &mut pub_out);
    k.corr_panel_scalar(&q, &p, &mut sc_out);
    assert_eq!(pub_out, sc_out);
}

/// The public dispatching entry points agree with both pinned backends
/// to ≤1e-12 at above-threshold sizes, whichever leg is active.
#[test]
fn public_dispatch_matches_both_backends_above_threshold() {
    let a = mat(40, 30, 32);
    let b = mat(30, 20, 33);
    let got = a.matmul(&b);
    assert_close(&got, &a.matmul_scalar(&b), TOL, "dispatch vs scalar");
    assert_close(&got, &a.matmul_simd(&b), TOL, "dispatch vs simd");
}

/// The dense covariance entry points (`cross_cov`, `sym_cov`) are routed
/// through the panel primitives; pin them to the per-pair oracle above
/// the dispatch threshold on whichever backend leg is active.
#[test]
fn dense_cov_blocks_match_per_pair_oracle() {
    let d = 4;
    let k = kernel(d);
    let a = mat(23, d, 40);
    let b = mat(37, d, 41);
    let c = k.cross_cov(&a, &b);
    for i in 0..a.rows() {
        for j in 0..b.rows() {
            let want = k.cov(a.row(i), b.row(j));
            assert!((c.get(i, j) - want).abs() <= TOL, "cross_cov [{i},{j}]");
        }
    }
    let s = k.sym_cov(&a, 0.013);
    for i in 0..a.rows() {
        for j in 0..a.rows() {
            let want = if i == j { k.variance + 0.013 } else { k.cov(a.row(i), a.row(j)) };
            assert!((s.get(i, j) - want).abs() <= TOL, "sym_cov [{i},{j}]");
            assert_eq!(s.get(i, j), s.get(j, i), "sym_cov symmetry [{i},{j}]");
        }
    }
}

/// The chaos-harness NaN-panel hook must keep firing when the panel was
/// computed by the lane backend (the fault surface is dispatch-independent).
#[test]
fn nan_panel_hook_fires_on_simd_path() {
    let d = 3;
    let k = kernel(d);
    let q = [0.1, 0.2, 0.3];
    let len = 128; // len·d well above the dispatch threshold
    let p = panel(len, d, &q, 7);
    let mut out = vec![0.0; len];
    let guard = faults::install(FaultPlan { nan_panel: true, ..Default::default() });
    k.corr_panel_simd(&q, &p, &mut out);
    assert!(out.iter().all(|v| v.is_nan()), "armed hook must poison the SIMD panel");
    drop(guard);
    k.corr_panel_simd(&q, &p, &mut out);
    assert!(out.iter().all(|v| v.is_finite()), "disarmed hook must leave the panel clean");
}
