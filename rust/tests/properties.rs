//! Property-based tests over the library's core invariants, using the
//! in-tree seeded property harness (`vifgp::testing::check`).

use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::linalg::{CholeskyFactor, Mat};
use vifgp::rng::Rng;
use vifgp::testing::{
    check, random_neighbor_graph, random_points, random_residual_factor, structures_max_abs_diff,
};
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vecchia::LevelSchedule;
use vifgp::vif::{select_inducing, select_neighbors, VifPlan, VifStructure};

fn random_kernel(rng: &mut Rng, d: usize) -> ArdMatern {
    let smoothness = match rng.below(4) {
        0 => Smoothness::Half,
        1 => Smoothness::ThreeHalves,
        2 => Smoothness::FiveHalves,
        _ => Smoothness::Gaussian,
    };
    ArdMatern::new(
        rng.uniform_in(0.3, 2.5),
        (0..d).map(|_| rng.uniform_in(0.15, 0.9)).collect(),
        smoothness,
    )
}

fn random_structure(rng: &mut Rng) -> (Mat, ArdMatern, VifStructure, f64) {
    let n = 20 + rng.below(25);
    let d = 1 + rng.below(3);
    let x = random_points(rng, n, d);
    let kernel = random_kernel(rng, d);
    let m = rng.below(8); // 0 → pure Vecchia
    let m_v = rng.below(6); // 0 → FITC
    let nugget = rng.uniform_in(0.01, 0.3);
    let z = select_inducing(&x, &kernel, m, 2, rng, None);
    let lr = z
        .clone()
        .map(|z| vifgp::vif::LowRank::build(&x, &kernel, z, 1e-10));
    let nb = select_neighbors(
        &x,
        &kernel,
        lr.as_ref(),
        m_v,
        NeighborSelection::CorrelationBruteForce,
    );
    let s = VifStructure::assemble(&x, &kernel, z, nb, nugget, 1e-10, 0);
    (x, kernel, s, nugget)
}

#[test]
fn prop_sigma_dagger_is_spd() {
    check(
        "Σ_† dense matrix is symmetric positive definite",
        25,
        42,
        |rng| random_structure(rng),
        |(_, _, s, _)| {
            let dense = s.dense_sigma_dagger();
            let sym_err = dense.max_abs_diff(&dense.t());
            if sym_err > 1e-8 {
                return Err(format!("asymmetry {sym_err}"));
            }
            CholeskyFactor::new_with_jitter(&dense, 1e-12)
                .map(|_| ())
                .map_err(|e| format!("not PD: {e}"))
        },
    );
}

#[test]
fn prop_inverse_consistency() {
    check(
        "Σ_†⁻¹ Σ_† v = v",
        25,
        7,
        |rng| {
            let (x, k, s, ng) = random_structure(rng);
            let v = rng.normal_vec(s.n());
            (x, k, s, ng, v)
        },
        |(_, _, s, _, v)| {
            let w = s.apply_sigma_dagger_inv(&s.apply_sigma_dagger(v));
            for (a, b) in w.iter().zip(v) {
                if (a - b).abs() > 1e-6 * (1.0 + b.abs()) {
                    return Err(format!("{a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_logdet_matches_dense() {
    check(
        "structure logdet equals dense Cholesky logdet",
        20,
        9,
        |rng| random_structure(rng),
        |(_, _, s, _)| {
            let dense = s.dense_sigma_dagger();
            let chol = CholeskyFactor::new_with_jitter(&dense, 1e-12)
                .map_err(|e| e.to_string())?;
            let (a, b) = (s.logdet(), chol.logdet());
            if (a - b).abs() > 1e-6 * (1.0 + b.abs()) {
                return Err(format!("logdet {a} vs {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_conditional_variances_decrease_with_more_neighbors() {
    // D_i is the conditional variance given N(i); conditioning on a
    // superset cannot increase it.
    check(
        "Vecchia D_i monotone under neighbor-set growth",
        15,
        21,
        |rng| {
            let n = 25 + rng.below(15);
            let x = random_points(rng, n, 2);
            let kernel = random_kernel(rng, 2);
            (x, kernel)
        },
        |(x, kernel)| {
            let nb_small = select_neighbors(x, kernel, None, 2, NeighborSelection::EuclideanTransformed);
            let nb_big: Vec<Vec<u32>> = (0..x.rows()).map(|i| (0..i as u32).collect()).collect();
            let s_small = VifStructure::assemble(x, kernel, None, nb_small, 0.05, 1e-10, 0);
            let s_big = VifStructure::assemble(x, kernel, None, nb_big, 0.05, 1e-10, 0);
            for i in 0..x.rows() {
                if s_big.resid.d[i] > s_small.resid.d[i] + 1e-8 {
                    return Err(format!(
                        "i={i}: full-cond D {} > truncated D {}",
                        s_big.resid.d[i], s_small.resid.d[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_covertree_neighbors_match_brute_force() {
    check(
        "cover-tree kNN distances equal brute-force kNN distances",
        10,
        33,
        |rng| {
            let n = 60 + rng.below(120);
            let x = random_points(rng, n, 2);
            let kernel = random_kernel(rng, 2);
            (x, kernel)
        },
        |(x, kernel)| {
            let bf = select_neighbors(x, kernel, None, 4, NeighborSelection::CorrelationBruteForce);
            let ct = select_neighbors(x, kernel, None, 4, NeighborSelection::CorrelationCoverTree);
            // compare multisets of kernel correlations (ties may reorder)
            for i in 0..x.rows() {
                let mut db: Vec<f64> = bf[i]
                    .iter()
                    .map(|&j| kernel.cov(x.row(i), x.row(j as usize)))
                    .collect();
                let mut dc: Vec<f64> = ct[i]
                    .iter()
                    .map(|&j| kernel.cov(x.row(i), x.row(j as usize)))
                    .collect();
                db.sort_by(f64::total_cmp);
                dc.sort_by(f64::total_cmp);
                for (a, b) in db.iter().zip(&dc) {
                    if (a - b).abs() > 1e-10 {
                        return Err(format!("i={i}: corr {a} vs {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_level_schedule_is_topological_partition() {
    check(
        "level schedule covers rows exactly once; neighbors strictly earlier",
        40,
        101,
        |rng| {
            let n = 1 + rng.below(70);
            random_neighbor_graph(rng, n, 8)
        },
        |nb| {
            let n = nb.len();
            let sched = LevelSchedule::from_neighbors(nb);
            let mut level_of = vec![usize::MAX; n];
            for (l, rows) in sched.levels.iter().enumerate() {
                if rows.is_empty() {
                    return Err(format!("level {l} is empty"));
                }
                for &iu in rows {
                    let i = iu as usize;
                    if i >= n {
                        return Err(format!("row {i} out of range"));
                    }
                    if level_of[i] != usize::MAX {
                        return Err(format!("row {i} appears in two levels"));
                    }
                    level_of[i] = l;
                }
            }
            for (i, &l) in level_of.iter().enumerate() {
                if l == usize::MAX {
                    return Err(format!("row {i} missing from the schedule"));
                }
                for &j in &nb[i] {
                    if level_of[j as usize] >= l {
                        return Err(format!(
                            "row {i} (level {l}) has neighbor {j} in level {} (not earlier)",
                            level_of[j as usize]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_solve_is_left_inverse_of_mul() {
    // solve_b(mul_b(v)) == v and solve_bt(mul_bt(v)) == v to machine
    // precision, with the scheduled path forced on (sched_min_rows = 0).
    check(
        "B solves invert B products to machine precision",
        30,
        67,
        |rng| {
            let n = 1 + rng.below(70);
            let nb = random_neighbor_graph(rng, n, 8);
            let mut f = random_residual_factor(rng, nb);
            f.sched_min_rows = 0;
            let v = rng.normal_vec(n);
            (f, v)
        },
        |(f, v)| {
            let fwd = f.solve_b(&f.mul_b(v));
            let bwd = f.solve_bt(&f.mul_bt(v));
            for (which, got) in [("B", &fwd), ("Bᵀ", &bwd)] {
                for (g, w) in got.iter().zip(v) {
                    if (g - w).abs() > 1e-11 * (1.0 + w.abs()) {
                        return Err(format!("{which} roundtrip: {g} vs {w}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_reuse_across_rounds_matches_fresh_assembly() {
    // The plan/refresh split: one θ-independent plan, several θ steps of
    // in-place refresh — every refreshed state must equal a from-scratch
    // assembly with the same structure choices (m=0, m_v=0, and the
    // general case are all drawn by the generator).
    check(
        "plan-reuse refresh equals fresh assembly over a θ trajectory",
        10,
        77,
        |rng| {
            let n = 20 + rng.below(25);
            let d = 1 + rng.below(3);
            let x = random_points(rng, n, d);
            let kernel = random_kernel(rng, d);
            let m = rng.below(8); // 0 → pure Vecchia
            let m_v = rng.below(6); // 0 → FITC
            let nugget = rng.uniform_in(0.01, 0.3);
            let z = select_inducing(&x, &kernel, m, 2, rng, None);
            let lr = z
                .clone()
                .map(|z| vifgp::vif::LowRank::build(&x, &kernel, z, 1e-10));
            let nb = select_neighbors(
                &x,
                &kernel,
                lr.as_ref(),
                m_v,
                NeighborSelection::CorrelationBruteForce,
            );
            (x, kernel, z, nb, nugget)
        },
        |(x, kernel, z, nb, nugget)| {
            let plan = VifPlan::build(x, z.clone(), nb.clone());
            let mut s = VifStructure::from_plan(x, kernel, &plan, *nugget, 1e-10, 0);
            let fresh0 =
                VifStructure::assemble(x, kernel, z.clone(), nb.clone(), *nugget, 1e-10, 0);
            let d0 = structures_max_abs_diff(&s, &fresh0);
            if d0 > 1e-12 {
                return Err(format!("from_plan vs assemble diff {d0:.3e}"));
            }
            for t in 1..=3usize {
                let mut p = kernel.log_params();
                for (j, pj) in p.iter_mut().enumerate() {
                    *pj += 0.1 * ((t * (j + 1)) as f64).sin();
                }
                let kt = ArdMatern::from_log_params(&p, kernel.smoothness);
                s.refresh(&plan, x, &kt, *nugget, 1e-10);
                let fresh =
                    VifStructure::assemble(x, &kt, z.clone(), nb.clone(), *nugget, 1e-10, 0);
                let diff = structures_max_abs_diff(&s, &fresh);
                if diff > 1e-12 {
                    return Err(format!("round {t}: refresh vs assemble diff {diff:.3e}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sampling_has_right_first_two_moments() {
    check(
        "Σ_† samples have zero mean and matching variance scale",
        6,
        55,
        |rng| {
            let (x, k, s, ng) = random_structure(rng);
            let seed = rng.next_u64();
            (x, k, s, ng, seed)
        },
        |(_, _, s, _, seed)| {
            let dense = s.dense_sigma_dagger();
            let mut rng = Rng::seed_from(*seed);
            let reps = 4000;
            let n = s.n();
            let mut mean = vec![0.0; n];
            let mut var = vec![0.0; n];
            for _ in 0..reps {
                let smp = s.sample(&mut rng);
                for i in 0..n {
                    mean[i] += smp[i];
                    var[i] += smp[i] * smp[i];
                }
            }
            for i in 0..n {
                mean[i] /= reps as f64;
                var[i] = var[i] / reps as f64 - mean[i] * mean[i];
                let want = dense.get(i, i);
                if (var[i] - want).abs() > 0.25 * want.max(0.1) {
                    return Err(format!("var[{i}] {} vs {}", var[i], want));
                }
            }
            Ok(())
        },
    );
}
