//! End-to-end non-Gaussian workloads through the VIF-Laplace pipeline
//! with iterative methods: classification recovers signal; Poisson and
//! Gamma regressions beat the prior-mean baseline; Fig-1 shape (σ₁² bias
//! shrinks with n).

use vifgp::data;
use vifgp::iterative::{IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vif::laplace::{PredVarMethod, SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

fn iter_mode() -> SolveMode {
    SolveMode::Iterative(IterConfig {
        precond: PrecondType::Fitc,
        ell: 20,
        fitc_k: 40,
        ..Default::default()
    })
}

type Sim = (vifgp::linalg::Mat, Vec<f64>, vifgp::linalg::Mat, Vec<f64>, Vec<f64>);

fn simulate(seed: u64, n: usize, n_test: usize, lik: &Likelihood) -> Sim {
    let mut rng = Rng::seed_from(seed);
    let x = data::uniform_inputs(&mut rng, n + n_test, 2);
    let kernel = ArdMatern::new(1.0, vec![0.15, 0.25], Smoothness::ThreeHalves);
    let latent = data::simulate_latent_gp(&mut rng, &x, &kernel);
    let y = data::simulate_response(&mut rng, &latent, lik);
    let idx: Vec<usize> = (0..n + n_test).collect();
    let (tr, te) = idx.split_at(n);
    (
        data::subset_rows(&x, tr),
        data::subset_vec(&y, tr),
        data::subset_rows(&x, te),
        data::subset_vec(&y, te),
        data::subset_vec(&latent, te),
    )
}

fn config(seed: u64) -> VifConfig {
    VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 30,
        num_neighbors: 6,
        seed,
        ..Default::default()
    }
}

#[test]
fn bernoulli_classification_recovers_signal() {
    let lik = Likelihood::BernoulliLogit;
    let (xtr, ytr, xte, yte, _) = simulate(11, 800, 300, &lik);
    let init = ArdMatern::isotropic(0.5, 0.4, 2, Smoothness::ThreeHalves);
    let mut model = VifLaplaceModel::new(xtr, ytr, config(1), iter_mode(), init, lik);
    model.fit(15);
    let pred = model.predict(&xte, PredVarMethod::Sbpv, 30);
    let labels: Vec<bool> = yte.iter().map(|&v| v > 0.5).collect();
    // Note: for a unit-variance logit GP the *Bayes-optimal* AUC is only
    // ≈ 0.74 (class overlap); at n = 800 with estimated parameters the
    // model should capture most of that.
    let auc = metrics::auc(&pred.response_mean, &labels);
    assert!(auc > 0.63, "AUC {auc}");
    let acc = metrics::accuracy(&pred.response_mean, &labels);
    assert!(acc > 0.55, "ACC {acc}"); // Bayes-optimal ≈ 0.70 here
}

#[test]
fn poisson_regression_tracks_latent_intensity() {
    let lik = Likelihood::Poisson;
    let (xtr, ytr, xte, _, latent_te) = simulate(13, 700, 300, &lik);
    let init = ArdMatern::isotropic(0.5, 0.4, 2, Smoothness::ThreeHalves);
    let mut model = VifLaplaceModel::new(xtr, ytr, config(2), iter_mode(), init, lik);
    model.fit(15);
    let pred = model.predict(&xte, PredVarMethod::Spv, 30);
    // latent prediction should clearly beat the zero (prior-mean) predictor
    let rmse_model = metrics::rmse(&pred.latent_mean, &latent_te);
    let rmse_zero = metrics::rmse(&vec![0.0; latent_te.len()], &latent_te);
    assert!(
        rmse_model < 0.8 * rmse_zero,
        "model {rmse_model} vs zero {rmse_zero}"
    );
}

#[test]
fn gamma_regression_estimates_shape() {
    let lik = Likelihood::Gamma { shape: 2.0 };
    let (xtr, ytr, xte, _, latent_te) = simulate(17, 700, 250, &lik);
    // start the shape off-true
    let init_lik = Likelihood::Gamma { shape: 1.0 };
    let init = ArdMatern::isotropic(0.5, 0.4, 2, Smoothness::ThreeHalves);
    let mut model = VifLaplaceModel::new(xtr, ytr, config(3), iter_mode(), init, init_lik);
    model.fit(20);
    let shape = match model.lik {
        Likelihood::Gamma { shape } => shape,
        _ => unreachable!(),
    };
    // The shape is only weakly identified against the kernel variance at
    // this n (dispersion can be absorbed by the latent GP); require a
    // sane range, and rely on the latent-RMSE check below for signal.
    assert!(shape > 0.3 && shape < 5.0, "estimated shape {shape}");
    let pred = model.predict(&xte, PredVarMethod::Sbpv, 30);
    let rmse = metrics::rmse(&pred.latent_mean, &latent_te);
    assert!(rmse < 0.8, "latent rmse {rmse}");
}

#[test]
fn fig1_variance_bias_shrinks_with_n() {
    // Fig 1 (paper): the downward bias of σ₁² under VIFLA shrinks with n.
    let lik = Likelihood::BernoulliLogit;
    let mut biases = Vec::new();
    for (seedbase, n) in [(100u64, 300usize), (200, 1200)] {
        let mut est = Vec::new();
        for r in 0..3 {
            let (xtr, ytr, _, _, _) = simulate(seedbase + r, n, 10, &lik);
            let init = ArdMatern::isotropic(1.0, 0.2, 2, Smoothness::ThreeHalves);
            let mut model = VifLaplaceModel::new(
                xtr,
                ytr,
                VifConfig {
                    num_inducing: 20,
                    num_neighbors: 5,
                    seed: r,
                    ..config(4)
                },
                iter_mode(),
                init,
                lik.clone(),
            );
            model.fit(12);
            est.push(model.kernel.variance);
        }
        let mean_est = est.iter().sum::<f64>() / est.len() as f64;
        biases.push((1.0 - mean_est).abs());
    }
    // larger n → estimate closer to the true σ₁² = 1 (generous slack for
    // the tiny replicate count).
    assert!(
        biases[1] < biases[0] + 0.25,
        "bias at n=300: {} vs n=1200: {}",
        biases[0],
        biases[1]
    );
}
