//! End-to-end chaos tests: deterministic fault injection
//! ([`vifgp::faults`]) driven through the public API, asserting the
//! containment contracts of the crate-root "Failure semantics" section —
//! injected numerical failures are escalated and recovered inside the
//! iterative stack, and injected serving failures are quarantined
//! per-request without taking the engine down.
//!
//! Every test brackets itself with [`vifgp::faults::install`], which
//! serializes the suite behind a global lock: the tests are
//! deterministic regardless of the harness' thread count and also pass
//! under a plain `cargo test` with `VIFGP_FAULTS` unset. Fixtures are
//! built while the guard holds an *empty* plan, so no other test's
//! faults can leak into model construction.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use vifgp::faults::{self, FaultPlan};
use vifgp::iterative::{solve_stats, IterConfig};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::linalg::{CholeskyFactor, Mat};
use vifgp::rng::Rng;
use vifgp::serve::{Health, Prediction, ServeEngine, ServeModel, ServeOptions};
use vifgp::testing::random_points;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::laplace::{SolveMode, VifLaplaceModel};
use vifgp::vif::VifConfig;

fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / (1.0 + b.abs())
}

/// Assembled Gaussian model over `n` random 2-d points (serving only
/// needs a structure, not an optimized fit).
fn make_gaussian(n: usize) -> VifRegression {
    let mut rng = Rng::seed_from(42);
    let x = random_points(&mut rng, n, 2);
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let kernel = ArdMatern::new(1.1, vec![0.4, 0.5], Smoothness::ThreeHalves);
    let config = VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 12,
        num_neighbors: 5,
        selection: NeighborSelection::CorrelationBruteForce,
        seed: 7,
        ..Default::default()
    };
    let mut model = VifRegression::new(x, y, config, GaussianParams { kernel, noise: 0.1 });
    model.assemble();
    model
}

/// Fault budgets count down deterministically and the guard disarms
/// everything on drop. Lives here (not in the `faults` unit tests)
/// because arming a live CG-stall budget or NaN panels would leak into
/// whatever lib test happens to run concurrently; in this binary every
/// test holds the install lock.
#[test]
fn budgets_count_down_and_guard_disarms() {
    let g = faults::install(FaultPlan { cg_stall: Some(2), ..Default::default() });
    assert!(faults::cg_stall_active());
    assert!(faults::cg_stall_active());
    assert!(!faults::cg_stall_active(), "budget of 2 exhausted");
    g.set(FaultPlan { nan_panel: true, ..Default::default() });
    let mut v = [1.0];
    faults::poison_panel(&mut v);
    assert!(v[0].is_nan());
    drop(g);
    assert!(!faults::enabled());
}

/// Acceptance headline: one poisoned request inside a coalesced batch is
/// isolated by bisection — only it gets an error reply, every healthy
/// request in the same batch still gets its exact prediction, and the
/// dispatcher keeps serving afterwards.
#[test]
fn poisoned_request_is_quarantined_by_bisection() {
    const SENTINEL: f64 = -4321.25;
    let g = faults::install(FaultPlan::default());
    let model = make_gaussian(120);
    let mut rng = Rng::seed_from(1234);
    let xq = random_points(&mut rng, 16, 2);
    let plan = model.build_predict_plan(&xq);
    let (mean_ref, _) = model.predict_with_plan(&xq, &plan);
    let snapshot: Arc<dyn ServeModel> = Arc::new(model.snapshot());
    g.set(FaultPlan { serve_poison: Some(SENTINEL), ..Default::default() });

    let engine = ServeEngine::start(
        snapshot,
        // A wide window so the concurrent requests coalesce and the
        // poison rides in a batch with healthy neighbors.
        ServeOptions { max_batch: 16, batch_window: Duration::from_millis(5) },
    );
    let poisoned_idx = 7usize;
    let results: Mutex<Vec<(usize, Result<Prediction, String>)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for i in 0..xq.rows() {
            let engine = &engine;
            let xq = &xq;
            let results = &results;
            scope.spawn(move || {
                let r = if i == poisoned_idx {
                    engine.predict(&[SENTINEL, 0.5])
                } else {
                    engine.predict(xq.row(i))
                };
                results.lock().unwrap().push((i, r));
            });
        }
    });
    let results = results.into_inner().unwrap();
    assert_eq!(results.len(), xq.rows());
    for (i, r) in results {
        if i == poisoned_idx {
            let e = r.expect_err("the poisoned request must get an error reply");
            assert!(e.contains("quarantined"), "poisoned request error: {e}");
        } else {
            let p = r.unwrap_or_else(|e| panic!("healthy request {i} failed: {e}"));
            assert!(
                rel_diff(p.mean, mean_ref[i]) < 1e-12,
                "healthy request {i} answered with a wrong value after bisection"
            );
        }
    }
    // The dispatcher survived: a follow-up request is served normally.
    let p = engine.predict(xq.row(0)).expect("post-quarantine request");
    assert!(p.mean.is_finite() && p.var.is_finite());
    let rep = engine.metrics().report();
    assert_eq!(rep.quarantined_requests, 1, "exactly the poisoned request is quarantined");
    assert!(rep.panics_caught >= 1);
    assert_eq!(rep.health, Health::Degraded);
    drop(g);
}

/// Acceptance: an injected CG stall during a Laplace fit is classified,
/// escalated (raised budget retry, then dense fallback if needed), and
/// the fit completes with a finite objective — no garbage reaches
/// L-BFGS, and the incident is visible in the solve-stats registry.
#[test]
fn cg_stall_during_fit_escalates_and_completes() {
    let g = faults::install(FaultPlan { seed: 9, cg_stall: Some(1), ..Default::default() });
    solve_stats().reset();
    let mut rng = Rng::seed_from(faults::active_seed());
    let n = 60;
    let x = random_points(&mut rng, n, 2);
    let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
    let config = VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 8,
        num_neighbors: 4,
        selection: NeighborSelection::CorrelationBruteForce,
        seed: 3,
        ..Default::default()
    };
    let kernel = ArdMatern::new(1.0, vec![0.4, 0.4], Smoothness::ThreeHalves);
    let mode = SolveMode::Iterative(IterConfig { seed: 3, ..Default::default() });
    let mut model =
        VifLaplaceModel::try_new(x, y, config, mode, kernel, Likelihood::BernoulliLogit).unwrap();
    let nll = model.fit(2);
    assert!(nll.is_finite(), "fit must complete with a finite objective, got {nll}");
    assert!(model.fit_trace.iter().all(|v| v.is_finite()), "fit trace: {:?}", model.fit_trace);
    let s = solve_stats().snapshot();
    assert!(s.failures() >= 1, "the stalled solve must be classified: {s:?}");
    assert!(s.retries >= 1, "the ladder must have escalated: {s:?}");
    assert!(
        s.retry_successes + s.dense_fallbacks >= 1,
        "escalation must have recovered the solve: {s:?}"
    );
    drop(g);
}

/// Injected Cholesky failures below a jitter floor force the escalation
/// ladder to climb exactly to that floor, record the consumed jitter,
/// and still produce a usable factor; disarming restores clean
/// zero-jitter factorization.
#[test]
fn injected_cholesky_failures_climb_the_jitter_ladder() {
    let g = faults::install(FaultPlan { chol_fail_below: Some(1e-8), ..Default::default() });
    solve_stats().reset();
    let a = Mat::from_fn(4, 4, |i, j| if i == j { 2.0 } else { 0.1 });
    let jf = CholeskyFactor::new_with_jitter_tracked(&a, 1e-12).expect("ladder must recover");
    assert!(
        jf.jitter >= 1e-8,
        "consumed jitter {} must clear the injected failure floor",
        jf.jitter
    );
    let id = jf.factor.solve(&[1.0, 0.0, 0.0, 0.0]);
    assert!(id.iter().all(|v| v.is_finite()));
    solve_stats().note_jitter(jf.jitter);
    assert!(solve_stats().snapshot().chol_jitter_escalations >= 1);
    drop(g);
    // Disarmed: the same matrix factors cleanly with zero jitter.
    let jf = CholeskyFactor::new_with_jitter_tracked(&a, 1e-12).unwrap();
    assert_eq!(jf.jitter, 0.0, "no injected failure → first clean attempt succeeds");
}

/// NaN-poisoned kernel panels must never reach a client as data: the
/// serving engine converts them into per-request error replies (or a
/// quarantine, if the NaN trips a panic deeper in the prediction
/// pipeline), flags itself Degraded — and recovers as soon as the fault
/// clears, on the same engine instance.
#[test]
fn nan_panels_yield_error_replies_then_recovery() {
    let g = faults::install(FaultPlan::default());
    let model = make_gaussian(80);
    let snapshot: Arc<dyn ServeModel> = Arc::new(model.snapshot());
    let engine = ServeEngine::start(snapshot, ServeOptions::default());
    // Healthy baseline on the same engine.
    let p = engine.predict(&[0.5, 0.5]).expect("pre-fault request");
    assert!(p.mean.is_finite() && p.var.is_finite());
    assert_eq!(engine.health(), Health::Healthy);

    g.set(FaultPlan { nan_panel: true, ..Default::default() });
    let err = engine.predict(&[0.5, 0.5]).expect_err("poisoned panels must not serve data");
    assert!(
        err.contains("non-finite") || err.contains("quarantined"),
        "unexpected error reply: {err}"
    );
    assert_eq!(engine.health(), Health::Degraded);
    let rep = engine.metrics().report();
    assert!(rep.nonfinite_replies + rep.quarantined_requests >= 1, "{rep:?}");

    // Clear the fault (guard still held): the same request now succeeds
    // on the same engine — containment, not a crash-and-restart.
    g.set(FaultPlan::default());
    let p2 = engine.predict(&[0.5, 0.5]).expect("post-recovery request");
    assert!(rel_diff(p2.mean, p.mean) < 1e-12 && rel_diff(p2.var, p.var) < 1e-12);
    drop(g);
}

/// An injected dispatcher-loop panic (outside the per-batch quarantine)
/// drops that batch's reply senders — the waiter gets a clean error, not
/// a hang — and the dispatcher survives: the next request is answered
/// normally, with the incident visible in metrics/health. Lives in this
/// binary (not tests/serve.rs) because the armed panic budget is global:
/// any concurrently running engine's dispatcher could consume it.
#[test]
fn request_after_dispatcher_panic_is_answered() {
    let g = faults::install(FaultPlan::default());
    let model = make_gaussian(80);
    let snapshot: Arc<dyn ServeModel> = Arc::new(model.snapshot());
    g.set(FaultPlan { dispatcher_panic: Some(1), ..Default::default() });
    let engine = ServeEngine::start(snapshot, ServeOptions::default());
    let err = engine.predict(&[0.4, 0.6]).expect_err("panicked batch must error, not hang");
    assert!(err.contains("dropped the request"), "unexpected error: {err}");
    let p = engine.predict(&[0.4, 0.6]).expect("post-panic request must be answered");
    assert!(p.mean.is_finite() && p.var.is_finite());
    assert_eq!(engine.health(), Health::Degraded);
    assert!(engine.metrics().report().panics_caught >= 1);
    drop(g);
}

/// An injected slow batch plus a short client deadline: the request is
/// shed with a clean deadline error instead of blocking, shedding alone
/// keeps the engine Healthy, and a relaxed deadline is met once the
/// slowdown clears.
#[test]
fn slow_batches_shed_expired_deadlines() {
    let g = faults::install(FaultPlan::default());
    let model = make_gaussian(80);
    let snapshot: Arc<dyn ServeModel> = Arc::new(model.snapshot());
    g.set(FaultPlan { serve_slow_us: Some(20_000), ..Default::default() });
    let engine = ServeEngine::start(
        snapshot,
        ServeOptions { max_batch: 4, batch_window: Duration::ZERO },
    );
    std::thread::scope(|scope| {
        let engine = &engine;
        // Occupies the dispatcher for ≥ 20ms per batch.
        scope.spawn(move || {
            let _ = engine.predict(&[0.2, 0.2]);
        });
        std::thread::sleep(Duration::from_millis(2));
        let err = engine
            .predict_deadline(&[0.3, 0.3], Duration::from_millis(1))
            .expect_err("a 1ms deadline cannot survive a 20ms injected slowdown");
        assert!(err.contains("deadline"), "unexpected error: {err}");
    });
    assert_eq!(engine.metrics().report().deadline_expired, 1);
    // Load shedding is the engine doing its job — not a degradation.
    assert_eq!(engine.health(), Health::Healthy);

    g.set(FaultPlan::default());
    let p = engine
        .predict_deadline(&[0.4, 0.4], Duration::from_secs(5))
        .expect("relaxed deadline met once the slowdown clears");
    assert!(p.mean.is_finite() && p.var.is_finite());
    drop(g);
}
