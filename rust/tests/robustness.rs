//! Failure-injection and edge-case robustness tests.

use vifgp::iterative::{pcg, IdentityPrecond, LinOp};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::linalg::{CholeskyFactor, Mat};
use vifgp::rng::Rng;
use vifgp::testing::random_points;
use vifgp::vecchia::neighbors::{self, NeighborSelection};
use vifgp::vif::laplace::{find_mode, SolveMode};
use vifgp::vif::{select_neighbors, VifStructure};

#[test]
fn cg_reports_non_convergence_gracefully() {
    struct Ill(Mat);
    impl LinOp for Ill {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn apply(&self, v: &[f64]) -> Vec<f64> {
            self.0.matvec(v)
        }
    }
    let n = 50;
    // condition number ~1e8
    let a = Mat::from_fn(n, n, |i, j| {
        if i == j {
            1e-4 + (i as f64 / n as f64).powi(4) * 1e4
        } else {
            0.0
        }
    });
    let b = vec![1.0; n];
    let res = pcg(&Ill(a), &IdentityPrecond(n), &b, 1e-12, 3, false);
    assert!(!res.converged);
    assert_eq!(res.iters, 3);
    assert!(res.x.iter().all(|v| v.is_finite()));
}

#[test]
fn cholesky_error_reports_pivot() {
    let a = Mat::from_vec(3, 3, vec![1.0, 0.0, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0, 1.0]);
    let err = CholeskyFactor::new(&a).unwrap_err();
    assert_eq!(err.pivot, 1);
    assert!(err.to_string().contains("positive definite"));
}

#[test]
fn covertree_tolerates_duplicate_points() {
    // Several exactly coincident points → zero correlation distances.
    let n = 60;
    let mut data = Vec::new();
    for i in 0..n {
        let base = (i % 10) as f64 / 10.0;
        data.push(base);
        data.push(base * 0.5);
    }
    let x = Mat::from_vec(n, 2, data);
    let kernel = ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves);
    let nb = select_neighbors(
        &x,
        &kernel,
        None,
        5,
        NeighborSelection::CorrelationCoverTree,
    );
    assert_eq!(nb.len(), n);
    for (i, set) in nb.iter().enumerate() {
        assert!(set.len() <= 5.max(i));
        assert!(set.iter().all(|&j| (j as usize) < i || i == 0));
    }
}

#[test]
fn mode_finding_survives_degenerate_labels() {
    // All-positive labels: the mode drifts upward but must remain finite
    // and the Newton loop must terminate.
    let mut rng = Rng::seed_from(2);
    let n = 60;
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves);
    let nb = select_neighbors(&x, &kernel, None, 4, NeighborSelection::EuclideanTransformed);
    let s = VifStructure::assemble(&x, &kernel, None, nb, 0.0, 1e-10, 0);
    let y = vec![1.0; n];
    let state = find_mode(
        &s,
        &x,
        &kernel,
        &Likelihood::BernoulliLogit,
        &y,
        &SolveMode::Cholesky,
        None,
    );
    assert!(state.b.iter().all(|b| b.is_finite()));
    assert!(state.b.iter().all(|&b| b > 0.0)); // pushed toward +
    assert!(state.newton_iters <= 100);
}

#[test]
fn empty_and_tiny_neighbor_sets_work() {
    let mut rng = Rng::seed_from(3);
    let x = random_points(&mut rng, 5, 2);
    let kernel = ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::Gaussian);
    // n smaller than m_v
    let nb = neighbors::prefix_neighbors(5, 30);
    let s = VifStructure::assemble(&x, &kernel, None, nb, 0.1, 1e-10, 0);
    let v = vec![1.0; 5];
    assert!(s.apply_sigma_dagger_inv(&v).iter().all(|x| x.is_finite()));
    // single point
    let x1 = random_points(&mut rng, 1, 2);
    let s1 = VifStructure::assemble(&x1, &kernel, None, vec![vec![]], 0.1, 1e-10, 0);
    assert!((s1.logdet() - (1.1f64).ln()).abs() < 1e-10);
}

#[test]
fn huge_and_tiny_length_scales_stay_finite() {
    let mut rng = Rng::seed_from(5);
    let x = random_points(&mut rng, 40, 2);
    for ls in [1e-4, 1e4] {
        let kernel = ArdMatern::new(1.0, vec![ls; 2], Smoothness::ThreeHalves);
        let nb = select_neighbors(&x, &kernel, None, 4, NeighborSelection::EuclideanTransformed);
        let s = VifStructure::assemble(&x, &kernel, None, nb, 0.01, 1e-10, 1);
        let y: Vec<f64> = (0..40).map(|i| (i as f64).sin()).collect();
        let v = vifgp::vif::gaussian::nll(&s, &y);
        assert!(v.is_finite(), "ls={ls} nll={v}");
        let (_, g) = vifgp::vif::gaussian::nll_and_grad(&s, &x, &kernel, &y);
        assert!(g.iter().all(|x| x.is_finite()), "ls={ls} grad={g:?}");
    }
}

#[test]
fn csv_loader_rejects_garbage() {
    let dir = std::env::temp_dir();
    let p = dir.join("vifgp_bad.csv");
    std::fs::write(&p, "1,2,3\n4,not_a_number,6\n").unwrap();
    assert!(vifgp::data::load_csv(&p).is_err());
    std::fs::write(&p, "1,2,3\n1,2\n").unwrap();
    assert!(vifgp::data::load_csv(&p).is_err()); // ragged
    std::fs::write(&p, "").unwrap();
    assert!(vifgp::data::load_csv(&p).is_err());
    // header tolerated
    std::fs::write(&p, "x1,x2,y\n0.1,0.2,1.0\n0.3,0.4,2.0\n").unwrap();
    let (x, y) = vifgp::data::load_csv(&p).unwrap();
    assert_eq!((x.rows(), x.cols()), (2, 2));
    assert_eq!(y, vec![1.0, 2.0]);
    let _ = std::fs::remove_file(&p);
}
