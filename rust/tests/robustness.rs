//! Failure-injection and edge-case robustness tests.

use vifgp::iterative::{pcg, IdentityPrecond, LinOp};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::linalg::{CholeskyFactor, Mat};
use vifgp::rng::Rng;
use vifgp::testing::random_points;
use vifgp::vecchia::neighbors::{self, NeighborSelection};
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::laplace::{find_mode, SolveMode};
use vifgp::vif::{select_neighbors, VifConfig, VifStructure};

#[test]
fn cg_reports_non_convergence_gracefully() {
    struct Ill(Mat);
    impl LinOp for Ill {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn apply(&self, v: &[f64]) -> Vec<f64> {
            self.0.matvec(v)
        }
    }
    let n = 50;
    // condition number ~1e8
    let a = Mat::from_fn(n, n, |i, j| {
        if i == j {
            1e-4 + (i as f64 / n as f64).powi(4) * 1e4
        } else {
            0.0
        }
    });
    let b = vec![1.0; n];
    let res = pcg(&Ill(a), &IdentityPrecond(n), &b, 1e-12, 3, false);
    assert!(!res.converged);
    assert_eq!(res.iters, 3);
    assert!(res.x.iter().all(|v| v.is_finite()));
}

#[test]
fn cholesky_error_reports_pivot() {
    let a = Mat::from_vec(3, 3, vec![1.0, 0.0, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0, 1.0]);
    let err = CholeskyFactor::new(&a).unwrap_err();
    assert_eq!(err.pivot, 1);
    assert!(err.to_string().contains("positive definite"));
}

#[test]
fn covertree_tolerates_duplicate_points() {
    // Several exactly coincident points → zero correlation distances.
    let n = 60;
    let mut data = Vec::new();
    for i in 0..n {
        let base = (i % 10) as f64 / 10.0;
        data.push(base);
        data.push(base * 0.5);
    }
    let x = Mat::from_vec(n, 2, data);
    let kernel = ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves);
    let nb = select_neighbors(
        &x,
        &kernel,
        None,
        5,
        NeighborSelection::CorrelationCoverTree,
    );
    assert_eq!(nb.len(), n);
    for (i, set) in nb.iter().enumerate() {
        assert!(set.len() <= 5.max(i));
        assert!(set.iter().all(|&j| (j as usize) < i || i == 0));
    }
}

#[test]
fn mode_finding_survives_degenerate_labels() {
    // All-positive labels: the mode drifts upward but must remain finite
    // and the Newton loop must terminate.
    let mut rng = Rng::seed_from(2);
    let n = 60;
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves);
    let nb = select_neighbors(&x, &kernel, None, 4, NeighborSelection::EuclideanTransformed);
    let s = VifStructure::assemble(&x, &kernel, None, nb, 0.0, 1e-10, 0);
    let y = vec![1.0; n];
    let state = find_mode(
        &s,
        &x,
        &kernel,
        &Likelihood::BernoulliLogit,
        &y,
        &SolveMode::Cholesky,
        None,
    );
    assert!(state.b.iter().all(|b| b.is_finite()));
    assert!(state.b.iter().all(|&b| b > 0.0)); // pushed toward +
    assert!(state.newton_iters <= 100);
}

#[test]
fn empty_and_tiny_neighbor_sets_work() {
    let mut rng = Rng::seed_from(3);
    let x = random_points(&mut rng, 5, 2);
    let kernel = ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::Gaussian);
    // n smaller than m_v
    let nb = neighbors::prefix_neighbors(5, 30);
    let s = VifStructure::assemble(&x, &kernel, None, nb, 0.1, 1e-10, 0);
    let v = vec![1.0; 5];
    assert!(s.apply_sigma_dagger_inv(&v).iter().all(|x| x.is_finite()));
    // single point
    let x1 = random_points(&mut rng, 1, 2);
    let s1 = VifStructure::assemble(&x1, &kernel, None, vec![vec![]], 0.1, 1e-10, 0);
    assert!((s1.logdet() - (1.1f64).ln()).abs() < 1e-10);
}

#[test]
fn huge_and_tiny_length_scales_stay_finite() {
    let mut rng = Rng::seed_from(5);
    let x = random_points(&mut rng, 40, 2);
    for ls in [1e-4, 1e4] {
        let kernel = ArdMatern::new(1.0, vec![ls; 2], Smoothness::ThreeHalves);
        let nb = select_neighbors(&x, &kernel, None, 4, NeighborSelection::EuclideanTransformed);
        let s = VifStructure::assemble(&x, &kernel, None, nb, 0.01, 1e-10, 1);
        let y: Vec<f64> = (0..40).map(|i| (i as f64).sin()).collect();
        let v = vifgp::vif::gaussian::nll(&s, &y);
        assert!(v.is_finite(), "ls={ls} nll={v}");
        let (_, g) = vifgp::vif::gaussian::nll_and_grad(&s, &x, &kernel, &y);
        assert!(g.iter().all(|x| x.is_finite()), "ls={ls} grad={g:?}");
    }
}

/// Small assembled Gaussian model for the degenerate-append cases.
fn append_fixture() -> VifRegression {
    let mut rng = Rng::seed_from(61);
    let n = 80;
    let x = random_points(&mut rng, n, 2);
    let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();
    let config = VifConfig {
        num_inducing: 10,
        num_neighbors: 4,
        selection: NeighborSelection::CorrelationBruteForce,
        lloyd_iters: 2,
        ..Default::default()
    };
    let init = GaussianParams {
        kernel: ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves),
        noise: 0.05,
    };
    let mut model = VifRegression::new(x, y, config, init);
    model.assemble();
    model
}

#[test]
fn empty_append_is_bitwise_noop() {
    let mut model = append_fixture();
    let s0 = model.structure.as_ref().unwrap();
    let a0 = s0.resid.a.clone();
    let d0 = s0.resid.d.clone();
    let gen0 = s0.generation;
    let n0 = model.x.rows();

    model.append_points(&Mat::zeros(0, 2), &[]).unwrap();

    let s1 = model.structure.as_ref().unwrap();
    assert_eq!(model.x.rows(), n0);
    assert_eq!(s1.generation, gen0, "empty append must not bump the generation");
    assert_eq!(s1.resid.a, a0, "coefficient rows must be bitwise untouched");
    assert_eq!(s1.resid.d, d0, "conditional variances must be bitwise untouched");
}

#[test]
fn duplicate_point_append_stays_finite() {
    // An exact copy of an existing training point: zero residual
    // distance to its duplicate, so the conditional variance collapses
    // to the nugget — the factorization must stay finite and positive.
    let mut model = append_fixture();
    let dup = Mat::from_fn(1, 2, |_, j| model.x.get(17, j));
    let ydup = model.y[17];
    model.append_points(&dup, &[ydup]).unwrap();

    let s = model.structure.as_ref().unwrap();
    assert!(s.resid.d.iter().all(|d| d.is_finite() && *d > 0.0));
    let nll = vifgp::vif::gaussian::nll(s, &model.y);
    assert!(nll.is_finite(), "nll after duplicate append: {nll}");
    let xp = Mat::from_fn(3, 2, |i, j| 0.1 + 0.2 * (i + j) as f64 / 3.0);
    let (mean, var) = model.predict(&xp);
    assert!(mean.iter().chain(&var).all(|v| v.is_finite()));
}

#[test]
fn non_finite_and_mismatched_appends_rejected_cleanly() {
    let mut model = append_fixture();
    let s0_d = model.structure.as_ref().unwrap().resid.d.clone();
    let gen0 = model.structure.as_ref().unwrap().generation;
    let n0 = model.x.rows();

    let err = model
        .append_points(&Mat::from_vec(1, 2, vec![f64::NAN, 0.5]), &[1.0])
        .unwrap_err();
    assert!(err.contains("non-finite"), "{err}");
    let err = model
        .append_points(&Mat::from_vec(1, 2, vec![0.4, 0.5]), &[f64::INFINITY])
        .unwrap_err();
    assert!(err.contains("non-finite"), "{err}");
    let err = model
        .append_points(&Mat::from_vec(1, 2, vec![0.4, 0.5]), &[1.0, 2.0])
        .unwrap_err();
    assert!(err.contains("responses"), "{err}");
    let err = model
        .append_points(&Mat::from_vec(1, 3, vec![0.4, 0.5, 0.6]), &[1.0])
        .unwrap_err();
    assert!(err.contains("dimension"), "{err}");

    // Every rejection left the model untouched...
    let s = model.structure.as_ref().unwrap();
    assert_eq!(model.x.rows(), n0);
    assert_eq!(s.generation, gen0);
    assert_eq!(s.resid.d, s0_d);
    // ...and it still ingests a valid batch afterwards.
    model
        .append_points(&Mat::from_vec(1, 2, vec![0.4, 0.5]), &[1.0])
        .unwrap();
    assert_eq!(model.x.rows(), n0 + 1);
}

#[test]
fn constructors_reject_invalid_training_data() {
    let mut rng = Rng::seed_from(11);
    let x = random_points(&mut rng, 20, 2);
    let y: Vec<f64> = (0..20).map(|i| (i as f64 * 0.3).sin()).collect();
    let config = VifConfig {
        num_inducing: 5,
        num_neighbors: 3,
        selection: NeighborSelection::CorrelationBruteForce,
        ..Default::default()
    };
    let params = GaussianParams {
        kernel: ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves),
        noise: 0.1,
    };

    // Length mismatch between X rows and y.
    let err = VifRegression::try_new(x.clone(), y[..19].to_vec(), config.clone(), params.clone())
        .unwrap_err();
    assert!(err.to_string().contains("must match X rows"), "{err}");

    // Non-finite X entry.
    let mut x_bad = x.clone();
    x_bad.set(7, 1, f64::NAN);
    let err =
        VifRegression::try_new(x_bad, y.clone(), config.clone(), params.clone()).unwrap_err();
    assert!(err.to_string().contains("non-finite"), "{err}");

    // Non-finite response.
    let mut y_bad = y.clone();
    y_bad[3] = f64::INFINITY;
    let err = VifRegression::try_new(x.clone(), y_bad, config.clone(), params.clone()).unwrap_err();
    assert!(err.to_string().contains("non-finite"), "{err}");

    // Laplace constructor shares the validation.
    let labels: Vec<f64> = (0..20).map(|i| (i % 2) as f64).collect();
    let mut x_bad = x.clone();
    x_bad.set(0, 0, f64::NEG_INFINITY);
    let err = vifgp::vif::laplace::VifLaplaceModel::try_new(
        x_bad,
        labels.clone(),
        config.clone(),
        SolveMode::Cholesky,
        ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves),
        Likelihood::BernoulliLogit,
    )
    .unwrap_err();
    assert!(err.to_string().contains("non-finite"), "{err}");

    // Clean data constructs fine through the same path.
    assert!(VifRegression::try_new(x, y, config, params).is_ok());
}

#[test]
fn csv_loader_rejects_garbage() {
    let dir = std::env::temp_dir();
    let p = dir.join("vifgp_bad.csv");
    std::fs::write(&p, "1,2,3\n4,not_a_number,6\n").unwrap();
    assert!(vifgp::data::load_csv(&p).is_err());
    std::fs::write(&p, "1,2,3\n1,2\n").unwrap();
    assert!(vifgp::data::load_csv(&p).is_err()); // ragged
    std::fs::write(&p, "").unwrap();
    assert!(vifgp::data::load_csv(&p).is_err());
    // header tolerated
    std::fs::write(&p, "x1,x2,y\n0.1,0.2,1.0\n0.3,0.4,2.0\n").unwrap();
    let (x, y) = vifgp::data::load_csv(&p).unwrap();
    assert_eq!((x.rows(), x.cols()), (2, 2));
    assert_eq!(y, vec![1.0, 2.0]);
    let _ = std::fs::remove_file(&p);
}
