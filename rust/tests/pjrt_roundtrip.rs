//! Integration test: the python-AOT → rust-PJRT path produces the same
//! covariance panels as the native Rust kernels (requires
//! `make artifacts` to have run; skips otherwise).

use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::linalg::Mat;
use vifgp::rng::Rng;
use vifgp::runtime::PjrtCovEngine;

fn artifacts_dir() -> std::path::PathBuf {
    let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}

#[test]
fn pjrt_cross_cov_matches_native() {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = PjrtCovEngine::load(&dir).expect("engine load");
    let mut rng = Rng::seed_from(42);
    for (smoothness, d) in [
        (Smoothness::Half, 2),
        (Smoothness::ThreeHalves, 3),
        (Smoothness::FiveHalves, 5),
        (Smoothness::Gaussian, 8),
    ] {
        let kernel = ArdMatern::new(
            1.4,
            (0..d).map(|k| 0.25 + 0.1 * k as f64).collect(),
            smoothness,
        );
        // sizes that exercise padding and multi-panel tiling
        for (n, m) in [(37usize, 20usize), (600, 300)] {
            let x = Mat::from_fn(n, d, |_, _| rng.uniform());
            let z = Mat::from_fn(m, d, |_, _| rng.uniform());
            let got = engine.cross_cov(&x, &z, &kernel).expect("panel");
            let want = kernel.cross_cov(&x, &z);
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-10, "{smoothness:?} n={n} m={m}: diff {diff}");
        }
    }
    let stats = *engine.stats.lock().unwrap();
    assert!(stats.pjrt_panels > 0);
}

#[test]
fn engine_rejects_unsupported_kernels() {
    let dir = artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        return;
    }
    let engine = PjrtCovEngine::load(&dir).expect("engine load");
    let too_wide = ArdMatern::new(1.0, vec![0.3; 20], Smoothness::Gaussian);
    assert!(!engine.supports(&too_wide));
    let general = ArdMatern::new(1.0, vec![0.3; 2], Smoothness::General(0.8));
    assert!(!engine.supports(&general));
}
