//! Empirical validation of the convergence theory (§5, Theorems 5.1–5.2):
//!
//! * both preconditioners accelerate CG over no preconditioning;
//! * with the FITC preconditioner, more inducing points `m` → fewer CG
//!   iterations (λ_{m+1} shrinks), and fewer Vecchia neighbors `m_v` →
//!   no slower convergence;
//! * the FITC preconditioner is less sensitive to the marginal variance
//!   σ₁² (≈ λ₁ scaling) than VIFDU — Theorem 5.2's λ₁-independence.

use vifgp::data;
use vifgp::iterative::{pcg, FitcPrecond, IdentityPrecond, VifduPrecond};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::rng::Rng;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{OpWPlusPrec, OpWinvPlusCov};
use vifgp::vif::{select_inducing, select_neighbors, VifStructure};

struct Setup {
    x: vifgp::linalg::Mat,
    kernel: ArdMatern,
    s: VifStructure,
    w: Vec<f64>,
    rhs: Vec<f64>,
}

fn setup(n: usize, m: usize, m_v: usize, variance: f64, seed: u64) -> Setup {
    let mut rng = Rng::seed_from(seed);
    let x = data::uniform_inputs(&mut rng, n, 2);
    let kernel = ArdMatern::new(variance, vec![0.2, 0.3], Smoothness::ThreeHalves);
    let z = select_inducing(&x, &kernel, m, 3, &mut rng, None);
    let lr = z
        .clone()
        .map(|z| vifgp::vif::LowRank::build(&x, &kernel, z, 1e-8));
    let nb = select_neighbors(
        &x,
        &kernel,
        lr.as_ref(),
        m_v,
        NeighborSelection::CorrelationCoverTree,
    );
    let s = VifStructure::assemble(&x, &kernel, z, nb, 0.0, 1e-8, 0);
    let latent = data::simulate_latent_gp(&mut rng, &x, &kernel);
    let lik = Likelihood::BernoulliLogit;
    let y = data::simulate_response(&mut rng, &latent, &lik);
    let w: Vec<f64> = y
        .iter()
        .zip(&latent)
        .map(|(yi, bi)| lik.w(*yi, *bi))
        .collect();
    let rhs: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    Setup { x, kernel, s, w, rhs }
}

fn iters_vifdu(su: &Setup) -> usize {
    let op = OpWPlusPrec { s: &su.s, w: &su.w };
    let pre = VifduPrecond::new(&su.s, &su.w);
    pcg(&op, &pre, &su.rhs, 1e-8, 2000, false).iters
}

fn iters_fitc(su: &Setup, k: usize) -> usize {
    let op = OpWinvPlusCov { s: &su.s, w: &su.w };
    let pre = FitcPrecond::new(&su.x, &su.kernel, k, &su.w, 99);
    pcg(&op, &pre, &su.rhs, 1e-8, 2000, false).iters
}

fn iters_plain(su: &Setup) -> usize {
    let op = OpWPlusPrec { s: &su.s, w: &su.w };
    pcg(&op, &IdentityPrecond(su.rhs.len()), &su.rhs, 1e-8, 2000, false).iters
}

#[test]
fn preconditioning_accelerates_cg() {
    let su = setup(600, 50, 10, 4.0, 1);
    let plain = iters_plain(&su);
    let vifdu = iters_vifdu(&su);
    let fitc = iters_fitc(&su, 50);
    assert!(
        vifdu < plain,
        "VIFDU {vifdu} should beat plain {plain}"
    );
    assert!(fitc < plain, "FITC {fitc} should beat plain {plain}");
}

#[test]
fn fitc_more_inducing_points_fewer_iterations() {
    // Theorem 5.2: λ_{m+1} decreases with k → faster convergence.
    let su = setup(600, 50, 10, 1.0, 2);
    let small = iters_fitc(&su, 10);
    let large = iters_fitc(&su, 100);
    assert!(
        large <= small,
        "k=100 took {large} vs k=10 {small} iterations"
    );
}

#[test]
fn fewer_neighbors_no_slower_convergence() {
    // Both theorems: smaller m_v → smaller bound.
    let su_big = setup(500, 40, 20, 1.0, 3);
    let su_small = setup(500, 40, 3, 1.0, 3);
    let big = iters_fitc(&su_big, 40);
    let small = iters_fitc(&su_small, 40);
    assert!(
        small <= big + 2,
        "m_v=3 took {small} vs m_v=20 {big} iterations"
    );
}

#[test]
fn fitc_less_sensitive_to_marginal_variance_than_vifdu() {
    // Theorem 5.1's bound grows with λ₁ (∝ σ₁²); Theorem 5.2's does not.
    let lo = setup(500, 40, 8, 1.0, 4);
    let hi = setup(500, 40, 8, 25.0, 4);
    let vifdu_growth = iters_vifdu(&hi) as f64 / iters_vifdu(&lo).max(1) as f64;
    let fitc_growth = iters_fitc(&hi, 40) as f64 / iters_fitc(&lo, 40).max(1) as f64;
    assert!(
        fitc_growth <= vifdu_growth + 0.5,
        "FITC growth {fitc_growth:.2} vs VIFDU growth {vifdu_growth:.2}"
    );
}
