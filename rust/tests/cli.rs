//! End-to-end CLI contract tests against the built `vifgp` binary:
//! malformed flags and env knobs must fail loudly (exit 2 / loud panic)
//! naming the offending flag and value — never a silent fallback — and
//! the happy paths (simulate → train → serve) must round-trip.

use std::process::{Command, Output};

fn vifgp() -> Command {
    Command::new(env!("CARGO_BIN_EXE_vifgp"))
}

fn run(args: &[&str]) -> Output {
    vifgp().args(args).output().expect("spawn vifgp")
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn info_succeeds() {
    let out = run(&["info"]);
    assert!(out.status.success(), "info failed: {}", stderr(&out));
}

#[test]
fn unknown_command_exits_2() {
    let out = run(&["frobnicate"]);
    assert_eq!(out.status.code(), Some(2));
    assert!(stderr(&out).contains("unknown command"));
}

/// The satellite bugfix: numeric flags that don't parse must exit 2
/// naming the flag, the value, and the expected type — previously they
/// silently fell back to the default.
#[test]
fn malformed_numeric_flags_exit_2() {
    let cases: &[(&[&str], &str)] = &[
        (&["train", "--data", "x.csv", "--m", "abc"], "--m"),
        (&["train", "--data", "x.csv", "--iters", "1e3"], "--iters"),
        (&["train", "--data", "x.csv", "--test-frac", "20%"], "--test-frac"),
        (&["train", "--data", "x.csv", "--mv", "-3"], "--mv"),
        (&["train", "--data", "x.csv", "--seed", "0x10"], "--seed"),
        (&["simulate", "--n", "12.5", "--out", "x.csv"], "--n"),
        (&["serve", "--data", "x.csv", "--requests", "many"], "--requests"),
        (&["serve", "--data", "x.csv", "--concurrency", "8.0"], "--concurrency"),
    ];
    for (args, flag) in cases {
        let out = run(args);
        assert_eq!(
            out.status.code(),
            Some(2),
            "{args:?} should exit 2, stderr: {}",
            stderr(&out)
        );
        let err = stderr(&out);
        assert!(err.contains(flag), "{args:?} stderr must name {flag}: {err}");
        assert!(err.contains(args[args.len() - 1]), "{args:?} stderr must echo the value: {err}");
    }
}

/// `--test-frac` must be finite and in [0, 1): a full-test split (or
/// worse) is a config error, not something to clamp quietly.
#[test]
fn test_frac_out_of_range_exits_2() {
    for bad in ["1.0", "-0.1", "nan", "inf"] {
        let out = run(&["train", "--data", "x.csv", "--test-frac", bad]);
        assert_eq!(out.status.code(), Some(2), "--test-frac {bad} should exit 2");
        assert!(stderr(&out).contains("--test-frac"), "stderr: {}", stderr(&out));
    }
}

/// The satellite bugfix: likelihood/smoothness typos used to be
/// swallowed (warn-then-Gaussian, `.unwrap_or(ThreeHalves)`). Now they
/// exit 2 listing the valid names.
#[test]
fn unknown_likelihood_and_smoothness_exit_2() {
    let out = run(&["simulate", "--n", "10", "--likelihood", "gausian"]);
    assert_eq!(out.status.code(), Some(2));
    let err = stderr(&out);
    assert!(err.contains("gausian") && err.contains("gaussian"), "stderr: {err}");

    let out = run(&["simulate", "--n", "10", "--smoothness", "matern3/2"]);
    assert_eq!(out.status.code(), Some(2));
    let err = stderr(&out);
    assert!(err.contains("matern3/2") && err.contains("1.5"), "stderr: {err}");
}

#[test]
fn malformed_threads_flag_exits_2() {
    for bad in ["0", "abc"] {
        let out = run(&["info", "--threads", bad]);
        assert_eq!(out.status.code(), Some(2), "--threads {bad} should exit 2");
        assert!(stderr(&out).contains("--threads"));
    }
}

/// The env-knob satellite: a malformed `VIFGP_THREADS` must panic loudly
/// (naming the variable and value) instead of being ignored; `0` is no
/// longer clamped to 1.
#[test]
fn malformed_threads_env_panics_loudly() {
    for bad in ["abc", "0", "-2", "1.5"] {
        let out = vifgp().args(["info"]).env("VIFGP_THREADS", bad).output().expect("spawn");
        assert!(!out.status.success(), "VIFGP_THREADS={bad} must fail");
        let err = stderr(&out);
        assert!(
            err.contains("VIFGP_THREADS") && err.contains(bad),
            "VIFGP_THREADS={bad} stderr must name the knob and value: {err}"
        );
    }
}

#[test]
fn malformed_serve_env_knobs_panic_loudly() {
    for (knob, bad) in
        [("VIFGP_SERVE_MAX_BATCH", "0"), ("VIFGP_SERVE_MAX_BATCH", "lots"), ("VIFGP_SERVE_BATCH_WINDOW_US", "-1")]
    {
        let out = vifgp()
            .args(["serve", "--data", "/nonexistent.csv"])
            .env(knob, bad)
            .output()
            .expect("spawn");
        assert!(!out.status.success(), "{knob}={bad} must fail");
        let err = stderr(&out);
        assert!(err.contains(knob), "{knob}={bad} stderr must name the knob: {err}");
    }
}

/// `VIFGP_SIMD` is a strict two-state switch: `0` and `1` are accepted,
/// anything else must panic at startup naming the knob and the value
/// rather than silently picking a backend.
#[test]
fn malformed_simd_env_panics_loudly() {
    for bad in ["2", "yes", "true", "on", ""] {
        let out = vifgp().args(["info"]).env("VIFGP_SIMD", bad).output().expect("spawn");
        assert!(!out.status.success(), "VIFGP_SIMD={bad:?} must fail");
        let err = stderr(&out);
        assert!(
            err.contains("VIFGP_SIMD") && err.contains(bad),
            "VIFGP_SIMD={bad:?} stderr must name the knob and value: {err}"
        );
    }
    for good in ["0", "1"] {
        let out = vifgp().args(["info"]).env("VIFGP_SIMD", good).output().expect("spawn");
        assert!(out.status.success(), "VIFGP_SIMD={good} must succeed: {}", stderr(&out));
    }
}

/// `VIFGP_WARM_START` is a strict two-state switch like `VIFGP_SIMD`:
/// `0` (cold oracle) and `1` (warm-started fitting) are accepted,
/// anything else must panic at startup naming the knob and the value
/// rather than silently picking a solver path.
#[test]
fn malformed_warm_start_env_panics_loudly() {
    for bad in ["2", "yes", "true", "on", ""] {
        let out = vifgp().args(["info"]).env("VIFGP_WARM_START", bad).output().expect("spawn");
        assert!(!out.status.success(), "VIFGP_WARM_START={bad:?} must fail");
        let err = stderr(&out);
        assert!(
            err.contains("VIFGP_WARM_START") && err.contains(bad),
            "VIFGP_WARM_START={bad:?} stderr must name the knob and value: {err}"
        );
    }
    for good in ["0", "1"] {
        let out = vifgp().args(["info"]).env("VIFGP_WARM_START", good).output().expect("spawn");
        assert!(out.status.success(), "VIFGP_WARM_START={good} must succeed: {}", stderr(&out));
    }
}

/// The `--warm-start` flag mirrors the env knob: strict `0`/`1`, exit 2
/// naming flag and value otherwise.
#[test]
fn malformed_warm_start_flag_exits_2() {
    for bad in ["2", "warm", ""] {
        let out = run(&["info", "--warm-start", bad]);
        assert_eq!(out.status.code(), Some(2), "--warm-start {bad:?} should exit 2");
        let err = stderr(&out);
        assert!(
            err.contains("--warm-start") && err.contains(bad),
            "--warm-start {bad:?} stderr must name the flag and value: {err}"
        );
    }
    for good in ["0", "1"] {
        let out = run(&["info", "--warm-start", good]);
        assert!(out.status.success(), "--warm-start {good} must succeed: {}", stderr(&out));
    }
}

/// Happy path: simulate a small dataset, train on it, then serve it with
/// a writer publishing generations under traffic. Exercises the full
/// flag surface end to end.
#[test]
fn simulate_train_serve_round_trip() {
    let dir = std::env::temp_dir().join(format!("vifgp-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("tmp dir");
    let csv = dir.join("toy.csv");
    let csv_s = csv.to_str().unwrap();

    let out = run(&["simulate", "--n", "80", "--d", "2", "--seed", "3", "--out", csv_s]);
    assert!(out.status.success(), "simulate failed: {}", stderr(&out));

    let out = run(&[
        "train", "--data", csv_s, "--m", "10", "--mv", "4", "--iters", "2", "--test-frac", "0.25",
    ]);
    assert!(out.status.success(), "train failed: {}", stderr(&out));

    let metrics = dir.join("serve_metrics.json");
    let out = vifgp()
        .args([
            "serve",
            "--data",
            csv_s,
            "--m",
            "10",
            "--mv",
            "4",
            "--iters",
            "1",
            "--requests",
            "64",
            "--concurrency",
            "4",
            "--append-every",
            "24",
            "--append-batch",
            "4",
            "--max-batch",
            "8",
            "--batch-window-us",
            "100",
        ])
        .env("VIFGP_SERVE_METRICS_JSON", metrics.to_str().unwrap())
        .output()
        .expect("spawn");
    assert!(out.status.success(), "serve failed: {}", stderr(&out));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(stdout.contains("served 64 requests"), "stdout: {stdout}");
    let json = std::fs::read_to_string(&metrics).expect("metrics json written");
    assert!(json.contains("\"requests\": 64"), "metrics: {json}");

    let _ = std::fs::remove_dir_all(&dir);
}
