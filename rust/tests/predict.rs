//! Pinning tests for the shared panelized prediction pipeline
//! (`vif::predict`): the batched path must match the scalar per-point
//! reference (`testing::scalar_predict_reference`) to ≤1e-12 for the
//! Gaussian model (m = 0, m > 0, m_v = 0) and the Laplace model (exact
//! and both stochastic variance estimators), a frozen `PredictPlan`
//! must be reusable (two calls at fixed θ give identical results), and
//! the cover-tree prediction neighbor search must agree with brute
//! force up to ties.

use vifgp::iterative::map_columns;
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::linalg::{dot, Mat};
use vifgp::rng::Rng;
use vifgp::testing::{random_points, scalar_predict_reference, ScalarPrediction};
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{self, LaplaceState, PredVarMethod, SolveMode, WSolver};
use vifgp::vif::predict::{posterior_mean, project_q_batch, PredictBlocks, PredictPlan};
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifStructure};

const TOL: f64 = 1e-12;

fn rel_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / (1.0 + y.abs()))
        .fold(0.0, f64::max)
}

/// Gaussian-scale setup: structure with noise nugget and the extra
/// noise-parameter slot.
fn gaussian_setup(
    n: usize,
    m: usize,
    m_v: usize,
) -> (Mat, ArdMatern, VifStructure, Vec<f64>, Mat) {
    let mut rng = Rng::seed_from(91);
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.2, vec![0.3, 0.45], Smoothness::ThreeHalves);
    let z = select_inducing(&x, &kernel, m, 2, &mut rng, None);
    let nb = if m_v == 0 {
        vec![vec![]; n]
    } else {
        let lr_tmp = z
            .clone()
            .map(|z| LowRank::build(&x, &kernel, z, 1e-10));
        select_neighbors(
            &x,
            &kernel,
            lr_tmp.as_ref(),
            m_v,
            NeighborSelection::CorrelationBruteForce,
        )
    };
    let s = VifStructure::assemble(&x, &kernel, z, nb, 0.05, 1e-10, 1);
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let xp = random_points(&mut rng, 23, 2);
    (x, kernel, s, y, xp)
}

fn check_gaussian_matches_scalar(m: usize, m_v: usize, selection: NeighborSelection) {
    let (x, kernel, s, y, xp) = gaussian_setup(90, m, m_v);
    let plan = PredictPlan::build(&s, &x, &kernel, &xp, m_v, selection);
    let (mean_b, var_b) = gaussian::predict_with_plan(&s, &kernel, &y, &xp, &plan);
    let want = scalar_predict_reference(&s, &x, &kernel, &y, &xp, &plan.neighbors, 1e-10);
    assert!(
        rel_diff(&mean_b, &want.mean) <= TOL,
        "mean diverged: {:.3e}",
        rel_diff(&mean_b, &want.mean)
    );
    assert!(
        rel_diff(&var_b, &want.var_det) <= TOL,
        "var diverged: {:.3e}",
        rel_diff(&var_b, &want.var_det)
    );
    // The one-shot entry point builds the same plan internally.
    let (mean_1, var_1) = gaussian::predict(&s, &x, &kernel, &y, &xp, m_v, selection);
    assert_eq!(mean_1, mean_b, "one-shot path diverged from plan path");
    assert_eq!(var_1, var_b, "one-shot path diverged from plan path");
}

#[test]
fn gaussian_pipeline_matches_scalar_full_model() {
    check_gaussian_matches_scalar(9, 6, NeighborSelection::CorrelationBruteForce);
}

#[test]
fn gaussian_pipeline_matches_scalar_pure_vecchia() {
    // m = 0: no low-rank part anywhere in the pipeline.
    check_gaussian_matches_scalar(0, 6, NeighborSelection::CorrelationBruteForce);
}

#[test]
fn gaussian_pipeline_matches_scalar_fitc() {
    // m_v = 0: empty conditioning sets, Woodbury terms only.
    check_gaussian_matches_scalar(9, 0, NeighborSelection::CorrelationBruteForce);
}

#[test]
fn gaussian_pipeline_matches_scalar_euclidean_selection() {
    check_gaussian_matches_scalar(9, 6, NeighborSelection::EuclideanTransformed);
}

#[test]
fn predict_plan_reuse_is_identical() {
    // Serving scenario: one plan, repeated predict calls at fixed θ —
    // results must be bitwise identical, and identical to a plan built
    // from scratch at the same θ.
    let (x, kernel, s, y, xp) = gaussian_setup(80, 8, 5);
    let plan = PredictPlan::build(
        &s,
        &x,
        &kernel,
        &xp,
        5,
        NeighborSelection::CorrelationBruteForce,
    );
    let (m1, v1) = gaussian::predict_with_plan(&s, &kernel, &y, &xp, &plan);
    let (m2, v2) = gaussian::predict_with_plan(&s, &kernel, &y, &xp, &plan);
    assert_eq!(m1, m2);
    assert_eq!(v1, v2);
    let plan2 = PredictPlan::build(
        &s,
        &x,
        &kernel,
        &xp,
        5,
        NeighborSelection::CorrelationBruteForce,
    );
    assert_eq!(plan.neighbors, plan2.neighbors, "plan rebuild changed the sets");
    let (m3, v3) = gaussian::predict_with_plan(&s, &kernel, &y, &xp, &plan2);
    assert_eq!(m1, m3);
    assert_eq!(v1, v3);
}

#[test]
fn cover_tree_pred_neighbors_match_brute_force() {
    let (x, kernel, s, _y, _xp) = gaussian_setup(200, 10, 5);
    let mut rng = Rng::seed_from(5);
    let xp = random_points(&mut rng, 40, 2);
    let bf = PredictPlan::build(
        &s,
        &x,
        &kernel,
        &xp,
        5,
        NeighborSelection::CorrelationBruteForce,
    );
    let ct = PredictPlan::build(
        &s,
        &x,
        &kernel,
        &xp,
        5,
        NeighborSelection::CorrelationCoverTree,
    );
    // Stacked-space correlation distance, computed independently.
    let lr = s.lr.as_ref().unwrap();
    let m = lr.m();
    let dist = |p: usize, j: usize| -> f64 {
        let sp = xp.row(p);
        let mut vt_p: Vec<f64> = (0..m).map(|l| kernel.cov(sp, lr.z.row(l))).collect();
        lr.chol_m.solve_lower_in_place(&mut vt_p);
        let dp = (kernel.variance - dot(&vt_p, &vt_p)).max(1e-300);
        let vj = lr.vt.row(j);
        let dj = (kernel.variance - dot(vj, vj)).max(1e-300);
        let rho = kernel.cov(sp, x.row(j)) - dot(&vt_p, vj);
        let r = rho / (dp * dj).sqrt();
        (1.0 - r.abs()).max(0.0).sqrt()
    };
    for p in 0..xp.rows() {
        if bf.neighbors[p] == ct.neighbors[p] {
            continue;
        }
        // Ties may swap indices: the distance multisets must agree.
        let mut db: Vec<f64> = bf.neighbors[p].iter().map(|&j| dist(p, j as usize)).collect();
        let mut dc: Vec<f64> = ct.neighbors[p].iter().map(|&j| dist(p, j as usize)).collect();
        db.sort_by(f64::total_cmp);
        dc.sort_by(f64::total_cmp);
        for (a, b) in db.iter().zip(&dc) {
            assert!(
                (a - b).abs() < 1e-10,
                "point {p}: cover tree disagrees with brute force ({a} vs {b})"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Laplace: the batched pipeline (mean, deterministic variance, and the
// batched Q/Qᵀ projections feeding SBPV/SPV and the exact path) must
// match a scalar per-point replication of the pre-refactor code.
// ---------------------------------------------------------------------

fn laplace_setup(
    n: usize,
    m: usize,
    m_v: usize,
) -> (Mat, ArdMatern, VifStructure, Vec<f64>, LaplaceState, Mat) {
    let mut rng = Rng::seed_from(51);
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.1, vec![0.35, 0.45], Smoothness::ThreeHalves);
    let z = select_inducing(&x, &kernel, m, 2, &mut rng, None);
    let lr_tmp = z
        .clone()
        .map(|z| LowRank::build(&x, &kernel, z, 1e-10));
    let nb = select_neighbors(
        &x,
        &kernel,
        lr_tmp.as_ref(),
        m_v,
        NeighborSelection::CorrelationBruteForce,
    );
    let s = VifStructure::assemble(&x, &kernel, z, nb, 0.0, 1e-10, 0);
    let mut r2 = Rng::seed_from(17);
    let b = s.sample(&mut r2);
    let y: Vec<f64> = b
        .iter()
        .map(|bi| {
            if r2.bernoulli(vifgp::likelihoods::sigmoid(*bi)) {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let lik = Likelihood::BernoulliLogit;
    let mut rng3 = Rng::seed_from(3);
    let (_, state) = laplace::nll(&s, &x, &kernel, &lik, &y, &SolveMode::Cholesky, &mut rng3);
    let xp = random_points(&mut rng, 9, 2);
    (x, kernel, s, y, state, xp)
}

/// Scalar replication of the pre-refactor `Q w1` projection (w1 already
/// carries `Σ_†⁻¹`).
fn scalar_project_q(
    s: &VifStructure,
    oracle: &ScalarPrediction,
    pred_nb: &[Vec<u32>],
    w1: &[f64],
) -> Vec<f64> {
    let q_m = match &s.lr {
        Some(lr) => lr.chol_m.solve(&lr.sigma_nm.matvec_t(w1)),
        None => vec![],
    };
    let w2 = s.resid.apply_s_inv(w1);
    (0..pred_nb.len())
        .map(|p| {
            let mut acc = if s.m() > 0 {
                dot(oracle.kp.row(p), &q_m)
            } else {
                0.0
            };
            for (k_i, &j) in pred_nb[p].iter().enumerate() {
                acc += oracle.a_rows[p][k_i] * w2[j as usize];
            }
            acc
        })
        .collect()
}

/// Scalar replication of the pre-refactor `Σ_†⁻¹ Qᵀ z` adjoint.
fn scalar_project_qt(
    s: &VifStructure,
    oracle: &ScalarPrediction,
    pred_nb: &[Vec<u32>],
    z: &[f64],
) -> Vec<f64> {
    let n = s.n();
    let mut t = vec![0.0; n];
    if let Some(lr) = &s.lr {
        let tm = lr.chol_m.solve(&oracle.kp.matvec_t(z));
        let q1 = lr.sigma_nm.matvec(&tm);
        t.copy_from_slice(&q1);
    }
    let mut bt = vec![0.0; n];
    for (p, zp) in z.iter().enumerate() {
        if *zp == 0.0 {
            continue;
        }
        for (k, &j) in pred_nb[p].iter().enumerate() {
            bt[j as usize] -= oracle.a_rows[p][k] * zp;
        }
    }
    let sb = s.resid.apply_s_inv(&bt);
    for (ti, sbi) in t.iter_mut().zip(&sb) {
        *ti -= sbi;
    }
    s.apply_sigma_dagger_inv(&t)
}

#[test]
fn laplace_pipeline_matches_scalar_all_variance_methods() {
    let (x, kernel, s, _y, state, xp) = laplace_setup(70, 7, 5);
    let lik = Likelihood::BernoulliLogit;
    let mode = SolveMode::Cholesky;
    let np_pts = xp.rows();
    let plan = PredictPlan::build(
        &s,
        &x,
        &kernel,
        &xp,
        5,
        NeighborSelection::CorrelationBruteForce,
    );
    let oracle =
        scalar_predict_reference(&s, &x, &kernel, &state.b, &xp, &plan.neighbors, 1e-8);

    for method in [PredVarMethod::Exact, PredVarMethod::Sbpv, PredVarMethod::Spv] {
        let ell = 60;
        let mut rng = Rng::seed_from(77);
        let got = laplace::predict_with_plan(
            &s, &x, &kernel, &lik, &state, &xp, &plan, &mode, method, ell, &mut rng,
        );
        // Scalar replication of the pre-refactor stochastic part, on the
        // same probe streams.
        let solver = WSolver::new(&s, &x, &kernel, state.w.clone(), &mode, None);
        let mut rng2 = Rng::seed_from(77);
        let var_stoch: Vec<f64> = match method {
            PredVarMethod::Exact => {
                let sigma_dense = s.dense_sigma_dagger();
                let dsolver = WSolver::new(
                    &s,
                    &x,
                    &kernel,
                    state.w.clone(),
                    &SolveMode::Cholesky,
                    Some(&sigma_dense),
                );
                (0..np_pts)
                    .map(|p| {
                        let mut z = vec![0.0; np_pts];
                        z[p] = 1.0;
                        let qt = scalar_project_qt(&s, &oracle, &plan.neighbors, &z);
                        let cqt = dsolver.solve(&qt);
                        dot(&qt, &cqt)
                    })
                    .collect()
            }
            PredVarMethod::Sbpv => {
                let mut local_rng = rng2.split(0xabc);
                vifgp::iterative::sbpv_diag(
                    ell,
                    np_pts,
                    &mut local_rng,
                    |r| {
                        let sig = s.sample(r);
                        let mut z = s.apply_sigma_dagger_inv(&sig);
                        for (zi, wi) in z.iter_mut().zip(&state.w) {
                            *zi += wi.sqrt() * r.normal();
                        }
                        z
                    },
                    |z6| solver.solve_batch(z6),
                    |z7| {
                        map_columns(z7, |col| {
                            scalar_project_q(
                                &s,
                                &oracle,
                                &plan.neighbors,
                                &s.apply_sigma_dagger_inv(col),
                            )
                        })
                    },
                )
            }
            PredVarMethod::Spv => {
                let mut local_rng = rng2.split(0xdef);
                vifgp::iterative::spv_diag(ell, np_pts, &mut local_rng, |z1| {
                    let qt = map_columns(z1, |z| {
                        scalar_project_qt(&s, &oracle, &plan.neighbors, z)
                    });
                    let sol = solver.solve_batch(&qt);
                    map_columns(&sol, |col| {
                        scalar_project_q(
                            &s,
                            &oracle,
                            &plan.neighbors,
                            &s.apply_sigma_dagger_inv(col),
                        )
                    })
                })
            }
        };
        let want_var: Vec<f64> = oracle
            .var_det
            .iter()
            .zip(&var_stoch)
            .map(|(d, st)| (d + st).max(1e-12))
            .collect();
        assert!(
            rel_diff(&got.latent_mean, &oracle.mean) <= TOL,
            "{method:?} mean diverged: {:.3e}",
            rel_diff(&got.latent_mean, &oracle.mean)
        );
        assert!(
            rel_diff(&got.latent_var, &want_var) <= TOL,
            "{method:?} var diverged: {:.3e}",
            rel_diff(&got.latent_var, &want_var)
        );
    }
}

#[test]
fn laplace_batched_projections_match_scalar() {
    // The batched Q/Qᵀ projections against random blocks, directly.
    let (x, kernel, s, _y, state, xp) = laplace_setup(60, 6, 4);
    let plan = PredictPlan::build(
        &s,
        &x,
        &kernel,
        &xp,
        4,
        NeighborSelection::CorrelationBruteForce,
    );
    let blocks = PredictBlocks::compute(&s, &kernel, &xp, &plan, 1e-8);
    let oracle =
        scalar_predict_reference(&s, &x, &kernel, &state.b, &xp, &plan.neighbors, 1e-8);
    let n = s.n();
    let np_pts = xp.rows();
    let zn = Mat::from_fn(n, 5, |i, j| ((i * 7 + j * 3) as f64 * 0.23).sin());
    let w1 = s.apply_sigma_dagger_inv_batch(&zn);
    let got_q = project_q_batch(&s, &plan, &blocks, &w1);
    for j in 0..5 {
        let want = scalar_project_q(&s, &oracle, &plan.neighbors, &w1.col(j));
        assert!(
            rel_diff(&got_q.col(j), &want) <= TOL,
            "project_q col {j}: {:.3e}",
            rel_diff(&got_q.col(j), &want)
        );
    }
    let zp = Mat::from_fn(np_pts, 5, |i, j| ((i * 3 + j * 11) as f64 * 0.31).cos());
    let got_qt = vifgp::vif::predict::project_qt_batch(&s, &plan, &blocks, &zp);
    for j in 0..5 {
        let want = scalar_project_qt(&s, &oracle, &plan.neighbors, &zp.col(j));
        assert!(
            rel_diff(&got_qt.col(j), &want) <= TOL,
            "project_qt col {j}: {:.3e}",
            rel_diff(&got_qt.col(j), &want)
        );
    }
    // Blocks agree with the scalar oracle too.
    assert!(rel_diff(&blocks.d, &oracle.d) <= TOL);
    for p in 0..np_pts {
        assert!(rel_diff(&blocks.a_rows[p], &oracle.a_rows[p]) <= TOL);
        assert!(rel_diff(blocks.kp.row(p), oracle.kp.row(p)) <= TOL);
        assert!(rel_diff(blocks.alpha.row(p), oracle.alpha.row(p)) <= TOL);
    }
    // Mean through the batched pipeline.
    let mean = posterior_mean(&s, &plan, &blocks, &state.b);
    assert!(rel_diff(&mean, &oracle.mean) <= TOL);
}
