//! Append ≡ rebuild oracle suite for the streaming-append path.
//!
//! Streams interleaved `append_points` batches (sizes 1, 7, 64) into both
//! models and pins, after every batch, that the incrementally updated
//! structure matches a from-scratch `VifStructure::from_plan` over the
//! extended plan — the factor rows, schedule, low-rank panels, and
//! Woodbury blocks all land within ≤1e-12 (most are bitwise). NLL,
//! gradients, and predictions are compared on top, the extended level
//! schedule is checked bit-identical across worker-pool sizes 1/2/8, and
//! the structure-generation counter is pinned to refuse stale
//! prediction plans.

use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::{sigmoid, Likelihood};
use vifgp::linalg::Mat;
use vifgp::rng::Rng;
use vifgp::testing::{
    assert_b_kernels_pool_size_invariant, random_points, structures_max_abs_diff,
};
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::gaussian::{self, GaussianParams, VifRegression};
use vifgp::vif::laplace::{self, PredVarMethod, SolveMode, VifLaplaceModel};
use vifgp::vif::{predict, VifConfig, VifStructure};

const BATCHES: [usize; 3] = [1, 7, 64];

fn test_kernel() -> ArdMatern {
    ArdMatern::new(1.0, vec![0.3, 0.4], Smoothness::ThreeHalves)
}

fn test_config() -> VifConfig {
    VifConfig {
        num_inducing: 20,
        num_neighbors: 6,
        selection: NeighborSelection::CorrelationBruteForce,
        lloyd_iters: 2,
        ..Default::default()
    }
}

/// Rows `lo..hi` of `x` as a fresh matrix (the append batch).
fn rows(x: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, x.cols(), |i, j| x.get(lo + i, j))
}

fn sim_gaussian(n: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::seed_from(seed);
    let x = random_points(&mut rng, n, 2);
    let latent = vifgp::data::simulate_latent_gp(&mut rng, &x, &test_kernel());
    let y: Vec<f64> = latent.iter().map(|l| l + 0.05 * rng.normal()).collect();
    (x, y)
}

fn gaussian_model(x: Mat, y: Vec<f64>) -> VifRegression {
    let init = GaussianParams { kernel: test_kernel(), noise: 0.05 };
    VifRegression::new(x, y, test_config(), init)
}

/// Rebuild the Gaussian model's structure from scratch over its (already
/// extended) plan — the oracle the appended structure must match.
fn rebuild_gaussian(model: &VifRegression) -> VifStructure {
    VifStructure::from_plan(
        &model.x,
        &model.params.kernel,
        model.plan.as_ref().unwrap(),
        model.params.noise,
        model.config.jitter,
        1,
    )
}

#[test]
fn gaussian_append_equals_rebuild() {
    // Base chosen so the streamed fraction (72/472) stays below the
    // compaction threshold: every batch takes the incremental path.
    let total: usize = 400 + BATCHES.iter().sum::<usize>();
    let (x, y) = sim_gaussian(total, 71);
    let mut rng = Rng::seed_from(72);
    let xp = random_points(&mut rng, 12, 2);

    let mut done = 400;
    let mut model = gaussian_model(rows(&x, 0, done), y[..done].to_vec());
    model.assemble();

    for &k in &BATCHES {
        model
            .append_points(&rows(&x, done, done + k), &y[done..done + k])
            .unwrap();
        done += k;
        assert_eq!(model.x.rows(), done);

        let rebuilt = rebuild_gaussian(&model);
        let appended = model.structure.as_ref().unwrap();
        let sdiff = structures_max_abs_diff(appended, &rebuilt);
        assert!(sdiff <= 1e-12, "batch {k}: structure diff {sdiff}");

        let kernel = &model.params.kernel;
        let (v1, g1) = gaussian::nll_and_grad(appended, &model.x, kernel, &model.y);
        let (v2, g2) = gaussian::nll_and_grad(&rebuilt, &model.x, kernel, &model.y);
        assert!(
            (v1 - v2).abs() <= 1e-12 * (1.0 + v2.abs()),
            "batch {k}: nll {v1} vs {v2}"
        );
        for (p, (a, b)) in g1.iter().zip(&g2).enumerate() {
            assert!(
                (a - b).abs() <= 1e-10 * (1.0 + b.abs()),
                "batch {k}: grad[{p}] {a} vs {b}"
            );
        }

        let sel = model.config.selection;
        let (m1, var1) = gaussian::predict(appended, &model.x, kernel, &model.y, &xp, 6, sel);
        let (m2, var2) = gaussian::predict(&rebuilt, &model.x, kernel, &model.y, &xp, 6, sel);
        for p in 0..xp.rows() {
            assert!(
                (m1[p] - m2[p]).abs() <= 1e-12 * (1.0 + m2[p].abs()),
                "batch {k}: mean[{p}] {} vs {}",
                m1[p],
                m2[p]
            );
            assert!(
                (var1[p] - var2[p]).abs() <= 1e-12 * (1.0 + var2[p].abs()),
                "batch {k}: var[{p}] {} vs {}",
                var1[p],
                var2[p]
            );
        }
    }
    assert_eq!(done, total);
}

#[test]
fn laplace_append_equals_rebuild() {
    let total: usize = 300 + BATCHES.iter().sum::<usize>();
    let mut rng = Rng::seed_from(91);
    let x = random_points(&mut rng, total, 2);
    let latent = vifgp::data::simulate_latent_gp(&mut rng, &x, &test_kernel());
    let y: Vec<f64> = latent
        .iter()
        .map(|l| if rng.bernoulli(sigmoid(*l)) { 1.0 } else { 0.0 })
        .collect();
    let xp = random_points(&mut rng, 6, 2);

    let mut done = 300;
    let mut model = VifLaplaceModel::new(
        rows(&x, 0, done),
        y[..done].to_vec(),
        test_config(),
        SolveMode::Cholesky,
        test_kernel(),
        Likelihood::BernoulliLogit,
    );
    model.assemble();

    for &k in &BATCHES {
        model
            .append_points(&rows(&x, done, done + k), &y[done..done + k])
            .unwrap();
        done += k;
        assert!(model.state.is_none(), "append must clear the mode state");

        // Latent-scale rebuild over the extended plan.
        let rebuilt = VifStructure::from_plan(
            &model.x,
            &model.kernel,
            model.plan.as_ref().unwrap(),
            0.0,
            model.config.jitter,
            0,
        );
        let appended = model.structure.as_ref().unwrap();
        let sdiff = structures_max_abs_diff(appended, &rebuilt);
        assert!(sdiff <= 1e-12, "batch {k}: structure diff {sdiff}");
    }
    assert_eq!(done, total);

    // NLL, gradient, and predictions once on the fully streamed model.
    // Mode finding is itself iterative, so the appended/rebuilt mode
    // paths amplify the ≤1e-12 structure difference slightly; the
    // tolerances below are still far under any real approximation drift.
    let rebuilt = VifStructure::from_plan(
        &model.x,
        &model.kernel,
        model.plan.as_ref().unwrap(),
        0.0,
        model.config.jitter,
        0,
    );
    let appended = model.structure.as_ref().unwrap();
    let mode = SolveMode::Cholesky;
    let mut r1 = Rng::seed_from(5);
    let (v1, g1, _) = laplace::nll_and_grad(
        appended,
        &model.x,
        &model.kernel,
        &model.lik,
        &model.y,
        &mode,
        &mut r1,
    );
    let mut r2 = Rng::seed_from(5);
    let (v2, g2, _) = laplace::nll_and_grad(
        &rebuilt,
        &model.x,
        &model.kernel,
        &model.lik,
        &model.y,
        &mode,
        &mut r2,
    );
    assert!((v1 - v2).abs() <= 1e-10 * (1.0 + v2.abs()), "nll {v1} vs {v2}");
    for (p, (a, b)) in g1.iter().zip(&g2).enumerate() {
        assert!(
            (a - b).abs() <= 1e-8 * (1.0 + b.abs()),
            "grad[{p}] {a} vs {b}"
        );
    }

    // Predictions share one mode state so the comparison isolates the
    // structure difference.
    let state = laplace::find_mode(
        appended,
        &model.x,
        &model.kernel,
        &model.lik,
        &model.y,
        &mode,
        None,
    );
    let mut rp = Rng::seed_from(7);
    let p1 = laplace::predict(
        appended,
        &model.x,
        &model.kernel,
        &model.lik,
        &state,
        &xp,
        6,
        model.config.selection,
        &mode,
        PredVarMethod::Exact,
        0,
        &mut rp,
    );
    let p2 = laplace::predict(
        &rebuilt,
        &model.x,
        &model.kernel,
        &model.lik,
        &state,
        &xp,
        6,
        model.config.selection,
        &mode,
        PredVarMethod::Exact,
        0,
        &mut rp,
    );
    for p in 0..xp.rows() {
        assert!(
            (p1.latent_mean[p] - p2.latent_mean[p]).abs()
                <= 1e-11 * (1.0 + p2.latent_mean[p].abs()),
            "mean[{p}]: {} vs {}",
            p1.latent_mean[p],
            p2.latent_mean[p]
        );
        assert!(
            (p1.latent_var[p] - p2.latent_var[p]).abs()
                <= 1e-11 * (1.0 + p2.latent_var[p].abs()),
            "var[{p}]: {} vs {}",
            p1.latent_var[p],
            p2.latent_var[p]
        );
    }
}

#[test]
fn appended_schedule_bitwise_identical_across_pool_sizes() {
    // The extended level schedule must preserve the determinism contract:
    // every scheduled sweep over the appended factor is bit-identical
    // across worker pools of size 1/2/8 and the sequential reference.
    let total: usize = 400 + BATCHES.iter().sum::<usize>();
    let (x, y) = sim_gaussian(total, 77);
    let mut done = 400;
    let mut model = gaussian_model(rows(&x, 0, done), y[..done].to_vec());
    model.assemble();
    for &k in &BATCHES {
        model
            .append_points(&rows(&x, done, done + k), &y[done..done + k])
            .unwrap();
        done += k;
    }
    let mut rng = Rng::seed_from(123);
    assert_b_kernels_pool_size_invariant(
        &model.structure.as_ref().unwrap().resid,
        &mut rng,
        &[1, 2, 8],
        3,
    );
}

#[test]
fn append_bumps_generation_and_fresh_plans_serve() {
    let (x, y) = sim_gaussian(140, 31);
    let mut model = gaussian_model(rows(&x, 0, 120), y[..120].to_vec());
    model.assemble();
    let mut rng = Rng::seed_from(32);
    let xp = random_points(&mut rng, 8, 2);

    let g0 = model.structure.as_ref().unwrap().generation;
    let plan = model.build_predict_plan(&xp);
    assert_eq!(plan.generation(), g0, "plan must record the structure generation");

    model
        .append_points(&rows(&x, 120, 140), &y[120..140])
        .unwrap();
    let g1 = model.structure.as_ref().unwrap().generation;
    assert!(g1 > g0, "append must bump the generation ({g0} -> {g1})");

    // A freshly built plan serves the appended structure.
    let plan2 = model.build_predict_plan(&xp);
    assert_eq!(plan2.generation(), g1);
    let (mean, var) = model.predict_with_plan(&xp, &plan2);
    assert!(mean.iter().chain(&var).all(|v| v.is_finite()));
}

#[test]
#[should_panic(expected = "stale prediction plan")]
fn stale_plan_is_refused_after_append() {
    let (x, y) = sim_gaussian(140, 33);
    let mut model = gaussian_model(rows(&x, 0, 120), y[..120].to_vec());
    model.assemble();
    let mut rng = Rng::seed_from(34);
    let xp = random_points(&mut rng, 8, 2);
    let plan = model.build_predict_plan(&xp);
    model
        .append_points(&rows(&x, 120, 140), &y[120..140])
        .unwrap();
    let _ = model.predict_with_plan(&xp, &plan); // panics: generation mismatch
}

#[test]
fn theta_change_is_counted_as_panel_cache_miss() {
    // A θ refresh does not change the generation (the symbolic structure
    // is untouched), so a reused plan is *allowed* — but its low-rank
    // panel cache no longer matches and the fallback must be counted.
    let (x, y) = sim_gaussian(120, 41);
    let mut model = gaussian_model(x, y);
    model.assemble();
    let mut rng = Rng::seed_from(42);
    let xp = random_points(&mut rng, 8, 2);
    let plan = model.build_predict_plan(&xp);

    model.params.kernel = ArdMatern::new(0.9, vec![0.35, 0.45], Smoothness::ThreeHalves);
    let vplan = model.plan.take().unwrap();
    let mut s = model.structure.take().unwrap();
    s.refresh(
        &vplan,
        &model.x,
        &model.params.kernel,
        model.params.noise,
        model.config.jitter,
    );
    model.plan = Some(vplan);
    model.structure = Some(s);

    let before = predict::lr_panel_cache_misses();
    let (mean, _) = model.predict_with_plan(&xp, &plan);
    assert!(mean.iter().all(|v| v.is_finite()));
    assert!(
        predict::lr_panel_cache_misses() > before,
        "θ-mismatched panel cache fallback must be observable"
    );
}
