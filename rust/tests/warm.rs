//! Warm-started fitting (`FitSession`) contract tests: a cold session is
//! the oracle (Gaussian fits are bitwise identical warm vs cold), a warm
//! Laplace fit must land on the same final NLL as a cold one to ≤1e-6,
//! SLQ probes are common-random-number deterministic on identical seeds,
//! and the per-round probe tag is 0 in round 0 (legacy probes) and
//! advances only at re-selection rounds.

use vifgp::iterative::{solve_stats, IterConfig, PrecondType};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::linalg::Mat;
use vifgp::rng::Rng;
use vifgp::testing::random_points;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{self, SolveMode, VifLaplaceModel};
use vifgp::vif::{
    fit_with_reselection_session, gaussian, select_inducing, select_neighbors, FitSession,
    LowRank, VifConfig, VifPlan, VifStructure,
};

fn small_config(seed: u64) -> VifConfig {
    VifConfig {
        num_inducing: 8,
        num_neighbors: 4,
        selection: NeighborSelection::EuclideanTransformed,
        lloyd_iters: 2,
        seed,
        ..Default::default()
    }
}

/// Binary classification targets sampled from a latent GP draw.
fn binary_problem(n: usize, seed: u64) -> (Mat, Vec<f64>, ArdMatern) {
    let mut rng = Rng::seed_from(seed);
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.2, vec![0.3, 0.45], Smoothness::ThreeHalves);
    let z = select_inducing(&x, &kernel, 8, 2, &mut rng, None);
    let lr = z.clone().map(|z| LowRank::build(&x, &kernel, z, 1e-10));
    let nb = select_neighbors(&x, &kernel, lr.as_ref(), 4, NeighborSelection::CorrelationBruteForce);
    let s = VifStructure::assemble(&x, &kernel, z, nb, 0.0, 1e-10, 0);
    let b = s.sample(&mut rng);
    let y: Vec<f64> = b
        .iter()
        .map(|bi| {
            if rng.bernoulli(vifgp::likelihoods::sigmoid(*bi)) {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    (x, y, kernel)
}

/// Gaussian evaluations are direct (Woodbury + Cholesky, no CG), so the
/// session carries nothing for them: a warm fit must be bitwise
/// identical to a cold one — final NLL and adopted parameters alike.
#[test]
fn gaussian_warm_fit_is_bitwise_identical_to_cold() {
    let build = || {
        let mut rng = Rng::seed_from(31);
        let x = random_points(&mut rng, 60, 2);
        let kernel = ArdMatern::new(1.1, vec![0.35, 0.4], Smoothness::ThreeHalves);
        let latent = vifgp::data::simulate_latent_gp(&mut rng, &x, &kernel);
        let y: Vec<f64> = latent.iter().map(|l| l + 0.2 * rng.normal()).collect();
        let start = gaussian::GaussianParams {
            kernel: ArdMatern::new(0.7, vec![0.6, 0.3], Smoothness::ThreeHalves),
            noise: 0.3,
        };
        gaussian::VifRegression::new(x, y, small_config(3), start)
    };
    let mut warm_model = build();
    let mut cold_model = build();
    let warm_nll = fit_with_reselection_session(&mut warm_model, 10, 2, true);
    let cold_nll = fit_with_reselection_session(&mut cold_model, 10, 2, false);
    assert_eq!(
        warm_nll.to_bits(),
        cold_nll.to_bits(),
        "gaussian warm {warm_nll} vs cold {cold_nll}"
    );
    let pw = warm_model.params.pack();
    let pc = cold_model.params.pack();
    for (a, b) in pw.iter().zip(&pc) {
        assert_eq!(a.to_bits(), b.to_bits(), "params diverged: {a} vs {b}");
    }
}

/// A warm Laplace fit (Newton mode carry-over) must reach the same final
/// NLL as a cold one to ≤1e-6. Cholesky mode: every solve is exact, so
/// the only warm/cold difference is the Newton starting point, which the
/// 1e-8 mode-convergence tolerance bounds.
#[test]
fn laplace_warm_fit_matches_cold_nll_cholesky() {
    let (x, y, _) = binary_problem(40, 7);
    let init = ArdMatern::new(1.0, vec![0.4, 0.5], Smoothness::ThreeHalves);
    let build = |x: &Mat, y: &[f64]| {
        VifLaplaceModel::new(
            x.clone(),
            y.to_vec(),
            small_config(5),
            SolveMode::Cholesky,
            init.clone(),
            Likelihood::BernoulliLogit,
        )
    };
    let mut warm_model = build(&x, &y);
    let mut cold_model = build(&x, &y);
    let warm_nll = fit_with_reselection_session(&mut warm_model, 8, 2, true);
    let cold_nll = fit_with_reselection_session(&mut cold_model, 8, 2, false);
    assert!(
        (warm_nll - cold_nll).abs() <= 1e-6 * (1.0 + cold_nll.abs()),
        "warm {warm_nll} vs cold {cold_nll}"
    );
}

/// Same contract on the iterative path (VIFDU + tight CG): warm starts
/// change iteration counts, not answers. Also checks that the fit
/// actually reused carried state (warm-hit counter moved).
#[test]
fn laplace_warm_fit_matches_cold_nll_iterative() {
    let (x, y, _) = binary_problem(48, 13);
    let init = ArdMatern::new(1.0, vec![0.4, 0.5], Smoothness::ThreeHalves);
    let cfg = IterConfig {
        precond: PrecondType::Vifdu,
        ell: 6,
        cg_tol: 1e-8,
        slq_min_iter: 10,
        ..Default::default()
    };
    let build = |x: &Mat, y: &[f64]| {
        VifLaplaceModel::new(
            x.clone(),
            y.to_vec(),
            small_config(5),
            SolveMode::Iterative(cfg.clone()),
            init.clone(),
            Likelihood::BernoulliLogit,
        )
    };
    let mut cold_model = build(&x, &y);
    let cold_nll = fit_with_reselection_session(&mut cold_model, 6, 1, false);
    let hits_before = solve_stats().snapshot().warm_hits;
    let mut warm_model = build(&x, &y);
    let warm_nll = fit_with_reselection_session(&mut warm_model, 6, 1, true);
    let hits_after = solve_stats().snapshot().warm_hits;
    assert!(
        (warm_nll - cold_nll).abs() <= 1e-6 * (1.0 + cold_nll.abs()),
        "warm {warm_nll} vs cold {cold_nll}"
    );
    assert!(
        hits_after > hits_before,
        "a warm fit must reuse carried state (hits {hits_before} -> {hits_after})"
    );
}

/// SLQ probes are CRN-deterministic: two evaluations from identical RNG
/// seeds draw identical probe vectors and produce bitwise-identical
/// log-determinants — the property the per-round probe tag relies on to
/// keep probes fixed along a round's L-BFGS trajectory.
#[test]
fn slq_probes_are_fixed_on_identical_seeds() {
    let (x, y, kernel) = binary_problem(44, 23);
    let mut rng = Rng::seed_from(23);
    let z = select_inducing(&x, &kernel, 8, 2, &mut rng, None);
    let lr = z.clone().map(|z| LowRank::build(&x, &kernel, z, 1e-10));
    let nb = select_neighbors(&x, &kernel, lr.as_ref(), 4, NeighborSelection::CorrelationBruteForce);
    let plan = VifPlan::build(&x, z, nb);
    let s = VifStructure::from_plan(&x, &kernel, &plan, 0.0, 1e-10, 0);
    let lik = Likelihood::BernoulliLogit;
    let mode = SolveMode::Iterative(IterConfig {
        precond: PrecondType::Vifdu,
        ell: 6,
        cg_tol: 1e-6,
        slq_min_iter: 10,
        ..Default::default()
    });
    let mut r1 = Rng::seed_from(77);
    let (v1, _) = laplace::nll(&s, &x, &kernel, &lik, &y, &mode, &mut r1);
    let mut r2 = Rng::seed_from(77);
    let (v2, _) = laplace::nll(&s, &x, &kernel, &lik, &y, &mode, &mut r2);
    assert_eq!(v1.to_bits(), v2.to_bits(), "{v1} vs {v2}");
    // A different seed must actually draw different probes (the
    // determinism above is CRN, not probe-independence).
    let mut r3 = Rng::seed_from(78);
    let (v3, _) = laplace::nll(&s, &x, &kernel, &lik, &y, &mode, &mut r3);
    assert_ne!(v1.to_bits(), v3.to_bits(), "distinct seeds should move the SLQ estimate");
}

/// The probe tag: 0 for cold sessions and for round 0 of warm ones (so
/// the first warm round reproduces the legacy probe draws bit for bit),
/// then a distinct nonzero tag per re-selection round.
#[test]
fn probe_tag_is_zero_in_round_zero_and_advances_per_round() {
    let mut warm = FitSession::new(true);
    assert!(warm.warm());
    assert_eq!(warm.probe_tag(), 0, "round 0 must reproduce legacy probes");
    warm.start_round();
    let t1 = warm.probe_tag();
    assert_ne!(t1, 0);
    warm.start_round();
    let t2 = warm.probe_tag();
    assert_ne!(t2, 0);
    assert_ne!(t1, t2, "each round must redraw probes");

    let mut cold = FitSession::cold();
    assert!(!cold.warm());
    assert_eq!(cold.probe_tag(), 0);
    cold.start_round();
    assert_eq!(cold.probe_tag(), 0, "cold sessions never re-tag probes");
}
