//! Plan/refresh split: `VifStructure::refresh` (the θ-dependent numeric
//! pass over a frozen `VifPlan`) must be numerically identical — to
//! ≤1e-12 — to a from-scratch `VifStructure::assemble` with the same
//! structure choices, across a multi-step θ trajectory. Covered paths:
//! m=0 (pure Vecchia), m>0 (full VIF), m_v=0 (FITC), and the Laplace
//! latent scale (nugget = 0), including NLL values and gradients.

use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::linalg::Mat;
use vifgp::rng::Rng;
use vifgp::testing::{random_points, structures_max_abs_diff};
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::{self, SolveMode};
use vifgp::vif::{gaussian, select_inducing, select_neighbors, LowRank, VifPlan, VifStructure};

const TOL: f64 = 1e-12;

/// Fixed structure choices (z, neighbors) for a random problem.
fn setup(
    n: usize,
    m: usize,
    m_v: usize,
    seed: u64,
) -> (Mat, ArdMatern, Option<Mat>, Vec<Vec<u32>>) {
    let mut rng = Rng::seed_from(seed);
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.2, vec![0.3, 0.45], Smoothness::ThreeHalves);
    let z = select_inducing(&x, &kernel, m, 2, &mut rng, None);
    let lr_tmp = z.clone().map(|z| LowRank::build(&x, &kernel, z, 1e-10));
    let nb = if m_v == 0 {
        vec![vec![]; n]
    } else {
        select_neighbors(
            &x,
            &kernel,
            lr_tmp.as_ref(),
            m_v,
            NeighborSelection::CorrelationBruteForce,
        )
    };
    (x, kernel, z, nb)
}

/// Deterministic θ trajectory: multiplicative log-parameter steps around
/// the starting kernel (the shape an L-BFGS line search walks).
fn theta_step(kernel: &ArdMatern, t: usize) -> ArdMatern {
    let mut p = kernel.log_params();
    for (j, pj) in p.iter_mut().enumerate() {
        *pj += 0.08 * ((t * (j + 2)) as f64 * 0.7).sin() + 0.02 * t as f64;
    }
    ArdMatern::from_log_params(&p, kernel.smoothness)
}

fn synthetic_targets(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed_from(seed);
    (0..n).map(|_| rng.normal()).collect()
}

/// Walk a θ trajectory refreshing one structure in place and assert it
/// matches a fresh assemble at every step (structure internals, NLL,
/// gradients).
fn assert_refresh_trajectory(
    x: &Mat,
    kernel: &ArdMatern,
    z: Option<Mat>,
    nb: Vec<Vec<u32>>,
    base_nugget: f64,
    steps: usize,
) {
    let y = synthetic_targets(x.rows(), 99);
    let plan = VifPlan::build(x, z.clone(), nb.clone());
    let mut s = VifStructure::from_plan(x, kernel, &plan, base_nugget, 1e-10, 1);
    // from_plan itself must match a from-scratch assemble.
    let fresh0 = VifStructure::assemble(x, kernel, z.clone(), nb.clone(), base_nugget, 1e-10, 1);
    let d0 = structures_max_abs_diff(&s, &fresh0);
    assert!(d0 <= TOL, "from_plan vs assemble diff {d0:.3e}");
    for t in 1..=steps {
        let kt = theta_step(kernel, t);
        let nug = base_nugget * (1.0 + 0.15 * t as f64);
        s.refresh(&plan, x, &kt, nug, 1e-10);
        let fresh = VifStructure::assemble(x, &kt, z.clone(), nb.clone(), nug, 1e-10, 1);
        let diff = structures_max_abs_diff(&s, &fresh);
        assert!(diff <= TOL, "step {t}: refresh vs assemble diff {diff:.3e}");
        // NLL and gradients through both structures.
        let (v1, g1) = gaussian::nll_and_grad(&s, x, &kt, &y);
        let (v2, g2) = gaussian::nll_and_grad(&fresh, x, &kt, &y);
        assert!(
            (v1 - v2).abs() <= TOL * (1.0 + v2.abs()),
            "step {t}: NLL {v1} vs {v2}"
        );
        for (p, (a, b)) in g1.iter().zip(&g2).enumerate() {
            assert!(
                (a - b).abs() <= TOL * (1.0 + b.abs()),
                "step {t}: grad[{p}] {a} vs {b}"
            );
        }
    }
}

#[test]
fn refresh_matches_assemble_full_vif() {
    let (x, kernel, z, nb) = setup(60, 8, 5, 3);
    assert_refresh_trajectory(&x, &kernel, z, nb, 0.05, 6);
}

#[test]
fn refresh_matches_assemble_pure_vecchia() {
    let (x, kernel, z, nb) = setup(55, 0, 5, 7);
    assert!(z.is_none());
    assert_refresh_trajectory(&x, &kernel, z, nb, 0.08, 6);
}

#[test]
fn refresh_matches_assemble_fitc() {
    let (x, kernel, z, nb) = setup(50, 7, 0, 11);
    assert!(nb.iter().all(Vec::is_empty));
    assert_refresh_trajectory(&x, &kernel, z, nb, 0.05, 4);
}

#[test]
fn refresh_matches_assemble_laplace_latent_scale() {
    // Latent scale: nugget = 0 throughout; compare structures and the
    // (deterministic) Cholesky-mode L^{VIFLA} at every step.
    let (x, kernel, z, nb) = setup(32, 5, 4, 13);
    let plan = VifPlan::build(&x, z.clone(), nb.clone());
    let mut s = VifStructure::from_plan(&x, &kernel, &plan, 0.0, 1e-10, 0);
    let lik = Likelihood::BernoulliLogit;
    // Simulate binary targets from the initial structure.
    let mut rng = Rng::seed_from(17);
    let b = s.sample(&mut rng);
    let y: Vec<f64> = b
        .iter()
        .map(|bi| {
            if rng.bernoulli(vifgp::likelihoods::sigmoid(*bi)) {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    for t in 1..=4 {
        let kt = theta_step(&kernel, t);
        s.refresh(&plan, &x, &kt, 0.0, 1e-10);
        let fresh = VifStructure::assemble(&x, &kt, z.clone(), nb.clone(), 0.0, 1e-10, 0);
        let diff = structures_max_abs_diff(&s, &fresh);
        assert!(diff <= TOL, "step {t}: refresh vs assemble diff {diff:.3e}");
        let mut r1 = Rng::seed_from(5);
        let (v1, _) = laplace::nll(&s, &x, &kt, &lik, &y, &SolveMode::Cholesky, &mut r1);
        let mut r2 = Rng::seed_from(5);
        let (v2, _) = laplace::nll(&fresh, &x, &kt, &lik, &y, &SolveMode::Cholesky, &mut r2);
        assert!(
            (v1 - v2).abs() <= TOL * (1.0 + v2.abs()),
            "step {t}: L^VIFLA {v1} vs {v2}"
        );
    }
}

#[test]
fn refresh_is_idempotent_at_fixed_theta() {
    // Refreshing twice at the same θ must not drift: the numeric pass
    // overwrites every θ-dependent buffer.
    let (x, kernel, z, nb) = setup(45, 6, 4, 19);
    let plan = VifPlan::build(&x, z, nb);
    let mut s = VifStructure::from_plan(&x, &kernel, &plan, 0.05, 1e-10, 1);
    let kt = theta_step(&kernel, 3);
    s.refresh(&plan, &x, &kt, 0.07, 1e-10);
    let snapshot_d = s.resid.d.clone();
    let snapshot_ss = s.ss.clone();
    let ld = s.logdet();
    s.refresh(&plan, &x, &kt, 0.07, 1e-10);
    for (a, b) in s.resid.d.iter().zip(&snapshot_d) {
        assert!((a - b).abs() <= TOL, "D drifted: {a} vs {b}");
    }
    assert!(s.ss.max_abs_diff(&snapshot_ss) <= TOL, "SS drifted");
    assert!((s.logdet() - ld).abs() <= TOL, "logdet drifted");
}

#[test]
fn fit_round_reuses_plan_and_improves_nll() {
    // End-to-end through the shared driver: the Gaussian model's fit
    // must still beat its starting NLL with the plan/refresh hot loop.
    let mut rng = Rng::seed_from(29);
    let x = random_points(&mut rng, 70, 2);
    let kernel = ArdMatern::new(1.1, vec![0.35, 0.4], Smoothness::ThreeHalves);
    let latent = vifgp::data::simulate_latent_gp(&mut rng, &x, &kernel);
    let y: Vec<f64> = latent.iter().map(|l| l + 0.2 * rng.normal()).collect();
    let config = vifgp::vif::VifConfig {
        num_inducing: 9,
        num_neighbors: 4,
        selection: NeighborSelection::EuclideanTransformed,
        lloyd_iters: 2,
        ..Default::default()
    };
    let start = gaussian::GaussianParams {
        kernel: ArdMatern::new(0.6, vec![0.7, 0.2], Smoothness::ThreeHalves),
        noise: 0.3,
    };
    let mut model = gaussian::VifRegression::new(x, y, config, start.clone());
    let final_nll = model.fit(30);
    let nb = model.structure.as_ref().unwrap().resid.neighbors.clone();
    let z = model.inducing.clone();
    let start_nll = model.nll_at(&start.pack(), &nb, z.as_ref());
    assert!(
        final_nll < start_nll,
        "fit {final_nll} did not beat start {start_nll}"
    );
    assert!(!model.fit_trace.is_empty(), "driver recorded no trace");
}
