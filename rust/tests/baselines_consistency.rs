//! Baseline-reduction consistency: the VIF approximation degenerates to
//! its two named special cases exactly (paper §2.1), and the SGPR bound
//! behaves like a bound.

use vifgp::data;
use vifgp::baselines::sgpr::neg_elbo;
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::linalg::{dot, CholeskyFactor};
use vifgp::rng::Rng;
use vifgp::testing::random_points;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::gaussian::nll;
use vifgp::vif::{select_inducing, select_neighbors, VifStructure};

const LN_2PI: f64 = 1.8378770664093453;

#[test]
fn vif_with_mv0_equals_fitc_likelihood() {
    // m_v = 0: Σ_† = Q_nn + diag(Σ − Q_nn) + σ²I — the FITC marginal.
    let mut rng = Rng::seed_from(4);
    let n = 80;
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.2, vec![0.3, 0.4], Smoothness::ThreeHalves);
    let noise = 0.07;
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let z = select_inducing(&x, &kernel, 12, 3, &mut rng, None).unwrap();
    let s = VifStructure::assemble(&x, &kernel, Some(z.clone()), vec![vec![]; n], noise, 1e-12, 1);
    let got = nll(&s, &y);
    // dense FITC marginal
    let mut sig_m = kernel.sym_cov(&z, 0.0);
    sig_m.add_diag(1e-10 * kernel.variance);
    let chol_m = CholeskyFactor::new(&sig_m).unwrap();
    let mut cov = vifgp::linalg::Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let ki: Vec<f64> = (0..12).map(|l| kernel.cov(x.row(i), z.row(l))).collect();
            let kj: Vec<f64> = (0..12).map(|l| kernel.cov(x.row(j), z.row(l))).collect();
            let q = dot(&ki, &chol_m.solve(&kj));
            let mut v = q;
            if i == j {
                v += (kernel.variance - q) + noise;
            }
            cov.set(i, j, v);
        }
    }
    let chol = CholeskyFactor::new_with_jitter(&cov, 1e-10).unwrap();
    let alpha = chol.solve(&y);
    let want = 0.5 * (n as f64 * LN_2PI + chol.logdet() + dot(&y, &alpha));
    assert!((got - want).abs() < 1e-4, "{got} vs {want}");
}

#[test]
fn vif_with_m0_equals_vecchia_likelihood() {
    // m = 0: Σ_† = B⁻¹DB⁻ᵀ of the response covariance — plain Vecchia.
    let mut rng = Rng::seed_from(6);
    let n = 60;
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(0.9, vec![0.25, 0.35], Smoothness::FiveHalves);
    let noise = 0.1;
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let nb = select_neighbors(&x, &kernel, None, 5, NeighborSelection::CorrelationBruteForce);
    let s = VifStructure::assemble(&x, &kernel, None, nb.clone(), noise, 1e-12, 1);
    let got = nll(&s, &y);
    // direct Vecchia NLL: ½Σ[log 2π + log D_i + r_i²/D_i], r = B y
    let by = s.resid.mul_b(&y);
    let want = 0.5
        * by.iter()
            .zip(&s.resid.d)
            .map(|(r, d)| LN_2PI + d.ln() + r * r / d)
            .sum::<f64>();
    assert!((got - want).abs() < 1e-8, "{got} vs {want}");
}

#[test]
fn sgpr_bound_dominates_exact_nll_for_any_inducing_subset() {
    let mut rng = Rng::seed_from(8);
    let n = 70;
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.0, vec![0.4, 0.5], Smoothness::Gaussian);
    let noise = 0.15;
    let cov = kernel.sym_cov(&x, noise);
    let chol = CholeskyFactor::new(&cov).unwrap();
    let y = chol.mul_lower(&rng.normal_vec(n));
    let alpha = chol.solve(&y);
    let exact = 0.5 * (n as f64 * LN_2PI + chol.logdet() + dot(&y, &alpha));
    for m in [5usize, 15, 40] {
        let z = data::subset_rows(&x, &(0..m).collect::<Vec<_>>());
        let bound = neg_elbo(&x, &y, &kernel, noise, &z);
        assert!(
            bound >= exact - 1e-6,
            "m={m}: bound {bound} below exact {exact}"
        );
    }
}

#[test]
fn vif_interpolates_between_fitc_and_exact() {
    // With m fixed, increasing m_v should (weakly) improve the VIF NLL's
    // agreement with the exact marginal NLL.
    let mut rng = Rng::seed_from(12);
    let n = 70;
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.0, vec![0.2, 0.3], Smoothness::ThreeHalves);
    let noise = 0.05;
    let cov = kernel.sym_cov(&x, noise);
    let chol = CholeskyFactor::new(&cov).unwrap();
    let y = chol.mul_lower(&rng.normal_vec(n));
    let alpha = chol.solve(&y);
    let exact = 0.5 * (n as f64 * LN_2PI + chol.logdet() + dot(&y, &alpha));
    let z = select_inducing(&x, &kernel, 8, 3, &mut rng, None);
    let mut errs = Vec::new();
    for m_v in [0usize, 4, 20, n - 1] {
        let nb: Vec<Vec<u32>> = (0..n)
            .map(|i| {
                let lo = i.saturating_sub(m_v);
                (lo..i).map(|j| j as u32).collect()
            })
            .collect();
        let s = VifStructure::assemble(&x, &kernel, z.clone(), nb, noise, 1e-12, 1);
        errs.push((nll(&s, &y) - exact).abs());
    }
    // full conditioning is exact
    assert!(errs[3] < 1e-5, "full conditioning err {}", errs[3]);
    // and more neighbors should not make things dramatically worse
    assert!(
        errs[2] <= errs[0] + 1e-6,
        "m_v=20 err {} vs FITC err {}",
        errs[2],
        errs[0]
    );
}
