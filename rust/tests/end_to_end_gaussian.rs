//! End-to-end Gaussian regression: simulate → fit → predict, verifying
//! the paper's qualitative claims on a small workload: parameter
//! recovery, VIF ≥ {Vecchia, FITC} prediction accuracy, and calibrated
//! predictive intervals.

use vifgp::baselines;
use vifgp::data;
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::metrics;
use vifgp::rng::Rng;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::VifConfig;

struct Workload {
    xtr: vifgp::linalg::Mat,
    ytr: Vec<f64>,
    xte: vifgp::linalg::Mat,
    yte: Vec<f64>,
}

fn workload(seed: u64, n_train: usize, n_test: usize, d: usize, noise: f64) -> Workload {
    let mut rng = Rng::seed_from(seed);
    let x = data::uniform_inputs(&mut rng, n_train + n_test, d);
    let kernel = ArdMatern::new(
        1.0,
        data::paper_length_scales(d, Smoothness::ThreeHalves),
        Smoothness::ThreeHalves,
    );
    let latent = data::simulate_latent_gp(&mut rng, &x, &kernel);
    let y = data::simulate_response(&mut rng, &latent, &Likelihood::Gaussian { variance: noise });
    let idx: Vec<usize> = (0..n_train + n_test).collect();
    let (tr, te) = idx.split_at(n_train);
    Workload {
        xtr: data::subset_rows(&x, tr),
        ytr: data::subset_vec(&y, tr),
        xte: data::subset_rows(&x, te),
        yte: data::subset_vec(&y, te),
    }
}

fn fit_and_score(w: &Workload, config: VifConfig) -> (f64, f64, GaussianParams) {
    let init = GaussianParams {
        kernel: ArdMatern::isotropic(0.5, 0.5, w.xtr.cols(), config.smoothness),
        noise: 0.2,
    };
    let mut model = VifRegression::new(w.xtr.clone(), w.ytr.clone(), config, init);
    model.fit(30);
    let (mean, var) = model.predict(&w.xte);
    (
        metrics::rmse(&mean, &w.yte),
        metrics::log_score_gaussian(&mean, &var, &w.yte),
        model.params.clone(),
    )
}

#[test]
fn vif_beats_or_matches_baselines_and_recovers_noise() {
    let w = workload(3, 800, 300, 2, 0.05);
    let base = VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 40,
        num_neighbors: 8,
        seed: 1,
        ..Default::default()
    };
    let (rmse_vif, ls_vif, pars) = fit_and_score(&w, base.clone());
    let (rmse_vec, _, _) = fit_and_score(&w, baselines::vecchia_config(8, &base));
    let (rmse_fitc, _, _) = fit_and_score(&w, baselines::fitc_config(40, &base));
    // paper headline: VIF at least as accurate as both baselines (margin
    // for stochastic selection).
    assert!(
        rmse_vif <= rmse_vec * 1.10,
        "VIF {rmse_vif} vs Vecchia {rmse_vec}"
    );
    assert!(
        rmse_vif <= rmse_fitc * 1.10,
        "VIF {rmse_vif} vs FITC {rmse_fitc}"
    );
    // the fitted noise should be near the true 0.05
    assert!(
        pars.noise > 0.01 && pars.noise < 0.2,
        "noise estimate {}",
        pars.noise
    );
    assert!(ls_vif < 0.5, "log-score {ls_vif}");
}

#[test]
fn predictive_intervals_are_calibrated() {
    let w = workload(5, 700, 400, 2, 0.1);
    let base = VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 30,
        num_neighbors: 8,
        seed: 2,
        ..Default::default()
    };
    let init = GaussianParams {
        kernel: ArdMatern::isotropic(0.5, 0.5, 2, base.smoothness),
        noise: 0.2,
    };
    let mut model = VifRegression::new(w.xtr.clone(), w.ytr.clone(), base, init);
    model.fit(30);
    let (mean, var) = model.predict(&w.xte);
    // ±2 sd coverage should be near 95%
    let covered = mean
        .iter()
        .zip(&var)
        .zip(&w.yte)
        .filter(|((m, v), y)| (*y - **m).abs() <= 2.0 * v.sqrt())
        .count() as f64
        / w.yte.len() as f64;
    assert!(covered > 0.85 && covered <= 1.0, "coverage {covered}");
}

#[test]
fn accuracy_improves_with_budget() {
    // More inducing points + neighbors → no worse accuracy (Fig 11 shape).
    let w = workload(7, 700, 300, 5, 0.05);
    let small = VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 10,
        num_neighbors: 2,
        seed: 3,
        ..Default::default()
    };
    let big = VifConfig { num_inducing: 60, num_neighbors: 12, ..small.clone() };
    let (rmse_small, _, _) = fit_and_score(&w, small);
    let (rmse_big, _, _) = fit_and_score(&w, big);
    assert!(
        rmse_big <= rmse_small * 1.05,
        "budget: small {rmse_small} vs big {rmse_big}"
    );
}
