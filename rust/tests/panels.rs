//! Panel-layer equivalence tests: the panelized residual-covariance
//! blocks (`rho_block` / `rho_and_grad_block` on `VifResidualOracle`),
//! the batched correlation metric, and the panelized
//! `ResidualFactor::build` / `grads` paths must all agree with the
//! scalar per-pair reference (the `ResidualCov`/`Metric` default impls)
//! to tight absolute tolerance on every conditioning-graph shape.

use std::sync::Mutex;

use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::rng::Rng;
use vifgp::testing::{
    assert_metric_batch_matches_scalar, assert_rho_blocks_match_scalar, random_neighbor_graph,
    random_points, ScalarizedMetric, ScalarizedOracle,
};
use vifgp::vecchia::neighbors::covertree_ordered_knn;
use vifgp::vecchia::ResidualFactor;
use vifgp::vif::{select_inducing, CorrelationMetric, GradAux, LowRank, VifResidualOracle};
use vifgp::Mat;

const TOL: f64 = 1e-12;

fn graphs(rng: &mut Rng, n: usize) -> Vec<(&'static str, Vec<Vec<u32>>)> {
    let empty: Vec<Vec<u32>> = vec![vec![]; n];
    let chain: Vec<Vec<u32>> = (0..n as u32)
        .map(|i| if i == 0 { vec![] } else { vec![i - 1] })
        .collect();
    let saturated: Vec<Vec<u32>> = (0..n).map(|i| (0..i as u32).collect()).collect();
    let irregular = random_neighbor_graph(rng, n, 8);
    vec![
        ("empty", empty),
        ("chain", chain),
        ("saturated", saturated),
        ("irregular", irregular),
    ]
}

struct Setup {
    x: Mat,
    kernel: ArdMatern,
    lr: Option<LowRank>,
}

fn setup(n: usize, m: usize, smoothness: Smoothness, seed: u64) -> Setup {
    let mut rng = Rng::seed_from(seed);
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.4, vec![0.3, 0.5], smoothness);
    let lr = select_inducing(&x, &kernel, m, 2, &mut rng, None)
        .map(|z| LowRank::build(&x, &kernel, z, 1e-10));
    Setup { x, kernel, lr }
}

#[test]
fn rho_blocks_match_scalar_on_all_graphs() {
    for (m, smoothness) in [
        (0usize, Smoothness::ThreeHalves),
        (7, Smoothness::ThreeHalves),
        (7, Smoothness::Gaussian),
    ] {
        let s = setup(50, m, smoothness, 11);
        let aux = s.lr.as_ref().map(|lr| GradAux::build(&s.x, &s.kernel, lr));
        let oracle = VifResidualOracle {
            kernel: &s.kernel,
            x: &s.x,
            lr: s.lr.as_ref(),
            grad_aux: aux.as_ref(),
            extra_params: 1,
            x_panels: None,
        };
        let mut rng = Rng::seed_from(5);
        for (name, nb) in graphs(&mut rng, 50) {
            let _ = name;
            assert_rho_blocks_match_scalar(&oracle, &nb, TOL);
        }
    }
}

#[test]
fn panel_build_and_grads_match_scalarized_oracle() {
    let s = setup(60, 6, Smoothness::ThreeHalves, 23);
    let aux = s.lr.as_ref().map(|lr| GradAux::build(&s.x, &s.kernel, lr));
    let oracle = VifResidualOracle {
        kernel: &s.kernel,
        x: &s.x,
        lr: s.lr.as_ref(),
        grad_aux: aux.as_ref(),
        extra_params: 1,
        x_panels: None,
    };
    let scalar = ScalarizedOracle(&oracle);
    let np = 1 + 2 + 1; // log σ₁², two log λ, log σ²
    let mut rng = Rng::seed_from(3);
    for (name, nb) in graphs(&mut rng, 60) {
        let f_panel = ResidualFactor::build(&oracle, nb.clone(), 0.05, 1e-10);
        let f_scalar = ResidualFactor::build(&scalar, nb.clone(), 0.05, 1e-10);
        for i in 0..60 {
            assert!(
                (f_panel.d[i] - f_scalar.d[i]).abs() <= TOL,
                "{name}: d[{i}] {} vs {}",
                f_panel.d[i],
                f_scalar.d[i]
            );
            for (k, (a, b)) in f_panel.a[i].iter().zip(&f_scalar.a[i]).enumerate() {
                assert!((a - b).abs() <= TOL, "{name}: a[{i}][{k}] {a} vs {b}");
            }
        }
        // Gradient pass: same dd/da from both oracles.
        let collect = |orc: &dyn vifgp::vecchia::ResidualCov| {
            let dd = Mutex::new(vec![vec![0.0; np]; 60]);
            let da = Mutex::new(vec![Vec::<Vec<f64>>::new(); 60]);
            f_panel.grads(orc, 0.05, Some(np - 1), 1e-10, &|i, ddi, dai| {
                dd.lock().unwrap()[i].copy_from_slice(ddi);
                da.lock().unwrap()[i] = dai.to_vec();
            });
            (dd.into_inner().unwrap(), da.into_inner().unwrap())
        };
        let (dd_p, da_p) = collect(&oracle);
        let (dd_s, da_s) = collect(&scalar);
        for i in 0..60 {
            for p in 0..np {
                assert!(
                    (dd_p[i][p] - dd_s[i][p]).abs() <= 1e-10,
                    "{name}: dd[{i}][{p}] {} vs {}",
                    dd_p[i][p],
                    dd_s[i][p]
                );
                for (k, (a, b)) in da_p[i][p].iter().zip(&da_s[i][p]).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-10,
                        "{name}: da[{i}][{p}][{k}] {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn panel_gradients_match_finite_differences() {
    // FD over the packed kernel log-parameters, with z (and hence the
    // low-rank blocks) rebuilt at every perturbed θ — the same
    // dependency structure rho_and_grad differentiates.
    let n = 40;
    let mut rng = Rng::seed_from(7);
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.2, vec![0.35, 0.45], Smoothness::ThreeHalves);
    let z = select_inducing(&x, &kernel, 5, 2, &mut rng, None).unwrap();
    let lr = LowRank::build(&x, &kernel, z.clone(), 1e-10);
    let aux = GradAux::build(&x, &kernel, &lr);
    let oracle = VifResidualOracle {
        kernel: &kernel,
        x: &x,
        lr: Some(&lr),
        grad_aux: Some(&aux),
        extra_params: 0,
        x_panels: None,
    };
    let nb: Vec<u32> = vec![2, 9, 17, 30];
    let i = 35usize;
    let q = nb.len();
    let np = kernel.num_params();
    let mut rho_nn = Mat::zeros(q, q);
    let mut rho_in = vec![0.0; q];
    let mut d_nn: Vec<Mat> = (0..np).map(|_| Mat::zeros(q, q)).collect();
    let mut d_in = Mat::zeros(np, q);
    let mut d_ii = vec![0.0; np];
    use vifgp::vecchia::ResidualCov;
    let rho_ii = oracle.rho_and_grad_block(
        i,
        &nb,
        &mut rho_nn,
        &mut rho_in,
        &mut d_nn,
        &mut d_in,
        &mut d_ii,
    );
    let _ = rho_ii;
    let p0 = kernel.log_params();
    let h = 1e-5;
    let eval = |packed: &[f64]| -> (Mat, Vec<f64>, f64) {
        let kp = ArdMatern::from_log_params(packed, Smoothness::ThreeHalves);
        let lrp = LowRank::build(&x, &kp, z.clone(), 1e-10);
        let orc = VifResidualOracle {
            kernel: &kp,
            x: &x,
            lr: Some(&lrp),
            grad_aux: None,
            extra_params: 0,
            x_panels: None,
        };
        let mut cnn = Mat::zeros(q, q);
        let mut cin = vec![0.0; q];
        let cii = orc.rho_block(i, &nb, &mut cnn, &mut cin);
        (cnn, cin, cii)
    };
    for p in 0..np {
        let mut pp = p0.clone();
        pp[p] += h;
        let mut pm = p0.clone();
        pm[p] -= h;
        let (nn_p, in_p, ii_p) = eval(&pp);
        let (nn_m, in_m, ii_m) = eval(&pm);
        let fd_ii = (ii_p - ii_m) / (2.0 * h);
        assert!(
            (fd_ii - d_ii[p]).abs() < 1e-5 * (1.0 + d_ii[p].abs()),
            "p={p}: d_rho_ii fd {fd_ii} vs analytic {}",
            d_ii[p]
        );
        for t in 0..q {
            let fd = (in_p[t] - in_m[t]) / (2.0 * h);
            assert!(
                (fd - d_in.get(p, t)).abs() < 1e-5 * (1.0 + d_in.get(p, t).abs()),
                "p={p}: d_rho_in[{t}] fd {fd} vs analytic {}",
                d_in.get(p, t)
            );
        }
        for a in 0..q {
            for b in 0..q {
                let fd = (nn_p.get(a, b) - nn_m.get(a, b)) / (2.0 * h);
                assert!(
                    (fd - d_nn[p].get(a, b)).abs() < 1e-5 * (1.0 + d_nn[p].get(a, b).abs()),
                    "p={p}: d_rho_nn[{a},{b}] fd {fd} vs analytic {}",
                    d_nn[p].get(a, b)
                );
            }
        }
    }
}

#[test]
fn correlation_metric_batch_matches_scalar() {
    for m in [0usize, 6] {
        let s = setup(80, m, Smoothness::ThreeHalves, 31);
        let metric = CorrelationMetric::new(&s.kernel, &s.x, s.lr.as_ref());
        let mut rng = Rng::seed_from(19);
        assert_metric_batch_matches_scalar(&metric, 80, &mut rng, 40, TOL);
    }
}

#[test]
fn covertree_search_identical_with_batched_and_scalar_metric() {
    let s = setup(300, 6, Smoothness::ThreeHalves, 41);
    let metric = CorrelationMetric::new(&s.kernel, &s.x, s.lr.as_ref());
    let batched = covertree_ordered_knn(300, 5, &metric);
    let scalar = covertree_ordered_knn(300, 5, &ScalarizedMetric(&metric));
    assert_eq!(batched, scalar, "batched metric changed the search result");
}
