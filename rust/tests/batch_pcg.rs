//! Regression tests for the batched multi-RHS PCG engine: k stacked
//! right-hand sides must reproduce k sequential `pcg` solves — solutions,
//! iteration counts under the shared stopping rule, and per-column
//! Lanczos tridiagonal quadrature — for identity, Jacobi, and VIFDU
//! preconditioners; and threaded batch order must not change results.

use vifgp::iterative::{
    pcg_batch_with_min, pcg_with_min, slq_logdet, IdentityPrecond, Preconditioner, VifduPrecond,
};
use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::linalg::{dot, Mat};
use vifgp::rng::Rng;
use vifgp::testing::random_points;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::laplace::OpWPlusPrec;
use vifgp::vif::{select_inducing, select_neighbors, VifStructure};

struct JacobiPrecond(Vec<f64>);
impl Preconditioner for JacobiPrecond {
    fn n(&self) -> usize {
        self.0.len()
    }
    fn solve(&self, v: &[f64]) -> Vec<f64> {
        v.iter().zip(&self.0).map(|(x, d)| x / d).collect()
    }
    fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        self.0.iter().map(|d| rng.normal() * d.sqrt()).collect()
    }
    fn logdet(&self) -> f64 {
        self.0.iter().map(|d| d.ln()).sum()
    }
}

fn setup(n: usize) -> (VifStructure, Vec<f64>) {
    let mut rng = Rng::seed_from(33);
    let x = random_points(&mut rng, n, 2);
    let kernel = ArdMatern::new(1.2, vec![0.3, 0.4], Smoothness::ThreeHalves);
    let z = select_inducing(&x, &kernel, 8, 2, &mut rng, None);
    let nb = select_neighbors(&x, &kernel, None, 5, NeighborSelection::EuclideanTransformed);
    let s = VifStructure::assemble(&x, &kernel, z, nb, 0.0, 1e-10, 0);
    let w: Vec<f64> = (0..n)
        .map(|i| 0.15 + 0.1 * ((i as f64 * 0.31).sin().abs()))
        .collect();
    (s, w)
}

fn rhs(n: usize, k: usize) -> Mat {
    Mat::from_fn(n, k, |i, j| ((i * 7 + j * 13) as f64 * 0.17).sin())
}

#[test]
fn batch_matches_sequential_for_all_preconditioners() {
    let n = 60;
    let k = 6;
    let (s, w) = setup(n);
    let op = OpWPlusPrec { s: &s, w: &w };
    let b = rhs(n, k);
    let jacobi_diag: Vec<f64> = (0..n).map(|i| 1.5 + 0.3 * (i as f64 * 0.2).sin()).collect();
    let pres: Vec<Box<dyn Preconditioner + '_>> = vec![
        Box::new(IdentityPrecond(n)),
        Box::new(JacobiPrecond(jacobi_diag)),
        Box::new(VifduPrecond::new(&s, &w)),
    ];
    for (pi, pre) in pres.iter().enumerate() {
        let res = pcg_batch_with_min(&op, pre.as_ref(), &b, 1e-8, 5, 500, true);
        for j in 0..k {
            let want = pcg_with_min(&op, pre.as_ref(), &b.col(j), 1e-8, 5, 500, true);
            assert_eq!(
                res.columns[j].iters, want.iters,
                "precond {pi} col {j}: batched iters differ"
            );
            assert_eq!(res.columns[j].converged, want.converged, "precond {pi} col {j}");
            for (g, wv) in res.x.col(j).iter().zip(&want.x) {
                assert!(
                    (g - wv).abs() < 1e-8 * (1.0 + wv.abs()),
                    "precond {pi} col {j}: solution {g} vs {wv}"
                );
            }
            let tg = res.columns[j].tridiag.as_ref().expect("batch tridiag");
            let tw = want.tridiag.as_ref().expect("seq tridiag");
            let qg = tg.quadrature(|l| l.max(1e-300).ln());
            let qw = tw.quadrature(|l| l.max(1e-300).ln());
            assert!(
                (qg - qw).abs() < 1e-7 * (1.0 + qw.abs()),
                "precond {pi} col {j}: quadrature {qg} vs {qw}"
            );
        }
    }
}

#[test]
fn threaded_batch_order_does_not_change_results() {
    let n = 50;
    let k = 8;
    let (s, w) = setup(n);
    let op = OpWPlusPrec { s: &s, w: &w };
    let pre = VifduPrecond::new(&s, &w);
    let b = rhs(n, k);
    let res1 = pcg_batch_with_min(&op, &pre, &b, 1e-9, 5, 500, true);
    // Same batch twice: thread scheduling must not leak into results.
    let res1b = pcg_batch_with_min(&op, &pre, &b, 1e-9, 5, 500, true);
    for j in 0..k {
        assert_eq!(res1.x.col(j), res1b.x.col(j), "rerun col {j} diverged");
        assert_eq!(res1.columns[j].iters, res1b.columns[j].iters);
    }
    // Reversed column order: each column's result must be bitwise
    // identical wherever it sits in the block.
    let b_rev = Mat::from_fn(n, k, |i, j| b.get(i, k - 1 - j));
    let res2 = pcg_batch_with_min(&op, &pre, &b_rev, 1e-9, 5, 500, true);
    for j in 0..k {
        assert_eq!(
            res1.x.col(j),
            res2.x.col(k - 1 - j),
            "col {j}: batch position changed the solution"
        );
        assert_eq!(res1.columns[j].iters, res2.columns[k - 1 - j].iters);
    }
}

#[test]
fn batch_matches_sequential_with_level_scheduling_forced() {
    // Forcing sched_min_rows = 0 routes every B sweep inside the VIF
    // operator and the VIFDU preconditioner through the level-scheduled
    // pool path; batch/sequential equivalence must be unaffected.
    let n = 60;
    let k = 6;
    let (mut s, w) = setup(n);
    s.resid.sched_min_rows = 0;
    let op = OpWPlusPrec { s: &s, w: &w };
    let pre = VifduPrecond::new(&s, &w);
    let b = rhs(n, k);
    let res = pcg_batch_with_min(&op, &pre, &b, 1e-8, 5, 500, true);
    for j in 0..k {
        let want = pcg_with_min(&op, &pre, &b.col(j), 1e-8, 5, 500, true);
        assert_eq!(res.columns[j].iters, want.iters, "col {j}: iters differ");
        assert_eq!(res.columns[j].converged, want.converged, "col {j}");
        for (g, wv) in res.x.col(j).iter().zip(&want.x) {
            assert!(
                (g - wv).abs() < 1e-8 * (1.0 + wv.abs()),
                "col {j}: scheduled solution {g} vs {wv}"
            );
        }
    }
}

#[test]
fn batched_slq_matches_sequential_reference_on_vif_system() {
    let n = 80;
    let (s, w) = setup(n);
    let op = OpWPlusPrec { s: &s, w: &w };
    let pre = VifduPrecond::new(&s, &w);
    let ell = 12;
    let (tol, max_cg) = (1e-8, 500);
    // Sequential reference: the seed's per-probe loop on the same stream.
    let mut rng = Rng::seed_from(5);
    let mut acc = 0.0;
    for _ in 0..ell {
        let z = pre.sample(&mut rng);
        let pinv_z = pre.solve(&z);
        let norm2 = dot(&z, &pinv_z);
        let res = pcg_with_min(&op, &pre, &z, tol, 25.min(n), max_cg, true);
        let t = res.tridiag.expect("tridiag");
        acc += norm2 * t.quadrature(|lam| lam.max(1e-300).ln());
    }
    let want = acc / ell as f64 + pre.logdet();
    let mut rng = Rng::seed_from(5);
    let run = slq_logdet(&op, &pre, ell, &mut rng, tol, max_cg);
    assert!(
        (run.logdet - want).abs() < 1e-6 * (1.0 + want.abs()),
        "batched {} vs sequential {want}",
        run.logdet
    );
}
