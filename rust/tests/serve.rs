//! Serving-engine tests: micro-batched served predictions must match the
//! single-threaded `predict_with_plan` reference to ≤ 1e-12, and
//! generation swaps under concurrent traffic (readers hammering
//! `predict` while a writer `append_points` + publishes) must never
//! panic or serve a mixed generation.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use vifgp::kernels::{ArdMatern, Smoothness};
use vifgp::likelihoods::Likelihood;
use vifgp::rng::Rng;
use vifgp::serve::{ServeEngine, ServeModel, ServeOptions};
use vifgp::testing::random_points;
use vifgp::vecchia::neighbors::NeighborSelection;
use vifgp::vif::gaussian::{GaussianParams, VifRegression};
use vifgp::vif::laplace::{SolveMode, VifLaplaceModel};
use vifgp::vif::{predict, VifConfig};
use vifgp::Mat;

const TOL: f64 = 1e-12;

fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / (1.0 + b.abs())
}

fn make_config(selection: NeighborSelection, seed: u64) -> VifConfig {
    VifConfig {
        smoothness: Smoothness::ThreeHalves,
        num_inducing: 12,
        num_neighbors: 5,
        selection,
        seed,
        ..Default::default()
    }
}

/// Assembled (not optimized — serving only needs a structure) Gaussian
/// model over `n` random 2-d points.
fn make_gaussian(n: usize, selection: NeighborSelection) -> VifRegression {
    let mut rng = Rng::seed_from(42);
    let x = random_points(&mut rng, n, 2);
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let kernel = ArdMatern::new(1.1, vec![0.4, 0.5], Smoothness::ThreeHalves);
    let mut model =
        VifRegression::new(x, y, make_config(selection, 7), GaussianParams { kernel, noise: 0.1 });
    model.assemble();
    model
}

fn query_points(np: usize) -> Mat {
    let mut rng = Rng::seed_from(1234);
    random_points(&mut rng, np, 2)
}

/// Served predictions (micro-batched, concurrent clients) must equal the
/// one-shot batched reference bit-for-bit (≤ 1e-12): the snapshot's
/// cached cover tree makes every micro-batch select the same
/// conditioning sets as the single large reference call, and the numeric
/// pass is per-point independent.
fn check_served_matches_reference(selection: NeighborSelection) {
    let model = make_gaussian(130, selection);
    let xq = query_points(96);
    let plan = model.build_predict_plan(&xq);
    let (mean_ref, var_ref) = model.predict_with_plan(&xq, &plan);

    let snapshot = Arc::new(model.snapshot());
    // Sanity: the snapshot's own batched read path matches first.
    let (mean_snap, var_snap) = snapshot.predict(&xq);
    for i in 0..xq.rows() {
        assert!(rel_diff(mean_snap[i], mean_ref[i]) < TOL, "snapshot mean {i}");
        assert!(rel_diff(var_snap[i], var_ref[i]) < TOL, "snapshot var {i}");
    }

    let engine = ServeEngine::start(
        snapshot,
        ServeOptions { max_batch: 16, batch_window: Duration::from_micros(300) },
    );
    let clients = 8;
    let results: Mutex<Vec<(usize, f64, f64)>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for t in 0..clients {
            let engine = &engine;
            let xq = &xq;
            let results = &results;
            scope.spawn(move || {
                let mut i = t;
                while i < xq.rows() {
                    let p = engine.predict(xq.row(i)).expect("serve request failed");
                    results.lock().unwrap().push((i, p.mean, p.var));
                    i += clients;
                }
            });
        }
    });
    let results = results.into_inner().unwrap();
    assert_eq!(results.len(), xq.rows());
    for (i, mean, var) in results {
        assert!(
            rel_diff(mean, mean_ref[i]) < TOL,
            "served mean {i}: {mean} vs {} ({selection:?})",
            mean_ref[i]
        );
        assert!(
            rel_diff(var, var_ref[i]) < TOL,
            "served var {i}: {var} vs {} ({selection:?})",
            var_ref[i]
        );
    }
    let report = engine.metrics().report();
    assert_eq!(report.requests, xq.rows() as u64);
    assert!(report.batches >= 1 && report.batches <= report.requests);
    assert!(report.p50_latency_us <= report.p99_latency_us);
}

#[test]
fn served_matches_reference_cover_tree() {
    check_served_matches_reference(NeighborSelection::CorrelationCoverTree);
}

#[test]
fn served_matches_reference_brute_force() {
    check_served_matches_reference(NeighborSelection::CorrelationBruteForce);
}

/// Laplace snapshots serve the latent mean and deterministic variance of
/// the shared batched pipeline (the stochastic correction stays on the
/// offline path).
#[test]
fn laplace_snapshot_matches_deterministic_reference() {
    let n = 110;
    let mut rng = Rng::seed_from(5);
    let x = random_points(&mut rng, n, 2);
    let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
    let kernel = ArdMatern::new(0.9, vec![0.35, 0.5], Smoothness::ThreeHalves);
    let mut model = VifLaplaceModel::new(
        x,
        y,
        make_config(NeighborSelection::CorrelationCoverTree, 3),
        SolveMode::Cholesky,
        kernel,
        Likelihood::BernoulliLogit,
    );
    model.assemble();
    model.refresh_state();

    let xq = query_points(64);
    let plan = model.build_predict_plan(&xq);
    let s = model.structure.as_ref().unwrap();
    let state = model.state.as_ref().unwrap();
    let blocks = predict::PredictBlocks::compute(s, &model.kernel, &xq, &plan, 1e-8);
    let mean_ref = predict::posterior_mean(s, &plan, &blocks, &state.b);
    let var_ref = &blocks.var_det;

    let snapshot = model.snapshot();
    let (mean, var) = snapshot.predict(&xq);
    for i in 0..xq.rows() {
        assert!(rel_diff(mean[i], mean_ref[i]) < TOL, "laplace mean {i}");
        assert!(rel_diff(var[i], var_ref[i]) < TOL, "laplace var {i}");
    }
}

/// Queries with the wrong input dimension get a loud per-request error,
/// not a panic, and don't poison the batch they rode in with.
#[test]
fn dimension_mismatch_is_rejected_per_request() {
    let model = make_gaussian(80, NeighborSelection::CorrelationBruteForce);
    let snapshot: Arc<dyn ServeModel> = Arc::new(model.snapshot());
    let engine = ServeEngine::start(snapshot, ServeOptions::default());
    let err = engine.predict(&[0.5]).unwrap_err();
    assert!(err.contains("dimension"), "unexpected error: {err}");
    // A well-formed query still succeeds afterwards.
    let ok = engine.predict(&[0.5, 0.5]).expect("well-formed query");
    assert!(ok.var.is_finite() && ok.mean.is_finite());
}

/// The swap-under-traffic contract: `readers` client threads hammer the
/// engine while a writer ingests batches and publishes new generations.
/// Every reply must (a) succeed, (b) carry a generation that was
/// actually published (old-complete or new-complete — never a stale-plan
/// panic, never a mixed state), and (c) after the final publish, served
/// results must match the final model's single-threaded reference.
fn check_generation_swap_under_traffic(readers: usize) {
    let mut model = make_gaussian(150, NeighborSelection::CorrelationCoverTree);
    let mut ingest_rng = Rng::seed_from(777);

    let published: Mutex<std::collections::HashSet<u64>> = Mutex::new(Default::default());
    let snapshot = Arc::new(model.snapshot());
    published.lock().unwrap().insert(snapshot.generation());
    let engine = ServeEngine::start(
        snapshot,
        ServeOptions { max_batch: 8, batch_window: Duration::from_micros(100) },
    );
    let xq = query_points(32);
    let done = AtomicBool::new(false);

    std::thread::scope(|scope| {
        let engine = &engine;
        let xq = &xq;
        let done = &done;
        let published = &published;
        for t in 0..readers {
            scope.spawn(move || {
                let mut i = t;
                let mut last_gen = 0u64;
                while !done.load(Ordering::Acquire) {
                    let p = engine
                        .predict(xq.row(i % xq.rows()))
                        .expect("reader request failed during swap");
                    assert!(p.mean.is_finite() && p.var.is_finite());
                    assert!(
                        published.lock().unwrap().contains(&p.generation),
                        "served unpublished generation {}",
                        p.generation
                    );
                    // Batches are dispatched in order against a
                    // monotonically-published state, so one reader never
                    // sees generations go backwards.
                    assert!(p.generation >= last_gen, "generation went backwards");
                    last_gen = p.generation;
                    i += 1;
                }
            });
        }
        // Writer: five ingest rounds, each publishing a new generation.
        for round in 0..5 {
            let xa = random_points(&mut ingest_rng, 6, 2);
            let ya: Vec<f64> = (0..6).map(|_| ingest_rng.normal()).collect();
            model.append_points(&xa, &ya).expect("append failed");
            let snap = Arc::new(model.snapshot());
            // Register before publishing so readers can never observe a
            // generation that isn't in the set.
            published.lock().unwrap().insert(snap.generation());
            engine.publish(snap);
            if round % 2 == 1 {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        std::thread::sleep(Duration::from_millis(5));
        done.store(true, Ordering::Release);
    });

    // After the last publish, serving matches the final model exactly.
    let plan = model.build_predict_plan(&xq);
    let (mean_ref, var_ref) = model.predict_with_plan(&xq, &plan);
    let final_gen = engine.current_generation();
    assert_eq!(final_gen, model.structure.as_ref().unwrap().generation);
    for i in 0..xq.rows() {
        let p = engine.predict(xq.row(i)).expect("post-swap request failed");
        assert_eq!(p.generation, final_gen);
        assert!(rel_diff(p.mean, mean_ref[i]) < TOL, "post-swap mean {i}");
        assert!(rel_diff(p.var, var_ref[i]) < TOL, "post-swap var {i}");
    }
}

#[test]
fn generation_swap_under_traffic_pool_1() {
    check_generation_swap_under_traffic(1);
}

#[test]
fn generation_swap_under_traffic_pool_2() {
    check_generation_swap_under_traffic(2);
}

#[test]
fn generation_swap_under_traffic_pool_8() {
    check_generation_swap_under_traffic(8);
}

/// A [`ServeModel`] wrapper that sleeps before every batched prediction
/// — lets tests pile requests into the queue behind a slow dispatch.
struct SlowModel {
    inner: Arc<dyn ServeModel>,
    delay: Duration,
}

impl ServeModel for SlowModel {
    fn input_dim(&self) -> usize {
        self.inner.input_dim()
    }
    fn generation(&self) -> u64 {
        self.inner.generation()
    }
    fn predict_batch(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        std::thread::sleep(self.delay);
        self.inner.predict_batch(xp)
    }
}

/// Shutdown with the queue still loaded: every waiter gets a reply —
/// requests already queued are served during the drain, anything racing
/// the flag gets the clean shutdown error, and nobody hangs on a
/// dropped channel.
#[test]
fn shutdown_replies_to_every_queued_waiter() {
    let model = make_gaussian(80, NeighborSelection::CorrelationBruteForce);
    let snapshot: Arc<dyn ServeModel> =
        Arc::new(SlowModel { inner: Arc::new(model.snapshot()), delay: Duration::from_millis(10) });
    // max_batch 1 → the first request occupies the dispatcher while the
    // rest pile up in the queue.
    let engine =
        ServeEngine::start(snapshot, ServeOptions { max_batch: 1, batch_window: Duration::ZERO });
    let xq = query_points(8);
    let replies: Mutex<Vec<Result<vifgp::serve::Prediction, String>>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for t in 0..xq.rows() {
            let engine = &engine;
            let xq = &xq;
            let replies = &replies;
            scope.spawn(move || {
                let r = engine.predict(xq.row(t));
                replies.lock().unwrap().push(r);
            });
        }
        // Let the first batch start computing and the rest enqueue, then
        // shut down while the queue is still loaded.
        std::thread::sleep(Duration::from_millis(3));
        engine.shutdown();
    });
    let replies = replies.into_inner().unwrap();
    assert_eq!(replies.len(), xq.rows(), "every waiter must get a reply");
    let mut served = 0;
    for r in replies {
        match r {
            Ok(p) => {
                assert!(p.mean.is_finite() && p.var.is_finite());
                served += 1;
            }
            Err(e) => assert!(e.contains("shut down"), "unexpected error: {e}"),
        }
    }
    assert!(served >= 1, "the queued requests must be served during the drain");
}

/// `batch_window == 0` (serve whatever is queued immediately) under 8
/// contending clients: no request is ever dropped or answered with the
/// wrong value.
#[test]
fn zero_batch_window_under_contention_serves_every_request() {
    let model = make_gaussian(100, NeighborSelection::CorrelationBruteForce);
    let xq = query_points(64);
    let plan = model.build_predict_plan(&xq);
    let (mean_ref, _) = model.predict_with_plan(&xq, &plan);
    let snapshot: Arc<dyn ServeModel> = Arc::new(model.snapshot());
    let engine =
        ServeEngine::start(snapshot, ServeOptions { max_batch: 8, batch_window: Duration::ZERO });
    let clients = 8;
    let rounds = 5;
    std::thread::scope(|scope| {
        for t in 0..clients {
            let engine = &engine;
            let xq = &xq;
            let mean_ref = &mean_ref;
            scope.spawn(move || {
                for _ in 0..rounds {
                    let mut i = t;
                    while i < xq.rows() {
                        let p = engine.predict(xq.row(i)).expect("zero-window request dropped");
                        assert!(rel_diff(p.mean, mean_ref[i]) < TOL, "zero-window mean {i}");
                        i += clients;
                    }
                }
            });
        }
    });
    let report = engine.metrics().report();
    assert_eq!(report.requests, (xq.rows() * rounds) as u64);
    assert_eq!(report.quarantined_requests, 0);
    assert_eq!(report.health, vifgp::serve::Health::Healthy);
}

/// Shutdown drains the queue: every request enqueued before shutdown
/// still gets a reply, and late requests get a clean error.
#[test]
fn shutdown_drains_and_rejects_late_requests() {
    let model = make_gaussian(80, NeighborSelection::CorrelationBruteForce);
    let snapshot: Arc<dyn ServeModel> = Arc::new(model.snapshot());
    let engine = ServeEngine::start(
        snapshot,
        ServeOptions { max_batch: 4, batch_window: Duration::from_micros(50) },
    );
    let xq = query_points(12);
    for i in 0..xq.rows() {
        engine.predict(xq.row(i)).expect("pre-shutdown request");
    }
    engine.shutdown();
    let err = engine.predict(xq.row(0)).unwrap_err();
    assert!(err.contains("shut down"), "unexpected error: {err}");
}
