//! Modified cover tree for ordered correlation-distance neighbor search
//! (paper §6, Algorithms 3 and 4).
//!
//! Differences from Beygelzimer et al. (2006), following the paper:
//!
//! * **Ordered insertion** — at every level the next knot extracted from a
//!   cover set is the remaining point with the *smallest index*. As a
//!   consequence every descendant of a knot has a larger index than the
//!   knot itself, so an ordered-Vecchia query for point `i` may prune any
//!   child with index `≥ i` together with its entire subtree.
//! * **Bounded metric** — the correlation distance `d_c ∈ [0, 1]`, so the
//!   root radius is `R_max = 1` and level `l` uses `R_l = 2^{−l}`.
//!
//! The metric is supplied through the [`Metric`] trait over point
//! indices, which lets the same tree code serve the residual-process
//! correlation metric of the VIF approximation and the plain
//! kernel-correlation metric of a standalone Vecchia approximation.
//! Plain closures `Fn(usize, usize) -> f64 + Sync` implement [`Metric`]
//! automatically (scalar path only).
//!
//! # Batched metric evaluation
//!
//! Both tree construction (partitioning a cover set against a new knot)
//! and the kNN query (scoring a level's candidate set) evaluate one
//! fixed point against many candidates. [`Metric::dist_batch`] exposes
//! that shape so structured metrics can amortize per-query work: the
//! VIF correlation metric (`vif::CorrelationMetric`) fetches `x_i`/`v_i`
//! once per query, gathers the candidate inputs into a panel, and
//! evaluates the whole batch through the `kernels` panel evaluators plus
//! length-`m` dot-product corrections — no scalar per-pair `rho` calls
//! remain in the search hot loop. The default `dist_batch` is the scalar
//! loop, so closure metrics keep working unchanged.
//!
//! # External queries
//!
//! The ordered-Vecchia pruning rule generalizes to points outside the
//! tree: a query with index `i ≥ n` (any index at least the member
//! count) prunes nothing by ordering and returns the k nearest tree
//! members, provided the metric answers `dist(i, j)` for the external
//! index. Both prediction (`vif::predict`, conditioning test points on
//! training points) and streaming appends (`VifStructure::append`,
//! conditioning each appended point on the pre-existing points only)
//! query a tree built over the base set this way — appended rows never
//! need the tree to be rebuilt or mutated.

/// Metric over point indices `0..n`, bounded by 1, with an optional
/// batched evaluation path (see the module docs).
pub trait Metric: Sync {
    /// Distance between points `i` and `j` (symmetric, in `[0, 1]`).
    fn dist(&self, i: usize, j: usize) -> f64;

    /// Fill `out[t] = dist(i, cand[t])`. Override to amortize per-query
    /// work over the candidate batch; the default is the scalar loop.
    fn dist_batch(&self, i: usize, cand: &[u32], out: &mut [f64]) {
        debug_assert_eq!(cand.len(), out.len());
        for (o, &j) in out.iter_mut().zip(cand) {
            *o = self.dist(i, j as usize);
        }
    }
}

/// Every `Fn(usize, usize) -> f64 + Sync` is a scalar-only [`Metric`].
impl<F: Fn(usize, usize) -> f64 + Sync + ?Sized> Metric for F {
    fn dist(&self, i: usize, j: usize) -> f64 {
        self(i, j)
    }
}

/// Cover tree over points `0..n` under a metric bounded by 1.
pub struct CoverTree {
    /// `children[k]` = knots extracted from `k`'s cover set, ascending.
    children: Vec<Vec<u32>>,
    /// Number of levels (root at level 1).
    depth: usize,
}

/// Per-query scratch buffers, reusable across queries to avoid the
/// per-query allocation + hash-map overhead that dominated the original
/// implementation (§Perf log in EXPERIMENTS.md).
pub struct QueryScratch {
    /// stamp-versioned distance cache: `dist[i]` valid iff `stamp[i] == cur`
    dist: Vec<f64>,
    stamp: Vec<u32>,
    /// membership marker for candidate dedup, same stamping scheme
    member: Vec<u32>,
    /// not-yet-cached candidates awaiting one `dist_batch` call
    pend: Vec<u32>,
    /// batched-distance output buffer matching `pend`
    dbuf: Vec<f64>,
    cur: u32,
}

impl QueryScratch {
    pub fn new(n: usize) -> Self {
        QueryScratch {
            dist: vec![0.0; n],
            stamp: vec![0; n],
            member: vec![0; n],
            pend: Vec::new(),
            dbuf: Vec::new(),
            cur: 0,
        }
    }
}

impl CoverTree {
    /// Build the tree (Algorithm 3). The metric must be symmetric,
    /// nonnegative and `≤ 1`. Cover-set partitioning scores every
    /// remaining point against the freshly extracted knot in one
    /// [`Metric::dist_batch`] call.
    pub fn build(n: usize, metric: &dyn Metric) -> Self {
        let mut children: Vec<Vec<u32>> = vec![vec![]; n];
        if n == 0 {
            return CoverTree { children, depth: 0 };
        }
        // Cover sets of the knots at the *current* level, as (knot, points).
        // Point lists are kept ascending so "smallest index" is the front.
        let mut level_sets: Vec<(u32, Vec<u32>)> = vec![(0, (1..n as u32).collect())];
        let mut depth = 1usize;
        let mut level = 1usize;
        let mut dbuf: Vec<f64> = Vec::new();
        while !level_sets.is_empty() {
            let r_l = 0.5f64.powi(level as i32);
            let mut next_level: Vec<(u32, Vec<u32>)> = Vec::new();
            for (knot, mut cover) in level_sets {
                while !cover.is_empty() {
                    // Extract the smallest-index point as a new knot.
                    let new_knot = cover[0];
                    children[knot as usize].push(new_knot);
                    let rest = &cover[1..];
                    // Partition remaining points by distance to the new knot.
                    dbuf.resize(rest.len(), 0.0);
                    metric.dist_batch(new_knot as usize, rest, &mut dbuf);
                    let mut mine: Vec<u32> = Vec::new();
                    let mut keep: Vec<u32> = Vec::with_capacity(rest.len());
                    for (t, &s) in rest.iter().enumerate() {
                        if dbuf[t] <= r_l {
                            mine.push(s);
                        } else {
                            keep.push(s);
                        }
                    }
                    if !mine.is_empty() {
                        next_level.push((new_knot, mine));
                    }
                    cover = keep;
                }
            }
            if !next_level.is_empty() {
                depth += 1;
            }
            level += 1;
            level_sets = next_level;
        }
        CoverTree { children, depth }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Ordered m_v-nearest-neighbor query (Algorithm 4): the `m_v`
    /// closest points with index `< i` under the tree's metric.
    /// The returned indices are unsorted.
    pub fn knn_ordered(&self, i: usize, m_v: usize, metric: &dyn Metric) -> Vec<u32> {
        let mut scratch = QueryScratch::new(self.children.len());
        self.knn_ordered_with(i, m_v, metric, &mut scratch)
    }

    /// [`Self::knn_ordered`] with caller-provided scratch buffers (the
    /// batch path reuses one `QueryScratch` per worker — see §Perf).
    /// Each level's not-yet-cached candidates are scored through a
    /// single [`Metric::dist_batch`] call.
    pub fn knn_ordered_with(
        &self,
        i: usize,
        m_v: usize,
        metric: &dyn Metric,
        scratch: &mut QueryScratch,
    ) -> Vec<u32> {
        if i == 0 || m_v == 0 {
            return vec![];
        }
        if i <= m_v {
            // N(i) = {0..i-1} for i ≤ m_v (paper's convention).
            return (0..i as u32).collect();
        }
        scratch.cur = scratch.cur.wrapping_add(1);
        if scratch.cur == 0 {
            // stamp wrapped: reset (rare)
            scratch.stamp.iter_mut().for_each(|s| *s = 0);
            scratch.member.iter_mut().for_each(|s| *s = 0);
            scratch.cur = 1;
        }
        let cur = scratch.cur;
        let iu = i as u32;
        let mut q: Vec<u32> = vec![0]; // root = point 0 (< i always here)
        let mut dists: Vec<f64> = Vec::new();
        let mut sorted: Vec<f64> = Vec::new();
        for j in 1..=self.depth {
            // C = Q ∪ {children of Q with index < i}, dedup via stamping.
            let mut c: Vec<u32> = Vec::with_capacity(q.len() * 2);
            for &s in &q {
                if scratch.member[s as usize] != cur {
                    scratch.member[s as usize] = cur;
                    c.push(s);
                }
            }
            for &k in &q {
                for &ch in &self.children[k as usize] {
                    if ch >= iu {
                        break; // children ascending; subtree indices even larger
                    }
                    if scratch.member[ch as usize] != cur {
                        scratch.member[ch as usize] = cur;
                        c.push(ch);
                    }
                }
            }
            // clear membership stamps for the next level (cheap: only |c|)
            for &s in &c {
                scratch.member[s as usize] = cur.wrapping_sub(1);
            }
            // Score the candidates: one batched metric call for every
            // candidate not already in the stamp-versioned cache.
            scratch.pend.clear();
            for &s in &c {
                if scratch.stamp[s as usize] != cur {
                    scratch.pend.push(s);
                }
            }
            if !scratch.pend.is_empty() {
                scratch.dbuf.resize(scratch.pend.len(), 0.0);
                metric.dist_batch(i, &scratch.pend, &mut scratch.dbuf);
                for (t, &s) in scratch.pend.iter().enumerate() {
                    scratch.stamp[s as usize] = cur;
                    scratch.dist[s as usize] = scratch.dbuf[t];
                }
            }
            dists.clear();
            dists.extend(c.iter().map(|&s| scratch.dist[s as usize]));
            // m_v-th smallest distance in C (1 if |C| < m_v).
            let d_mv = if dists.len() < m_v {
                1.0
            } else {
                sorted.clear();
                sorted.extend_from_slice(&dists);
                sorted.select_nth_unstable_by(m_v - 1, |a, b| a.total_cmp(b));
                sorted[m_v - 1]
            };
            let thresh = d_mv + 0.5f64.powi(j as i32 - 1);
            q.clear();
            for (idx, &s) in c.iter().enumerate() {
                if dists[idx] <= thresh {
                    q.push(s);
                }
            }
            if q.len() <= m_v && j >= self.depth {
                break;
            }
        }
        // Brute force the m_v nearest within the candidate set (every
        // survivor's distance is cached — it was scored this level).
        let mut cand: Vec<(f64, u32)> = q
            .into_iter()
            .map(|s| (scratch.dist[s as usize], s))
            .collect();
        if cand.len() > m_v {
            cand.select_nth_unstable_by(m_v - 1, |a, b| a.0.total_cmp(&b.0));
            cand.truncate(m_v);
        }
        cand.into_iter().map(|(_, s)| s).collect()
    }

    /// Total number of parent→child edges (diagnostics).
    pub fn num_edges(&self) -> usize {
        self.children.iter().map(|c| c.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn gauss_metric(x: Vec<(f64, f64)>, ls: f64) -> impl Fn(usize, usize) -> f64 + Sync {
        move |i: usize, j: usize| {
            let (dx, dy) = (x[i].0 - x[j].0, x[i].1 - x[j].1);
            let r2 = (dx * dx + dy * dy) / (ls * ls);
            (1.0f64 - (-0.5 * r2).exp()).sqrt()
        }
    }

    #[test]
    fn every_point_becomes_a_knot_exactly_once() {
        let mut rng = Rng::seed_from(3);
        let n = 200;
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.uniform(), rng.uniform())).collect();
        let metric = gauss_metric(pts, 0.3);
        let tree = CoverTree::build(n, &metric);
        // Edges = n - 1 (every point except the root has exactly one parent).
        assert_eq!(tree.num_edges(), n - 1);
    }

    #[test]
    fn children_have_larger_indices_than_parent() {
        let mut rng = Rng::seed_from(5);
        let n = 150;
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.uniform(), rng.uniform())).collect();
        let metric = gauss_metric(pts, 0.25);
        let tree = CoverTree::build(n, &metric);
        for (k, ch) in tree.children.iter().enumerate() {
            for &c in ch {
                assert!(c as usize > k, "child {c} not after parent {k}");
            }
            // ascending order (needed by the query's early break)
            assert!(ch.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn knn_matches_brute_force() {
        let mut rng = Rng::seed_from(11);
        let n = 250;
        let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.uniform(), rng.uniform())).collect();
        let metric = gauss_metric(pts, 0.2);
        let tree = CoverTree::build(n, &metric);
        for &i in &[10usize, 57, 123, 249] {
            let mut got = tree.knn_ordered(i, 6, &metric);
            got.sort_unstable();
            let mut cand: Vec<(f64, u32)> =
                (0..i).map(|j| (metric(i, j), j as u32)).collect();
            cand.sort_by(|a, b| a.0.total_cmp(&b.0));
            let mut want: Vec<u32> = cand.iter().take(6).map(|&(_, j)| j).collect();
            want.sort_unstable();
            // distances must agree (ties may swap indices)
            let gd: Vec<f64> = got.iter().map(|&j| metric(i, j as usize)).collect();
            let wd: Vec<f64> = want.iter().map(|&j| metric(i, j as usize)).collect();
            let (mut gd, mut wd) = (gd, wd);
            gd.sort_by(f64::total_cmp);
            wd.sort_by(f64::total_cmp);
            for (a, b) in gd.iter().zip(&wd) {
                assert!((a - b).abs() < 1e-12, "i={i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn small_index_queries_return_prefix() {
        let pts: Vec<(f64, f64)> = (0..20).map(|i| (i as f64 / 20.0, 0.0)).collect();
        let metric = gauss_metric(pts, 0.5);
        let tree = CoverTree::build(20, &metric);
        assert_eq!(tree.knn_ordered(0, 5, &metric), Vec::<u32>::new());
        assert_eq!(tree.knn_ordered(3, 5, &metric), vec![0, 1, 2]);
    }
}
