//! Vecchia approximation of the residual process (paper §2.1, Eq. 4).
//!
//! Given a *residual covariance oracle* `ρ(i, j) = Σ_ij − Σ_mi ᵀ Σ_m⁻¹ Σ_mj`
//! (plus an optional error-variance nugget on the diagonal) and ordered
//! conditioning sets `N(i) ⊆ {0..i-1}`, this module builds the sparse
//! triangular factor
//!
//! ```text
//! (Σ̃ˢ)⁻¹ = Bᵀ D⁻¹ B,   B = I − A (strictly lower, rows A_i on N(i)),
//! A_i = ρ_{iN} ρ_{NN}⁻¹,    D_i = ρ_{ii} − A_i ρ_{iN}ᵀ
//! ```
//!
//! and provides the triangular/sparse operations the VIF pipeline needs:
//! products and solves with `B`, `Bᵀ`, and `S = Bᵀ D⁻¹ B`, plus the
//! Appendix-A gradients `∂B/∂θ_p`, `∂D/∂θ_p`.

pub mod neighbors;

use crate::coordinator::parallel_map;
use crate::linalg::{dot, CholeskyFactor, Mat};

/// Oracle for residual covariances and (optionally) their gradients with
/// respect to the packed log-parameters.
pub trait ResidualCov: Sync {
    /// Residual covariance `ρ(i, j)` **without** any nugget.
    fn rho(&self, i: usize, j: usize) -> f64;

    /// Number of packed parameters gradients are taken against.
    fn num_params(&self) -> usize;

    /// Residual covariance and its gradient `∂ρ(i,j)/∂θ_p` for all p.
    fn rho_and_grad(&self, i: usize, j: usize, grad: &mut [f64]) -> f64;
}

/// The sparse Vecchia factor `(B, D)` of the residual process.
#[derive(Clone, Debug, Default)]
pub struct ResidualFactor {
    /// Conditioning sets `N(i)` (ascending indices `< i`).
    pub neighbors: Vec<Vec<u32>>,
    /// Rows `A_i` so that `B[i, N(i)] = −A_i`.
    pub a: Vec<Vec<f64>>,
    /// Conditional variances `D_i > 0`.
    pub d: Vec<f64>,
}

#[derive(Clone)]
struct Row {
    a: Vec<f64>,
    d: f64,
}
impl Default for Row {
    fn default() -> Self {
        Row { a: vec![], d: 1.0 }
    }
}

impl ResidualFactor {
    /// Build `(B, D)` from a residual-covariance oracle.
    ///
    /// `nugget` is added to every diagonal residual covariance (the error
    /// variance σ² for the response-scale Vecchia of §2; zero for the
    /// latent-scale Vecchia of §3). `jitter` guards the small Cholesky
    /// factorizations.
    pub fn build(
        oracle: &dyn ResidualCov,
        neighbors: Vec<Vec<u32>>,
        nugget: f64,
        jitter: f64,
    ) -> Self {
        let n = neighbors.len();
        let rows = parallel_map(n, |i| {
            let nb = &neighbors[i];
            let q = nb.len();
            let rho_ii = oracle.rho(i, i) + nugget;
            if q == 0 {
                return Row { a: vec![], d: rho_ii.max(1e-12) };
            }
            // ρ_NN + nugget I
            let mut c = Mat::zeros(q, q);
            for (a_idx, &ja) in nb.iter().enumerate() {
                c.set(a_idx, a_idx, oracle.rho(ja as usize, ja as usize) + nugget);
                for (b_idx, &jb) in nb.iter().enumerate().take(a_idx) {
                    let v = oracle.rho(ja as usize, jb as usize);
                    c.set(a_idx, b_idx, v);
                    c.set(b_idx, a_idx, v);
                }
            }
            // ρ_iN
            let rho_in: Vec<f64> = nb.iter().map(|&j| oracle.rho(i, j as usize)).collect();
            let chol = CholeskyFactor::new_with_jitter(&c, jitter.max(1e-10))
                .expect("residual block not PD even with jitter");
            let a_i = chol.solve(&rho_in);
            let d_i = rho_ii - dot(&a_i, &rho_in);
            Row { a: a_i, d: d_i.max(1e-12) }
        });
        let mut a = Vec::with_capacity(n);
        let mut d = Vec::with_capacity(n);
        for r in rows {
            a.push(r.a);
            d.push(r.d);
        }
        ResidualFactor { neighbors, a, d }
    }

    pub fn n(&self) -> usize {
        self.d.len()
    }

    /// `w = B v` (unit lower triangular, sparse).
    pub fn mul_b(&self, v: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        (0..n)
            .map(|i| {
                let mut s = v[i];
                for (k, &j) in self.neighbors[i].iter().enumerate() {
                    s -= self.a[i][k] * v[j as usize];
                }
                s
            })
            .collect()
    }

    /// `w = Bᵀ v`.
    pub fn mul_bt(&self, v: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        let mut out = v.to_vec();
        for i in 0..n {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            for (k, &j) in self.neighbors[i].iter().enumerate() {
                out[j as usize] -= self.a[i][k] * vi;
            }
        }
        out
    }

    /// Solve `B x = v` (forward substitution).
    pub fn solve_b(&self, v: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        let mut x = vec![0.0; n];
        for i in 0..n {
            let mut s = v[i];
            for (k, &j) in self.neighbors[i].iter().enumerate() {
                s += self.a[i][k] * x[j as usize];
            }
            x[i] = s;
        }
        x
    }

    /// Solve `Bᵀ x = v` (backward substitution).
    pub fn solve_bt(&self, v: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        let mut x = v.to_vec();
        for i in (0..n).rev() {
            let xi = x[i];
            for (k, &j) in self.neighbors[i].iter().enumerate() {
                x[j as usize] += self.a[i][k] * xi;
            }
        }
        x
    }

    /// `w = S v = Bᵀ D⁻¹ B v` — the residual precision applied to a vector.
    pub fn apply_s(&self, v: &[f64]) -> Vec<f64> {
        let mut w = self.mul_b(v);
        for (wi, di) in w.iter_mut().zip(&self.d) {
            *wi /= di;
        }
        self.mul_bt(&w)
    }

    /// `w = S⁻¹ v = B⁻¹ D B⁻ᵀ v` — the approximated residual covariance.
    pub fn apply_s_inv(&self, v: &[f64]) -> Vec<f64> {
        let mut w = self.solve_bt(v);
        for (wi, di) in w.iter_mut().zip(&self.d) {
            *wi *= di;
        }
        self.solve_b(&w)
    }

    /// Row-wise `B X` for an n×k matrix (columns treated independently).
    pub fn mul_b_mat(&self, x: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(x.rows(), n);
        let k = x.cols();
        let mut out = x.clone();
        for i in 0..n {
            for (t, &j) in self.neighbors[i].iter().enumerate() {
                let a = self.a[i][t];
                let (ri, rj) = (i * k, j as usize * k);
                for c in 0..k {
                    out.data_mut()[ri + c] -= a * x.data()[rj + c];
                }
            }
        }
        out
    }

    /// Row-wise `Bᵀ X` for an n×k matrix.
    pub fn mul_bt_mat(&self, x: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(x.rows(), n);
        let k = x.cols();
        let mut out = x.clone();
        for i in 0..n {
            for (t, &j) in self.neighbors[i].iter().enumerate() {
                let a = self.a[i][t];
                let (ri, rj) = (i * k, j as usize * k);
                for c in 0..k {
                    out.data_mut()[rj + c] -= a * x.data()[ri + c];
                }
            }
        }
        out
    }

    /// Row-wise solve `B X = V`.
    pub fn solve_b_mat(&self, v: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(v.rows(), n);
        let k = v.cols();
        let mut x = v.clone();
        for i in 0..n {
            for (t, &j) in self.neighbors[i].iter().enumerate() {
                let a = self.a[i][t];
                let (ri, rj) = (i * k, j as usize * k);
                for c in 0..k {
                    let add = a * x.data()[rj + c];
                    x.data_mut()[ri + c] += add;
                }
            }
        }
        x
    }

    /// Row-wise solve `Bᵀ X = V`.
    pub fn solve_bt_mat(&self, v: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(v.rows(), n);
        let k = v.cols();
        let mut x = v.clone();
        for i in (0..n).rev() {
            for (t, &j) in self.neighbors[i].iter().enumerate() {
                let a = self.a[i][t];
                let (ri, rj) = (i * k, j as usize * k);
                for c in 0..k {
                    let add = a * x.data()[ri + c];
                    x.data_mut()[rj + c] += add;
                }
            }
        }
        x
    }

    /// `log det Σ̃ˢ = Σ log D_i` (B has unit diagonal).
    pub fn logdet(&self) -> f64 {
        self.d.iter().map(|d| d.ln()).sum()
    }

    /// Sample `x ~ N(0, Σ̃ˢ)`: `x = B⁻¹ D^{1/2} z` for `z ~ N(0, I)`.
    pub fn sample(&self, z: &[f64]) -> Vec<f64> {
        let w: Vec<f64> = z
            .iter()
            .zip(&self.d)
            .map(|(zi, di)| zi * di.sqrt())
            .collect();
        self.solve_b(&w)
    }

    /// Sample `x ~ N(0, S) = N(0, (Σ̃ˢ)⁻¹)`: `x = Bᵀ D^{-1/2} z`.
    pub fn sample_precision(&self, z: &[f64]) -> Vec<f64> {
        let w: Vec<f64> = z
            .iter()
            .zip(&self.d)
            .map(|(zi, di)| zi / di.sqrt())
            .collect();
        self.mul_bt(&w)
    }

    /// Densify `S = Bᵀ D⁻¹ B` (tests / small n only).
    pub fn dense_s(&self) -> Mat {
        let n = self.n();
        let mut s = Mat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = self.apply_s(&e);
            for i in 0..n {
                s.set(i, j, col[i]);
            }
        }
        s
    }

    /// Appendix-A gradients: `∂D_i/∂θ_p` and `∂A_i/∂θ_p` for every
    /// parameter, recomputing the per-point blocks from the oracle.
    ///
    /// Calls `sink(i, dd_i, da_i)` per point, where `dd_i[p]` is the
    /// D-gradient and `da_i[p]` the A-row gradient for parameter `p`.
    /// `d_nugget_param`: index of the parameter whose exponential is the
    /// nugget (the Gaussian error variance, `None` for latent models);
    /// `∂nugget/∂log σ² = σ²` is added on diagonal blocks.
    pub fn grads(
        &self,
        oracle: &dyn ResidualCov,
        nugget: f64,
        d_nugget_param: Option<usize>,
        jitter: f64,
        sink: &(dyn Fn(usize, &[f64], &[Vec<f64>]) + Sync),
    ) {
        let n = self.n();
        let np = oracle.num_params();
        crate::coordinator::parallel_for_chunks(n, |start, end| {
            let mut gbuf = vec![0.0; np];
            for i in start..end {
                let nb = &self.neighbors[i];
                let q = nb.len();
                let a_i = &self.a[i];
                // dρ_ii
                let mut d_rho_ii = vec![0.0; np];
                let _ = oracle.rho_and_grad(i, i, &mut d_rho_ii);
                if let Some(pn) = d_nugget_param {
                    d_rho_ii[pn] += nugget;
                }
                if q == 0 {
                    let da: Vec<Vec<f64>> = (0..np).map(|_| vec![]).collect();
                    sink(i, &d_rho_ii, &da);
                    continue;
                }
                // Blocks ρ_NN (+nugget I), ρ_iN and gradients.
                let mut c = Mat::zeros(q, q);
                let mut dc: Vec<Mat> = (0..np).map(|_| Mat::zeros(q, q)).collect();
                for (ai, &ja) in nb.iter().enumerate() {
                    for (bi, &jb) in nb.iter().enumerate().take(ai + 1) {
                        let v = oracle.rho_and_grad(ja as usize, jb as usize, &mut gbuf);
                        let vd = if ai == bi { v + nugget } else { v };
                        c.set(ai, bi, vd);
                        c.set(bi, ai, vd);
                        for p in 0..np {
                            let mut g = gbuf[p];
                            if ai == bi {
                                if Some(p) == d_nugget_param {
                                    g += nugget;
                                }
                            }
                            dc[p].set(ai, bi, g);
                            dc[p].set(bi, ai, g);
                        }
                    }
                }
                let mut rho_in = vec![0.0; q];
                let mut d_rho_in: Vec<Vec<f64>> = (0..np).map(|_| vec![0.0; q]).collect();
                for (k, &j) in nb.iter().enumerate() {
                    rho_in[k] = oracle.rho_and_grad(i, j as usize, &mut gbuf);
                    for p in 0..np {
                        d_rho_in[p][k] = gbuf[p];
                    }
                }
                let chol = CholeskyFactor::new_with_jitter(&c, jitter.max(1e-10))
                    .expect("residual block not PD in gradient pass");
                // dA_i = (dρ_iN − A_i dρ_NN) ρ_NN⁻¹
                // dD_i = dρ_ii − 2 dρ_iN·A_i + A_i dρ_NN A_iᵀ
                let mut dd = vec![0.0; np];
                let mut da: Vec<Vec<f64>> = Vec::with_capacity(np);
                for p in 0..np {
                    let w = dc[p].matvec(a_i);
                    let rhs: Vec<f64> = d_rho_in[p]
                        .iter()
                        .zip(&w)
                        .map(|(x, y)| x - y)
                        .collect();
                    let dap = chol.solve(&rhs);
                    dd[p] = d_rho_ii[p] - 2.0 * dot(&d_rho_in[p], a_i) + dot(a_i, &w);
                    da.push(dap);
                }
                sink(i, &dd, &da);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny dense SPD "residual covariance" for direct verification.
    struct DenseOracle {
        cov: Mat,
    }
    impl ResidualCov for DenseOracle {
        fn rho(&self, i: usize, j: usize) -> f64 {
            self.cov.get(i, j)
        }
        fn num_params(&self) -> usize {
            0
        }
        fn rho_and_grad(&self, i: usize, j: usize, _g: &mut [f64]) -> f64 {
            self.rho(i, j)
        }
    }

    fn toy_cov(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| {
            let d = (i as f64 - j as f64).abs();
            (-d / 3.0).exp()
        })
    }

    fn all_prev_neighbors(n: usize) -> Vec<Vec<u32>> {
        (0..n).map(|i| (0..i as u32).collect()).collect()
    }

    #[test]
    fn full_conditioning_is_exact() {
        // With N(i) = {0..i-1}, the Vecchia approximation is exact:
        // S = Σ⁻¹ (it is the LDLᵀ factorization of the precision).
        let n = 8;
        let cov = toy_cov(n);
        let oracle = DenseOracle { cov: cov.clone() };
        let f = ResidualFactor::build(&oracle, all_prev_neighbors(n), 0.0, 0.0);
        let chol = CholeskyFactor::new(&cov).unwrap();
        assert!((f.logdet() - chol.logdet()).abs() < 1e-7);
        let v: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let sv = f.apply_s(&v);
        let siv = chol.solve(&v);
        for (a, b) in sv.iter().zip(&siv) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn b_ops_are_consistent() {
        let n = 12;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let nb: Vec<Vec<u32>> = (0..n)
            .map(|i| (i.saturating_sub(3)..i).map(|j| j as u32).collect())
            .collect();
        let f = ResidualFactor::build(&oracle, nb, 0.05, 0.0);
        let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
        let x = f.solve_b(&v);
        let back = f.mul_b(&x);
        for (a, b) in back.iter().zip(&v) {
            assert!((a - b).abs() < 1e-10);
        }
        let x = f.solve_bt(&v);
        let back = f.mul_bt(&x);
        for (a, b) in back.iter().zip(&v) {
            assert!((a - b).abs() < 1e-10);
        }
        // Bᵀ agrees with B through dense reconstruction
        let dense = |f: &ResidualFactor, t: bool| {
            let mut m = Mat::zeros(n, n);
            for j in 0..n {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                let col = if t { f.mul_bt(&e) } else { f.mul_b(&e) };
                for i in 0..n {
                    m.set(i, j, col[i]);
                }
            }
            m
        };
        assert!(dense(&f, true).max_abs_diff(&dense(&f, false).t()) < 1e-14);
    }

    #[test]
    fn s_and_s_inv_are_inverses() {
        let n = 10;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let nb: Vec<Vec<u32>> = (0..n)
            .map(|i| (i.saturating_sub(4)..i).map(|j| j as u32).collect())
            .collect();
        let f = ResidualFactor::build(&oracle, nb, 0.1, 0.0);
        let v: Vec<f64> = (0..n).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let w = f.apply_s_inv(&f.apply_s(&v));
        for (a, b) in w.iter().zip(&v) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn sample_covariance_matches() {
        // Cov of x = B⁻¹ D^{1/2} z should approximate Σ̃ˢ.
        let n = 5;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let f = ResidualFactor::build(&oracle, all_prev_neighbors(n), 0.0, 0.0);
        let mut rng = crate::rng::Rng::seed_from(4);
        let reps = 40_000;
        let mut acc = Mat::zeros(n, n);
        for _ in 0..reps {
            let x = f.sample(&rng.normal_vec(n));
            for i in 0..n {
                for j in 0..n {
                    acc.add_to(i, j, x[i] * x[j]);
                }
            }
        }
        acc.scale(1.0 / reps as f64);
        assert!(acc.max_abs_diff(&toy_cov(n)) < 0.05);
    }

    #[test]
    fn precision_sample_covariance_matches_s() {
        let n = 5;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let f = ResidualFactor::build(&oracle, all_prev_neighbors(n), 0.2, 0.0);
        let s = f.dense_s();
        let mut rng = crate::rng::Rng::seed_from(9);
        let reps = 60_000;
        let mut acc = Mat::zeros(n, n);
        for _ in 0..reps {
            let x = f.sample_precision(&rng.normal_vec(n));
            for i in 0..n {
                for j in 0..n {
                    acc.add_to(i, j, x[i] * x[j]);
                }
            }
        }
        acc.scale(1.0 / reps as f64);
        assert!(acc.max_abs_diff(&s) < 0.1, "diff {}", acc.max_abs_diff(&s));
    }
}
