//! Vecchia approximation of the residual process (paper §2.1, Eq. 4).
//!
//! Given a *residual covariance oracle* `ρ(i, j) = Σ_ij − Σ_mi ᵀ Σ_m⁻¹ Σ_mj`
//! (plus an optional error-variance nugget on the diagonal) and ordered
//! conditioning sets `N(i) ⊆ {0..i-1}`, this module builds the sparse
//! triangular factor
//!
//! ```text
//! (Σ̃ˢ)⁻¹ = Bᵀ D⁻¹ B,   B = I − A (strictly lower, rows A_i on N(i)),
//! A_i = ρ_{iN} ρ_{NN}⁻¹,    D_i = ρ_{ii} − A_i ρ_{iN}ᵀ
//! ```
//!
//! and provides the triangular/sparse operations the VIF pipeline needs:
//! products and solves with `B`, `Bᵀ`, and `S = Bᵀ D⁻¹ B`, plus the
//! Appendix-A gradients `∂B/∂θ_p`, `∂D/∂θ_p`.
//!
//! # Panelized residual-covariance assembly
//!
//! [`ResidualFactor::build`] and [`ResidualFactor::grads`] request each
//! row's conditioning-set blocks (`ρ_NN`, `ρ_iN`, and all parameter
//! gradients) through **one** [`ResidualCov::rho_block`] /
//! [`ResidualCov::rho_and_grad_block`] call instead of `~q²/2` scalar
//! `rho`/`rho_and_grad` calls. Oracles with structure override the block
//! methods — `vif::VifResidualOracle` gathers each row's neighbor panel
//! (inputs, `V`/`E`/`T^p` rows) once into per-worker scratch, evaluates
//! the kernel part through the `kernels` panel evaluators, and applies
//! the low-rank corrections as blocked `m_v×m` SYRK/GEMM rank updates
//! (`linalg::Mat::{syrk_sub_panel, syr2k_sub_panel}`). The trait
//! defaults delegate to the scalar calls, which keeps dense test oracles
//! working and doubles as the equivalence baseline (see
//! `testing::ScalarizedOracle` and perf_hotpath stage 10).
//!
//! # Level-scheduled parallel sweeps
//!
//! The eight `B` kernels (`mul_b`/`mul_bt`/`solve_b`/`solve_bt` and their
//! `_mat` block variants) are the innermost loop of every VIF operator
//! apply and of both preconditioners, so they are parallelized with a
//! *level schedule* computed once at [`ResidualFactor::build`] time:
//!
//! * [`LevelSchedule`] is a topological partition of the row-dependency
//!   DAG induced by the neighbor lists — level 0 holds rows with no
//!   neighbors, and every row's neighbors lie strictly in earlier levels.
//!   Forward substitution (`solve_b*`) walks levels in order, backward
//!   substitution (`solve_bt*`) walks the *same* levels in reverse (if
//!   `j ∈ N(i)` then `level(j) < level(i)`, so the reversed order
//!   satisfies the transposed dependencies). Rows inside one level are
//!   independent and fan out over the shared
//!   [`coordinator::global_pool`] via scoped borrowed jobs.
//! * [`TransposedIndex`] is a CSC-style index of the strictly-lower part
//!   of `B`: for each column `j`, the owning rows `i` with `j ∈ N(i)`
//!   (ascending) and their coefficients `A_i[k]`. It turns every `Bᵀ`
//!   operation into a per-row *gather* instead of a racy scatter, which
//!   makes the parallel sweeps deterministic: each output element is
//!   accumulated by exactly one task in a fixed order, so results are
//!   bit-identical for any pool size (1, 2, 8, ...) and identical to the
//!   sequential path.
//! * Small problems keep a sequential code path: sweeps only fan out
//!   when the factor has at least [`ResidualFactor::sched_min_rows`] rows
//!   (default [`DEFAULT_SCHED_MIN_ROWS`], overridable with the
//!   `VIFGP_SCHED_THRESHOLD` environment variable or the CLI's
//!   `--sched-threshold`), and levels narrower than a small fan-out
//!   width run inline to avoid paying queue overhead on degenerate
//!   (chain-like) schedules. The `_mat` variants additionally tile each
//!   level over column blocks so wide operands spread across workers.
//!
//! The `*_with` kernel variants take an explicit [`SweepExec`] so tests
//! and benches can pin the execution mode (sequential reference vs. a
//! specific pool) regardless of the threshold.

pub mod neighbors;

use crate::coordinator::{self, parallel_map, SyncSlice, ThreadPool};
use crate::linalg::{dot, CholeskyFactor, Mat};
use std::sync::OnceLock;

/// Oracle for residual covariances and (optionally) their gradients with
/// respect to the packed log-parameters.
///
/// Besides the scalar per-pair entry points, the trait exposes *block*
/// methods ([`rho_block`](Self::rho_block),
/// [`rho_and_grad_block`](Self::rho_and_grad_block)) that fill a whole
/// conditioning-set panel at once. The default implementations delegate
/// to the scalar calls — they are the reference the panelized overrides
/// (e.g. `vif::VifResidualOracle`, which routes through the `kernels`
/// panel evaluators and `linalg` SYRK/GEMM rank updates) are tested
/// against, and they keep simple oracles (dense test matrices) working
/// unchanged. [`ResidualFactor::build`] and [`ResidualFactor::grads`]
/// call only the block methods.
pub trait ResidualCov: Sync {
    /// Residual covariance `ρ(i, j)` **without** any nugget.
    fn rho(&self, i: usize, j: usize) -> f64;

    /// Number of packed parameters gradients are taken against.
    fn num_params(&self) -> usize;

    /// Residual covariance and its gradient `∂ρ(i,j)/∂θ_p` for all p.
    fn rho_and_grad(&self, i: usize, j: usize, grad: &mut [f64]) -> f64;

    /// Fill the symmetric `q×q` block `ρ_NN` over the conditioning set
    /// `nb` and the row `ρ_iN` (both **without** nugget — the caller
    /// owns nugget plumbing), returning `ρ(i, i)`. Every output entry is
    /// overwritten. The default delegates to per-pair [`rho`](Self::rho)
    /// calls.
    fn rho_block(&self, i: usize, nb: &[u32], rho_nn: &mut Mat, rho_in: &mut [f64]) -> f64 {
        debug_assert_eq!(rho_nn.rows(), nb.len());
        debug_assert_eq!(rho_nn.cols(), nb.len());
        debug_assert_eq!(rho_in.len(), nb.len());
        for (ai, &ja) in nb.iter().enumerate() {
            rho_nn.set(ai, ai, self.rho(ja as usize, ja as usize));
            for (bi, &jb) in nb.iter().enumerate().take(ai) {
                let v = self.rho(ja as usize, jb as usize);
                rho_nn.set(ai, bi, v);
                rho_nn.set(bi, ai, v);
            }
            rho_in[ai] = self.rho(i, ja as usize);
        }
        self.rho(i, i)
    }

    /// [`rho_block`](Self::rho_block) plus all parameter gradients:
    /// `d_rho_nn[p]` is the `q×q` gradient block for parameter `p`,
    /// `d_rho_in` is `np×q` with row `p` holding `∂ρ_iN/∂θ_p`
    /// contiguously, and `d_rho_ii` (length `np`) is `∂ρ(i,i)/∂θ_p`.
    /// No nugget anywhere; every output entry is overwritten. Returns
    /// `ρ(i, i)`. The default delegates to per-pair
    /// [`rho_and_grad`](Self::rho_and_grad) calls.
    #[allow(clippy::too_many_arguments)]
    fn rho_and_grad_block(
        &self,
        i: usize,
        nb: &[u32],
        rho_nn: &mut Mat,
        rho_in: &mut [f64],
        d_rho_nn: &mut [Mat],
        d_rho_in: &mut Mat,
        d_rho_ii: &mut [f64],
    ) -> f64 {
        let np = self.num_params();
        debug_assert_eq!(d_rho_nn.len(), np);
        debug_assert_eq!(d_rho_in.rows(), np);
        debug_assert_eq!(d_rho_in.cols(), nb.len());
        debug_assert_eq!(d_rho_ii.len(), np);
        let mut g = vec![0.0; np];
        for (ai, &ja) in nb.iter().enumerate() {
            for (bi, &jb) in nb.iter().enumerate().take(ai + 1) {
                let v = self.rho_and_grad(ja as usize, jb as usize, &mut g);
                rho_nn.set(ai, bi, v);
                rho_nn.set(bi, ai, v);
                for (p, &gp) in g.iter().enumerate() {
                    d_rho_nn[p].set(ai, bi, gp);
                    d_rho_nn[p].set(bi, ai, gp);
                }
            }
            rho_in[ai] = self.rho_and_grad(i, ja as usize, &mut g);
            for (p, &gp) in g.iter().enumerate() {
                d_rho_in.set(p, ai, gp);
            }
        }
        self.rho_and_grad(i, i, d_rho_ii)
    }
}

/// Default minimum row count before the `B` sweeps fan out on the global
/// pool (see the module docs). `VIFGP_SCHED_THRESHOLD` overrides it.
pub const DEFAULT_SCHED_MIN_ROWS: usize = 2048;

/// Minimum number of output elements (level width × column count) a
/// sweep dispatch must cover before it fans out to the pool. Per-element
/// work is only a handful of multiply–adds, so narrow levels run inline
/// — a chain-like schedule degrades to the sequential sweep plus only
/// per-level bookkeeping, while wide levels (and the level-free `mul`
/// kernels, whose width is all of `n`) amortize the dispatch cost.
const FANOUT_MIN_WORK: usize = 4096;

/// Minimum rows per fanned job (vector sweeps).
const MIN_JOB_ROWS: usize = 256;

/// Column-block width for the `_mat` sweep tiles (level × column-block).
const MAT_COL_BLOCK: usize = 32;

/// The process-wide scheduling threshold: `VIFGP_SCHED_THRESHOLD` if
/// set, else [`DEFAULT_SCHED_MIN_ROWS`]. Read once. A set-but-unparseable
/// value panics with the same message style as the CLI's
/// `--sched-threshold` flag instead of silently falling back to the
/// default (see the environment-variable table in the crate root docs).
pub fn sched_min_rows_default() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| match std::env::var("VIFGP_SCHED_THRESHOLD") {
        Ok(s) => s.parse::<usize>().unwrap_or_else(|_| {
            panic!("VIFGP_SCHED_THRESHOLD expects a non-negative integer, got `{s}`")
        }),
        Err(_) => DEFAULT_SCHED_MIN_ROWS,
    })
}

/// How a triangular sweep executes: sequentially, or with each level
/// fanned out over a worker pool. Results are bit-identical either way —
/// every output element is a gather accumulated in a fixed order.
#[derive(Clone, Copy)]
pub enum SweepExec<'p> {
    /// Single-threaded reference path.
    Seq,
    /// Fan levels out over (at most) `usize` chunks on the pool.
    Pool(&'p ThreadPool, usize),
}

/// Topological level partition of the row-dependency DAG induced by the
/// conditioning sets: `level(i) = 1 + max_{j ∈ N(i)} level(j)` (0 for
/// rows with no neighbors). Levels list rows in ascending order; together
/// they cover every row exactly once.
#[derive(Clone, Debug, Default)]
pub struct LevelSchedule {
    /// `levels[l]` = rows (ascending) whose neighbors all lie in levels `< l`.
    pub levels: Vec<Vec<u32>>,
}

impl LevelSchedule {
    /// Compute the schedule for ordered conditioning sets (`N(i) ⊆ {0..i-1}`).
    pub fn from_neighbors(neighbors: &[Vec<u32>]) -> Self {
        let n = neighbors.len();
        let mut level = vec![0u32; n];
        let mut num_levels = 0usize;
        for i in 0..n {
            let mut l = 0u32;
            for &j in &neighbors[i] {
                assert!(
                    (j as usize) < i,
                    "neighbor {j} of row {i} is not an earlier row"
                );
                l = l.max(level[j as usize] + 1);
            }
            level[i] = l;
            num_levels = num_levels.max(l as usize + 1);
        }
        let mut levels = vec![Vec::new(); num_levels];
        for (i, &l) in level.iter().enumerate() {
            levels[l as usize].push(i as u32);
        }
        LevelSchedule { levels }
    }

    /// Extend the schedule with appended rows `base..base+k` whose
    /// conditioning sets lie entirely in `0..base` or in earlier appended
    /// rows — the streaming-append path. Each new row is placed at
    /// `level(i) = 1 + max_{j ∈ N(i)} level(j)` (0 for empty sets), which
    /// is exactly where [`from_neighbors`](Self::from_neighbors) would
    /// put it on the extended graph; because appended indices exceed all
    /// existing ones, pushing them at the end keeps every level's
    /// ascending row order, so the extended schedule is **identical**
    /// (not just equivalent) to a from-scratch one — and with it the
    /// parallel sweeps stay bit-identical across pool sizes.
    pub fn extend_leaves(&mut self, new_neighbors: &[Vec<u32>], base: usize) {
        let mut level = vec![0u32; base];
        for (l, rows) in self.levels.iter().enumerate() {
            for &i in rows {
                level[i as usize] = l as u32;
            }
        }
        debug_assert_eq!(
            self.levels.iter().map(Vec::len).sum::<usize>(),
            base,
            "schedule does not cover 0..base"
        );
        level.reserve(new_neighbors.len());
        for (t, nb) in new_neighbors.iter().enumerate() {
            let i = base + t;
            let mut l = 0u32;
            for &j in nb {
                assert!(
                    (j as usize) < i,
                    "neighbor {j} of appended row {i} is not an earlier row"
                );
                l = l.max(level[j as usize] + 1);
            }
            if self.levels.len() <= l as usize {
                self.levels.resize(l as usize + 1, Vec::new());
            }
            self.levels[l as usize].push(i as u32);
            level.push(l);
        }
    }

    /// Number of levels (sweep depth; 0 only for an empty factor).
    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    /// Widest level (peak available parallelism).
    pub fn max_width(&self) -> usize {
        self.levels.iter().map(Vec::len).max().unwrap_or(0)
    }
}

/// CSC-style transposed index of the strictly-lower part of `B`: for each
/// column `j`, the rows `i` with `j ∈ N(i)` (ascending) and the matching
/// coefficients `A_i[k]` (so `B[i, j] = −coef`). `Bᵀ` products and solves
/// gather through this index instead of scattering row by row.
///
/// The sparsity *pattern* (`ptr`/`row`/`pos`) depends only on the
/// neighbor graph; only `coef` carries θ-dependent values. The plan/
/// refresh split (see the `vif` module docs) exploits this: a frozen
/// pattern is reused across every optimizer step and
/// [`refresh_coef`](Self::refresh_coef) rewrites the coefficients in
/// place from updated `A` rows.
#[derive(Clone, Debug, Default)]
pub struct TransposedIndex {
    /// Column extents: entries of column `j` are `ptr[j]..ptr[j+1]`.
    pub ptr: Vec<usize>,
    /// Owning row `i` per entry, ascending within each column.
    pub row: Vec<u32>,
    /// Position `k` of this column inside `N(row)` — so each entry's
    /// coefficient is `a[row][pos]`. Pattern data, θ-independent.
    pub pos: Vec<u32>,
    /// Coefficient `A_i[k]` per entry.
    pub coef: Vec<f64>,
}

impl TransposedIndex {
    /// Build from neighbor lists and their coefficient rows.
    pub fn build(neighbors: &[Vec<u32>], a: &[Vec<f64>]) -> Self {
        let mut idx = Self::pattern(neighbors);
        idx.refresh_coef(a);
        idx
    }

    /// Build only the sparsity pattern (`ptr`/`row`/`pos`) with zeroed
    /// coefficients — for θ-independent plans whose consumers refresh
    /// the coefficients from real rows later.
    pub fn pattern(neighbors: &[Vec<u32>]) -> Self {
        let n = neighbors.len();
        let mut ptr = vec![0usize; n + 1];
        for nb in neighbors {
            for &j in nb {
                ptr[j as usize + 1] += 1;
            }
        }
        for j in 0..n {
            ptr[j + 1] += ptr[j];
        }
        let nnz = ptr[n];
        let mut row = vec![0u32; nnz];
        let mut pos = vec![0u32; nnz];
        let coef = vec![0.0f64; nnz];
        let mut cursor = ptr.clone();
        // Visiting owners in ascending i keeps each column's entries
        // ascending in i, which fixes the gather accumulation order.
        for (i, nb) in neighbors.iter().enumerate() {
            for (k, &j) in nb.iter().enumerate() {
                let c = cursor[j as usize];
                row[c] = i as u32;
                pos[c] = k as u32;
                cursor[j as usize] += 1;
            }
        }
        TransposedIndex { ptr, row, pos, coef }
    }

    /// Grow the CSC pattern in place for appended rows `base..base+k`
    /// (the streaming-append path). Because every appended owner index
    /// exceeds every existing one, each existing column's new entries
    /// belong strictly *after* its current ones, so the result is
    /// **identical** to [`pattern`](Self::pattern) on the extended graph
    /// — including the ascending-owner order that fixes the gather
    /// accumulation order of the `Bᵀ` kernels. Existing coefficients are
    /// preserved; appended entries get zero coefficients until the next
    /// [`refresh_coef`](Self::refresh_coef).
    pub fn append_pattern(&mut self, new_neighbors: &[Vec<u32>], base: usize) {
        let k_new = new_neighbors.len();
        let n = base + k_new;
        assert_eq!(self.ptr.len(), base + 1, "pattern built for a different n");
        let mut add = vec![0usize; n];
        for nb in new_neighbors {
            for &j in nb {
                add[j as usize] += 1;
            }
        }
        let mut ptr = vec![0usize; n + 1];
        for j in 0..n {
            let old = if j < base { self.ptr[j + 1] - self.ptr[j] } else { 0 };
            ptr[j + 1] = ptr[j] + old + add[j];
        }
        let nnz = ptr[n];
        let mut row = vec![0u32; nnz];
        let mut pos = vec![0u32; nnz];
        let mut coef = vec![0.0f64; nnz];
        let mut cursor = vec![0usize; n];
        for j in 0..base {
            let (s, e) = (self.ptr[j], self.ptr[j + 1]);
            let d = ptr[j];
            row[d..d + (e - s)].copy_from_slice(&self.row[s..e]);
            pos[d..d + (e - s)].copy_from_slice(&self.pos[s..e]);
            coef[d..d + (e - s)].copy_from_slice(&self.coef[s..e]);
            cursor[j] = d + (e - s);
        }
        for (j, c) in cursor.iter_mut().enumerate().take(n).skip(base) {
            *c = ptr[j];
        }
        // Visiting appended owners in ascending i keeps each column's
        // entries ascending, exactly as `pattern` would on the full graph.
        for (t, nb) in new_neighbors.iter().enumerate() {
            let i = (base + t) as u32;
            for (k, &j) in nb.iter().enumerate() {
                let c = cursor[j as usize];
                row[c] = i;
                pos[c] = k as u32;
                cursor[j as usize] += 1;
            }
        }
        *self = TransposedIndex { ptr, row, pos, coef };
    }

    /// Rewrite only the coefficients from updated rows `a`, leaving the
    /// pattern untouched — the θ-refresh path. `a` must come from the
    /// same neighbor graph the pattern was built from.
    pub fn refresh_coef(&mut self, a: &[Vec<f64>]) {
        for ((c, &i), &k) in self.coef.iter_mut().zip(&self.row).zip(&self.pos) {
            *c = a[i as usize][k as usize];
        }
    }
}

/// Run `f(start, end)` over chunk ranges of `0..width`. Inline for the
/// sequential exec, narrow widths, or single-worker pools; otherwise the
/// chunks are scoped jobs on the pool. Chunk boundaries never affect
/// results — callers only write disjoint output elements, each computed
/// entirely within one chunk.
fn fan(exec: SweepExec<'_>, width: usize, f: &(dyn Fn(usize, usize) + Sync)) {
    let (pool, workers) = match exec {
        SweepExec::Seq => {
            f(0, width);
            return;
        }
        SweepExec::Pool(pool, workers) => (pool, workers),
    };
    if workers <= 1 || width < FANOUT_MIN_WORK {
        f(0, width);
        return;
    }
    let max_jobs = width / MIN_JOB_ROWS;
    let njobs = (workers * 2).min(max_jobs).max(1);
    let chunk = width.div_ceil(njobs);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..njobs)
        .map(|t| {
            let start = t * chunk;
            let end = (start + chunk).min(width);
            Box::new(move || {
                if start < end {
                    f(start, end);
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool.run_scoped(jobs);
}

/// 2-D variant of [`fan`] for the `_mat` sweeps: tiles `0..items` ×
/// `0..cols` into (item-chunk, column-block) jobs `f(i0, i1, c0, c1)`.
fn fan2(
    exec: SweepExec<'_>,
    items: usize,
    cols: usize,
    f: &(dyn Fn(usize, usize, usize, usize) + Sync),
) {
    let (pool, workers) = match exec {
        SweepExec::Seq => {
            f(0, items, 0, cols);
            return;
        }
        SweepExec::Pool(pool, workers) => (pool, workers),
    };
    if workers <= 1 || items.saturating_mul(cols) < FANOUT_MIN_WORK {
        f(0, items, 0, cols);
        return;
    }
    let col_blocks = cols.div_ceil(MAT_COL_BLOCK).max(1);
    let target = workers * 2;
    // Row chunks of at least 32 items; column blocks supply the rest of
    // the parallelism for wide operands.
    let max_item_jobs = (items / 32).max(1);
    let item_jobs = target.div_ceil(col_blocks).min(max_item_jobs).max(1);
    let chunk = items.div_ceil(item_jobs);
    let cblock = cols.div_ceil(col_blocks);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
        Vec::with_capacity(item_jobs * col_blocks);
    for t in 0..item_jobs {
        for b in 0..col_blocks {
            let (i0, i1) = (t * chunk, ((t + 1) * chunk).min(items));
            let (c0, c1) = (b * cblock, ((b + 1) * cblock).min(cols));
            jobs.push(Box::new(move || {
                if i0 < i1 && c0 < c1 {
                    f(i0, i1, c0, c1);
                }
            }) as Box<dyn FnOnce() + Send + '_>);
        }
    }
    pool.run_scoped(jobs);
}

/// The sparse Vecchia factor `(B, D)` of the residual process, plus the
/// level schedule and transposed index that drive the parallel sweeps.
///
/// Construct through [`build`](Self::build) or
/// [`from_parts`](Self::from_parts) only — the private `schedule` and
/// `bt_index` are derived from `neighbors`/`a` and must stay in sync
/// with them (there is deliberately no `Default` and no field-wise
/// construction from outside this module).
#[derive(Clone, Debug)]
pub struct ResidualFactor {
    /// Conditioning sets `N(i)` (ascending indices `< i`).
    pub neighbors: Vec<Vec<u32>>,
    /// Rows `A_i` so that `B[i, N(i)] = −A_i`.
    pub a: Vec<Vec<f64>>,
    /// Conditional variances `D_i > 0`. Read-only by convention: the
    /// private `inv_d` cache is derived from it at construction, so
    /// mutating `d` in place would silently desync every `D⁻¹` scaling —
    /// rebuild through [`from_parts`](Self::from_parts) instead.
    pub d: Vec<f64>,
    /// Cached reciprocals `1/D_i`, computed once at construction so the
    /// `D⁻¹` scalings in every operator apply (and in
    /// `VifStructure::assemble`) stop allocating a fresh vector.
    inv_d: Vec<f64>,
    /// Topological level partition of the row-dependency DAG.
    schedule: LevelSchedule,
    /// CSC-style index of the strictly-lower part of `B`.
    bt_index: TransposedIndex,
    /// Minimum `n` before sweeps fan out on the global pool; set from
    /// [`sched_min_rows_default`] at build time. Tests force the
    /// scheduled path by setting this to 0.
    pub sched_min_rows: usize,
}

#[derive(Clone)]
struct Row {
    a: Vec<f64>,
    d: f64,
}
impl Default for Row {
    fn default() -> Self {
        Row { a: vec![], d: 1.0 }
    }
}

/// One row of the factor from the oracle: a single panelized
/// [`ResidualCov::rho_block`] call fills `ρ_NN` and `ρ_iN` (gathered
/// neighbor panel + SYRK low-rank correction in the `VifResidualOracle`
/// override; per-pair scalar calls in the default impl), then
/// `A_i = ρ_NN⁻¹ ρ_iN` and `D_i = ρ_ii − A_i·ρ_iN`. Shared by
/// [`ResidualFactor::build`] and [`ResidualFactor::refresh_values`] so
/// a refreshed factor is numerically identical to a freshly built one.
fn compute_row(
    oracle: &dyn ResidualCov,
    i: usize,
    nb: &[u32],
    nugget: f64,
    jitter: f64,
) -> Row {
    let q = nb.len();
    let mut c = Mat::zeros(q, q);
    let mut rho_in = vec![0.0; q];
    let rho_ii = oracle.rho_block(i, nb, &mut c, &mut rho_in) + nugget;
    if q == 0 {
        return Row { a: vec![], d: rho_ii.max(1e-12) };
    }
    c.add_diag(nugget);
    let chol = CholeskyFactor::new_with_jitter(&c, jitter.max(1e-10))
        .expect("residual block not PD even with jitter");
    let a_i = chol.solve(&rho_in);
    let d_i = rho_ii - dot(&a_i, &rho_in);
    Row { a: a_i, d: d_i.max(1e-12) }
}

impl ResidualFactor {
    /// Build `(B, D)` from a residual-covariance oracle.
    ///
    /// `nugget` is added to every diagonal residual covariance (the error
    /// variance σ² for the response-scale Vecchia of §2; zero for the
    /// latent-scale Vecchia of §3). `jitter` guards the small Cholesky
    /// factorizations.
    pub fn build(
        oracle: &dyn ResidualCov,
        neighbors: Vec<Vec<u32>>,
        nugget: f64,
        jitter: f64,
    ) -> Self {
        let (a, d) = ResidualFactor::compute_rows(oracle, &neighbors, nugget, jitter);
        ResidualFactor::from_parts(neighbors, a, d)
    }

    /// The numeric half of [`build`](Self::build): every row's
    /// coefficients `A_i` and conditional variance `D_i` from the
    /// oracle, without any of the symbolic (schedule / transposed-index)
    /// work. Used by [`build`](Self::build) and by the `vif::VifPlan`
    /// assembly path that reuses a precomputed symbolic structure.
    pub fn compute_rows(
        oracle: &dyn ResidualCov,
        neighbors: &[Vec<u32>],
        nugget: f64,
        jitter: f64,
    ) -> (Vec<Vec<f64>>, Vec<f64>) {
        let n = neighbors.len();
        let rows = parallel_map(n, |i| compute_row(oracle, i, &neighbors[i], nugget, jitter));
        let mut a = Vec::with_capacity(n);
        let mut d = Vec::with_capacity(n);
        for r in rows {
            a.push(r.a);
            d.push(r.d);
        }
        (a, d)
    }

    /// [`compute_rows`](Self::compute_rows) for appended rows: row `t` of
    /// `new_neighbors` describes global row `base + t`, so the oracle is
    /// queried at the appended indices while only the new rows' math runs.
    /// Per-row arithmetic is `compute_row`, the same function the build
    /// and refresh paths use — an appended row is bit-identical to the
    /// row a from-scratch build would produce.
    pub fn compute_rows_at(
        oracle: &dyn ResidualCov,
        new_neighbors: &[Vec<u32>],
        base: usize,
        nugget: f64,
        jitter: f64,
    ) -> (Vec<Vec<f64>>, Vec<f64>) {
        let k = new_neighbors.len();
        let rows = parallel_map(k, |t| {
            compute_row(oracle, base + t, &new_neighbors[t], nugget, jitter)
        });
        let mut a = Vec::with_capacity(k);
        let mut d = Vec::with_capacity(k);
        for r in rows {
            a.push(r.a);
            d.push(r.d);
        }
        (a, d)
    }

    /// Append rows to the factor in place — the vecchia layer of the
    /// streaming-append path. The appended rows' conditioning sets must
    /// lie strictly below them (`N(base+t) ⊆ {0..base+t-1}`; the vif
    /// layer restricts them further to pre-existing points). The level
    /// schedule grows through [`LevelSchedule::extend_leaves`], the CSC
    /// pattern through [`TransposedIndex::append_pattern`], and the
    /// coefficients are rewritten through the same
    /// [`TransposedIndex::refresh_coef`] the θ-refresh path uses — the
    /// resulting factor is field-for-field identical to
    /// [`from_parts`](Self::from_parts) on the extended graph.
    pub fn append_rows(
        &mut self,
        new_neighbors: Vec<Vec<u32>>,
        a_new: Vec<Vec<f64>>,
        d_new: Vec<f64>,
    ) {
        let base = self.n();
        let k = new_neighbors.len();
        assert_eq!(a_new.len(), k, "appended coefficient rows / neighbor lists mismatch");
        assert_eq!(d_new.len(), k, "appended diagonal / neighbor lists mismatch");
        for (t, (nb, ai)) in new_neighbors.iter().zip(&a_new).enumerate() {
            assert_eq!(
                ai.len(),
                nb.len(),
                "appended row {}: coefficients / neighbors mismatch",
                base + t
            );
        }
        self.schedule.extend_leaves(&new_neighbors, base);
        self.bt_index.append_pattern(&new_neighbors, base);
        self.neighbors.extend(new_neighbors);
        self.a.extend(a_new);
        self.inv_d.extend(d_new.iter().map(|di| 1.0 / di));
        self.d.extend(d_new);
        self.bt_index.refresh_coef(&self.a);
    }

    /// Assemble a factor from explicit parts, computing the level
    /// schedule and transposed index. Panics if any `N(i)` contains a
    /// non-earlier row or the part lengths disagree.
    pub fn from_parts(neighbors: Vec<Vec<u32>>, a: Vec<Vec<f64>>, d: Vec<f64>) -> Self {
        let n = neighbors.len();
        assert_eq!(a.len(), n, "coefficient rows / neighbor lists mismatch");
        assert_eq!(d.len(), n, "diagonal / neighbor lists mismatch");
        for (i, (nb, ai)) in neighbors.iter().zip(&a).enumerate() {
            assert_eq!(ai.len(), nb.len(), "row {i}: coefficients / neighbors mismatch");
        }
        let schedule = LevelSchedule::from_neighbors(&neighbors);
        let bt_index = TransposedIndex::build(&neighbors, &a);
        let inv_d: Vec<f64> = d.iter().map(|di| 1.0 / di).collect();
        ResidualFactor {
            neighbors,
            a,
            d,
            inv_d,
            schedule,
            bt_index,
            sched_min_rows: sched_min_rows_default(),
        }
    }

    /// [`from_parts`](Self::from_parts), but reusing a previously
    /// computed level schedule and transposed-index *pattern* (e.g. the
    /// ones a `vif::VifPlan` owns) instead of recomputing them from the
    /// graph. The pattern's coefficients are refreshed from `a`; the
    /// caller guarantees `schedule` and `bt_index` were built from this
    /// exact `neighbors` graph (debug-asserted on sizes).
    pub fn from_parts_precomputed(
        neighbors: Vec<Vec<u32>>,
        a: Vec<Vec<f64>>,
        d: Vec<f64>,
        schedule: LevelSchedule,
        mut bt_index: TransposedIndex,
    ) -> Self {
        let n = neighbors.len();
        assert_eq!(a.len(), n, "coefficient rows / neighbor lists mismatch");
        assert_eq!(d.len(), n, "diagonal / neighbor lists mismatch");
        for (i, (nb, ai)) in neighbors.iter().zip(&a).enumerate() {
            assert_eq!(ai.len(), nb.len(), "row {i}: coefficients / neighbors mismatch");
        }
        assert_eq!(bt_index.ptr.len(), n + 1, "pattern built for a different n");
        let nnz: usize = neighbors.iter().map(Vec::len).sum();
        assert_eq!(bt_index.coef.len(), nnz, "pattern built for a different graph");
        debug_assert_eq!(
            schedule.levels.iter().map(Vec::len).sum::<usize>(),
            n,
            "schedule built for a different graph"
        );
        bt_index.refresh_coef(&a);
        let inv_d: Vec<f64> = d.iter().map(|di| 1.0 / di).collect();
        ResidualFactor {
            neighbors,
            a,
            d,
            inv_d,
            schedule,
            bt_index,
            sched_min_rows: sched_min_rows_default(),
        }
    }

    /// θ-refresh: recompute every row's coefficients `A_i` and
    /// conditional variance `D_i` from `oracle` **in place** — the same
    /// per-row math as [`build`](Self::build), written into the existing
    /// row buffers — then refresh the cached reciprocals and the
    /// transposed-index coefficients. The neighbor graph, the level
    /// schedule, and the `Bᵀ` sparsity pattern are untouched (they are
    /// θ-independent).
    pub fn refresh_values(&mut self, oracle: &dyn ResidualCov, nugget: f64, jitter: f64) {
        let n = self.n();
        {
            let neighbors = &self.neighbors;
            let a_ptr = SyncSlice(self.a.as_mut_ptr());
            let d_ptr = SyncSlice(self.d.as_mut_ptr());
            let a_ptr = &a_ptr;
            let d_ptr = &d_ptr;
            coordinator::parallel_for_chunks(n, |start, end| {
                for i in start..end {
                    let row = compute_row(oracle, i, &neighbors[i], nugget, jitter);
                    // SAFETY: each row index is written by exactly one
                    // chunk; `neighbors` is only read.
                    unsafe {
                        (*a_ptr.get().add(i)).copy_from_slice(&row.a);
                        *d_ptr.get().add(i) = row.d;
                    }
                }
            });
        }
        for (inv, di) in self.inv_d.iter_mut().zip(&self.d) {
            *inv = 1.0 / di;
        }
        self.bt_index.refresh_coef(&self.a);
    }

    /// Cached `1/D_i` (valid for the `d` the factor was built with).
    pub fn inv_d(&self) -> &[f64] {
        &self.inv_d
    }

    pub fn n(&self) -> usize {
        self.d.len()
    }

    /// The level schedule computed at build time (read-only; diagnostics
    /// and benches report its depth/width).
    pub fn schedule(&self) -> &LevelSchedule {
        &self.schedule
    }

    /// The execution mode the plain kernel entry points use: scheduled
    /// when the factor is large enough and parallelism is available,
    /// sequential otherwise.
    fn default_exec(&self) -> SweepExec<'static> {
        if self.n() >= self.sched_min_rows && coordinator::num_threads() > 1 {
            SweepExec::Pool(coordinator::global_pool(), coordinator::num_threads())
        } else {
            SweepExec::Seq
        }
    }

    /// `w = B v` (unit lower triangular, sparse).
    pub fn mul_b(&self, v: &[f64]) -> Vec<f64> {
        self.mul_b_with(v, self.default_exec())
    }

    /// [`mul_b`](Self::mul_b) with an explicit execution mode. Rows are
    /// independent gathers, so no level ordering is needed.
    pub fn mul_b_with(&self, v: &[f64], exec: SweepExec<'_>) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        let mut out = vec![0.0; n];
        let optr = SyncSlice(out.as_mut_ptr());
        let optr = &optr;
        fan(exec, n, &|start, end| {
            for i in start..end {
                let mut acc = v[i];
                for (k, &j) in self.neighbors[i].iter().enumerate() {
                    acc -= self.a[i][k] * v[j as usize];
                }
                // SAFETY: each row index is written by exactly one chunk.
                unsafe {
                    *optr.get().add(i) = acc;
                }
            }
        });
        out
    }

    /// `w = Bᵀ v`.
    pub fn mul_bt(&self, v: &[f64]) -> Vec<f64> {
        self.mul_bt_with(v, self.default_exec())
    }

    /// [`mul_bt`](Self::mul_bt) with an explicit execution mode: a gather
    /// per output row through the transposed index (owners ascending, the
    /// same accumulation order as a dense `Bᵀ` product row).
    pub fn mul_bt_with(&self, v: &[f64], exec: SweepExec<'_>) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        let bt = &self.bt_index;
        let mut out = vec![0.0; n];
        let optr = SyncSlice(out.as_mut_ptr());
        let optr = &optr;
        fan(exec, n, &|start, end| {
            for j in start..end {
                let mut acc = v[j];
                for t in bt.ptr[j]..bt.ptr[j + 1] {
                    acc -= bt.coef[t] * v[bt.row[t] as usize];
                }
                // SAFETY: each row index is written by exactly one chunk.
                unsafe {
                    *optr.get().add(j) = acc;
                }
            }
        });
        out
    }

    /// Solve `B x = v` (forward substitution, level-ordered).
    pub fn solve_b(&self, v: &[f64]) -> Vec<f64> {
        self.solve_b_with(v, self.default_exec())
    }

    /// [`solve_b`](Self::solve_b) with an explicit execution mode.
    pub fn solve_b_with(&self, v: &[f64], exec: SweepExec<'_>) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        let mut x = vec![0.0; n];
        if let SweepExec::Seq = exec {
            for i in 0..n {
                let mut acc = v[i];
                for (k, &j) in self.neighbors[i].iter().enumerate() {
                    acc += self.a[i][k] * x[j as usize];
                }
                x[i] = acc;
            }
            return x;
        }
        let xptr = SyncSlice(x.as_mut_ptr());
        let xptr = &xptr;
        for level in &self.schedule.levels {
            let rows = &level[..];
            fan(exec, rows.len(), &|start, end| {
                for &iu in &rows[start..end] {
                    let i = iu as usize;
                    let mut acc = v[i];
                    for (k, &j) in self.neighbors[i].iter().enumerate() {
                        // SAFETY: j lies in an earlier level, fully written
                        // before this level's barrier released.
                        acc += self.a[i][k] * unsafe { *xptr.get().add(j as usize) };
                    }
                    // SAFETY: each row is written by exactly one chunk.
                    unsafe {
                        *xptr.get().add(i) = acc;
                    }
                }
            });
        }
        x
    }

    /// Solve `Bᵀ x = v` (backward substitution, reverse level order).
    pub fn solve_bt(&self, v: &[f64]) -> Vec<f64> {
        self.solve_bt_with(v, self.default_exec())
    }

    /// [`solve_bt`](Self::solve_bt) with an explicit execution mode: a
    /// gather per output row through the transposed index (`x_j = v_j +
    /// Σ coef·x_i` over owners `i > j`), walking levels in reverse.
    pub fn solve_bt_with(&self, v: &[f64], exec: SweepExec<'_>) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        let bt = &self.bt_index;
        let mut x = vec![0.0; n];
        if let SweepExec::Seq = exec {
            for j in (0..n).rev() {
                let mut acc = v[j];
                for t in bt.ptr[j]..bt.ptr[j + 1] {
                    acc += bt.coef[t] * x[bt.row[t] as usize];
                }
                x[j] = acc;
            }
            return x;
        }
        let xptr = SyncSlice(x.as_mut_ptr());
        let xptr = &xptr;
        for level in self.schedule.levels.iter().rev() {
            let rows = &level[..];
            fan(exec, rows.len(), &|start, end| {
                for &ju in &rows[start..end] {
                    let j = ju as usize;
                    let mut acc = v[j];
                    for t in bt.ptr[j]..bt.ptr[j + 1] {
                        // SAFETY: owner rows lie in strictly later levels,
                        // fully written before this level's barrier released.
                        acc += bt.coef[t] * unsafe { *xptr.get().add(bt.row[t] as usize) };
                    }
                    // SAFETY: each row is written by exactly one chunk.
                    unsafe {
                        *xptr.get().add(j) = acc;
                    }
                }
            });
        }
        x
    }

    /// `w = S v = Bᵀ D⁻¹ B v` — the residual precision applied to a vector.
    pub fn apply_s(&self, v: &[f64]) -> Vec<f64> {
        let mut w = self.mul_b(v);
        for (wi, di) in w.iter_mut().zip(&self.inv_d) {
            *wi *= di;
        }
        self.mul_bt(&w)
    }

    /// `w = S⁻¹ v = B⁻¹ D B⁻ᵀ v` — the approximated residual covariance.
    pub fn apply_s_inv(&self, v: &[f64]) -> Vec<f64> {
        let mut w = self.solve_bt(v);
        for (wi, di) in w.iter_mut().zip(&self.d) {
            *wi *= di;
        }
        self.solve_b(&w)
    }

    /// Column-blocked `S⁻¹ V = B⁻¹ D B⁻ᵀ V` — the approximated residual
    /// covariance applied to a block of vectors through the
    /// level-scheduled `_mat` sweeps (one `B`/`Bᵀ` pass over all columns
    /// instead of per-column applies; used by the batched prediction
    /// projections in `vif::predict`).
    pub fn apply_s_inv_mat(&self, v: &Mat) -> Mat {
        let mut w = self.solve_bt_mat(v);
        w.scale_rows(&self.d);
        self.solve_b_mat(&w)
    }

    /// Row-wise `B X` for an n×k matrix (columns treated independently).
    pub fn mul_b_mat(&self, x: &Mat) -> Mat {
        self.mul_b_mat_with(x, self.default_exec())
    }

    /// [`mul_b_mat`](Self::mul_b_mat) with an explicit execution mode.
    pub fn mul_b_mat_with(&self, x: &Mat, exec: SweepExec<'_>) -> Mat {
        let mut out = Mat::zeros(x.rows(), x.cols());
        self.mul_b_mat_into_with(x, &mut out, exec);
        out
    }

    /// [`mul_b_mat`](Self::mul_b_mat) writing into a preallocated output
    /// of the same shape (the θ-refresh path: no allocation per apply).
    pub fn mul_b_mat_into(&self, x: &Mat, out: &mut Mat) {
        self.mul_b_mat_into_with(x, out, self.default_exec())
    }

    /// [`mul_b_mat_into`](Self::mul_b_mat_into) with an explicit
    /// execution mode.
    pub fn mul_b_mat_into_with(&self, x: &Mat, out: &mut Mat, exec: SweepExec<'_>) {
        let n = self.n();
        assert_eq!(x.rows(), n);
        assert_eq!(out.rows(), n);
        assert_eq!(out.cols(), x.cols());
        let k = x.cols();
        out.data_mut().copy_from_slice(x.data());
        if k == 0 {
            return;
        }
        let optr = SyncSlice(out.data_mut().as_mut_ptr());
        let optr = &optr;
        fan2(exec, n, k, &|i0, i1, c0, c1| {
            for i in i0..i1 {
                let ri = i * k;
                for (t, &j) in self.neighbors[i].iter().enumerate() {
                    let a = self.a[i][t];
                    let rj = j as usize * k;
                    for c in c0..c1 {
                        // SAFETY: each (row, column) cell belongs to
                        // exactly one tile; reads go to the input matrix.
                        unsafe {
                            *optr.get().add(ri + c) -= a * x.data()[rj + c];
                        }
                    }
                }
            }
        });
    }

    /// Row-wise `Bᵀ X` for an n×k matrix.
    pub fn mul_bt_mat(&self, x: &Mat) -> Mat {
        self.mul_bt_mat_with(x, self.default_exec())
    }

    /// [`mul_bt_mat`](Self::mul_bt_mat) with an explicit execution mode
    /// (gather per output row through the transposed index).
    pub fn mul_bt_mat_with(&self, x: &Mat, exec: SweepExec<'_>) -> Mat {
        let mut out = Mat::zeros(x.rows(), x.cols());
        self.mul_bt_mat_into_with(x, &mut out, exec);
        out
    }

    /// [`mul_bt_mat`](Self::mul_bt_mat) writing into a preallocated
    /// output of the same shape (the θ-refresh path).
    pub fn mul_bt_mat_into(&self, x: &Mat, out: &mut Mat) {
        self.mul_bt_mat_into_with(x, out, self.default_exec())
    }

    /// [`mul_bt_mat_into`](Self::mul_bt_mat_into) with an explicit
    /// execution mode.
    pub fn mul_bt_mat_into_with(&self, x: &Mat, out: &mut Mat, exec: SweepExec<'_>) {
        let n = self.n();
        assert_eq!(x.rows(), n);
        assert_eq!(out.rows(), n);
        assert_eq!(out.cols(), x.cols());
        let k = x.cols();
        let bt = &self.bt_index;
        out.data_mut().copy_from_slice(x.data());
        if k == 0 {
            return;
        }
        let optr = SyncSlice(out.data_mut().as_mut_ptr());
        let optr = &optr;
        fan2(exec, n, k, &|j0, j1, c0, c1| {
            for j in j0..j1 {
                let rj = j * k;
                for t in bt.ptr[j]..bt.ptr[j + 1] {
                    let a = bt.coef[t];
                    let ri = bt.row[t] as usize * k;
                    for c in c0..c1 {
                        // SAFETY: each (row, column) cell belongs to
                        // exactly one tile; reads go to the input matrix.
                        unsafe {
                            *optr.get().add(rj + c) -= a * x.data()[ri + c];
                        }
                    }
                }
            }
        });
    }

    /// Row-wise solve `B X = V` (level-ordered).
    pub fn solve_b_mat(&self, v: &Mat) -> Mat {
        self.solve_b_mat_with(v, self.default_exec())
    }

    /// [`solve_b_mat`](Self::solve_b_mat) with an explicit execution mode.
    pub fn solve_b_mat_with(&self, v: &Mat, exec: SweepExec<'_>) -> Mat {
        let n = self.n();
        assert_eq!(v.rows(), n);
        let k = v.cols();
        let mut x = v.clone();
        if k == 0 {
            return x;
        }
        if let SweepExec::Seq = exec {
            for i in 0..n {
                for (t, &j) in self.neighbors[i].iter().enumerate() {
                    let a = self.a[i][t];
                    let (ri, rj) = (i * k, j as usize * k);
                    for c in 0..k {
                        let add = a * x.data()[rj + c];
                        x.data_mut()[ri + c] += add;
                    }
                }
            }
            return x;
        }
        let xptr = SyncSlice(x.data_mut().as_mut_ptr());
        let xptr = &xptr;
        for level in &self.schedule.levels {
            let rows = &level[..];
            fan2(exec, rows.len(), k, &|i0, i1, c0, c1| {
                for &iu in &rows[i0..i1] {
                    let i = iu as usize;
                    let ri = i * k;
                    for (t, &j) in self.neighbors[i].iter().enumerate() {
                        let a = self.a[i][t];
                        let rj = j as usize * k;
                        for c in c0..c1 {
                            // SAFETY: neighbor rows lie in earlier levels
                            // (fully written); each (row, column) cell of
                            // this level belongs to exactly one tile.
                            unsafe {
                                *xptr.get().add(ri + c) += a * *xptr.get().add(rj + c);
                            }
                        }
                    }
                }
            });
        }
        x
    }

    /// Row-wise solve `Bᵀ X = V` (reverse level order).
    pub fn solve_bt_mat(&self, v: &Mat) -> Mat {
        self.solve_bt_mat_with(v, self.default_exec())
    }

    /// [`solve_bt_mat`](Self::solve_bt_mat) with an explicit execution
    /// mode (gather per output row through the transposed index).
    pub fn solve_bt_mat_with(&self, v: &Mat, exec: SweepExec<'_>) -> Mat {
        let n = self.n();
        assert_eq!(v.rows(), n);
        let k = v.cols();
        let bt = &self.bt_index;
        let mut x = v.clone();
        if k == 0 {
            return x;
        }
        if let SweepExec::Seq = exec {
            for j in (0..n).rev() {
                let rj = j * k;
                for t in bt.ptr[j]..bt.ptr[j + 1] {
                    let a = bt.coef[t];
                    let ri = bt.row[t] as usize * k;
                    for c in 0..k {
                        let add = a * x.data()[ri + c];
                        x.data_mut()[rj + c] += add;
                    }
                }
            }
            return x;
        }
        let xptr = SyncSlice(x.data_mut().as_mut_ptr());
        let xptr = &xptr;
        for level in self.schedule.levels.iter().rev() {
            let rows = &level[..];
            fan2(exec, rows.len(), k, &|j0, j1, c0, c1| {
                for &ju in &rows[j0..j1] {
                    let j = ju as usize;
                    let rj = j * k;
                    for t in bt.ptr[j]..bt.ptr[j + 1] {
                        let a = bt.coef[t];
                        let ri = bt.row[t] as usize * k;
                        for c in c0..c1 {
                            // SAFETY: owner rows lie in later levels (fully
                            // written); each (row, column) cell of this
                            // level belongs to exactly one tile.
                            unsafe {
                                *xptr.get().add(rj + c) += a * *xptr.get().add(ri + c);
                            }
                        }
                    }
                }
            });
        }
        x
    }

    /// `log det Σ̃ˢ = Σ log D_i` (B has unit diagonal).
    pub fn logdet(&self) -> f64 {
        self.d.iter().map(|d| d.ln()).sum()
    }

    /// Sample `x ~ N(0, Σ̃ˢ)`: `x = B⁻¹ D^{1/2} z` for `z ~ N(0, I)`.
    pub fn sample(&self, z: &[f64]) -> Vec<f64> {
        let w: Vec<f64> = z
            .iter()
            .zip(&self.d)
            .map(|(zi, di)| zi * di.sqrt())
            .collect();
        self.solve_b(&w)
    }

    /// Sample `x ~ N(0, S) = N(0, (Σ̃ˢ)⁻¹)`: `x = Bᵀ D^{-1/2} z`.
    pub fn sample_precision(&self, z: &[f64]) -> Vec<f64> {
        let w: Vec<f64> = z
            .iter()
            .zip(&self.d)
            .map(|(zi, di)| zi / di.sqrt())
            .collect();
        self.mul_bt(&w)
    }

    /// Densify `B = I − A` (tests / small n only).
    pub fn dense_b(&self) -> Mat {
        let n = self.n();
        let mut b = Mat::eye(n);
        for i in 0..n {
            for (k, &j) in self.neighbors[i].iter().enumerate() {
                b.set(i, j as usize, -self.a[i][k]);
            }
        }
        b
    }

    /// Densify `S = Bᵀ D⁻¹ B` (tests / small n only).
    pub fn dense_s(&self) -> Mat {
        let n = self.n();
        let mut s = Mat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = self.apply_s(&e);
            for i in 0..n {
                s.set(i, j, col[i]);
            }
        }
        s
    }

    /// Appendix-A gradients: `∂D_i/∂θ_p` and `∂A_i/∂θ_p` for every
    /// parameter, recomputing the per-point blocks from the oracle via
    /// one [`ResidualCov::rho_and_grad_block`] call per point (panelized
    /// kernel evaluation + small-GEMM low-rank corrections for the VIF
    /// oracle; scalar per-pair fallback for simple oracles).
    ///
    /// Calls `sink(i, dd_i, da_i)` per point, where `dd_i[p]` is the
    /// D-gradient and `da_i[p]` the A-row gradient for parameter `p`.
    /// `d_nugget_param`: index of the parameter whose exponential is the
    /// nugget (the Gaussian error variance, `None` for latent models);
    /// `∂nugget/∂log σ² = σ²` is added on diagonal blocks.
    pub fn grads(
        &self,
        oracle: &dyn ResidualCov,
        nugget: f64,
        d_nugget_param: Option<usize>,
        jitter: f64,
        sink: &(dyn Fn(usize, &[f64], &[Vec<f64>]) + Sync),
    ) {
        let n = self.n();
        let np = oracle.num_params();
        crate::coordinator::parallel_for_chunks(n, |start, end| {
            for i in start..end {
                let nb = &self.neighbors[i];
                let q = nb.len();
                let a_i = &self.a[i];
                // Blocks ρ_NN, ρ_iN, ρ_ii and all parameter gradients in
                // one oracle call (no nugget yet — added below).
                let mut c = Mat::zeros(q, q);
                let mut dc: Vec<Mat> = (0..np).map(|_| Mat::zeros(q, q)).collect();
                let mut rho_in = vec![0.0; q];
                let mut d_rho_in = Mat::zeros(np, q);
                let mut d_rho_ii = vec![0.0; np];
                let _rho_ii = oracle.rho_and_grad_block(
                    i,
                    nb,
                    &mut c,
                    &mut rho_in,
                    &mut dc,
                    &mut d_rho_in,
                    &mut d_rho_ii,
                );
                if let Some(pn) = d_nugget_param {
                    d_rho_ii[pn] += nugget;
                }
                if q == 0 {
                    let da: Vec<Vec<f64>> = (0..np).map(|_| vec![]).collect();
                    sink(i, &d_rho_ii, &da);
                    continue;
                }
                c.add_diag(nugget);
                if let Some(pn) = d_nugget_param {
                    dc[pn].add_diag(nugget);
                }
                let chol = CholeskyFactor::new_with_jitter(&c, jitter.max(1e-10))
                    .expect("residual block not PD in gradient pass");
                // dA_i = (dρ_iN − A_i dρ_NN) ρ_NN⁻¹
                // dD_i = dρ_ii − 2 dρ_iN·A_i + A_i dρ_NN A_iᵀ
                let mut dd = vec![0.0; np];
                let mut da: Vec<Vec<f64>> = Vec::with_capacity(np);
                for p in 0..np {
                    let w = dc[p].matvec(a_i);
                    let drow = d_rho_in.row(p);
                    let rhs: Vec<f64> = drow.iter().zip(&w).map(|(x, y)| x - y).collect();
                    let dap = chol.solve(&rhs);
                    dd[p] = d_rho_ii[p] - 2.0 * dot(drow, a_i) + dot(a_i, &w);
                    da.push(dap);
                }
                sink(i, &dd, &da);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny dense SPD "residual covariance" for direct verification.
    struct DenseOracle {
        cov: Mat,
    }
    impl ResidualCov for DenseOracle {
        fn rho(&self, i: usize, j: usize) -> f64 {
            self.cov.get(i, j)
        }
        fn num_params(&self) -> usize {
            0
        }
        fn rho_and_grad(&self, i: usize, j: usize, _g: &mut [f64]) -> f64 {
            self.rho(i, j)
        }
    }

    fn toy_cov(n: usize) -> Mat {
        Mat::from_fn(n, n, |i, j| {
            let d = (i as f64 - j as f64).abs();
            (-d / 3.0).exp()
        })
    }

    fn all_prev_neighbors(n: usize) -> Vec<Vec<u32>> {
        (0..n).map(|i| (0..i as u32).collect()).collect()
    }

    #[test]
    fn full_conditioning_is_exact() {
        // With N(i) = {0..i-1}, the Vecchia approximation is exact:
        // S = Σ⁻¹ (it is the LDLᵀ factorization of the precision).
        let n = 8;
        let cov = toy_cov(n);
        let oracle = DenseOracle { cov: cov.clone() };
        let f = ResidualFactor::build(&oracle, all_prev_neighbors(n), 0.0, 0.0);
        let chol = CholeskyFactor::new(&cov).unwrap();
        assert!((f.logdet() - chol.logdet()).abs() < 1e-7);
        let v: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let sv = f.apply_s(&v);
        let siv = chol.solve(&v);
        for (a, b) in sv.iter().zip(&siv) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn b_ops_are_consistent() {
        let n = 12;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let nb: Vec<Vec<u32>> = (0..n)
            .map(|i| (i.saturating_sub(3)..i).map(|j| j as u32).collect())
            .collect();
        let f = ResidualFactor::build(&oracle, nb, 0.05, 0.0);
        let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
        let x = f.solve_b(&v);
        let back = f.mul_b(&x);
        for (a, b) in back.iter().zip(&v) {
            assert!((a - b).abs() < 1e-10);
        }
        let x = f.solve_bt(&v);
        let back = f.mul_bt(&x);
        for (a, b) in back.iter().zip(&v) {
            assert!((a - b).abs() < 1e-10);
        }
        // Bᵀ agrees with B through dense reconstruction
        let dense = |f: &ResidualFactor, t: bool| {
            let mut m = Mat::zeros(n, n);
            for j in 0..n {
                let mut e = vec![0.0; n];
                e[j] = 1.0;
                let col = if t { f.mul_bt(&e) } else { f.mul_b(&e) };
                for i in 0..n {
                    m.set(i, j, col[i]);
                }
            }
            m
        };
        assert!(dense(&f, true).max_abs_diff(&dense(&f, false).t()) < 1e-14);
    }

    #[test]
    fn s_and_s_inv_are_inverses() {
        let n = 10;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let nb: Vec<Vec<u32>> = (0..n)
            .map(|i| (i.saturating_sub(4)..i).map(|j| j as u32).collect())
            .collect();
        let f = ResidualFactor::build(&oracle, nb, 0.1, 0.0);
        let v: Vec<f64> = (0..n).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let w = f.apply_s_inv(&f.apply_s(&v));
        for (a, b) in w.iter().zip(&v) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn sample_covariance_matches() {
        // Cov of x = B⁻¹ D^{1/2} z should approximate Σ̃ˢ.
        let n = 5;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let f = ResidualFactor::build(&oracle, all_prev_neighbors(n), 0.0, 0.0);
        let mut rng = crate::rng::Rng::seed_from(4);
        let reps = 40_000;
        let mut acc = Mat::zeros(n, n);
        for _ in 0..reps {
            let x = f.sample(&rng.normal_vec(n));
            for i in 0..n {
                for j in 0..n {
                    acc.add_to(i, j, x[i] * x[j]);
                }
            }
        }
        acc.scale(1.0 / reps as f64);
        assert!(acc.max_abs_diff(&toy_cov(n)) < 0.05);
    }

    #[test]
    fn precision_sample_covariance_matches_s() {
        let n = 5;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let f = ResidualFactor::build(&oracle, all_prev_neighbors(n), 0.2, 0.0);
        let s = f.dense_s();
        let mut rng = crate::rng::Rng::seed_from(9);
        let reps = 60_000;
        let mut acc = Mat::zeros(n, n);
        for _ in 0..reps {
            let x = f.sample_precision(&rng.normal_vec(n));
            for i in 0..n {
                for j in 0..n {
                    acc.add_to(i, j, x[i] * x[j]);
                }
            }
        }
        acc.scale(1.0 / reps as f64);
        assert!(acc.max_abs_diff(&s) < 0.1, "diff {}", acc.max_abs_diff(&s));
    }

    #[test]
    fn level_schedule_of_chain_and_empty_graphs() {
        // Empty graph: one level holding every row.
        let empty: Vec<Vec<u32>> = vec![vec![]; 5];
        let sched = LevelSchedule::from_neighbors(&empty);
        assert_eq!(sched.num_levels(), 1);
        assert_eq!(sched.levels[0], vec![0, 1, 2, 3, 4]);
        assert_eq!(sched.max_width(), 5);
        // Chain N(i) = {i-1}: n levels of one row each.
        let chain: Vec<Vec<u32>> = (0..5)
            .map(|i: u32| if i == 0 { vec![] } else { vec![i - 1] })
            .collect();
        let sched = LevelSchedule::from_neighbors(&chain);
        assert_eq!(sched.num_levels(), 5);
        for (l, rows) in sched.levels.iter().enumerate() {
            assert_eq!(rows.as_slice(), &[l as u32]);
        }
        // Empty factor: zero levels.
        assert_eq!(LevelSchedule::from_neighbors(&[]).num_levels(), 0);
    }

    #[test]
    fn transposed_index_matches_neighbors() {
        let neighbors: Vec<Vec<u32>> = vec![vec![], vec![0], vec![0, 1], vec![1]];
        let a: Vec<Vec<f64>> = vec![vec![], vec![2.0], vec![3.0, 4.0], vec![5.0]];
        let bt = TransposedIndex::build(&neighbors, &a);
        assert_eq!(bt.ptr, vec![0, 2, 4, 4, 4]);
        // Column 0 owned by rows 1 (coef 2) and 2 (coef 3), ascending.
        assert_eq!(&bt.row[0..2], &[1, 2]);
        assert_eq!(&bt.coef[0..2], &[2.0, 3.0]);
        // Column 1 owned by rows 2 (coef 4) and 3 (coef 5).
        assert_eq!(&bt.row[2..4], &[2, 3]);
        assert_eq!(&bt.coef[2..4], &[4.0, 5.0]);
    }

    #[test]
    fn transposed_index_pos_and_refresh_coef() {
        let neighbors: Vec<Vec<u32>> = vec![vec![], vec![0], vec![0, 1], vec![1]];
        let a: Vec<Vec<f64>> = vec![vec![], vec![2.0], vec![3.0, 4.0], vec![5.0]];
        let mut bt = TransposedIndex::build(&neighbors, &a);
        // Column 0 owned by (row 1, k 0) and (row 2, k 0); column 1 by
        // (row 2, k 1) and (row 3, k 0).
        assert_eq!(bt.pos, vec![0, 0, 1, 0]);
        let a2: Vec<Vec<f64>> = vec![vec![], vec![-1.0], vec![-2.0, -3.0], vec![-4.0]];
        bt.refresh_coef(&a2);
        assert_eq!(bt.coef, vec![-1.0, -2.0, -3.0, -4.0]);
    }

    #[test]
    fn refresh_values_matches_rebuild() {
        // Build against one oracle, refresh against another: the factor
        // must equal a from-scratch build for the second oracle, and the
        // transposed-index coefficients must follow (checked through a
        // Bᵀ product).
        let n = 12;
        let o1 = DenseOracle { cov: toy_cov(n) };
        let mut cov2 = toy_cov(n);
        cov2.scale(1.7);
        cov2.add_diag(0.3);
        let o2 = DenseOracle { cov: cov2 };
        let nb: Vec<Vec<u32>> = (0..n)
            .map(|i| (i.saturating_sub(3)..i).map(|j| j as u32).collect())
            .collect();
        let mut f = ResidualFactor::build(&o1, nb.clone(), 0.05, 0.0);
        f.refresh_values(&o2, 0.1, 0.0);
        let fresh = ResidualFactor::build(&o2, nb, 0.1, 0.0);
        for i in 0..n {
            assert!((f.d[i] - fresh.d[i]).abs() < 1e-14, "D[{i}]");
            for (a, b) in f.a[i].iter().zip(&fresh.a[i]) {
                assert!((a - b).abs() < 1e-14, "A[{i}]");
            }
        }
        let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).cos()).collect();
        for (a, b) in f.mul_bt(&v).iter().zip(&fresh.mul_bt(&v)) {
            assert!((a - b).abs() < 1e-14, "Bᵀ product diverged");
        }
        for (a, b) in f.inv_d().iter().zip(fresh.inv_d()) {
            assert!((a - b).abs() < 1e-14, "1/D cache diverged");
        }
    }

    #[test]
    fn extend_leaves_matches_from_neighbors() {
        // Mixed graph: some chains, some empty sets, then appended leaf
        // rows conditioning on arbitrary earlier rows (including other
        // appended rows). The extended schedule must be *identical* to a
        // from-scratch one on the full graph.
        let mut nb: Vec<Vec<u32>> = vec![vec![], vec![0], vec![], vec![1, 2], vec![0, 3]];
        let base = nb.len();
        let appended: Vec<Vec<u32>> = vec![vec![3], vec![], vec![0, 4], vec![5, 6]];
        let mut sched = LevelSchedule::from_neighbors(&nb);
        sched.extend_leaves(&appended, base);
        nb.extend(appended);
        let fresh = LevelSchedule::from_neighbors(&nb);
        assert_eq!(sched.levels, fresh.levels);
    }

    #[test]
    fn append_pattern_matches_pattern() {
        let mut nb: Vec<Vec<u32>> = vec![vec![], vec![0], vec![0, 1], vec![1]];
        let base = nb.len();
        let appended: Vec<Vec<u32>> = vec![vec![0, 3], vec![], vec![1, 4]];
        let mut bt = TransposedIndex::pattern(&nb);
        bt.append_pattern(&appended, base);
        nb.extend(appended);
        let fresh = TransposedIndex::pattern(&nb);
        assert_eq!(bt.ptr, fresh.ptr);
        assert_eq!(bt.row, fresh.row);
        assert_eq!(bt.pos, fresh.pos);
        assert_eq!(bt.coef, fresh.coef); // both all-zero here
    }

    #[test]
    fn append_rows_matches_from_parts() {
        // Split a factor's rows into a prefix build plus two appended
        // batches and require field-for-field identity with a
        // from-scratch build on the full graph — including the schedule,
        // the transposed index, and the sweep outputs.
        let n = 14;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let nb: Vec<Vec<u32>> = (0..n)
            .map(|i| (i.saturating_sub(3)..i).map(|j| j as u32).collect())
            .collect();
        let full = ResidualFactor::build(&oracle, nb.clone(), 0.05, 0.0);
        let base = 9;
        let mut f = ResidualFactor::build(&oracle, nb[..base].to_vec(), 0.05, 0.0);
        for (s, e) in [(base, 12), (12, n)] {
            let batch = nb[s..e].to_vec();
            let (a_new, d_new) =
                ResidualFactor::compute_rows_at(&oracle, &batch, s, 0.05, 0.0);
            f.append_rows(batch, a_new, d_new);
        }
        assert_eq!(f.n(), n);
        assert_eq!(f.neighbors, full.neighbors);
        assert_eq!(f.a, full.a, "appended A rows must be bit-identical");
        assert_eq!(f.d, full.d, "appended D must be bit-identical");
        assert_eq!(f.inv_d, full.inv_d);
        assert_eq!(f.schedule.levels, full.schedule.levels);
        assert_eq!(f.bt_index.ptr, full.bt_index.ptr);
        assert_eq!(f.bt_index.row, full.bt_index.row);
        assert_eq!(f.bt_index.pos, full.bt_index.pos);
        assert_eq!(f.bt_index.coef, full.bt_index.coef);
        let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.4).sin()).collect();
        assert_eq!(f.mul_bt(&v), full.mul_bt(&v));
        assert_eq!(f.apply_s(&v), full.apply_s(&v));
        assert_eq!(f.apply_s_inv(&v), full.apply_s_inv(&v));
    }

    #[test]
    fn dense_b_matches_kernels() {
        let n = 9;
        let oracle = DenseOracle { cov: toy_cov(n) };
        let nb: Vec<Vec<u32>> = (0..n)
            .map(|i| (i.saturating_sub(3)..i).map(|j| j as u32).collect())
            .collect();
        let f = ResidualFactor::build(&oracle, nb, 0.05, 0.0);
        let b = f.dense_b();
        let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.9).sin()).collect();
        let want = b.matvec(&v);
        for (a, w) in f.mul_b(&v).iter().zip(&want) {
            assert!((a - w).abs() < 1e-12);
        }
        let want = b.matvec_t(&v);
        for (a, w) in f.mul_bt(&v).iter().zip(&want) {
            assert!((a - w).abs() < 1e-12);
        }
    }
}
