//! Ordered nearest-neighbor selection for Vecchia conditioning sets.
//!
//! Two strategies (paper §6): plain Euclidean distance in the (possibly
//! length-scale-transformed) input space, and the correlation distance
//! `d_c` on the residual process, searched either brute-force (small n,
//! tests) or through the modified cover tree in [`crate::covertree`].
//!
//! All searches take the metric as a [`Metric`] trait object so that
//! candidate batches flow through [`Metric::dist_batch`] (one panelized
//! evaluation per query/level instead of per-pair scalar calls — see
//! `vif::CorrelationMetric`); plain closures still work through the
//! scalar blanket impl.

use crate::covertree::{CoverTree, Metric};
use crate::linalg::Mat;

/// How Vecchia neighbors are selected (paper §6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NeighborSelection {
    /// `m_v` nearest earlier points under Euclidean distance in the
    /// λ-transformed input space.
    EuclideanTransformed,
    /// `m_v` nearest earlier points under the correlation distance `d_c`
    /// of the residual process, via the modified cover tree.
    CorrelationCoverTree,
    /// Correlation distance by brute force (O(n²); validation only).
    CorrelationBruteForce,
}

/// Brute-force ordered kNN under a generic metric: `N(i)` = the `m_v`
/// smallest `dist(i, j)` over `j < i` (ascending index order in the
/// output). The whole earlier-point prefix is scored with one
/// [`Metric::dist_batch`] call per query.
pub fn brute_force_ordered_knn(n: usize, m_v: usize, metric: &dyn Metric) -> Vec<Vec<u32>> {
    let ids: Vec<u32> = (0..n as u32).collect();
    crate::coordinator::parallel_map(n, |i| {
        let mut dists = vec![0.0; i];
        metric.dist_batch(i, &ids[..i], &mut dists);
        let mut cand: Vec<(f64, u32)> = dists
            .into_iter()
            .zip(ids[..i].iter().copied())
            .collect();
        if cand.len() > m_v {
            cand.select_nth_unstable_by(m_v - 1, |a, b| a.0.total_cmp(&b.0));
            cand.truncate(m_v);
        }
        let mut idx: Vec<u32> = cand.into_iter().map(|(_, j)| j).collect();
        idx.sort_unstable();
        idx
    })
}

/// Ordered kNN in Euclidean metric on λ-scaled inputs (`x` is n×d,
/// `inv_scales[k] = 1/λ_k`). Brute force — used for moderate n and for
/// validating the cover tree.
pub fn euclidean_ordered_knn(x: &Mat, inv_scales: &[f64], m_v: usize) -> Vec<Vec<u32>> {
    let d2 = |i: usize, j: usize| -> f64 {
        x.row(i)
            .iter()
            .zip(x.row(j))
            .zip(inv_scales)
            .map(|((a, b), s)| {
                let u = (a - b) * s;
                u * u
            })
            .sum()
    };
    brute_force_ordered_knn(x.rows(), m_v, &d2)
}

/// Ordered kNN under a bounded metric `d(i,j) ∈ [0,1]` via the modified
/// cover tree (Algorithms 3–4 of the paper). `partitions > 1` splits the
/// data into sequential blocks processed independently (paper §6's
/// parallel variant); neighbors never cross a partition boundary backwards
/// beyond the block start, except that every block's points still may
/// condition on *earlier partitions* through a shared prefix tree when
/// `partitions == 1`.
pub fn covertree_ordered_knn(n: usize, m_v: usize, metric: &dyn Metric) -> Vec<Vec<u32>> {
    let tree = CoverTree::build(n, metric);
    // Chunked queries with reused scratch buffers (see §Perf).
    let mut out: Vec<Vec<u32>> = vec![vec![]; n];
    {
        let out_ptr = crate::coordinator::SyncSlice(out.as_mut_ptr());
        crate::coordinator::parallel_for_chunks(n, |start, end| {
            let mut scratch = crate::covertree::QueryScratch::new(n);
            for i in start..end {
                let mut idx = tree.knn_ordered_with(i, m_v, metric, &mut scratch);
                idx.sort_unstable();
                // SAFETY: disjoint indices per chunk.
                unsafe {
                    *out_ptr.get().add(i) = idx;
                }
            }
        });
    }
    out
}

/// The first `min(i, m_v)` indices `{0..}` — the paper's rule
/// `N(i) = {1..i-1}` for `i ≤ m_v + 1` falls out of both searches; this
/// helper exists for tests.
pub fn prefix_neighbors(n: usize, m_v: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| (0..i.min(m_v)).map(|j| j as u32).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn brute_force_orders_and_truncates() {
        // 1-D points at positions 0, 10, 1, 9, 2 → check N(4) for m_v=2
        let pos = [0.0f64, 10.0, 1.0, 9.0, 2.0];
        let d = move |i: usize, j: usize| (pos[i] - pos[j]).abs();
        let nb = brute_force_ordered_knn(5, 2, &d);
        assert_eq!(nb[0], Vec::<u32>::new());
        assert_eq!(nb[1], vec![0]);
        assert_eq!(nb[2], vec![0, 1]);
        // point 4 at 2.0: nearest two among {0,10,1,9} are 1 (idx 2) and 0 (idx 0)
        assert_eq!(nb[4], vec![0, 2]);
    }

    #[test]
    fn euclidean_respects_scaling() {
        // Two dims; second dim has huge length scale → effectively ignored.
        let x = Mat::from_vec(4, 2, vec![0.0, 0.0, 1.0, 100.0, 2.0, 0.0, 1.1, -100.0]);
        let nb = euclidean_ordered_knn(&x, &[1.0, 1e-9], 1);
        // point 3 at x1=1.1 → nearest in dim-1 is point 1 (x1=1.0)
        assert_eq!(nb[3], vec![1]);
    }

    #[test]
    fn covertree_matches_brute_force_on_random_points() {
        let mut rng = crate::rng::Rng::seed_from(17);
        let n = 300;
        let x = crate::testing::random_points(&mut rng, n, 2);
        // Bounded correlation-style metric from a Gaussian kernel.
        let dist = move |i: usize, j: usize| {
            let mut r2 = 0.0;
            for k in 0..2 {
                let u = (x.get(i, k) - x.get(j, k)) / 0.3;
                r2 += u * u;
            }
            let corr = (-0.5 * r2 as f64).exp();
            (1.0 - corr).sqrt()
        };
        let bf = brute_force_ordered_knn(n, 5, &dist);
        let ct = covertree_ordered_knn(n, 5, &dist);
        let mut mismatches = 0;
        for i in 0..n {
            if bf[i] != ct[i] {
                // Allow ties: verify distance multisets agree instead.
                let db: Vec<f64> = bf[i].iter().map(|&j| dist(i, j as usize)).collect();
                let dc: Vec<f64> = ct[i].iter().map(|&j| dist(i, j as usize)).collect();
                let (mut db, mut dc) = (db, dc);
                db.sort_by(f64::total_cmp);
                dc.sort_by(f64::total_cmp);
                let tied = db
                    .iter()
                    .zip(&dc)
                    .all(|(a, b)| (a - b).abs() < 1e-12);
                if !tied {
                    mismatches += 1;
                }
            }
        }
        assert_eq!(mismatches, 0, "cover tree disagrees with brute force");
    }

    #[test]
    fn prefix_neighbors_shape() {
        let nb = prefix_neighbors(5, 3);
        assert_eq!(nb[0].len(), 0);
        assert_eq!(nb[3], vec![0, 1, 2]);
        assert_eq!(nb[4], vec![0, 1, 2]);
    }
}

/// Index-shifted view of a [`Metric`]: block-local indices `0..len`
/// mapped onto global indices `lo..lo+len`. Keeps the batched path by
/// shifting candidate lists through a per-thread scratch buffer.
struct OffsetMetric<'a> {
    base: &'a dyn Metric,
    lo: usize,
}

impl Metric for OffsetMetric<'_> {
    fn dist(&self, i: usize, j: usize) -> f64 {
        self.base.dist(i + self.lo, j + self.lo)
    }

    fn dist_batch(&self, i: usize, cand: &[u32], out: &mut [f64]) {
        thread_local! {
            static SHIFTED: std::cell::RefCell<Vec<u32>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        SHIFTED.with(|cell| {
            let shifted = &mut *cell.borrow_mut();
            shifted.clear();
            shifted.extend(cand.iter().map(|&j| j + self.lo as u32));
            self.base.dist_batch(i + self.lo, shifted, out);
        });
    }
}

/// Partitioned cover-tree search (paper §6: "partitioning the data set
/// into equally sized, sequentially ordered subsets, allowing for the
/// parallel application of the cover tree algorithm"). Each block builds
/// its own tree and serves its own queries; conditioning sets therefore
/// do not cross block boundaries (the paper's accepted approximation),
/// except that the first `m_v` points of each block condition on the
/// immediately preceding global points so no conditioning set collapses.
pub fn covertree_ordered_knn_partitioned(
    n: usize,
    m_v: usize,
    metric: &dyn Metric,
    partitions: usize,
) -> Vec<Vec<u32>> {
    let partitions = partitions.max(1);
    if partitions == 1 {
        return covertree_ordered_knn(n, m_v, metric);
    }
    let mut out: Vec<Vec<u32>> = vec![vec![]; n];
    let block = n.div_ceil(partitions);
    // Blocks are independent → natural parallel units (one tree each).
    let blocks: Vec<(usize, usize)> = (0..partitions)
        .map(|b| (b * block, ((b + 1) * block).min(n)))
        .filter(|(lo, hi)| lo < hi)
        .collect();
    let results: Vec<Vec<Vec<u32>>> = crate::coordinator::parallel_map(blocks.len(), |bi| {
        let (lo, hi) = blocks[bi];
        let len = hi - lo;
        let local = OffsetMetric { base: metric, lo };
        let tree = CoverTree::build(len, &local);
        let mut scratch = crate::covertree::QueryScratch::new(len);
        (0..len)
            .map(|li| {
                let gi = li + lo;
                if gi < m_v {
                    return (0..gi as u32).collect();
                }
                if li < m_v {
                    // block head: condition on the immediately preceding
                    // global points (crossing the boundary backwards)
                    return ((gi - m_v) as u32..gi as u32).collect();
                }
                let mut idx = tree.knn_ordered_with(li, m_v, &local, &mut scratch);
                for j in idx.iter_mut() {
                    *j += lo as u32;
                }
                idx.sort_unstable();
                idx
            })
            .collect()
    });
    // Move each block's rows into place (no per-set clone).
    for (&(lo, _hi), sets) in blocks.iter().zip(results) {
        for (li, set) in sets.into_iter().enumerate() {
            out[lo + li] = set;
        }
    }
    out
}

#[cfg(test)]
mod partition_tests {
    use super::*;

    #[test]
    fn partitioned_matches_exact_away_from_boundaries() {
        let mut rng = crate::rng::Rng::seed_from(23);
        let n = 400;
        let x = crate::testing::random_points(&mut rng, n, 2);
        let dist = move |i: usize, j: usize| {
            let mut r2 = 0.0;
            for k in 0..2 {
                let u = (x.get(i, k) - x.get(j, k)) / 0.25;
                r2 += u * u;
            }
            (1.0f64 - (-0.5 * r2).exp()).max(0.0).sqrt()
        };
        let exact = covertree_ordered_knn(n, 5, &dist);
        let part = covertree_ordered_knn_partitioned(n, 5, &dist, 4);
        // valid conditioning sets everywhere
        for i in 0..n {
            assert!(part[i].len() <= 5.max(i));
            assert!(part[i].iter().all(|&j| (j as usize) < i));
        }
        // agreement for points whose exact neighbors stay in-block
        let block = n.div_ceil(4);
        let mut agree = 0;
        let mut eligible = 0;
        for i in 0..n {
            let b = i / block;
            let (lo, _) = (b * block, ((b + 1) * block).min(n));
            if i % block < 5 {
                continue;
            }
            if exact[i].iter().all(|&j| (j as usize) >= lo) {
                eligible += 1;
                if exact[i] == part[i] {
                    agree += 1;
                }
            }
        }
        assert!(eligible > 0);
        assert!(
            agree as f64 >= 0.95 * eligible as f64,
            "agree {agree}/{eligible}"
        );
    }

    #[test]
    fn partitioned_single_partition_is_exact() {
        let pos: Vec<f64> = (0..50).map(|i| i as f64 / 50.0).collect();
        let dist = move |i: usize, j: usize| ((pos[i] - pos[j]).abs()).min(1.0);
        let a = covertree_ordered_knn(50, 4, &dist);
        let b = covertree_ordered_knn_partitioned(50, 4, &dist, 1);
        assert_eq!(a, b);
    }
}
