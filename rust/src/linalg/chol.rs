//! Cholesky factorization and triangular solves.
//!
//! The multi-RHS solves (`solve_lower_mat`, `solve_upper_mat`,
//! `solve_mat`) dispatch onto a row-oriented lane path above
//! [`simd::SIMD_MIN_WORK`]: substitution runs in place over contiguous
//! RHS rows with four pivot rows' updates fused per pass
//! ([`simd::axpy4`]), instead of transposing the RHS and solving one
//! column at a time. The transpose-per-column loop stays as the
//! `*_scalar` oracle (see the `linalg` module docs, "Lane backend").

use super::{dot, simd, Mat};

/// Error returned when a matrix is not (numerically) positive definite.
#[derive(Debug)]
pub struct CholeskyError {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "matrix not positive definite at pivot {} (value {:.3e})",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholeskyError {}

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
#[derive(Clone, Debug)]
pub struct CholeskyFactor {
    l: Mat,
}

/// Result of the jitter-escalation path
/// ([`CholeskyFactor::new_with_jitter_tracked`]): the factor, the matrix
/// actually factored, and the diagonal jitter consumed to get there.
#[derive(Clone, Debug)]
pub struct JitteredFactor {
    pub factor: CholeskyFactor,
    pub matrix: Mat,
    /// `0.0` when the input factored cleanly on the first attempt.
    pub jitter: f64,
}

impl CholeskyFactor {
    /// Factorize a symmetric positive definite matrix.
    pub fn new(a: &Mat) -> Result<Self, CholeskyError> {
        assert_eq!(a.rows(), a.cols(), "Cholesky needs a square matrix");
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // s = A[i,j] - sum_k L[i,k] L[j,k]
                let s = a.get(i, j)
                    - dot(&l.row(i)[..j], &l.row(j)[..j]);
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(CholeskyError { pivot: i, value: s });
                    }
                    l.set(i, j, s.sqrt());
                } else {
                    let ljj = l.get(j, j);
                    l.set(i, j, s / ljj);
                }
            }
        }
        Ok(CholeskyFactor { l })
    }

    /// Factorize with escalating diagonal jitter (used when the residual
    /// covariance is numerically on the PSD boundary).
    pub fn new_with_jitter(a: &Mat, base_jitter: f64) -> Result<Self, CholeskyError> {
        Self::new_with_jitter_tracked(a, base_jitter).map(|j| j.factor)
    }

    /// [`Self::new_with_jitter`], additionally returning the matrix that
    /// was actually factored (the input plus any escalated diagonal
    /// jitter). Callers that keep the matrix alongside its factor (e.g.
    /// `vif::LowRank`, whose `Σ_m` is later added into the Woodbury
    /// core) stay exactly consistent with `L Lᵀ` on the retry path.
    pub fn new_with_jitter_mat(a: &Mat, base_jitter: f64) -> Result<(Self, Mat), CholeskyError> {
        Self::new_with_jitter_tracked(a, base_jitter).map(|j| (j.factor, j.matrix))
    }

    /// The single home of the jitter-escalation policy, reporting the
    /// diagonal jitter it consumed (`0.0` on a clean factorization) so
    /// callers can record escalations in the crate failure taxonomy
    /// instead of hiding them. Hooks `faults::chol_should_fail` so chaos
    /// tests can force the ladder to climb deterministically.
    pub fn new_with_jitter_tracked(
        a: &Mat,
        base_jitter: f64,
    ) -> Result<JitteredFactor, CholeskyError> {
        if !crate::faults::chol_should_fail(0.0) {
            if let Ok(f) = Self::new(a) {
                return Ok(JitteredFactor { factor: f, matrix: a.clone(), jitter: 0.0 });
            }
        }
        let mut jitter = base_jitter.max(1e-12);
        // Synthetic placeholder error for the all-attempts-injected case.
        let mut last = CholeskyError { pivot: 0, value: f64::NAN };
        for _ in 0..10 {
            if crate::faults::chol_should_fail(jitter) {
                jitter *= 10.0;
                continue;
            }
            let mut aj = a.clone();
            aj.add_diag(jitter);
            match Self::new(&aj) {
                Ok(f) => return Ok(JitteredFactor { factor: f, matrix: aj, jitter }),
                Err(e) => last = e,
            }
            jitter *= 10.0;
        }
        Err(last)
    }

    /// The lower factor.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// `log det A = 2 Σ log L_ii`.
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l.get(i, i).ln()).sum::<f64>() * 2.0
    }

    /// Solve `L x = b` (forward substitution), in place.
    pub fn solve_lower_in_place(&self, b: &mut [f64]) {
        let n = self.n();
        assert_eq!(b.len(), n);
        for i in 0..n {
            let s = b[i] - dot(&self.l.row(i)[..i], &b[..i]);
            b[i] = s / self.l.get(i, i);
        }
    }

    /// Solve `Lᵀ x = b` (backward substitution), in place.
    pub fn solve_upper_in_place(&self, b: &mut [f64]) {
        let n = self.n();
        assert_eq!(b.len(), n);
        for i in (0..n).rev() {
            let mut s = b[i];
            for k in i + 1..n {
                s -= self.l.get(k, i) * b[k];
            }
            b[i] = s / self.l.get(i, i);
        }
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_lower_in_place(&mut x);
        self.solve_upper_in_place(&mut x);
        x
    }

    /// Solve `A X = B` for a matrix RHS. Dispatches onto the
    /// row-oriented lane path above the work threshold.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        if simd::use_simd(self.n() * self.n() * b.cols()) {
            self.solve_mat_simd(b)
        } else {
            self.solve_mat_scalar(b)
        }
    }

    /// Scalar oracle for [`solve_mat`](Self::solve_mat): column-wise on
    /// the transpose for contiguity.
    pub fn solve_mat_scalar(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let bt = b.t();
        let mut xt = Mat::zeros(b.cols(), n);
        for j in 0..b.cols() {
            let mut col = bt.row(j).to_vec();
            self.solve_lower_in_place(&mut col);
            self.solve_upper_in_place(&mut col);
            xt.row_mut(j).copy_from_slice(&col);
        }
        xt.t()
    }

    /// Lane-backend [`solve_mat`](Self::solve_mat): both substitutions
    /// run in place over contiguous RHS rows, no transposes.
    pub fn solve_mat_simd(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows(), self.n());
        let mut x = b.clone();
        self.trsm_lower_rows(&mut x);
        self.trsm_upper_rows(&mut x);
        x
    }

    /// Solve `L X = B` for a matrix RHS (forward only). Dispatches onto
    /// the row-oriented lane path above the work threshold.
    pub fn solve_lower_mat(&self, b: &Mat) -> Mat {
        if simd::use_simd(self.n() * self.n() * b.cols()) {
            self.solve_lower_mat_simd(b)
        } else {
            self.solve_lower_mat_scalar(b)
        }
    }

    /// Scalar oracle for [`solve_lower_mat`](Self::solve_lower_mat).
    pub fn solve_lower_mat_scalar(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let bt = b.t();
        let mut xt = Mat::zeros(b.cols(), n);
        for j in 0..b.cols() {
            let mut col = bt.row(j).to_vec();
            self.solve_lower_in_place(&mut col);
            xt.row_mut(j).copy_from_slice(&col);
        }
        xt.t()
    }

    /// Lane-backend [`solve_lower_mat`](Self::solve_lower_mat).
    pub fn solve_lower_mat_simd(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows(), self.n());
        let mut x = b.clone();
        self.trsm_lower_rows(&mut x);
        x
    }

    /// Solve `Lᵀ X = B` for a matrix RHS (backward only) — the second
    /// half of [`solve_mat`](Self::solve_mat) for callers that already
    /// hold the forward-solved block. Dispatches onto the row-oriented
    /// lane path above the work threshold.
    pub fn solve_upper_mat(&self, b: &Mat) -> Mat {
        if simd::use_simd(self.n() * self.n() * b.cols()) {
            self.solve_upper_mat_simd(b)
        } else {
            self.solve_upper_mat_scalar(b)
        }
    }

    /// Scalar oracle for [`solve_upper_mat`](Self::solve_upper_mat).
    pub fn solve_upper_mat_scalar(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let bt = b.t();
        let mut xt = Mat::zeros(b.cols(), n);
        for j in 0..b.cols() {
            let mut col = bt.row(j).to_vec();
            self.solve_upper_in_place(&mut col);
            xt.row_mut(j).copy_from_slice(&col);
        }
        xt.t()
    }

    /// Lane-backend [`solve_upper_mat`](Self::solve_upper_mat).
    pub fn solve_upper_mat_simd(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows(), self.n());
        let mut x = b.clone();
        self.trsm_upper_rows(&mut x);
        x
    }

    /// Row-oriented forward substitution `L X = B` in place:
    /// `x_i −= Σ_{k<i} L[i,k]·x_k` as fused 4-row axpys over contiguous
    /// rows, then a division by the pivot (division, not reciprocal
    /// multiply, to match the scalar substitution's rounding). Each
    /// column's result is independent of the RHS width, so column-block
    /// calls reproduce full-RHS entries bitwise.
    fn trsm_lower_rows(&self, x: &mut Mat) {
        let n = self.n();
        let w = x.cols();
        for i in 0..n {
            let li = self.l.row(i);
            let (solved, rest) = x.data_mut().split_at_mut(i * w);
            let xi = &mut rest[..w];
            let i4 = i - i % 4;
            let mut k0 = 0;
            while k0 < i4 {
                simd::axpy4(
                    [-li[k0], -li[k0 + 1], -li[k0 + 2], -li[k0 + 3]],
                    &solved[k0 * w..(k0 + 1) * w],
                    &solved[(k0 + 1) * w..(k0 + 2) * w],
                    &solved[(k0 + 2) * w..(k0 + 3) * w],
                    &solved[(k0 + 3) * w..(k0 + 4) * w],
                    xi,
                );
                k0 += 4;
            }
            for k in i4..i {
                super::axpy(-li[k], &solved[k * w..(k + 1) * w], xi);
            }
            let pivot = li[i];
            for v in xi.iter_mut() {
                *v /= pivot;
            }
        }
    }

    /// Row-oriented backward substitution `Lᵀ X = B` in place (reads the
    /// stored lower factor column-wise: `x_i −= Σ_{k>i} L[k,i]·x_k`).
    fn trsm_upper_rows(&self, x: &mut Mat) {
        let n = self.n();
        let w = x.cols();
        for i in (0..n).rev() {
            let (head, solved) = x.data_mut().split_at_mut((i + 1) * w);
            let xi = &mut head[i * w..];
            let cnt = n - i - 1;
            let c4 = cnt - cnt % 4;
            let mut t0 = 0;
            while t0 < c4 {
                let k = i + 1 + t0;
                simd::axpy4(
                    [
                        -self.l.get(k, i),
                        -self.l.get(k + 1, i),
                        -self.l.get(k + 2, i),
                        -self.l.get(k + 3, i),
                    ],
                    &solved[t0 * w..(t0 + 1) * w],
                    &solved[(t0 + 1) * w..(t0 + 2) * w],
                    &solved[(t0 + 2) * w..(t0 + 3) * w],
                    &solved[(t0 + 3) * w..(t0 + 4) * w],
                    xi,
                );
                t0 += 4;
            }
            for t in c4..cnt {
                super::axpy(-self.l.get(i + 1 + t, i), &solved[t * w..(t + 1) * w], xi);
            }
            let pivot = self.l.get(i, i);
            for v in xi.iter_mut() {
                *v /= pivot;
            }
        }
    }

    /// Explicit inverse `A⁻¹` (small matrices only: Woodbury cores).
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.n()))
    }

    /// `L v` (multiply by lower factor), for sampling `N(0, A)`.
    pub fn mul_lower(&self, v: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(v.len(), n);
        (0..n).map(|i| dot(&self.l.row(i)[..=i], &v[..=i])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize) -> Mat {
        // A = G Gᵀ + n I with a deterministic G.
        let g = Mat::from_fn(n, n, |i, j| ((i * 31 + j * 17) as f64).sin());
        let mut a = g.matmul_nt(&g);
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(8);
        let f = CholeskyFactor::new(&a).unwrap();
        let rec = f.l().matmul_nt(f.l());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_matches_direct() {
        let a = spd(6);
        let f = CholeskyFactor::new(&a).unwrap();
        let b: Vec<f64> = (0..6).map(|i| (i as f64).cos()).collect();
        let x = f.solve(&b);
        let ax = a.matvec(&x);
        for (l, r) in ax.iter().zip(&b) {
            assert!((l - r).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_mat_matches_vector_solves() {
        let a = spd(5);
        let f = CholeskyFactor::new(&a).unwrap();
        let b = Mat::from_fn(5, 3, |i, j| (i + 2 * j) as f64);
        let x = f.solve_mat(&b);
        for j in 0..3 {
            let xj = f.solve(&b.col(j));
            for i in 0..5 {
                assert!((x.get(i, j) - xj[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let f = CholeskyFactor::new(&a).unwrap();
        assert!((f.logdet() - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn not_pd_detected() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(CholeskyFactor::new(&a).is_err());
        // ... but jitter rescues a barely-indefinite matrix.
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0 - 1e-14]);
        assert!(CholeskyFactor::new_with_jitter(&b, 1e-10).is_ok());
    }

    #[test]
    fn tracked_factorization_reports_consumed_jitter() {
        // Clean input: no jitter consumed.
        let a = spd(5);
        let j = CholeskyFactor::new_with_jitter_tracked(&a, 1e-10).unwrap();
        assert_eq!(j.jitter, 0.0);
        assert!(j.matrix.max_abs_diff(&a) < 1e-15);

        // Singular input: the escalation climbs and reports the level
        // that succeeded, and the returned matrix carries that jitter.
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let j = CholeskyFactor::new_with_jitter_tracked(&b, 1e-10).unwrap();
        assert!(j.jitter > 0.0, "singular input must consume jitter");
        assert!((j.matrix.get(0, 0) - (1.0 + j.jitter)).abs() < 1e-15);
        let rec = j.factor.l().matmul_nt(j.factor.l());
        assert!(rec.max_abs_diff(&j.matrix) < 1e-10);
    }

    #[test]
    fn mul_lower_round_trip() {
        let a = spd(7);
        let f = CholeskyFactor::new(&a).unwrap();
        let v: Vec<f64> = (0..7).map(|i| i as f64 - 3.0).collect();
        let mut w = f.mul_lower(&v);
        f.solve_lower_in_place(&mut w);
        for (l, r) in w.iter().zip(&v) {
            assert!((l - r).abs() < 1e-10);
        }
    }
}
