//! Fixed-width SIMD lane backend and register-blocked micro-kernels.
//!
//! # Lane backend
//!
//! The offline registry has no BLAS and `std::simd` is nightly-only, so
//! the lane type is a std-only `[f64; 4]` newtype ([`F64x4`]) whose
//! add/mul/fma/hsum ops are fully unrolled: on any x86-64 baseline the
//! compiler lowers each op to a pair of 128-bit packed instructions (and
//! to single 256-bit ops when built with `-C target-cpu` enabling AVX).
//! `fma` is deliberately `a*b + c` per lane — `f64::mul_add` without the
//! `fma` target feature lowers to a libm call, and the two-rounding form
//! keeps every lane's arithmetic directly comparable to the scalar
//! oracle's.
//!
//! **Packing layout.** All kernels work on the crate's row-major slices
//! directly (shapes are small enough that packed copies don't pay):
//!
//! * [`matmul_nn`] / [`matmul_tn`] hold a 4×4 accumulator tile of `C` in
//!   registers across the whole `k` loop (broadcast-A × vector-B), so a
//!   `C` tile is loaded/stored once instead of once per `k` step. 4×4 is
//!   chosen to fit the 16 xmm registers of baseline x86-64 without
//!   spilling.
//! * [`matmul_nt`], [`dot4`] vectorize over the contiguous `k` axis with
//!   four independent lane accumulators sharing each `A`-row load.
//! * [`axpy4`] fuses four rank-1 row updates per pass over the
//!   destination row (the TRSM and weighted-SYRK building block).
//!
//! **Dispatch threshold.** Public `Mat`/`CholeskyFactor`/`ArdMatern`
//! entry points route onto these kernels when the loop-nest work (the
//! product of its extents) reaches [`SIMD_MIN_WORK`] and the backend is
//! enabled; below it the scalar path runs and results are bit-identical
//! to `VIFGP_SIMD=0`.
//!
//! **Scalar-oracle contract.** Every dispatching entry point keeps its
//! scalar loop as a `*_scalar` method and exposes the lane path as
//! `*_simd` (both valid at every size, remainders included). `VIFGP_SIMD`
//! selects the backend at runtime: unset or `1` → lane backend above the
//! threshold, `0` → scalar everywhere; anything else panics loudly
//! (crate env-knob policy). SIMD ≡ scalar is pinned to ≤1e-12 by the
//! oracle suites (`rust/tests/simd.rs`) — observed differences are
//! reassociation-level (~1e-15 relative).

use std::sync::OnceLock;

/// Lane width of the backend (f64 elements per [`F64x4`]).
pub const LANES: usize = 4;

/// Minimum loop-nest work (product of loop extents) before a dispatching
/// entry point leaves the scalar path. Below this the tile setup costs
/// more than it saves, and small panels stay bit-identical across
/// backends (the existing ≤1e-14 panel unit tests run below it).
pub const SIMD_MIN_WORK: usize = 256;

/// Four f64 lanes with unrolled elementwise ops.
#[derive(Clone, Copy, Debug, Default)]
#[repr(transparent)]
pub struct F64x4(pub [f64; 4]);

impl F64x4 {
    pub const ZERO: F64x4 = F64x4([0.0; 4]);

    #[inline(always)]
    pub fn splat(v: f64) -> Self {
        F64x4([v; 4])
    }

    /// Load four lanes from the front of `s` (`s.len() >= 4`).
    #[inline(always)]
    pub fn load(s: &[f64]) -> Self {
        F64x4([s[0], s[1], s[2], s[3]])
    }

    /// Store the four lanes to the front of `s` (`s.len() >= 4`).
    #[inline(always)]
    pub fn store(self, s: &mut [f64]) {
        s[..4].copy_from_slice(&self.0);
    }

    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        F64x4([
            self.0[0] + o.0[0],
            self.0[1] + o.0[1],
            self.0[2] + o.0[2],
            self.0[3] + o.0[3],
        ])
    }

    #[inline(always)]
    pub fn sub(self, o: Self) -> Self {
        F64x4([
            self.0[0] - o.0[0],
            self.0[1] - o.0[1],
            self.0[2] - o.0[2],
            self.0[3] - o.0[3],
        ])
    }

    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        F64x4([
            self.0[0] * o.0[0],
            self.0[1] * o.0[1],
            self.0[2] * o.0[2],
            self.0[3] * o.0[3],
        ])
    }

    /// `self + a·b` per lane. Plain mul+add, **not** `f64::mul_add`: the
    /// fused form is a libm call without the `fma` target feature, and
    /// two-rounding arithmetic matches the scalar oracle's.
    #[inline(always)]
    pub fn fma(self, a: Self, b: Self) -> Self {
        F64x4([
            self.0[0] + a.0[0] * b.0[0],
            self.0[1] + a.0[1] * b.0[1],
            self.0[2] + a.0[2] * b.0[2],
            self.0[3] + a.0[3] * b.0[3],
        ])
    }

    /// Horizontal sum, pairwise: `(l0+l2) + (l1+l3)`.
    #[inline(always)]
    pub fn hsum(self) -> f64 {
        (self.0[0] + self.0[2]) + (self.0[1] + self.0[3])
    }
}

/// `VIFGP_SIMD`: `1`/unset → lane backend, `0` → scalar oracle. Only
/// those two values are accepted — anything else panics loudly (crate
/// env-knob policy; see the crate-root table).
fn parse_simd(s: &str) -> bool {
    match s.trim() {
        "1" => true,
        "0" => false,
        other => panic!(
            "VIFGP_SIMD must be `0` (scalar oracle) or `1` (lane backend), got `{other}`"
        ),
    }
}

/// Whether the lane backend is enabled (`VIFGP_SIMD`, parsed once).
pub fn simd_enabled() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| match std::env::var("VIFGP_SIMD") {
        Ok(s) => parse_simd(&s),
        Err(_) => true,
    })
}

/// Dispatch predicate used by every SIMD-capable entry point: take the
/// lane path iff the backend is enabled and the loop-nest `work`
/// (product of its extents) reaches [`SIMD_MIN_WORK`].
#[inline]
pub fn use_simd(work: usize) -> bool {
    work >= SIMD_MIN_WORK && simd_enabled()
}

/// `C = A·B` for row-major `A (m×k)`, `B (k×n)` into zero-initialised
/// row-major `out (m×n)`. Register-blocked 4×4 micro-kernel; row/column
/// remainders fall to narrower tiles. Each `C[i][j]` accumulates over
/// ascending `kk` in one chain, so results are independent of tile
/// membership (column-block calls reproduce full-matrix entries bitwise).
pub fn matmul_nn(a: &[f64], b: &[f64], out: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    const MR: usize = 4;
    let n4 = n & !(LANES - 1);
    let m4 = m - m % MR;
    let mut i0 = 0;
    while i0 < m4 {
        let a0 = &a[i0 * k..(i0 + 1) * k];
        let a1 = &a[(i0 + 1) * k..(i0 + 2) * k];
        let a2 = &a[(i0 + 2) * k..(i0 + 3) * k];
        let a3 = &a[(i0 + 3) * k..(i0 + 4) * k];
        let mut j0 = 0;
        while j0 < n4 {
            let mut c0 = F64x4::ZERO;
            let mut c1 = F64x4::ZERO;
            let mut c2 = F64x4::ZERO;
            let mut c3 = F64x4::ZERO;
            let mut boff = j0;
            for kk in 0..k {
                let vb = F64x4::load(&b[boff..boff + LANES]);
                c0 = c0.fma(F64x4::splat(a0[kk]), vb);
                c1 = c1.fma(F64x4::splat(a1[kk]), vb);
                c2 = c2.fma(F64x4::splat(a2[kk]), vb);
                c3 = c3.fma(F64x4::splat(a3[kk]), vb);
                boff += n;
            }
            c0.store(&mut out[i0 * n + j0..]);
            c1.store(&mut out[(i0 + 1) * n + j0..]);
            c2.store(&mut out[(i0 + 2) * n + j0..]);
            c3.store(&mut out[(i0 + 3) * n + j0..]);
            j0 += LANES;
        }
        for j in n4..n {
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            let mut boff = j;
            for kk in 0..k {
                let bv = b[boff];
                s0 += a0[kk] * bv;
                s1 += a1[kk] * bv;
                s2 += a2[kk] * bv;
                s3 += a3[kk] * bv;
                boff += n;
            }
            out[i0 * n + j] = s0;
            out[(i0 + 1) * n + j] = s1;
            out[(i0 + 2) * n + j] = s2;
            out[(i0 + 3) * n + j] = s3;
        }
        i0 += MR;
    }
    for i in m4..m {
        let ai = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n4 {
            let mut c = F64x4::ZERO;
            let mut boff = j0;
            for &av in ai {
                c = c.fma(F64x4::splat(av), F64x4::load(&b[boff..boff + LANES]));
                boff += n;
            }
            c.store(&mut orow[j0..]);
            j0 += LANES;
        }
        for (j, o) in orow.iter_mut().enumerate().take(n).skip(n4) {
            let mut s = 0.0;
            let mut boff = j;
            for &av in ai {
                s += av * b[boff];
                boff += n;
            }
            *o = s;
        }
    }
}

/// `C = Aᵀ·B` for row-major `A (k×m)`, `B (k×n)` into zero-initialised
/// row-major `out (m×n)`, without forming the transpose: the 4×4 tile
/// reads four contiguous `A`-row entries per `kk` step. Same
/// tile-independent accumulation order as [`matmul_nn`].
pub fn matmul_tn(a: &[f64], b: &[f64], out: &mut [f64], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    const MR: usize = 4;
    let n4 = n & !(LANES - 1);
    let m4 = m - m % MR;
    let mut i0 = 0;
    while i0 < m4 {
        let mut j0 = 0;
        while j0 < n4 {
            let mut c0 = F64x4::ZERO;
            let mut c1 = F64x4::ZERO;
            let mut c2 = F64x4::ZERO;
            let mut c3 = F64x4::ZERO;
            for kk in 0..k {
                let ar = &a[kk * m + i0..kk * m + i0 + MR];
                let vb = F64x4::load(&b[kk * n + j0..kk * n + j0 + LANES]);
                c0 = c0.fma(F64x4::splat(ar[0]), vb);
                c1 = c1.fma(F64x4::splat(ar[1]), vb);
                c2 = c2.fma(F64x4::splat(ar[2]), vb);
                c3 = c3.fma(F64x4::splat(ar[3]), vb);
            }
            c0.store(&mut out[i0 * n + j0..]);
            c1.store(&mut out[(i0 + 1) * n + j0..]);
            c2.store(&mut out[(i0 + 2) * n + j0..]);
            c3.store(&mut out[(i0 + 3) * n + j0..]);
            j0 += LANES;
        }
        for j in n4..n {
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for kk in 0..k {
                let ar = &a[kk * m + i0..kk * m + i0 + MR];
                let bv = b[kk * n + j];
                s0 += ar[0] * bv;
                s1 += ar[1] * bv;
                s2 += ar[2] * bv;
                s3 += ar[3] * bv;
            }
            out[i0 * n + j] = s0;
            out[(i0 + 1) * n + j] = s1;
            out[(i0 + 2) * n + j] = s2;
            out[(i0 + 3) * n + j] = s3;
        }
        i0 += MR;
    }
    for i in m4..m {
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n4 {
            let mut c = F64x4::ZERO;
            for kk in 0..k {
                let av = a[kk * m + i];
                c = c.fma(F64x4::splat(av), F64x4::load(&b[kk * n + j0..kk * n + j0 + LANES]));
            }
            c.store(&mut orow[j0..]);
            j0 += LANES;
        }
        for (j, o) in orow.iter_mut().enumerate().take(n).skip(n4) {
            let mut s = 0.0;
            for kk in 0..k {
                s += a[kk * m + i] * b[kk * n + j];
            }
            *o = s;
        }
    }
}

/// Four simultaneous dot products of `a` against `b0..b3` (equal
/// lengths), k-vectorized with one shared `a` load per lane step. The
/// per-pair accumulation order (lanes stride 4 over `k`, then the
/// pairwise [`F64x4::hsum`]) is fixed regardless of which rows are
/// batched together.
#[inline]
pub fn dot4(a: &[f64], b0: &[f64], b1: &[f64], b2: &[f64], b3: &[f64]) -> [f64; 4] {
    let k = a.len();
    debug_assert!(b0.len() == k && b1.len() == k && b2.len() == k && b3.len() == k);
    let k4 = k & !(LANES - 1);
    let mut c0 = F64x4::ZERO;
    let mut c1 = F64x4::ZERO;
    let mut c2 = F64x4::ZERO;
    let mut c3 = F64x4::ZERO;
    let mut kk = 0;
    while kk < k4 {
        let va = F64x4::load(&a[kk..kk + LANES]);
        c0 = c0.fma(va, F64x4::load(&b0[kk..kk + LANES]));
        c1 = c1.fma(va, F64x4::load(&b1[kk..kk + LANES]));
        c2 = c2.fma(va, F64x4::load(&b2[kk..kk + LANES]));
        c3 = c3.fma(va, F64x4::load(&b3[kk..kk + LANES]));
        kk += LANES;
    }
    let mut s = [c0.hsum(), c1.hsum(), c2.hsum(), c3.hsum()];
    for kk in k4..k {
        let av = a[kk];
        s[0] += av * b0[kk];
        s[1] += av * b1[kk];
        s[2] += av * b2[kk];
        s[3] += av * b3[kk];
    }
    s
}

/// `C = A·Bᵀ` for row-major `A (m×k)`, `B (n×k)` into row-major
/// `out (m×n)` (overwritten): per output row, [`dot4`]-style batches of
/// four `B` rows share each `A`-row load.
pub fn matmul_nt(a: &[f64], b: &[f64], out: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let n4 = n - n % 4;
    for i in 0..m {
        let ai = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n4 {
            let s = dot4(
                ai,
                &b[j0 * k..(j0 + 1) * k],
                &b[(j0 + 1) * k..(j0 + 2) * k],
                &b[(j0 + 2) * k..(j0 + 3) * k],
                &b[(j0 + 3) * k..(j0 + 4) * k],
            );
            orow[j0..j0 + 4].copy_from_slice(&s);
            j0 += 4;
        }
        for (j, o) in orow.iter_mut().enumerate().take(n).skip(n4) {
            *o = dot1(ai, &b[j * k..(j + 1) * k]);
        }
    }
}

/// Single k-vectorized dot with the same lane/hsum order as [`dot4`].
#[inline]
pub fn dot1(a: &[f64], b: &[f64]) -> f64 {
    let k = a.len();
    debug_assert_eq!(b.len(), k);
    let k4 = k & !(LANES - 1);
    let mut c = F64x4::ZERO;
    let mut kk = 0;
    while kk < k4 {
        c = c.fma(F64x4::load(&a[kk..kk + LANES]), F64x4::load(&b[kk..kk + LANES]));
        kk += LANES;
    }
    let mut s = c.hsum();
    for kk in k4..k {
        s += a[kk] * b[kk];
    }
    s
}

/// `y += α₀·x0 + α₁·x1 + α₂·x2 + α₃·x3` over equal-length rows, fused:
/// one pass over `y` applies all four rank-1 row updates (the TRSM /
/// weighted-SYRK building block).
#[inline]
pub fn axpy4(alpha: [f64; 4], x0: &[f64], x1: &[f64], x2: &[f64], x3: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert!(x0.len() == n && x1.len() == n && x2.len() == n && x3.len() == n);
    let n4 = n & !(LANES - 1);
    let va0 = F64x4::splat(alpha[0]);
    let va1 = F64x4::splat(alpha[1]);
    let va2 = F64x4::splat(alpha[2]);
    let va3 = F64x4::splat(alpha[3]);
    let mut j = 0;
    while j < n4 {
        let mut vy = F64x4::load(&y[j..j + LANES]);
        vy = vy.fma(va0, F64x4::load(&x0[j..j + LANES]));
        vy = vy.fma(va1, F64x4::load(&x1[j..j + LANES]));
        vy = vy.fma(va2, F64x4::load(&x2[j..j + LANES]));
        vy = vy.fma(va3, F64x4::load(&x3[j..j + LANES]));
        vy.store(&mut y[j..]);
        j += LANES;
    }
    for j in n4..n {
        y[j] += ((alpha[0] * x0[j] + alpha[1] * x1[j]) + alpha[2] * x2[j]) + alpha[3] * x3[j];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_ops() {
        let a = F64x4([1.0, 2.0, 3.0, 4.0]);
        let b = F64x4([0.5, -1.0, 2.0, 0.0]);
        assert_eq!(a.add(b).0, [1.5, 1.0, 5.0, 4.0]);
        assert_eq!(a.sub(b).0, [0.5, 3.0, 1.0, 4.0]);
        assert_eq!(a.mul(b).0, [0.5, -2.0, 6.0, 0.0]);
        assert_eq!(F64x4::splat(10.0).fma(a, b).0, [10.5, 8.0, 16.0, 10.0]);
        assert_eq!(a.hsum(), 10.0);
        let mut out = [0.0; 5];
        a.store(&mut out);
        assert_eq!(F64x4::load(&out).0, a.0);
    }

    #[test]
    fn parse_accepts_zero_and_one() {
        assert!(parse_simd("1"));
        assert!(!parse_simd("0"));
        assert!(parse_simd(" 1 "));
    }

    #[test]
    #[should_panic(expected = "VIFGP_SIMD")]
    fn parse_rejects_malformed() {
        parse_simd("2");
    }

    #[test]
    #[should_panic(expected = "got `yes`")]
    fn parse_names_the_offending_value() {
        parse_simd("yes");
    }

    #[test]
    fn dot4_and_dot1_match_naive() {
        for k in [0usize, 1, 3, 4, 5, 8, 17] {
            let a: Vec<f64> = (0..k).map(|i| (i as f64 * 0.7).sin()).collect();
            let bs: Vec<Vec<f64>> = (0..4)
                .map(|r| (0..k).map(|i| ((i * 3 + r) as f64 * 0.3).cos()).collect())
                .collect();
            let got = dot4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            for r in 0..4 {
                let naive: f64 = a.iter().zip(&bs[r]).map(|(x, y)| x * y).sum();
                assert!((got[r] - naive).abs() < 1e-12, "dot4 k={k} r={r}");
                assert!((dot1(&a, &bs[r]) - naive).abs() < 1e-12, "dot1 k={k} r={r}");
            }
        }
    }

    #[test]
    fn axpy4_matches_naive() {
        for n in [0usize, 1, 4, 7, 13] {
            let alpha = [0.3, -1.1, 2.0, 0.0];
            let xs: Vec<Vec<f64>> = (0..4)
                .map(|r| (0..n).map(|i| ((i + r) as f64 * 0.5).sin()).collect())
                .collect();
            let mut y: Vec<f64> = (0..n).map(|i| i as f64 * 0.1).collect();
            let want: Vec<f64> = (0..n)
                .map(|i| {
                    y[i] + (0..4).map(|r| alpha[r] * xs[r][i]).sum::<f64>()
                })
                .collect();
            axpy4(alpha, &xs[0], &xs[1], &xs[2], &xs[3], &mut y);
            for i in 0..n {
                assert!((y[i] - want[i]).abs() < 1e-12, "axpy4 n={n} i={i}");
            }
        }
    }
}
