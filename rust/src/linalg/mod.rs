//! Dense linear algebra substrate.
//!
//! The offline crate registry contains no BLAS/LAPACK bindings, so the
//! dense kernels the VIF approximation needs — blocked matrix multiply,
//! Cholesky factorization, triangular solves, and a symmetric tridiagonal
//! eigensolver for stochastic Lanczos quadrature — are implemented here
//! from scratch. Matrices are row-major `f64`.
//!
//! # Lane backend
//!
//! The dense hot paths — `Mat::{matmul, matmul_tn_into, matmul_nt,
//! gram_t, syrk_sub_panel, syr2k_sub_panel, syrk_add_panel_weighted}`
//! and `CholeskyFactor::{solve_lower_mat, solve_upper_mat, solve_mat}` —
//! dispatch onto the register-blocked micro-kernels of [`simd`] (4-lane
//! `f64` arrays, 4×4 accumulator tiles) when the loop-nest work reaches
//! [`simd::SIMD_MIN_WORK`] and `VIFGP_SIMD` ≠ `0`. Each entry point
//! keeps its scalar loop as a `*_scalar` oracle and exposes the lane
//! path as `*_simd`; the two agree to ≤1e-12 at every size (pinned by
//! `rust/tests/simd.rs`). See the [`simd`] module docs for lane width,
//! packing layout, and the dispatch contract.

mod chol;
mod mat;
pub mod simd;
mod tridiag;

pub use chol::{CholeskyError, CholeskyFactor, JitteredFactor};
pub use mat::Mat;
pub use tridiag::{tridiag_eigen, SymTridiag};

/// Dot product of two equal-length slices (unrolled by 4 for ILP).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = 4 * i;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in 4 * chunks..n {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    norm2_sq(x).sqrt()
}

/// Elementwise product accumulate: `out[i] += a[i] * b[i]`.
#[inline]
pub fn hadamard_acc(a: &[f64], b: &[f64], out: &mut [f64]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o += x * y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| (13 - i) as f64).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn norms() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert!((norm2_sq(&[3.0, 4.0]) - 25.0).abs() < 1e-15);
    }
}
