//! Row-major dense matrix with blocked multiply.
//!
//! The GEMM/SYRK entry points dispatch onto the register-blocked lane
//! kernels of [`super::simd`] above [`simd::SIMD_MIN_WORK`]; each keeps
//! its scalar loop as a `*_scalar` oracle (see the `linalg` module docs,
//! "Lane backend").

use super::{dot, simd};

/// A dense, row-major `f64` matrix.
///
/// This is the workhorse container for all dense blocks in the VIF
/// approximation: `Σ_m` (m×m), `Σ_mn` panels, Woodbury cores, and the
/// small per-point Vecchia systems.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of shape `(rows, cols)`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector. Panics if sizes mismatch.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(rows * cols, data.len(), "Mat::from_vec size mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// A column vector (n×1) from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Mat { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_to(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] += v;
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Underlying data (row-major).
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Column `j` copied into a fresh vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Columns `lo..hi` copied into a fresh `rows×(hi-lo)` matrix
    /// (column-block extraction for the batched solvers).
    pub fn cols_range(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols, "cols_range out of bounds");
        let k = hi - lo;
        let mut out = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            out.data[i * k..(i + 1) * k]
                .copy_from_slice(&self.data[i * self.cols + lo..i * self.cols + hi]);
        }
        out
    }

    /// Write `block` (rows×(hi-lo)) into columns `lo..hi` of `self`.
    pub fn set_cols_range(&mut self, lo: usize, block: &Mat) {
        let k = block.cols;
        assert!(lo + k <= self.cols, "set_cols_range out of bounds");
        assert_eq!(block.rows, self.rows, "set_cols_range row mismatch");
        for i in 0..self.rows {
            self.data[i * self.cols + lo..i * self.cols + lo + k]
                .copy_from_slice(&block.data[i * k..(i + 1) * k]);
        }
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Simple cache-blocked transpose.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Matrix-vector product `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// `selfᵀ * x` without forming the transpose.
    ///
    /// Keeps the `x[i] == 0` row skip as a **documented sparse fast
    /// path**: the Vecchia scatter/gather callers pass `x` vectors that
    /// are mostly zero (per-point conditioning-set masks), where skipping
    /// whole rows beats streaming them. Dense GEMM paths must not carry
    /// such skips — they defeat vectorization (see `matmul`).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len(), "matvec_t shape mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = self.row(i);
            for (o, r) in out.iter_mut().zip(row) {
                *o += xi * r;
            }
        }
        out
    }

    /// Matrix product `self * other`. Dispatches onto the 4×4
    /// register-blocked lane kernel above the work threshold; the
    /// blocked i-k-j scalar loop stays as the oracle
    /// ([`matmul_scalar`](Self::matmul_scalar)).
    pub fn matmul(&self, other: &Mat) -> Mat {
        if simd::use_simd(self.rows * self.cols * other.cols) {
            self.matmul_simd(other)
        } else {
            self.matmul_scalar(other)
        }
    }

    /// Scalar oracle for [`matmul`](Self::matmul): blocked i-k-j loop
    /// order with the inner j loop over contiguous rows of `other`.
    pub fn matmul_scalar(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for (kk, &aik) in arow.iter().enumerate().take(k) {
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    /// Lane-backend [`matmul`](Self::matmul) (valid at every size;
    /// remainders handled inside the micro-kernel).
    pub fn matmul_simd(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        simd::matmul_nn(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// `selfᵀ * other` without forming the transpose.
    pub fn matmul_tn(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.cols, other.cols);
        self.matmul_tn_into(other, &mut out);
        out
    }

    /// [`matmul_tn`](Self::matmul_tn) writing into a preallocated
    /// `self.cols × other.cols` output (overwritten). Dispatches like
    /// [`matmul`](Self::matmul).
    pub fn matmul_tn_into(&self, other: &Mat, out: &mut Mat) {
        if simd::use_simd(self.rows * self.cols * other.cols) {
            self.matmul_tn_into_simd(other, out)
        } else {
            self.matmul_tn_into_scalar(other, out)
        }
    }

    /// Scalar oracle for [`matmul_tn_into`](Self::matmul_tn_into):
    /// kk-outer rank-1 accumulation over contiguous output rows.
    pub fn matmul_tn_into_scalar(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        assert_eq!(out.rows, m, "matmul_tn_into row mismatch");
        assert_eq!(out.cols, n, "matmul_tn_into col mismatch");
        out.data.fill(0.0);
        for kk in 0..k {
            let arow = self.row(kk);
            let brow = other.row(kk);
            for (i, &aki) in arow.iter().enumerate().take(m) {
                let orow = &mut out.data[i * n..(i + 1) * n];
                for (o, b) in orow.iter_mut().zip(brow) {
                    *o += aki * b;
                }
            }
        }
    }

    /// Lane-backend [`matmul_tn_into`](Self::matmul_tn_into).
    pub fn matmul_tn_into_simd(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.rows, other.rows, "matmul_tn shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        assert_eq!(out.rows, m, "matmul_tn_into row mismatch");
        assert_eq!(out.cols, n, "matmul_tn_into col mismatch");
        out.data.fill(0.0);
        simd::matmul_tn(&self.data, &other.data, &mut out.data, k, m, n);
    }

    /// `self * otherᵀ`. Dispatches onto the k-vectorized `dot4` lane
    /// kernel above the work threshold (the historical per-element `dot`
    /// loop stays as [`matmul_nt_scalar`](Self::matmul_nt_scalar)).
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        if simd::use_simd(self.rows * self.cols * other.rows) {
            self.matmul_nt_simd(other)
        } else {
            self.matmul_nt_scalar(other)
        }
    }

    /// Scalar oracle for [`matmul_nt`](Self::matmul_nt): per-element
    /// dots over the contiguous shared axis.
    pub fn matmul_nt_scalar(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, n) = (self.rows, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            for j in 0..n {
                out.data[i * n + j] = dot(arow, other.row(j));
            }
        }
        out
    }

    /// Lane-backend [`matmul_nt`](Self::matmul_nt): batches of four
    /// `other` rows share each `self`-row load.
    pub fn matmul_nt_simd(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        simd::matmul_nt(&self.data, &other.data, &mut out.data, m, k, n);
        out
    }

    /// Symmetric rank-k style product `selfᵀ * self` (upper computed,
    /// mirrored). Dispatches like [`matmul`](Self::matmul).
    pub fn gram_t(&self) -> Mat {
        if simd::use_simd(self.rows * self.cols * self.cols) {
            self.gram_t_simd()
        } else {
            self.gram_t_scalar()
        }
    }

    /// Scalar oracle for [`gram_t`](Self::gram_t): kk-outer rank-1
    /// updates on the upper triangle.
    pub fn gram_t_scalar(&self) -> Mat {
        let (k, m) = (self.rows, self.cols);
        let mut out = Mat::zeros(m, m);
        for kk in 0..k {
            let row = self.row(kk);
            for i in 0..m {
                let ri = row[i];
                for j in i..m {
                    out.data[i * m + j] += ri * row[j];
                }
            }
        }
        Self::mirror_upper_to_lower(&mut out);
        out
    }

    /// Lane-backend [`gram_t`](Self::gram_t): four rank-1 updates fused
    /// per pass over each upper-triangle row.
    pub fn gram_t_simd(&self) -> Mat {
        let (k, m) = (self.rows, self.cols);
        let mut out = Mat::zeros(m, m);
        let k4 = k - k % 4;
        let mut kk = 0;
        while kk < k4 {
            let r0 = self.row(kk);
            let r1 = self.row(kk + 1);
            let r2 = self.row(kk + 2);
            let r3 = self.row(kk + 3);
            for i in 0..m {
                let coeff = [r0[i], r1[i], r2[i], r3[i]];
                simd::axpy4(
                    coeff,
                    &r0[i..],
                    &r1[i..],
                    &r2[i..],
                    &r3[i..],
                    &mut out.data[i * m + i..(i + 1) * m],
                );
            }
            kk += 4;
        }
        for kk in k4..k {
            let row = self.row(kk);
            for i in 0..m {
                super::axpy(row[i], &row[i..], &mut out.data[i * m + i..(i + 1) * m]);
            }
        }
        Self::mirror_upper_to_lower(&mut out);
        out
    }

    /// Copy the strictly-upper triangle of a square matrix to its lower
    /// triangle, reading each source row as one contiguous slice.
    fn mirror_upper_to_lower(out: &mut Mat) {
        let m = out.rows;
        for i in 1..m {
            let (upper, lower) = out.data.split_at_mut(i * m);
            for (j, l) in lower[..i].iter_mut().enumerate() {
                *l = upper[j * m + i];
            }
        }
    }

    /// `self -= V Vᵀ` for a row-major `n×k` panel `v` (SYRK): the lower
    /// triangle is computed and mirrored, so `self` must be square and
    /// is assumed symmetric on entry. This is the low-rank correction
    /// `ρ_NN −= V_nb V_nbᵀ` of the panelized residual assembly.
    pub fn syrk_sub_panel(&mut self, v: &[f64], k: usize) {
        if simd::use_simd(self.rows * self.rows * k) {
            self.syrk_sub_panel_simd(v, k)
        } else {
            self.syrk_sub_panel_scalar(v, k)
        }
    }

    /// Scalar oracle for [`syrk_sub_panel`](Self::syrk_sub_panel).
    pub fn syrk_sub_panel_scalar(&mut self, v: &[f64], k: usize) {
        let n = self.rows;
        debug_assert_eq!(self.rows, self.cols);
        debug_assert_eq!(v.len(), n * k);
        for i in 0..n {
            let vi = &v[i * k..(i + 1) * k];
            for j in 0..=i {
                let s = dot(vi, &v[j * k..(j + 1) * k]);
                self.data[i * n + j] -= s;
                if j != i {
                    self.data[j * n + i] -= s;
                }
            }
        }
    }

    /// Lane-backend [`syrk_sub_panel`](Self::syrk_sub_panel): four
    /// lower-triangle dots per `dot4` batch share each `v_i` load.
    pub fn syrk_sub_panel_simd(&mut self, v: &[f64], k: usize) {
        let n = self.rows;
        debug_assert_eq!(self.rows, self.cols);
        debug_assert_eq!(v.len(), n * k);
        for i in 0..n {
            let vi = &v[i * k..(i + 1) * k];
            let jmax = i + 1;
            let j4 = jmax - jmax % 4;
            let mut j0 = 0;
            while j0 < j4 {
                let s = simd::dot4(
                    vi,
                    &v[j0 * k..(j0 + 1) * k],
                    &v[(j0 + 1) * k..(j0 + 2) * k],
                    &v[(j0 + 2) * k..(j0 + 3) * k],
                    &v[(j0 + 3) * k..(j0 + 4) * k],
                );
                for (t, &st) in s.iter().enumerate() {
                    let j = j0 + t;
                    self.data[i * n + j] -= st;
                    if j != i {
                        self.data[j * n + i] -= st;
                    }
                }
                j0 += 4;
            }
            for j in j4..jmax {
                let s = simd::dot1(vi, &v[j * k..(j + 1) * k]);
                self.data[i * n + j] -= s;
                if j != i {
                    self.data[j * n + i] -= s;
                }
            }
        }
    }

    /// `self -= A Bᵀ + B Aᵀ` for row-major `n×k` panels (symmetric
    /// rank-2k update): lower triangle computed and mirrored, `self`
    /// square and symmetric on entry. This is the gradient correction
    /// `∂ρ_NN −= T^p_nb E_nbᵀ + E_nb (T^p_nb)ᵀ`.
    pub fn syr2k_sub_panel(&mut self, a: &[f64], b: &[f64], k: usize) {
        if simd::use_simd(self.rows * self.rows * k) {
            self.syr2k_sub_panel_simd(a, b, k)
        } else {
            self.syr2k_sub_panel_scalar(a, b, k)
        }
    }

    /// Scalar oracle for [`syr2k_sub_panel`](Self::syr2k_sub_panel).
    pub fn syr2k_sub_panel_scalar(&mut self, a: &[f64], b: &[f64], k: usize) {
        let n = self.rows;
        debug_assert_eq!(self.rows, self.cols);
        debug_assert_eq!(a.len(), n * k);
        debug_assert_eq!(b.len(), n * k);
        for i in 0..n {
            let ai = &a[i * k..(i + 1) * k];
            let bi = &b[i * k..(i + 1) * k];
            for j in 0..=i {
                let s = dot(ai, &b[j * k..(j + 1) * k]) + dot(bi, &a[j * k..(j + 1) * k]);
                self.data[i * n + j] -= s;
                if j != i {
                    self.data[j * n + i] -= s;
                }
            }
        }
    }

    /// Lane-backend [`syr2k_sub_panel`](Self::syr2k_sub_panel): paired
    /// `dot4` batches over the lower triangle.
    pub fn syr2k_sub_panel_simd(&mut self, a: &[f64], b: &[f64], k: usize) {
        let n = self.rows;
        debug_assert_eq!(self.rows, self.cols);
        debug_assert_eq!(a.len(), n * k);
        debug_assert_eq!(b.len(), n * k);
        for i in 0..n {
            let ai = &a[i * k..(i + 1) * k];
            let bi = &b[i * k..(i + 1) * k];
            let jmax = i + 1;
            let j4 = jmax - jmax % 4;
            let mut j0 = 0;
            while j0 < j4 {
                let sab = simd::dot4(
                    ai,
                    &b[j0 * k..(j0 + 1) * k],
                    &b[(j0 + 1) * k..(j0 + 2) * k],
                    &b[(j0 + 2) * k..(j0 + 3) * k],
                    &b[(j0 + 3) * k..(j0 + 4) * k],
                );
                let sba = simd::dot4(
                    bi,
                    &a[j0 * k..(j0 + 1) * k],
                    &a[(j0 + 1) * k..(j0 + 2) * k],
                    &a[(j0 + 2) * k..(j0 + 3) * k],
                    &a[(j0 + 3) * k..(j0 + 4) * k],
                );
                for t in 0..4 {
                    let j = j0 + t;
                    let s = sab[t] + sba[t];
                    self.data[i * n + j] -= s;
                    if j != i {
                        self.data[j * n + i] -= s;
                    }
                }
                j0 += 4;
            }
            for j in j4..jmax {
                let s = simd::dot1(ai, &b[j * k..(j + 1) * k])
                    + simd::dot1(bi, &a[j * k..(j + 1) * k]);
                self.data[i * n + j] -= s;
                if j != i {
                    self.data[j * n + i] -= s;
                }
            }
        }
    }

    /// `self -= A Aᵀ` ([`syrk_sub_panel`](Self::syrk_sub_panel) over a
    /// `Mat` operand; `self` symmetric on entry).
    pub fn sub_aat(&mut self, a: &Mat) {
        assert_eq!(a.rows, self.rows, "sub_aat shape mismatch");
        assert_eq!(self.rows, self.cols, "sub_aat needs a square target");
        self.syrk_sub_panel(&a.data, a.cols);
    }

    /// `self -= A Bᵀ + B Aᵀ` ([`syr2k_sub_panel`](Self::syr2k_sub_panel)
    /// over `Mat` operands; `self` symmetric on entry).
    pub fn sub_abt_sym(&mut self, a: &Mat, b: &Mat) {
        assert_eq!(a.rows, self.rows, "sub_abt_sym shape mismatch");
        assert_eq!(b.rows, self.rows, "sub_abt_sym shape mismatch");
        assert_eq!(a.cols, b.cols, "sub_abt_sym inner-dim mismatch");
        assert_eq!(self.rows, self.cols, "sub_abt_sym needs a square target");
        self.syr2k_sub_panel(&a.data, &b.data, a.cols);
    }

    /// Append the rows of `other` below `self` — one contiguous copy in
    /// row-major storage. Appending to an empty `0×0` matrix adopts
    /// `other`'s width (the `m = 0` structures keep `0×0` placeholders).
    /// This is the growth primitive of the streaming-append path: the
    /// `Σ_mn`/`V`/`E` panels and the Woodbury side blocks all grow by
    /// whole rows.
    pub fn append_rows(&mut self, other: &Mat) {
        if self.rows == 0 && self.cols == 0 {
            self.cols = other.cols;
        }
        assert_eq!(self.cols, other.cols, "append_rows column mismatch");
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// `self += Σ_t w[t] · v_t v_tᵀ` for a row-major panel `v` whose
    /// rows `v_t` have length `k` (weighted SYRK in the `gram_t`
    /// orientation: `self` is `k×k`). The lower triangle is computed
    /// once per pair and written to both halves, so `self` must be
    /// square and symmetric on entry. This is the blocked Woodbury
    /// rank-k update `M += ΔΣᵀ D⁻¹ ΔΣ` of the streaming-append path
    /// (weights `w = 1/D` over the appended rows).
    pub fn syrk_add_panel_weighted(&mut self, v: &[f64], k: usize, w: &[f64]) {
        if simd::use_simd(w.len() * k * k) {
            self.syrk_add_panel_weighted_simd(v, k, w)
        } else {
            self.syrk_add_panel_weighted_scalar(v, k, w)
        }
    }

    /// Scalar oracle for
    /// [`syrk_add_panel_weighted`](Self::syrk_add_panel_weighted):
    /// per-pair weighted dots with strided `v[t*k + i]` access.
    pub fn syrk_add_panel_weighted_scalar(&mut self, v: &[f64], k: usize, w: &[f64]) {
        debug_assert_eq!(self.rows, self.cols);
        debug_assert_eq!(self.rows, k);
        debug_assert_eq!(v.len(), w.len() * k);
        for i in 0..k {
            for j in 0..=i {
                let mut s = 0.0;
                for (t, &wt) in w.iter().enumerate() {
                    s += wt * v[t * k + i] * v[t * k + j];
                }
                self.data[i * k + j] += s;
                if j != i {
                    self.data[j * k + i] += s;
                }
            }
        }
    }

    /// Lane-backend
    /// [`syrk_add_panel_weighted`](Self::syrk_add_panel_weighted),
    /// restructured t-outer: four weighted rank-1 updates fused per pass
    /// over each contiguous lower-triangle row (the scalar path streams
    /// `v` with stride `k` per inner step). `self` is symmetric on entry
    /// and the update is symmetric, so only the lower triangle is
    /// accumulated and mirrored once at the end.
    pub fn syrk_add_panel_weighted_simd(&mut self, v: &[f64], k: usize, w: &[f64]) {
        debug_assert_eq!(self.rows, self.cols);
        debug_assert_eq!(self.rows, k);
        debug_assert_eq!(v.len(), w.len() * k);
        let nt = w.len();
        let t4 = nt - nt % 4;
        let mut t0 = 0;
        while t0 < t4 {
            let v0 = &v[t0 * k..(t0 + 1) * k];
            let v1 = &v[(t0 + 1) * k..(t0 + 2) * k];
            let v2 = &v[(t0 + 2) * k..(t0 + 3) * k];
            let v3 = &v[(t0 + 3) * k..(t0 + 4) * k];
            for i in 0..k {
                let coeff =
                    [w[t0] * v0[i], w[t0 + 1] * v1[i], w[t0 + 2] * v2[i], w[t0 + 3] * v3[i]];
                simd::axpy4(
                    coeff,
                    &v0[..=i],
                    &v1[..=i],
                    &v2[..=i],
                    &v3[..=i],
                    &mut self.data[i * k..i * k + i + 1],
                );
            }
            t0 += 4;
        }
        for t in t4..nt {
            let vt = &v[t * k..(t + 1) * k];
            for i in 0..k {
                super::axpy(w[t] * vt[i], &vt[..=i], &mut self.data[i * k..i * k + i + 1]);
            }
        }
        // Mirror the (symmetric-on-entry + symmetric-update) lower
        // triangle back to the upper half, row-slice reads.
        for i in 1..k {
            let (upper, lower) = self.data.split_at_mut(i * k);
            for (j, &l) in lower[..i].iter().enumerate() {
                upper[j * k + i] = l;
            }
        }
    }

    /// Elementwise in-place add.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise in-place subtract.
    pub fn sub_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, alpha: f64) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Add `alpha` to the diagonal.
    pub fn add_diag(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// Scale row `i` by `d[i]` (left-multiply by diag(d)).
    pub fn scale_rows(&mut self, d: &[f64]) {
        assert_eq!(d.len(), self.rows);
        for i in 0..self.rows {
            let di = d[i];
            for v in self.row_mut(i) {
                *v *= di;
            }
        }
    }

    /// Diagonal as a vector.
    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self.get(i, i)).collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max absolute entry difference to `other` (for tests).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> Mat {
        Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn matmul_basic() {
        let b = Mat::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a().matmul(&b);
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let b = Mat::from_vec(2, 4, (0..8).map(|i| i as f64).collect());
        let c1 = a().matmul_tn(&b);
        let c2 = a().t().matmul(&b);
        assert!(c1.max_abs_diff(&c2) < 1e-14);
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let b = Mat::from_vec(4, 3, (0..12).map(|i| i as f64).collect());
        let c1 = a().matmul_nt(&b);
        let c2 = a().matmul(&b.t());
        assert!(c1.max_abs_diff(&c2) < 1e-14);
    }

    #[test]
    fn gram_matches_matmul() {
        let m = Mat::from_vec(4, 3, (0..12).map(|i| (i as f64).sin()).collect());
        let g1 = m.gram_t();
        let g2 = m.t().matmul(&m);
        assert!(g1.max_abs_diff(&g2) < 1e-14);
    }

    #[test]
    fn matvec_and_t() {
        let m = a();
        assert_eq!(m.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(m.matvec_t(&[1.0, -1.0]), vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let m = Mat::from_vec(5, 7, (0..35).map(|i| i as f64).collect());
        assert!(m.t().t().max_abs_diff(&m) < 1e-15);
    }

    #[test]
    fn syrk_and_syr2k_match_dense() {
        for (n, k) in [(1usize, 3usize), (4, 6), (5, 0), (6, 1), (7, 9)] {
            let a = Mat::from_fn(n, k, |i, j| ((i * 3 + j) as f64 * 0.23).sin());
            let b = Mat::from_fn(n, k, |i, j| ((i + j * 2) as f64 * 0.41).cos());
            // symmetric starting target
            let base = Mat::from_fn(n, n, |i, j| ((i + j) as f64 * 0.1).cos());
            let mut got = base.clone();
            got.sub_aat(&a);
            let mut want = base.clone();
            want.sub_assign(&a.matmul_nt(&a));
            assert!(got.max_abs_diff(&want) < 1e-13, "syrk n={n} k={k}");
            let mut got2 = base.clone();
            got2.sub_abt_sym(&a, &b);
            let mut want2 = base.clone();
            want2.sub_assign(&a.matmul_nt(&b));
            want2.sub_assign(&b.matmul_nt(&a));
            assert!(got2.max_abs_diff(&want2) < 1e-13, "syr2k n={n} k={k}");
        }
    }

    #[test]
    fn append_rows_matches_from_fn() {
        let top = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let bot = Mat::from_fn(2, 4, |i, j| ((i + 3) * 4 + j) as f64);
        let mut m = top.clone();
        m.append_rows(&bot);
        let want = Mat::from_fn(5, 4, |i, j| (i * 4 + j) as f64);
        assert_eq!(m.data(), want.data());
        assert_eq!((m.rows(), m.cols()), (5, 4));
        // appending to an empty placeholder adopts the width
        let mut e = Mat::zeros(0, 0);
        e.append_rows(&bot);
        assert_eq!((e.rows(), e.cols()), (2, 4));
        assert_eq!(e.data(), bot.data());
    }

    #[test]
    fn syrk_add_panel_weighted_matches_dense() {
        for (t, k) in [(1usize, 3usize), (5, 4), (0, 2), (7, 1)] {
            let v = Mat::from_fn(t, k, |i, j| ((i * 5 + j) as f64 * 0.31).sin());
            let w: Vec<f64> = (0..t).map(|i| 0.5 + i as f64 * 0.1).collect();
            let base = Mat::from_fn(k, k, |i, j| ((i + j) as f64 * 0.2).cos());
            let mut got = base.clone();
            got.syrk_add_panel_weighted(v.data(), k, &w);
            let mut vw = v.clone();
            vw.scale_rows(&w);
            let mut want = base.clone();
            want.add_assign(&vw.matmul_tn(&v));
            assert!(got.max_abs_diff(&want) < 1e-13, "weighted syrk t={t} k={k}");
        }
    }

    #[test]
    fn diag_ops() {
        let mut m = Mat::eye(3);
        m.add_diag(2.0);
        assert_eq!(m.diag(), vec![3.0, 3.0, 3.0]);
        m.scale_rows(&[1.0, 2.0, 3.0]);
        assert_eq!(m.diag(), vec![3.0, 6.0, 9.0]);
    }
}
