//! Symmetric tridiagonal eigensolver (implicit QL with Wilkinson shift).
//!
//! Stochastic Lanczos quadrature (§4.1, Eq. 18/19 of the paper) needs
//! `e₁ᵀ log(T̃) e₁` for the small tridiagonal matrices recovered from the
//! CG coefficients. We compute the full eigendecomposition of `T̃` and
//! evaluate `Σ_k w_k² log(λ_k)` with `w_k` the first components of the
//! eigenvectors — the classic Golub–Welsch quadrature identity.

/// A symmetric tridiagonal matrix with diagonal `d` (len k) and
/// off-diagonal `e` (len k-1).
#[derive(Clone, Debug, Default)]
pub struct SymTridiag {
    pub d: Vec<f64>,
    pub e: Vec<f64>,
}

impl SymTridiag {
    pub fn new(d: Vec<f64>, e: Vec<f64>) -> Self {
        assert!(e.len() + 1 == d.len() || (d.is_empty() && e.is_empty()));
        SymTridiag { d, e }
    }

    pub fn n(&self) -> usize {
        self.d.len()
    }

    /// Quadrature form `e₁ᵀ f(T) e₁ = Σ_k w_k² f(λ_k)`.
    pub fn quadrature(&self, f: impl Fn(f64) -> f64) -> f64 {
        let (eigs, first_row) = tridiag_eigen(self);
        eigs.iter()
            .zip(&first_row)
            .map(|(&lam, &w)| w * w * f(lam))
            .sum()
    }
}

/// Eigenvalues and the *first row* of the eigenvector matrix of a
/// symmetric tridiagonal matrix, via implicit QL with Wilkinson shifts.
///
/// Returns `(eigenvalues, first_components)`; only the first eigenvector
/// components are accumulated since that is all SLQ needs.
pub fn tridiag_eigen(t: &SymTridiag) -> (Vec<f64>, Vec<f64>) {
    let n = t.n();
    if n == 0 {
        return (vec![], vec![]);
    }
    let mut d = t.d.clone();
    let mut e = t.e.clone();
    e.push(0.0); // sentinel
    // z holds the first row of the accumulated rotation product.
    let mut z = vec![0.0; n];
    z[0] = 1.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small off-diagonal element.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tridiag_eigen: too many QL iterations");
            // Wilkinson shift.
            let g0 = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let r0 = g0.hypot(1.0);
            let mut g = d[m] - d[l] + e[l] / (g0 + if g0 >= 0.0 { r0 } else { -r0 });
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                let r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                let r2 = (d[i] - g) * s + 2.0 * c * b;
                p = s * r2;
                d[i + 1] = g + p;
                g = c * r2 - b;
                // Accumulate first-row components only.
                f = z[i + 1];
                z[i + 1] = s * z[i] + c * f;
                z[i] = c * z[i] - s * f;
            }
            if e[m] == 0.0 && m > l + 1 {
                // restarted via r == 0 branch
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    (d, z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn dense(t: &SymTridiag) -> Mat {
        let n = t.n();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, t.d[i]);
            if i + 1 < n {
                m.set(i, i + 1, t.e[i]);
                m.set(i + 1, i, t.e[i]);
            }
        }
        m
    }

    #[test]
    fn eigenvalues_2x2_closed_form() {
        let t = SymTridiag::new(vec![2.0, 1.0], vec![0.5]);
        let (mut eigs, _) = tridiag_eigen(&t);
        eigs.sort_by(f64::total_cmp);
        // closed form: (3 ± sqrt(1+1))/2
        let disc = (1.0f64 + 1.0).sqrt();
        assert!((eigs[0] - (3.0 - disc) / 2.0).abs() < 1e-12);
        assert!((eigs[1] - (3.0 + disc) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_logdet_preserved() {
        let t = SymTridiag::new(vec![4.0, 5.0, 6.0, 7.0, 8.0], vec![0.3, 0.2, 0.5, 0.1]);
        let (eigs, w) = tridiag_eigen(&t);
        let trace: f64 = eigs.iter().sum();
        assert!((trace - 30.0).abs() < 1e-10);
        // first-row weights sum to 1 (orthogonal rows)
        let wsum: f64 = w.iter().map(|x| x * x).sum();
        assert!((wsum - 1.0).abs() < 1e-10);
        // e1' T e1 = d[0] via quadrature with identity
        let q = t.quadrature(|x| x);
        assert!((q - 4.0).abs() < 1e-10);
    }

    #[test]
    fn quadrature_log_matches_dense_logdet_weighted() {
        // e1' log(T) e1 computed by dense eigen through 3x3 explicit check:
        // verify with matrix power series via diagonalization from our own
        // routine against f(x)=x^2, where e1' T^2 e1 = (T^2)[0,0].
        let t = SymTridiag::new(vec![3.0, 2.0, 4.0], vec![0.7, 0.4]);
        let m = dense(&t);
        let m2 = m.matmul(&m);
        let q = t.quadrature(|x| x * x);
        assert!((q - m2.get(0, 0)).abs() < 1e-10);
    }

    #[test]
    fn handles_diagonal_matrix() {
        let t = SymTridiag::new(vec![1.0, 2.0, 3.0], vec![0.0, 0.0]);
        let (mut eigs, _) = tridiag_eigen(&t);
        eigs.sort_by(f64::total_cmp);
        assert!((eigs[0] - 1.0).abs() < 1e-14);
        assert!((eigs[2] - 3.0).abs() < 1e-14);
    }
}
