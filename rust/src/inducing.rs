//! Inducing-point selection via kMeans++ (paper §6).
//!
//! Following Gyger et al. (2026), inducing points are chosen as kMeans++
//! cluster centers in the λ-transformed input space `q_λ(s) = s/λ`, with
//! optional Lloyd refinement, and support warm starting from the centers
//! of a previous optimization iteration (the paper re-determines inducing
//! points at power-of-two optimization iterations).
//!
//! The same warm start serves the streaming-append lifecycle: when a
//! model's appended fraction crosses the compaction threshold
//! (`FitModel::compact`), the full re-selection restarts Lloyd from the
//! inducing set of the structure being compacted, so the re-selected
//! centers track the previous ones instead of re-seeding from scratch.

use crate::linalg::Mat;
use crate::rng::Rng;

/// kMeans++ seeding + `lloyd_iters` Lloyd steps over the rows of
/// `x_scaled` (already transformed by 1/λ). Returns an m×d matrix of
/// centers (in the *scaled* space — callers undo the scaling).
pub fn kmeanspp(x_scaled: &Mat, m: usize, lloyd_iters: usize, rng: &mut Rng) -> Mat {
    let n = x_scaled.rows();
    let d = x_scaled.cols();
    assert!(m >= 1 && m <= n, "need 1 <= m <= n (m={m}, n={n})");
    let mut centers = Mat::zeros(m, d);
    // -- kMeans++ seeding --
    let first = rng.below(n);
    centers.row_mut(0).copy_from_slice(x_scaled.row(first));
    let mut d2: Vec<f64> = (0..n)
        .map(|i| sqdist(x_scaled.row(i), centers.row(0)))
        .collect();
    for k in 1..m {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.uniform() * total;
            let mut idx = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        centers.row_mut(k).copy_from_slice(x_scaled.row(pick));
        for i in 0..n {
            let nd = sqdist(x_scaled.row(i), centers.row(k));
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    lloyd(x_scaled, centers, lloyd_iters)
}

/// Lloyd refinement starting from given centers — used for warm starts
/// from a previous optimization iteration (§6).
pub fn lloyd(x_scaled: &Mat, mut centers: Mat, iters: usize) -> Mat {
    let n = x_scaled.rows();
    let d = x_scaled.cols();
    let m = centers.rows();
    // Accumulators are reused across iterations: warm-started
    // re-selection runs Lloyd on every plan rebuild, so the refinement
    // loop itself stays allocation-free.
    let mut sums = Mat::zeros(m, d);
    let mut counts = vec![0usize; m];
    for _ in 0..iters {
        sums.data_mut().fill(0.0);
        counts.fill(0);
        for i in 0..n {
            let xi = x_scaled.row(i);
            let k = nearest_center(xi, &centers);
            counts[k] += 1;
            for (s, v) in sums.row_mut(k).iter_mut().zip(xi) {
                *s += v;
            }
        }
        let mut moved = 0.0;
        for k in 0..m {
            if counts[k] == 0 {
                continue; // keep empty-cluster center in place
            }
            let inv = 1.0 / counts[k] as f64;
            let mut delta = 0.0;
            for (c, s) in centers.row_mut(k).iter_mut().zip(sums.row(k)) {
                let newc = s * inv;
                delta += (newc - *c) * (newc - *c);
                *c = newc;
            }
            moved += delta;
        }
        if moved < 1e-12 {
            break;
        }
    }
    centers
}

fn nearest_center(x: &[f64], centers: &Mat) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for k in 0..centers.rows() {
        let d = sqdist(x, centers.row(k));
        if d < best_d {
            best_d = d;
            best = k;
        }
    }
    best
}

#[inline]
fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum()
}

/// Scale inputs by 1/λ per dimension (the `q_λ` transformation).
pub fn scale_inputs(x: &Mat, length_scales: &[f64]) -> Mat {
    assert_eq!(x.cols(), length_scales.len());
    Mat::from_fn(x.rows(), x.cols(), |i, j| x.get(i, j) / length_scales[j])
}

/// Undo the `q_λ` transformation on a set of centers.
pub fn unscale_inputs(x_scaled: &Mat, length_scales: &[f64]) -> Mat {
    Mat::from_fn(x_scaled.rows(), x_scaled.cols(), |i, j| {
        x_scaled.get(i, j) * length_scales[j]
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centers_land_on_clusters() {
        // Two tight clusters; 2 centers must split them.
        let mut rng = Rng::seed_from(2);
        let mut data = Vec::new();
        for _ in 0..50 {
            data.push(0.0 + 0.01 * rng.normal());
            data.push(0.0 + 0.01 * rng.normal());
        }
        for _ in 0..50 {
            data.push(5.0 + 0.01 * rng.normal());
            data.push(5.0 + 0.01 * rng.normal());
        }
        let x = Mat::from_vec(100, 2, data);
        let c = kmeanspp(&x, 2, 10, &mut rng);
        let mut near_origin = 0;
        let mut near_five = 0;
        for k in 0..2 {
            let r = c.row(k);
            if r[0] < 1.0 && r[1] < 1.0 {
                near_origin += 1;
            }
            if r[0] > 4.0 && r[1] > 4.0 {
                near_five += 1;
            }
        }
        assert_eq!((near_origin, near_five), (1, 1));
    }

    #[test]
    fn m_equals_n_returns_all_points() {
        let mut rng = Rng::seed_from(8);
        let x = crate::testing::random_points(&mut rng, 10, 3);
        let c = kmeanspp(&x, 10, 0, &mut rng);
        assert_eq!(c.rows(), 10);
    }

    #[test]
    fn scaling_round_trip() {
        let mut rng = Rng::seed_from(4);
        let x = crate::testing::random_points(&mut rng, 7, 3);
        let ls = [0.5, 2.0, 1.5];
        let xs = scale_inputs(&x, &ls);
        let back = unscale_inputs(&xs, &ls);
        assert!(back.max_abs_diff(&x) < 1e-14);
    }

    #[test]
    fn lloyd_reduces_inertia() {
        let mut rng = Rng::seed_from(6);
        let x = crate::testing::random_points(&mut rng, 200, 2);
        let seed_centers = kmeanspp(&x, 8, 0, &mut rng);
        let refined = lloyd(&x, seed_centers.clone(), 15);
        let inertia = |c: &Mat| -> f64 {
            (0..x.rows())
                .map(|i| {
                    (0..c.rows())
                        .map(|k| sqdist(x.row(i), c.row(k)))
                        .fold(f64::INFINITY, f64::min)
                })
                .sum()
        };
        assert!(inertia(&refined) <= inertia(&seed_centers) + 1e-12);
    }
}
