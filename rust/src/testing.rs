//! Lightweight property-testing and gradient-checking substrate.
//!
//! The offline registry has no `proptest`, so this module provides a
//! small seeded-random property harness: generators draw random cases,
//! a failing case is reported with its seed, and numeric helpers check
//! gradients against central finite differences.

use crate::rng::Rng;

/// Run `prop` over `cases` randomly generated inputs. On failure, panics
/// with the case index and seed so the case can be replayed
/// deterministically (inputs need not be `Debug` — regenerate from the
/// reported seed).
pub fn check<T>(
    name: &str,
    cases: usize,
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Rng::seed_from(seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (replay seed {}):\n  {msg}",
                seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Relative-tolerance comparison helper.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    if (a - b).abs() <= atol + rtol * a.abs().max(b.abs()) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (rtol {rtol}, atol {atol})"))
    }
}

/// Check an analytic gradient of `f: R^p -> R` against central finite
/// differences at `x0`. `h` is the FD step; tolerance is relative.
pub fn check_gradient(
    f: impl Fn(&[f64]) -> f64,
    grad: &[f64],
    x0: &[f64],
    h: f64,
    rtol: f64,
    atol: f64,
) -> Result<(), String> {
    for i in 0..x0.len() {
        let mut xp = x0.to_vec();
        xp[i] += h;
        let mut xm = x0.to_vec();
        xm[i] -= h;
        let fd = (f(&xp) - f(&xm)) / (2.0 * h);
        if (fd - grad[i]).abs() > atol + rtol * fd.abs().max(grad[i].abs()) {
            return Err(format!(
                "gradient component {i}: analytic {} vs finite-diff {fd}",
                grad[i]
            ));
        }
    }
    Ok(())
}

/// Random points in the unit hypercube as a `Mat` (n × d).
pub fn random_points(rng: &mut Rng, n: usize, d: usize) -> crate::linalg::Mat {
    crate::linalg::Mat::from_fn(n, d, |_, _| rng.uniform())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(
            "abs-nonneg",
            50,
            1,
            |r| r.normal(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn check_reports_failure() {
        check("always-fails", 3, 7, |r| r.uniform(), |_| Err("nope".into()));
    }

    #[test]
    fn gradient_checker_quadratic() {
        let f = |x: &[f64]| x[0] * x[0] + 3.0 * x[1];
        let x0 = [2.0, -1.0];
        check_gradient(f, &[4.0, 3.0], &x0, 1e-6, 1e-6, 1e-8).unwrap();
        assert!(check_gradient(f, &[4.1, 3.0], &x0, 1e-6, 1e-6, 1e-8).is_err());
    }
}
