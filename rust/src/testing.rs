//! Lightweight property-testing and gradient-checking substrate.
//!
//! The offline registry has no `proptest`, so this module provides a
//! small seeded-random property harness: generators draw random cases,
//! a failing case is reported with its seed, and numeric helpers check
//! gradients against central finite differences.

use crate::coordinator::ThreadPool;
use crate::covertree::Metric;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::vecchia::{ResidualCov, ResidualFactor, SweepExec};
use crate::vif::VifStructure;

/// Run `prop` over `cases` randomly generated inputs. On failure, panics
/// with the case index and seed so the case can be replayed
/// deterministically (inputs need not be `Debug` — regenerate from the
/// reported seed).
pub fn check<T>(
    name: &str,
    cases: usize,
    seed: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Rng::seed_from(seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (replay seed {}):\n  {msg}",
                seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Relative-tolerance comparison helper.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    if (a - b).abs() <= atol + rtol * a.abs().max(b.abs()) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (rtol {rtol}, atol {atol})"))
    }
}

/// Check an analytic gradient of `f: R^p -> R` against central finite
/// differences at `x0`. `h` is the FD step; tolerance is relative.
pub fn check_gradient(
    f: impl Fn(&[f64]) -> f64,
    grad: &[f64],
    x0: &[f64],
    h: f64,
    rtol: f64,
    atol: f64,
) -> Result<(), String> {
    for i in 0..x0.len() {
        let mut xp = x0.to_vec();
        xp[i] += h;
        let mut xm = x0.to_vec();
        xm[i] -= h;
        let fd = (f(&xp) - f(&xm)) / (2.0 * h);
        if (fd - grad[i]).abs() > atol + rtol * fd.abs().max(grad[i].abs()) {
            return Err(format!(
                "gradient component {i}: analytic {} vs finite-diff {fd}",
                grad[i]
            ));
        }
    }
    Ok(())
}

/// Random points in the unit hypercube as a `Mat` (n × d).
pub fn random_points(rng: &mut Rng, n: usize, d: usize) -> crate::linalg::Mat {
    crate::linalg::Mat::from_fn(n, d, |_, _| rng.uniform())
}

/// Random strictly-lower neighbor graph with per-row degree `≤ kmax`
/// (an irregular Vecchia-style conditioning structure).
pub fn random_neighbor_graph(rng: &mut Rng, n: usize, kmax: usize) -> Vec<Vec<u32>> {
    (0..n)
        .map(|i| {
            let k = rng.below(i.min(kmax) + 1);
            let mut picked = vec![false; i];
            let mut count = 0;
            while count < k {
                let j = rng.below(i);
                if !picked[j] {
                    picked[j] = true;
                    count += 1;
                }
            }
            (0..i).filter(|&j| picked[j]).map(|j| j as u32).collect()
        })
        .collect()
}

/// Build a [`ResidualFactor`] with random coefficients on a given
/// neighbor graph — no covariance oracle involved, so the dense-oracle
/// harness can exercise the sweep kernels on arbitrary strictly-lower
/// sparsity (empty, chain, saturated, irregular). Coefficients shrink
/// with the row degree so round-trips stay well-conditioned.
pub fn random_residual_factor(rng: &mut Rng, neighbors: Vec<Vec<u32>>) -> ResidualFactor {
    let a: Vec<Vec<f64>> = neighbors
        .iter()
        .map(|nb| {
            let scale = 0.8 / (nb.len() as f64).sqrt().max(1.0);
            nb.iter()
                .map(|_| rng.uniform_in(-1.0, 1.0) * scale)
                .collect()
        })
        .collect();
    let d: Vec<f64> = (0..neighbors.len())
        .map(|_| rng.uniform_in(0.5, 2.0))
        .collect();
    ResidualFactor::from_parts(neighbors, a, d)
}

/// Dense forward substitution `L x = v` for unit-lower-triangular `L`.
pub fn dense_solve_unit_lower(l: &Mat, v: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut x = v.to_vec();
    for i in 0..n {
        let mut s = x[i];
        for j in 0..i {
            s -= l.get(i, j) * x[j];
        }
        x[i] = s;
    }
    x
}

/// Dense backward substitution `U x = v` for unit-upper-triangular `U`.
pub fn dense_solve_unit_upper(u: &Mat, v: &[f64]) -> Vec<f64> {
    let n = u.rows();
    let mut x = v.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in i + 1..n {
            s -= u.get(i, j) * x[j];
        }
        x[i] = s;
    }
    x
}

fn assert_vec_close(got: &[f64], want: &[f64], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{what}: element {i}: {g} vs dense {w}"
        );
    }
}

/// Dense-oracle harness for the eight `B` kernels: checks
/// `mul_b`/`mul_bt`/`solve_b`/`solve_bt` and their `_mat` variants (one
/// width per entry of `col_counts`) against dense matrix products and
/// unit-triangular solves built from [`ResidualFactor::dense_b`]. Each
/// kernel is exercised under both the sequential and the pool-scheduled
/// execution mode.
pub fn assert_b_kernels_match_dense(
    f: &ResidualFactor,
    rng: &mut Rng,
    col_counts: &[usize],
    tol: f64,
) {
    let n = f.n();
    let b = f.dense_b();
    let bt = b.t();
    let v = rng.normal_vec(n);
    let mats: Vec<Mat> = col_counts
        .iter()
        .map(|&k| Mat::from_fn(n, k, |_, _| rng.normal()))
        .collect();
    let execs: [(SweepExec<'_>, &str); 2] = [
        (SweepExec::Seq, "seq"),
        (
            SweepExec::Pool(crate::coordinator::global_pool(), crate::coordinator::num_threads()),
            "pool",
        ),
    ];
    for (exec, mode) in execs {
        assert_vec_close(
            &f.mul_b_with(&v, exec),
            &b.matvec(&v),
            tol,
            &format!("mul_b[{mode}]"),
        );
        assert_vec_close(
            &f.mul_bt_with(&v, exec),
            &bt.matvec(&v),
            tol,
            &format!("mul_bt[{mode}]"),
        );
        assert_vec_close(
            &f.solve_b_with(&v, exec),
            &dense_solve_unit_lower(&b, &v),
            tol,
            &format!("solve_b[{mode}]"),
        );
        assert_vec_close(
            &f.solve_bt_with(&v, exec),
            &dense_solve_unit_upper(&bt, &v),
            tol,
            &format!("solve_bt[{mode}]"),
        );
        for x in &mats {
            let k = x.cols();
            let cases: [(Mat, &str); 4] = [
                (f.mul_b_mat_with(x, exec), "mul_b_mat"),
                (f.mul_bt_mat_with(x, exec), "mul_bt_mat"),
                (f.solve_b_mat_with(x, exec), "solve_b_mat"),
                (f.solve_bt_mat_with(x, exec), "solve_bt_mat"),
            ];
            for (got, name) in &cases {
                for j in 0..k {
                    let col = x.col(j);
                    let want = match *name {
                        "mul_b_mat" => b.matvec(&col),
                        "mul_bt_mat" => bt.matvec(&col),
                        "solve_b_mat" => dense_solve_unit_lower(&b, &col),
                        _ => dense_solve_unit_upper(&bt, &col),
                    };
                    assert_vec_close(
                        &got.col(j),
                        &want,
                        tol,
                        &format!("{name}[{mode}] k={k} col {j}"),
                    );
                }
            }
        }
    }
}

/// Max absolute difference between two assembled [`VifStructure`]s over
/// everything the θ-refresh path recomputes: the residual factor's
/// `A`/`D` rows, the low-rank panels (`Σ_m`, `Σ_mn`, `V`, `E`), the
/// Woodbury blocks (`BΣ_mnᵀ`, `H`, `SΣ_mnᵀ`, `SS`, `M`), and the log
/// determinant. Panics on any shape/presence mismatch — that indicates
/// the structures were built for different plans, not a numeric drift.
/// This is the oracle check behind `tests/refresh.rs` and perf_hotpath
/// stage 11 (refresh ≡ fresh-assemble ≤ 1e-12).
pub fn structures_max_abs_diff(s1: &VifStructure, s2: &VifStructure) -> f64 {
    assert_eq!(s1.n(), s2.n(), "structure sizes differ");
    assert_eq!(s1.m(), s2.m(), "inducing counts differ");
    let mut diff = 0.0f64;
    for (i, (a1, a2)) in s1.resid.a.iter().zip(&s2.resid.a).enumerate() {
        assert_eq!(a1.len(), a2.len(), "row {i}: coefficient lengths differ");
        for (x, y) in a1.iter().zip(a2) {
            diff = diff.max((x - y).abs());
        }
    }
    for (x, y) in s1.resid.d.iter().zip(&s2.resid.d) {
        diff = diff.max((x - y).abs());
    }
    for (m1, m2) in [
        (&s1.bsig, &s2.bsig),
        (&s1.h, &s2.h),
        (&s1.ssig, &s2.ssig),
        (&s1.ss, &s2.ss),
    ] {
        diff = diff.max(m1.max_abs_diff(m2));
    }
    match (&s1.mcal, &s2.mcal) {
        (Some(m1), Some(m2)) => diff = diff.max(m1.max_abs_diff(m2)),
        (None, None) => {}
        _ => panic!("Woodbury core presence differs"),
    }
    match (&s1.lr, &s2.lr) {
        (Some(l1), Some(l2)) => {
            diff = diff.max(l1.sig_m.max_abs_diff(&l2.sig_m));
            diff = diff.max(l1.sigma_nm.max_abs_diff(&l2.sigma_nm));
            diff = diff.max(l1.vt.max_abs_diff(&l2.vt));
            diff = diff.max(l1.et.max_abs_diff(&l2.et));
        }
        (None, None) => {}
        _ => panic!("low-rank presence differs"),
    }
    diff = diff.max((s1.logdet() - s2.logdet()).abs());
    diff
}

/// Output of [`scalar_predict_reference`]: the per-point conditional
/// blocks and deterministic posterior terms, mirroring
/// `vif::predict::PredictBlocks` plus the mean.
pub struct ScalarPrediction {
    pub mean: Vec<f64>,
    /// Deterministic predictive variance (full Prop 2.1 response
    /// variance on the Gaussian scale; Eq. 20 on the latent scale).
    pub var_det: Vec<f64>,
    pub a_rows: Vec<Vec<f64>>,
    pub d: Vec<f64>,
    /// `K(X_p, Z)` rows (n_p×m).
    pub kp: Mat,
    /// `Σ_m⁻¹ k_p` rows (n_p×m).
    pub alpha: Mat,
}

/// Scalar per-point reference of the shared prediction pipeline
/// (`vif::predict`): the pre-refactor per-point bodies — one scalar
/// `kernel.cov` call per pair, one dense Cholesky, `matvec`/`solve`
/// Woodbury terms per point — evaluated for fixed conditioning sets,
/// with the target vector on the Gaussian response scale (`y`) or the
/// Laplace latent scale (the mode `b̃`). The points are fanned out over
/// the worker pool exactly like the pre-refactor Gaussian `predict`
/// loop was, so the perf_hotpath stage-12 baseline isolates the
/// panelization/batching win rather than thread-count parallelism.
/// This is the oracle for the panelized/batched pipeline tests
/// (`tests/predict.rs`) and the baseline for perf_hotpath stage 12.
pub fn scalar_predict_reference(
    s: &VifStructure,
    x: &Mat,
    kernel: &crate::kernels::ArdMatern,
    target: &[f64],
    xp: &Mat,
    neighbors: &[Vec<u32>],
    block_jitter: f64,
) -> ScalarPrediction {
    use crate::linalg::{dot, CholeskyFactor};
    let np_pts = xp.rows();
    let m = s.m();
    let nugget = s.nugget;
    let u = s.apply_sigma_dagger_inv(target);
    let resid_target: Vec<f64> = match (&s.lr, &s.chol_mcal) {
        (Some(lr), Some(cm)) => {
            let c = cm.solve(&s.ssig.matvec_t(target));
            let corr = lr.sigma_nm.matvec(&c);
            target.iter().zip(&corr).map(|(t, co)| t - co).collect()
        }
        _ => target.to_vec(),
    };
    let smu = match &s.lr {
        Some(lr) => lr.sigma_nm.matvec_t(&u),
        None => vec![],
    };
    let mut mean = vec![0.0; np_pts];
    let mut var = vec![0.0; np_pts];
    let mut a_rows: Vec<Vec<f64>> = vec![vec![]; np_pts];
    let mut d_out = vec![0.0; np_pts];
    let mut kp_rows = Mat::zeros(np_pts, m);
    let mut alpha_rows = Mat::zeros(np_pts, m);
    type PointOut = (f64, f64, Vec<f64>, f64, Vec<f64>, Vec<f64>);
    let per_point: Vec<PointOut> = crate::coordinator::parallel_map(np_pts, |p| {
        let sp = xp.row(p);
        let nb = &neighbors[p];
        let q = nb.len();
        let (kp, alpha, vt_p): (Vec<f64>, Vec<f64>, Vec<f64>) = match &s.lr {
            Some(lr) => {
                let kp: Vec<f64> = (0..m).map(|l| kernel.cov(sp, lr.z.row(l))).collect();
                let mut vt_p = kp.clone();
                lr.chol_m.solve_lower_in_place(&mut vt_p);
                let mut alpha = vt_p.clone();
                lr.chol_m.solve_upper_in_place(&mut alpha);
                (kp, alpha, vt_p)
            }
            None => (vec![], vec![], vec![]),
        };
        let rho_pp = kernel.variance - dot(&vt_p, &vt_p);
        let (a_p, d_p) = if q == 0 {
            (vec![], (rho_pp + nugget).max(1e-12))
        } else {
            let rho = |a: usize, b: usize| -> f64 {
                let k = kernel.cov(x.row(a), x.row(b));
                match &s.lr {
                    Some(lr) => k - dot(lr.vt.row(a), lr.vt.row(b)),
                    None => k,
                }
            };
            let mut cnn = Mat::zeros(q, q);
            for (ai, &ja) in nb.iter().enumerate() {
                cnn.set(ai, ai, rho(ja as usize, ja as usize) + nugget);
                for (bi, &jb) in nb.iter().enumerate().take(ai) {
                    let vv = rho(ja as usize, jb as usize);
                    cnn.set(ai, bi, vv);
                    cnn.set(bi, ai, vv);
                }
            }
            let rho_pn: Vec<f64> = nb
                .iter()
                .map(|&j| {
                    let k = kernel.cov(sp, x.row(j as usize));
                    match &s.lr {
                        Some(lr) => k - dot(&vt_p, lr.vt.row(j as usize)),
                        None => k,
                    }
                })
                .collect();
            let chol = CholeskyFactor::new_with_jitter(&cnn, block_jitter)
                .expect("prediction block not PD");
            let a_p = chol.solve(&rho_pn);
            let d_p = rho_pp + nugget - dot(&a_p, &rho_pn);
            (a_p, d_p.max(1e-12))
        };
        let mut mu = 0.0;
        for (k_i, &j) in nb.iter().enumerate() {
            mu += a_p[k_i] * resid_target[j as usize];
        }
        if m > 0 {
            mu += dot(&alpha, &smu);
        }
        let mut var_p = d_p;
        if m > 0 {
            let lr = s.lr.as_ref().unwrap();
            let cm = s.chol_mcal.as_ref().unwrap();
            let mut beta = vec![0.0; m];
            for (k_i, &j) in nb.iter().enumerate() {
                let srow = lr.sigma_nm.row(j as usize);
                for (l, &sv) in srow.iter().enumerate() {
                    beta[l] -= a_p[k_i] * sv;
                }
            }
            let ss_alpha = s.ss.matvec(&alpha);
            var_p += dot(&kp, &alpha) - dot(&alpha, &ss_alpha) + 2.0 * dot(&alpha, &beta);
            let diff: Vec<f64> = beta.iter().zip(&ss_alpha).map(|(b, s)| b - s).collect();
            let mdiff = cm.solve(&diff);
            var_p += dot(&diff, &mdiff);
        }
        (mu, var_p.max(1e-12), a_p, d_p, kp, alpha)
    });
    for (p, (mu, var_p, a_p, d_p, kp, alpha)) in per_point.into_iter().enumerate() {
        mean[p] = mu;
        var[p] = var_p;
        if m > 0 {
            kp_rows.row_mut(p).copy_from_slice(&kp);
            alpha_rows.row_mut(p).copy_from_slice(&alpha);
        }
        d_out[p] = d_p;
        a_rows[p] = a_p;
    }
    ScalarPrediction {
        mean,
        var_det: var,
        a_rows,
        d: d_out,
        kp: kp_rows,
        alpha: alpha_rows,
    }
}

/// Wrapper that strips an oracle's panel overrides, forcing the scalar
/// per-pair `ResidualCov` default impls. This is the baseline for the
/// panel-vs-scalar equivalence tests and for perf_hotpath stage 10.
pub struct ScalarizedOracle<'a>(pub &'a dyn ResidualCov);

impl ResidualCov for ScalarizedOracle<'_> {
    fn rho(&self, i: usize, j: usize) -> f64 {
        self.0.rho(i, j)
    }
    fn num_params(&self) -> usize {
        self.0.num_params()
    }
    fn rho_and_grad(&self, i: usize, j: usize, grad: &mut [f64]) -> f64 {
        self.0.rho_and_grad(i, j, grad)
    }
}

/// Wrapper that strips a metric's `dist_batch` override, forcing the
/// scalar per-pair default (the cover-tree perf baseline).
pub struct ScalarizedMetric<'a>(pub &'a dyn Metric);

impl Metric for ScalarizedMetric<'_> {
    fn dist(&self, i: usize, j: usize) -> f64 {
        self.0.dist(i, j)
    }
}

/// Panel-vs-scalar equivalence: `rho_block` and `rho_and_grad_block` of
/// `oracle` (i.e. its panelized overrides, if any) must match the
/// per-pair scalar `rho`/`rho_and_grad` calls to absolute tolerance
/// `tol` on every row of `neighbors`.
pub fn assert_rho_blocks_match_scalar(
    oracle: &dyn ResidualCov,
    neighbors: &[Vec<u32>],
    tol: f64,
) {
    let np = oracle.num_params();
    let mut g = vec![0.0; np];
    for (i, nb) in neighbors.iter().enumerate() {
        let q = nb.len();
        // rho_block
        let mut rho_nn = Mat::zeros(q, q);
        let mut rho_in = vec![0.0; q];
        let rho_ii = oracle.rho_block(i, nb, &mut rho_nn, &mut rho_in);
        assert!(
            (rho_ii - oracle.rho(i, i)).abs() <= tol,
            "row {i}: rho_ii {} vs scalar {}",
            rho_ii,
            oracle.rho(i, i)
        );
        for (ai, &ja) in nb.iter().enumerate() {
            let want = oracle.rho(i, ja as usize);
            assert!(
                (rho_in[ai] - want).abs() <= tol,
                "row {i}: rho_in[{ai}] {} vs scalar {want}",
                rho_in[ai]
            );
            for (bi, &jb) in nb.iter().enumerate() {
                let want = oracle.rho(ja as usize, jb as usize);
                assert!(
                    (rho_nn.get(ai, bi) - want).abs() <= tol,
                    "row {i}: rho_nn[{ai},{bi}] {} vs scalar {want}",
                    rho_nn.get(ai, bi)
                );
            }
        }
        // rho_and_grad_block
        let mut rho_nn2 = Mat::zeros(q, q);
        let mut rho_in2 = vec![0.0; q];
        let mut d_nn: Vec<Mat> = (0..np).map(|_| Mat::zeros(q, q)).collect();
        let mut d_in = Mat::zeros(np, q);
        let mut d_ii = vec![0.0; np];
        let rho_ii2 = oracle.rho_and_grad_block(
            i,
            nb,
            &mut rho_nn2,
            &mut rho_in2,
            &mut d_nn,
            &mut d_in,
            &mut d_ii,
        );
        let want_ii = oracle.rho_and_grad(i, i, &mut g);
        assert!((rho_ii2 - want_ii).abs() <= tol, "row {i}: grad-block rho_ii");
        for p in 0..np {
            assert!(
                (d_ii[p] - g[p]).abs() <= tol,
                "row {i}: d_rho_ii[{p}] {} vs scalar {}",
                d_ii[p],
                g[p]
            );
        }
        assert!(rho_nn2.max_abs_diff(&rho_nn) <= tol, "row {i}: grad-block rho_nn");
        for (ai, &ja) in nb.iter().enumerate() {
            let want = oracle.rho_and_grad(i, ja as usize, &mut g);
            assert!(
                (rho_in2[ai] - want).abs() <= tol,
                "row {i}: grad-block rho_in[{ai}]"
            );
            for p in 0..np {
                assert!(
                    (d_in.get(p, ai) - g[p]).abs() <= tol,
                    "row {i}: d_rho_in[{p},{ai}] {} vs scalar {}",
                    d_in.get(p, ai),
                    g[p]
                );
            }
            for (bi, &jb) in nb.iter().enumerate() {
                let _ = oracle.rho_and_grad(ja as usize, jb as usize, &mut g);
                for p in 0..np {
                    assert!(
                        (d_nn[p].get(ai, bi) - g[p]).abs() <= tol,
                        "row {i}: d_rho_nn[{p}][{ai},{bi}] {} vs scalar {}",
                        d_nn[p].get(ai, bi),
                        g[p]
                    );
                }
            }
        }
    }
}

/// Batched-vs-scalar metric equivalence: `dist_batch` must match the
/// scalar `dist` to absolute tolerance `tol` on random query points and
/// random candidate subsets.
pub fn assert_metric_batch_matches_scalar(
    metric: &dyn Metric,
    n: usize,
    rng: &mut Rng,
    queries: usize,
    tol: f64,
) {
    for _ in 0..queries {
        let i = 1 + rng.below(n - 1);
        let csize = 1 + rng.below(i.min(64));
        let cand: Vec<u32> = (0..csize).map(|_| rng.below(i) as u32).collect();
        let mut out = vec![0.0; csize];
        metric.dist_batch(i, &cand, &mut out);
        for (t, &j) in cand.iter().enumerate() {
            let want = metric.dist(i, j as usize);
            assert!(
                (out[t] - want).abs() <= tol,
                "dist_batch({i}, {j}) = {} vs scalar {want}",
                out[t]
            );
        }
    }
}

fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} not bit-identical: {g} vs {w}"
        );
    }
}

/// Assert the scheduled sweeps are *bit-identical* across worker pools of
/// every given size, and identical to the sequential reference — the
/// determinism contract of the level schedule (gathers with a fixed
/// accumulation order; no racy scatters).
pub fn assert_b_kernels_pool_size_invariant(
    f: &ResidualFactor,
    rng: &mut Rng,
    pool_sizes: &[usize],
    cols: usize,
) {
    let n = f.n();
    let v = rng.normal_vec(n);
    let x = Mat::from_fn(n, cols, |_, _| rng.normal());
    let seq = (
        f.mul_b_with(&v, SweepExec::Seq),
        f.mul_bt_with(&v, SweepExec::Seq),
        f.solve_b_with(&v, SweepExec::Seq),
        f.solve_bt_with(&v, SweepExec::Seq),
        f.mul_b_mat_with(&x, SweepExec::Seq),
        f.mul_bt_mat_with(&x, SweepExec::Seq),
        f.solve_b_mat_with(&x, SweepExec::Seq),
        f.solve_bt_mat_with(&x, SweepExec::Seq),
    );
    for &size in pool_sizes {
        let pool = ThreadPool::new(size);
        let exec = SweepExec::Pool(&pool, size);
        let tag = |k: &str| format!("{k} (pool size {size})");
        assert_bits_eq(&f.mul_b_with(&v, exec), &seq.0, &tag("mul_b"));
        assert_bits_eq(&f.mul_bt_with(&v, exec), &seq.1, &tag("mul_bt"));
        assert_bits_eq(&f.solve_b_with(&v, exec), &seq.2, &tag("solve_b"));
        assert_bits_eq(&f.solve_bt_with(&v, exec), &seq.3, &tag("solve_bt"));
        assert_bits_eq(f.mul_b_mat_with(&x, exec).data(), seq.4.data(), &tag("mul_b_mat"));
        assert_bits_eq(f.mul_bt_mat_with(&x, exec).data(), seq.5.data(), &tag("mul_bt_mat"));
        assert_bits_eq(f.solve_b_mat_with(&x, exec).data(), seq.6.data(), &tag("solve_b_mat"));
        assert_bits_eq(f.solve_bt_mat_with(&x, exec).data(), seq.7.data(), &tag("solve_bt_mat"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(
            "abs-nonneg",
            50,
            1,
            |r| r.normal(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn check_reports_failure() {
        check("always-fails", 3, 7, |r| r.uniform(), |_| Err("nope".into()));
    }

    #[test]
    fn gradient_checker_quadratic() {
        let f = |x: &[f64]| x[0] * x[0] + 3.0 * x[1];
        let x0 = [2.0, -1.0];
        check_gradient(f, &[4.0, 3.0], &x0, 1e-6, 1e-6, 1e-8).unwrap();
        assert!(check_gradient(f, &[4.1, 3.0], &x0, 1e-6, 1e-6, 1e-8).is_err());
    }
}
