//! Baseline GP approximations the paper compares against.
//!
//! * **Standalone Vecchia** — VIF with `m = 0` inducing points; for
//!   non-Gaussian likelihoods the VIFDU preconditioner degenerates to
//!   exactly the VADU preconditioner of Kündig & Sigrist (2025).
//! * **FITC** — VIF with `m_v = 0` Vecchia neighbors.
//! * **SGPR** (Titsias 2009) — the variational inducing-point baseline
//!   standing in for the paper's GPyTorch comparator class (DESIGN.md
//!   §Substitutions), implemented from the collapsed evidence lower
//!   bound with Woodbury algebra.

pub mod sgpr;

pub use sgpr::SgprModel;

use crate::vecchia::neighbors::NeighborSelection;
use crate::vif::VifConfig;

/// A standalone Vecchia approximation (m = 0), correlation-based
/// neighbor selection as in §6.
pub fn vecchia_config(m_v: usize, base: &VifConfig) -> VifConfig {
    VifConfig {
        num_inducing: 0,
        num_neighbors: m_v,
        selection: NeighborSelection::CorrelationCoverTree,
        ..base.clone()
    }
}

/// A FITC approximation (m_v = 0).
pub fn fitc_config(m: usize, base: &VifConfig) -> VifConfig {
    VifConfig { num_inducing: m, num_neighbors: 0, ..base.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_are_special_cases() {
        let base = VifConfig::default();
        let v = vecchia_config(25, &base);
        assert_eq!(v.num_inducing, 0);
        assert_eq!(v.num_neighbors, 25);
        let f = fitc_config(150, &base);
        assert_eq!(f.num_inducing, 150);
        assert_eq!(f.num_neighbors, 0);
    }
}
