//! SGPR: Titsias (2009) collapsed variational inducing-point regression.
//!
//! Negative ELBO (to minimize):
//!
//! ```text
//! −ELBO = ½[n log 2π + log|Q_nn + σ²I| + yᵀ(Q_nn+σ²I)⁻¹y] + Tr(Σ−Q_nn)/(2σ²)
//! ```
//!
//! with `Q_nn = K_nm K_mm⁻¹ K_mn`, evaluated in O(n·m²) via
//! `A = I_m + σ⁻² V Vᵀ`, `V = L_m⁻¹ K_mn`. Gradients are central finite
//! differences over the packed log-parameters (the bound is cheap and
//! smooth; this matches what the torch comparators do with autograd
//! numerically). Stands in for the paper's SGPR/SVGP inducing-point
//! class (DESIGN.md §Substitutions).

use crate::inducing;
use crate::kernels::{ArdMatern, Smoothness};
use crate::linalg::{dot, CholeskyFactor, Mat};
use crate::rng::Rng;

const LN_2PI: f64 = 1.8378770664093453;

/// Fitted SGPR state.
pub struct SgprModel {
    pub kernel: ArdMatern,
    pub noise: f64,
    pub z: Mat,
    pub smoothness: Smoothness,
    /// Cached prediction state (chol_m, chol_a, c = L_A⁻¹ V y / σ²).
    cache: Option<PredCache>,
}

struct PredCache {
    chol_m: CholeskyFactor,
    chol_a: CholeskyFactor,
    c: Vec<f64>,
}

/// Negative ELBO for given parameters and inducing points.
pub fn neg_elbo(x: &Mat, y: &[f64], kernel: &ArdMatern, noise: f64, z: &Mat) -> f64 {
    let n = x.rows();
    let m = z.rows();
    let mut sig_m = kernel.sym_cov(z, 0.0);
    sig_m.add_diag(1e-10 * kernel.variance);
    let chol_m = match CholeskyFactor::new_with_jitter(&sig_m, 1e-10) {
        Ok(c) => c,
        Err(_) => return f64::INFINITY,
    };
    // V = L⁻¹ K_mn  (m×n), built row-block-wise from the runtime panel.
    let k_nm = crate::runtime::cross_cov_panel(x, z, kernel); // n×m
    let v = chol_m.solve_lower_mat(&k_nm.t()); // m×n
    // A = I + σ⁻² V Vᵀ
    let mut a = v.matmul_nt(&v);
    a.scale(1.0 / noise);
    a.add_diag(1.0);
    let chol_a = match CholeskyFactor::new_with_jitter(&a, 1e-10) {
        Ok(c) => c,
        Err(_) => return f64::INFINITY,
    };
    let vy = v.matvec(y);
    let mut lavy = vy.clone();
    chol_a.solve_lower_in_place(&mut lavy);
    let yty = dot(y, y);
    let quad = (yty - dot(&lavy, &lavy) / noise) / noise;
    let logdet = chol_a.logdet() + n as f64 * noise.ln();
    // trace term: Σ(k_ii − ‖v_i‖²)
    let mut tr = 0.0;
    for i in 0..n {
        let vi = v.col(i);
        tr += kernel.variance - dot(&vi, &vi);
    }
    let _ = m;
    0.5 * (n as f64 * LN_2PI + logdet + quad) + tr / (2.0 * noise)
}

impl SgprModel {
    /// Fit by L-BFGS on `[log σ₁², log λ…, log σ²]` with FD gradients.
    /// Inducing points are selected once by kMeans++ (the paper's SGPR
    /// comparator subsamples; kMeans++ is at least as strong).
    pub fn fit(
        x: &Mat,
        y: &[f64],
        m: usize,
        smoothness: Smoothness,
        init_kernel: ArdMatern,
        init_noise: f64,
        max_iters: usize,
        seed: u64,
    ) -> SgprModel {
        let mut rng = Rng::seed_from(seed);
        let xs = inducing::scale_inputs(x, &init_kernel.length_scales);
        let z = inducing::unscale_inputs(
            &inducing::kmeanspp(&xs, m.min(x.rows()), 5, &mut rng),
            &init_kernel.length_scales,
        );
        let mut packed = init_kernel.log_params();
        packed.push(init_noise.ln());
        let obj = |p: &[f64]| -> (f64, Vec<f64>) {
            let nk = p.len() - 1;
            let kernel = ArdMatern::from_log_params(&p[..nk], smoothness);
            let noise = p[nk].exp();
            let f0 = neg_elbo(x, y, &kernel, noise, &z);
            let h = 1e-5;
            let mut g = vec![0.0; p.len()];
            for i in 0..p.len() {
                let mut pp = p.to_vec();
                pp[i] += h;
                let kp = ArdMatern::from_log_params(&pp[..nk], smoothness);
                let fp = neg_elbo(x, y, &kp, pp[nk].exp(), &z);
                let mut pm = p.to_vec();
                pm[i] -= h;
                let km = ArdMatern::from_log_params(&pm[..nk], smoothness);
                let fm = neg_elbo(x, y, &km, pm[nk].exp(), &z);
                g[i] = (fp - fm) / (2.0 * h);
            }
            (f0, g)
        };
        let res = crate::optim::lbfgs(&obj, &packed, max_iters, 1e-4);
        let nk = res.x.len() - 1;
        let kernel = ArdMatern::from_log_params(&res.x[..nk], smoothness);
        let noise = res.x[nk].exp();
        let mut model = SgprModel { kernel, noise, z, smoothness, cache: None };
        model.refresh_cache(x, y);
        model
    }

    fn refresh_cache(&mut self, x: &Mat, y: &[f64]) {
        let mut sig_m = self.kernel.sym_cov(&self.z, 0.0);
        sig_m.add_diag(1e-10 * self.kernel.variance);
        let chol_m = CholeskyFactor::new_with_jitter(&sig_m, 1e-10).unwrap();
        let k_nm = crate::runtime::cross_cov_panel(x, &self.z, &self.kernel);
        let v = chol_m.solve_lower_mat(&k_nm.t());
        let mut a = v.matmul_nt(&v);
        a.scale(1.0 / self.noise);
        a.add_diag(1.0);
        let chol_a = CholeskyFactor::new_with_jitter(&a, 1e-10).unwrap();
        let vy = v.matvec(y);
        let mut c = vy;
        chol_a.solve_lower_in_place(&mut c);
        for ci in c.iter_mut() {
            *ci /= self.noise;
        }
        self.cache = Some(PredCache { chol_m, chol_a, c });
    }

    /// Predictive mean and response variance at new inputs.
    pub fn predict(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        let cache = self.cache.as_ref().expect("fit first");
        let np = xp.rows();
        let mut mean = vec![0.0; np];
        let mut var = vec![0.0; np];
        for p in 0..np {
            let kp: Vec<f64> = (0..self.z.rows())
                .map(|l| self.kernel.cov(xp.row(p), self.z.row(l)))
                .collect();
            let mut q = kp.clone();
            cache.chol_m.solve_lower_in_place(&mut q); // L_m⁻¹ k_p
            let mut laq = q.clone();
            cache.chol_a.solve_lower_in_place(&mut laq); // L_A⁻¹ q
            mean[p] = dot(&laq, &cache.c);
            var[p] = (self.kernel.variance - dot(&q, &q) + dot(&laq, &laq) + self.noise)
                .max(1e-12);
        }
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::random_points;

    #[test]
    fn elbo_lower_bounds_exact_marginal() {
        // −ELBO ≥ exact NLL, with equality as Z → X.
        let mut rng = Rng::seed_from(9);
        let n = 60;
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.0, vec![0.4, 0.4], Smoothness::Gaussian);
        let noise = 0.1;
        let cov = kernel.sym_cov(&x, noise);
        let chol = CholeskyFactor::new(&cov).unwrap();
        let y = chol.mul_lower(&rng.normal_vec(n));
        let alpha = chol.solve(&y);
        let exact = 0.5 * (n as f64 * LN_2PI + chol.logdet() + dot(&y, &alpha));
        // Z = X → bound tight
        let tight = neg_elbo(&x, &y, &kernel, noise, &x);
        assert!((tight - exact).abs() < 1e-3, "tight {tight} vs exact {exact}");
        // Z = subset → bound above exact
        let z = crate::data::subset_rows(&x, &(0..10).collect::<Vec<_>>());
        let loose = neg_elbo(&x, &y, &kernel, noise, &z);
        assert!(loose >= exact - 1e-8, "loose {loose} vs exact {exact}");
    }

    #[test]
    fn fit_and_predict_recovers_signal() {
        let mut rng = Rng::seed_from(10);
        let n = 150;
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.0, vec![0.35, 0.35], Smoothness::Gaussian);
        let latent = crate::data::simulate_latent_gp(&mut rng, &x, &kernel);
        let y: Vec<f64> = latent.iter().map(|b| b + 0.1 * rng.normal()).collect();
        let init = ArdMatern::new(0.5, vec![0.6, 0.6], Smoothness::Gaussian);
        let model = SgprModel::fit(&x, &y, 25, Smoothness::Gaussian, init, 0.3, 40, 1);
        let (mean, var) = model.predict(&x);
        let rmse = crate::metrics::rmse(&mean, &latent);
        assert!(rmse < 0.35, "rmse {rmse}");
        assert!(var.iter().all(|&v| v > 0.0));
    }
}
