//! Serving metrics: per-request latency recording and windowed
//! percentile reports (p50/p99, points/sec).
//!
//! The recorder is deliberately simple — a mutex-guarded latency vector
//! per measurement window. Requests finish at micro-batch granularity
//! (≤ `max_batch` per dispatch), so the dispatcher takes the lock once
//! per *batch*, not once per point, and the lock never sits on the
//! request threads' enqueue path.

use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe latency/throughput recorder for one serving engine.
pub struct ServeMetrics {
    inner: Mutex<Window>,
}

struct Window {
    /// Per-request end-to-end latency (enqueue → reply), microseconds.
    latencies_us: Vec<f64>,
    /// Micro-batches dispatched in this window.
    batches: u64,
    /// Window start (for points/sec).
    started: Instant,
}

impl Window {
    fn fresh() -> Self {
        Window { latencies_us: Vec::new(), batches: 0, started: Instant::now() }
    }
}

/// A point-in-time summary of one measurement window.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    /// Requests completed in the window.
    pub requests: u64,
    /// Median end-to-end latency (µs).
    pub p50_latency_us: f64,
    /// 99th-percentile end-to-end latency (µs).
    pub p99_latency_us: f64,
    /// Mean end-to-end latency (µs).
    pub mean_latency_us: f64,
    /// Completed points per second over the window.
    pub points_per_sec: f64,
    /// Micro-batches dispatched.
    pub batches: u64,
    /// Mean points per micro-batch.
    pub mean_batch: f64,
    /// Window length (seconds).
    pub elapsed_secs: f64,
}

impl MetricsReport {
    /// Render as a compact JSON object (used by `vifgp serve` and the
    /// serving bench artifact).
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"requests\": {}, \"p50_latency_us\": {:.2}, \"p99_latency_us\": {:.2}, ",
                "\"mean_latency_us\": {:.2}, \"points_per_sec\": {:.1}, \"batches\": {}, ",
                "\"mean_batch\": {:.2}, \"elapsed_secs\": {:.4}}}"
            ),
            self.requests,
            self.p50_latency_us,
            self.p99_latency_us,
            self.mean_latency_us,
            self.points_per_sec,
            self.batches,
            self.mean_batch,
            self.elapsed_secs,
        )
    }
}

/// Nearest-rank percentile of an ascending-sorted slice (`q` in [0,1]).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

impl ServeMetrics {
    pub fn new() -> Self {
        ServeMetrics { inner: Mutex::new(Window::fresh()) }
    }

    /// Record one dispatched micro-batch (one latency entry per point).
    pub(crate) fn record_batch(&self, latencies_us: &[f64]) {
        let mut w = self.inner.lock().unwrap();
        w.latencies_us.extend_from_slice(latencies_us);
        w.batches += 1;
    }

    fn summarize(w: &Window) -> MetricsReport {
        let mut sorted = w.latencies_us.clone();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        let requests = sorted.len() as u64;
        let elapsed = w.started.elapsed().as_secs_f64();
        MetricsReport {
            requests,
            p50_latency_us: percentile(&sorted, 0.50),
            p99_latency_us: percentile(&sorted, 0.99),
            mean_latency_us: if sorted.is_empty() {
                0.0
            } else {
                sorted.iter().sum::<f64>() / sorted.len() as f64
            },
            points_per_sec: if elapsed > 0.0 { requests as f64 / elapsed } else { 0.0 },
            batches: w.batches,
            mean_batch: if w.batches > 0 { requests as f64 / w.batches as f64 } else { 0.0 },
            elapsed_secs: elapsed,
        }
    }

    /// Summarize the current window without resetting it.
    pub fn report(&self) -> MetricsReport {
        Self::summarize(&self.inner.lock().unwrap())
    }

    /// Summarize the current window and start a fresh one (the bench's
    /// per-concurrency-sweep reset).
    pub fn drain(&self) -> MetricsReport {
        let mut w = self.inner.lock().unwrap();
        let report = Self::summarize(&w);
        *w = Window::fresh();
        report
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn drain_resets_window() {
        let m = ServeMetrics::new();
        m.record_batch(&[10.0, 20.0, 30.0]);
        let r = m.drain();
        assert_eq!(r.requests, 3);
        assert_eq!(r.batches, 1);
        assert!((r.mean_batch - 3.0).abs() < 1e-12);
        let r2 = m.report();
        assert_eq!(r2.requests, 0);
        assert_eq!(r2.batches, 0);
    }
}
