//! Serving metrics: per-request latency recording, windowed percentile
//! reports (p50/p99, points/sec), and cumulative containment counters.
//!
//! The recorder is deliberately simple — a mutex-guarded latency vector
//! per measurement window. Requests finish at micro-batch granularity
//! (≤ `max_batch` per dispatch), so the dispatcher takes the lock once
//! per *batch*, not once per point, and the lock never sits on the
//! request threads' enqueue path.
//!
//! Containment counters (panics caught, quarantined requests, expired
//! deadlines, non-finite replies) live *outside* the window mutex as
//! plain atomics: they are cumulative over the engine's lifetime and are
//! **not** reset by [`ServeMetrics::drain`], so an operator polling
//! windowed reports still sees every incident since startup.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Coarse engine health derived from the containment counters.
///
/// `Degraded` means the engine has caught at least one prediction panic,
/// quarantined a request, or produced a non-finite reply since startup —
/// it is still serving, but something upstream (model state, input data)
/// deserves a look. Expired deadlines alone do **not** degrade health:
/// shedding late requests under load is the engine doing its job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Degraded,
}

/// Thread-safe latency/throughput recorder for one serving engine.
pub struct ServeMetrics {
    inner: Mutex<Window>,
    /// Prediction panics caught by the dispatch quarantine or the
    /// dispatcher's outer recovery net (cumulative, never reset).
    panics_caught: AtomicU64,
    /// Requests isolated by batch bisection and answered with an error
    /// instead of a prediction (cumulative).
    quarantined_requests: AtomicU64,
    /// Requests whose deadline expired before dispatch (cumulative).
    deadline_expired: AtomicU64,
    /// Requests answered with an error because the model produced a
    /// non-finite mean or variance (cumulative).
    nonfinite_replies: AtomicU64,
}

struct Window {
    /// Per-request end-to-end latency (enqueue → reply), microseconds.
    latencies_us: Vec<f64>,
    /// Micro-batches dispatched in this window.
    batches: u64,
    /// Window start (for points/sec).
    started: Instant,
}

impl Window {
    fn fresh() -> Self {
        Window { latencies_us: Vec::new(), batches: 0, started: Instant::now() }
    }
}

/// A point-in-time summary of one measurement window.
#[derive(Clone, Debug)]
pub struct MetricsReport {
    /// Requests completed in the window.
    pub requests: u64,
    /// Median end-to-end latency (µs).
    pub p50_latency_us: f64,
    /// 99th-percentile end-to-end latency (µs).
    pub p99_latency_us: f64,
    /// Mean end-to-end latency (µs).
    pub mean_latency_us: f64,
    /// Completed points per second over the window.
    pub points_per_sec: f64,
    /// Micro-batches dispatched.
    pub batches: u64,
    /// Mean points per micro-batch.
    pub mean_batch: f64,
    /// Window length (seconds).
    pub elapsed_secs: f64,
    /// Prediction panics caught since engine startup (cumulative — not
    /// reset by `drain`).
    pub panics_caught: u64,
    /// Requests quarantined by batch bisection since startup.
    pub quarantined_requests: u64,
    /// Requests shed because their deadline expired before dispatch.
    pub deadline_expired: u64,
    /// Requests answered with an error for non-finite predictions.
    pub nonfinite_replies: u64,
    /// Engine health at report time (see [`Health`]).
    pub health: Health,
}

impl MetricsReport {
    /// Render as a compact JSON object (used by `vifgp serve` and the
    /// serving bench artifact).
    pub fn to_json(&self) -> String {
        format!(
            concat!(
                "{{\"requests\": {}, \"p50_latency_us\": {:.2}, \"p99_latency_us\": {:.2}, ",
                "\"mean_latency_us\": {:.2}, \"points_per_sec\": {:.1}, \"batches\": {}, ",
                "\"mean_batch\": {:.2}, \"elapsed_secs\": {:.4}, ",
                "\"panics_caught\": {}, \"quarantined_requests\": {}, ",
                "\"deadline_expired\": {}, \"nonfinite_replies\": {}, \"health\": \"{}\"}}"
            ),
            self.requests,
            self.p50_latency_us,
            self.p99_latency_us,
            self.mean_latency_us,
            self.points_per_sec,
            self.batches,
            self.mean_batch,
            self.elapsed_secs,
            self.panics_caught,
            self.quarantined_requests,
            self.deadline_expired,
            self.nonfinite_replies,
            match self.health {
                Health::Healthy => "healthy",
                Health::Degraded => "degraded",
            },
        )
    }
}

/// Nearest-rank percentile of an ascending-sorted slice (`q` in [0,1]).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

impl ServeMetrics {
    pub fn new() -> Self {
        ServeMetrics {
            inner: Mutex::new(Window::fresh()),
            panics_caught: AtomicU64::new(0),
            quarantined_requests: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            nonfinite_replies: AtomicU64::new(0),
        }
    }

    /// Record one dispatched micro-batch (one latency entry per point).
    /// Recovers a poisoned window lock: a panic elsewhere must not take
    /// the metrics down with it.
    pub(crate) fn record_batch(&self, latencies_us: &[f64]) {
        let mut w = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        w.latencies_us.extend_from_slice(latencies_us);
        w.batches += 1;
    }

    pub(crate) fn note_panic(&self) {
        self.panics_caught.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_quarantined(&self, n: u64) {
        self.quarantined_requests.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn note_deadline_expired(&self, n: u64) {
        self.deadline_expired.fetch_add(n, Ordering::Relaxed);
    }

    pub(crate) fn note_nonfinite(&self, n: u64) {
        self.nonfinite_replies.fetch_add(n, Ordering::Relaxed);
    }

    /// Current engine health: `Degraded` once any panic, quarantine, or
    /// non-finite reply has occurred; deadline sheds alone stay
    /// `Healthy` (load shedding is intended behavior).
    pub fn health(&self) -> Health {
        let degraded = self.panics_caught.load(Ordering::Relaxed) > 0
            || self.quarantined_requests.load(Ordering::Relaxed) > 0
            || self.nonfinite_replies.load(Ordering::Relaxed) > 0;
        if degraded {
            Health::Degraded
        } else {
            Health::Healthy
        }
    }

    fn summarize(&self, w: &Window) -> MetricsReport {
        let mut sorted = w.latencies_us.clone();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        let requests = sorted.len() as u64;
        let elapsed = w.started.elapsed().as_secs_f64();
        MetricsReport {
            requests,
            p50_latency_us: percentile(&sorted, 0.50),
            p99_latency_us: percentile(&sorted, 0.99),
            mean_latency_us: if sorted.is_empty() {
                0.0
            } else {
                sorted.iter().sum::<f64>() / sorted.len() as f64
            },
            points_per_sec: if elapsed > 0.0 { requests as f64 / elapsed } else { 0.0 },
            batches: w.batches,
            mean_batch: if w.batches > 0 { requests as f64 / w.batches as f64 } else { 0.0 },
            elapsed_secs: elapsed,
            panics_caught: self.panics_caught.load(Ordering::Relaxed),
            quarantined_requests: self.quarantined_requests.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            nonfinite_replies: self.nonfinite_replies.load(Ordering::Relaxed),
            health: self.health(),
        }
    }

    /// Summarize the current window without resetting it.
    pub fn report(&self) -> MetricsReport {
        let w = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        self.summarize(&w)
    }

    /// Summarize the current window and start a fresh one (the bench's
    /// per-concurrency-sweep reset). Containment counters are cumulative
    /// and survive the reset.
    pub fn drain(&self) -> MetricsReport {
        let mut w = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let report = self.summarize(&w);
        *w = Window::fresh();
        report
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn drain_resets_window() {
        let m = ServeMetrics::new();
        m.record_batch(&[10.0, 20.0, 30.0]);
        let r = m.drain();
        assert_eq!(r.requests, 3);
        assert_eq!(r.batches, 1);
        assert!((r.mean_batch - 3.0).abs() < 1e-12);
        let r2 = m.report();
        assert_eq!(r2.requests, 0);
        assert_eq!(r2.batches, 0);
    }

    #[test]
    fn containment_counters_are_cumulative_across_drains() {
        let m = ServeMetrics::new();
        assert_eq!(m.health(), Health::Healthy);
        m.note_deadline_expired(2);
        // Deadline sheds alone never degrade health (load shedding).
        assert_eq!(m.health(), Health::Healthy);
        m.note_panic();
        m.note_quarantined(1);
        m.note_nonfinite(3);
        assert_eq!(m.health(), Health::Degraded);
        let r = m.drain();
        assert_eq!(r.panics_caught, 1);
        assert_eq!(r.quarantined_requests, 1);
        assert_eq!(r.deadline_expired, 2);
        assert_eq!(r.nonfinite_replies, 3);
        assert_eq!(r.health, Health::Degraded);
        // Counters survive the window reset.
        let r2 = m.report();
        assert_eq!(r2.requests, 0);
        assert_eq!(r2.panics_caught, 1);
        assert_eq!(r2.nonfinite_replies, 3);
        assert_eq!(r2.health, Health::Degraded);
        let json = r2.to_json();
        assert!(json.contains("\"health\": \"degraded\""));
        assert!(json.contains("\"panics_caught\": 1"));
    }
}
