//! Long-lived concurrent serving engine for fitted VIF models
//! (ROADMAP item 1): generation-snapshotted read state, micro-batched
//! request coalescing, and latency/throughput metrics.
//!
//! # Architecture
//!
//! ```text
//! request threads        dispatcher thread            writer thread
//! ──────────────        ─────────────────            ─────────────
//! predict(point) ──► queue (Mutex + Condvar)         append_points /
//!      ▲                    │ coalesce ≤ max_batch    refit on its own
//!      │                    │ within batch_window     model copy, then
//!      │                    ▼                         snapshot()
//!   reply ◄── ServeModel::predict_batch(X_batch)          │
//!              ▲                                          │
//!              └── RwLock<Arc<dyn ServeModel>> ◄── publish(Arc::new(snap))
//! ```
//!
//! * **Generation snapshots.** The engine never mutates model state. It
//!   holds an `Arc<dyn ServeModel>` — in practice a
//!   [`crate::vif::gaussian::FittedGaussian`] or
//!   [`crate::vif::laplace::FittedLaplace`] snapshot, which owns its
//!   structure *and* its per-generation read caches (the prediction
//!   cover tree and the hoisted global mean solves). A writer ingests or
//!   refits on its own authoritative model and [`ServeEngine::publish`]es
//!   a fresh snapshot; the swap is one `Arc` store under a write lock.
//!   Every request batch grabs the current `Arc` once and serves
//!   entirely against that coherent generation, so the
//!   `PredictBlocks::compute` stale-plan panic path is unreachable by
//!   construction: plans are built from the same snapshot they are
//!   evaluated against, and in-flight batches keep the old generation
//!   alive until their last reply is sent (old-complete or new-complete,
//!   never mixed).
//! * **Micro-batching.** Point queries enqueue onto a `Mutex<VecDeque>`;
//!   a dispatcher thread coalesces them — up to
//!   [`ServeOptions::max_batch`] points (default 64, the numeric pass's
//!   column-block width) or until [`ServeOptions::batch_window`] has
//!   passed since the oldest enqueued request — and runs one batched
//!   prediction. The batched numeric pass is per-point independent, so
//!   coalescing changes throughput, never results.
//! * **Metrics.** Per-request end-to-end latency (enqueue → reply) and
//!   batch occupancy land in [`ServeMetrics`]; [`ServeMetrics::drain`]
//!   yields p50/p99/points-per-sec windows for the load bench
//!   (`BENCH_serving.json`, perf_hotpath stage 14).
//!
//! # Env knobs (see the crate-level table)
//!
//! `VIFGP_SERVE_MAX_BATCH`, `VIFGP_SERVE_BATCH_WINDOW_US` configure
//! [`ServeOptions::from_env`]; `VIFGP_SERVE_METRICS_JSON` is consumed by
//! the `vifgp serve` subcommand. Malformed values panic loudly, like
//! every other `VIFGP_*` knob.

mod metrics;

pub use metrics::{MetricsReport, ServeMetrics};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::linalg::Mat;

/// What the engine needs from a fitted model: an immutable, thread-safe
/// batched read path stamped with its structure generation.
///
/// Implementors are *snapshots* — all interior state (including caches)
/// is built at construction, so `predict_batch` is a pure read and may
/// run concurrently from many threads.
pub trait ServeModel: Send + Sync {
    /// Input dimension the model was trained on.
    fn input_dim(&self) -> usize;
    /// Structure generation this snapshot serves.
    fn generation(&self) -> u64;
    /// Batched posterior (mean, variance) at `xp` (one row per point).
    /// Gaussian snapshots return the response-scale mean/variance;
    /// Laplace snapshots the latent mean and deterministic variance.
    fn predict_batch(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>);
}

impl ServeModel for crate::vif::gaussian::FittedGaussian {
    fn input_dim(&self) -> usize {
        self.x.cols()
    }
    fn generation(&self) -> u64 {
        self.generation()
    }
    fn predict_batch(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        self.predict(xp)
    }
}

impl ServeModel for crate::vif::laplace::FittedLaplace {
    fn input_dim(&self) -> usize {
        self.x.cols()
    }
    fn generation(&self) -> u64 {
        self.generation()
    }
    fn predict_batch(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        self.predict(xp)
    }
}

/// Micro-batching knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Maximum points per dispatched batch (≥ 1). Default 64 — the
    /// `PRED_BLOCK` column width of the batched numeric pass, so a full
    /// micro-batch is exactly one block.
    pub max_batch: usize,
    /// How long the dispatcher waits past the *oldest* queued request
    /// for more arrivals before dispatching a partial batch. `0` serves
    /// whatever is queued immediately. Default 200µs.
    pub batch_window: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 64, batch_window: Duration::from_micros(200) }
    }
}

/// Parse an integer env knob loudly: a set-but-malformed value panics
/// (crate policy), absent uses the default.
fn env_knob(name: &str, default: u64, min: u64) -> u64 {
    match std::env::var(name) {
        Ok(s) => match s.parse::<u64>() {
            Ok(v) if v >= min => v,
            _ => panic!("{name} expects an integer ≥ {min}, got `{s}`"),
        },
        Err(_) => default,
    }
}

impl ServeOptions {
    /// Defaults overridden by `VIFGP_SERVE_MAX_BATCH` /
    /// `VIFGP_SERVE_BATCH_WINDOW_US`. Malformed values panic loudly.
    pub fn from_env() -> Self {
        ServeOptions {
            max_batch: env_knob("VIFGP_SERVE_MAX_BATCH", 64, 1) as usize,
            batch_window: Duration::from_micros(env_knob("VIFGP_SERVE_BATCH_WINDOW_US", 200, 0)),
        }
    }
}

/// One served prediction, stamped with the generation that produced it
/// so callers (and the swap-under-traffic tests) can tell which
/// published state they observed.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub mean: f64,
    pub var: f64,
    pub generation: u64,
}

struct Pending {
    point: Vec<f64>,
    enqueued: Instant,
    reply: mpsc::SyncSender<Result<Prediction, String>>,
}

struct Shared {
    /// The published generation. Readers clone the `Arc` once per batch.
    state: RwLock<Arc<dyn ServeModel>>,
    queue: Mutex<VecDeque<Pending>>,
    arrived: Condvar,
    shutdown: AtomicBool,
    opts: ServeOptions,
    metrics: ServeMetrics,
}

/// The serving engine: one dispatcher thread draining a shared request
/// queue into micro-batched reads of the published model snapshot. See
/// the module docs for the full lifecycle.
pub struct ServeEngine {
    shared: Arc<Shared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl ServeEngine {
    /// Start the engine serving `model`.
    pub fn start(model: Arc<dyn ServeModel>, opts: ServeOptions) -> Self {
        assert!(opts.max_batch >= 1, "ServeOptions::max_batch must be ≥ 1");
        let shared = Arc::new(Shared {
            state: RwLock::new(model),
            queue: Mutex::new(VecDeque::new()),
            arrived: Condvar::new(),
            shutdown: AtomicBool::new(false),
            opts,
            metrics: ServeMetrics::new(),
        });
        let worker = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("vifgp-serve".into())
            .spawn(move || dispatcher_loop(&worker))
            .expect("spawn serve dispatcher");
        ServeEngine { shared, dispatcher: Some(dispatcher) }
    }

    /// Serve one point query: enqueue, wait for the micro-batched reply.
    /// Blocks the calling thread; safe from any number of threads.
    pub fn predict(&self, point: &[f64]) -> Result<Prediction, String> {
        let (tx, rx) = mpsc::sync_channel(1);
        {
            let mut q = self.shared.queue.lock().unwrap();
            if self.shared.shutdown.load(Ordering::Acquire) {
                return Err("serving engine is shut down".to_string());
            }
            q.push_back(Pending { point: point.to_vec(), enqueued: Instant::now(), reply: tx });
        }
        self.shared.arrived.notify_one();
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err("serving engine dropped the request".to_string()),
        }
    }

    /// Atomically publish a new model snapshot (a refit or an
    /// `append_points` ingest). In-flight batches finish against the
    /// generation they started with; every later batch sees the new one.
    /// Returns the published generation.
    pub fn publish(&self, model: Arc<dyn ServeModel>) -> u64 {
        let generation = model.generation();
        *self.shared.state.write().unwrap() = model;
        generation
    }

    /// Generation currently being served.
    pub fn current_generation(&self) -> u64 {
        self.shared.state.read().unwrap().generation()
    }

    /// Latency/throughput recorder (use `report()`/`drain()`).
    pub fn metrics(&self) -> &ServeMetrics {
        &self.shared.metrics
    }

    /// Stop accepting requests, serve everything already queued, and
    /// join the dispatcher. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.arrived.notify_all();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn dispatcher_loop(shared: &Shared) {
    loop {
        let batch: Vec<Pending> = {
            let mut q = shared.queue.lock().unwrap();
            // Wait for work (or shutdown with an empty queue → done).
            loop {
                if !q.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.arrived.wait(q).unwrap();
            }
            // Coalesce: fill up to max_batch, bounded by batch_window
            // past the oldest request's enqueue time. On shutdown, flush
            // immediately.
            let deadline = q.front().unwrap().enqueued + shared.opts.batch_window;
            while q.len() < shared.opts.max_batch && !shared.shutdown.load(Ordering::Acquire) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = shared.arrived.wait_timeout(q, deadline - now).unwrap();
                q = guard;
            }
            let take = q.len().min(shared.opts.max_batch);
            q.drain(..take).collect()
        };
        serve_batch(shared, batch);
    }
}

fn serve_batch(shared: &Shared, batch: Vec<Pending>) {
    // One coherent snapshot per batch: the Arc clone pins the generation
    // for the whole dispatch even if a publish lands mid-compute.
    let model = Arc::clone(&shared.state.read().unwrap());
    let d = model.input_dim();
    let generation = model.generation();
    // Reject malformed queries up front; serve the rest as one block.
    let mut ok: Vec<Pending> = Vec::with_capacity(batch.len());
    for p in batch {
        if p.point.len() == d {
            ok.push(p);
        } else {
            let msg = format!("query has dimension {}, model expects {}", p.point.len(), d);
            let _ = p.reply.send(Err(msg));
        }
    }
    if ok.is_empty() {
        return;
    }
    let xp = Mat::from_fn(ok.len(), d, |i, j| ok[i].point[j]);
    let (mean, var) = model.predict_batch(&xp);
    let mut latencies = Vec::with_capacity(ok.len());
    for (i, p) in ok.iter().enumerate() {
        latencies.push(p.enqueued.elapsed().as_secs_f64() * 1e6);
        let _ = p.reply.send(Ok(Prediction { mean: mean[i], var: var[i], generation }));
    }
    shared.metrics.record_batch(&latencies);
}
