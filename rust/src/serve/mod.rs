//! Long-lived concurrent serving engine for fitted VIF models
//! (ROADMAP item 1): generation-snapshotted read state, micro-batched
//! request coalescing, and latency/throughput metrics.
//!
//! # Architecture
//!
//! ```text
//! request threads        dispatcher thread            writer thread
//! ──────────────        ─────────────────            ─────────────
//! predict(point) ──► queue (Mutex + Condvar)         append_points /
//!      ▲                    │ coalesce ≤ max_batch    refit on its own
//!      │                    │ within batch_window     model copy, then
//!      │                    ▼                         snapshot()
//!   reply ◄── ServeModel::predict_batch(X_batch)          │
//!              ▲                                          │
//!              └── RwLock<Arc<dyn ServeModel>> ◄── publish(Arc::new(snap))
//! ```
//!
//! * **Generation snapshots.** The engine never mutates model state. It
//!   holds an `Arc<dyn ServeModel>` — in practice a
//!   [`crate::vif::gaussian::FittedGaussian`] or
//!   [`crate::vif::laplace::FittedLaplace`] snapshot, which owns its
//!   structure *and* its per-generation read caches (the prediction
//!   cover tree and the hoisted global mean solves). A writer ingests or
//!   refits on its own authoritative model and [`ServeEngine::publish`]es
//!   a fresh snapshot; the swap is one `Arc` store under a write lock.
//!   Every request batch grabs the current `Arc` once and serves
//!   entirely against that coherent generation, so the
//!   `PredictBlocks::compute` stale-plan panic path is unreachable by
//!   construction: plans are built from the same snapshot they are
//!   evaluated against, and in-flight batches keep the old generation
//!   alive until their last reply is sent (old-complete or new-complete,
//!   never mixed).
//! * **Micro-batching.** Point queries enqueue onto a `Mutex<VecDeque>`;
//!   a dispatcher thread coalesces them — up to
//!   [`ServeOptions::max_batch`] points (default 64, the numeric pass's
//!   column-block width) or until [`ServeOptions::batch_window`] has
//!   passed since the oldest enqueued request — and runs one batched
//!   prediction. The batched numeric pass is per-point independent, so
//!   coalescing changes throughput, never results.
//! * **Metrics.** Per-request end-to-end latency (enqueue → reply) and
//!   batch occupancy land in [`ServeMetrics`]; [`ServeMetrics::drain`]
//!   yields p50/p99/points-per-sec windows for the load bench
//!   (`BENCH_serving.json`, perf_hotpath stage 14).
//!
//! # Failure containment
//!
//! The dispatcher is **immortal**: its loop body runs under
//! `catch_unwind`, so a panic escaping a batch dispatch (a model bug, or
//! injected via [`crate::faults`]) drops that batch's reply senders —
//! every waiter gets a clean error instead of a hang — and the loop
//! keeps serving. Inside a batch, [`ServeModel::predict_batch`] runs
//! under its own panic net with **bisection quarantine**: if a batch
//! panics, it is split in half and each half retried, until the single
//! poisoned request is isolated and answered with an error while every
//! healthy request in the batch still gets its prediction (one poisoned
//! request costs O(log max_batch) extra dispatches). Non-finite
//! predictions are converted to error replies rather than returned as
//! data. [`ServeEngine::predict_deadline`] adds a per-request client
//! timeout: a request whose deadline has passed when its batch is
//! dispatched is shed with a clean error. All incidents land in
//! cumulative [`ServeMetrics`] counters and fold into a
//! [`Health`] flag (`Degraded` on panic / quarantine / non-finite;
//! deadline sheds alone stay `Healthy`). All engine locks recover from
//! poisoning — a panic anywhere never wedges enqueue, publish, or
//! metrics.
//!
//! # Env knobs (see the crate-level table)
//!
//! `VIFGP_SERVE_MAX_BATCH`, `VIFGP_SERVE_BATCH_WINDOW_US` configure
//! [`ServeOptions::from_env`]; `VIFGP_SERVE_METRICS_JSON` is consumed by
//! the `vifgp serve` subcommand. Malformed values panic loudly, like
//! every other `VIFGP_*` knob.

mod metrics;

pub use metrics::{Health, MetricsReport, ServeMetrics};

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::time::{Duration, Instant};

use crate::linalg::Mat;

/// What the engine needs from a fitted model: an immutable, thread-safe
/// batched read path stamped with its structure generation.
///
/// Implementors are *snapshots* — all interior state (including caches)
/// is built at construction, so `predict_batch` is a pure read and may
/// run concurrently from many threads.
pub trait ServeModel: Send + Sync {
    /// Input dimension the model was trained on.
    fn input_dim(&self) -> usize;
    /// Structure generation this snapshot serves.
    fn generation(&self) -> u64;
    /// Batched posterior (mean, variance) at `xp` (one row per point).
    /// Gaussian snapshots return the response-scale mean/variance;
    /// Laplace snapshots the latent mean and deterministic variance.
    fn predict_batch(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>);
}

impl ServeModel for crate::vif::gaussian::FittedGaussian {
    fn input_dim(&self) -> usize {
        self.x.cols()
    }
    fn generation(&self) -> u64 {
        self.generation()
    }
    fn predict_batch(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        self.predict(xp)
    }
}

impl ServeModel for crate::vif::laplace::FittedLaplace {
    fn input_dim(&self) -> usize {
        self.x.cols()
    }
    fn generation(&self) -> u64 {
        self.generation()
    }
    fn predict_batch(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        self.predict(xp)
    }
}

/// Micro-batching knobs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Maximum points per dispatched batch (≥ 1). Default 64 — the
    /// `PRED_BLOCK` column width of the batched numeric pass, so a full
    /// micro-batch is exactly one block.
    pub max_batch: usize,
    /// How long the dispatcher waits past the *oldest* queued request
    /// for more arrivals before dispatching a partial batch. `0` serves
    /// whatever is queued immediately. Default 200µs.
    pub batch_window: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 64, batch_window: Duration::from_micros(200) }
    }
}

/// Parse an integer env knob loudly: a set-but-malformed value panics
/// (crate policy), absent uses the default.
fn env_knob(name: &str, default: u64, min: u64) -> u64 {
    match std::env::var(name) {
        Ok(s) => match s.parse::<u64>() {
            Ok(v) if v >= min => v,
            _ => panic!("{name} expects an integer ≥ {min}, got `{s}`"),
        },
        Err(_) => default,
    }
}

impl ServeOptions {
    /// Defaults overridden by `VIFGP_SERVE_MAX_BATCH` /
    /// `VIFGP_SERVE_BATCH_WINDOW_US`. Malformed values panic loudly.
    pub fn from_env() -> Self {
        ServeOptions {
            max_batch: env_knob("VIFGP_SERVE_MAX_BATCH", 64, 1) as usize,
            batch_window: Duration::from_micros(env_knob("VIFGP_SERVE_BATCH_WINDOW_US", 200, 0)),
        }
    }
}

/// One served prediction, stamped with the generation that produced it
/// so callers (and the swap-under-traffic tests) can tell which
/// published state they observed.
#[derive(Clone, Copy, Debug)]
pub struct Prediction {
    pub mean: f64,
    pub var: f64,
    pub generation: u64,
}

struct Pending {
    point: Vec<f64>,
    enqueued: Instant,
    /// Client deadline: if this has passed when the batch is dispatched,
    /// the request is shed with a clean error instead of computed.
    deadline: Option<Instant>,
    reply: mpsc::SyncSender<Result<Prediction, String>>,
}

/// Recover a possibly poisoned mutex guard: a panic caught elsewhere
/// (quarantine, fault injection) must never wedge the engine's queue or
/// metrics. Invariants are re-established by the panicking code path
/// itself (replies are per-request; the queue only holds whole entries).
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Shared {
    /// The published generation. Readers clone the `Arc` once per batch.
    state: RwLock<Arc<dyn ServeModel>>,
    queue: Mutex<VecDeque<Pending>>,
    arrived: Condvar,
    shutdown: AtomicBool,
    opts: ServeOptions,
    metrics: ServeMetrics,
}

impl Shared {
    fn current_model(&self) -> Arc<dyn ServeModel> {
        Arc::clone(&self.state.read().unwrap_or_else(|e| e.into_inner()))
    }
}

/// The serving engine: one dispatcher thread draining a shared request
/// queue into micro-batched reads of the published model snapshot. See
/// the module docs for the full lifecycle and failure containment.
pub struct ServeEngine {
    shared: Arc<Shared>,
    dispatcher: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ServeEngine {
    /// Start the engine serving `model`.
    pub fn start(model: Arc<dyn ServeModel>, opts: ServeOptions) -> Self {
        assert!(opts.max_batch >= 1, "ServeOptions::max_batch must be ≥ 1");
        let shared = Arc::new(Shared {
            state: RwLock::new(model),
            queue: Mutex::new(VecDeque::new()),
            arrived: Condvar::new(),
            shutdown: AtomicBool::new(false),
            opts,
            metrics: ServeMetrics::new(),
        });
        let worker = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("vifgp-serve".into())
            .spawn(move || dispatcher_loop(&worker))
            .expect("spawn serve dispatcher");
        ServeEngine { shared, dispatcher: Mutex::new(Some(dispatcher)) }
    }

    /// Serve one point query: enqueue, wait for the micro-batched reply.
    /// Blocks the calling thread; safe from any number of threads.
    pub fn predict(&self, point: &[f64]) -> Result<Prediction, String> {
        self.enqueue_and_wait(point, None)
    }

    /// Like [`Self::predict`], but with a client timeout: if `timeout`
    /// has elapsed by the time the request's batch is dispatched, the
    /// request is shed with a clean error instead of being computed.
    /// A request that makes it into a dispatch is always computed and
    /// answered, even if the computation finishes past the deadline —
    /// the deadline bounds *queueing*, the dominant delay under load.
    pub fn predict_deadline(
        &self,
        point: &[f64],
        timeout: Duration,
    ) -> Result<Prediction, String> {
        self.enqueue_and_wait(point, Some(Instant::now() + timeout))
    }

    fn enqueue_and_wait(
        &self,
        point: &[f64],
        deadline: Option<Instant>,
    ) -> Result<Prediction, String> {
        let (tx, rx) = mpsc::sync_channel(1);
        {
            let mut q = lock_recover(&self.shared.queue);
            if self.shared.shutdown.load(Ordering::Acquire) {
                return Err("serving engine is shut down".to_string());
            }
            q.push_back(Pending {
                point: point.to_vec(),
                enqueued: Instant::now(),
                deadline,
                reply: tx,
            });
        }
        self.shared.arrived.notify_one();
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err("serving engine dropped the request".to_string()),
        }
    }

    /// Atomically publish a new model snapshot (a refit or an
    /// `append_points` ingest). In-flight batches finish against the
    /// generation they started with; every later batch sees the new one.
    /// Returns the published generation.
    pub fn publish(&self, model: Arc<dyn ServeModel>) -> u64 {
        let generation = model.generation();
        *self.shared.state.write().unwrap_or_else(|e| e.into_inner()) = model;
        generation
    }

    /// Generation currently being served.
    pub fn current_generation(&self) -> u64 {
        self.shared.current_model().generation()
    }

    /// Latency/throughput recorder (use `report()`/`drain()`).
    pub fn metrics(&self) -> &ServeMetrics {
        &self.shared.metrics
    }

    /// Current engine health (see [`Health`]): `Degraded` once any
    /// prediction panic, quarantine, or non-finite reply has occurred.
    pub fn health(&self) -> Health {
        self.shared.metrics.health()
    }

    /// Stop accepting requests, serve everything already queued, and
    /// join the dispatcher. Idempotent; also runs on drop. Takes `&self`
    /// so it can be invoked while client threads still hold references
    /// (the shutdown-with-queued-waiters path).
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.arrived.notify_all();
        if let Some(h) = lock_recover(&self.dispatcher).take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Drain the next micro-batch from the queue, or `None` on shutdown
/// with an empty queue (dispatcher exit).
fn next_batch(shared: &Shared) -> Option<Vec<Pending>> {
    let mut q = lock_recover(&shared.queue);
    // Wait for work (or shutdown with an empty queue → done).
    loop {
        if !q.is_empty() {
            break;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            return None;
        }
        q = shared.arrived.wait(q).unwrap_or_else(|e| e.into_inner());
    }
    // Coalesce: fill up to max_batch, bounded by batch_window past the
    // oldest request's enqueue time. On shutdown, flush immediately.
    let deadline = q.front().unwrap().enqueued + shared.opts.batch_window;
    while q.len() < shared.opts.max_batch && !shared.shutdown.load(Ordering::Acquire) {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let (guard, _) = shared
            .arrived
            .wait_timeout(q, deadline - now)
            .unwrap_or_else(|e| e.into_inner());
        q = guard;
    }
    let take = q.len().min(shared.opts.max_batch);
    Some(q.drain(..take).collect())
}

fn dispatcher_loop(shared: &Shared) {
    loop {
        let batch = match next_batch(shared) {
            Some(b) => b,
            None => return,
        };
        // The dispatcher is immortal: any panic escaping a batch — the
        // injected dispatcher fault, or a model bug the per-group
        // quarantine net somehow missed — drops the batch's reply
        // senders (every waiter gets a clean "dropped the request"
        // error, no hang) and the loop keeps serving.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if crate::faults::dispatcher_should_panic() {
                panic!("injected fault: dispatcher loop panic");
            }
            serve_batch(shared, batch);
        }));
        if outcome.is_err() {
            shared.metrics.note_panic();
        }
    }
}

fn serve_batch(shared: &Shared, batch: Vec<Pending>) {
    // One coherent snapshot per batch: the Arc clone pins the generation
    // for the whole dispatch even if a publish lands mid-compute.
    let model = shared.current_model();
    let d = model.input_dim();
    let generation = model.generation();
    crate::faults::serve_delay();
    // Shed expired deadlines and reject malformed queries up front;
    // serve the rest as one block.
    let now = Instant::now();
    let mut ok: Vec<Pending> = Vec::with_capacity(batch.len());
    let mut expired = 0u64;
    for p in batch {
        if p.deadline.is_some_and(|dl| now >= dl) {
            expired += 1;
            let _ = p.reply.send(Err("deadline expired before dispatch".to_string()));
        } else if p.point.len() == d {
            ok.push(p);
        } else {
            let msg = format!("query has dimension {}, model expects {}", p.point.len(), d);
            let _ = p.reply.send(Err(msg));
        }
    }
    if expired > 0 {
        shared.metrics.note_deadline_expired(expired);
    }
    if ok.is_empty() {
        return;
    }
    dispatch_quarantine(shared, model.as_ref(), generation, &ok);
    // Every request in `ok` has been answered (prediction or error);
    // record end-to-end latency for the whole micro-batch.
    let latencies: Vec<f64> =
        ok.iter().map(|p| p.enqueued.elapsed().as_secs_f64() * 1e6).collect();
    shared.metrics.record_batch(&latencies);
}

/// Run `group` through `predict_batch` under a panic net. On success,
/// reply per request (converting non-finite predictions to errors). On
/// a panic, bisect: a group of one *is* the poisoned request —
/// quarantine it with an error reply; larger groups split in half and
/// recurse, so one poisoned request costs O(log max_batch) extra
/// dispatches and every healthy request still gets its prediction.
fn dispatch_quarantine(
    shared: &Shared,
    model: &dyn ServeModel,
    generation: u64,
    group: &[Pending],
) {
    if group.is_empty() {
        return;
    }
    let d = model.input_dim();
    let result = catch_unwind(AssertUnwindSafe(|| {
        let xp = Mat::from_fn(group.len(), d, |i, j| group[i].point[j]);
        crate::faults::serve_check_poison(&xp);
        model.predict_batch(&xp)
    }));
    match result {
        Ok((mean, var)) => {
            let mut nonfinite = 0u64;
            for (i, p) in group.iter().enumerate() {
                if mean[i].is_finite() && var[i].is_finite() {
                    let _ =
                        p.reply.send(Ok(Prediction { mean: mean[i], var: var[i], generation }));
                } else {
                    nonfinite += 1;
                    let _ = p.reply.send(Err(format!(
                        "model produced a non-finite prediction (mean {}, var {})",
                        mean[i], var[i]
                    )));
                }
            }
            if nonfinite > 0 {
                shared.metrics.note_nonfinite(nonfinite);
            }
        }
        Err(_) => {
            shared.metrics.note_panic();
            if group.len() == 1 {
                shared.metrics.note_quarantined(1);
                let _ = group[0]
                    .reply
                    .send(Err("prediction panicked; request quarantined".to_string()));
            } else {
                let mid = group.len() / 2;
                dispatch_quarantine(shared, model, generation, &group[..mid]);
                dispatch_quarantine(shared, model, generation, &group[mid..]);
            }
        }
    }
}
