//! Pseudo-random number generation substrate.
//!
//! The offline registry carries no `rand` crate, so the library ships its
//! own xoshiro256++ generator (seeded via splitmix64) together with the
//! samplers the paper's experiments need: standard normals (Box–Muller),
//! Rademacher probe vectors (Hutchinson/STE), gamma (Marsaglia–Tsang),
//! Poisson, and Student-t variates.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic generator from a 64-bit seed.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-thread RNGs).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.cached_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Rademacher ±1 vector (STE probe vectors, Algorithm 2).
    pub fn rademacher_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Gamma(shape, scale=1) via Marsaglia–Tsang (2000).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: X_a = X_{a+1} * U^{1/a}
            let x = self.gamma(shape + 1.0);
            let u: f64 = self.uniform().max(f64::MIN_POSITIVE);
            return x * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let mut x;
            let mut v;
            loop {
                x = self.normal();
                v = 1.0 + c * x;
                if v > 0.0 {
                    break;
                }
            }
            v = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Poisson(mean) — Knuth for small means, normal approximation tail.
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Gaussian approximation with continuity correction.
            let z = self.normal();
            let v = mean + mean.sqrt() * z + 0.5;
            if v < 0.0 {
                0
            } else {
                v.floor() as u64
            }
        }
    }

    /// Student-t with `df` degrees of freedom.
    pub fn student_t(&mut self, df: f64) -> f64 {
        let z = self.normal();
        let g = self.gamma(df / 2.0) * 2.0; // chi2(df)
        z / (g / df).sqrt()
    }

    /// Bernoulli with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::seed_from(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn gamma_mean_var() {
        let mut r = Rng::seed_from(9);
        let shape = 3.5;
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(shape)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - shape).abs() < 0.06, "mean {mean}");
        assert!((var - shape).abs() < 0.2, "var {var}");
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Rng::seed_from(10);
        let shape = 0.4;
        let n = 40_000;
        let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
        assert!((mean - shape).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::seed_from(11);
        for &mu in &[0.5, 4.0, 60.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(mu) as f64).sum::<f64>() / n as f64;
            assert!((mean - mu).abs() < 0.05 * mu.max(1.0), "mu {mu} mean {mean}");
        }
    }

    #[test]
    fn rademacher_is_pm_one() {
        let mut r = Rng::seed_from(3);
        let v = r.rademacher_vec(1000);
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
        let mean: f64 = v.iter().sum::<f64>() / 1000.0;
        assert!(mean.abs() < 0.1);
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::seed_from(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn student_t_symmetric() {
        let mut r = Rng::seed_from(13);
        let n = 40_000;
        let mean: f64 = (0..n).map(|_| r.student_t(8.0)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn split_streams_differ() {
        let mut base = Rng::seed_from(1);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
