//! L3 coordinator: parallel execution substrate, experiment scheduling,
//! and result aggregation.
//!
//! The offline registry has no `rayon`/`tokio`, so this module provides
//! the coordination primitives the library needs from `std::thread`:
//!
//! * [`parallel_for_chunks`] / [`parallel_map`] — scoped data-parallel
//!   loops used by the Vecchia factor build, covariance panels, CG probe
//!   vectors, and cover-tree partitions;
//! * [`ThreadPool`] — a long-lived work queue for heterogeneous jobs
//!   (cross-validation folds, parameter sweeps);
//! * [`ResultsTable`] — experiment-result accumulation and rendering in
//!   the row format the paper's tables use.

mod pool;
mod table;

pub use pool::{in_pool_worker, ThreadPool};
pub use table::ResultsTable;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads to use (`VIFGP_THREADS` overrides the
/// detected parallelism). Resolved once and cached: the hot sweep
/// kernels consult this on every dispatch, and `std::env::var` takes a
/// process-wide lock. Set the variable before first use (the CLI's
/// `--threads` does), not mid-run.
///
/// A set-but-malformed `VIFGP_THREADS` (including `0`) panics loudly —
/// the crate-doc policy for every `VIFGP_*` knob (see
/// `VIFGP_SCHED_THRESHOLD`) — instead of silently running on the
/// detected parallelism.
pub fn num_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(s) = std::env::var("VIFGP_THREADS") {
            return match s.parse::<usize>() {
                Ok(v) if v >= 1 => v,
                _ => panic!("VIFGP_THREADS expects a positive integer, got `{s}`"),
            };
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// The process-wide worker pool used by the batched iterative solvers
/// (column blocks, probe fan-out). Lazily created with [`num_threads`]
/// workers and kept alive for the process lifetime, so per-call thread
/// spawning is amortized across the many small dispatches a blocked CG
/// iteration makes.
pub fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(num_threads()))
}

/// Parallel map for *small counts of heavy items* (grain = 1): dispatches
/// each item to the global pool even when `n` is far below the
/// [`parallel_for_chunks`] threshold. Runs inline when parallelism is
/// unavailable or the caller is already on a pool worker.
pub fn parallel_map_heavy<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    if n <= 1 || num_threads() <= 1 || in_pool_worker() {
        return (0..n).map(&f).collect();
    }
    let fref = &f;
    let jobs: Vec<Box<dyn FnOnce() -> T + Send + '_>> = (0..n)
        .map(|i| Box::new(move || fref(i)) as Box<dyn FnOnce() -> T + Send + '_>)
        .collect();
    global_pool().run_scoped(jobs)
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on the worker
/// threads. `f` must be safe to run concurrently on disjoint ranges.
pub fn parallel_for_chunks(n: usize, f: impl Fn(usize, usize) + Sync) {
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n < 256 {
        f(0, n);
        return;
    }
    let counter = AtomicUsize::new(0);
    // Dynamic scheduling in modest grains to balance uneven per-item cost
    // (early Vecchia rows have fewer neighbors than later ones).
    let grain = (n / (workers * 8)).max(32);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let start = counter.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + grain).min(n);
                f(start, end);
            });
        }
    });
}

/// Parallel map over `0..n` writing `out[i] = f(i)`. The output vector is
/// index-partitioned across threads.
pub fn parallel_map<T: Send + Sync + Default + Clone>(
    n: usize,
    f: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SyncSlice(out.as_mut_ptr());
        parallel_for_chunks(n, |start, end| {
            for i in start..end {
                // SAFETY: each index is visited exactly once across all chunks.
                unsafe {
                    *out_ptr.get().add(i) = f(i);
                }
            }
        });
    }
    out
}

/// Shares a raw pointer across scoped threads; callers guarantee disjoint
/// index access. (A method accessor is used so the 2021-edition closure
/// captures the wrapper, not the raw-pointer field.)
pub struct SyncSlice<T>(pub *mut T);
unsafe impl<T: Send> Sync for SyncSlice<T> {}
impl<T> SyncSlice<T> {
    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(n, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_matches_serial() {
        let out = parallel_map(5000, |i| (i * i) as u64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * i) as u64);
        }
    }

    #[test]
    fn small_n_runs_inline() {
        let out = parallel_map(3, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3]);
    }
}
