//! Experiment-result table: accumulate (row, column) → repeated values,
//! render mean ± 2·SE in the format the paper's tables use.

use std::collections::BTreeMap;

/// A results table keyed by row label and column label; each cell holds
/// all replicate values so means and standard errors can be reported.
#[derive(Default)]
pub struct ResultsTable {
    title: String,
    cells: BTreeMap<(String, String), Vec<f64>>,
    row_order: Vec<String>,
    col_order: Vec<String>,
}

impl ResultsTable {
    pub fn new(title: &str) -> Self {
        ResultsTable { title: title.to_string(), ..Default::default() }
    }

    /// Record one replicate value in cell (row, col).
    pub fn record(&mut self, row: &str, col: &str, value: f64) {
        if !self.row_order.iter().any(|r| r == row) {
            self.row_order.push(row.to_string());
        }
        if !self.col_order.iter().any(|c| c == col) {
            self.col_order.push(col.to_string());
        }
        self.cells
            .entry((row.to_string(), col.to_string()))
            .or_default()
            .push(value);
    }

    /// Mean of a cell, NaN if empty.
    pub fn mean(&self, row: &str, col: &str) -> f64 {
        match self.cells.get(&(row.to_string(), col.to_string())) {
            Some(v) if !v.is_empty() => v.iter().sum::<f64>() / v.len() as f64,
            _ => f64::NAN,
        }
    }

    /// Two standard errors of a cell (paper's ±2 SE convention).
    pub fn two_se(&self, row: &str, col: &str) -> f64 {
        match self.cells.get(&(row.to_string(), col.to_string())) {
            Some(v) if v.len() > 1 => {
                let n = v.len() as f64;
                let mean = v.iter().sum::<f64>() / n;
                let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
                2.0 * (var / n).sqrt()
            }
            _ => 0.0,
        }
    }

    /// Render the table as aligned text (mean ± 2SE per cell).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let w = 22;
        out.push_str(&format!("{:<18}", ""));
        for c in &self.col_order {
            out.push_str(&format!("{:>w$}", c, w = w));
        }
        out.push('\n');
        for r in &self.row_order {
            out.push_str(&format!("{:<18}", r));
            for c in &self.col_order {
                let m = self.mean(r, c);
                let se = self.two_se(r, c);
                let cell = if m.is_nan() {
                    "—".to_string()
                } else if se > 0.0 {
                    format!("{m:.4}±{se:.4}")
                } else {
                    format!("{m:.4}")
                };
                out.push_str(&format!("{:>w$}", cell, w = w));
            }
            out.push('\n');
        }
        out
    }

    /// Render as CSV (one line per cell with all replicates averaged).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("row,col,mean,two_se,n\n");
        for r in &self.row_order {
            for c in &self.col_order {
                if let Some(v) = self.cells.get(&(r.clone(), c.clone())) {
                    out.push_str(&format!(
                        "{r},{c},{},{},{}\n",
                        self.mean(r, c),
                        self.two_se(r, c),
                        v.len()
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_se() {
        let mut t = ResultsTable::new("t");
        t.record("a", "rmse", 1.0);
        t.record("a", "rmse", 3.0);
        assert!((t.mean("a", "rmse") - 2.0).abs() < 1e-12);
        // sample var = 2, se = sqrt(2/2)=1, 2se=2
        assert!((t.two_se("a", "rmse") - 2.0).abs() < 1e-12);
    }

    #[test]
    fn render_contains_labels() {
        let mut t = ResultsTable::new("demo");
        t.record("VIF", "rmse", 0.5);
        let s = t.render();
        assert!(s.contains("demo") && s.contains("VIF") && s.contains("rmse"));
        assert!(t.to_csv().contains("VIF,rmse,0.5"));
    }

    #[test]
    fn missing_cell_is_nan() {
        let t = ResultsTable::new("x");
        assert!(t.mean("nope", "nope").is_nan());
    }
}
