//! A minimal long-lived thread pool for heterogeneous jobs
//! (cross-validation folds, sweep points). Jobs are boxed closures; the
//! pool is dropped by joining all workers after the queue closes.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool with a shared FIFO queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|_| {
                let rx = Arc::clone(&receiver);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed
                    }
                })
            })
            .collect();
        ThreadPool { sender: Some(sender), workers }
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool closed")
            .send(Box::new(job))
            .expect("worker hung up");
    }

    /// Run a batch of jobs to completion, returning outputs in order.
    pub fn run_batch<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (tx, rx) = mpsc::channel();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.execute(move || {
                let out = job();
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, out) in rx {
            slots[i] = Some(out);
        }
        slots.into_iter().map(|s| s.expect("job lost")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn run_batch_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..20usize).map(|i| Box::new(move || i * 7) as _).collect();
        let out = pool.run_batch(jobs);
        assert_eq!(out, (0..20).map(|i| i * 7).collect::<Vec<_>>());
    }
}
