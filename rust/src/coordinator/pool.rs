//! A minimal long-lived thread pool for heterogeneous jobs
//! (cross-validation folds, sweep points, batched-solver column blocks).
//! Jobs are boxed closures; the pool is dropped by joining all workers
//! after the queue closes.

use std::cell::Cell;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    static IN_POOL_WORKER: Cell<bool> = Cell::new(false);
}

/// Whether the current thread is one of a [`ThreadPool`]'s workers.
/// Scoped batch submitters consult this to run nested work inline instead
/// of re-entering the queue (which could deadlock a saturated pool).
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|f| f.get())
}

/// Fixed-size worker pool with a shared FIFO queue. The sender side is
/// mutex-wrapped so a pool can live in a `static` and be used from many
/// threads at once.
pub struct ThreadPool {
    sender: Option<Mutex<mpsc::Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|_| {
                let rx = Arc::clone(&receiver);
                std::thread::spawn(move || {
                    IN_POOL_WORKER.with(|f| f.set(true));
                    loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    }
                })
            })
            .collect();
        ThreadPool { sender: Some(Mutex::new(sender)), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool closed")
            .lock()
            .unwrap()
            .send(Box::new(job))
            .expect("worker hung up");
    }

    /// Run a batch of jobs to completion, returning outputs in order.
    pub fn run_batch<T: Send + 'static>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'static>>,
    ) -> Vec<T> {
        let n = jobs.len();
        let (tx, rx) = mpsc::channel();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.execute(move || {
                let out = job();
                let _ = tx.send((i, out));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, out) in rx {
            slots[i] = Some(out);
        }
        slots.into_iter().map(|s| s.expect("job lost")).collect()
    }

    /// Run a batch of *borrowing* jobs to completion, returning outputs in
    /// order. Unlike [`run_batch`](Self::run_batch), the jobs may borrow
    /// from the caller's stack: this call blocks until every job has
    /// finished (panics included), so no borrow escapes.
    ///
    /// Called from inside a pool worker, the jobs run inline on the
    /// current thread — a saturated pool waiting on its own queue would
    /// otherwise deadlock.
    pub fn run_scoped<'env, T: Send + 'env>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
    ) -> Vec<T> {
        use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
        if in_pool_worker() {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let njobs = jobs.len();
        let (tx, rx) = mpsc::channel::<(usize, std::thread::Result<T>)>();
        for (i, job) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(job));
                let _ = tx.send((i, out));
            });
            // SAFETY: the receive loop below blocks until every submitted
            // job has sent its result — catch_unwind guarantees a send even
            // on panic — so no job (or its borrows) outlives this call.
            let wrapped: Box<dyn FnOnce() + Send + 'static> =
                unsafe { std::mem::transmute(wrapped) };
            self.execute(wrapped);
        }
        drop(tx);
        let mut slots: Vec<Option<std::thread::Result<T>>> = (0..njobs).map(|_| None).collect();
        for _ in 0..njobs {
            let (i, out) = rx.recv().expect("pool worker lost");
            slots[i] = Some(out);
        }
        let mut out = Vec::with_capacity(njobs);
        for slot in slots {
            match slot.expect("job result missing") {
                Ok(v) => out.push(v),
                Err(payload) => resume_unwind(payload),
            }
        }
        out
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn run_batch_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..20usize).map(|i| Box::new(move || i * 7) as _).collect();
        let out = pool.run_batch(jobs);
        assert_eq!(out, (0..20).map(|i| i * 7).collect::<Vec<_>>());
    }

    #[test]
    fn run_scoped_borrows_from_stack() {
        let pool = ThreadPool::new(3);
        let data: Vec<usize> = (0..50).collect();
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send + '_>> = data
            .iter()
            .map(|v| Box::new(move || v * 3) as Box<dyn FnOnce() -> usize + Send + '_>)
            .collect();
        let out = pool.run_scoped(jobs);
        assert_eq!(out, (0..50).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn run_scoped_nested_runs_inline() {
        // A scoped batch submitted from inside a worker must not deadlock.
        let outer = ThreadPool::new(1);
        let inner = Arc::new(ThreadPool::new(1));
        let i2 = Arc::clone(&inner);
        let (tx, rx) = mpsc::channel();
        outer.execute(move || {
            let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
                (0..4usize).map(|i| Box::new(move || i + 1) as _).collect();
            let out = i2.run_scoped(jobs);
            let _ = tx.send(out.iter().sum::<usize>());
        });
        assert_eq!(rx.recv().unwrap(), 10);
        // Join the outer worker first so `inner`'s last Arc drops on this
        // thread (a pool must never be dropped from its own worker).
        drop(outer);
        drop(inner);
    }
}
