//! Shared panelized prediction pipeline for the VIF approximation
//! (Prop 2.1 for the Gaussian model, Prop 3.1 for the Laplace model,
//! both with prediction points conditioning on training points only, so
//! `B_p = I` and `D_p` is diagonal).
//!
//! Both models' predictive distributions have the same structure: each
//! prediction point `p` conditions on a set `N(p)` of training points
//! through residual-process regression weights `A_p` and a conditional
//! variance `D_p`, plus low-rank Woodbury corrections through
//! `k_p = K(s_p, Z)`. Before this module the two `predict` bodies were
//! copy-pasted scalar hot loops; prediction is the serving hot path, so
//! it now runs through the same symbolic/numeric split and panel
//! machinery as training assembly (see the `vif` module docs):
//!
//! * [`PredictPlan`] is the **θ-frozen symbolic half**: the per-point
//!   conditioning sets `N(p)` among training points (searched through
//!   the batched correlation metric — cover tree or brute force — or
//!   the λ-scaled Euclidean metric), the pre-gathered training
//!   coordinate panels ([`NeighborPanels`]) the numeric pass reads
//!   instead of re-copying coordinates, and the CSC-style scatter
//!   pattern of `B_poᵀ` that turns the Laplace adjoint projection into
//!   a deterministic per-training-row gather. A plan is built once and
//!   reused across repeated `predict` calls at fixed θ — exactly the
//!   serving scenario. It is **invalidated** by anything that changes
//!   what it froze: new kernel parameters θ (the conditioning sets and
//!   panels were selected under the old metric), a re-assembled or
//!   refreshed [`VifStructure`], or different training/prediction
//!   inputs.
//! * [`PredictBlocks`] is the **θ-dependent numeric half**: one
//!   `K(X_p, Z)` panel for all prediction points, blocked `Σ_m`
//!   triangular solves for the `α_p`/`v_p` columns, per-point `ρ_NN`
//!   blocks evaluated through the panel kernels
//!   ([`ArdMatern::sym_cov_panel`] + SYRK low-rank rank updates —
//!   no scalar per-pair `kernel.cov` calls remain), and the mean and
//!   deterministic-variance Woodbury terms batched over column blocks
//!   of prediction points as small GEMMs plus one `M⁻¹` block solve per
//!   block. Global solves (`Σ_†⁻¹ y`, `Σ_mn Σ_†⁻¹ y`, the residual
//!   target `y − Σ_mnᵀ M⁻¹ Σ_mn S y`) are hoisted out of the per-point
//!   loop entirely.
//!
//! The Laplace stochastic variance corrections (Algorithms 1–2) consume
//! the same blocks through [`project_q_batch`] / [`project_qt_batch`]:
//! `Q`/`Qᵀ` applied to whole probe blocks as one GEMM + one
//! level-scheduled `S⁻¹` sweep per block, feeding
//! `iterative::pred_var::{sbpv_diag, spv_diag}` so every probe system —
//! CG solves *and* projections — is a multi-RHS batch.

use std::borrow::Cow;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::covertree::{CoverTree, Metric, QueryScratch};
use crate::kernels::ArdMatern;
use crate::linalg::{dot, CholeskyFactor, Mat};
use crate::vecchia::neighbors::NeighborSelection;

use super::{gather_rows, LowRank, NeighborPanels, VifStructure, PANEL_SCRATCH};
use crate::coordinator::SyncSlice;

/// Column-block width for the batched numeric pass (bounds the size of
/// the per-block GEMM operands and `M⁻¹` solves).
const PRED_BLOCK: usize = 64;

/// θ-frozen symbolic half of the prediction pipeline: conditioning sets,
/// pre-gathered coordinate panels, and the `B_poᵀ` scatter pattern. See
/// the module docs for reuse and invalidation rules.
pub struct PredictPlan {
    /// Per-prediction-point conditioning sets `N(p)` among training
    /// points (ascending training indices).
    pub neighbors: Vec<Vec<u32>>,
    /// Pre-gathered training-coordinate panels, one `|N(p)| × d` block
    /// per prediction point.
    x_panels: NeighborPanels,
    /// CSC-style pattern of `B_poᵀ`: for training row `j`, the entries
    /// `bt_entries[bt_ptr[j]..bt_ptr[j+1]]` list the `(p, slot)` pairs
    /// with `j = N(p)[slot]`, ascending in `p`.
    bt_ptr: Vec<usize>,
    bt_entries: Vec<(u32, u32)>,
    /// Low-rank panels carried over from the correlation neighbor
    /// search so the numeric pass does not recompute the `K(X_p, Z)`
    /// panel or its forward substitutions. `None` for
    /// Euclidean-selection or externally supplied plans.
    lr_panels: Option<LrPanelCache>,
    /// Generation of the [`VifStructure`] the plan was built against
    /// (0 = externally built, unchecked). The numeric pass refuses a
    /// mismatch: an append/compact/re-selection changed the training
    /// point set, so the frozen conditioning sets index the wrong rows
    /// — recomputation could not save the plan, unlike the soft
    /// θ/Z-keyed panel-cache fallback.
    generation: u64,
}

/// Process-wide count of soft panel-cache fallbacks: a plan reused after
/// a θ or inducing-set change had its `K(X_p, Z)` panels recomputed
/// instead of served from the cache. Cheap observability for the
/// "silently degrades to recomputation" path — serving setups polling
/// this can tell cache-hot plans from ones that should be rebuilt.
static LR_PANEL_MISSES: AtomicU64 = AtomicU64::new(0);

/// Cumulative [`PredictPlan`] panel-cache misses in this process (see
/// [`PredictBlocks::compute`]; debug builds also log each miss).
pub fn lr_panel_cache_misses() -> u64 {
    LR_PANEL_MISSES.load(Ordering::Relaxed)
}

/// θ-dependent low-rank panels cached on a [`PredictPlan`], keyed by
/// the kernel parameters and inducing inputs they were evaluated at.
/// [`PredictBlocks::compute`] only trusts the cache when the key still
/// matches the structure it is given, so a stale plan (reused across a
/// refit, against the documented invalidation contract) degrades to
/// recomputation instead of silently wrong numbers.
struct LrPanelCache {
    /// Packed kernel log-parameters at evaluation time.
    theta: Vec<f64>,
    /// Inducing inputs at evaluation time.
    z: Mat,
    /// `K(X_p, Z)` (`n_p × m`).
    kp: Mat,
    /// `(L_m⁻¹ K(Z, X_p))ᵀ` (`n_p × m`).
    vt: Mat,
}

impl PredictPlan {
    /// Build a plan for prediction inputs `xp`: search the conditioning
    /// sets under the structure's residual process at the current θ,
    /// then freeze panels and the scatter pattern.
    pub fn build(
        s: &VifStructure,
        x: &Mat,
        kernel: &ArdMatern,
        xp: &Mat,
        m_v: usize,
        selection: NeighborSelection,
    ) -> Self {
        let (neighbors, lr_panels) = pred_neighbor_sets(s, x, kernel, xp, m_v, selection);
        let mut plan = Self::from_neighbor_sets(x, neighbors);
        plan.lr_panels = lr_panels;
        plan.generation = s.generation;
        plan
    }

    /// Build a plan from externally chosen conditioning sets (tests and
    /// oracles; `neighbors[p]` indexes rows of `x`).
    pub fn from_neighbor_sets(x: &Mat, neighbors: Vec<Vec<u32>>) -> Self {
        let x_panels = NeighborPanels::gather(x, &neighbors);
        let n = x.rows();
        let mut bt_ptr = vec![0usize; n + 1];
        for nb in &neighbors {
            for &j in nb {
                bt_ptr[j as usize + 1] += 1;
            }
        }
        for j in 0..n {
            bt_ptr[j + 1] += bt_ptr[j];
        }
        let mut bt_entries = vec![(0u32, 0u32); bt_ptr[n]];
        let mut cursor = bt_ptr.clone();
        for (p, nb) in neighbors.iter().enumerate() {
            for (k, &j) in nb.iter().enumerate() {
                let c = &mut cursor[j as usize];
                bt_entries[*c] = (p as u32, k as u32);
                *c += 1;
            }
        }
        PredictPlan {
            neighbors,
            x_panels,
            bt_ptr,
            bt_entries,
            lr_panels: None,
            generation: 0,
        }
    }

    /// Like [`PredictPlan::build`], but reuses a per-generation
    /// [`PredSearchCache`] so repeated small-batch builds (the serving
    /// micro-batch path) skip the per-call cover-tree construction. A
    /// cache keyed for a different generation or θ is ignored (counted
    /// by [`pred_search_cache_misses`]) and the per-call path runs —
    /// same soft-fallback contract as the low-rank panel cache.
    pub fn build_cached(
        s: &VifStructure,
        x: &Mat,
        kernel: &ArdMatern,
        xp: &Mat,
        m_v: usize,
        selection: NeighborSelection,
        search: Option<&PredSearchCache>,
    ) -> Self {
        let tree = search.and_then(|c| {
            if c.generation == s.generation && c.theta == kernel.log_params() {
                c.tree.as_ref()
            } else {
                PRED_SEARCH_MISSES.fetch_add(1, Ordering::Relaxed);
                None
            }
        });
        let (neighbors, lr_panels) =
            pred_neighbor_sets_with(s, x, kernel, xp, m_v, selection, tree);
        let mut plan = Self::from_neighbor_sets(x, neighbors);
        plan.lr_panels = lr_panels;
        plan.generation = s.generation;
        plan
    }

    /// Number of prediction points the plan covers.
    pub fn n_points(&self) -> usize {
        self.neighbors.len()
    }

    /// Generation of the structure this plan was built against
    /// (0 = externally built plan, exempt from the staleness check).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Non-panicking form of the [`PredictBlocks::compute`] staleness
    /// check: `true` when the plan may be used against `s` (built for
    /// the same structure generation, or externally built and therefore
    /// unchecked). The serving read path consults this before the
    /// numeric pass so a racing `append`/`compact` downgrades to a plan
    /// rebuild instead of a panic.
    pub fn is_current(&self, s: &VifStructure) -> bool {
        self.generation == 0 || self.generation == s.generation
    }
}

/// Process-wide count of [`PredSearchCache`] key mismatches (generation
/// or θ moved since the cache was built); the same observability hook as
/// [`lr_panel_cache_misses`].
static PRED_SEARCH_MISSES: AtomicU64 = AtomicU64::new(0);

/// Cumulative [`PredSearchCache`] misses in this process.
pub fn pred_search_cache_misses() -> u64 {
    PRED_SEARCH_MISSES.load(Ordering::Relaxed)
}

/// Per-generation neighbor-search state shared across plan builds: one
/// correlation cover tree over the **training** points, reusable for any
/// batch of prediction queries at the same `(generation, θ)`. The tree
/// only encodes training–training distances, so a query batch `X_p`
/// supplies its own stacked metric at search time; building it once per
/// published generation turns the serving micro-batch path from
/// `O(n·depth)` metric evaluations per batch into a lookup.
pub struct PredSearchCache {
    tree: Option<CoverTree>,
    theta: Vec<f64>,
    generation: u64,
}

impl PredSearchCache {
    /// Build the search cache for the current `(structure, θ)`. Only the
    /// correlation cover-tree selection has per-generation state; other
    /// selections yield an empty cache (plan builds fall through to the
    /// per-call path).
    pub fn build(
        s: &VifStructure,
        x: &Mat,
        kernel: &ArdMatern,
        selection: NeighborSelection,
    ) -> Self {
        let n = x.rows();
        let tree = if selection == NeighborSelection::CorrelationCoverTree && n > 0 {
            let empty = Mat::zeros(0, x.cols());
            // Panels for an empty query set: the metric only ever sees
            // training indices during the build.
            let vt_empty = s.lr.as_ref().map(|lr| pred_lr_panels(lr, kernel, &empty).1);
            let metric = PredCorrelationMetric::new(s, x, kernel, &empty, vt_empty.as_ref());
            Some(CoverTree::build(n, &metric))
        } else {
            None
        };
        PredSearchCache { tree, theta: kernel.log_params(), generation: s.generation }
    }

    /// Generation of the structure the cache was built against.
    pub fn generation(&self) -> u64 {
        self.generation
    }
}

/// Hoisted global solves of [`posterior_mean`] for a fixed
/// `(structure, target)`: the residual-scale target
/// `t − Σ_mnᵀ M⁻¹ Σ_mn S t` and the contraction `Σ_mn Σ_†⁻¹ t`. Both
/// are per-θ-generation constants of the serving read path — computing
/// them per `predict` call costs `O(n·m)` plus a Vecchia sweep, so a
/// long-lived server builds one `MeanCache` per published generation and
/// every request batch reuses it through [`posterior_mean_cached`].
pub struct MeanCache {
    generation: u64,
    /// `t − Σ_mnᵀ M⁻¹ Σ_mn S t` (length n).
    resid_target: Vec<f64>,
    /// `Σ_mn Σ_†⁻¹ t` (length m; `None` when the structure has no
    /// low-rank part).
    smu: Option<Vec<f64>>,
}

impl MeanCache {
    /// Run the global solves once for `target` (`y` on the Gaussian
    /// response scale, the Laplace mode `b̃` on the latent scale).
    pub fn build(s: &VifStructure, target: &[f64]) -> Self {
        let resid_target: Vec<f64> = match (&s.lr, &s.chol_mcal) {
            (Some(lr), Some(cm)) => {
                // t − Σ_mnᵀ M⁻¹ Σ_mn S t : the residual-scale target (§2.3).
                let c = cm.solve(&s.ssig.matvec_t(target));
                let corr = lr.sigma_nm.matvec(&c);
                target.iter().zip(&corr).map(|(t, co)| t - co).collect()
            }
            _ => target.to_vec(),
        };
        let smu = s.lr.as_ref().map(|lr| {
            let u = s.apply_sigma_dagger_inv(target);
            lr.sigma_nm.matvec_t(&u) // hoisted: one O(n·m) pass
        });
        MeanCache { generation: s.generation, resid_target, smu }
    }

    /// Generation of the structure the cache was built against.
    pub fn generation(&self) -> u64 {
        self.generation
    }
}

/// θ-dependent numeric half: the per-point conditional blocks and the
/// batched deterministic mean/variance ingredients (module docs).
pub struct PredictBlocks<'a> {
    /// Regression weights `A_p` on `N(p)`.
    pub a_rows: Vec<Vec<f64>>,
    /// Conditional variances `D_p` (the structure's nugget included;
    /// floored at `1e-12`).
    pub d: Vec<f64>,
    /// `k_p = K(X_p, Z)` rows (`n_p × m`; `n_p × 0` when `m = 0`) —
    /// borrowed from the plan's panel cache when that is still valid,
    /// owned otherwise.
    pub kp: Cow<'a, Mat>,
    /// `α_p = Σ_m⁻¹ k_p` rows (`n_p × m`).
    pub alpha: Mat,
    /// Deterministic predictive variance — `D_p` plus the Woodbury
    /// terms of Eq. 20 / App. C.1 with `B_p = I` (floored at `1e-12`).
    /// For the Gaussian model this is the full response variance; the
    /// Laplace model adds the stochastic correction (21) on top.
    pub var_det: Vec<f64>,
}

impl<'a> PredictBlocks<'a> {
    /// Run the numeric pass for `xp` against a frozen plan.
    /// `block_jitter` is the base jitter of the per-point `ρ_NN`
    /// Cholesky factorizations (the Gaussian path uses `1e-10` — its
    /// blocks carry the noise nugget on the diagonal — and the
    /// latent-scale Laplace path `1e-8`).
    pub fn compute(
        s: &VifStructure,
        kernel: &ArdMatern,
        xp: &Mat,
        plan: &'a PredictPlan,
        block_jitter: f64,
    ) -> Self {
        let np = plan.n_points();
        assert!(
            plan.generation == 0 || plan.generation == s.generation,
            "stale prediction plan: built for structure generation {}, structure is at {} \
             (append_points/compact/reselect invalidates prediction plans — rebuild via \
             build_predict_plan)",
            plan.generation,
            s.generation
        );
        assert_eq!(xp.rows(), np, "plan built for different prediction inputs");
        let m = s.m();
        let nugget = s.nugget;
        // Trust the plan's panel cache only when it was evaluated at
        // this exact θ and inducing set; count key mismatches so the
        // silent fall-back to recomputation stays observable.
        let cache = match (plan.lr_panels.as_ref(), &s.lr) {
            (Some(c), Some(lr)) => {
                if c.theta == kernel.log_params() && c.z == lr.z {
                    Some(c)
                } else {
                    LR_PANEL_MISSES.fetch_add(1, Ordering::Relaxed);
                    if cfg!(debug_assertions) {
                        eprintln!(
                            "vifgp: predict plan low-rank panel cache miss \
                             (θ or Z changed since the plan was built); recomputing panels"
                        );
                    }
                    None
                }
            }
            _ => None,
        };
        let kp: Cow<'a, Mat> = match (&s.lr, cache) {
            (Some(_), Some(c)) => Cow::Borrowed(&c.kp),
            (Some(lr), None) => {
                Cow::Owned(crate::runtime::cross_cov_panel(xp, &lr.z, kernel))
            }
            (None, _) => Cow::Owned(Mat::zeros(np, 0)),
        };
        let mut a_rows: Vec<Vec<f64>> = vec![vec![]; np];
        let mut d = vec![0.0; np];
        let mut alpha = Mat::zeros(np, m);
        let mut var_det = vec![0.0; np];
        if np == 0 {
            return PredictBlocks { a_rows, d, kp, alpha, var_det };
        }
        let nblocks = np.div_ceil(PRED_BLOCK);
        {
            let ap = SyncSlice(a_rows.as_mut_ptr());
            let dp = SyncSlice(d.as_mut_ptr());
            let alp = SyncSlice(alpha.data_mut().as_mut_ptr());
            let vp = SyncSlice(var_det.as_mut_ptr());
            let (ap, dp, alp, vp) = (&ap, &dp, &alp, &vp);
            crate::coordinator::parallel_map_heavy(nblocks, |b| {
                let lo = b * PRED_BLOCK;
                let hi = (lo + PRED_BLOCK).min(np);
                let blk = hi - lo;
                // Low-rank column blocks: the forward-solved `v_p`
                // columns come from the plan cache when the neighbor
                // search already computed them, else from one blocked
                // forward substitution; `α_p` back-substitutes the same
                // forward-solved block (no second L-solve).
                let (vt_cols, alpha_cols) = match &s.lr {
                    Some(lr) => {
                        let vt_cols = match cache {
                            Some(c) => {
                                let mut vc = Mat::zeros(m, blk);
                                for (col, p) in (lo..hi).enumerate() {
                                    for (l, &v) in c.vt.row(p).iter().enumerate() {
                                        vc.set(l, col, v);
                                    }
                                }
                                vc
                            }
                            None => {
                                let mut kpt = Mat::zeros(m, blk);
                                for (c, p) in (lo..hi).enumerate() {
                                    let row = kp.row(p);
                                    for (l, &v) in row.iter().enumerate() {
                                        kpt.set(l, c, v);
                                    }
                                }
                                lr.chol_m.solve_lower_mat(&kpt)
                            }
                        };
                        let alpha_cols = lr.chol_m.solve_upper_mat(&vt_cols);
                        (vt_cols, alpha_cols)
                    }
                    None => (Mat::zeros(0, blk), Mat::zeros(0, blk)),
                };
                // Per-point conditional blocks (panel kernels + SYRK).
                let mut beta_cols = Mat::zeros(m, blk);
                let mut var_loc = vec![0.0; blk];
                PANEL_SCRATCH.with(|cell| {
                    let scr = &mut *cell.borrow_mut();
                    for (c, p) in (lo..hi).enumerate() {
                        let vt_p: Vec<f64> = (0..m).map(|l| vt_cols.get(l, c)).collect();
                        let rho_pp = kernel.variance - dot(&vt_p, &vt_p);
                        let nb = &plan.neighbors[p];
                        let q = nb.len();
                        let (a_p, d_p) = if q == 0 {
                            (vec![], (rho_pp + nugget).max(1e-12))
                        } else {
                            let xpan = plan.x_panels.row_panel(p);
                            let mut cnn = Mat::zeros(q, q);
                            kernel.sym_cov_panel(xpan, &mut cnn);
                            let mut rho_pn = vec![0.0; q];
                            kernel.cov_panel(xp.row(p), xpan, &mut rho_pn);
                            if let Some(lr) = &s.lr {
                                gather_rows(&lr.vt, nb, &mut scr.vp);
                                cnn.syrk_sub_panel(&scr.vp, m);
                                for (t, r) in rho_pn.iter_mut().enumerate() {
                                    *r -= dot(&scr.vp[t * m..(t + 1) * m], &vt_p);
                                }
                            }
                            // Nugget after the SYRK so the diagonal matches
                            // the scalar `(σ₁² − v·v) + nugget` grouping
                            // bit-for-bit.
                            for a in 0..q {
                                cnn.add_to(a, a, nugget);
                            }
                            let chol =
                                CholeskyFactor::new_with_jitter(&cnn, block_jitter)
                                    .expect("prediction block not PD");
                            let a_p = chol.solve(&rho_pn);
                            let d_p = rho_pp + nugget - dot(&a_p, &rho_pn);
                            (a_p, d_p.max(1e-12))
                        };
                        // β_p = −Σ_k A_pk Σ_m,N(p)_k (column c of the block).
                        if let Some(lr) = &s.lr {
                            for (k, &j) in nb.iter().enumerate() {
                                let srow = lr.sigma_nm.row(j as usize);
                                let apk = a_p[k];
                                for (l, &sv) in srow.iter().enumerate() {
                                    beta_cols.add_to(l, c, -(apk * sv));
                                }
                            }
                        }
                        var_loc[c] = d_p;
                        // SAFETY: index p belongs to exactly this block.
                        unsafe {
                            *dp.get().add(p) = d_p;
                            for l in 0..m {
                                *alp.get().add(p * m + l) = alpha_cols.get(l, c);
                            }
                            *ap.get().add(p) = a_p;
                        }
                    }
                });
                // Woodbury variance terms for the whole block: `SS α_p`
                // per contiguous column (the same `matvec` kernel as the
                // scalar path, so the variance stays bit-identical to
                // the per-point reference), then one `M⁻¹` block solve
                // for all `β − SSα` columns and contiguous dots.
                if m > 0 {
                    let cm = s.chol_mcal.as_ref().unwrap();
                    let mut al = vec![0.0; m];
                    let mut bet = vec![0.0; m];
                    let mut ssa_cols = Mat::zeros(m, blk);
                    let mut diff = beta_cols.clone();
                    for c in 0..blk {
                        for l in 0..m {
                            al[l] = alpha_cols.get(l, c);
                        }
                        let ssa = s.ss.matvec(&al);
                        for (l, &v) in ssa.iter().enumerate() {
                            ssa_cols.set(l, c, v);
                            diff.add_to(l, c, -v);
                        }
                    }
                    let mdiff = cm.solve_mat(&diff);
                    let mut ssa = vec![0.0; m];
                    let mut df = vec![0.0; m];
                    let mut md = vec![0.0; m];
                    for (c, p) in (lo..hi).enumerate() {
                        for l in 0..m {
                            al[l] = alpha_cols.get(l, c);
                            ssa[l] = ssa_cols.get(l, c);
                            bet[l] = beta_cols.get(l, c);
                            df[l] = diff.get(l, c);
                            md[l] = mdiff.get(l, c);
                        }
                        let mut v = var_loc[c];
                        v += dot(kp.row(p), &al) - dot(&al, &ssa) + 2.0 * dot(&al, &bet);
                        v += dot(&df, &md);
                        var_loc[c] = v;
                    }
                }
                // SAFETY: indices lo..hi belong to exactly this block.
                unsafe {
                    for (c, p) in (lo..hi).enumerate() {
                        *vp.get().add(p) = var_loc[c].max(1e-12);
                    }
                }
            });
        }
        PredictBlocks { a_rows, d, kp, alpha, var_det }
    }
}

/// Posterior predictive mean for a target vector (`y` on the Gaussian
/// response scale, the Laplace mode `b̃` on the latent scale):
/// `μ_p = A_p (t − Σ_mnᵀ M⁻¹ Σ_mn S t)|_{N(p)} + α_p · (Σ_mn Σ_†⁻¹ t)`.
/// All global solves — `Σ_†⁻¹ t`, the `M⁻¹` core solve, and the
/// `Σ_mn Σ_†⁻¹ t` contraction — happen exactly once; the per-point work
/// is one gather over `N(p)` plus one row of a blocked GEMV.
pub fn posterior_mean(
    s: &VifStructure,
    plan: &PredictPlan,
    blocks: &PredictBlocks<'_>,
    target: &[f64],
) -> Vec<f64> {
    posterior_mean_cached(plan, blocks, &MeanCache::build(s, target))
}

/// [`posterior_mean`] with the global solves supplied by a pre-built
/// [`MeanCache`] — the serving read path (one cache per published
/// generation, reused across every request batch). Panics on a
/// generation mismatch between the plan and the cache, mirroring the
/// [`PredictBlocks::compute`] staleness contract.
pub fn posterior_mean_cached(
    plan: &PredictPlan,
    blocks: &PredictBlocks<'_>,
    cache: &MeanCache,
) -> Vec<f64> {
    assert!(
        plan.generation == 0 || cache.generation == 0 || plan.generation == cache.generation,
        "stale mean cache: plan built for structure generation {}, cache for {}",
        plan.generation,
        cache.generation
    );
    let np = plan.n_points();
    let resid_target = &cache.resid_target;
    let mut mean = match &cache.smu {
        Some(smu) => blocks.alpha.matvec(smu),
        None => vec![0.0; np],
    };
    let mp = SyncSlice(mean.as_mut_ptr());
    let mp = &mp;
    crate::coordinator::parallel_for_chunks(np, |start, end| {
        for p in start..end {
            let mut acc = 0.0;
            for (k, &j) in plan.neighbors[p].iter().enumerate() {
                acc += blocks.a_rows[p][k] * resid_target[j as usize];
            }
            // SAFETY: disjoint indices per chunk.
            unsafe {
                *mp.get().add(p) += acc;
            }
        }
    });
    mean
}

/// `Q W` for a column block, where each column of `w1` is already
/// `Σ_†⁻¹ z` and `Q = Σ_mn_pᵀ Σ_m⁻¹ Σ_mn − B_po S⁻¹` (the Laplace
/// stochastic-variance projection, Prop 3.1 / Eq. 21): one
/// `Σ_mn`-GEMM + `Σ_m` block solve + `k_p` GEMM for the low-rank part,
/// one level-scheduled `S⁻¹` sweep over all columns, and a per-point
/// gather over `N(p)` for the `B_po` part.
pub fn project_q_batch(
    s: &VifStructure,
    plan: &PredictPlan,
    blocks: &PredictBlocks<'_>,
    w1: &Mat,
) -> Mat {
    let np = plan.n_points();
    let k = w1.cols();
    let w2 = s.resid.apply_s_inv_mat(w1);
    let mut out = match &s.lr {
        Some(lr) => {
            let q_m = lr.chol_m.solve_mat(&lr.sigma_nm.matmul_tn(w1)); // m×k
            blocks.kp.matmul(&q_m) // np×k
        }
        None => Mat::zeros(np, k),
    };
    let optr = SyncSlice(out.data_mut().as_mut_ptr());
    let optr = &optr;
    crate::coordinator::parallel_for_chunks(np, |start, end| {
        for p in start..end {
            let a_p = &blocks.a_rows[p];
            for (t, &j) in plan.neighbors[p].iter().enumerate() {
                let a = a_p[t];
                let src = w2.row(j as usize);
                // SAFETY: disjoint output rows per chunk.
                unsafe {
                    let dst = optr.get().add(p * k);
                    for (c, &sv) in src.iter().enumerate() {
                        *dst.add(c) += a * sv;
                    }
                }
            }
        }
    });
    out
}

/// `Σ_†⁻¹ Qᵀ Z` for a column block of `n_p`-vectors — the adjoint used
/// by SPV and the exact variance path. The `B_poᵀ` part runs as a
/// deterministic per-training-row gather through the plan's CSC
/// pattern (fixed accumulation order, so results are independent of
/// the worker count), followed by one `S⁻¹` sweep and one
/// `Σ_†⁻¹` application over the whole block.
pub fn project_qt_batch(
    s: &VifStructure,
    plan: &PredictPlan,
    blocks: &PredictBlocks<'_>,
    z: &Mat,
) -> Mat {
    let n = s.n();
    let k = z.cols();
    let mut t = match &s.lr {
        Some(lr) => {
            let tm = lr.chol_m.solve_mat(&blocks.kp.matmul_tn(z)); // m×k
            lr.sigma_nm.matmul(&tm) // n×k
        }
        None => Mat::zeros(n, k),
    };
    let mut bt = Mat::zeros(n, k);
    {
        let btp = SyncSlice(bt.data_mut().as_mut_ptr());
        let btp = &btp;
        crate::coordinator::parallel_for_chunks(n, |start, end| {
            for j in start..end {
                for e in plan.bt_ptr[j]..plan.bt_ptr[j + 1] {
                    let (p, slot) = plan.bt_entries[e];
                    let a = blocks.a_rows[p as usize][slot as usize];
                    let src = z.row(p as usize);
                    // SAFETY: disjoint output rows per chunk.
                    unsafe {
                        let dst = btp.get().add(j * k);
                        for (c, &zv) in src.iter().enumerate() {
                            *dst.add(c) -= a * zv;
                        }
                    }
                }
            }
        });
    }
    let sb = s.resid.apply_s_inv_mat(&bt);
    t.sub_assign(&sb);
    s.apply_sigma_dagger_inv_batch(&t)
}

/// Below this many prediction points the cover-tree search falls back
/// to the brute-force metric sweep: building the tree costs on the
/// order of `n · depth` metric evaluations, which only amortizes once
/// enough queries share it. Both paths score through the same batched
/// metric, so the selected sets agree up to distance ties.
pub(crate) const COVER_TREE_MIN_QUERIES: usize = 32;

/// Conditioning sets for prediction points among training points, under
/// the same metric family as training-set selection (§6). The
/// correlation searches run over the stacked index space
/// `[X; X_p]` through [`PredCorrelationMetric`], so every candidate
/// batch flows through the panel kernels; the cover-tree variant builds
/// one tree over the training points and serves every prediction query
/// from it (a query index `n + p` exceeds every training index, so the
/// ordered query prunes nothing away), falling back to the brute-force
/// sweep below [`COVER_TREE_MIN_QUERIES`] so one-shot small-batch
/// `predict` calls don't pay the tree build. Returns the sets together
/// with the keyed [`LrPanelCache`] the correlation metric computed, so
/// the plan can hand the panels to the numeric pass.
fn pred_neighbor_sets(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    xp: &Mat,
    m_v: usize,
    selection: NeighborSelection,
) -> (Vec<Vec<u32>>, Option<LrPanelCache>) {
    pred_neighbor_sets_with(s, x, kernel, xp, m_v, selection, None)
}

/// [`pred_neighbor_sets`] with an optional pre-built cover tree over the
/// training points (from a [`PredSearchCache`]). With a cached tree the
/// cover-tree search runs even below [`COVER_TREE_MIN_QUERIES`] — the
/// build cost is already paid, and micro-batched serving queries then
/// select the *same* conditioning sets as one large batched call (the
/// tree and the query-to-training metric are both batch-independent).
fn pred_neighbor_sets_with(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    xp: &Mat,
    m_v: usize,
    selection: NeighborSelection,
    cached_tree: Option<&CoverTree>,
) -> (Vec<Vec<u32>>, Option<LrPanelCache>) {
    let n = x.rows();
    let np = xp.rows();
    if m_v == 0 || n == 0 {
        return (vec![vec![]; np], None);
    }
    let m_v = m_v.min(n);
    match selection {
        NeighborSelection::EuclideanTransformed => {
            let sets = crate::coordinator::parallel_map(np, |p| {
                let sp = xp.row(p);
                let cand: Vec<(f64, u32)> = (0..n)
                    .map(|j| {
                        let d2: f64 = sp
                            .iter()
                            .zip(x.row(j))
                            .zip(&kernel.length_scales)
                            .map(|((a, b), l)| {
                                let u = (a - b) / l;
                                u * u
                            })
                            .sum();
                        (d2, j as u32)
                    })
                    .collect();
                take_m_v(cand, m_v)
            });
            (sets, None)
        }
        NeighborSelection::CorrelationCoverTree | NeighborSelection::CorrelationBruteForce => {
            let panels = s.lr.as_ref().map(|lr| {
                let (kp, vt) = pred_lr_panels(lr, kernel, xp);
                LrPanelCache { theta: kernel.log_params(), z: lr.z.clone(), kp, vt }
            });
            let metric = PredCorrelationMetric::new(
                s,
                x,
                kernel,
                xp,
                panels.as_ref().map(|c| &c.vt),
            );
            let use_tree = selection == NeighborSelection::CorrelationCoverTree
                && (cached_tree.is_some() || np >= COVER_TREE_MIN_QUERIES);
            let sets = if use_tree {
                let built;
                let tree = match cached_tree {
                    Some(t) => t,
                    None => {
                        built = CoverTree::build(n, &metric);
                        &built
                    }
                };
                let mut out: Vec<Vec<u32>> = vec![vec![]; np];
                {
                    let out_ptr = SyncSlice(out.as_mut_ptr());
                    let out_ptr = &out_ptr;
                    crate::coordinator::parallel_for_chunks(np, |start, end| {
                        let mut scratch = QueryScratch::new(n);
                        for p in start..end {
                            let mut idx =
                                tree.knn_ordered_with(n + p, m_v, &metric, &mut scratch);
                            idx.sort_unstable();
                            // SAFETY: disjoint indices per chunk.
                            unsafe {
                                *out_ptr.get().add(p) = idx;
                            }
                        }
                    });
                }
                out
            } else {
                let ids: Vec<u32> = (0..n as u32).collect();
                crate::coordinator::parallel_map(np, |p| {
                    let mut dists = vec![0.0; n];
                    metric.dist_batch(n + p, &ids, &mut dists);
                    let cand: Vec<(f64, u32)> =
                        dists.into_iter().zip(ids.iter().copied()).collect();
                    take_m_v(cand, m_v)
                })
            };
            (sets, panels)
        }
    }
}

/// Keep the `m_v` smallest-score candidates, ascending index order.
pub(crate) fn take_m_v(mut cand: Vec<(f64, u32)>, m_v: usize) -> Vec<u32> {
    if cand.len() > m_v {
        cand.select_nth_unstable_by(m_v - 1, |a, b| a.0.total_cmp(&b.0));
        cand.truncate(m_v);
    }
    let mut idx: Vec<u32> = cand.into_iter().map(|(_, j)| j).collect();
    idx.sort_unstable();
    idx
}

/// Correlation distance `d_c` of the residual process over the stacked
/// index space `[training 0..n, prediction n..n+n_p]`: training rows
/// read the structure's `V` panel, prediction rows a `L_m⁻¹ K(Z, X_p)`
/// panel computed once at construction. The batched path mirrors
/// [`super::CorrelationMetric`] — one `cov_panel` sweep per candidate
/// batch plus length-`m` dot corrections.
struct PredCorrelationMetric<'a> {
    kernel: &'a ArdMatern,
    x: &'a Mat,
    xp: &'a Mat,
    lr: Option<&'a LowRank>,
    /// `(L_m⁻¹ K(Z, X_p))ᵀ` rows for the prediction points (required
    /// whenever `lr` is set; the caller computes it once via
    /// [`pred_lr_panels`] and also hands it to the plan).
    vt_pred: Option<&'a Mat>,
    /// `ρ(j,j)` over the stacked space, clamped away from zero.
    diag: Vec<f64>,
    n: usize,
}

impl<'a> PredCorrelationMetric<'a> {
    fn new(
        s: &'a VifStructure,
        x: &'a Mat,
        kernel: &'a ArdMatern,
        xp: &'a Mat,
        vt_pred: Option<&'a Mat>,
    ) -> Self {
        let n = x.rows();
        let np = xp.rows();
        let lr = s.lr.as_ref();
        let mut diag = Vec::with_capacity(n + np);
        match lr {
            Some(lr) => {
                let vt = vt_pred.expect("low-rank structure needs the prediction V panel");
                for j in 0..n {
                    diag.push(
                        (kernel.variance - crate::linalg::norm2_sq(lr.vt.row(j)))
                            .max(1e-300),
                    );
                }
                for p in 0..np {
                    diag.push(
                        (kernel.variance - crate::linalg::norm2_sq(vt.row(p))).max(1e-300),
                    );
                }
            }
            None => diag.resize(n + np, kernel.variance.max(1e-300)),
        }
        PredCorrelationMetric { kernel, x, xp, lr, vt_pred, diag, n }
    }

    fn coords(&self, j: usize) -> &[f64] {
        if j < self.n {
            self.x.row(j)
        } else {
            self.xp.row(j - self.n)
        }
    }

    fn vrow<'b>(&'b self, lr: &'b LowRank, j: usize) -> &'b [f64] {
        if j < self.n {
            lr.vt.row(j)
        } else {
            self.vt_pred
                .expect("low-rank structure needs the prediction V panel")
                .row(j - self.n)
        }
    }
}

impl Metric for PredCorrelationMetric<'_> {
    fn dist(&self, i: usize, j: usize) -> f64 {
        let k = if i == j {
            self.kernel.variance
        } else {
            self.kernel.cov(self.coords(i), self.coords(j))
        };
        let rho = match self.lr {
            Some(lr) => k - dot(self.vrow(lr, i), self.vrow(lr, j)),
            None => k,
        };
        super::correlation_distance(rho, self.diag[i], self.diag[j])
    }

    fn dist_batch(&self, i: usize, cand: &[u32], out: &mut [f64]) {
        PANEL_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            s.xp.clear();
            s.xp.reserve(cand.len() * self.x.cols());
            for &j in cand {
                s.xp.extend_from_slice(self.coords(j as usize));
            }
            self.kernel.cov_panel(self.coords(i), &s.xp, out);
            if let Some(lr) = self.lr {
                let vi = self.vrow(lr, i);
                for (o, &j) in out.iter_mut().zip(cand) {
                    *o -= dot(vi, self.vrow(lr, j as usize));
                }
            }
            let di = self.diag[i];
            for (o, &j) in out.iter_mut().zip(cand) {
                *o = super::correlation_distance(*o, di, self.diag[j as usize]);
            }
        })
    }
}

/// `K(X_p, Z)` and its forward solve `(L_m⁻¹ K(Z, X_p))ᵀ` (`n_p × m`
/// each): one cross-covariance panel (PJRT-served when available) +
/// row-wise forward substitutions. Computed once per plan build and
/// shared between the correlation metric and the numeric pass.
fn pred_lr_panels(lr: &LowRank, kernel: &ArdMatern, xp: &Mat) -> (Mat, Mat) {
    let kp = crate::runtime::cross_cov_panel(xp, &lr.z, kernel);
    let m = lr.m();
    let mut vt = Mat::zeros(xp.rows(), m);
    {
        let vtp = SyncSlice(vt.data_mut().as_mut_ptr());
        let vtp = &vtp;
        crate::coordinator::parallel_for_chunks(xp.rows(), |start, end| {
            for i in start..end {
                let mut v = kp.row(i).to_vec();
                lr.chol_m.solve_lower_in_place(&mut v);
                // SAFETY: disjoint rows per chunk.
                unsafe {
                    std::ptr::copy_nonoverlapping(v.as_ptr(), vtp.get().add(i * m), m);
                }
            }
        });
    }
    (kp, vt)
}
