//! The Vecchia-inducing-points full-scale (VIF) approximation (paper §2).
//!
//! `Σ̃_† = Σˡ + Σ̃ˢ` with `Σˡ = Σ_mnᵀ Σ_m⁻¹ Σ_mn` the predictive-process
//! low-rank part over `m` inducing points and `Σ̃ˢ ≈ Cov(b_s)` a Vecchia
//! approximation of the residual process. This module holds the shared
//! structure: the low-rank blocks, the residual-covariance oracle, the
//! Woodbury core `M = Σ_m + Σ_mn Bᵀ D⁻¹ B Σ_mnᵀ`, and the linear-algebra
//! entry points that the Gaussian likelihood (`gaussian`), the Laplace
//! approximation (`laplace`), and the iterative methods build on.
//!
//! Special cases: `m = 0` reduces to a classical Vecchia approximation;
//! `m_v = 0` reduces to FITC. Both reductions are exercised in tests and
//! used for the paper's baselines.
//!
//! # Plan/refresh split (symbolic vs. numeric assembly)
//!
//! Hyperparameter optimization (§6) freezes the structure choices —
//! inducing points `Z` and Vecchia conditioning sets `N(i)` — within an
//! optimization round and only re-selects them between rounds. Assembly
//! is therefore split like a sparse direct solver's analyze/factorize
//! decomposition:
//!
//! * [`VifPlan`] is the **θ-independent (symbolic) half**: the owned
//!   neighbor graph, the frozen inducing inputs, the
//!   [`LevelSchedule`] and the `Bᵀ` [`TransposedIndex`] pattern
//!   (both functions of the graph alone), and the pre-gathered per-row
//!   neighbor coordinate panels ([`NeighborPanels`]) the panelized
//!   oracle reads instead of re-copying coordinates per evaluation.
//!   Built once per re-selection by [`VifPlan::build`].
//! * [`VifStructure::from_plan`] performs the one allocation/symbolic
//!   pass per round (cloning the plan's schedule and pattern instead of
//!   recomputing them), and [`VifStructure::refresh`] is the
//!   **θ-dependent (numeric) half**: it re-evaluates the kernel through
//!   the PR-3 panel evaluators and rewrites `A`/`D`, the low-rank
//!   panels (`Σ_m`, `Σ_mn`, `V`, `E`), and the Woodbury blocks
//!   (`BΣ_mnᵀ`, `H`, `SΣ_mnᵀ`, `SS`, `M`) **in place**, touching
//!   neither the graph, nor the schedule, nor the big-buffer allocator.
//!   A refreshed structure is numerically identical to a from-scratch
//!   [`VifStructure::assemble`] at the same θ (pinned to ≤1e-12 by
//!   `tests/refresh.rs` and perf_hotpath stage 11).
//!
//! A plan is **invalidated** by anything that changes the structure
//! choices: re-selecting neighbors or inducing points (the power-of-two
//! cadence between rounds), or changing the data set. The shared
//! [`fit_with_reselection`] driver encodes the cadence for both the
//! Gaussian and the Laplace models: one plan + one structure per round,
//! every L-BFGS evaluation borrows them and refreshes in place.
//!
//! Prediction follows the same split: the [`predict`] module holds the
//! shared panelized serving pipeline (Prop 2.1 / Prop 3.1) — a θ-frozen
//! [`predict::PredictPlan`] (per-point conditioning sets, pre-gathered
//! coordinate panels, `B_poᵀ` scatter pattern) plus a batched numeric
//! pass — which both the Gaussian and the Laplace `predict` entry
//! points run through.
//!
//! # Structure lifecycle (select → plan → refresh → append → compact)
//!
//! Over a model's life the pieces above compose into one cycle:
//!
//! 1. **select** — [`select_structure`] picks inducing points `Z`
//!    (kMeans++/Lloyd in λ-scaled space) and conditioning sets `N(i)`.
//! 2. **plan** — [`VifPlan::build`] freezes those choices symbolically;
//!    [`VifStructure::from_plan`] runs the one numeric assembly of the
//!    round.
//! 3. **refresh** — every optimizer evaluation rewrites the θ-dependent
//!    numbers in place via [`VifStructure::refresh`]; the plan and the
//!    structure's *generation* are untouched.
//! 4. **append** — [`VifStructure::append`] (driven by the models'
//!    `append_points`) ingests new observations incrementally: new
//!    low-rank columns ([`LowRank::append_cols`]), leaf conditioning
//!    sets among pre-existing points only, new Vecchia rows
//!    (`ResidualFactor::append_rows`), extended plan pieces
//!    ([`VifPlan::append`]), and a blocked rank-k Woodbury-core update.
//!    Equivalent to a from-scratch rebuild at the same θ (≤1e-12,
//!    pinned by `tests/append.rs`), and it **bumps the structure
//!    generation**, invalidating every cached
//!    [`predict::PredictPlan`] exactly as a refit does.
//! 5. **compact** — leaf-only conditioning accumulates approximation
//!    drift (appended points never enter earlier rows' conditioning
//!    sets), so past an appended-fraction threshold the models'
//!    `compact()` re-runs a full selection over all data — inducing
//!    points warm-started through Lloyd (see `inducing`) — producing a
//!    fresh plan, structure, and generation.
//!
//! Serving-side, [`predict::PredictPlan`] records the generation of the
//! structure it was built against and the numeric pass refuses a stale
//! plan (generation mismatch ⇒ panic with a rebuild hint); the softer
//! θ/Z-keyed panel-cache fallback stays observable through
//! [`predict::lr_panel_cache_misses`].
//!
//! # Serving lifecycle (snapshot → publish → swap)
//!
//! The lifecycle above describes a *mutating* model (fit, append,
//! compact). Concurrent serving ([`crate::serve`]) never takes locks
//! around that mutation; it freezes it out instead:
//!
//! 1. **snapshot** — `VifRegression::snapshot` /
//!    `VifLaplaceModel::snapshot` clone the fitted read state (data,
//!    parameters, assembled [`VifStructure`]) into an immutable
//!    [`gaussian::FittedGaussian`] / [`laplace::FittedLaplace`] and
//!    build the per-generation read caches once: the hoisted global
//!    mean solves ([`predict::MeanCache`] — the two Σ_†⁻¹-sized solves
//!    shared by every query) and the prediction cover tree
//!    ([`predict::PredSearchCache`] — the tree only touches
//!    training–training correlations, so one tree serves every future
//!    query batch, however the micro-batcher slices it).
//! 2. **publish** — the writer hands an `Arc` of the snapshot to
//!    [`crate::serve::ServeEngine::publish`]; the swap is one atomic
//!    `Arc` store. The authoritative model keeps mutating on the writer
//!    thread only.
//! 3. **swap semantics** — each request batch clones the published
//!    `Arc` once and builds its [`predict::PredictPlan`] *from that
//!    snapshot* ([`predict::PredictPlan::build_cached`]), so plan and
//!    numeric pass always see one coherent generation: the stale-plan
//!    panic is unreachable on the serving path, and in-flight batches
//!    finish against the old generation while new batches pick up the
//!    new one (old-complete or new-complete, never mixed). Cache-key
//!    mismatches degrade softly and observably
//!    ([`predict::pred_search_cache_misses`]), mirroring the
//!    [`predict::lr_panel_cache_misses`] precedent.
//!
//! # Warm-start lifecycle (the fit-trajectory analogue of plan/refresh)
//!
//! Consecutive L-BFGS objective evaluations sit at nearby θ, so the
//! expensive iterative state of one evaluation is an excellent starting
//! point for the next. A [`FitSession`] threads that state along the
//! whole trajectory, extending the plan/refresh split in time:
//!
//! * **CG initial guesses** — the previous evaluation's solutions seed
//!   [`crate::iterative::pcg_with_min_from`]: the Laplace Newton solves
//!   start from the current mode iterate, and the `s̃` gradient helper
//!   starts from the previous θ's `s̃`. SLQ probe solves always run
//!   cold: their Lanczos tridiagonals need the pure Krylov recurrence
//!   from `r₀ = b` (enforced by an assert).
//! * **Preconditioner refresh-in-place** — the FITC preconditioner keeps
//!   its kMeans++ inducing set `Ẑ` across evaluations
//!   ([`crate::iterative::FitcPrecond::refresh`]), and successive Newton
//!   iterations recompute only its weight diagonal
//!   ([`crate::iterative::FitcPrecond::refresh_weights`]); the VIFDU
//!   preconditioner refreshes across Newton iterations within one
//!   evaluation ([`crate::iterative::VifduPrecond::refresh`] — it
//!   borrows the structure, which the driver refreshes mutably between
//!   evaluations, so it cannot cross them).
//! * **Laplace mode carry-over** — each Newton search starts from the
//!   previous evaluation's converged mode instead of `b = 0`.
//! * **Per-round probe draws** — the SLQ probe seed is fixed within a
//!   round (common random numbers keep the stochastic objective smooth
//!   along the trajectory) and re-drawn at re-selection rounds via
//!   [`FitSession::probe_tag`].
//!
//! Everything carried is a guess or a refreshable cache: the session
//! changes *where iterative solvers start*, never what they converge
//! to. The cold path remains the oracle — `VIFGP_WARM_START=0` (or
//! [`fit_with_reselection_session`] with `warm = false`) reproduces the
//! legacy fit bit for bit, and warm-start reuse is observable through
//! the `warm_hits`/`warm_misses`/`cg_iters` counters of
//! [`crate::iterative::solve_stats`].

pub mod gaussian;
pub mod laplace;
pub mod predict;

use crate::covertree::{CoverTree, Metric, QueryScratch};
use crate::inducing;
use crate::kernels::{ArdMatern, Smoothness};
use crate::linalg::{dot, norm2_sq, CholeskyFactor, Mat};
use crate::rng::Rng;
use crate::vecchia::neighbors::{self, NeighborSelection};
use crate::vecchia::{LevelSchedule, ResidualCov, ResidualFactor, TransposedIndex};
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Configuration of a VIF approximation.
#[derive(Clone, Debug)]
pub struct VifConfig {
    /// Matérn smoothness ν of the ARD kernel.
    pub smoothness: Smoothness,
    /// Number of inducing points m (0 → pure Vecchia approximation).
    pub num_inducing: usize,
    /// Number of Vecchia neighbors m_v (0 → FITC approximation).
    pub num_neighbors: usize,
    /// Neighbor-selection strategy (§6).
    pub selection: NeighborSelection,
    /// Diagonal jitter for the small Cholesky factorizations.
    pub jitter: f64,
    /// Lloyd refinement iterations after kMeans++ seeding.
    pub lloyd_iters: usize,
    /// RNG seed for kMeans++ (and everything stochastic downstream).
    pub seed: u64,
}

impl Default for VifConfig {
    fn default() -> Self {
        VifConfig {
            smoothness: Smoothness::ThreeHalves,
            num_inducing: 200,
            num_neighbors: 30,
            selection: NeighborSelection::CorrelationCoverTree,
            jitter: 1e-8,
            lloyd_iters: 5,
            seed: 0,
        }
    }
}

/// Structured validation/containment errors of the VIF model layer
/// (part of the crate failure taxonomy; see the crate-root "Failure
/// semantics" section). Constructor and ingest validation reject bad
/// inputs with one of these *before* any structure is built or mutated.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VifError {
    /// Row/response or column-dimension mismatch between inputs.
    DimensionMismatch { expected: usize, got: usize, what: &'static str },
    /// An input contains NaN/Inf.
    NonFinite { what: &'static str },
}

impl std::fmt::Display for VifError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VifError::DimensionMismatch { expected, got, what } => {
                write!(f, "{what}: expected {expected}, got {got}")
            }
            VifError::NonFinite { what } => write!(f, "{what} contains non-finite values"),
        }
    }
}

impl std::error::Error for VifError {}

impl From<VifError> for String {
    fn from(e: VifError) -> String {
        e.to_string()
    }
}

/// Fit-time input validation shared by both models' constructors
/// (mirrors the `append_points` checks): responses must match the input
/// rows, and neither side may carry NaN/Inf. Returns before any
/// structure is built, so a rejected model leaves no partial state.
pub(crate) fn validate_training_data(x: &Mat, y: &[f64]) -> Result<(), VifError> {
    if x.rows() != y.len() {
        return Err(VifError::DimensionMismatch {
            expected: x.rows(),
            got: y.len(),
            what: "training responses y must match X rows",
        });
    }
    if x.data().iter().any(|v| !v.is_finite()) {
        return Err(VifError::NonFinite { what: "training inputs X" });
    }
    if y.iter().any(|v| !v.is_finite()) {
        return Err(VifError::NonFinite { what: "training responses y" });
    }
    Ok(())
}

/// Low-rank (predictive-process) blocks for a fixed kernel and inducing
/// set: `Σ_m = K(Z,Z)`, `Σ_mn = K(Z,X)` and the two solved panels used
/// everywhere downstream.
///
/// `Clone` exists for the serving snapshot path ([`crate::serve`]): a
/// publish clones the fitted numeric state once so request threads read
/// an immutable generation while the writer keeps mutating its own copy.
#[derive(Clone)]
pub struct LowRank {
    /// Inducing inputs Z (m×d).
    pub z: Mat,
    /// `Σ_m` itself (with the build-time diagonal jitter), kept so the
    /// Woodbury core `M = Σ_m + SS` is assembled by a rank-free add
    /// instead of an O(m³) `L Lᵀ` reconstruction.
    pub sig_m: Mat,
    /// Cholesky of `Σ_m` (+ jitter).
    pub chol_m: CholeskyFactor,
    /// `K(X, Z)` stored n×m (row i = Σ_mi ᵀ).
    pub sigma_nm: Mat,
    /// `(L_m⁻¹ Σ_mn)ᵀ` n×m — residual correction is `ρ(i,j) = k(i,j) − v_i·v_j`.
    pub vt: Mat,
    /// `(Σ_m⁻¹ Σ_mn)ᵀ` n×m — rows `e_i` used by gradients and predictions.
    pub et: Mat,
}

impl LowRank {
    /// Build low-rank blocks for inducing inputs `z`.
    pub fn build(x: &Mat, kernel: &ArdMatern, z: Mat, jitter: f64) -> Self {
        let m = z.rows();
        let n = x.rows();
        let mut sig_m = kernel.sym_cov(&z, 0.0);
        sig_m.add_diag(jitter.max(1e-10) * kernel.variance);
        // The tracked factorization hands back the matrix actually
        // factored (including any escalated jitter), so the stored Σ_m
        // that `assemble` adds into the Woodbury core is exactly `L Lᵀ`
        // even on the ill-conditioned retry path; consumed jitter is
        // recorded in the containment counters.
        let jf = CholeskyFactor::new_with_jitter_tracked(&sig_m, jitter.max(1e-10))
            .expect("inducing-point covariance not PD");
        crate::iterative::solve_stats().note_jitter(jf.jitter);
        let (chol_m, sig_m) = (jf.factor, jf.matrix);
        // Σ_mn panel: served by the AOT/PJRT engine when available (the
        // Layer-1 Pallas kernel), native fallback otherwise.
        let sigma_nm = crate::runtime::cross_cov_panel(x, &z, kernel);
        let mut vt = Mat::zeros(n, m);
        let mut et = Mat::zeros(n, m);
        Self::fill_vt_et(&chol_m, &sigma_nm, &mut vt, &mut et);
        LowRank { z, sig_m, chol_m, sigma_nm, vt, et }
    }

    /// In-place θ-refresh for the fixed inducing inputs `z`: recompute
    /// `Σ_m` (+ Cholesky), the `Σ_mn` panel, and the solved `V`/`E`
    /// panels in the existing buffers. The math (including the jitter
    /// escalation policy of `new_with_jitter_mat`) is identical to
    /// [`build`](Self::build), so a refreshed block matches a freshly
    /// built one exactly.
    pub fn refresh(&mut self, x: &Mat, kernel: &ArdMatern, jitter: f64) {
        debug_assert_eq!(self.sigma_nm.rows(), x.rows());
        kernel.sym_cov_into(&self.z, 0.0, &mut self.sig_m);
        self.sig_m.add_diag(jitter.max(1e-10) * kernel.variance);
        let jf = CholeskyFactor::new_with_jitter_tracked(&self.sig_m, jitter.max(1e-10))
            .expect("inducing-point covariance not PD");
        crate::iterative::solve_stats().note_jitter(jf.jitter);
        self.chol_m = jf.factor;
        self.sig_m = jf.matrix;
        crate::runtime::cross_cov_panel_into(x, &self.z, kernel, &mut self.sigma_nm);
        Self::fill_vt_et(&self.chol_m, &self.sigma_nm, &mut self.vt, &mut self.et);
    }

    /// Grow the panels by columns for appended inputs — the low-rank
    /// layer of the streaming-append path. `Z`, `Σ_m`, and its Cholesky
    /// depend only on the inducing set and stay frozen; the update
    /// evaluates one `K(X_new, Z)` cross-covariance panel plus the
    /// matching `V`/`E` rows (the same per-row `fill_vt_et` math as
    /// [`build`](Self::build)) and appends them. Existing rows are
    /// untouched, so the extended block matches a from-scratch build
    /// over the extended inputs row for row.
    pub fn append_cols(&mut self, x_new: &Mat, kernel: &ArdMatern) {
        let k_new = x_new.rows();
        if k_new == 0 {
            return;
        }
        let m = self.m();
        let panel = crate::runtime::cross_cov_panel(x_new, &self.z, kernel);
        let mut vt_new = Mat::zeros(k_new, m);
        let mut et_new = Mat::zeros(k_new, m);
        Self::fill_vt_et(&self.chol_m, &panel, &mut vt_new, &mut et_new);
        self.sigma_nm.append_rows(&panel);
        self.vt.append_rows(&vt_new);
        self.et.append_rows(&et_new);
    }

    /// Fill the `V = (L_m⁻¹Σ_mn)ᵀ` and `E = (Σ_m⁻¹Σ_mn)ᵀ` rows from the
    /// `Σ_mn` panel (disjoint rows per worker, written through the
    /// shared `SyncSlice` pointer idiom of the other parallel fills).
    fn fill_vt_et(chol_m: &CholeskyFactor, sigma_nm: &Mat, vt: &mut Mat, et: &mut Mat) {
        let n = sigma_nm.rows();
        let m = sigma_nm.cols();
        let vtp = crate::coordinator::SyncSlice(vt.data_mut().as_mut_ptr());
        let etp = crate::coordinator::SyncSlice(et.data_mut().as_mut_ptr());
        let vtp = &vtp;
        let etp = &etp;
        crate::coordinator::parallel_for_chunks(n, |start, end| {
            for i in start..end {
                let mut v = sigma_nm.row(i).to_vec();
                chol_m.solve_lower_in_place(&mut v);
                let mut e = v.clone();
                chol_m.solve_upper_in_place(&mut e);
                // SAFETY: disjoint rows per index (parallel_for_chunks).
                unsafe {
                    std::ptr::copy_nonoverlapping(v.as_ptr(), vtp.get().add(i * m), m);
                    std::ptr::copy_nonoverlapping(e.as_ptr(), etp.get().add(i * m), m);
                }
            }
        });
    }

    pub fn m(&self) -> usize {
        self.z.rows()
    }
}

/// Precomputed low-rank gradient panels `T^p = ∂Σ_mnᵀ/∂θ_p − ½ E ∂Σ_m/∂θ_p`
/// (n×m per kernel parameter), so that
/// `∂ρ(i,j)/∂θ_p = ∂k(i,j)/∂θ_p − T^p_i·e_j − e_i·T^p_j`.
pub struct GradAux {
    pub t: Vec<Mat>,
    /// `∂Σ_m/∂θ_p` (m×m per kernel parameter) for the m×m contractions.
    pub dsig_m: Vec<Mat>,
    /// Raw `∂K(X,Z)/∂θ_p` panels (n×m per kernel parameter), used by the
    /// Laplace derivative products.
    pub dsig_nm: Vec<Mat>,
}

impl GradAux {
    pub fn build(x: &Mat, kernel: &ArdMatern, lr: &LowRank) -> Self {
        let m = lr.m();
        let n = x.rows();
        let np = kernel.num_params();
        // dΣ_m per parameter.
        let mut dsig_m: Vec<Mat> = (0..np).map(|_| Mat::zeros(m, m)).collect();
        let mut g = vec![0.0; np];
        for a in 0..m {
            for b in 0..=a {
                kernel.cov_and_grad_into(lr.z.row(a), lr.z.row(b), &mut g);
                for p in 0..np {
                    dsig_m[p].set(a, b, g[p]);
                    dsig_m[p].set(b, a, g[p]);
                }
            }
        }
        // Half-corrections: ½ E dΣ_m (n×m each).
        let half_e: Vec<Mat> = (0..np)
            .map(|p| {
                let mut he = lr.et.matmul(&dsig_m[p]);
                he.scale(0.5);
                he
            })
            .collect();
        // T^p = dK(X,Z)^p − ½ E dΣ_m^p, keeping the raw panel too.
        let mut t: Vec<Mat> = (0..np).map(|_| Mat::zeros(n, m)).collect();
        let mut dsig_nm: Vec<Mat> = (0..np).map(|_| Mat::zeros(n, m)).collect();
        {
            let tps: Vec<crate::coordinator::SyncSlice<f64>> = t
                .iter_mut()
                .map(|mat| crate::coordinator::SyncSlice(mat.data_mut().as_mut_ptr()))
                .collect();
            let dps: Vec<crate::coordinator::SyncSlice<f64>> = dsig_nm
                .iter_mut()
                .map(|mat| crate::coordinator::SyncSlice(mat.data_mut().as_mut_ptr()))
                .collect();
            let (tps, dps) = (&tps, &dps);
            crate::coordinator::parallel_for_chunks(n, |start, end| {
                let mut g = vec![0.0; np];
                for i in start..end {
                    for l in 0..m {
                        kernel.cov_and_grad_into(x.row(i), lr.z.row(l), &mut g);
                        for p in 0..np {
                            // SAFETY: disjoint (i, l) cells per chunk.
                            unsafe {
                                *tps[p].get().add(i * m + l) = g[p] - half_e[p].get(i, l);
                                *dps[p].get().add(i * m + l) = g[p];
                            }
                        }
                    }
                }
            });
        }
        GradAux { t, dsig_m, dsig_nm }
    }
}

/// Pre-gathered, θ-independent per-row neighbor coordinate panels: for
/// each row `i`, the inputs `x[N(i)]` as one contiguous row-major block
/// (`|N(i)| × d`). Gathered once at [`VifPlan`] build time so the
/// panelized oracle stops re-copying coordinates on every numeric
/// refresh (the `V`/`E`/`T^p` gathers stay per-evaluation — those panels
/// are θ-dependent).
pub struct NeighborPanels {
    /// Row extents in points: row `i` spans `off[i]..off[i+1]`.
    off: Vec<usize>,
    /// Concatenated row-major coordinate blocks.
    data: Vec<f64>,
    /// Input dimension d.
    dim: usize,
}

impl NeighborPanels {
    /// Gather the panels for a fixed neighbor graph.
    pub fn gather(x: &Mat, neighbors: &[Vec<u32>]) -> Self {
        let d = x.cols();
        let total: usize = neighbors.iter().map(Vec::len).sum();
        let mut off = Vec::with_capacity(neighbors.len() + 1);
        off.push(0usize);
        let mut data = Vec::with_capacity(total * d);
        let mut count = 0usize;
        for nb in neighbors {
            for &j in nb {
                data.extend_from_slice(x.row(j as usize));
            }
            count += nb.len();
            off.push(count);
        }
        NeighborPanels { off, data, dim: d }
    }

    /// Grow the panels for appended rows (the streaming-append path):
    /// existing rows' blocks are untouched and the new blocks land at
    /// the end, so the result is identical to re-gathering over the
    /// extended graph.
    pub fn append(&mut self, x: &Mat, new_neighbors: &[Vec<u32>]) {
        debug_assert_eq!(self.dim, x.cols());
        let mut count = *self.off.last().expect("panels always cover row 0");
        for nb in new_neighbors {
            for &j in nb {
                self.data.extend_from_slice(x.row(j as usize));
            }
            count += nb.len();
            self.off.push(count);
        }
    }

    /// The gathered panel for row `i` (`|N(i)| × dim`, row-major).
    pub fn row_panel(&self, i: usize) -> &[f64] {
        &self.data[self.off[i] * self.dim..self.off[i + 1] * self.dim]
    }
}

/// θ-independent assembly plan: everything about a VIF structure that
/// depends only on the *structure choices* (conditioning sets `N(i)`
/// and inducing inputs `Z`), not on the kernel parameters — the
/// "analyze" half of the analyze/factorize split (module docs above).
///
/// A plan is built once per re-selection round, and the round's one
/// [`VifStructure::from_plan`] assembly clones the plan's graph,
/// schedule, and pattern into the structure; after that, every
/// optimizer evaluation borrows the plan and runs the numeric
/// [`VifStructure::refresh`] pass, which copies no structure data at
/// all. Re-selecting neighbors or inducing points invalidates the plan
/// — build a new one.
pub struct VifPlan {
    /// Frozen conditioning sets `N(i)` (ascending indices `< i`).
    pub neighbors: Vec<Vec<u32>>,
    /// Frozen inducing inputs (None → pure Vecchia).
    pub z: Option<Mat>,
    /// Level schedule of the neighbor DAG (computed once per plan).
    pub schedule: LevelSchedule,
    /// `Bᵀ` sparsity pattern; its coefficients are placeholders that
    /// every structure build/refresh rewrites numerically.
    pub bt_index: TransposedIndex,
    /// Pre-gathered per-row neighbor coordinate panels.
    pub x_panels: NeighborPanels,
}

impl VifPlan {
    /// Build a plan for fixed structure choices over the inputs `x`.
    pub fn build(x: &Mat, z: Option<Mat>, neighbors: Vec<Vec<u32>>) -> Self {
        let schedule = LevelSchedule::from_neighbors(&neighbors);
        let bt_index = TransposedIndex::pattern(&neighbors);
        let x_panels = NeighborPanels::gather(x, &neighbors);
        VifPlan { neighbors, z, schedule, bt_index, x_panels }
    }

    /// Extend a frozen plan for appended points — the symbolic layer of
    /// the streaming-append path. The existing graph, schedule, pattern,
    /// and panels are untouched; the appended rows' conditioning sets
    /// (selected among pre-existing points by [`VifStructure::append`])
    /// grow each piece through its incremental primitive
    /// ([`LevelSchedule::extend_leaves`],
    /// [`TransposedIndex::append_pattern`], [`NeighborPanels::append`]),
    /// each of which reproduces its from-scratch counterpart on the
    /// extended graph exactly. `x_full` must already contain the
    /// appended rows.
    pub fn append(&mut self, x_full: &Mat, new_neighbors: Vec<Vec<u32>>) {
        let base = self.n();
        assert_eq!(
            x_full.rows(),
            base + new_neighbors.len(),
            "x_full must contain exactly the appended rows"
        );
        self.schedule.extend_leaves(&new_neighbors, base);
        self.bt_index.append_pattern(&new_neighbors, base);
        self.x_panels.append(x_full, &new_neighbors);
        self.neighbors.extend(new_neighbors);
    }

    /// Number of data points the plan covers.
    pub fn n(&self) -> usize {
        self.neighbors.len()
    }

    /// Number of inducing points (0 → pure Vecchia).
    pub fn m(&self) -> usize {
        self.z.as_ref().map(|z| z.rows()).unwrap_or(0)
    }
}

/// Residual-covariance oracle `ρ(i,j) = k(x_i,x_j) − v_i·v_j` with
/// optional gradients. `extra_params` appends zero-gradient slots after
/// the kernel parameters (e.g. the Gaussian noise, whose contribution is
/// added by the nugget plumbing in [`ResidualFactor`]).
///
/// The scalar `rho`/`rho_and_grad` methods are the reference
/// implementations (kept as the test oracle and the perf baseline); the
/// hot paths go through the panelized `rho_block`/`rho_and_grad_block`
/// overrides, which gather each row's neighbor panel once into
/// per-worker scratch, evaluate the kernel part through the `kernels`
/// panel evaluators, and apply the low-rank corrections as blocked
/// `m_v×m` SYRK/GEMM rank updates.
pub struct VifResidualOracle<'a> {
    pub kernel: &'a ArdMatern,
    pub x: &'a Mat,
    pub lr: Option<&'a LowRank>,
    pub grad_aux: Option<&'a GradAux>,
    pub extra_params: usize,
    /// Pre-gathered coordinate panels from a frozen [`VifPlan`]. When
    /// set, the block methods read each row's neighbor inputs from the
    /// plan instead of gathering them into scratch per call. Must have
    /// been gathered for the same `x` and the same neighbor lists the
    /// block methods are called with.
    pub x_panels: Option<&'a NeighborPanels>,
}

/// Per-worker gather scratch for the panelized oracle and the batched
/// correlation metric. Thread-local because the worker threads are
/// long-lived: buffers grow to the working-set size once and are reused
/// across every row/query handled by that worker.
#[derive(Default)]
struct PanelScratch {
    /// Gathered neighbor inputs (q×d, row-major).
    xp: Vec<f64>,
    /// Gathered `V` rows (q×m).
    vp: Vec<f64>,
    /// Gathered `E` rows (q×m).
    ep: Vec<f64>,
    /// Gathered `T^p` rows for one parameter at a time (q×m).
    tp: Vec<f64>,
    /// Panel covariance buffer.
    buf: Vec<f64>,
    /// Panel gradient buffer ((1+d)·q per-parameter blocks).
    gbuf: Vec<f64>,
}

thread_local! {
    static PANEL_SCRATCH: RefCell<PanelScratch> = RefCell::new(PanelScratch::default());
}

/// Gather rows `idx` of `src` into the contiguous row-major panel `out`.
fn gather_rows(src: &Mat, idx: &[u32], out: &mut Vec<f64>) {
    out.clear();
    out.reserve(idx.len() * src.cols());
    for &j in idx {
        out.extend_from_slice(src.row(j as usize));
    }
}

impl<'a> ResidualCov for VifResidualOracle<'a> {
    fn rho(&self, i: usize, j: usize) -> f64 {
        let k = if i == j {
            self.kernel.variance
        } else {
            self.kernel.cov(self.x.row(i), self.x.row(j))
        };
        match self.lr {
            Some(lr) => k - dot(lr.vt.row(i), lr.vt.row(j)),
            None => k,
        }
    }

    fn num_params(&self) -> usize {
        self.kernel.num_params() + self.extra_params
    }

    fn rho_and_grad(&self, i: usize, j: usize, grad: &mut [f64]) -> f64 {
        let nk = self.kernel.num_params();
        let k = self
            .kernel
            .cov_and_grad_into(self.x.row(i), self.x.row(j), &mut grad[..nk]);
        for gp in grad[nk..].iter_mut() {
            *gp = 0.0;
        }
        match self.lr {
            Some(lr) => {
                let aux = self
                    .grad_aux
                    .expect("rho_and_grad with inducing points needs GradAux");
                let (ei, ej) = (lr.et.row(i), lr.et.row(j));
                for (p, gp) in grad[..nk].iter_mut().enumerate() {
                    *gp -= dot(aux.t[p].row(i), ej) + dot(ei, aux.t[p].row(j));
                }
                k - dot(lr.vt.row(i), lr.vt.row(j))
            }
            None => k,
        }
    }

    /// Panelized `ρ_NN`/`ρ_iN`: the strictly-lower kernel triangle is
    /// filled row-by-row against the gathered prefix panel, the diagonal
    /// is `σ₁²`, and the low-rank part is **one** `ρ_NN −= V_nb V_nbᵀ`
    /// SYRK plus a `V_nb v_i` product for the row.
    fn rho_block(&self, i: usize, nb: &[u32], rho_nn: &mut Mat, rho_in: &mut [f64]) -> f64 {
        let q = nb.len();
        let d = self.kernel.dim();
        PANEL_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            // Coordinate panel: from the frozen plan when available,
            // else gathered into per-worker scratch.
            let xp: &[f64] = match self.x_panels {
                Some(p) => p.row_panel(i),
                None => {
                    gather_rows(self.x, nb, &mut s.xp);
                    &s.xp
                }
            };
            for a in 0..q {
                let ja = nb[a] as usize;
                let row = rho_nn.row_mut(a);
                self.kernel
                    .cov_panel(self.x.row(ja), &xp[..a * d], &mut row[..a]);
                row[a] = self.kernel.variance;
            }
            // mirror the computed lower triangle
            for a in 0..q {
                for b in 0..a {
                    let v = rho_nn.get(a, b);
                    rho_nn.set(b, a, v);
                }
            }
            self.kernel.cov_panel(self.x.row(i), xp, rho_in);
            match self.lr {
                Some(lr) => {
                    let m = lr.m();
                    gather_rows(&lr.vt, nb, &mut s.vp);
                    rho_nn.syrk_sub_panel(&s.vp, m);
                    let vi = lr.vt.row(i);
                    for (t, r) in rho_in.iter_mut().enumerate() {
                        *r -= dot(&s.vp[t * m..(t + 1) * m], vi);
                    }
                    self.kernel.variance - dot(vi, vi)
                }
                None => self.kernel.variance,
            }
        })
    }

    /// Panelized blocks **and** gradients: kernel values + all `1+d`
    /// kernel-parameter gradients come from one `cov_and_grad_panel`
    /// sweep per row (shared `dcorr_dr`), and the low-rank corrections
    /// are blocked rank updates — `ρ_NN −= V_nb V_nbᵀ` (SYRK) and
    /// `∂ρ_NN −= T^p_nb E_nbᵀ + E_nb (T^p_nb)ᵀ` (SYR2K) per parameter.
    #[allow(clippy::too_many_arguments)]
    fn rho_and_grad_block(
        &self,
        i: usize,
        nb: &[u32],
        rho_nn: &mut Mat,
        rho_in: &mut [f64],
        d_rho_nn: &mut [Mat],
        d_rho_in: &mut Mat,
        d_rho_ii: &mut [f64],
    ) -> f64 {
        let q = nb.len();
        let d = self.kernel.dim();
        let nk = self.kernel.num_params();
        let np = self.num_params();
        PANEL_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            // Coordinate panel: frozen plan or per-worker scratch gather.
            let xp: &[f64] = match self.x_panels {
                Some(p) => p.row_panel(i),
                None => {
                    gather_rows(self.x, nb, &mut s.xp);
                    &s.xp
                }
            };
            // Kernel part: strictly-lower triangle row-by-row against the
            // gathered prefix panel; diagonal is σ₁² (gradients: the
            // log-σ₁² slot is σ₁², every other slot 0 at r = 0).
            for a in 0..q {
                let ja = nb[a] as usize;
                if a > 0 {
                    s.buf.resize(a, 0.0);
                    s.gbuf.resize(nk * a, 0.0);
                    self.kernel.cov_and_grad_panel(
                        self.x.row(ja),
                        &xp[..a * d],
                        &mut s.buf[..a],
                        &mut s.gbuf[..nk * a],
                    );
                    rho_nn.row_mut(a)[..a].copy_from_slice(&s.buf[..a]);
                    for (p, block) in s.gbuf[..nk * a].chunks_exact(a).enumerate() {
                        d_rho_nn[p].row_mut(a)[..a].copy_from_slice(block);
                    }
                }
                rho_nn.row_mut(a)[a] = self.kernel.variance;
                d_rho_nn[0].row_mut(a)[a] = self.kernel.variance;
                for mat in d_rho_nn.iter_mut().take(nk).skip(1) {
                    mat.row_mut(a)[a] = 0.0;
                }
            }
            // Mirror lower → upper for the kernel blocks.
            for a in 0..q {
                for b in 0..a {
                    let v = rho_nn.get(a, b);
                    rho_nn.set(b, a, v);
                    for mat in d_rho_nn.iter_mut().take(nk) {
                        let g = mat.get(a, b);
                        mat.set(b, a, g);
                    }
                }
            }
            // Extra (zero-gradient) parameter slots are fully overwritten.
            for mat in d_rho_nn.iter_mut().skip(nk) {
                for v in mat.data_mut() {
                    *v = 0.0;
                }
            }
            // ρ_iN row + gradients.
            if q > 0 {
                s.buf.resize(q, 0.0);
                s.gbuf.resize(nk * q, 0.0);
                self.kernel.cov_and_grad_panel(
                    self.x.row(i),
                    &xp[..q * d],
                    &mut s.buf[..q],
                    &mut s.gbuf[..nk * q],
                );
                rho_in.copy_from_slice(&s.buf[..q]);
                for p in 0..nk {
                    d_rho_in
                        .row_mut(p)
                        .copy_from_slice(&s.gbuf[p * q..(p + 1) * q]);
                }
            }
            for p in nk..np {
                for v in d_rho_in.row_mut(p) {
                    *v = 0.0;
                }
            }
            // ρ_ii and its gradients (r = 0 for the kernel part).
            d_rho_ii[0] = self.kernel.variance;
            for g in d_rho_ii.iter_mut().skip(1) {
                *g = 0.0;
            }
            match self.lr {
                Some(lr) => {
                    let aux = self
                        .grad_aux
                        .expect("rho_and_grad_block with inducing points needs GradAux");
                    let m = lr.m();
                    gather_rows(&lr.vt, nb, &mut s.vp);
                    gather_rows(&lr.et, nb, &mut s.ep);
                    rho_nn.syrk_sub_panel(&s.vp, m);
                    let vi = lr.vt.row(i);
                    for (t, r) in rho_in.iter_mut().enumerate() {
                        *r -= dot(&s.vp[t * m..(t + 1) * m], vi);
                    }
                    let ei = lr.et.row(i);
                    for p in 0..nk {
                        gather_rows(&aux.t[p], nb, &mut s.tp);
                        d_rho_nn[p].syr2k_sub_panel(&s.tp, &s.ep, m);
                        let ti = aux.t[p].row(i);
                        let drow = d_rho_in.row_mut(p);
                        for (t, g) in drow.iter_mut().enumerate() {
                            *g -= dot(ti, &s.ep[t * m..(t + 1) * m])
                                + dot(ei, &s.tp[t * m..(t + 1) * m]);
                        }
                        d_rho_ii[p] -= 2.0 * dot(ti, ei);
                    }
                    self.kernel.variance - dot(vi, vi)
                }
                None => self.kernel.variance,
            }
        })
    }
}

/// Correlation → distance transform `d_c = √(1 − |ρ/√(ρ_ii ρ_jj)|)`
/// (paper §6), shared by the training-side [`CorrelationMetric`] and the
/// prediction-side stacked-index metric in [`predict`] so the two
/// neighbor searches can never drift apart on the metric definition.
#[inline]
pub(crate) fn correlation_distance(rho: f64, di: f64, dj: f64) -> f64 {
    let r = rho / (di * dj).sqrt();
    (1.0 - r.abs()).max(0.0).sqrt()
}

/// Correlation distance `d_c(i,j) = √(1 − |ρ_ij/√(ρ_ii ρ_jj)|)` on the
/// residual process (paper §6), used by the cover-tree and brute-force
/// neighbor searches.
///
/// The batched path ([`Metric::dist_batch`]) fetches the query row
/// `x_i`/`v_i` once, gathers the candidate inputs into a per-worker
/// panel, evaluates the kernel part through
/// [`ArdMatern::cov_panel`], applies the low-rank correction as
/// length-`m` dot products against the cached `v_i`, and finishes with
/// the correlation→distance transform over the contiguous batch — no
/// scalar per-pair `rho` calls remain in the search hot loop. The
/// residual diagonal `ρ(j,j)` is precomputed for every point at
/// construction (directly as `σ₁² − ‖v_j‖²`, not through the oracle).
pub struct CorrelationMetric<'a> {
    kernel: &'a ArdMatern,
    x: &'a Mat,
    lr: Option<&'a LowRank>,
    /// `ρ(j,j)` clamped away from zero.
    diag: Vec<f64>,
}

impl<'a> CorrelationMetric<'a> {
    pub fn new(kernel: &'a ArdMatern, x: &'a Mat, lr: Option<&'a LowRank>) -> Self {
        let n = x.rows();
        let diag: Vec<f64> = match lr {
            Some(lr) => (0..n)
                .map(|j| (kernel.variance - norm2_sq(lr.vt.row(j))).max(1e-300))
                .collect(),
            None => vec![kernel.variance.max(1e-300); n],
        };
        CorrelationMetric { kernel, x, lr, diag }
    }
}

impl Metric for CorrelationMetric<'_> {
    fn dist(&self, i: usize, j: usize) -> f64 {
        let k = if i == j {
            self.kernel.variance
        } else {
            self.kernel.cov(self.x.row(i), self.x.row(j))
        };
        let rho = match self.lr {
            Some(lr) => k - dot(lr.vt.row(i), lr.vt.row(j)),
            None => k,
        };
        correlation_distance(rho, self.diag[i], self.diag[j])
    }

    fn dist_batch(&self, i: usize, cand: &[u32], out: &mut [f64]) {
        PANEL_SCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            gather_rows(self.x, cand, &mut s.xp);
            self.kernel.cov_panel(self.x.row(i), &s.xp, out);
            if let Some(lr) = self.lr {
                let vi = lr.vt.row(i);
                for (o, &j) in out.iter_mut().zip(cand) {
                    *o -= dot(vi, lr.vt.row(j as usize));
                }
            }
            let di = self.diag[i];
            for (o, &j) in out.iter_mut().zip(cand) {
                *o = correlation_distance(*o, di, self.diag[j as usize]);
            }
        })
    }
}

/// Process-wide monotone source of structure generations. Starts at 1 so
/// generation 0 stays free as the "unchecked" sentinel of externally
/// built prediction plans (`predict::PredictPlan::from_neighbor_sets`).
static STRUCTURE_GENERATION: AtomicU64 = AtomicU64::new(1);

/// A fresh, process-unique structure generation.
fn next_generation() -> u64 {
    STRUCTURE_GENERATION.fetch_add(1, Ordering::Relaxed)
}

/// The assembled VIF structure for one parameter vector θ.
///
/// `Clone` copies every numeric block (O(n·(m + m_v)) memory) and keeps
/// the generation stamp; it exists so the serving engine
/// ([`crate::serve`]) can freeze a fitted structure into an immutable
/// snapshot while the writer's copy continues to `append`/`refresh`.
#[derive(Clone)]
pub struct VifStructure {
    /// Low-rank part (None when m = 0 → pure Vecchia).
    pub lr: Option<LowRank>,
    /// Residual Vecchia factor (B, D).
    pub resid: ResidualFactor,
    /// `B Σ_mnᵀ` (n×m).
    pub bsig: Mat,
    /// `H = D⁻¹ B Σ_mnᵀ` (n×m).
    pub h: Mat,
    /// `S Σ_mnᵀ = Bᵀ H` (n×m).
    pub ssig: Mat,
    /// `SS = Σ_mn S Σ_mnᵀ` (m×m).
    pub ss: Mat,
    /// The Woodbury core `M = Σ_m + SS` itself (m×m); kept so consumers
    /// that need `M` minus a correction (e.g. the VIFDU preconditioner's
    /// `M₃`) do not have to reconstruct it from its factor.
    pub mcal: Option<Mat>,
    /// Cholesky of `M = Σ_m + SS`.
    pub chol_mcal: Option<CholeskyFactor>,
    /// Error-variance nugget baked into the residual factor (0 = latent scale).
    pub nugget: f64,
    /// Monotone structure generation: assigned fresh at assembly and
    /// bumped by every [`append`](Self::append), so serving-side caches
    /// (`predict::PredictPlan`) can detect that the point set they were
    /// built against changed. A θ-only [`refresh`](Self::refresh) keeps
    /// the generation — the conditioning sets a prediction plan froze
    /// are still the plan's own business to invalidate on θ changes
    /// (the keyed panel cache handles that softly).
    pub generation: u64,
}

impl VifStructure {
    /// Assemble the structure: low-rank blocks, residual factor, Woodbury
    /// core. `z` — inducing inputs (empty Mat → none); `neighbors` —
    /// conditioning sets; `nugget` — error variance on the residual diag.
    pub fn assemble(
        x: &Mat,
        kernel: &ArdMatern,
        z: Option<Mat>,
        neighbors: Vec<Vec<u32>>,
        nugget: f64,
        jitter: f64,
        extra_params: usize,
    ) -> Self {
        let lr = z.map(|z| LowRank::build(x, kernel, z, jitter));
        let oracle = VifResidualOracle {
            kernel,
            x,
            lr: lr.as_ref(),
            grad_aux: None,
            extra_params,
            x_panels: None,
        };
        let resid = ResidualFactor::build(&oracle, neighbors, nugget, jitter);
        Self::finish(lr, resid, nugget, jitter)
    }

    /// Assemble from a frozen θ-independent [`VifPlan`] — the single
    /// allocation/symbolic pass per re-selection round. The level
    /// schedule and `Bᵀ` pattern are cloned from the plan instead of
    /// recomputed, and the oracle reads the plan's pre-gathered
    /// coordinate panels. Numerically identical to
    /// [`assemble`](Self::assemble) with the same choices; every later
    /// θ step should go through [`refresh`](Self::refresh).
    pub fn from_plan(
        x: &Mat,
        kernel: &ArdMatern,
        plan: &VifPlan,
        nugget: f64,
        jitter: f64,
        extra_params: usize,
    ) -> Self {
        let lr = plan
            .z
            .clone()
            .map(|z| LowRank::build(x, kernel, z, jitter));
        let (a, d) = {
            let oracle = VifResidualOracle {
                kernel,
                x,
                lr: lr.as_ref(),
                grad_aux: None,
                extra_params,
                x_panels: Some(&plan.x_panels),
            };
            ResidualFactor::compute_rows(&oracle, &plan.neighbors, nugget, jitter)
        };
        let resid = ResidualFactor::from_parts_precomputed(
            plan.neighbors.clone(),
            a,
            d,
            plan.schedule.clone(),
            plan.bt_index.clone(),
        );
        Self::finish(lr, resid, nugget, jitter)
    }

    /// Shared tail of [`assemble`](Self::assemble) /
    /// [`from_plan`](Self::from_plan): the Woodbury blocks and core.
    fn finish(lr: Option<LowRank>, resid: ResidualFactor, nugget: f64, jitter: f64) -> Self {
        let (bsig, h, ssig, ss, mcal, chol_mcal) = match &lr {
            Some(lr) => {
                let bsig = resid.mul_b_mat(&lr.sigma_nm);
                let mut h = bsig.clone();
                h.scale_rows(resid.inv_d());
                let ssig = resid.mul_bt_mat(&h);
                // M = Σ_m + (BΣ_mnᵀ)ᵀ H;   SS = Σ_mnᵀ-weighted: sigma_nmᵀ ssig
                let ss = lr.sigma_nm.matmul_tn(&ssig);
                let mut mcal = bsig.matmul_tn(&h);
                // mcal = (BΣ)ᵀ H = Σ_mn Bᵀ D⁻¹ B Σ_mnᵀ = SS (same thing,
                // numerically symmetric by construction); add the Σ_m
                // already formed in LowRank::build (no L Lᵀ rebuild).
                mcal.add_assign(&lr.sig_m);
                let jf = CholeskyFactor::new_with_jitter_tracked(&mcal, jitter.max(1e-10))
                    .expect("Woodbury core M not PD");
                crate::iterative::solve_stats().note_jitter(jf.jitter);
                let chol_mcal = jf.factor;
                (bsig, h, ssig, ss, Some(mcal), Some(chol_mcal))
            }
            None => (
                Mat::zeros(0, 0),
                Mat::zeros(0, 0),
                Mat::zeros(0, 0),
                Mat::zeros(0, 0),
                None,
                None,
            ),
        };
        VifStructure {
            lr,
            resid,
            bsig,
            h,
            ssig,
            ss,
            mcal,
            chol_mcal,
            nugget,
            generation: next_generation(),
        }
    }

    /// θ-refresh — the numeric (factorize) half of the plan/refresh
    /// split: re-evaluate every θ-dependent quantity **in place** for
    /// the structure choices frozen in `plan`, without touching the
    /// neighbor graph, the level schedule, the `Bᵀ` pattern, or any of
    /// the big panel allocations. The math is identical to a fresh
    /// [`assemble`](Self::assemble) at the same θ (pinned ≤1e-12 in
    /// `tests/refresh.rs`), so L-BFGS objective closures can refresh one
    /// structure per evaluation instead of re-assembling.
    ///
    /// The structure must have been built for the same plan (same graph
    /// and inducing set); `x` is the same training input matrix.
    pub fn refresh(
        &mut self,
        plan: &VifPlan,
        x: &Mat,
        kernel: &ArdMatern,
        nugget: f64,
        jitter: f64,
    ) {
        debug_assert_eq!(self.n(), plan.n(), "structure/plan size mismatch");
        debug_assert_eq!(self.m(), plan.m(), "structure/plan inducing mismatch");
        // Low-rank panels (Σ_m, Σ_mn, V, E) in place.
        if let Some(lr) = self.lr.as_mut() {
            lr.refresh(x, kernel, jitter);
        }
        // Residual factor values (A, D, 1/D, Bᵀ coefficients) in place.
        {
            let oracle = VifResidualOracle {
                kernel,
                x,
                lr: self.lr.as_ref(),
                grad_aux: None,
                extra_params: 0,
                x_panels: Some(&plan.x_panels),
            };
            self.resid.refresh_values(&oracle, nugget, jitter);
        }
        // Woodbury blocks in place (same kernels as `finish`).
        if let Some(lr) = self.lr.as_ref() {
            self.resid.mul_b_mat_into(&lr.sigma_nm, &mut self.bsig);
            self.h.data_mut().copy_from_slice(self.bsig.data());
            self.h.scale_rows(self.resid.inv_d());
            self.resid.mul_bt_mat_into(&self.h, &mut self.ssig);
            lr.sigma_nm.matmul_tn_into(&self.ssig, &mut self.ss);
            let mcal = self.mcal.as_mut().expect("structure built with m > 0");
            self.bsig.matmul_tn_into(&self.h, mcal);
            mcal.add_assign(&lr.sig_m);
            let jf = CholeskyFactor::new_with_jitter_tracked(mcal, jitter.max(1e-10))
                .expect("Woodbury core M not PD");
            crate::iterative::solve_stats().note_jitter(jf.jitter);
            self.chol_mcal = Some(jf.factor);
        }
        self.nugget = nugget;
    }

    /// Incrementally ingest appended points — the numeric heart of the
    /// streaming-append path, layered bottom-up over the incremental
    /// primitives of every subsystem:
    ///
    /// 1. [`LowRank::append_cols`] grows `Σ_mn`/`V`/`E` by panel
    ///    evaluation of the new columns only (`Z`, `Σ_m`, `L_m` frozen);
    /// 2. leaf conditioning sets for the new rows are searched among the
    ///    **pre-existing** points only (cover-tree
    ///    `knn_ordered_with` over the frozen members via
    ///    [`CorrelationMetric`], brute-force panel sweeps otherwise);
    /// 3. [`VifPlan::append`] extends the frozen symbolic plan;
    /// 4. the new factor rows run through the same panelized oracle and
    ///    per-row math as a build (`ResidualFactor::compute_rows_at` +
    ///    `append_rows` — bit-identical rows, bit-identical `Bᵀ`
    ///    pattern);
    /// 5. the Woodbury side blocks grow by rows whose gather order
    ///    matches the rebuilt sweeps bit for bit, and the `m×m` core
    ///    takes one blocked weighted rank-k update
    ///    (`Mat::syrk_add_panel_weighted`) per batch.
    ///
    /// The result is numerically equivalent (≤1e-12, pinned by
    /// `tests/append.rs`) to a from-scratch [`from_plan`](Self::from_plan)
    /// over the extended data — `B`/`D`/schedule/pattern and the
    /// `BΣ_mnᵀ`/`H`/`SΣ_mnᵀ` blocks are exactly reproduced; `SS` and `M`
    /// differ only by floating-point regrouping of the rank-k sum. The
    /// structure generation is bumped, invalidating cached prediction
    /// plans. Appending never revisits existing rows' conditioning sets;
    /// the models' `compact()` bounds the drift.
    #[allow(clippy::too_many_arguments)]
    pub fn append(
        &mut self,
        plan: &mut VifPlan,
        x_full: &Mat,
        kernel: &ArdMatern,
        x_new: &Mat,
        m_v: usize,
        selection: NeighborSelection,
        jitter: f64,
    ) {
        let base = plan.n();
        let k_new = x_new.rows();
        assert_eq!(self.n(), base, "structure/plan size mismatch");
        assert_eq!(
            x_full.rows(),
            base + k_new,
            "x_full must already contain the appended rows"
        );
        if k_new == 0 {
            return; // bitwise no-op; generation unchanged
        }
        // 1. Low-rank columns first: the correlation metric and the
        // residual oracle below read `V` rows of the appended points.
        if let Some(lr) = self.lr.as_mut() {
            lr.append_cols(x_new, kernel);
        }
        // 2. Leaf conditioning sets among pre-existing points only.
        let new_nb =
            append_neighbor_sets(x_full, kernel, self.lr.as_ref(), base, m_v, selection);
        // 3. Extend the frozen plan (graph, schedule, pattern, panels).
        plan.append(x_full, new_nb.clone());
        // 4. New factor rows through the panelized oracle (plan panels).
        let (a_new, d_new) = {
            let oracle = VifResidualOracle {
                kernel,
                x: x_full,
                lr: self.lr.as_ref(),
                grad_aux: None,
                extra_params: 0, // gradients never flow through this path
                x_panels: Some(&plan.x_panels),
            };
            ResidualFactor::compute_rows_at(&oracle, &new_nb, base, self.nugget, jitter)
        };
        self.resid.append_rows(new_nb, a_new, d_new);
        // 5. Woodbury side blocks + blocked rank-k core update.
        if self.lr.is_some() {
            self.append_woodbury(base, k_new, jitter);
        }
        self.generation = next_generation();
    }

    /// Grow the Woodbury blocks for `k_new` appended rows. The row
    /// updates replay exactly the gather sequences the rebuilt sweeps
    /// would run — `ΔBΣ_mnᵀ` rows mirror `mul_b_mat`'s copy-then-subtract
    /// order, and existing `SΣ_mnᵀ` rows gain their new owners' terms in
    /// ascending owner order, matching `mul_bt_mat`'s per-column gather —
    /// so `BΣ_mnᵀ`, `H`, and `SΣ_mnᵀ` stay bit-identical to a rebuild.
    /// `SS` and `M` take the mathematically exact rank-k update
    /// `Σ_{new i} (1/D_i)·(BΣ)_iᵀ(BΣ)_i` (a different summation grouping
    /// than the rebuilt GEMM, hence ≤1e-12 rather than bitwise), and the
    /// `m×m` core is re-factorized — O(m³) per batch, negligible next to
    /// the per-batch panel work; a lazily updated factor past a fill
    /// threshold is the documented upgrade path if m grows.
    fn append_woodbury(&mut self, base: usize, k_new: usize, jitter: f64) {
        let lr = self.lr.as_ref().expect("append_woodbury needs the low-rank part");
        let m = lr.m();
        // ΔBΣ_mnᵀ rows (same per-row arithmetic order as mul_b_mat).
        let mut dbsig = Mat::zeros(k_new, m);
        let mut buf = vec![0.0; m];
        for t in 0..k_new {
            let i = base + t;
            buf.copy_from_slice(lr.sigma_nm.row(i));
            for (kk, &j) in self.resid.neighbors[i].iter().enumerate() {
                let a = self.resid.a[i][kk];
                for (o, &v) in buf.iter_mut().zip(lr.sigma_nm.row(j as usize)) {
                    *o -= a * v;
                }
            }
            dbsig.row_mut(t).copy_from_slice(&buf);
        }
        // ΔH = D⁻¹ ΔBΣ_mnᵀ rows.
        let w: Vec<f64> = self.resid.inv_d()[base..].to_vec();
        let mut dh = dbsig.clone();
        dh.scale_rows(&w);
        // Existing SΣ_mnᵀ rows gain the appended owners' gather terms in
        // ascending owner order — exactly where the rebuilt `mul_bt_mat`
        // gather would append them, so each row stays bit-identical.
        // Appended rows equal ΔH: new columns have no owners (appended
        // rows condition only on pre-existing points).
        for t in 0..k_new {
            let i = base + t;
            for (kk, &j) in self.resid.neighbors[i].iter().enumerate() {
                let a = self.resid.a[i][kk];
                let dst = self.ssig.row_mut(j as usize);
                for (o, &v) in dst.iter_mut().zip(dh.row(t)) {
                    *o -= a * v;
                }
            }
        }
        self.bsig.append_rows(&dbsig);
        self.h.append_rows(&dh);
        self.ssig.append_rows(&dh);
        // Rank-k core updates: SS += ΔΣᵀD⁻¹ΔΣ, M likewise (old rows of
        // BΣ_mnᵀ and D are untouched by the append, so the delta is
        // exactly the appended rows' weighted outer products).
        self.ss.syrk_add_panel_weighted(dbsig.data(), m, &w);
        let mcal = self.mcal.as_mut().expect("low-rank structure without Woodbury core");
        mcal.syrk_add_panel_weighted(dbsig.data(), m, &w);
        let jf =
            CholeskyFactor::new_with_jitter_tracked(self.mcal.as_ref().unwrap(), jitter.max(1e-10))
                .expect("Woodbury core M not PD after append");
        crate::iterative::solve_stats().note_jitter(jf.jitter);
        self.chol_mcal = Some(jf.factor);
    }

    pub fn n(&self) -> usize {
        self.resid.n()
    }

    pub fn m(&self) -> usize {
        self.lr.as_ref().map(|l| l.m()).unwrap_or(0)
    }

    /// `Σ̃_†⁻¹ v = S v − (SΣ_mnᵀ) M⁻¹ (Σ_mn S v)` (Sherman–Woodbury–Morrison).
    pub fn apply_sigma_dagger_inv(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.resid.apply_s(v);
        if let Some(chol_mcal) = &self.chol_mcal {
            let svt = self.ssig.matvec_t(v); // (SΣ_mnᵀ)ᵀ v = Σ_mn S v
            let c = chol_mcal.solve(&svt);
            let corr = self.ssig.matvec(&c);
            for (o, r) in out.iter_mut().zip(&corr) {
                *o -= r;
            }
        }
        out
    }

    /// `Σ̃_† v = Σ_mnᵀ Σ_m⁻¹ Σ_mn v + B⁻¹ D B⁻ᵀ v`.
    pub fn apply_sigma_dagger(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.resid.apply_s_inv(v);
        if let Some(lr) = &self.lr {
            let w = lr.vt.matvec_t(v); // (L⁻¹Σ_mn) v
            let corr = lr.vt.matvec(&w); // Σ_mnᵀ Σ_m⁻¹ Σ_mn v
            for (o, r) in out.iter_mut().zip(&corr) {
                *o += r;
            }
        }
        out
    }

    /// Column-blocked `Σ̃_†⁻¹ V` (n×k, one vector per column): one sparse
    /// B/Bᵀ sweep over all columns and the Woodbury core applied to the
    /// block in a single `solve_mat`. The `B` sweeps are level-scheduled
    /// (`vecchia` module docs) — for large `n` each dependency level fans
    /// out over the worker pool, tiled over column blocks.
    pub fn apply_sigma_dagger_inv_batch(&self, v: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(v.rows(), n);
        // S V = Bᵀ D⁻¹ B V (cached reciprocals, no per-apply allocation)
        let mut bv = self.resid.mul_b_mat(v);
        bv.scale_rows(self.resid.inv_d());
        let mut out = self.resid.mul_bt_mat(&bv);
        if let Some(chol_mcal) = &self.chol_mcal {
            let svt = self.ssig.matmul_tn(v); // Σ_mn S V (m×k)
            let c = chol_mcal.solve_mat(&svt); // M⁻¹ · (m×k)
            let corr = self.ssig.matmul(&c); // (SΣ_mnᵀ) · (n×k)
            out.sub_assign(&corr);
        }
        out
    }

    /// Column-blocked `Σ̃_† V` (n×k, one vector per column).
    pub fn apply_sigma_dagger_batch(&self, v: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(v.rows(), n);
        // S⁻¹ V = B⁻¹ D B⁻ᵀ V
        let mut bt = self.resid.solve_bt_mat(v);
        bt.scale_rows(&self.resid.d);
        let mut out = self.resid.solve_b_mat(&bt);
        if let Some(lr) = &self.lr {
            let w = lr.vt.matmul_tn(v); // (L⁻¹Σ_mn) V (m×k)
            let corr = lr.vt.matmul(&w); // Σ_mnᵀ Σ_m⁻¹ Σ_mn V (n×k)
            out.add_assign(&corr);
        }
        out
    }

    /// `log det Σ̃_† = log det M − log det Σ_m + log det D`.
    pub fn logdet(&self) -> f64 {
        let mut ld = self.resid.logdet();
        if let (Some(lr), Some(cm)) = (&self.lr, &self.chol_mcal) {
            ld += cm.logdet() - lr.chol_m.logdet();
        }
        ld
    }

    /// Sample `x ~ N(0, Σ̃_†)`: low-rank part `Σ_mnᵀ Σ_m^{-T/2} ε₁` plus
    /// residual part `B⁻¹ D^{1/2} ε₂` (used by Algorithm 1 line 4 and for
    /// data simulation).
    pub fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        let n = self.n();
        let mut out = self.resid.sample(&rng.normal_vec(n));
        if let Some(lr) = &self.lr {
            // Σ_mnᵀ Σ_m⁻¹ L_m ε = Σ_mnᵀ L_m⁻ᵀ ε = vtᵀ... : vt row i = L⁻¹Σ_mi,
            // so vt · ε has covariance Σ_mnᵀ Σ_m⁻¹ Σ_mn.
            let eps = rng.normal_vec(lr.m());
            let low = lr.vt.matvec(&eps);
            for (o, l) in out.iter_mut().zip(&low) {
                *o += l;
            }
        }
        out
    }

    /// Densify `Σ̃_†` (tests / small n only).
    pub fn dense_sigma_dagger(&self) -> Mat {
        let n = self.n();
        let mut out = Mat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = self.apply_sigma_dagger(&e);
            for i in 0..n {
                out.set(i, j, col[i]);
            }
        }
        out
    }
}

/// Select inducing points per §6: kMeans++ in the λ-scaled space,
/// optionally warm-started from previous centers.
pub fn select_inducing(
    x: &Mat,
    kernel: &ArdMatern,
    m: usize,
    lloyd_iters: usize,
    rng: &mut Rng,
    warm: Option<&Mat>,
) -> Option<Mat> {
    if m == 0 {
        return None;
    }
    let xs = inducing::scale_inputs(x, &kernel.length_scales);
    let centers_scaled = match warm {
        Some(w) => {
            let ws = inducing::scale_inputs(w, &kernel.length_scales);
            inducing::lloyd(&xs, ws, lloyd_iters.max(1))
        }
        None => inducing::kmeanspp(&xs, m, lloyd_iters, rng),
    };
    Some(inducing::unscale_inputs(&centers_scaled, &kernel.length_scales))
}

/// Select Vecchia conditioning sets per §6 for the residual process of a
/// given kernel + optional low-rank part.
pub fn select_neighbors(
    x: &Mat,
    kernel: &ArdMatern,
    lr: Option<&LowRank>,
    m_v: usize,
    selection: NeighborSelection,
) -> Vec<Vec<u32>> {
    let n = x.rows();
    if m_v == 0 {
        return vec![vec![]; n];
    }
    match selection {
        NeighborSelection::EuclideanTransformed => {
            let inv: Vec<f64> = kernel.length_scales.iter().map(|l| 1.0 / l).collect();
            neighbors::euclidean_ordered_knn(x, &inv, m_v)
        }
        NeighborSelection::CorrelationCoverTree | NeighborSelection::CorrelationBruteForce => {
            // d_c(i,j) = sqrt(1 − |ρ_ij / sqrt(ρ_ii ρ_jj)|)  (§6),
            // evaluated in candidate batches through the panel kernels.
            let metric = CorrelationMetric::new(kernel, x, lr);
            if selection == NeighborSelection::CorrelationCoverTree {
                neighbors::covertree_ordered_knn(n, m_v, &metric)
            } else {
                neighbors::brute_force_ordered_knn(n, m_v, &metric)
            }
        }
    }
}

/// Conditioning sets for appended points among the `base` pre-existing
/// points only — leaf conditioning: the frozen graph over `0..base` is
/// untouched and every appended row conditions strictly on earlier data
/// (the drift this one-sided rule accumulates is bounded by the models'
/// `compact()` re-selection). `x_full` already contains the appended
/// rows at `base..`, and `lr` — when present — already covers them
/// ([`LowRank::append_cols`] runs first). Mirrors the prediction-side
/// search in `predict`: same metric family, same cover-tree-over-members
/// external-query pattern, same brute-force fallback for small batches.
fn append_neighbor_sets(
    x_full: &Mat,
    kernel: &ArdMatern,
    lr: Option<&LowRank>,
    base: usize,
    m_v: usize,
    selection: NeighborSelection,
) -> Vec<Vec<u32>> {
    let k_new = x_full.rows() - base;
    if m_v == 0 || base == 0 {
        return vec![vec![]; k_new];
    }
    if base <= m_v {
        // Same convention as the ordered training search: with too few
        // predecessors every appended point conditions on all of them.
        return vec![(0..base as u32).collect(); k_new];
    }
    match selection {
        NeighborSelection::EuclideanTransformed => {
            crate::coordinator::parallel_map(k_new, |t| {
                let sp = x_full.row(base + t);
                let cand: Vec<(f64, u32)> = (0..base)
                    .map(|j| {
                        let d2: f64 = sp
                            .iter()
                            .zip(x_full.row(j))
                            .zip(&kernel.length_scales)
                            .map(|((a, b), l)| {
                                let u = (a - b) / l;
                                u * u
                            })
                            .sum();
                        (d2, j as u32)
                    })
                    .collect();
                predict::take_m_v(cand, m_v)
            })
        }
        NeighborSelection::CorrelationCoverTree | NeighborSelection::CorrelationBruteForce => {
            let metric = CorrelationMetric::new(kernel, x_full, lr);
            let use_tree = selection == NeighborSelection::CorrelationCoverTree
                && k_new >= predict::COVER_TREE_MIN_QUERIES;
            if use_tree {
                // Tree over the pre-existing points only; every appended
                // query index exceeds every member, so the ordered
                // query's `< i` pruning never hides a candidate (the
                // same external-query pattern as prediction search).
                let tree = CoverTree::build(base, &metric);
                let mut out: Vec<Vec<u32>> = vec![vec![]; k_new];
                {
                    let out_ptr = crate::coordinator::SyncSlice(out.as_mut_ptr());
                    let out_ptr = &out_ptr;
                    crate::coordinator::parallel_for_chunks(k_new, |start, end| {
                        let mut scratch = QueryScratch::new(base);
                        for t in start..end {
                            let mut idx =
                                tree.knn_ordered_with(base + t, m_v, &metric, &mut scratch);
                            idx.sort_unstable();
                            // SAFETY: disjoint indices per chunk.
                            unsafe {
                                *out_ptr.get().add(t) = idx;
                            }
                        }
                    });
                }
                out
            } else {
                let ids: Vec<u32> = (0..base as u32).collect();
                crate::coordinator::parallel_map(k_new, |t| {
                    let mut dists = vec![0.0; base];
                    metric.dist_batch(base + t, &ids, &mut dists);
                    let cand: Vec<(f64, u32)> =
                        dists.into_iter().zip(ids.iter().copied()).collect();
                    predict::take_m_v(cand, m_v)
                })
            }
        }
    }
}

/// Appended fraction of the training set past which the models'
/// `append_points` triggers a full [`FitModel::compact`] re-selection:
/// appended rows condition only on pre-existing points and never become
/// candidates for older rows' conditioning sets, so the approximation
/// drifts as the appended share grows — compaction bounds that drift.
pub(crate) const APPEND_COMPACT_FRACTION: f64 = 0.25;

/// Re-select the structure choices (§6) for the current kernel: inducing
/// points by kMeans++ in the λ-scaled space (warm-started from `warm`
/// when given), then Vecchia conditioning sets for the induced residual
/// process. Shared by the Gaussian and Laplace models' `assemble` paths
/// — this is the symbolic step that invalidates any existing [`VifPlan`].
pub fn select_structure(
    x: &Mat,
    kernel: &ArdMatern,
    config: &VifConfig,
    warm: Option<&Mat>,
) -> (Option<Mat>, Vec<Vec<u32>>) {
    let mut rng = Rng::seed_from(config.seed);
    let z = select_inducing(
        x,
        kernel,
        config.num_inducing.min(x.rows()),
        config.lloyd_iters,
        &mut rng,
        warm,
    );
    let lr_tmp = z
        .clone()
        .map(|z| LowRank::build(x, kernel, z, config.jitter));
    let nb = select_neighbors(x, kernel, lr_tmp.as_ref(), config.num_neighbors, config.selection);
    (z, nb)
}

/// Model hooks for the shared re-selection fit loop
/// [`fit_with_reselection`]. Implemented by `gaussian::VifRegression`
/// and `laplace::VifLaplaceModel`, which differ only in the objective —
/// the cadence (freeze → optimize → re-select → converge-check) and the
/// plan/refresh plumbing are identical.
pub trait FitModel {
    /// Re-select structure choices at the current parameters, build the
    /// round's [`VifPlan`], and assemble a fresh structure from it —
    /// the one symbolic/allocation pass per round.
    fn reselect(&mut self);
    /// Move the plan built by `reselect` out of the model; the round's
    /// L-BFGS evaluations borrow it.
    fn take_plan(&mut self) -> VifPlan;
    /// Move the assembled structure out of the model: it becomes the
    /// round's refresh target. `reselect` restores one afterwards.
    fn take_structure(&mut self) -> VifStructure;
    /// Packed optimizer parameters at the current model state.
    fn pack_params(&self) -> Vec<f64>;
    /// Adopt optimized packed parameters into the model state.
    fn adopt_params(&mut self, packed: &[f64]);
    /// Objective value + gradient at `packed`: numerically refresh `s`
    /// (shaped by `plan`) in place and evaluate — no symbolic work and
    /// no structure-choice clones on this path. `session` carries
    /// warm-start state across consecutive evaluations (see the
    /// module-level "Warm-start lifecycle" section); models with direct
    /// solves (Gaussian) ignore it, and a cold session must reproduce
    /// the session-free evaluation bit for bit.
    fn eval(
        &self,
        plan: &VifPlan,
        s: &mut VifStructure,
        packed: &[f64],
        session: &mut FitSession,
    ) -> (f64, Vec<f64>);
    /// Objective at the current parameters on the freshly re-selected
    /// structure (drives the between-round convergence check).
    fn round_nll(&mut self) -> f64;
    /// Gradient inf-norm tolerance handed to L-BFGS.
    fn lbfgs_tol(&self) -> f64;
    /// Append one round's accepted-step objective trace.
    fn record_trace(&mut self, trace: &[f64]);
    /// Incrementally ingest new observations at the current θ (the
    /// streaming-append path): validate, extend the model data, and run
    /// the layered [`VifStructure::append`] update — equivalent to a
    /// from-scratch re-assembly to ≤1e-12 (`tests/append.rs`). Bumps the
    /// structure generation (stale prediction plans are refused) and
    /// triggers [`compact`](Self::compact) past the appended-fraction
    /// threshold. Errors (dimension mismatch, non-finite inputs) leave
    /// the model untouched.
    fn append_points(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<(), String>;
    /// Full re-selection over all current data at the current θ —
    /// the compaction story that bounds leaf-conditioning drift from
    /// appends. Inducing points are warm-started through Lloyd, and the
    /// append drift counter resets.
    fn compact(&mut self);
}

/// Warm-start state threaded through [`fit_with_reselection`] across
/// consecutive L-BFGS objective evaluations (the module-level
/// "Warm-start lifecycle" section is the overview). A *cold* session
/// (`warm = false`) carries nothing and tags nothing: evaluations are
/// bit-for-bit identical to the session-free path, which stays the
/// oracle for the warm one.
pub struct FitSession {
    warm: bool,
    round: usize,
    /// Laplace-specific carried state (mode, s̃, FITC preconditioner).
    pub laplace: laplace::LaplaceSession,
}

impl FitSession {
    pub fn new(warm: bool) -> Self {
        FitSession { warm, round: 0, laplace: laplace::LaplaceSession::default() }
    }

    /// A session that never carries state (the oracle path).
    pub fn cold() -> Self {
        Self::new(false)
    }

    /// Whether evaluations may reuse state from previous ones.
    pub fn warm(&self) -> bool {
        self.warm
    }

    /// Re-selection round boundary: structure choices changed, so the
    /// structure-shaped carried state is dropped (the mode and s̃
    /// survive — they approximate the same posterior latents) and the
    /// SLQ probe tag advances.
    pub fn start_round(&mut self) {
        self.round += 1;
        self.laplace.clear_for_new_round();
    }

    /// Per-round SLQ probe-seed tag, XORed into the common-random-number
    /// seed: 0 when cold *and* in round 0 (reproducing the legacy
    /// probes), a round-indexed splitmix constant afterwards — probes
    /// are fixed along a round's trajectory and redrawn only at
    /// re-selection rounds.
    pub fn probe_tag(&self) -> u64 {
        if !self.warm || self.round == 0 {
            0
        } else {
            (self.round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        }
    }

    /// Per-objective-evaluation CG iteration deltas recorded by the fit
    /// driver (scalar + batched solves, from the
    /// [`crate::iterative::solve_stats`] registry).
    pub fn eval_cg_iters(&self) -> &[u64] {
        &self.laplace.eval_cg_iters
    }
}

/// Whether [`fit_with_reselection`] runs warm-started (`VIFGP_WARM_START`,
/// default on). Cached after the first read; malformed values panic
/// loudly rather than guessing.
pub fn warm_start_enabled() -> bool {
    use std::sync::OnceLock;
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| match std::env::var("VIFGP_WARM_START") {
        Ok(v) => parse_warm_start(&v),
        Err(_) => true,
    })
}

/// Parse a `VIFGP_WARM_START` value: `1` = warm-started fitting, `0` =
/// the cold oracle path. Anything else panics, naming knob and value —
/// a typo must not silently change which solver path benchmarks run.
fn parse_warm_start(v: &str) -> bool {
    match v {
        "1" => true,
        "0" => false,
        other => panic!(
            "VIFGP_WARM_START must be `0` (cold oracle) or `1` (warm-started), got `{other}`"
        ),
    }
}

/// Shared fit driver (§6 cadence) for Gaussian and Laplace models: up to
/// `rounds` rounds of {freeze structure choices into a [`VifPlan`] →
/// L-BFGS with in-place [`VifStructure::refresh`] per evaluation →
/// adopt parameters → re-select}, stopping early when the re-selected
/// objective stops moving. Exactly one plan build and one structure
/// assembly happen per round; every intermediate L-BFGS evaluation
/// borrows them. Consecutive evaluations share a [`FitSession`]
/// (warm-started unless `VIFGP_WARM_START=0`). Returns the final
/// objective value.
pub fn fit_with_reselection<M: FitModel>(model: &mut M, max_iters: usize, rounds: usize) -> f64 {
    fit_with_reselection_session(model, max_iters, rounds, warm_start_enabled())
}

/// [`fit_with_reselection`] with the warm/cold choice made explicitly —
/// the in-process entry point for tests and benches (the env knob is
/// cached process-wide, so it cannot be flipped between fits).
pub fn fit_with_reselection_session<M: FitModel>(
    model: &mut M,
    max_iters: usize,
    rounds: usize,
    warm: bool,
) -> f64 {
    model.reselect();
    let mut packed = model.pack_params();
    let mut last = f64::INFINITY;
    let session = RefCell::new(FitSession::new(warm));
    for round in 0..rounds {
        if round > 0 {
            session.borrow_mut().start_round();
        }
        // Freeze the structure choices for this round: the plan and
        // structure built by `reselect` move out of the model and every
        // objective evaluation below refreshes them in place.
        let plan = model.take_plan();
        let scratch = model.take_structure();
        let tol = model.lbfgs_tol();
        let res = {
            let m = &*model;
            let cell = RefCell::new(scratch);
            let f = |p: &[f64]| -> (f64, Vec<f64>) {
                let mut s = cell.borrow_mut();
                let mut sess = session.borrow_mut();
                let before = crate::iterative::solve_stats().snapshot().cg_iters;
                let (v, mut g) = m.eval(&plan, &mut s, p, &mut sess);
                let after = crate::iterative::solve_stats().snapshot().cg_iters;
                sess.laplace.eval_cg_iters.push(after.saturating_sub(before));
                // Containment: a non-finite objective or gradient is
                // sanitized to (+∞, finite gradient) so the L-BFGS line
                // search rejects the step (it only accepts finite trial
                // values) instead of walking on NaNs; occurrences are
                // counted in the process-wide containment registry.
                let bad_g = g.iter().any(|t| !t.is_finite());
                if !v.is_finite() || bad_g {
                    crate::iterative::solve_stats().note_nonfinite_eval();
                    for t in g.iter_mut() {
                        if !t.is_finite() {
                            *t = 0.0;
                        }
                    }
                    return (f64::INFINITY, g);
                }
                (v, g)
            };
            crate::optim::lbfgs(&f, &packed, max_iters, tol)
        };
        packed = res.x;
        model.record_trace(&res.trace);
        model.adopt_params(&packed);
        // Re-select structure for the new θ; stop when the objective
        // stops moving between rounds.
        model.reselect();
        let now = model.round_nll();
        if (last - now).abs() < 1e-4 * (1.0 + now.abs()) {
            last = now;
            break;
        }
        last = now;
    }
    // The final reselect left a plan behind; fitting is done, so free it
    // (panels + graph copy) instead of keeping it alive with the model.
    drop(model.take_plan());
    last
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::random_points;

    fn setup(n: usize, m: usize, m_v: usize) -> (Mat, ArdMatern, VifStructure) {
        let mut rng = Rng::seed_from(42);
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.3, vec![0.3, 0.4], Smoothness::ThreeHalves);
        let z = select_inducing(&x, &kernel, m, 3, &mut rng, None);
        let lr_tmp = z
            .clone()
            .map(|z| LowRank::build(&x, &kernel, z, 1e-10));
        let nb = select_neighbors(
            &x,
            &kernel,
            lr_tmp.as_ref(),
            m_v,
            NeighborSelection::CorrelationBruteForce,
        );
        let s = VifStructure::assemble(&x, &kernel, z, nb, 0.05, 1e-10, 0);
        (x, kernel, s)
    }

    #[test]
    fn inverse_is_consistent() {
        let (_, _, s) = setup(40, 8, 5);
        let v: Vec<f64> = (0..40).map(|i| (i as f64 * 0.3).sin()).collect();
        let w = s.apply_sigma_dagger_inv(&s.apply_sigma_dagger(&v));
        for (a, b) in w.iter().zip(&v) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_sigma_ops_match_columnwise() {
        let (_, _, s) = setup(40, 8, 5);
        let v = Mat::from_fn(40, 5, |i, j| ((i * 3 + j * 7) as f64 * 0.17).sin());
        let gi = s.apply_sigma_dagger_inv_batch(&v);
        let ga = s.apply_sigma_dagger_batch(&v);
        for j in 0..5 {
            let wi = s.apply_sigma_dagger_inv(&v.col(j));
            let wa = s.apply_sigma_dagger(&v.col(j));
            for i in 0..40 {
                assert!(
                    (gi.get(i, j) - wi[i]).abs() < 1e-10,
                    "inv col {j} row {i}: {} vs {}",
                    gi.get(i, j),
                    wi[i]
                );
                assert!(
                    (ga.get(i, j) - wa[i]).abs() < 1e-10,
                    "fwd col {j} row {i}: {} vs {}",
                    ga.get(i, j),
                    wa[i]
                );
            }
        }
    }

    #[test]
    fn logdet_matches_dense() {
        let (_, _, s) = setup(35, 6, 4);
        let dense = s.dense_sigma_dagger();
        let chol = CholeskyFactor::new(&dense).unwrap();
        assert!(
            (s.logdet() - chol.logdet()).abs() < 1e-7,
            "{} vs {}",
            s.logdet(),
            chol.logdet()
        );
    }

    #[test]
    fn full_conditioning_recovers_exact_covariance() {
        // With N(i)={0..i-1} and any m, Σ̃_† should equal Σ + σ²I exactly:
        // the Vecchia factor of the residual is exact, and low-rank +
        // exact-residual = full covariance.
        let mut rng = Rng::seed_from(7);
        let n = 25;
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(0.9, vec![0.25, 0.35], Smoothness::FiveHalves);
        let nb: Vec<Vec<u32>> = (0..n).map(|i| (0..i as u32).collect()).collect();
        let z = select_inducing(&x, &kernel, 5, 2, &mut rng, None);
        let s = VifStructure::assemble(&x, &kernel, z, nb, 0.01, 1e-12, 0);
        let dense = s.dense_sigma_dagger();
        let exact = kernel.sym_cov(&x, 0.01);
        assert!(
            dense.max_abs_diff(&exact) < 1e-5,
            "diff {}",
            dense.max_abs_diff(&exact)
        );
    }

    #[test]
    fn m_zero_equals_vecchia_and_mv_zero_equals_fitc() {
        let mut rng = Rng::seed_from(3);
        let n = 30;
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.0, vec![0.3, 0.3], Smoothness::ThreeHalves);
        // m=0: Σ̃_† = B⁻¹DB⁻ᵀ of the plain covariance
        let nb = select_neighbors(
            &x,
            &kernel,
            None,
            4,
            NeighborSelection::CorrelationBruteForce,
        );
        let s = VifStructure::assemble(&x, &kernel, None, nb, 0.02, 1e-12, 0);
        assert!(s.lr.is_none());
        let v: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let w1 = s.apply_sigma_dagger(&v);
        let w2 = s.resid.apply_s_inv(&v);
        for (a, b) in w1.iter().zip(&w2) {
            assert!((a - b).abs() < 1e-12);
        }
        // m_v=0: FITC — Σ̃_† = low-rank + diag
        let z = select_inducing(&x, &kernel, 6, 2, &mut rng, None);
        let s = VifStructure::assemble(&x, &kernel, z, vec![vec![]; n], 0.02, 1e-12, 0);
        let dense = s.dense_sigma_dagger();
        // off-diagonal equals pure low-rank part
        let lr = s.lr.as_ref().unwrap();
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    let low = dot(lr.vt.row(i), lr.vt.row(j));
                    assert!((dense.get(i, j) - low).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn sample_covariance_close_to_sigma_dagger() {
        let (_, _, s) = setup(15, 4, 3);
        let dense = s.dense_sigma_dagger();
        let mut rng = Rng::seed_from(100);
        let reps = 30_000;
        let mut acc = Mat::zeros(15, 15);
        for _ in 0..reps {
            let smp = s.sample(&mut rng);
            for i in 0..15 {
                for j in 0..15 {
                    acc.add_to(i, j, smp[i] * smp[j]);
                }
            }
        }
        acc.scale(1.0 / reps as f64);
        assert!(
            acc.max_abs_diff(&dense) < 0.08,
            "diff {}",
            acc.max_abs_diff(&dense)
        );
    }
}
