//! Solver backends for all `(W + Σ_†⁻¹)`-type operations of the
//! VIF-Laplace path: the dense Cholesky reference and the
//! preconditioned-CG / SLQ machinery of §4, split out of the parent
//! module so the model's append/refresh surface lives apart from the
//! mode-finding internals.

use crate::iterative::{
    map_columns, pcg, pcg_batch, slq_logdet_opts, FitcPrecond, IterConfig, LinOp, PrecondType,
    SlqRun, VifduPrecond,
};
use crate::kernels::ArdMatern;
use crate::linalg::{dot, CholeskyFactor, Mat};
use crate::rng::Rng;
use crate::vif::VifStructure;

/// Solver backend for all `(W + Σ_†⁻¹)`-type operations.
#[derive(Clone, Debug)]
pub enum SolveMode {
    /// Dense reference (O(n³); validation and small-n comparators).
    Cholesky,
    /// Preconditioned-CG / SLQ / STE path (the paper's §4).
    Iterative(IterConfig),
}

/// `(W + Σ_†⁻¹) v` operator (system 16).
pub struct OpWPlusPrec<'a> {
    pub s: &'a VifStructure,
    pub w: &'a [f64],
}
impl<'a> LinOp for OpWPlusPrec<'a> {
    fn n(&self) -> usize {
        self.s.n()
    }
    fn apply(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.s.apply_sigma_dagger_inv(v);
        for ((o, wi), vi) in out.iter_mut().zip(self.w).zip(v) {
            *o += wi * vi;
        }
        out
    }
    fn apply_batch(&self, v: &Mat) -> Mat {
        let mut out = self.s.apply_sigma_dagger_inv_batch(v);
        for i in 0..out.rows() {
            let wi = self.w[i];
            for (o, vi) in out.row_mut(i).iter_mut().zip(v.row(i)) {
                *o += wi * vi;
            }
        }
        out
    }
}

/// `(W⁻¹ + Σ_†) v` operator (system 17).
pub struct OpWinvPlusCov<'a> {
    pub s: &'a VifStructure,
    pub w: &'a [f64],
}
impl<'a> LinOp for OpWinvPlusCov<'a> {
    fn n(&self) -> usize {
        self.s.n()
    }
    fn apply(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.s.apply_sigma_dagger(v);
        for ((o, wi), vi) in out.iter_mut().zip(self.w).zip(v) {
            *o += vi / wi;
        }
        out
    }
    fn apply_batch(&self, v: &Mat) -> Mat {
        let mut out = self.s.apply_sigma_dagger_batch(v);
        for i in 0..out.rows() {
            let wi = self.w[i];
            for (o, vi) in out.row_mut(i).iter_mut().zip(v.row(i)) {
                *o += vi / wi;
            }
        }
        out
    }
}

/// Per-`W` solver state: rebuilt whenever `W` changes (each Newton step).
///
/// In iterative mode all `B`/`Bᵀ` sweeps — the VIF operator applies, the
/// VIFDU preconditioner, and the batched `solve_batch` path — run on the
/// residual factor's level-scheduled kernels (see the `vecchia` module
/// docs), so Newton steps on large `n` parallelize deterministically.
pub struct WSolver<'a> {
    s: &'a VifStructure,
    w: Vec<f64>,
    mode: SolveMode,
    /// Dense backend: `Σ_†` and Cholesky of `B_K = I + W½ Σ_† W½`.
    /// `pub(super)`: the parent module's exact-trace gradient path reads
    /// both pieces directly.
    pub(super) dense: Option<(Mat, CholeskyFactor)>,
    vifdu: Option<VifduPrecond<'a>>,
    fitc: Option<FitcPrecond>,
}

impl<'a> WSolver<'a> {
    pub fn new(
        s: &'a VifStructure,
        x: &Mat,
        kernel: &ArdMatern,
        w: Vec<f64>,
        mode: &SolveMode,
        sigma_dense_cache: Option<&Mat>,
    ) -> Self {
        match mode {
            SolveMode::Cholesky => {
                let sigma = match sigma_dense_cache {
                    Some(m) => m.clone(),
                    None => s.dense_sigma_dagger(),
                };
                let n = s.n();
                let mut bk = Mat::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        bk.set(i, j, w[i].sqrt() * sigma.get(i, j) * w[j].sqrt());
                    }
                }
                bk.add_diag(1.0);
                let chol = CholeskyFactor::new_with_jitter(&bk, 1e-10)
                    .expect("I + W½ΣW½ not PD");
                WSolver {
                    s,
                    w,
                    mode: mode.clone(),
                    dense: Some((sigma, chol)),
                    vifdu: None,
                    fitc: None,
                }
            }
            SolveMode::Iterative(cfg) => {
                let (vifdu, fitc) = match cfg.precond {
                    PrecondType::Vifdu => (Some(VifduPrecond::new(s, &w)), None),
                    PrecondType::Fitc => (
                        None,
                        Some(FitcPrecond::new(x, kernel, cfg.fitc_k, &w, cfg.seed ^ 0x5eed)),
                    ),
                    PrecondType::None => (None, None),
                };
                WSolver { s, w, mode: mode.clone(), dense: None, vifdu, fitc }
            }
        }
    }

    pub fn w(&self) -> &[f64] {
        &self.w
    }

    /// `(W + Σ_†⁻¹)⁻¹ v`.
    pub fn solve(&self, v: &[f64]) -> Vec<f64> {
        match &self.mode {
            SolveMode::Cholesky => {
                // (W+Σ⁻¹)⁻¹ = Σ − ΣW½ B_K⁻¹ W½Σ
                let (sigma, chol) = self.dense.as_ref().unwrap();
                let sv = sigma.matvec(v);
                let ws: Vec<f64> = sv.iter().zip(&self.w).map(|(a, w)| a * w.sqrt()).collect();
                let t = chol.solve(&ws);
                let wt: Vec<f64> = t.iter().zip(&self.w).map(|(a, w)| a * w.sqrt()).collect();
                let c = sigma.matvec(&wt);
                sv.iter().zip(&c).map(|(a, b)| a - b).collect()
            }
            SolveMode::Iterative(cfg) => match cfg.precond {
                PrecondType::Vifdu | PrecondType::None => {
                    let op = OpWPlusPrec { s: self.s, w: &self.w };
                    let res = match &self.vifdu {
                        Some(p) => pcg(&op, p, v, cfg.cg_tol, cfg.max_cg, false),
                        None => pcg(
                            &op,
                            &crate::iterative::IdentityPrecond(self.s.n()),
                            v,
                            cfg.cg_tol,
                            cfg.max_cg,
                            false,
                        ),
                    };
                    res.x
                }
                PrecondType::Fitc => {
                    // (W+Σ⁻¹)⁻¹v = W⁻¹ (W⁻¹+Σ)⁻¹ Σ v
                    let op = OpWinvPlusCov { s: self.s, w: &self.w };
                    let rhs = self.s.apply_sigma_dagger(v);
                    let res = pcg(
                        &op,
                        self.fitc.as_ref().unwrap(),
                        &rhs,
                        cfg.cg_tol,
                        cfg.max_cg,
                        false,
                    );
                    res.x.iter().zip(&self.w).map(|(a, w)| a / w).collect()
                }
            },
        }
    }

    /// `(W + Σ_†⁻¹)⁻¹ V` for a column block of right-hand sides (batched
    /// preconditioned CG; dense path maps columns).
    pub fn solve_batch(&self, v: &Mat) -> Mat {
        match &self.mode {
            SolveMode::Cholesky => map_columns(v, |col| self.solve(col)),
            SolveMode::Iterative(cfg) => match cfg.precond {
                PrecondType::Vifdu | PrecondType::None => {
                    let op = OpWPlusPrec { s: self.s, w: &self.w };
                    let res = match &self.vifdu {
                        Some(p) => pcg_batch(&op, p, v, cfg.cg_tol, cfg.max_cg, false),
                        None => pcg_batch(
                            &op,
                            &crate::iterative::IdentityPrecond(self.s.n()),
                            v,
                            cfg.cg_tol,
                            cfg.max_cg,
                            false,
                        ),
                    };
                    res.x
                }
                PrecondType::Fitc => {
                    // (W+Σ⁻¹)⁻¹V = W⁻¹ (W⁻¹+Σ)⁻¹ Σ V
                    let op = OpWinvPlusCov { s: self.s, w: &self.w };
                    let rhs = self.s.apply_sigma_dagger_batch(v);
                    let res = pcg_batch(
                        &op,
                        self.fitc.as_ref().unwrap(),
                        &rhs,
                        cfg.cg_tol,
                        cfg.max_cg,
                        false,
                    );
                    let mut x = res.x;
                    for i in 0..x.rows() {
                        let wi = self.w[i];
                        for xi in x.row_mut(i) {
                            *xi /= wi;
                        }
                    }
                    x
                }
            },
        }
    }

    /// `log det(Σ_† W + I)` plus retained probes for gradient STE.
    /// `probes_system` marks which system the probes solve.
    pub fn logdet_and_probes(&self, rng: &mut Rng) -> (f64, Option<(SlqRun, PrecondType)>) {
        match &self.mode {
            SolveMode::Cholesky => {
                let (_, chol) = self.dense.as_ref().unwrap();
                (chol.logdet(), None)
            }
            SolveMode::Iterative(cfg) => match cfg.precond {
                PrecondType::Vifdu | PrecondType::None => {
                    // (18): log det(Σ_†W+I) = log det Σ_† + log det(W+Σ_†⁻¹)
                    let op = OpWPlusPrec { s: self.s, w: &self.w };
                    let opts = cfg.slq_options();
                    let run = match &self.vifdu {
                        Some(p) => {
                            slq_logdet_opts(&op, p, cfg.ell, rng, cfg.cg_tol, cfg.max_cg, &opts)
                        }
                        None => slq_logdet_opts(
                            &op,
                            &crate::iterative::IdentityPrecond(self.s.n()),
                            cfg.ell,
                            rng,
                            cfg.cg_tol,
                            cfg.max_cg,
                            &opts,
                        ),
                    };
                    (
                        self.s.logdet() + run.logdet,
                        Some((run, PrecondType::Vifdu)),
                    )
                }
                PrecondType::Fitc => {
                    // (19): log det(Σ_†W+I) = log det W + log det(W⁻¹+Σ_†)
                    let op = OpWinvPlusCov { s: self.s, w: &self.w };
                    let run = slq_logdet_opts(
                        &op,
                        self.fitc.as_ref().unwrap(),
                        cfg.ell,
                        rng,
                        cfg.cg_tol,
                        cfg.max_cg,
                        &cfg.slq_options(),
                    );
                    let ld_w: f64 = self.w.iter().map(|w| w.ln()).sum();
                    (ld_w + run.logdet, Some((run, PrecondType::Fitc)))
                }
            },
        }
    }

    /// `diag((W + Σ_†⁻¹)⁻¹)` — exact (dense) or probe-based estimate.
    pub fn diag_inv(&self, probes: Option<&(SlqRun, PrecondType)>) -> Vec<f64> {
        match &self.mode {
            SolveMode::Cholesky => {
                let (sigma, chol) = self.dense.as_ref().unwrap();
                // diag(Σ − ΣW½ B_K⁻¹ W½Σ)
                let n = self.s.n();
                let mut out = vec![0.0; n];
                for j in 0..n {
                    let col: Vec<f64> = (0..n)
                        .map(|i| sigma.get(i, j) * self.w[i].sqrt())
                        .collect();
                    let t = chol.solve(&col);
                    out[j] = sigma.get(j, j) - dot(&col, &t);
                }
                out
            }
            SolveMode::Iterative(_) => {
                let (run, system) = probes.expect("iterative diag needs probes");
                let raw = crate::iterative::slq::diag_inv_estimate(&run.probes);
                match system {
                    PrecondType::Vifdu | PrecondType::None => raw,
                    PrecondType::Fitc => {
                        // diag((W+Σ⁻¹)⁻¹) = 1/W − (1/W²)·diag((W⁻¹+Σ)⁻¹)
                        raw.iter()
                            .zip(&self.w)
                            .map(|(d, w)| 1.0 / w - d / (w * w))
                            .collect()
                    }
                }
            }
        }
    }
}
