//! Solver backends for all `(W + Σ_†⁻¹)`-type operations of the
//! VIF-Laplace path: the dense Cholesky reference and the
//! preconditioned-CG / SLQ machinery of §4, split out of the parent
//! module so the model's append/refresh surface lives apart from the
//! mode-finding internals.
//!
//! # Failure containment
//!
//! Iterative solves here never silently return garbage. Every attempt is
//! classified per the crate taxonomy ([`crate::iterative::SolveDiag`]),
//! and on failure the escalation ladder runs: an escalated retry (4× CG
//! budget, doubled SLQ Lanczos floor, `None` preconditioner upgraded to
//! VIFDU), then an exact dense factorization below
//! [`DENSE_FALLBACK_MAX_N`], and only past that a best-effort result
//! with the `unrecovered` counter bumped. All steps are recorded in
//! [`crate::iterative::solve_stats`]. The escalation state (upgraded
//! preconditioner, dense backend) is built lazily behind `OnceLock`s so
//! the solver stays `&self` — the prediction path captures `solve_batch`
//! in `impl Fn` closures for the SBPV/SPV probe drivers.

use std::sync::OnceLock;

use crate::iterative::{
    map_columns, pcg_batch, pcg_with_min_from, slq_logdet_opts, solve_stats, FitcPrecond,
    IdentityPrecond, IterConfig, LinOp, PrecondType, Preconditioner, SlqRun, SolveDiag,
    SolveFailure, VifduPrecond,
};
use crate::kernels::ArdMatern;
use crate::linalg::{dot, CholeskyFactor, Mat};
use crate::rng::Rng;
use crate::vif::VifStructure;

/// Size cutoff for the dense `O(n³)` fallback factorization: below this
/// the ladder's last resort is exact; above it, best-effort iterates are
/// returned (with counters) rather than risking an enormous dense solve.
pub const DENSE_FALLBACK_MAX_N: usize = 2048;

/// Solver backend for all `(W + Σ_†⁻¹)`-type operations.
#[derive(Clone, Debug)]
pub enum SolveMode {
    /// Dense reference (O(n³); validation and small-n comparators).
    Cholesky,
    /// Preconditioned-CG / SLQ / STE path (the paper's §4).
    Iterative(IterConfig),
}

/// `(W + Σ_†⁻¹) v` operator (system 16).
pub struct OpWPlusPrec<'a> {
    pub s: &'a VifStructure,
    pub w: &'a [f64],
}
impl<'a> LinOp for OpWPlusPrec<'a> {
    fn n(&self) -> usize {
        self.s.n()
    }
    fn apply(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.s.apply_sigma_dagger_inv(v);
        for ((o, wi), vi) in out.iter_mut().zip(self.w).zip(v) {
            *o += wi * vi;
        }
        out
    }
    fn apply_batch(&self, v: &Mat) -> Mat {
        let mut out = self.s.apply_sigma_dagger_inv_batch(v);
        for i in 0..out.rows() {
            let wi = self.w[i];
            for (o, vi) in out.row_mut(i).iter_mut().zip(v.row(i)) {
                *o += wi * vi;
            }
        }
        out
    }
}

/// `(W⁻¹ + Σ_†) v` operator (system 17).
pub struct OpWinvPlusCov<'a> {
    pub s: &'a VifStructure,
    pub w: &'a [f64],
}
impl<'a> LinOp for OpWinvPlusCov<'a> {
    fn n(&self) -> usize {
        self.s.n()
    }
    fn apply(&self, v: &[f64]) -> Vec<f64> {
        let mut out = self.s.apply_sigma_dagger(v);
        for ((o, wi), vi) in out.iter_mut().zip(self.w).zip(v) {
            *o += vi / wi;
        }
        out
    }
    fn apply_batch(&self, v: &Mat) -> Mat {
        let mut out = self.s.apply_sigma_dagger_batch(v);
        for i in 0..out.rows() {
            let wi = self.w[i];
            for (o, vi) in out.row_mut(i).iter_mut().zip(v.row(i)) {
                *o += vi / wi;
            }
        }
        out
    }
}

/// Per-`W` solver state: rebuilt whenever `W` changes (each Newton step).
///
/// In iterative mode all `B`/`Bᵀ` sweeps — the VIF operator applies, the
/// VIFDU preconditioner, and the batched `solve_batch` path — run on the
/// residual factor's level-scheduled kernels (see the `vecchia` module
/// docs), so Newton steps on large `n` parallelize deterministically.
pub struct WSolver<'a> {
    s: &'a VifStructure,
    w: Vec<f64>,
    mode: SolveMode,
    /// Dense backend: `Σ_†` and Cholesky of `B_K = I + W½ Σ_† W½`.
    /// `pub(super)`: the parent module's exact-trace gradient path reads
    /// both pieces directly.
    pub(super) dense: Option<(Mat, CholeskyFactor)>,
    vifdu: Option<VifduPrecond<'a>>,
    fitc: Option<FitcPrecond>,
    /// Escalation state, built lazily on first failure (interior
    /// mutability keeps the solver `&self` for the `impl Fn` closure
    /// consumers of `solve_batch`).
    vifdu_upgrade: OnceLock<VifduPrecond<'a>>,
    /// Dense backstop `(Σ_†, chol(I + W½ΣW½))`; `None` inside means the
    /// build itself was attempted and failed (or n exceeds the cutoff
    /// check happens before init).
    fallback: OnceLock<Option<(Mat, CholeskyFactor)>>,
}

impl<'a> WSolver<'a> {
    pub fn new(
        s: &'a VifStructure,
        x: &Mat,
        kernel: &ArdMatern,
        w: Vec<f64>,
        mode: &SolveMode,
        sigma_dense_cache: Option<&Mat>,
    ) -> Self {
        match mode {
            SolveMode::Cholesky => {
                let sigma = match sigma_dense_cache {
                    Some(m) => m.clone(),
                    None => s.dense_sigma_dagger(),
                };
                let n = s.n();
                let mut bk = Mat::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        bk.set(i, j, w[i].sqrt() * sigma.get(i, j) * w[j].sqrt());
                    }
                }
                bk.add_diag(1.0);
                let jf = CholeskyFactor::new_with_jitter_tracked(&bk, 1e-10)
                    .expect("I + W½ΣW½ not PD");
                solve_stats().note_jitter(jf.jitter);
                WSolver {
                    s,
                    w,
                    mode: mode.clone(),
                    dense: Some((sigma, jf.factor)),
                    vifdu: None,
                    fitc: None,
                    vifdu_upgrade: OnceLock::new(),
                    fallback: OnceLock::new(),
                }
            }
            SolveMode::Iterative(cfg) => {
                let (vifdu, fitc) = match cfg.precond {
                    PrecondType::Vifdu => (Some(VifduPrecond::new(s, &w)), None),
                    PrecondType::Fitc => (
                        None,
                        Some(FitcPrecond::new(x, kernel, cfg.fitc_k, &w, cfg.seed ^ 0x5eed)),
                    ),
                    PrecondType::None => (None, None),
                };
                WSolver {
                    s,
                    w,
                    mode: mode.clone(),
                    dense: None,
                    vifdu,
                    fitc,
                    vifdu_upgrade: OnceLock::new(),
                    fallback: OnceLock::new(),
                }
            }
        }
    }

    /// Session-aware constructor: like [`new`](Self::new), but in
    /// iterative mode a preconditioner carried over from the previous
    /// `W` (or the previous θ) is *refreshed in place* instead of
    /// rebuilt, mirroring the `VifPlan`/`refresh` split:
    ///
    /// * a carried [`VifduPrecond`] (borrowing the same structure) gets
    ///   its diagonal and m×m core recomputed for the new `w`;
    /// * a carried [`FitcPrecond`] keeps its kMeans++ inducing set `Ẑ`:
    ///   with `theta_changed` the θ-dependent panels are recomputed
    ///   against `Ẑ`, otherwise only the `D_V` diagonal and k×k core
    ///   (weights-only Newton step).
    ///
    /// Each reuse is counted as a warm hit in
    /// [`solve_stats`]; an unusable carry (size mismatch after
    /// `append_points`, first evaluation) counts a warm miss and falls
    /// back to a cold build. [`new`](Self::new) itself counts nothing.
    #[allow(clippy::too_many_arguments)]
    pub fn new_session(
        s: &'a VifStructure,
        x: &Mat,
        kernel: &ArdMatern,
        w: Vec<f64>,
        mode: &SolveMode,
        sigma_dense_cache: Option<&Mat>,
        carried_vifdu: Option<VifduPrecond<'a>>,
        carried_fitc: Option<FitcPrecond>,
        theta_changed: bool,
    ) -> Self {
        let SolveMode::Iterative(cfg) = mode else {
            return Self::new(s, x, kernel, w, mode, sigma_dense_cache);
        };
        let (vifdu, fitc) = match cfg.precond {
            PrecondType::Vifdu => {
                let p = match carried_vifdu {
                    Some(mut p) if p.n() == s.n() => {
                        p.refresh(&w);
                        solve_stats().note_warm_hit();
                        p
                    }
                    _ => {
                        solve_stats().note_warm_miss();
                        VifduPrecond::new(s, &w)
                    }
                };
                (Some(p), None)
            }
            PrecondType::Fitc => {
                let p = match carried_fitc {
                    Some(mut p) if p.n() == x.rows() && p.k() == cfg.fitc_k.min(x.rows()) => {
                        if theta_changed {
                            p.refresh(x, kernel, &w);
                        } else {
                            p.refresh_weights(&w);
                        }
                        solve_stats().note_warm_hit();
                        p
                    }
                    _ => {
                        solve_stats().note_warm_miss();
                        FitcPrecond::new(x, kernel, cfg.fitc_k, &w, cfg.seed ^ 0x5eed)
                    }
                };
                (None, Some(p))
            }
            PrecondType::None => (None, None),
        };
        WSolver {
            s,
            w,
            mode: mode.clone(),
            dense: None,
            vifdu,
            fitc,
            vifdu_upgrade: OnceLock::new(),
            fallback: OnceLock::new(),
        }
    }

    /// Hand the owned preconditioners back to the session so the next
    /// `W` (or the next θ) refreshes them instead of rebuilding. The
    /// solver must not be used afterwards.
    pub fn take_preconds(&mut self) -> (Option<VifduPrecond<'a>>, Option<FitcPrecond>) {
        (self.vifdu.take(), self.fitc.take())
    }

    /// The VIFDU preconditioner to use: the configured one, or — on the
    /// escalated retry when the configuration runs unpreconditioned — a
    /// lazily built upgrade.
    fn vifdu_precond(&self, escalate: bool) -> Option<&VifduPrecond<'a>> {
        if let Some(p) = &self.vifdu {
            return Some(p);
        }
        if !escalate {
            return None;
        }
        Some(self.vifdu_upgrade.get_or_init(|| VifduPrecond::new(self.s, &self.w)))
    }

    /// The dense `(Σ_†, chol(B_K))` backstop: the primary dense backend
    /// in Cholesky mode, or the lazily built fallback below
    /// [`DENSE_FALLBACK_MAX_N`] in iterative mode.
    fn dense_backend(&self) -> Option<(&Mat, &CholeskyFactor)> {
        if let Some((sigma, chol)) = self.dense.as_ref() {
            return Some((sigma, chol));
        }
        if self.s.n() > DENSE_FALLBACK_MAX_N {
            return None;
        }
        self.fallback
            .get_or_init(|| {
                let sigma = self.s.dense_sigma_dagger();
                let n = self.s.n();
                let mut bk = Mat::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        bk.set(i, j, self.w[i].sqrt() * sigma.get(i, j) * self.w[j].sqrt());
                    }
                }
                bk.add_diag(1.0);
                match CholeskyFactor::new_with_jitter_tracked(&bk, 1e-10) {
                    Ok(jf) => {
                        solve_stats().note_jitter(jf.jitter);
                        Some((sigma, jf.factor))
                    }
                    Err(_) => None,
                }
            })
            .as_ref()
            .map(|(sigma, chol)| (sigma, chol))
    }

    /// Exact `(W + Σ_†⁻¹)⁻¹ v = Σv − ΣW½ B_K⁻¹ W½Σv` through a dense
    /// backend.
    fn dense_apply(&self, sigma: &Mat, chol: &CholeskyFactor, v: &[f64]) -> Vec<f64> {
        let sv = sigma.matvec(v);
        let ws: Vec<f64> = sv.iter().zip(&self.w).map(|(a, w)| a * w.sqrt()).collect();
        let t = chol.solve(&ws);
        let wt: Vec<f64> = t.iter().zip(&self.w).map(|(a, w)| a * w.sqrt()).collect();
        let c = sigma.matvec(&wt);
        sv.iter().zip(&c).map(|(a, b)| a - b).collect()
    }

    /// One iterative attempt at `(W + Σ_†⁻¹)⁻¹ v`, classified.
    /// `escalate` raises the CG budget 4× and upgrades a `None`
    /// preconditioner to VIFDU. `x0` warm-starts CG from a previous
    /// solution of a nearby system (`None` reproduces the cold start
    /// bit for bit).
    fn solve_attempt(
        &self,
        cfg: &IterConfig,
        v: &[f64],
        escalate: bool,
        x0: Option<&[f64]>,
    ) -> (Vec<f64>, SolveDiag) {
        let max_cg = if escalate { cfg.max_cg * 4 } else { cfg.max_cg };
        match cfg.precond {
            PrecondType::Vifdu | PrecondType::None => {
                let op = OpWPlusPrec { s: self.s, w: &self.w };
                let res = match self.vifdu_precond(escalate) {
                    Some(p) => pcg_with_min_from(&op, p, v, x0, cfg.cg_tol, 0, max_cg, false),
                    None => pcg_with_min_from(
                        &op,
                        &IdentityPrecond(self.s.n()),
                        v,
                        x0,
                        cfg.cg_tol,
                        0,
                        max_cg,
                        false,
                    ),
                };
                let mut diag = res.diag();
                diag.retried = escalate;
                (res.x, diag)
            }
            PrecondType::Fitc => {
                // (W+Σ⁻¹)⁻¹v = W⁻¹ (W⁻¹+Σ)⁻¹ Σ v. An external guess x0
                // for the outer system maps to u0 = W·x0 for the inner.
                let op = OpWinvPlusCov { s: self.s, w: &self.w };
                let rhs = self.s.apply_sigma_dagger(v);
                let u0: Option<Vec<f64>> =
                    x0.map(|g| g.iter().zip(&self.w).map(|(gi, wi)| gi * wi).collect());
                let res = pcg_with_min_from(
                    &op,
                    self.fitc.as_ref().unwrap(),
                    &rhs,
                    u0.as_deref(),
                    cfg.cg_tol,
                    0,
                    max_cg,
                    false,
                );
                let mut diag = res.diag();
                diag.retried = escalate;
                (
                    res.x.iter().zip(&self.w).map(|(a, w)| a / w).collect(),
                    diag,
                )
            }
        }
    }

    /// One iterative attempt at the batched solve; per-column failure
    /// classification (severity: non-finite > breakdown > max-iter).
    fn solve_batch_attempt(
        &self,
        cfg: &IterConfig,
        v: &Mat,
        escalate: bool,
    ) -> (Mat, Vec<Option<SolveFailure>>) {
        let max_cg = if escalate { cfg.max_cg * 4 } else { cfg.max_cg };
        let res = match cfg.precond {
            PrecondType::Vifdu | PrecondType::None => {
                let op = OpWPlusPrec { s: self.s, w: &self.w };
                match self.vifdu_precond(escalate) {
                    Some(p) => pcg_batch(&op, p, v, cfg.cg_tol, max_cg, false),
                    None => {
                        pcg_batch(&op, &IdentityPrecond(self.s.n()), v, cfg.cg_tol, max_cg, false)
                    }
                }
            }
            PrecondType::Fitc => {
                // (W+Σ⁻¹)⁻¹V = W⁻¹ (W⁻¹+Σ)⁻¹ Σ V
                let op = OpWinvPlusCov { s: self.s, w: &self.w };
                let rhs = self.s.apply_sigma_dagger_batch(v);
                let mut res =
                    pcg_batch(&op, self.fitc.as_ref().unwrap(), &rhs, cfg.cg_tol, max_cg, false);
                for i in 0..res.x.rows() {
                    let wi = self.w[i];
                    for xi in res.x.row_mut(i) {
                        *xi /= wi;
                    }
                }
                res
            }
        };
        let failures = (0..v.cols())
            .map(|j| {
                let col = &res.columns[j];
                if res.x.col(j).iter().any(|t| !t.is_finite()) {
                    Some(SolveFailure::NonFinite)
                } else if col.breakdown {
                    Some(SolveFailure::Breakdown)
                } else if !col.converged {
                    Some(SolveFailure::MaxIter)
                } else {
                    None
                }
            })
            .collect();
        (res.x, failures)
    }

    pub fn w(&self) -> &[f64] {
        &self.w
    }

    /// `(W + Σ_†⁻¹)⁻¹ v`, contained: on a classified failure the
    /// escalation ladder runs (retry → dense fallback → best effort).
    pub fn solve(&self, v: &[f64]) -> Vec<f64> {
        self.solve_from(v, None)
    }

    /// [`solve`](Self::solve) warm-started from `x0`, a previous
    /// solution of a nearby system (previous Newton iterate, previous
    /// θ's solve). Only the *first* attempt uses the guess: the
    /// escalated retry and the dense backstop always run cold, so the
    /// containment ladder's behavior is guess-independent. `x0 = None`
    /// is bitwise identical to [`solve`](Self::solve).
    pub fn solve_from(&self, v: &[f64], x0: Option<&[f64]>) -> Vec<f64> {
        match &self.mode {
            SolveMode::Cholesky => {
                // (W+Σ⁻¹)⁻¹ = Σ − ΣW½ B_K⁻¹ W½Σ  (direct: x0 is moot)
                let (sigma, chol) = self.dense.as_ref().unwrap();
                self.dense_apply(sigma, chol, v)
            }
            SolveMode::Iterative(cfg) => {
                let (x, diag) = self.solve_attempt(cfg, v, false, x0);
                let Some(failure) = diag.failure else {
                    return x;
                };
                let stats = solve_stats();
                stats.note_failure(failure);
                stats.note_retry();
                let (x2, diag2) = self.solve_attempt(cfg, v, true, None);
                if diag2.failure.is_none() {
                    stats.note_retry_success();
                    return x2;
                }
                if let Some((sigma, chol)) = self.dense_backend() {
                    stats.note_dense_fallback();
                    return self.dense_apply(sigma, chol, v);
                }
                stats.note_unrecovered();
                // Best effort: prefer a finite iterate.
                if x2.iter().all(|t| t.is_finite()) {
                    x2
                } else {
                    x
                }
            }
        }
    }

    /// `(W + Σ_†⁻¹)⁻¹ V` for a column block of right-hand sides (batched
    /// preconditioned CG; dense path maps columns). The escalation
    /// ladder runs per failed column: only failing columns are retried
    /// and, if still failing, answered by the dense backstop.
    pub fn solve_batch(&self, v: &Mat) -> Mat {
        match &self.mode {
            SolveMode::Cholesky => map_columns(v, |col| self.solve(col)),
            SolveMode::Iterative(cfg) => {
                let (mut x, failures) = self.solve_batch_attempt(cfg, v, false);
                let failed: Vec<usize> =
                    (0..v.cols()).filter(|&j| failures[j].is_some()).collect();
                if failed.is_empty() {
                    return x;
                }
                let stats = solve_stats();
                for &j in &failed {
                    stats.note_failure(failures[j].unwrap());
                }
                stats.note_retry();
                let n = v.rows();
                let sub = Mat::from_fn(n, failed.len(), |i, slot| v.get(i, failed[slot]));
                let (x2, failures2) = self.solve_batch_attempt(cfg, &sub, true);
                let mut still: Vec<(usize, usize)> = Vec::new();
                for (slot, &j) in failed.iter().enumerate() {
                    if failures2[slot].is_none() {
                        for i in 0..n {
                            x.set(i, j, x2.get(i, slot));
                        }
                    } else {
                        still.push((slot, j));
                    }
                }
                if still.is_empty() {
                    stats.note_retry_success();
                    return x;
                }
                if let Some((sigma, chol)) = self.dense_backend() {
                    stats.note_dense_fallback();
                    // Recovered escalated columns keep their iterates;
                    // still-failing ones get the exact dense solve.
                    for &(_, j) in &still {
                        let xd = self.dense_apply(sigma, chol, &v.col(j));
                        for i in 0..n {
                            x.set(i, j, xd[i]);
                        }
                    }
                    return x;
                }
                stats.note_unrecovered();
                // Best effort: take the escalated iterate where finite.
                for &(slot, j) in &still {
                    let cand = x2.col(slot);
                    if cand.iter().all(|t| t.is_finite()) {
                        for i in 0..n {
                            x.set(i, j, cand[i]);
                        }
                    }
                }
                x
            }
        }
    }

    /// One SLQ attempt on the configured system. `escalate` raises the
    /// CG budget 4×, doubles the Lanczos degree floor, and upgrades a
    /// `None` preconditioner to VIFDU.
    fn slq_attempt(&self, cfg: &IterConfig, rng: &mut Rng, escalate: bool) -> (SlqRun, PrecondType) {
        let max_cg = if escalate { cfg.max_cg * 4 } else { cfg.max_cg };
        let mut opts = cfg.slq_options();
        if escalate {
            opts.min_iter *= 2;
        }
        match cfg.precond {
            PrecondType::Vifdu | PrecondType::None => {
                // (18): log det(Σ_†W+I) = log det Σ_† + log det(W+Σ_†⁻¹)
                let op = OpWPlusPrec { s: self.s, w: &self.w };
                let run = match self.vifdu_precond(escalate) {
                    Some(p) => slq_logdet_opts(&op, p, cfg.ell, rng, cfg.cg_tol, max_cg, &opts),
                    None => slq_logdet_opts(
                        &op,
                        &IdentityPrecond(self.s.n()),
                        cfg.ell,
                        rng,
                        cfg.cg_tol,
                        max_cg,
                        &opts,
                    ),
                };
                (run, PrecondType::Vifdu)
            }
            PrecondType::Fitc => {
                // (19): log det(Σ_†W+I) = log det W + log det(W⁻¹+Σ_†)
                let op = OpWinvPlusCov { s: self.s, w: &self.w };
                let run = slq_logdet_opts(
                    &op,
                    self.fitc.as_ref().unwrap(),
                    cfg.ell,
                    rng,
                    cfg.cg_tol,
                    max_cg,
                    &opts,
                );
                (run, PrecondType::Fitc)
            }
        }
    }

    /// Add the system-specific composition constant so the returned
    /// total is `log det(Σ_† W + I)`.
    fn compose_logdet(
        &self,
        run: SlqRun,
        system: PrecondType,
    ) -> (f64, Option<(SlqRun, PrecondType)>) {
        let total = match system {
            PrecondType::Vifdu | PrecondType::None => self.s.logdet() + run.logdet,
            PrecondType::Fitc => self.w.iter().map(|w| w.ln()).sum::<f64>() + run.logdet,
        };
        (total, Some((run, system)))
    }

    /// `log det(Σ_† W + I)` plus retained probes for gradient STE.
    /// The second tuple element marks which system the probes solve.
    ///
    /// Contained: a run with failed probes is retried escalated; if
    /// probes still fail and a dense backend is available, the
    /// log-determinant is replaced by the exact `log det chol(B_K)` and
    /// every probe's `A⁻¹z` is recomputed exactly, so downstream STE
    /// gradients and diagonal estimates reuse exact solves with
    /// unchanged shapes.
    pub fn logdet_and_probes(&self, rng: &mut Rng) -> (f64, Option<(SlqRun, PrecondType)>) {
        let cfg = match &self.mode {
            SolveMode::Cholesky => {
                let (_, chol) = self.dense.as_ref().unwrap();
                return (chol.logdet(), None);
            }
            SolveMode::Iterative(cfg) => cfg,
        };
        let (run, system) = self.slq_attempt(cfg, rng, false);
        if run.failed_probes == 0 {
            return self.compose_logdet(run, system);
        }
        let stats = solve_stats();
        stats.note_retry();
        let (run2, system) = self.slq_attempt(cfg, rng, true);
        if run2.failed_probes == 0 {
            stats.note_retry_success();
            return self.compose_logdet(run2, system);
        }
        if let Some((sigma, chol)) = self.dense_backend() {
            stats.note_dense_fallback();
            let mut run = run2;
            let exact_bk = chol.logdet();
            match system {
                PrecondType::Vifdu | PrecondType::None => {
                    // A = W+Σ⁻¹: log det A = log det B_K − log det Σ, and
                    // A⁻¹z is exactly the dense apply.
                    run.logdet = exact_bk - self.s.logdet();
                    for p in run.probes.iter_mut() {
                        p.ainv_z = self.dense_apply(sigma, chol, &p.z);
                    }
                }
                PrecondType::Fitc => {
                    // A = W⁻¹+Σ = W^{-½} B_K W^{-½}: log det A =
                    // log det B_K − Σ log w, and A⁻¹ = W½ B_K⁻¹ W½.
                    let ld_w: f64 = self.w.iter().map(|w| w.ln()).sum();
                    run.logdet = exact_bk - ld_w;
                    for p in run.probes.iter_mut() {
                        let wz: Vec<f64> =
                            p.z.iter().zip(&self.w).map(|(z, w)| z * w.sqrt()).collect();
                        let t = chol.solve(&wz);
                        p.ainv_z = t.iter().zip(&self.w).map(|(t, w)| t * w.sqrt()).collect();
                    }
                }
            }
            run.failed_probes = 0;
            return self.compose_logdet(run, system);
        }
        stats.note_unrecovered();
        self.compose_logdet(run2, system)
    }

    /// `diag((W + Σ_†⁻¹)⁻¹)` — exact (dense) or probe-based estimate.
    pub fn diag_inv(&self, probes: Option<&(SlqRun, PrecondType)>) -> Vec<f64> {
        match &self.mode {
            SolveMode::Cholesky => {
                let (sigma, chol) = self.dense.as_ref().unwrap();
                // diag(Σ − ΣW½ B_K⁻¹ W½Σ)
                let n = self.s.n();
                let mut out = vec![0.0; n];
                for j in 0..n {
                    let col: Vec<f64> = (0..n)
                        .map(|i| sigma.get(i, j) * self.w[i].sqrt())
                        .collect();
                    let t = chol.solve(&col);
                    out[j] = sigma.get(j, j) - dot(&col, &t);
                }
                out
            }
            SolveMode::Iterative(_) => {
                let (run, system) = probes.expect("iterative diag needs probes");
                let raw = crate::iterative::slq::diag_inv_estimate(&run.probes);
                match system {
                    PrecondType::Vifdu | PrecondType::None => raw,
                    PrecondType::Fitc => {
                        // diag((W+Σ⁻¹)⁻¹) = 1/W − (1/W²)·diag((W⁻¹+Σ)⁻¹)
                        raw.iter()
                            .zip(&self.w)
                            .map(|(d, w)| 1.0 / w - d / (w * w))
                            .collect()
                    }
                }
            }
        }
    }
}
