//! VIF-Laplace approximations for non-Gaussian likelihoods (paper §3–§4).
//!
//! The latent process uses a *latent-scale* VIF structure (nugget = 0).
//! Two solver backends are provided:
//!
//! * [`SolveMode::Cholesky`] — the dense reference (small n): materializes
//!   `Σ_†` and uses the classic `B_K = I + W^{1/2} Σ_† W^{1/2}` identities,
//!   playing the role of the paper's "Cholesky-based" comparator;
//! * [`SolveMode::Iterative`] — the paper's contribution: preconditioned
//!   CG (VIFDU on `W + Σ_†⁻¹`, Eq. 16, or FITC on `W⁻¹ + Σ_†`, Eq. 17),
//!   SLQ log-determinants (18)/(19), and stochastic trace estimation with
//!   probe reuse for the gradients (Appendix D).

use crate::iterative::{
    sbpv_diag, solve_stats, spv_diag, FitcPrecond, IterConfig, PrecondType, VifduPrecond,
};
use crate::kernels::ArdMatern;
use crate::likelihoods::Likelihood;
use crate::linalg::{dot, Mat};
use crate::rng::Rng;
use crate::vecchia::neighbors::NeighborSelection;

use super::{
    predict, FitModel, GradAux, NeighborPanels, VifPlan, VifResidualOracle, VifStructure,
};

mod wsolver;

pub use wsolver::{OpWinvPlusCov, OpWPlusPrec, SolveMode, WSolver};

/// Mode-finding result (Newton's method, Eq. 13).
///
/// `Clone` exists for the serving snapshot path ([`crate::serve`]): the
/// mode vector is part of the immutable per-generation read state.
#[derive(Clone)]
pub struct LaplaceState {
    /// The mode b̃.
    pub b: Vec<f64>,
    /// `W` diagonal at the mode.
    pub w: Vec<f64>,
    pub newton_iters: usize,
    /// ψ(b̃) = −log p(y|b̃) + ½ b̃ᵀΣ_†⁻¹b̃.
    pub psi: f64,
}

/// Per-model warm-start state carried across consecutive L-BFGS
/// objective evaluations by a [`super::FitSession`] (see the parent
/// module's "Warm-start lifecycle" section). Everything here is a
/// *guess* or a refreshable cache: dropping the whole struct at any
/// point only costs speed, never correctness.
///
/// The VIFDU preconditioner is deliberately absent — it borrows the
/// `VifStructure` that the fit driver refreshes mutably between
/// evaluations, so it can only be carried *within* one evaluation
/// (across Newton iterations); [`find_mode_session`] does that locally.
#[derive(Default)]
pub struct LaplaceSession {
    /// Converged mode b̃ of the previous evaluation (Newton start).
    pub mode: Option<Vec<f64>>,
    /// Previous evaluation's `s̃ = (W+Σ_†⁻¹)⁻¹ s` gradient helper
    /// (CG initial guess for the next one).
    pub s_tilde: Option<Vec<f64>>,
    /// FITC preconditioner retained across evaluations: owns its panels
    /// and its kMeans++ inducing set `Ẑ`, refreshed in place per θ.
    pub fitc: Option<FitcPrecond>,
    /// Per-objective-evaluation CG iteration deltas (scalar + batched),
    /// recorded by the fit driver from the [`solve_stats`] registry.
    pub eval_cg_iters: Vec<u64>,
}

impl LaplaceSession {
    /// Reset at a re-selection round boundary: the preconditioner's
    /// inducing set should be re-selected against the new structure, so
    /// it is dropped; the mode and s̃ stay — they approximate the same
    /// posterior latents and remain good guesses across rounds.
    pub fn clear_for_new_round(&mut self) {
        self.fitc = None;
    }
}

/// Find the Laplace mode by damped Newton iterations.
pub fn find_mode(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    lik: &Likelihood,
    y: &[f64],
    mode: &SolveMode,
    sigma_dense_cache: Option<&Mat>,
) -> LaplaceState {
    find_mode_session(s, x, kernel, lik, y, mode, sigma_dense_cache, None)
}

/// [`find_mode`] with warm-start state: the Newton search starts from
/// the previous evaluation's converged mode (instead of `b = 0`), each
/// Newton solve warm-starts CG from the current iterate, and the
/// preconditioner is refreshed in place across Newton iterations —
/// θ-refreshed on the first (the carried one came from the previous θ),
/// weights-only after. `session = None` is bitwise identical to
/// [`find_mode`].
#[allow(clippy::too_many_arguments)]
pub fn find_mode_session(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    lik: &Likelihood,
    y: &[f64],
    mode: &SolveMode,
    sigma_dense_cache: Option<&Mat>,
    mut session: Option<&mut LaplaceSession>,
) -> LaplaceState {
    let n = y.len();
    let warm = session.is_some();
    let mut b = vec![0.0; n];
    if let Some(sess) = session.as_deref_mut() {
        // Previous θ's converged mode seeds the Newton search; the first
        // evaluation (or a size change after appends) runs from zero.
        match sess.mode.take() {
            Some(m) if m.len() == n => {
                b = m;
                solve_stats().note_warm_hit();
            }
            _ => solve_stats().note_warm_miss(),
        }
    }
    let psi = |b: &[f64]| -> f64 {
        let quad = dot(b, &s.apply_sigma_dagger_inv(b));
        -lik.log_density_sum(y, b) + 0.5 * quad
    };
    let mut psi_cur = psi(&b);
    let mut iters = 0;
    // Newton directions need tighter solves than the SLQ/STE tolerance δ:
    // ψ is evaluated exactly, so with loose directions the damped iteration
    // stalls above the true mode, biasing ψ(b̃) and hence L^{VIFLA}
    // (GPBoost likewise separates the mode-finding tolerance from δ).
    let mode = &match mode {
        SolveMode::Iterative(cfg) => SolveMode::Iterative(IterConfig {
            cg_tol: cfg.cg_tol.min(1e-4),
            ..cfg.clone()
        }),
        other => other.clone(),
    };
    let mut carried_vifdu: Option<VifduPrecond> = None;
    let mut carried_fitc: Option<FitcPrecond> =
        session.as_deref_mut().and_then(|sess| sess.fitc.take());
    let mut theta_changed = true;
    let mut converged = false;
    for _ in 0..100 {
        let w: Vec<f64> = y.iter().zip(&b).map(|(yi, bi)| lik.w(*yi, *bi)).collect();
        let mut solver = if warm {
            WSolver::new_session(
                s,
                x,
                kernel,
                w.clone(),
                mode,
                sigma_dense_cache,
                carried_vifdu.take(),
                carried_fitc.take(),
                theta_changed,
            )
        } else {
            WSolver::new(s, x, kernel, w.clone(), mode, sigma_dense_cache)
        };
        theta_changed = false;
        let rhs: Vec<f64> = y
            .iter()
            .zip(&b)
            .zip(&w)
            .map(|((yi, bi), wi)| wi * bi + lik.d1(*yi, *bi))
            .collect();
        // `b_new` is the full next mode (not an increment), so the
        // current iterate is a natural CG starting point.
        let b_new = if warm {
            solver.solve_from(&rhs, Some(&b))
        } else {
            solver.solve(&rhs)
        };
        if warm {
            let (cv, cf) = solver.take_preconds();
            carried_vifdu = cv;
            carried_fitc = cf;
        }
        // Damped step on ψ.
        let mut step = 1.0;
        let mut accepted = false;
        for _ in 0..20 {
            let cand: Vec<f64> = b
                .iter()
                .zip(&b_new)
                .map(|(bi, bn)| bi + step * (bn - bi))
                .collect();
            let psi_new = psi(&cand);
            if psi_new.is_finite() && psi_new <= psi_cur + 1e-12 {
                let delta = psi_cur - psi_new;
                b = cand;
                psi_cur = psi_new;
                accepted = true;
                iters += 1;
                if delta < 1e-8 * (1.0 + psi_cur.abs()) {
                    converged = true;
                }
                break;
            }
            step *= 0.5;
        }
        if converged || !accepted {
            break;
        }
    }
    if let Some(sess) = session {
        sess.mode = Some(b.clone());
        sess.fitc = carried_fitc;
    }
    let w = y.iter().zip(&b).map(|(yi, bi)| lik.w(*yi, *bi)).collect();
    LaplaceState { b, w, newton_iters: iters, psi: psi_cur }
}

/// Negative log-marginal likelihood `L^{VIFLA}` (Eq. 12).
pub fn nll(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    lik: &Likelihood,
    y: &[f64],
    mode: &SolveMode,
    rng: &mut Rng,
) -> (f64, LaplaceState) {
    let sigma_cache = match mode {
        SolveMode::Cholesky => Some(s.dense_sigma_dagger()),
        _ => None,
    };
    let state = find_mode(s, x, kernel, lik, y, mode, sigma_cache.as_ref());
    let solver = WSolver::new(s, x, kernel, state.w.clone(), mode, sigma_cache.as_ref());
    let (logdet, _) = solver.logdet_and_probes(rng);
    (state.psi + 0.5 * logdet, state)
}

/// Everything the gradient needs about `∂Σ_†/∂θ_p`: the Appendix-A
/// factor derivatives plus the low-rank panels.
pub struct VifDerivPack {
    /// Number of parameters (kernel params; latent models have no noise).
    pub np: usize,
    /// `∂D_i/∂θ_p` laid out `[p][i]`.
    pub dd: Vec<Vec<f64>>,
    /// `∂A_i/∂θ_p` laid out `[p][i][k]`.
    pub da: Vec<Vec<Vec<f64>>>,
    pub aux: Option<GradAux>,
}

impl VifDerivPack {
    pub fn build(s: &VifStructure, x: &Mat, kernel: &ArdMatern) -> Self {
        Self::build_panels(s, x, kernel, None)
    }

    /// [`build`](Self::build) with pre-gathered neighbor coordinate
    /// panels from a frozen [`VifPlan`] (the fit driver's
    /// per-evaluation path).
    pub fn build_panels(
        s: &VifStructure,
        x: &Mat,
        kernel: &ArdMatern,
        x_panels: Option<&NeighborPanels>,
    ) -> Self {
        let n = s.n();
        let np = kernel.num_params();
        let aux = s.lr.as_ref().map(|lr| GradAux::build(x, kernel, lr));
        let oracle = VifResidualOracle {
            kernel,
            x,
            lr: s.lr.as_ref(),
            grad_aux: aux.as_ref(),
            extra_params: 0,
            x_panels,
        };
        use std::sync::Mutex;
        let dd_store = Mutex::new(vec![vec![0.0; n]; np]);
        let da_store = Mutex::new(vec![vec![Vec::new(); n]; np]);
        s.resid.grads(&oracle, s.nugget, None, 1e-10, &|i, dd, da| {
            let mut ddl = dd_store.lock().unwrap();
            let mut dal = da_store.lock().unwrap();
            for p in 0..np {
                ddl[p][i] = dd[p];
                dal[p][i] = da[p].clone();
            }
        });
        VifDerivPack {
            np,
            dd: dd_store.into_inner().unwrap(),
            da: da_store.into_inner().unwrap(),
            aux,
        }
    }

    /// `(∂B/∂θ_p) v` — rows `−∂A_i` on `N(i)`.
    fn db_mul(&self, s: &VifStructure, p: usize, v: &[f64]) -> Vec<f64> {
        let n = s.n();
        (0..n)
            .map(|i| {
                let mut acc = 0.0;
                for (k, &j) in s.resid.neighbors[i].iter().enumerate() {
                    acc -= self.da[p][i][k] * v[j as usize];
                }
                acc
            })
            .collect()
    }

    /// `(∂B/∂θ_p)ᵀ v`.
    fn dbt_mul(&self, s: &VifStructure, p: usize, v: &[f64]) -> Vec<f64> {
        let n = s.n();
        let mut out = vec![0.0; n];
        for i in 0..n {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            for (k, &j) in s.resid.neighbors[i].iter().enumerate() {
                out[j as usize] -= self.da[p][i][k] * vi;
            }
        }
        out
    }

    /// `(∂S/∂θ_p) v` with `S = BᵀD⁻¹B`.
    pub fn apply_ds(&self, s: &VifStructure, p: usize, v: &[f64]) -> Vec<f64> {
        let bv = s.resid.mul_b(v);
        // ∂Bᵀ D⁻¹ B v
        let dinv_bv: Vec<f64> = bv.iter().zip(&s.resid.d).map(|(a, d)| a / d).collect();
        let mut out = self.dbt_mul(s, p, &dinv_bv);
        // Bᵀ ∂(D⁻¹) B v
        let dd_term: Vec<f64> = bv
            .iter()
            .zip(&s.resid.d)
            .zip(&self.dd[p])
            .map(|((a, d), dd)| -a * dd / (d * d))
            .collect();
        let t2 = s.resid.mul_bt(&dd_term);
        // Bᵀ D⁻¹ ∂B v
        let dbv = self.db_mul(s, p, v);
        let dinv_dbv: Vec<f64> = dbv.iter().zip(&s.resid.d).map(|(a, d)| a / d).collect();
        let t3 = s.resid.mul_bt(&dinv_dbv);
        for ((o, a), b) in out.iter_mut().zip(&t2).zip(&t3) {
            *o += a + b;
        }
        out
    }

    /// `(∂Σ̃ˢ/∂θ_p) v` with `Σ̃ˢ = B⁻¹DB⁻ᵀ`.
    pub fn apply_dsig_s(&self, s: &VifStructure, p: usize, v: &[f64]) -> Vec<f64> {
        let u1 = s.resid.solve_bt(v);
        let dd_u1: Vec<f64> = u1.iter().zip(&self.dd[p]).map(|(a, dd)| a * dd).collect();
        let mut out = s.resid.solve_b(&dd_u1);
        // − B⁻¹ ∂B Σ̃ˢ v
        let sv = s.resid.apply_s_inv(v);
        let t2 = s.resid.solve_b(&self.db_mul(s, p, &sv));
        // − Σ̃ˢ ∂Bᵀ B⁻ᵀ v
        let t3 = s.resid.apply_s_inv(&self.dbt_mul(s, p, &u1));
        for ((o, a), b) in out.iter_mut().zip(&t2).zip(&t3) {
            *o -= a + b;
        }
        out
    }

    /// `(∂Σˡ/∂θ_p) v` — low-rank part derivative.
    pub fn apply_dsig_l(&self, s: &VifStructure, p: usize, v: &[f64]) -> Vec<f64> {
        match (&s.lr, &self.aux) {
            (Some(lr), Some(aux)) => {
                let e = lr.chol_m.solve(&lr.sigma_nm.matvec_t(v)); // Σ_m⁻¹Σ_mn v
                let mut out = aux.dsig_nm[p].matvec(&e);
                let t2 = lr.et.matvec(&aux.dsig_nm[p].matvec_t(v));
                let t3 = lr.et.matvec(&aux.dsig_m[p].matvec(&e));
                for ((o, a), b) in out.iter_mut().zip(&t2).zip(&t3) {
                    *o += a - b;
                }
                out
            }
            _ => vec![0.0; s.n()],
        }
    }

    /// `(∂Σ_†/∂θ_p) v`.
    pub fn apply_dsig_dagger(&self, s: &VifStructure, p: usize, v: &[f64]) -> Vec<f64> {
        let mut out = self.apply_dsig_s(s, p, v);
        let low = self.apply_dsig_l(s, p, v);
        for (o, l) in out.iter_mut().zip(&low) {
            *o += l;
        }
        out
    }

    /// `(∂Σ_†⁻¹/∂θ_p) v` (product form of the Woodbury derivative).
    pub fn apply_dsig_dagger_inv(&self, s: &VifStructure, p: usize, v: &[f64]) -> Vec<f64> {
        let w1 = s.resid.apply_s(v);
        let w1d = self.apply_ds(s, p, v);
        let (lr, cm) = match (&s.lr, &s.chol_mcal) {
            (Some(lr), Some(cm)) => (lr, cm),
            _ => return w1d,
        };
        let aux = self.aux.as_ref().unwrap();
        // c = M⁻¹ Σ_mn S v
        let a1 = lr.sigma_nm.matvec_t(&w1);
        let c = cm.solve(&a1);
        let q_v = lr.sigma_nm.matvec(&c); // Σ_mnᵀ c
        // dc = M⁻¹(∂Σ_mn·Sv + Σ_mn·∂Sv − ∂M·c)
        let mut rhs = aux.dsig_nm[p].matvec_t(&w1);
        let t = lr.sigma_nm.matvec_t(&w1d);
        for (r, ti) in rhs.iter_mut().zip(&t) {
            *r += ti;
        }
        // ∂M c = ∂Σ_m c + ∂Σ_mn (S Σ_mnᵀ c) + Σ_mn ∂S (Σ_mnᵀ c) + Σ_mn S ∂Σ_mnᵀ c
        let s_q = s.resid.apply_s(&q_v);
        let mut dmc = aux.dsig_m[p].matvec(&c);
        let t1 = aux.dsig_nm[p].matvec_t(&s_q);
        let t2 = lr.sigma_nm.matvec_t(&self.apply_ds(s, p, &q_v));
        let t3 = lr
            .sigma_nm
            .matvec_t(&s.resid.apply_s(&aux.dsig_nm[p].matvec(&c)));
        for (((d, a), b), cc) in dmc.iter_mut().zip(&t1).zip(&t2).zip(&t3) {
            *d += a + b + cc;
        }
        for (r, d) in rhs.iter_mut().zip(&dmc) {
            *r -= d;
        }
        let dc = cm.solve(&rhs);
        // ∂F(v) = ∂S(Σ_mnᵀc) + S(∂Σ_mnᵀ c) + S(Σ_mnᵀ dc)
        let mut df = self.apply_ds(s, p, &q_v);
        let t4 = s.resid.apply_s(&aux.dsig_nm[p].matvec(&c));
        let t5 = s.resid.apply_s(&lr.sigma_nm.matvec(&dc));
        for ((d, a), b) in df.iter_mut().zip(&t4).zip(&t5) {
            *d += a + b;
        }
        w1d.iter().zip(&df).map(|(a, b)| a - b).collect()
    }

    /// Deterministic `∂ log det Σ_† / ∂θ_p`
    /// `= Tr(M⁻¹∂M) − Tr(Σ_m⁻¹∂Σ_m) + Σ_i ∂D_i/D_i`.
    pub fn dlogdet_sigma_dagger(&self, s: &VifStructure, p: usize) -> f64 {
        let mut out: f64 = self.dd[p]
            .iter()
            .zip(&s.resid.d)
            .map(|(dd, d)| dd / d)
            .sum();
        if let (Some(lr), Some(cm)) = (&s.lr, &s.chol_mcal) {
            let aux = self.aux.as_ref().unwrap();
            let m = lr.m();
            // ∂M = ∂Σ_m + ∂Σ_mn·(SΣ_mnᵀ) + (SΣ_mnᵀ)ᵀ∂Σ_mnᵀ + Σ_mn ∂S Σ_mnᵀ,
            // with Σ_mn∂SΣ_mnᵀ = (∂BΣ)ᵀH + bsigᵀ∂(D⁻¹)bsig + Hᵀ(∂BΣ).
            let mut dm = aux.dsig_m[p].clone();
            let c1 = aux.dsig_nm[p].matmul_tn(&s.ssig); // ∂Σ_mn·SΣ_mnᵀ (m×m)ᵀ layout
            for r in 0..m {
                for cix in 0..m {
                    dm.add_to(r, cix, c1.get(r, cix) + c1.get(cix, r));
                }
            }
            // ∂B Σ_mnᵀ rows
            let n = s.n();
            let mut dbsig = Mat::zeros(n, m);
            for i in 0..n {
                for (k, &j) in s.resid.neighbors[i].iter().enumerate() {
                    let a = -self.da[p][i][k];
                    let src = lr.sigma_nm.row(j as usize);
                    let dst = dbsig.row_mut(i);
                    for (dd, ss) in dst.iter_mut().zip(src) {
                        *dd += a * ss;
                    }
                }
            }
            let c2 = dbsig.matmul_tn(&s.h); // (∂BΣ)ᵀH
            let mut wbsig = s.bsig.clone();
            let scale: Vec<f64> = s
                .resid
                .d
                .iter()
                .zip(&self.dd[p])
                .map(|(d, dd)| -dd / (d * d))
                .collect();
            wbsig.scale_rows(&scale);
            let c3 = s.bsig.matmul_tn(&wbsig); // bsigᵀ∂(D⁻¹)bsig
            for r in 0..m {
                for cix in 0..m {
                    dm.add_to(r, cix, c2.get(r, cix) + c2.get(cix, r) + c3.get(r, cix));
                }
            }
            // Tr(M⁻¹∂M) − Tr(Σ_m⁻¹∂Σ_m)
            let minv_dm = cm.solve_mat(&dm);
            let sminv_dsm = lr.chol_m.solve_mat(&aux.dsig_m[p]);
            for r in 0..m {
                out += minv_dm.get(r, r) - sminv_dsm.get(r, r);
            }
        }
        out
    }
}

/// `L^{VIFLA}` and its gradient wrt `[kernel log-params..., aux ξ...]`.
pub fn nll_and_grad(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    lik: &Likelihood,
    y: &[f64],
    mode: &SolveMode,
    rng: &mut Rng,
) -> (f64, Vec<f64>, LaplaceState) {
    nll_and_grad_panels(s, x, kernel, lik, y, mode, rng, None)
}

/// [`nll_and_grad`] with pre-gathered neighbor coordinate panels from a
/// frozen [`VifPlan`] — the fit driver's per-evaluation path, which
/// spares the Appendix-A derivative pack the per-row coordinate gathers.
#[allow(clippy::too_many_arguments)]
pub fn nll_and_grad_panels(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    lik: &Likelihood,
    y: &[f64],
    mode: &SolveMode,
    rng: &mut Rng,
    x_panels: Option<&NeighborPanels>,
) -> (f64, Vec<f64>, LaplaceState) {
    nll_and_grad_panels_session(s, x, kernel, lik, y, mode, rng, x_panels, None)
}

/// [`nll_and_grad_panels`] with warm-start state: the mode search,
/// preconditioner, and `s̃` solve all start from the previous
/// evaluation's results (see [`LaplaceSession`]); the SLQ probe solves
/// deliberately stay cold — their Lanczos tridiagonals require the pure
/// Krylov recurrence. `session = None` is bitwise identical to
/// [`nll_and_grad_panels`].
#[allow(clippy::too_many_arguments)]
pub fn nll_and_grad_panels_session(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    lik: &Likelihood,
    y: &[f64],
    mode: &SolveMode,
    rng: &mut Rng,
    x_panels: Option<&NeighborPanels>,
    mut session: Option<&mut LaplaceSession>,
) -> (f64, Vec<f64>, LaplaceState) {
    let sigma_cache = match mode {
        SolveMode::Cholesky => Some(s.dense_sigma_dagger()),
        _ => None,
    };
    let state =
        find_mode_session(s, x, kernel, lik, y, mode, sigma_cache.as_ref(), session.as_deref_mut());
    // The mode search leaves its FITC preconditioner in the session; a
    // weights-only refresh aligns it with W(b̃) for the logdet/gradient
    // solves (θ is unchanged since the last Newton iteration).
    let mut solver = match session.as_deref_mut() {
        Some(sess) => WSolver::new_session(
            s,
            x,
            kernel,
            state.w.clone(),
            mode,
            sigma_cache.as_ref(),
            None,
            sess.fitc.take(),
            false,
        ),
        None => WSolver::new(s, x, kernel, state.w.clone(), mode, sigma_cache.as_ref()),
    };
    let (logdet, probes) = solver.logdet_and_probes(rng);
    let value = state.psi + 0.5 * logdet;

    let pack = VifDerivPack::build_panels(s, x, kernel, x_panels);
    let nk = pack.np;
    let naux = lik.num_aux();
    let mut grad = vec![0.0; nk + naux];

    // diag((W+Σ_†⁻¹)⁻¹) and the mode-derivative helper vectors.
    let diag = solver.diag_inv(probes.as_ref());
    let n = y.len();
    let s_vec: Vec<f64> = (0..n)
        .map(|i| -0.5 * lik.d3(y[i], state.b[i]) * diag[i])
        .collect();
    let s_tilde = match session.as_deref_mut() {
        Some(sess) => {
            let guess = sess.s_tilde.take().filter(|g| g.len() == n);
            solver.solve_from(&s_vec, guess.as_deref())
        }
        None => solver.solve(&s_vec),
    };

    // θ gradients: each parameter's trace/quadratic terms are
    // independent solves above the column-blocked layer, so they fan out
    // onto the process-wide pool (nested probe-level parallelism inside
    // collapses deterministically on pool workers). The arithmetic per p
    // is identical to the sequential loop, so results do not depend on
    // thread count.
    let theta_grads: Vec<f64> = crate::coordinator::parallel_map_heavy(nk, |p| {
        let g1 = pack.apply_dsig_dagger_inv(s, p, &state.b);
        // ∂logdet(Σ_†W+I)/∂θ
        let dld = match (&mode, &probes) {
            (SolveMode::Cholesky, _) => {
                // exact: Tr((W⁻¹+Σ_†)⁻¹ ∂Σ_†) via dense (W⁻¹+Σ)⁻¹ = W½B_K⁻¹W½
                let (_, chol) = solver.dense.as_ref().unwrap();
                let mut tr = 0.0;
                for j in 0..n {
                    let mut e = vec![0.0; n];
                    e[j] = 1.0;
                    let col = pack.apply_dsig_dagger(s, p, &e);
                    // (W½ B_K⁻¹ W½)[j, :] · col
                    let mut ej = vec![0.0; n];
                    ej[j] = state.w[j].sqrt();
                    let t = chol.solve(&ej);
                    let row: Vec<f64> = t
                        .iter()
                        .zip(&state.w)
                        .map(|(a, w)| a * w.sqrt())
                        .collect();
                    tr += dot(&row, &col);
                }
                tr
            }
            (SolveMode::Iterative(_), Some((run, PrecondType::Fitc))) => {
                // Tr((W⁻¹+Σ_†)⁻¹ ∂Σ_†) via retained FITC probes
                crate::iterative::slq::trace_estimate(&run.probes, |v| {
                    pack.apply_dsig_dagger(s, p, v)
                })
            }
            (SolveMode::Iterative(_), Some((run, _))) => {
                // ∂logdetΣ_† + Tr((W+Σ_†⁻¹)⁻¹ ∂Σ_†⁻¹) via VIFDU probes
                pack.dlogdet_sigma_dagger(s, p)
                    + crate::iterative::slq::trace_estimate(&run.probes, |v| {
                        pack.apply_dsig_dagger_inv(s, p, v)
                    })
            }
            _ => unreachable!("iterative mode always retains probes"),
        };
        0.5 * dot(&state.b, &g1) + 0.5 * dld - dot(&s_tilde, &g1)
    });
    grad[..nk].copy_from_slice(&theta_grads);

    // Auxiliary-parameter gradients.
    if naux > 0 {
        for i in 0..n {
            let daux = lik.d_aux(y[i], state.b[i]);
            let dwa = lik.d_w_aux(y[i], state.b[i]);
            let dadb = lik.d_aux_db(y[i], state.b[i]);
            for l in 0..naux {
                grad[nk + l] += -daux[l] + 0.5 * diag[i] * dwa[l] + s_tilde[i] * dadb[l];
            }
        }
    }

    // Hand the reusable pieces back for the next evaluation.
    if let Some(sess) = session {
        let (_, carried_fitc) = solver.take_preconds();
        sess.fitc = carried_fitc;
        sess.s_tilde = Some(s_tilde);
    }

    (value, grad, state)
}

/// Posterior predictive distribution of the latent process (Prop 3.1 with
/// `B_p = I`), with the predictive variances split into the deterministic
/// part (20) and the stochastic part (21) estimated by SBPV (Alg. 1) or
/// SPV (Alg. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredVarMethod {
    /// Algorithm 1.
    Sbpv,
    /// Algorithm 2.
    Spv,
    /// Dense-exact (validation).
    Exact,
}

pub struct LaplacePrediction {
    pub latent_mean: Vec<f64>,
    pub latent_var: Vec<f64>,
    pub response_mean: Vec<f64>,
    pub response_var: Vec<f64>,
}

/// Builds a one-shot [`predict::PredictPlan`] and runs the shared
/// panelized pipeline (`vif::predict`); for repeated predictions at
/// fixed θ build the plan once and call [`predict_with_plan`].
#[allow(clippy::too_many_arguments)]
pub fn predict(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    lik: &Likelihood,
    state: &LaplaceState,
    xp: &Mat,
    m_v: usize,
    selection: NeighborSelection,
    mode: &SolveMode,
    var_method: PredVarMethod,
    ell: usize,
    rng: &mut Rng,
) -> LaplacePrediction {
    let plan = predict::PredictPlan::build(s, x, kernel, xp, m_v, selection);
    predict_with_plan(s, x, kernel, lik, state, xp, &plan, mode, var_method, ell, rng)
}

/// [`predict`] against a frozen [`predict::PredictPlan`]: the latent
/// mean and the deterministic variance (20) come from the shared batched
/// pipeline (latent scale — the structure's nugget is 0), and the
/// stochastic correction (21) routes whole probe blocks through
/// [`predict::project_q_batch`] / [`predict::project_qt_batch`] and the
/// batched PCG engine — SBPV and SPV run one multi-RHS solve per probe
/// block, with no per-column projections left.
#[allow(clippy::too_many_arguments)]
pub fn predict_with_plan(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    lik: &Likelihood,
    state: &LaplaceState,
    xp: &Mat,
    plan: &predict::PredictPlan,
    mode: &SolveMode,
    var_method: PredVarMethod,
    ell: usize,
    rng: &mut Rng,
) -> LaplacePrediction {
    let np_pts = xp.rows();
    // Conditional blocks + deterministic terms (latent scale: the
    // structure was assembled with nugget = 0).
    let blocks = predict::PredictBlocks::compute(s, kernel, xp, plan, 1e-8);
    let mean = predict::posterior_mean(s, plan, &blocks, &state.b);
    let var_det = &blocks.var_det;

    // Stochastic part: diag of (21), probe blocks through the batched
    // projections.
    let solver = WSolver::new(s, x, kernel, state.w.clone(), mode, None);
    let var_stoch: Vec<f64> = match var_method {
        PredVarMethod::Exact => {
            // Exact (dense) diagonal of (21): for each prediction point p,
            // the correction is (Qᵀe_p)ᵀ (W+Σ_†⁻¹)⁻¹ (Qᵀe_p), where the
            // adjoint Qᵀe_p already carries the inner Σ_†⁻¹ factors.
            // Identity columns are fed through the batched adjoint in
            // blocks, the dense solver maps the columns.
            let sigma_dense = s.dense_sigma_dagger();
            let dsolver = WSolver::new(
                s,
                x,
                kernel,
                state.w.clone(),
                &SolveMode::Cholesky,
                Some(&sigma_dense),
            );
            let mut out = vec![0.0; np_pts];
            let mut done = 0;
            while done < np_pts {
                let width = (np_pts - done).min(64);
                let z = Mat::from_fn(
                    np_pts,
                    width,
                    |i, j| if i == done + j { 1.0 } else { 0.0 },
                );
                let qt = predict::project_qt_batch(s, plan, &blocks, &z);
                let cqt = dsolver.solve_batch(&qt);
                for j in 0..width {
                    out[done + j] = dot(&qt.col(j), &cqt.col(j));
                }
                done += width;
            }
            out
        }
        PredVarMethod::Sbpv => {
            let mut local_rng = rng.split(0xabc);
            sbpv_diag(
                ell,
                np_pts,
                &mut local_rng,
                |r| {
                    // z₆ ~ N(0, Σ_†⁻¹ + W): Σ_†⁻¹·sample(N(0,Σ_†)) + W^{1/2}ε
                    let sig = s.sample(r);
                    let mut z = s.apply_sigma_dagger_inv(&sig);
                    for (zi, wi) in z.iter_mut().zip(&state.w) {
                        *zi += wi.sqrt() * r.normal();
                    }
                    z
                },
                |z6| solver.solve_batch(z6),
                |z7| {
                    predict::project_q_batch(
                        s,
                        plan,
                        &blocks,
                        &s.apply_sigma_dagger_inv_batch(z7),
                    )
                },
            )
        }
        PredVarMethod::Spv => {
            let mut local_rng = rng.split(0xdef);
            spv_diag(ell, np_pts, &mut local_rng, |z1| {
                // Qᵀ for the whole probe block, one batched CG over all
                // probes, Q back — three batched passes, no columns.
                let qt = predict::project_qt_batch(s, plan, &blocks, z1);
                let sol = solver.solve_batch(&qt);
                predict::project_q_batch(s, plan, &blocks, &s.apply_sigma_dagger_inv_batch(&sol))
            })
        }
    };

    let latent_var: Vec<f64> = var_det
        .iter()
        .zip(&var_stoch)
        .map(|(d, st)| (d + st).max(1e-12))
        .collect();
    let response_mean: Vec<f64> = mean
        .iter()
        .zip(&latent_var)
        .map(|(m, v)| lik.predictive_mean(*m, *v))
        .collect();
    let response_var: Vec<f64> = mean
        .iter()
        .zip(&latent_var)
        .map(|(m, v)| lik.predictive_var(*m, *v))
        .collect();
    LaplacePrediction {
        latent_mean: mean,
        latent_var,
        response_mean,
        response_var,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Smoothness;
    use crate::linalg::CholeskyFactor;
    use crate::testing::random_points;
    use crate::vif::{select_inducing, select_neighbors};

    const LN_2PI: f64 = 1.8378770664093453;

    fn setup(
        n: usize,
        m: usize,
        m_v: usize,
        full_cond: bool,
    ) -> (Mat, ArdMatern, VifStructure) {
        let mut rng = Rng::seed_from(51);
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.1, vec![0.35, 0.45], Smoothness::ThreeHalves);
        let z = select_inducing(&x, &kernel, m, 2, &mut rng, None);
        let nb = if full_cond {
            (0..n).map(|i| (0..i as u32).collect()).collect()
        } else {
            let lr_tmp = z
                .clone()
                .map(|z| super::super::LowRank::build(&x, &kernel, z, 1e-10));
            select_neighbors(
                &x,
                &kernel,
                lr_tmp.as_ref(),
                m_v,
                NeighborSelection::CorrelationBruteForce,
            )
        };
        // latent scale: nugget = 0
        let s = VifStructure::assemble(&x, &kernel, z, nb, 0.0, 1e-10, 0);
        (x, kernel, s)
    }

    fn sim_bernoulli(s: &VifStructure, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed_from(seed);
        let b = s.sample(&mut rng);
        b.iter()
            .map(|bi| {
                if rng.bernoulli(crate::likelihoods::sigmoid(*bi)) {
                    1.0
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn gaussian_laplace_equals_exact_marginal() {
        // Laplace is exact for a Gaussian likelihood; with full
        // conditioning Σ_† = Σ, so VIFLA NLL must equal the dense
        // Gaussian marginal NLL of y ~ N(0, Σ + σ²I).
        let (x, kernel, s) = setup(25, 5, 0, true);
        let noise = 0.1;
        let lik = Likelihood::Gaussian { variance: noise };
        let mut rng = Rng::seed_from(3);
        let latent = s.sample(&mut rng);
        let y: Vec<f64> = latent.iter().map(|b| b + noise.sqrt() * rng.normal()).collect();
        let (got, state) = nll(&s, &x, &kernel, &lik, &y, &SolveMode::Cholesky, &mut rng);
        // dense marginal
        let cov = kernel.sym_cov(&x, noise);
        let chol = CholeskyFactor::new(&cov).unwrap();
        let alpha = chol.solve(&y);
        let want = 0.5 * (25.0 * LN_2PI + chol.logdet() + dot(&y, &alpha));
        assert!((got - want).abs() < 1e-4, "{got} vs {want}");
        assert!(state.newton_iters >= 1);
    }

    #[test]
    fn iterative_nll_matches_cholesky_both_preconditioners() {
        let (x, kernel, s) = setup(150, 12, 6, false);
        let lik = Likelihood::BernoulliLogit;
        let y = sim_bernoulli(&s, 9);
        let mut rng = Rng::seed_from(4);
        let (want, _) = nll(&s, &x, &kernel, &lik, &y, &SolveMode::Cholesky, &mut rng);
        for precond in [PrecondType::Vifdu, PrecondType::Fitc] {
            let cfg = IterConfig {
                precond,
                ell: 100,
                cg_tol: 1e-4,
                max_cg: 400,
                fitc_k: 20,
                slq_min_iter: 25,
                seed: 7,
            };
            let (got, _) = nll(
                &s,
                &x,
                &kernel,
                &lik,
                &y,
                &SolveMode::Iterative(cfg),
                &mut rng,
            );
            assert!(
                (got - want).abs() < 0.02 * want.abs().max(1.0),
                "{precond:?}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn cholesky_gradient_matches_fd_bernoulli() {
        let n = 30;
        let mut rng0 = Rng::seed_from(51);
        let x = random_points(&mut rng0, n, 2);
        let kernel = ArdMatern::new(1.1, vec![0.35, 0.45], Smoothness::ThreeHalves);
        let mut rngz = Rng::seed_from(11);
        let z = select_inducing(&x, &kernel, 5, 2, &mut rngz, None);
        let nb = select_neighbors(&x, &kernel, None, 4, NeighborSelection::EuclideanTransformed);
        let s = VifStructure::assemble(&x, &kernel, z.clone(), nb.clone(), 0.0, 1e-10, 0);
        let lik = Likelihood::BernoulliLogit;
        let y = sim_bernoulli(&s, 13);
        let mut rng = Rng::seed_from(5);
        let (_, grad, _) = nll_and_grad(
            &s,
            &x,
            &kernel,
            &lik,
            &y,
            &SolveMode::Cholesky,
            &mut rng,
        );
        let packed = kernel.log_params();
        let eval = |p: &[f64]| -> f64 {
            let k = ArdMatern::from_log_params(p, Smoothness::ThreeHalves);
            let s = VifStructure::assemble(&x, &k, z.clone(), nb.clone(), 0.0, 1e-10, 0);
            let mut r = Rng::seed_from(5);
            nll(&s, &x, &k, &lik, &y, &SolveMode::Cholesky, &mut r).0
        };
        crate::testing::check_gradient(eval, &grad[..packed.len()], &packed, 1e-5, 5e-3, 5e-4)
            .unwrap();
    }

    #[test]
    fn cholesky_aux_gradient_matches_fd_gamma() {
        let n = 25;
        let mut rng0 = Rng::seed_from(51);
        let x = random_points(&mut rng0, n, 2);
        let kernel = ArdMatern::new(0.8, vec![0.3, 0.4], Smoothness::ThreeHalves);
        let nb = select_neighbors(&x, &kernel, None, 4, NeighborSelection::EuclideanTransformed);
        let s = VifStructure::assemble(&x, &kernel, None, nb.clone(), 0.0, 1e-10, 0);
        let mut rng = Rng::seed_from(21);
        let latent = s.sample(&mut rng);
        let shape0 = 2.0;
        let y: Vec<f64> = latent
            .iter()
            .map(|b| rng.gamma(shape0) * b.exp() / shape0)
            .collect();
        let lik = Likelihood::Gamma { shape: shape0 };
        let (_, grad, _) = nll_and_grad(
            &s,
            &x,
            &kernel,
            &lik,
            &y,
            &SolveMode::Cholesky,
            &mut rng,
        );
        let nk = kernel.num_params();
        // FD on aux (log shape)
        let h = 1e-5;
        let eval_aux = |la: f64| -> f64 {
            let l = Likelihood::Gamma { shape: la.exp() };
            let mut r = Rng::seed_from(5);
            nll(&s, &x, &kernel, &l, &y, &SolveMode::Cholesky, &mut r).0
        };
        let la0 = shape0.ln();
        let fd = (eval_aux(la0 + h) - eval_aux(la0 - h)) / (2.0 * h);
        assert!(
            (grad[nk] - fd).abs() < 5e-3 * (1.0 + fd.abs()),
            "aux grad {} vs fd {fd}",
            grad[nk]
        );
    }

    #[test]
    fn iterative_gradient_close_to_cholesky_gradient() {
        let (x, kernel, s) = setup(120, 10, 5, false);
        let lik = Likelihood::BernoulliLogit;
        let y = sim_bernoulli(&s, 17);
        let mut rng = Rng::seed_from(6);
        let (_, g_chol, _) = nll_and_grad(
            &s,
            &x,
            &kernel,
            &lik,
            &y,
            &SolveMode::Cholesky,
            &mut rng,
        );
        // FITC preconditioner: low-variance STE (tight check).
        // VIFDU: unbiased but visibly noisier (matches the paper's Fig. 4
        // finding that FITC dominates) — looser check with more probes.
        for (precond, ell, rtol) in [
            (PrecondType::Fitc, 200usize, 0.15),
            (PrecondType::Vifdu, 800, 0.6),
        ] {
            let cfg = IterConfig {
                precond,
                ell,
                cg_tol: 1e-5,
                max_cg: 500,
                fitc_k: 15,
                slq_min_iter: 25,
                seed: 7,
            };
            let (_, g_iter, _) = nll_and_grad(
                &s,
                &x,
                &kernel,
                &lik,
                &y,
                &SolveMode::Iterative(cfg),
                &mut rng,
            );
            for (p, (a, b)) in g_chol.iter().zip(&g_iter).enumerate() {
                assert!(
                    (a - b).abs() < rtol * (1.0 + a.abs()),
                    "{precond:?} param {p}: chol {a} vs iter {b}"
                );
            }
        }
    }

    #[test]
    fn gaussian_laplace_prediction_matches_exact_gp() {
        // Gaussian likelihood + full conditioning: the latent posterior
        // mean/var from the Laplace path must match the exact GP.
        let (x, kernel, s) = setup(30, 6, 0, true);
        let noise = 0.15;
        let lik = Likelihood::Gaussian { variance: noise };
        let mut rng = Rng::seed_from(23);
        let latent = s.sample(&mut rng);
        let y: Vec<f64> = latent.iter().map(|b| b + noise.sqrt() * rng.normal()).collect();
        let xp = random_points(&mut rng, 5, 2);
        let (_, state) = nll(&s, &x, &kernel, &lik, &y, &SolveMode::Cholesky, &mut rng);
        let pred = predict(
            &s,
            &x,
            &kernel,
            &lik,
            &state,
            &xp,
            30,
            NeighborSelection::EuclideanTransformed,
            &SolveMode::Cholesky,
            PredVarMethod::Exact,
            0,
            &mut rng,
        );
        // exact latent posterior
        let cov = kernel.sym_cov(&x, noise);
        let chol = CholeskyFactor::new(&cov).unwrap();
        let alpha = chol.solve(&y);
        for p in 0..5 {
            let kxp: Vec<f64> = (0..30).map(|i| kernel.cov(x.row(i), xp.row(p))).collect();
            let mu = dot(&kxp, &alpha);
            let w = chol.solve(&kxp);
            let v = kernel.variance - dot(&kxp, &w);
            assert!(
                (pred.latent_mean[p] - mu).abs() < 1e-4,
                "mean {p}: {} vs {mu}",
                pred.latent_mean[p]
            );
            assert!(
                (pred.latent_var[p] - v).abs() < 1e-4,
                "var {p}: {} vs {v}",
                pred.latent_var[p]
            );
        }
    }

    #[test]
    fn sbpv_and_spv_match_exact_variances() {
        let (x, kernel, s) = setup(80, 8, 5, false);
        let lik = Likelihood::BernoulliLogit;
        let y = sim_bernoulli(&s, 29);
        let mut rng = Rng::seed_from(31);
        let xp = random_points(&mut rng, 6, 2);
        let (_, state) = nll(&s, &x, &kernel, &lik, &y, &SolveMode::Cholesky, &mut rng);
        let cfg = IterConfig {
            precond: PrecondType::Fitc,
            ell: 50,
            cg_tol: 1e-6,
            max_cg: 300,
            fitc_k: 10,
            slq_min_iter: 25,
            seed: 3,
        };
        let exact = predict(
            &s, &x, &kernel, &lik, &state, &xp, 5,
            NeighborSelection::CorrelationBruteForce,
            &SolveMode::Cholesky, PredVarMethod::Exact, 0, &mut rng,
        );
        for method in [PredVarMethod::Sbpv, PredVarMethod::Spv] {
            let got = predict(
                &s, &x, &kernel, &lik, &state, &xp, 5,
                NeighborSelection::CorrelationBruteForce,
                &SolveMode::Iterative(cfg.clone()), method, 400, &mut rng,
            );
            for p in 0..6 {
                assert!(
                    (got.latent_var[p] - exact.latent_var[p]).abs()
                        < 0.12 * exact.latent_var[p].max(0.05),
                    "{method:?} var {p}: {} vs {}",
                    got.latent_var[p],
                    exact.latent_var[p]
                );
                assert!((got.latent_mean[p] - exact.latent_mean[p]).abs() < 1e-8);
            }
        }
    }
}

#[cfg(test)]
mod derivpack_tests {
    use super::*;
    use crate::kernels::Smoothness;
    use crate::testing::random_points;
    use crate::vif::{select_inducing, select_neighbors};

    fn build_at(
        x: &Mat,
        packed: &[f64],
        z: &Option<Mat>,
        nb: &[Vec<u32>],
    ) -> (ArdMatern, VifStructure) {
        let k = ArdMatern::from_log_params(packed, Smoothness::ThreeHalves);
        let s = VifStructure::assemble(x, &k, z.clone(), nb.to_vec(), 0.0, 1e-12, 0);
        (k, s)
    }

    #[test]
    fn deriv_products_match_finite_differences() {
        let n = 18;
        let mut rng = Rng::seed_from(71);
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.2, vec![0.3, 0.5], Smoothness::ThreeHalves);
        let z = select_inducing(&x, &kernel, 5, 2, &mut rng, None);
        let nb = select_neighbors(&x, &kernel, None, 4, NeighborSelection::EuclideanTransformed);
        let packed = kernel.log_params();
        let (k0, s0) = build_at(&x, &packed, &z, &nb);
        let pack = VifDerivPack::build(&s0, &x, &k0);
        let v: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let h = 1e-6;
        for p in 0..packed.len() {
            let mut pp = packed.clone();
            pp[p] += h;
            let (_, sp) = build_at(&x, &pp, &z, &nb);
            let mut pm = packed.clone();
            pm[p] -= h;
            let (_, sm) = build_at(&x, &pm, &z, &nb);
            // ∂Σ_† v
            let fd: Vec<f64> = sp
                .apply_sigma_dagger(&v)
                .iter()
                .zip(&sm.apply_sigma_dagger(&v))
                .map(|(a, b)| (a - b) / (2.0 * h))
                .collect();
            let an = pack.apply_dsig_dagger(&s0, p, &v);
            for i in 0..n {
                assert!(
                    (fd[i] - an[i]).abs() < 1e-4 * (1.0 + fd[i].abs()),
                    "dsig_dagger p={p} i={i}: fd {} vs an {}",
                    fd[i],
                    an[i]
                );
            }
            // ∂Σ_†⁻¹ v
            let fd: Vec<f64> = sp
                .apply_sigma_dagger_inv(&v)
                .iter()
                .zip(&sm.apply_sigma_dagger_inv(&v))
                .map(|(a, b)| (a - b) / (2.0 * h))
                .collect();
            let an = pack.apply_dsig_dagger_inv(&s0, p, &v);
            for i in 0..n {
                assert!(
                    (fd[i] - an[i]).abs() < 1e-4 * (1.0 + fd[i].abs()),
                    "dsig_dagger_inv p={p} i={i}: fd {} vs an {}",
                    fd[i],
                    an[i]
                );
            }
            // ∂ log det Σ_†
            let fd_ld = (sp.logdet() - sm.logdet()) / (2.0 * h);
            let an_ld = pack.dlogdet_sigma_dagger(&s0, p);
            assert!(
                (fd_ld - an_ld).abs() < 1e-4 * (1.0 + fd_ld.abs()),
                "dlogdet p={p}: fd {fd_ld} vs an {an_ld}"
            );
        }
    }
}

#[cfg(test)]
mod ste_convergence {
    use super::*;
    use crate::kernels::Smoothness;
    use crate::testing::random_points;
    use crate::vif::{select_inducing, select_neighbors};

    #[test]
    #[ignore] // diagnostic
    fn vifdu_trace_converges_with_probes() {
        let n = 120;
        let mut rng = Rng::seed_from(51);
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.1, vec![0.35, 0.45], Smoothness::ThreeHalves);
        let z = select_inducing(&x, &kernel, 10, 2, &mut rng, None);
        let lr_tmp = z.clone().map(|z| crate::vif::LowRank::build(&x, &kernel, z, 1e-10));
        let nb = select_neighbors(&x, &kernel, lr_tmp.as_ref(), 5,
            NeighborSelection::CorrelationBruteForce);
        let s = VifStructure::assemble(&x, &kernel, z, nb, 0.0, 1e-10, 0);
        let lik = Likelihood::BernoulliLogit;
        let mut r2 = Rng::seed_from(17);
        let b = s.sample(&mut r2);
        let y: Vec<f64> = b.iter().map(|bi| if r2.bernoulli(crate::likelihoods::sigmoid(*bi)) {1.0} else {0.0}).collect();
        let mut rng = Rng::seed_from(6);
        let (_, g_chol, _) = nll_and_grad(&s, &x, &kernel, &lik, &y, &SolveMode::Cholesky, &mut rng);
        for ell in [200usize, 1000, 4000] {
            let cfg = IterConfig { precond: PrecondType::Vifdu, ell, cg_tol: 1e-6, max_cg: 500, fitc_k: 15, slq_min_iter: 25, seed: 7 };
            let (_, g, _) = nll_and_grad(&s, &x, &kernel, &lik, &y, &SolveMode::Iterative(cfg), &mut rng);
            eprintln!("ell={ell}: iter grad {:?}\n        chol grad {:?}", g, g_chol);
        }
    }
}

/// High-level VIF-Laplace model for non-Gaussian likelihoods: owns data
/// and configuration, optimizes `[kernel log-params, aux ξ]` with L-BFGS
/// using common random numbers (fixed SLQ seed per fit) so the
/// stochastic objective behaves deterministically for the line search.
pub struct VifLaplaceModel {
    pub config: crate::vif::VifConfig,
    pub mode: SolveMode,
    pub x: Mat,
    pub y: Vec<f64>,
    pub kernel: ArdMatern,
    pub lik: Likelihood,
    pub inducing: Option<Mat>,
    pub structure: Option<VifStructure>,
    /// The θ-independent plan matching `structure` (set by `assemble`;
    /// the fit driver moves it out for each optimization round).
    pub plan: Option<VifPlan>,
    pub state: Option<LaplaceState>,
    pub fit_trace: Vec<f64>,
    /// Rows ingested through [`Self::append_points`] since the last full
    /// re-selection; drives the [`crate::vif::APPEND_COMPACT_FRACTION`]
    /// compaction trigger.
    appended_since_select: usize,
}

impl VifLaplaceModel {
    pub fn new(
        x: Mat,
        y: Vec<f64>,
        config: crate::vif::VifConfig,
        mode: SolveMode,
        kernel: ArdMatern,
        lik: Likelihood,
    ) -> Self {
        Self::try_new(x, y, config, mode, kernel, lik)
            .unwrap_or_else(|e| panic!("VifLaplaceModel::new: {e}"))
    }

    /// Validating constructor: rejects dimension-mismatched or non-finite
    /// training data before any VIF structure is built (see
    /// [`crate::vif::VifError`]).
    pub fn try_new(
        x: Mat,
        y: Vec<f64>,
        config: crate::vif::VifConfig,
        mode: SolveMode,
        kernel: ArdMatern,
        lik: Likelihood,
    ) -> Result<Self, crate::vif::VifError> {
        crate::vif::validate_training_data(&x, &y)?;
        Ok(VifLaplaceModel {
            config,
            mode,
            x,
            y,
            kernel,
            lik,
            inducing: None,
            structure: None,
            plan: None,
            state: None,
            fit_trace: vec![],
            appended_since_select: 0,
        })
    }

    fn pack(&self) -> Vec<f64> {
        let mut p = self.kernel.log_params();
        p.extend(self.lik.pack_aux());
        p
    }

    fn unpack(&self, p: &[f64]) -> (ArdMatern, Likelihood) {
        let nk = self.kernel.num_params();
        (
            ArdMatern::from_log_params(&p[..nk], self.config.smoothness),
            self.lik.with_aux(&p[nk..]),
        )
    }

    /// (Re-)select inducing points + neighbors for the current kernel,
    /// build the θ-independent [`VifPlan`], and assemble the latent-scale
    /// structure from it — the one symbolic/allocation pass per
    /// re-selection round (see the `vif` module docs).
    pub fn assemble(&mut self) {
        let (z, nb) =
            crate::vif::select_structure(&self.x, &self.kernel, &self.config, self.inducing.as_ref());
        let plan = VifPlan::build(&self.x, z, nb);
        self.structure = Some(VifStructure::from_plan(
            &self.x,
            &self.kernel,
            &plan,
            0.0, // latent scale
            self.config.jitter,
            0,
        ));
        self.inducing = plan.z.clone();
        self.plan = Some(plan);
        self.appended_since_select = 0;
    }

    /// Incrementally ingest new observations at the current θ (the
    /// streaming-append path). Validates the batch, extends `x`/`y`, and
    /// runs the layered [`VifStructure::append`] update against the
    /// frozen plan — equivalent to a from-scratch `assemble` over the
    /// extended data to ≤1e-12, on the latent scale (nugget 0; new rows
    /// condition on their `m_v` nearest *pre-existing* points only).
    /// Bumps the structure generation, so cached
    /// [`predict::PredictPlan`]s are refused, and clears the mode state
    /// (`state = None`) — the mode depends on every observation, so call
    /// [`Self::refresh_state`] (or `fit`) before predicting. Past an
    /// appended fraction of [`crate::vif::APPEND_COMPACT_FRACTION`] the
    /// model [`compact`](Self::compact)s itself. An empty batch is a
    /// bitwise no-op; errors leave the model untouched.
    pub fn append_points(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<(), String> {
        if x_new.rows() == 0 && y_new.is_empty() {
            return Ok(());
        }
        if x_new.rows() != y_new.len() {
            return Err(format!(
                "append_points: {} input rows but {} responses",
                x_new.rows(),
                y_new.len()
            ));
        }
        if x_new.cols() != self.x.cols() {
            return Err(format!(
                "append_points: input dimension {} does not match training dimension {}",
                x_new.cols(),
                self.x.cols()
            ));
        }
        if x_new.data().iter().any(|v| !v.is_finite()) {
            return Err("append_points: non-finite coordinate in X_new".to_string());
        }
        if y_new.iter().any(|v| !v.is_finite()) {
            return Err("append_points: non-finite response in y_new".to_string());
        }
        if self.structure.is_none() || self.plan.is_none() {
            self.assemble();
        }
        self.x.append_rows(x_new);
        self.y.extend_from_slice(y_new);
        let plan = self.plan.as_mut().unwrap();
        let s = self.structure.as_mut().unwrap();
        s.append(
            plan,
            &self.x,
            &self.kernel,
            x_new,
            self.config.num_neighbors,
            self.config.selection,
            self.config.jitter,
        );
        self.state = None; // the mode is a function of all observations
        self.appended_since_select += x_new.rows();
        if self.appended_since_select as f64
            > crate::vif::APPEND_COMPACT_FRACTION * self.x.rows() as f64
        {
            self.compact();
        }
        Ok(())
    }

    /// Full re-selection over all current data at the current θ — the
    /// compaction step bounding the leaf-conditioning drift of
    /// [`Self::append_points`]. Inducing points warm-start from the
    /// current set through Lloyd; the append drift counter resets and
    /// the mode state is cleared.
    pub fn compact(&mut self) {
        self.assemble();
        self.state = None;
    }

    /// Fit by L-BFGS via the shared [`crate::vif::fit_with_reselection`]
    /// driver (one plan build + one assembly per round; objective
    /// evaluations refresh the frozen structure in place, with common
    /// random numbers — the same probe seed at every θ). Returns the
    /// final `L^{VIFLA}`.
    pub fn fit(&mut self, max_iters: usize) -> f64 {
        crate::vif::fit_with_reselection(self, max_iters, 3)
    }

    /// Predict latent + response distributions at new inputs.
    pub fn predict(&self, xp: &Mat, var_method: PredVarMethod, ell: usize) -> LaplacePrediction {
        let s = self.structure.as_ref().expect("fit or assemble first");
        let state = self.state.as_ref().expect("fit first");
        let mut rng = Rng::seed_from(self.config.seed ^ 0xFACADE);
        predict(
            s,
            &self.x,
            &self.kernel,
            &self.lik,
            state,
            xp,
            self.config.num_neighbors.max(1),
            self.config.selection,
            &self.mode,
            var_method,
            ell,
            &mut rng,
        )
    }

    /// Build a reusable prediction plan for `xp` at the current θ (the
    /// serving path — see [`crate::vif::predict`]). Invalidated by
    /// `fit`, `assemble`, or any parameter change.
    pub fn build_predict_plan(&self, xp: &Mat) -> predict::PredictPlan {
        let s = self.structure.as_ref().expect("fit or assemble first");
        predict::PredictPlan::build(
            s,
            &self.x,
            &self.kernel,
            xp,
            self.config.num_neighbors.max(1),
            self.config.selection,
        )
    }

    /// [`Self::predict`] against a plan from [`Self::build_predict_plan`].
    pub fn predict_with_plan(
        &self,
        xp: &Mat,
        plan: &predict::PredictPlan,
        var_method: PredVarMethod,
        ell: usize,
    ) -> LaplacePrediction {
        let s = self.structure.as_ref().expect("fit or assemble first");
        let state = self.state.as_ref().expect("fit first");
        let mut rng = Rng::seed_from(self.config.seed ^ 0xFACADE);
        predict_with_plan(
            s,
            &self.x,
            &self.kernel,
            &self.lik,
            state,
            xp,
            plan,
            &self.mode,
            var_method,
            ell,
            &mut rng,
        )
    }

    /// Refresh the mode at the current parameters (e.g. after `assemble`).
    pub fn refresh_state(&mut self) {
        let s = self.structure.as_ref().expect("assemble first");
        let mut rng = Rng::seed_from(self.config.seed ^ 0xC0FFEE);
        let (_, state) = nll(s, &self.x, &self.kernel, &self.lik, &self.y, &self.mode, &mut rng);
        self.state = Some(state);
    }

    /// Freeze the fitted state into an immutable serving snapshot
    /// ([`FittedLaplace`]): data, kernel/likelihood parameters, the
    /// assembled latent-scale structure, and the Laplace mode are cloned
    /// (no fit-time scratch — no [`VifPlan`], no optimizer trace), and
    /// the per-generation read caches are built once here. The model
    /// must be assembled and have a mode (`fit`, or
    /// `assemble` + [`Self::refresh_state`]) first.
    pub fn snapshot(&self) -> FittedLaplace {
        let s = self.structure.as_ref().expect("fit or assemble before snapshot");
        let state = self.state.as_ref().expect("fit or refresh_state before snapshot");
        let mean_cache = predict::MeanCache::build(s, &state.b);
        let search_cache =
            predict::PredSearchCache::build(s, &self.x, &self.kernel, self.config.selection);
        FittedLaplace {
            config: self.config.clone(),
            x: self.x.clone(),
            kernel: self.kernel.clone(),
            lik: self.lik.clone(),
            structure: s.clone(),
            state: state.clone(),
            mean_cache,
            search_cache,
        }
    }
}

/// Immutable fitted-state snapshot of a [`VifLaplaceModel`] — the
/// serving handle, mirroring [`crate::vif::gaussian::FittedGaussian`].
/// Serves the *deterministic* predictive quantities (latent mean and the
/// Eq. 20 variance with `B_p = I`); the stochastic SBPV/SPV correction
/// needs a CG solver and probe RNG per call, which stays on the offline
/// [`VifLaplaceModel::predict_with_plan`] path.
pub struct FittedLaplace {
    pub config: crate::vif::VifConfig,
    pub x: Mat,
    pub kernel: ArdMatern,
    pub lik: Likelihood,
    pub structure: VifStructure,
    pub state: LaplaceState,
    mean_cache: predict::MeanCache,
    search_cache: predict::PredSearchCache,
}

impl FittedLaplace {
    /// Structure generation this snapshot serves.
    pub fn generation(&self) -> u64 {
        self.structure.generation
    }

    /// Latent posterior mean and deterministic latent variance for a
    /// batch of points — identical to the `latent_mean` /
    /// deterministic-variance half of [`predict_with_plan`] (the shared
    /// batched pipeline at latent-scale jitter `1e-8`), with the global
    /// mean solves served from the snapshot's cache.
    pub fn predict(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        let s = &self.structure;
        let plan = predict::PredictPlan::build_cached(
            s,
            &self.x,
            &self.kernel,
            xp,
            self.config.num_neighbors.max(1),
            self.config.selection,
            Some(&self.search_cache),
        );
        let blocks = predict::PredictBlocks::compute(s, &self.kernel, xp, &plan, 1e-8);
        let mean = predict::posterior_mean_cached(&plan, &blocks, &self.mean_cache);
        (mean, blocks.var_det)
    }
}

impl FitModel for VifLaplaceModel {
    fn reselect(&mut self) {
        self.assemble();
    }

    fn take_plan(&mut self) -> VifPlan {
        self.plan.take().expect("reselect before take_plan")
    }

    fn take_structure(&mut self) -> VifStructure {
        self.structure.take().expect("assemble before fitting")
    }

    fn pack_params(&self) -> Vec<f64> {
        self.pack()
    }

    fn adopt_params(&mut self, packed: &[f64]) {
        let (kernel, lik) = self.unpack(packed);
        self.kernel = kernel;
        self.lik = lik;
    }

    fn eval(
        &self,
        plan: &VifPlan,
        s: &mut VifStructure,
        packed: &[f64],
        session: &mut super::FitSession,
    ) -> (f64, Vec<f64>) {
        let (kernel, lik) = self.unpack(packed);
        // Latent scale: nugget = 0 in every refresh.
        s.refresh(plan, &self.x, &kernel, 0.0, self.config.jitter);
        // Common random numbers: same probe seed at every θ within a
        // round; the session tag re-draws them at re-selection rounds
        // (it is 0 when cold or in round 0, reproducing the legacy seed).
        let mut rng = Rng::seed_from(self.config.seed ^ 0xC0FFEE ^ session.probe_tag());
        let laplace_session = session.warm().then_some(&mut session.laplace);
        let (v, g, _) = nll_and_grad_panels_session(
            s,
            &self.x,
            &kernel,
            &lik,
            &self.y,
            &self.mode,
            &mut rng,
            Some(&plan.x_panels),
            laplace_session,
        );
        (v, g)
    }

    fn round_nll(&mut self) -> f64 {
        let mut rng = Rng::seed_from(self.config.seed ^ 0xC0FFEE);
        let (now, state) = nll(
            self.structure.as_ref().unwrap(),
            &self.x,
            &self.kernel,
            &self.lik,
            &self.y,
            &self.mode,
            &mut rng,
        );
        self.state = Some(state);
        now
    }

    fn lbfgs_tol(&self) -> f64 {
        1e-4
    }

    fn record_trace(&mut self, trace: &[f64]) {
        self.fit_trace.extend_from_slice(trace);
    }

    fn append_points(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<(), String> {
        VifLaplaceModel::append_points(self, x_new, y_new)
    }

    fn compact(&mut self) {
        VifLaplaceModel::compact(self);
    }
}
