//! VIF approximation for Gaussian-likelihood GP regression (paper §2).
//!
//! Implements the negative log-likelihood `L_†(θ; y)` with the
//! Sherman–Woodbury–Morrison + Sylvester identities of §2.2, its analytic
//! gradient with respect to the packed log-parameters, and the predictive
//! distribution of Proposition 2.1 (with the Appendix C.1 expansion and
//! prediction points conditioning on training points only, so `B_p = I`
//! and `D_p` is diagonal).

use crate::kernels::{ArdMatern, Smoothness};
use crate::linalg::{dot, Mat};
use crate::vecchia::neighbors::NeighborSelection;

use super::{
    predict, FitModel, GradAux, NeighborPanels, VifConfig, VifPlan, VifResidualOracle,
    VifStructure,
};

const LN_2PI: f64 = 1.8378770664093453;

/// Packed parameters of the Gaussian VIF model:
/// `[log σ₁², log λ₁…λ_d, log σ²]`.
#[derive(Clone, Debug)]
pub struct GaussianParams {
    pub kernel: ArdMatern,
    /// Error (noise) variance σ².
    pub noise: f64,
}

impl GaussianParams {
    pub fn pack(&self) -> Vec<f64> {
        let mut p = self.kernel.log_params();
        p.push(self.noise.ln());
        p
    }

    pub fn unpack(p: &[f64], smoothness: Smoothness) -> Self {
        let nk = p.len() - 1;
        GaussianParams {
            kernel: ArdMatern::from_log_params(&p[..nk], smoothness),
            noise: p[nk].exp(),
        }
    }

    pub fn num_params(&self) -> usize {
        self.kernel.num_params() + 1
    }
}

/// Negative log-likelihood `L_†(θ; y)` for an assembled structure.
pub fn nll(s: &VifStructure, y: &[f64]) -> f64 {
    let n = y.len() as f64;
    let u = s.apply_sigma_dagger_inv(y);
    0.5 * (n * LN_2PI + s.logdet() + dot(y, &u))
}

/// Negative log-likelihood and its gradient with respect to the packed
/// log-parameters `[log σ₁², log λ…, log σ²]`.
///
/// The gradient assembles, per §2.2 + Appendix A:
/// * residual-part traces through the identity
///   `Tr(Σ_†⁻¹ ∂Σ̃ˢ) = Σ_i ∂D_i/D_i − Tr(M⁻¹Hᵀ ∂D H) + 2Tr(M⁻¹Hᵀ ∂B Σ_mnᵀ)`
/// * low-rank traces through `J = Σ_†⁻¹ Σ_mnᵀ` panels,
/// * quadratic forms through `v = B⁻ᵀ u`, `z = B⁻¹ D v`.
pub fn nll_and_grad(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    y: &[f64],
) -> (f64, Vec<f64>) {
    nll_and_grad_panels(s, x, kernel, y, None)
}

/// [`nll_and_grad`] with pre-gathered neighbor coordinate panels from a
/// frozen [`VifPlan`] — the fit driver's per-evaluation path, which
/// spares the Appendix-A gradient pass the per-row coordinate gathers.
pub fn nll_and_grad_panels(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    y: &[f64],
    x_panels: Option<&NeighborPanels>,
) -> (f64, Vec<f64>) {
    let n = y.len();
    let nk = kernel.num_params();
    let np = nk + 1; // + noise
    let noise_param = nk;

    let u = s.apply_sigma_dagger_inv(y);
    let value = 0.5 * (n as f64 * LN_2PI + s.logdet() + dot(y, &u));

    // Residual-part helper vectors.
    let v = s.resid.solve_bt(&u); // B⁻ᵀ u
    let dv: Vec<f64> = v.iter().zip(&s.resid.d).map(|(vi, di)| vi * di).collect();
    let z = s.resid.solve_b(&dv); // B⁻¹ D B⁻ᵀ u

    // Low-rank panels (empty when m = 0).
    let (t_vec, hm, g2, a_vec, js, grad_aux) = match (&s.lr, &s.chol_mcal) {
        (Some(lr), Some(cm)) => {
            // t_i = h_i M⁻¹ h_iᵀ  via HM = H M⁻¹ (n×m).
            let hm = cm.solve_mat(&s.h.t()).t(); // solve M X = Hᵀ → Xᵀ = H M⁻¹
            let t_vec: Vec<f64> = (0..n).map(|i| dot(s.h.row(i), hm.row(i))).collect();
            // J = Σ_†⁻¹ Σ_mnᵀ = ssig − ssig M⁻¹ SS;  JS = J Σ_m⁻¹.
            let k1 = cm.solve_mat(&s.ss); // M⁻¹ SS (m×m)
            let mut j = s.ssig.matmul(&k1);
            j.scale(-1.0);
            j.add_assign(&s.ssig);
            let js = lr.chol_m.solve_mat(&j.t()).t(); // J Σ_m⁻¹ (n×m)
            // G2 = Σ_m⁻¹ (Σ_mn J) Σ_m⁻¹  (m×m)
            let c2 = lr.sigma_nm.matmul_tn(&j);
            let g2 = lr.chol_m.solve_mat(&lr.chol_m.solve_mat(&c2).t()).t();
            // a = Σ_m⁻¹ Σ_mn u (m)
            let a_vec = lr.chol_m.solve(&lr.sigma_nm.matvec_t(&u));
            let grad_aux = GradAux::build(x, kernel, lr);
            (t_vec, hm, g2, a_vec, js, Some(grad_aux))
        }
        _ => (
            vec![0.0; n],
            Mat::zeros(0, 0),
            Mat::zeros(0, 0),
            vec![],
            Mat::zeros(0, 0),
            None,
        ),
    };

    let oracle = VifResidualOracle {
        kernel,
        x,
        lr: s.lr.as_ref(),
        grad_aux: grad_aux.as_ref(),
        extra_params: 1,
        x_panels,
    };

    // Residual-part contributions, accumulated per point i.
    use std::sync::Mutex;
    let grad_acc = Mutex::new(vec![0.0; np]);
    let m = s.m();
    s.resid.grads(
        &oracle,
        s.nugget,
        Some(noise_param),
        1e-10,
        &|i, dd, da| {
            let mut local = vec![0.0; np];
            let nb = &s.resid.neighbors[i];
            for p in 0..np {
                // trace: ½ dd (1/D_i − t_i); quad: −½ dd v_i²
                local[p] += 0.5 * dd[p] * (1.0 / s.resid.d[i] - t_vec[i])
                    - 0.5 * dd[p] * v[i] * v[i];
                if !nb.is_empty() {
                    // trace part: ½·2·Tr(M⁻¹Hᵀ ∂B Σ_mnᵀ) = −Σ_k ∂A_ik g_{jk,i}
                    // quad part:  −½ uᵀ∂Σ̃ˢu ⊃ −v_i Σ_k ∂A_ik z_jk
                    let dap = &da[p];
                    let mut tr_term = 0.0;
                    let mut quad_term = 0.0;
                    for (k, &j) in nb.iter().enumerate() {
                        let jj = j as usize;
                        if m > 0 {
                            // g_{j,i} = Σ_mj ᵀ (M⁻¹ h_i)
                            let lr = s.lr.as_ref().unwrap();
                            tr_term += dap[k] * dot(lr.sigma_nm.row(jj), hm.row(i));
                        }
                        quad_term += dap[k] * z[jj];
                    }
                    local[p] += -tr_term - v[i] * quad_term;
                }
            }
            let mut g = grad_acc.lock().unwrap();
            for p in 0..np {
                g[p] += local[p];
            }
        },
    );
    let mut grad = grad_acc.into_inner().unwrap();

    // Low-rank contributions (kernel params only).
    if let Some(lr) = &s.lr {
        let aux = grad_aux.as_ref().unwrap();
        // per-point: dot(∂K(Z,x_i), JS_i − u_i a)
        let per_point = crate::coordinator::parallel_map(n, |i| {
            let mut out = vec![0.0; nk];
            let mut g = vec![0.0; nk];
            let js_i = js.row(i);
            let ui = u[i];
            for l in 0..lr.m() {
                kernel.cov_and_grad_into(x.row(i), lr.z.row(l), &mut g);
                let w = js_i[l] - ui * a_vec[l];
                for (p, gp) in g.iter().enumerate() {
                    out[p] += gp * w;
                }
            }
            out
        });
        for pp in per_point {
            for p in 0..nk {
                grad[p] += pp[p];
            }
        }
        // m×m contractions: −½ Tr(G2 ∂Σ_m) + ½ aᵀ ∂Σ_m a
        for p in 0..nk {
            let dsm = &aux.dsig_m[p];
            let mut tr = 0.0;
            for r in 0..lr.m() {
                tr += dot(g2.row(r), dsm.row(r));
            }
            let mut qa = 0.0;
            for r in 0..lr.m() {
                qa += a_vec[r] * dot(dsm.row(r), &a_vec);
            }
            grad[p] += -0.5 * tr + 0.5 * qa;
        }
    }

    (value, grad)
}

/// Predictive distribution (Proposition 2.1 / Appendix C.1) at new inputs
/// `xp`, conditioning each prediction point on its `m_v` nearest training
/// points (so `B_p = I`, `D_p` diagonal). Builds a one-shot
/// [`predict::PredictPlan`] and runs the shared panelized pipeline; for
/// repeated predictions at fixed θ build the plan once and call
/// [`predict_with_plan`].
///
/// Returns `(mean, var)` for the **response** `y^p` (includes σ²);
/// subtract `noise` from `var` for the latent process.
pub fn predict(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    y: &[f64],
    xp: &Mat,
    m_v: usize,
    selection: NeighborSelection,
) -> (Vec<f64>, Vec<f64>) {
    let plan = predict::PredictPlan::build(s, x, kernel, xp, m_v, selection);
    predict_with_plan(s, kernel, y, xp, &plan)
}

/// [`predict`] against a frozen [`predict::PredictPlan`] — the serving
/// path: the plan's conditioning sets, coordinate panels, and scatter
/// pattern are reused across calls at fixed θ, and only the batched
/// numeric pass runs per call.
pub fn predict_with_plan(
    s: &VifStructure,
    kernel: &ArdMatern,
    y: &[f64],
    xp: &Mat,
    plan: &predict::PredictPlan,
) -> (Vec<f64>, Vec<f64>) {
    let blocks = predict::PredictBlocks::compute(s, kernel, xp, plan, 1e-10);
    let mean = predict::posterior_mean(s, plan, &blocks, y);
    (mean, blocks.var_det)
}

/// High-level Gaussian VIF regression model: owns data + config, fits by
/// L-BFGS on the packed log-parameters, predicts via Prop 2.1.
pub struct VifRegression {
    pub config: VifConfig,
    pub x: Mat,
    pub y: Vec<f64>,
    pub params: GaussianParams,
    pub inducing: Option<Mat>,
    pub structure: Option<VifStructure>,
    /// The θ-independent plan matching `structure` (set by `assemble`;
    /// the fit driver moves it out for each optimization round).
    pub plan: Option<VifPlan>,
    pub fit_trace: Vec<f64>,
    /// Rows ingested through [`Self::append_points`] since the last full
    /// re-selection; drives the [`super::APPEND_COMPACT_FRACTION`]
    /// compaction trigger.
    appended_since_select: usize,
}

impl VifRegression {
    /// Panicking constructor; see [`Self::try_new`] for the validating
    /// variant (CLI surfaces route through it).
    pub fn new(x: Mat, y: Vec<f64>, config: VifConfig, init: GaussianParams) -> Self {
        Self::try_new(x, y, config, init).unwrap_or_else(|e| panic!("VifRegression::new: {e}"))
    }

    /// Construct after validating the training data (the same checks as
    /// [`Self::append_points`]: row/response match, no NaN/Inf on either
    /// side). A rejected construction builds no structure at all.
    pub fn try_new(
        x: Mat,
        y: Vec<f64>,
        config: VifConfig,
        init: GaussianParams,
    ) -> Result<Self, crate::vif::VifError> {
        crate::vif::validate_training_data(&x, &y)?;
        Ok(VifRegression {
            config,
            x,
            y,
            params: init,
            inducing: None,
            structure: None,
            plan: None,
            fit_trace: vec![],
            appended_since_select: 0,
        })
    }

    /// (Re-)select inducing points and neighbors for the current kernel,
    /// build the θ-independent [`VifPlan`], and assemble the structure
    /// from it — the one symbolic/allocation pass per re-selection
    /// round (see the module docs on the plan/refresh split).
    pub fn assemble(&mut self) {
        let (z, nb) = super::select_structure(
            &self.x,
            &self.params.kernel,
            &self.config,
            self.inducing.as_ref(),
        );
        let plan = VifPlan::build(&self.x, z, nb);
        self.structure = Some(VifStructure::from_plan(
            &self.x,
            &self.params.kernel,
            &plan,
            self.params.noise,
            self.config.jitter,
            1,
        ));
        self.inducing = plan.z.clone();
        self.plan = Some(plan);
        self.appended_since_select = 0;
    }

    /// Incrementally ingest new observations at the current θ (the
    /// streaming-append path). Validates the batch, extends `x`/`y`, and
    /// runs the layered [`VifStructure::append`] update against the
    /// frozen plan — equivalent to a from-scratch `assemble` over the
    /// extended data to ≤1e-12 (new rows condition on their `m_v`
    /// nearest *pre-existing* points only). Bumps the structure
    /// generation, so cached [`predict::PredictPlan`]s are refused;
    /// past an appended fraction of [`super::APPEND_COMPACT_FRACTION`]
    /// the model [`compact`](Self::compact)s itself. An empty batch is a
    /// bitwise no-op; errors leave the model untouched.
    pub fn append_points(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<(), String> {
        if x_new.rows() == 0 && y_new.is_empty() {
            return Ok(());
        }
        if x_new.rows() != y_new.len() {
            return Err(format!(
                "append_points: {} input rows but {} responses",
                x_new.rows(),
                y_new.len()
            ));
        }
        if x_new.cols() != self.x.cols() {
            return Err(format!(
                "append_points: input dimension {} does not match training dimension {}",
                x_new.cols(),
                self.x.cols()
            ));
        }
        if x_new.data().iter().any(|v| !v.is_finite()) {
            return Err("append_points: non-finite coordinate in X_new".to_string());
        }
        if y_new.iter().any(|v| !v.is_finite()) {
            return Err("append_points: non-finite response in y_new".to_string());
        }
        if self.structure.is_none() || self.plan.is_none() {
            self.assemble();
        }
        self.x.append_rows(x_new);
        self.y.extend_from_slice(y_new);
        let plan = self.plan.as_mut().unwrap();
        let s = self.structure.as_mut().unwrap();
        s.append(
            plan,
            &self.x,
            &self.params.kernel,
            x_new,
            self.config.num_neighbors,
            self.config.selection,
            self.config.jitter,
        );
        self.appended_since_select += x_new.rows();
        if self.appended_since_select as f64
            > super::APPEND_COMPACT_FRACTION * self.x.rows() as f64
        {
            self.compact();
        }
        Ok(())
    }

    /// Full re-selection over all current data at the current θ — the
    /// compaction step bounding the leaf-conditioning drift of
    /// [`Self::append_points`]. Inducing points warm-start from the
    /// current set through Lloyd, and the append drift counter resets.
    pub fn compact(&mut self) {
        self.assemble();
    }

    /// Negative log-likelihood at the current parameters (assembles with
    /// fixed inducing points/neighbors for the evaluated θ).
    pub fn nll_at(&self, packed: &[f64], neighbors: &[Vec<u32>], z: Option<&Mat>) -> f64 {
        let pars = GaussianParams::unpack(packed, self.config.smoothness);
        let s = VifStructure::assemble(
            &self.x,
            &pars.kernel,
            z.cloned(),
            neighbors.to_vec(),
            pars.noise,
            self.config.jitter,
            1,
        );
        nll(&s, &self.y)
    }

    /// Fit by L-BFGS, re-selecting inducing points and neighbors between
    /// rounds (§6). Runs the shared [`super::fit_with_reselection`]
    /// driver: one plan build + one structure assembly per round, every
    /// L-BFGS evaluation refreshes the frozen structure in place.
    /// Returns the final NLL.
    pub fn fit(&mut self, max_iters: usize) -> f64 {
        super::fit_with_reselection(self, max_iters, 3)
    }

    /// Predict mean and response-variance at new inputs.
    pub fn predict(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        let s = self.structure.as_ref().expect("fit or assemble first");
        predict(
            s,
            &self.x,
            &self.params.kernel,
            &self.y,
            xp,
            self.config.num_neighbors.max(1),
            self.config.selection,
        )
    }

    /// Build a reusable prediction plan for `xp` at the current θ (the
    /// serving path: one neighbor search + panel gather, then any number
    /// of [`Self::predict_with_plan`] calls). Invalidated by `fit`,
    /// `assemble`, or any parameter change.
    pub fn build_predict_plan(&self, xp: &Mat) -> predict::PredictPlan {
        let s = self.structure.as_ref().expect("fit or assemble first");
        predict::PredictPlan::build(
            s,
            &self.x,
            &self.params.kernel,
            xp,
            self.config.num_neighbors.max(1),
            self.config.selection,
        )
    }

    /// [`Self::predict`] against a plan from [`Self::build_predict_plan`].
    pub fn predict_with_plan(
        &self,
        xp: &Mat,
        plan: &predict::PredictPlan,
    ) -> (Vec<f64>, Vec<f64>) {
        let s = self.structure.as_ref().expect("fit or assemble first");
        predict_with_plan(s, &self.params.kernel, &self.y, xp, plan)
    }

    /// Freeze the fitted state into an immutable serving snapshot
    /// ([`FittedGaussian`]): the data, parameters, and assembled
    /// structure are cloned (no fit-time scratch — no [`VifPlan`], no
    /// optimizer trace), and the per-generation read caches (the hoisted
    /// mean solves and the prediction cover tree) are built once here so
    /// request threads only ever run the per-batch numeric pass. The
    /// model must be assembled (`fit`/`assemble`) first.
    pub fn snapshot(&self) -> FittedGaussian {
        let s = self.structure.as_ref().expect("fit or assemble before snapshot");
        let mean_cache = predict::MeanCache::build(s, &self.y);
        let search_cache =
            predict::PredSearchCache::build(s, &self.x, &self.params.kernel, self.config.selection);
        FittedGaussian {
            config: self.config.clone(),
            x: self.x.clone(),
            y: self.y.clone(),
            params: self.params.clone(),
            structure: s.clone(),
            mean_cache,
            search_cache,
        }
    }
}

/// Immutable fitted-state snapshot of a [`VifRegression`] — the serving
/// handle. Owns exactly what the prediction read path needs (data,
/// parameters, assembled [`VifStructure`]) plus the per-generation read
/// caches ([`predict::MeanCache`], [`predict::PredSearchCache`]), so a
/// server publishes one `Arc<FittedGaussian>` per θ-generation and every
/// request batch against it is a pure read: plan build from the cached
/// cover tree, batched numeric pass, cached-mean gather. No interior
/// mutability — a refit or append produces a *new* snapshot (new
/// generation) instead of mutating this one.
pub struct FittedGaussian {
    pub config: VifConfig,
    pub x: Mat,
    pub y: Vec<f64>,
    pub params: GaussianParams,
    pub structure: VifStructure,
    mean_cache: predict::MeanCache,
    search_cache: predict::PredSearchCache,
}

impl FittedGaussian {
    /// Structure generation this snapshot serves.
    pub fn generation(&self) -> u64 {
        self.structure.generation
    }

    /// Predictive mean and response variance for a batch of points —
    /// numerically identical to [`VifRegression::predict_with_plan`] on
    /// the source model (same conditioning-set search, same batched
    /// numeric pass; the global mean solves come from the snapshot's
    /// cache instead of being recomputed per call).
    pub fn predict(&self, xp: &Mat) -> (Vec<f64>, Vec<f64>) {
        let s = &self.structure;
        let plan = predict::PredictPlan::build_cached(
            s,
            &self.x,
            &self.params.kernel,
            xp,
            self.config.num_neighbors.max(1),
            self.config.selection,
            Some(&self.search_cache),
        );
        let blocks = predict::PredictBlocks::compute(s, &self.params.kernel, xp, &plan, 1e-10);
        let mean = predict::posterior_mean_cached(&plan, &blocks, &self.mean_cache);
        (mean, blocks.var_det)
    }
}

impl FitModel for VifRegression {
    fn reselect(&mut self) {
        self.assemble();
    }

    fn take_plan(&mut self) -> VifPlan {
        self.plan.take().expect("reselect before take_plan")
    }

    fn take_structure(&mut self) -> VifStructure {
        self.structure.take().expect("assemble before fitting")
    }

    fn pack_params(&self) -> Vec<f64> {
        self.params.pack()
    }

    fn adopt_params(&mut self, packed: &[f64]) {
        self.params = GaussianParams::unpack(packed, self.config.smoothness);
    }

    fn eval(
        &self,
        plan: &VifPlan,
        s: &mut VifStructure,
        packed: &[f64],
        _session: &mut super::FitSession,
    ) -> (f64, Vec<f64>) {
        // Gaussian evaluations are direct (Woodbury + Cholesky, no CG),
        // so there is no iterative state to carry: warm ≡ cold bitwise.
        let pars = GaussianParams::unpack(packed, self.config.smoothness);
        s.refresh(plan, &self.x, &pars.kernel, pars.noise, self.config.jitter);
        nll_and_grad_panels(s, &self.x, &pars.kernel, &self.y, Some(&plan.x_panels))
    }

    fn round_nll(&mut self) -> f64 {
        nll(self.structure.as_ref().unwrap(), &self.y)
    }

    fn lbfgs_tol(&self) -> f64 {
        1e-5
    }

    fn record_trace(&mut self, trace: &[f64]) {
        self.fit_trace.extend_from_slice(trace);
    }

    fn append_points(&mut self, x_new: &Mat, y_new: &[f64]) -> Result<(), String> {
        VifRegression::append_points(self, x_new, y_new)
    }

    fn compact(&mut self) {
        VifRegression::compact(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::random_points;
    use crate::vif::{select_inducing, select_neighbors};

    /// Exact dense GP NLL for verification.
    fn dense_nll(x: &Mat, kernel: &ArdMatern, noise: f64, y: &[f64]) -> f64 {
        let cov = kernel.sym_cov(x, noise);
        let chol = crate::linalg::CholeskyFactor::new(&cov).unwrap();
        let alpha = chol.solve(y);
        0.5 * (y.len() as f64 * LN_2PI + chol.logdet() + dot(y, &alpha))
    }

    fn toy(n: usize) -> (Mat, ArdMatern, Vec<f64>) {
        let mut rng = Rng::seed_from(21);
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.2, vec![0.3, 0.5], Smoothness::ThreeHalves);
        let cov = kernel.sym_cov(&x, 0.05);
        let chol = crate::linalg::CholeskyFactor::new(&cov).unwrap();
        let y = chol.mul_lower(&rng.normal_vec(n));
        (x, kernel, y)
    }

    #[test]
    fn full_conditioning_nll_matches_dense() {
        let (x, kernel, y) = toy(30);
        let nb: Vec<Vec<u32>> = (0..30).map(|i| (0..i as u32).collect()).collect();
        let mut rng = Rng::seed_from(5);
        let z = select_inducing(&x, &kernel, 6, 2, &mut rng, None);
        let s = VifStructure::assemble(&x, &kernel, z, nb, 0.05, 1e-12, 1);
        let got = nll(&s, &y);
        let want = dense_nll(&x, &kernel, 0.05, &y);
        assert!((got - want).abs() < 1e-5, "{got} vs {want}");
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (x, kernel, y) = toy(25);
        let nb = select_neighbors(
            &x,
            &kernel,
            None,
            4,
            NeighborSelection::EuclideanTransformed,
        );
        let mut rng = Rng::seed_from(9);
        let z = select_inducing(&x, &kernel, 5, 2, &mut rng, None);
        let pars = GaussianParams { kernel: kernel.clone(), noise: 0.05 };
        let packed = pars.pack();
        let eval = |p: &[f64]| -> f64 {
            let pr = GaussianParams::unpack(p, Smoothness::ThreeHalves);
            let s = VifStructure::assemble(
                &x,
                &pr.kernel,
                z.clone(),
                nb.clone(),
                pr.noise,
                1e-12,
                1,
            );
            nll(&s, &y)
        };
        let s = VifStructure::assemble(&x, &kernel, z.clone(), nb.clone(), 0.05, 1e-12, 1);
        let (val, grad) = nll_and_grad(&s, &x, &kernel, &y);
        assert!((val - eval(&packed)).abs() < 1e-9);
        crate::testing::check_gradient(eval, &grad, &packed, 1e-5, 2e-3, 1e-4).unwrap();
    }

    #[test]
    fn gradient_matches_fd_pure_vecchia_and_fitc() {
        let (x, kernel, y) = toy(22);
        // m = 0 (Vecchia)
        let nb = select_neighbors(
            &x,
            &kernel,
            None,
            5,
            NeighborSelection::CorrelationBruteForce,
        );
        let pars = GaussianParams { kernel: kernel.clone(), noise: 0.05 };
        let packed = pars.pack();
        {
            let eval = |p: &[f64]| -> f64 {
                let pr = GaussianParams::unpack(p, Smoothness::ThreeHalves);
                let s = VifStructure::assemble(
                    &x,
                    &pr.kernel,
                    None,
                    nb.clone(),
                    pr.noise,
                    1e-12,
                    1,
                );
                nll(&s, &y)
            };
            let s = VifStructure::assemble(&x, &kernel, None, nb.clone(), 0.05, 1e-12, 1);
            let (_, grad) = nll_and_grad(&s, &x, &kernel, &y);
            crate::testing::check_gradient(eval, &grad, &packed, 1e-5, 2e-3, 1e-4).unwrap();
        }
        // m_v = 0 (FITC)
        {
            let mut rng = Rng::seed_from(13);
            let z = select_inducing(&x, &kernel, 6, 2, &mut rng, None);
            let nb0: Vec<Vec<u32>> = vec![vec![]; 22];
            let eval = |p: &[f64]| -> f64 {
                let pr = GaussianParams::unpack(p, Smoothness::ThreeHalves);
                let s = VifStructure::assemble(
                    &x,
                    &pr.kernel,
                    z.clone(),
                    nb0.clone(),
                    pr.noise,
                    1e-12,
                    1,
                );
                nll(&s, &y)
            };
            let s = VifStructure::assemble(&x, &kernel, z.clone(), nb0.clone(), 0.05, 1e-12, 1);
            let (_, grad) = nll_and_grad(&s, &x, &kernel, &y);
            crate::testing::check_gradient(eval, &grad, &packed, 1e-5, 2e-3, 1e-4).unwrap();
        }
    }

    #[test]
    fn prediction_matches_dense_gp_with_full_conditioning() {
        // Full conditioning + m inducing points: predictive mean/var must
        // match the exact GP because Σ̃_† = Σ̃ and the joint residual
        // factorization is exact.
        let (x, kernel, y) = toy(40);
        let mut rng = Rng::seed_from(31);
        let xp = random_points(&mut rng, 8, 2);
        let nb: Vec<Vec<u32>> = (0..40).map(|i| (0..i as u32).collect()).collect();
        let z = select_inducing(&x, &kernel, 8, 2, &mut rng, None);
        let s = VifStructure::assemble(&x, &kernel, z, nb, 0.05, 1e-12, 1);
        // predict with FULL conditioning on all training points
        let (mean, var) = predict(
            &s,
            &x,
            &kernel,
            &y,
            &xp,
            40,
            NeighborSelection::EuclideanTransformed,
        );
        // exact GP
        let cov = kernel.sym_cov(&x, 0.05);
        let chol = crate::linalg::CholeskyFactor::new(&cov).unwrap();
        let alpha = chol.solve(&y);
        for p in 0..8 {
            let kxp: Vec<f64> = (0..40).map(|i| kernel.cov(x.row(i), xp.row(p))).collect();
            let mu = dot(&kxp, &alpha);
            let w = chol.solve(&kxp);
            let v = kernel.variance + 0.05 - dot(&kxp, &w);
            assert!((mean[p] - mu).abs() < 1e-5, "mean {p}: {} vs {mu}", mean[p]);
            assert!((var[p] - v).abs() < 1e-5, "var {p}: {} vs {v}", var[p]);
        }
    }

    #[test]
    fn fit_recovers_reasonable_parameters() {
        // Small end-to-end: simulate from known params, fit, check the
        // NLL at the estimate beats the NLL at a perturbed start.
        let (x, kernel, y) = toy(60);
        let config = VifConfig {
            num_inducing: 10,
            num_neighbors: 5,
            selection: NeighborSelection::EuclideanTransformed,
            lloyd_iters: 2,
            ..Default::default()
        };
        let start = GaussianParams {
            kernel: ArdMatern::new(0.5, vec![0.6, 0.2], Smoothness::ThreeHalves),
            noise: 0.2,
        };
        let mut model = VifRegression::new(x.clone(), y.clone(), config, start.clone());
        let final_nll = model.fit(40);
        // NLL at fit should beat NLL at start.
        let nb = model.structure.as_ref().unwrap().resid.neighbors.clone();
        let z = model.inducing.clone();
        let start_nll = model.nll_at(&start.pack(), &nb, z.as_ref());
        assert!(
            final_nll < start_nll - 1.0,
            "fit {final_nll} vs start {start_nll}"
        );
        let _ = kernel;
    }
}

// ---------------------------------------------------------------------
// Non-zero prior mean functions (paper §8.3): linear fixed effects
// F(x) = xᵀβ, profiled out by generalized least squares. By the envelope
// theorem the profile-likelihood gradient with respect to θ equals the
// partial gradient at β̂, so the zero-mean machinery is reused verbatim
// on the residual y − Xβ̂.
// ---------------------------------------------------------------------

/// Generalized-least-squares estimate `β̂ = (XᵀΣ_†⁻¹X)⁻¹ XᵀΣ_†⁻¹ y` for a
/// fixed-effects design matrix `f` (n×p).
pub fn gls_beta(s: &VifStructure, f: &Mat, y: &[f64]) -> Vec<f64> {
    // Σ_†⁻¹ X for all design columns in one blocked application.
    let sx = s.apply_sigma_dagger_inv_batch(f);
    let xtx = f.matmul_tn(&sx); // XᵀΣ⁻¹X (p×p)
    let xty = sx.matvec_t(y); // (Σ⁻¹X)ᵀy
    let jf = crate::linalg::CholeskyFactor::new_with_jitter_tracked(&xtx, 1e-10)
        .unwrap_or_else(|e| {
            panic!("gls_beta: fixed-effects normal equations not PD ({e}); rank-deficient design?")
        });
    crate::iterative::solve_stats().note_jitter(jf.jitter);
    jf.factor.solve(&xty)
}

/// Profile NLL and gradient with linear fixed effects (envelope theorem).
/// Returns `(nll, grad, beta_hat)`.
pub fn nll_and_grad_with_effects(
    s: &VifStructure,
    x: &Mat,
    kernel: &ArdMatern,
    f: &Mat,
    y: &[f64],
) -> (f64, Vec<f64>, Vec<f64>) {
    let beta = gls_beta(s, f, y);
    let resid: Vec<f64> = y
        .iter()
        .enumerate()
        .map(|(i, yi)| yi - dot(f.row(i), &beta))
        .collect();
    let (v, g) = nll_and_grad(s, x, kernel, &resid);
    (v, g, beta)
}

#[cfg(test)]
mod fixed_effects_tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::random_points;

    #[test]
    fn gls_recovers_linear_trend() {
        let mut rng = Rng::seed_from(3);
        let n = 300;
        let x = random_points(&mut rng, n, 2);
        // Small GP variance so the linear trend is identifiable against
        // the prior (a unit-variance GP over [0,1]² absorbs linear terms).
        let kernel = ArdMatern::new(0.1, vec![0.3, 0.3], Smoothness::ThreeHalves);
        let latent = crate::data::simulate_latent_gp(&mut rng, &x, &kernel);
        // design = [1, x1, x2], true beta = [2.0, -1.5, 0.7]
        let f = Mat::from_fn(n, 3, |i, j| if j == 0 { 1.0 } else { x.get(i, j - 1) });
        let beta_true = [2.0, -1.5, 0.7];
        let y: Vec<f64> = (0..n)
            .map(|i| dot(f.row(i), &beta_true) + latent[i] + 0.05 * rng.normal())
            .collect();
        let nb = crate::vif::select_neighbors(
            &x,
            &kernel,
            None,
            6,
            NeighborSelection::EuclideanTransformed,
        );
        let s = VifStructure::assemble(&x, &kernel, None, nb, 0.0025, 1e-10, 1);
        let beta = gls_beta(&s, &f, &y);
        for (b, t) in beta.iter().zip(&beta_true) {
            assert!((b - t).abs() < 0.5, "beta {b} vs {t}");
        }
        // profile gradient matches FD of the profiled objective
        let (_, grad, _) = nll_and_grad_with_effects(&s, &x, &kernel, &f, &y);
        let packed = GaussianParams { kernel: kernel.clone(), noise: 0.0025 }.pack();
        let nbc = s.resid.neighbors.clone();
        let eval = |p: &[f64]| -> f64 {
            let pr = GaussianParams::unpack(p, Smoothness::ThreeHalves);
            let s2 = VifStructure::assemble(&x, &pr.kernel, None, nbc.clone(), pr.noise, 1e-10, 1);
            let b = gls_beta(&s2, &f, &y);
            let r: Vec<f64> = (0..n).map(|i| y[i] - dot(f.row(i), &b)).collect();
            nll(&s2, &r)
        };
        crate::testing::check_gradient(eval, &grad, &packed, 1e-5, 5e-3, 1e-3).unwrap();
    }
}
