//! Deterministic fault injection for chaos testing.
//!
//! The production code calls the tiny hook functions in this module at
//! the points where numerical or operational failures can originate:
//! Cholesky factorization attempts, CG convergence checks, kernel panel
//! evaluation, and the serving engine's dispatch loop. Every hook is a
//! single relaxed atomic load when injection is disarmed, so the hot
//! path pays no measurable cost (perf_hotpath stage 15 asserts this
//! against the stage-14 serving numbers).
//!
//! Faults are armed in one of two ways:
//!
//! * **Environment / CLI** — `VIFGP_FAULTS` (or `vifgp --faults SPEC`)
//!   holds a comma-separated spec, e.g.
//!   `chol_fail_below=1e-8,cg_stall=2,seed=7`. `1`/`on` arms the
//!   machinery with an empty plan (hooks stay no-ops until a test
//!   installs one); `0`/unset disables it. Malformed specs panic — the
//!   crate's loud-failure policy, same as the other `VIFGP_*` knobs.
//! * **Test API** — [`install`] force-enables a [`FaultPlan`] for the
//!   lifetime of the returned [`FaultGuard`] and serializes chaos tests
//!   behind a global lock, so `rust/tests/chaos.rs` is deterministic
//!   regardless of the harness' thread count and also passes under a
//!   plain `cargo test` with `VIFGP_FAULTS` unset.
//!
//! All triggers are deterministic: budgets are decremented in solver
//! call order (one fit / one dispatcher thread), and the serve-request
//! poison is content-based (a sentinel coordinate), so batch bisection
//! always isolates the same request. The plan's `seed` feeds the chaos
//! suite's data generation through the crate's own [`crate::rng`]
//! (xoshiro256++), keeping the whole suite reproducible from one value.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::linalg::Mat;

/// What to break, and how hard. All fields default to "no fault".
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    /// Seed for chaos-test data generation (reported back by
    /// [`active_seed`]); the triggers themselves are counter/content
    /// based and need no randomness.
    pub seed: u64,
    /// Fail every Cholesky attempt whose diagonal jitter is strictly
    /// below this value, forcing the escalation ladder to climb.
    pub chol_fail_below: Option<f64>,
    /// Suppress the CG convergence check for this many `pcg*` calls,
    /// so each affected solve runs to `max_iter` without converging.
    pub cg_stall: Option<u32>,
    /// Poison kernel correlation panels (write NaN) while armed.
    pub nan_panel: bool,
    /// Panic inside the serve batch for any request containing a
    /// coordinate exactly equal to this sentinel value.
    pub serve_poison: Option<f64>,
    /// Sleep this many microseconds at the start of every serve batch.
    pub serve_slow_us: Option<u64>,
    /// Panic the dispatcher loop body for this many batches.
    pub dispatcher_panic: Option<u32>,
}

struct FaultState {
    plan: Mutex<FaultPlan>,
    cg_stall_left: AtomicU32,
    dispatcher_panic_left: AtomicU32,
}

/// Master switch: a single relaxed load of this is the entire cost of
/// every hook when faults are disabled.
static ACTIVE: AtomicBool = AtomicBool::new(false);

static STATE: OnceLock<FaultState> = OnceLock::new();

/// Serializes chaos tests that `install` plans (see [`FaultGuard`]).
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn state() -> &'static FaultState {
    STATE.get_or_init(|| FaultState {
        plan: Mutex::new(FaultPlan::default()),
        cg_stall_left: AtomicU32::new(0),
        dispatcher_panic_left: AtomicU32::new(0),
    })
}

fn lock_plan() -> MutexGuard<'static, FaultPlan> {
    // A panicking hook (that's the point of this module) may poison the
    // plan lock; the plan itself is always in a consistent state.
    state().plan.lock().unwrap_or_else(|e| e.into_inner())
}

fn set_plan(plan: FaultPlan) {
    let st = state();
    st.cg_stall_left.store(plan.cg_stall.unwrap_or(0), Ordering::Relaxed);
    st.dispatcher_panic_left.store(plan.dispatcher_panic.unwrap_or(0), Ordering::Relaxed);
    *lock_plan() = plan;
}

/// Parse a `VIFGP_FAULTS` spec. `""`/`"0"`/`"off"` → disabled; `"1"`/
/// `"on"` → armed with an empty plan; otherwise a comma-separated
/// `key=value` list. Panics on malformed input (loud-failure policy).
fn parse_spec(spec: &str) -> Option<FaultPlan> {
    match spec.trim() {
        "" | "0" | "off" => return None,
        "1" | "on" => return Some(FaultPlan::default()),
        _ => {}
    }
    let mut plan = FaultPlan::default();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (key, val) = part
            .split_once('=')
            .unwrap_or_else(|| panic!("VIFGP_FAULTS: expected key=value, got {part:?}"));
        let bad = |what: &str| -> ! {
            panic!("VIFGP_FAULTS: invalid {what} value {val:?} in {part:?}")
        };
        match key.trim() {
            "seed" => plan.seed = val.parse().unwrap_or_else(|_| bad("integer")),
            "chol_fail_below" => {
                plan.chol_fail_below = Some(val.parse().unwrap_or_else(|_| bad("float")))
            }
            "cg_stall" => plan.cg_stall = Some(val.parse().unwrap_or_else(|_| bad("integer"))),
            "nan_panel" => {
                plan.nan_panel = match val.trim() {
                    "1" | "on" | "true" => true,
                    "0" | "off" | "false" => false,
                    _ => bad("boolean"),
                }
            }
            "serve_poison" => {
                plan.serve_poison = Some(val.parse().unwrap_or_else(|_| bad("float")))
            }
            "serve_slow_us" => {
                plan.serve_slow_us = Some(val.parse().unwrap_or_else(|_| bad("integer")))
            }
            "dispatcher_panic" => {
                plan.dispatcher_panic = Some(val.parse().unwrap_or_else(|_| bad("integer")))
            }
            other => panic!("VIFGP_FAULTS: unknown fault key {other:?}"),
        }
    }
    Some(plan)
}

/// Arm faults from the `VIFGP_FAULTS` environment variable. Called once
/// from the CLI entry point; library users call [`install`] instead.
/// Panics on a malformed spec.
pub fn init_from_env() {
    if let Ok(spec) = std::env::var("VIFGP_FAULTS") {
        if let Some(plan) = parse_spec(&spec) {
            set_plan(plan);
            ACTIVE.store(true, Ordering::Relaxed);
        }
    }
}

/// True when fault injection is armed (env or an active [`FaultGuard`]).
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// The armed plan's seed (0 when disarmed) — chaos tests derive their
/// data RNG from this so the whole suite keys off one value.
pub fn active_seed() -> u64 {
    if !enabled() {
        return 0;
    }
    lock_plan().seed
}

/// Force-enable `plan` for the lifetime of the returned guard. Takes a
/// global lock so concurrently running chaos tests serialize instead of
/// trampling each other's plans.
pub fn install(plan: FaultPlan) -> FaultGuard {
    let lock = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_plan(plan);
    ACTIVE.store(true, Ordering::Relaxed);
    FaultGuard { _lock: lock }
}

/// RAII handle from [`install`]: dropping it disarms all faults and
/// releases the chaos-test lock.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl FaultGuard {
    /// Swap the active plan without releasing the test lock — lets one
    /// chaos test inject, then clear, then assert recovery.
    pub fn set(&self, plan: FaultPlan) {
        set_plan(plan);
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        ACTIVE.store(false, Ordering::Relaxed);
        set_plan(FaultPlan::default());
    }
}

// ---------------------------------------------------------------------
// Hooks — each is one relaxed load when disarmed.
// ---------------------------------------------------------------------

/// Should the Cholesky attempt at diagonal jitter level `jitter` be
/// forced to fail?
#[inline]
pub fn chol_should_fail(jitter: f64) -> bool {
    if !enabled() {
        return false;
    }
    matches!(lock_plan().chol_fail_below, Some(below) if jitter < below)
}

/// Consume one unit of the CG-stall budget. While it returns true the
/// caller must suppress its convergence check so the solve runs to
/// `max_iter` without converging.
#[inline]
pub fn cg_stall_active() -> bool {
    if !enabled() {
        return false;
    }
    let left = &state().cg_stall_left;
    left.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
        .is_ok()
}

/// Poison a freshly computed kernel panel with NaN while armed.
#[inline]
pub fn poison_panel(out: &mut [f64]) {
    if !enabled() {
        return;
    }
    if lock_plan().nan_panel {
        for v in out.iter_mut() {
            *v = f64::NAN;
        }
    }
}

/// Panic if any coordinate of the gathered query batch equals the
/// configured poison sentinel. Called *inside* the serve engine's
/// `catch_unwind` so bisection can quarantine the poisoned request.
#[inline]
pub fn serve_check_poison(xp: &Mat) {
    if !enabled() {
        return;
    }
    if let Some(sentinel) = lock_plan().serve_poison {
        if xp.data().iter().any(|&v| v == sentinel) {
            panic!("injected fault: poisoned serve request (sentinel {sentinel})");
        }
    }
}

/// Sleep the configured per-batch delay (deadline testing).
#[inline]
pub fn serve_delay() {
    if !enabled() {
        return;
    }
    if let Some(us) = lock_plan().serve_slow_us {
        std::thread::sleep(std::time::Duration::from_micros(us));
    }
}

/// Consume one unit of the dispatcher-panic budget; while it returns
/// true the dispatcher loop body panics (outside the per-batch
/// quarantine, to prove the outer recovery net).
#[inline]
pub fn dispatcher_should_panic() -> bool {
    if !enabled() {
        return false;
    }
    let left = &state().dispatcher_panic_left;
    left.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
        .is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_hooks_are_noops() {
        // Not under a guard here — relies on VIFGP_FAULTS being unset in
        // the unit-test environment; `install`-based tests below take
        // the lock.
        let _g = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert!(!enabled());
        assert!(!chol_should_fail(0.0));
        assert!(!cg_stall_active());
        assert!(!dispatcher_should_panic());
        let mut v = [1.0, 2.0];
        poison_panel(&mut v);
        assert_eq!(v, [1.0, 2.0]);
    }

    #[test]
    fn guard_arms_and_disarms() {
        // Only an *empty* plan here: unit tests share the lib test
        // binary with every other suite, and arming a live fault (a CG
        // stall budget, NaN panels) would leak into concurrently
        // running tests. Budget countdown and panel poisoning are
        // asserted in `rust/tests/chaos.rs`, whose tests all hold the
        // install lock. An empty armed plan must leave every hook a
        // no-op.
        let g = install(FaultPlan::default());
        assert!(enabled());
        assert!(!chol_should_fail(0.0));
        assert!(!cg_stall_active());
        assert!(!dispatcher_should_panic());
        let mut v = [1.0, 2.0];
        poison_panel(&mut v);
        assert_eq!(v, [1.0, 2.0]);
        g.set(FaultPlan::default());
        drop(g);
        assert!(!enabled());
    }

    #[test]
    fn spec_parsing_round_trips() {
        assert!(parse_spec("0").is_none());
        assert!(parse_spec("").is_none());
        let plan = parse_spec("1").expect("armed");
        assert!(plan.chol_fail_below.is_none() && plan.cg_stall.is_none());
        let plan = parse_spec("chol_fail_below=1e-8,cg_stall=3,seed=7,nan_panel=on")
            .expect("armed");
        assert_eq!(plan.chol_fail_below, Some(1e-8));
        assert_eq!(plan.cg_stall, Some(3));
        assert_eq!(plan.seed, 7);
        assert!(plan.nan_panel);
    }

    #[test]
    #[should_panic(expected = "unknown fault key")]
    fn spec_parsing_rejects_unknown_keys() {
        parse_spec("frobnicate=1");
    }
}
