//! PJRT runtime: load and execute the AOT HLO artifacts from the Rust
//! request path (Python is build-time only).
//!
//! `make artifacts` lowers the Layer-2 JAX graphs (which embed the
//! Layer-1 Pallas kernel) to HLO text; the `pjrt` feature compiles them
//! on the PJRT CPU client (`xla` crate) and serves covariance panels
//! through [`PjrtCovEngine`]. Shapes are fixed at export: panels are
//! padded to `(panel_n, panel_m, d_pad)` with zero inverse length scales
//! masking unused feature dimensions, and padded rows discarded on
//! readback.
//!
//! The default (offline) build has no `xla`/`anyhow` dependencies: the
//! engine is a stub that always reports "unavailable" and every panel is
//! served by the native Rust kernels. A native fallback also covers
//! shapes the artifacts cannot serve (d > d_pad, general-ν Matérn) when
//! the real engine is present.

use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use crate::kernels::ArdMatern;
use crate::linalg::Mat;

/// Artifact metadata (mirrors python/compile/aot.py's manifest).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub panel_n: usize,
    pub panel_m: usize,
    pub d_pad: usize,
    pub tile_n: usize,
    pub tile_m: usize,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let mut kv = std::collections::HashMap::new();
        for line in text.lines() {
            if let Some((k, v)) = line.split_once('=') {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
        }
        let get = |k: &str| -> Result<usize, String> {
            kv.get(k)
                .ok_or_else(|| format!("manifest missing {k}"))?
                .parse::<usize>()
                .map_err(|e| format!("manifest bad {k}: {e}"))
        };
        Ok(Manifest {
            panel_n: get("panel_n")?,
            panel_m: get("panel_m")?,
            d_pad: get("d_pad")?,
            tile_n: get("tile_n")?,
            tile_m: get("tile_m")?,
        })
    }
}

/// Panels served / fallbacks taken (diagnostics).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub pjrt_panels: u64,
    pub native_panels: u64,
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;
    use crate::kernels::Smoothness;
    use anyhow::{Context, Result};

    struct Executables {
        #[allow(dead_code)] // keeps the PJRT client alive for the executables
        client: xla::PjRtClient,
        cov_cross: std::collections::HashMap<&'static str, xla::PjRtLoadedExecutable>,
    }

    // SAFETY: the xla crate's client/executable handles are `Rc`-based and
    // hence `!Send`, but every access in this module happens under the
    // `Mutex` in `PjrtCovEngine` and no handle is ever cloned out of the
    // guard, so at most one thread touches them at any time.
    unsafe impl Send for Executables {}

    /// The PJRT-backed covariance engine.
    pub struct PjrtCovEngine {
        manifest: Manifest,
        // PJRT executables are not Sync; guard with a mutex (the panel calls
        // are coarse enough that contention is negligible).
        exe: Mutex<Executables>,
        /// Panels served / fallbacks taken (diagnostics).
        pub stats: Mutex<EngineStats>,
    }

    fn smoothness_key(s: Smoothness) -> Option<&'static str> {
        match s {
            Smoothness::Half => Some("half"),
            Smoothness::ThreeHalves => Some("three_halves"),
            Smoothness::FiveHalves => Some("five_halves"),
            Smoothness::Gaussian => Some("gaussian"),
            Smoothness::General(_) => None,
        }
    }

    impl PjrtCovEngine {
        /// Load all artifacts from a directory (errors if any is missing).
        pub fn load(dir: &Path) -> Result<Self> {
            let manifest_text = std::fs::read_to_string(dir.join("manifest.txt"))
                .with_context(|| format!("no manifest in {dir:?} — run `make artifacts`"))?;
            let manifest = Manifest::parse(&manifest_text).map_err(anyhow::Error::msg)?;
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let mut cov_cross = std::collections::HashMap::new();
            for key in ["half", "three_halves", "five_halves", "gaussian"] {
                let path = dir.join(format!("cov_cross_{key}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("utf8 path")?,
                )
                .with_context(|| format!("parse {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp).with_context(|| format!("compile {key}"))?;
                cov_cross.insert(
                    match key {
                        "half" => "half",
                        "three_halves" => "three_halves",
                        "five_halves" => "five_halves",
                        _ => "gaussian",
                    },
                    exe,
                );
            }
            Ok(PjrtCovEngine {
                manifest,
                exe: Mutex::new(Executables { client, cov_cross }),
                stats: Mutex::new(EngineStats::default()),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Whether this engine can serve the kernel (dimension and smoothness).
        pub fn supports(&self, kernel: &ArdMatern) -> bool {
            kernel.dim() <= self.manifest.d_pad && smoothness_key(kernel.smoothness).is_some()
        }

        /// One padded panel execution: cross-covariance of up to
        /// (panel_n × panel_m) points.
        fn run_panel(
            &self,
            xs_pad: &[f64],
            zs_pad: &[f64],
            variance: f64,
            key: &'static str,
        ) -> Result<Vec<f64>> {
            let mf = &self.manifest;
            let guard = self.exe.lock().unwrap();
            let xs = xla::Literal::vec1(xs_pad)
                .reshape(&[mf.panel_n as i64, mf.d_pad as i64])?;
            let zs = xla::Literal::vec1(zs_pad)
                .reshape(&[mf.panel_m as i64, mf.d_pad as i64])?;
            let var = xla::Literal::vec1(&[variance]).reshape(&[1, 1])?;
            let exe = guard.cov_cross.get(key).context("missing executable")?;
            let result = exe.execute::<xla::Literal>(&[xs, zs, var])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f64>()?)
        }

        /// Cross-covariance panel `K(X, Z)` (n×m) through the artifacts,
        /// tiling over the fixed panel shape.
        pub fn cross_cov(&self, x: &Mat, z: &Mat, kernel: &ArdMatern) -> Result<Mat> {
            let key = smoothness_key(kernel.smoothness).context("unsupported smoothness")?;
            let mf = &self.manifest;
            anyhow::ensure!(kernel.dim() <= mf.d_pad, "d > d_pad");
            let (n, m) = (x.rows(), z.rows());
            let inv_ls: Vec<f64> = kernel.length_scales.iter().map(|l| 1.0 / l).collect();
            let mut out = Mat::zeros(n, m);
            let pad_points = |pts: &Mat, lo: usize, hi: usize, rows: usize| -> Vec<f64> {
                let mut buf = vec![0.0; rows * mf.d_pad];
                for (r, i) in (lo..hi).enumerate() {
                    for (k, &il) in inv_ls.iter().enumerate() {
                        buf[r * mf.d_pad + k] = pts.get(i, k) * il;
                    }
                }
                buf
            };
            let mut row0 = 0;
            while row0 < n {
                let row1 = (row0 + mf.panel_n).min(n);
                let xs_pad = pad_points(x, row0, row1, mf.panel_n);
                let mut col0 = 0;
                while col0 < m {
                    let col1 = (col0 + mf.panel_m).min(m);
                    let zs_pad = pad_points(z, col0, col1, mf.panel_m);
                    let panel = self.run_panel(&xs_pad, &zs_pad, kernel.variance, key)?;
                    for (r, i) in (row0..row1).enumerate() {
                        for (c, j) in (col0..col1).enumerate() {
                            out.set(i, j, panel[r * mf.panel_m + c]);
                        }
                    }
                    self.stats.lock().unwrap().pjrt_panels += 1;
                    col0 = col1;
                }
                row0 = row1;
            }
            Ok(out)
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtCovEngine;

/// Stub engine for builds without the `pjrt` feature: never loads, never
/// serves a panel. Keeps the public surface (and its consumers in the
/// benches/examples/tests) compiling in the offline registry.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtCovEngine {
    manifest: Manifest,
    /// Panels served / fallbacks taken (diagnostics).
    pub stats: Mutex<EngineStats>,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtCovEngine {
    /// Always errors: this build has no PJRT client.
    pub fn load(_dir: &Path) -> Result<Self, String> {
        Err("built without the `pjrt` feature; native covariance path only".to_string())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn supports(&self, _kernel: &ArdMatern) -> bool {
        false
    }

    /// Native fallback so call sites remain functional if an engine value
    /// is ever constructed (it is not, in this build).
    pub fn cross_cov(&self, x: &Mat, z: &Mat, kernel: &ArdMatern) -> Result<Mat, String> {
        self.stats.lock().unwrap().native_panels += 1;
        Ok(kernel.cross_cov(x, z))
    }
}

/// Global engine, installed once at process start (CLI / examples call
/// [`init_from_artifacts`]); covariance panel builders consult it.
static ENGINE: OnceLock<Option<PjrtCovEngine>> = OnceLock::new();

/// Install the PJRT engine from an artifact directory. Returns whether
/// artifacts were found and compiled. Safe to call more than once.
pub fn init_from_artifacts(dir: &Path) -> bool {
    ENGINE
        .get_or_init(|| match PjrtCovEngine::load(dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!(
                    "[runtime] PJRT engine unavailable ({err:#}); using native covariance path"
                );
                None
            }
        })
        .is_some()
}

/// Disable the engine explicitly (tests / benchmarking native path).
pub fn init_native_only() {
    let _ = ENGINE.set(None);
}

pub fn engine() -> Option<&'static PjrtCovEngine> {
    ENGINE.get().and_then(|e| e.as_ref())
}

/// Default artifact directory: `$VIFGP_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("VIFGP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Cross-covariance panel through the engine when available + supported,
/// else the native Rust path. This is the single entry point the VIF
/// structure uses for its low-rank panels.
pub fn cross_cov_panel(x: &Mat, z: &Mat, kernel: &ArdMatern) -> Mat {
    let mut out = Mat::zeros(x.rows(), z.rows());
    cross_cov_panel_into(x, z, kernel, &mut out);
    out
}

/// [`cross_cov_panel`] writing into a preallocated `n × m` output — the
/// θ-refresh path reuses the `Σ_mn` panel buffer across optimizer steps.
/// Engine-served panels are copied into `out`; the native path fills it
/// directly via `ArdMatern::cross_cov_into`, which routes row-wise
/// through the panel primitives and so inherits the CPU lane-backend
/// dispatch (`VIFGP_SIMD`; see the `kernels` module docs).
pub fn cross_cov_panel_into(x: &Mat, z: &Mat, kernel: &ArdMatern, out: &mut Mat) {
    assert_eq!(out.rows(), x.rows(), "cross_cov_panel_into row mismatch");
    assert_eq!(out.cols(), z.rows(), "cross_cov_panel_into col mismatch");
    if let Some(engine) = engine() {
        if engine.supports(kernel) {
            match engine.cross_cov(x, z, kernel) {
                Ok(panel) => {
                    out.data_mut().copy_from_slice(panel.data());
                    return;
                }
                Err(err) => {
                    eprintln!("[runtime] PJRT panel failed ({err:#}); native fallback");
                }
            }
        }
        engine.stats.lock().unwrap().native_panels += 1;
    }
    kernel.cross_cov_into(x, z, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            "panel_n=512\npanel_m=256\nd_pad=8\ntile_n=128\ntile_m=128\ndtype=f64\n",
        )
        .unwrap();
        assert_eq!(m.panel_n, 512);
        assert_eq!(m.d_pad, 8);
    }

    #[test]
    fn manifest_missing_key_errors() {
        assert!(Manifest::parse("panel_n=512\n").is_err());
    }

    // PJRT round-trip tests live in rust/tests/pjrt_roundtrip.rs (they
    // need built artifacts and the `pjrt` feature).
}
