//! Simulation-based predictive-variance estimators (paper §4.2):
//! Algorithm 1 (SBPV) and Algorithm 2 (SPV). Both estimate the diagonal
//! of the stochastic correction term (21); the deterministic part (20)
//! is computed in closed form by the prediction code.
//!
//! The ℓ probe systems share one operator, so both estimators consume
//! *batched* solve **and projection** closures over column-blocked `Mat`
//! operands: the solves route through the batched PCG engine
//! (`iterative::batch`) as one multi-RHS system per probe block, and the
//! `Q`/`Qᵀ` projections route through the batched prediction pipeline
//! (`vif::predict::{project_q_batch, project_qt_batch}`) so no
//! per-column matvecs or triangular sweeps remain on the probe path.
//! Probe draws stay sequential on the caller's RNG so probe streams
//! match the scalar implementations.
//!
//! Failure containment lives one layer down: the `solve_batch` closures
//! the VIF models pass in are backed by
//! [`crate::vif::laplace::WSolver::solve_batch`], whose escalation
//! ladder (retry with a raised budget, then dense fallback below the
//! size cutoff) runs per column — a CG breakdown in one probe column is
//! recovered or replaced there, so the estimators here always average
//! finite probe contributions (see the crate-root "Failure semantics"
//! section).

use crate::linalg::Mat;
use crate::rng::Rng;

/// Column-block width for the probe batches (bounds working-set memory).
const PROBE_BLOCK: usize = 64;

/// Algorithm 1 (SBPV): the correction matrix is `Q A⁻¹ Qᵀ` with
/// `A = Σ_†⁻¹ + W`; sampling `z₆ ~ N(0, A)` gives
/// `z₈ = Q A⁻¹ z₆ ~ N(0, Q A⁻¹ Qᵀ)`, so `(1/ℓ) Σ z₈ ∘ z₈` is an
/// unbiased, consistent estimator of its diagonal (Proposition 4.1).
///
/// * `sample_z6` draws one `z₆ ~ N(0, Σ_†⁻¹ + W)` (lines 3–6),
/// * `solve_batch` computes `A⁻¹ Z₆` for a column block (line 7,
///   batched preconditioned CG),
/// * `project_batch` applies `Q = (Σ_mn_pᵀΣ_m⁻¹Σ_mn − B_p⁻¹B_po S⁻¹) Σ_†⁻¹`
///   (line 8) to the whole solved column block at once, returning an
///   `n_p × width` block — the VIF models route this through the
///   batched projections of `vif::predict` (one GEMM + one
///   level-scheduled sparse sweep per block instead of per-column
///   matvecs and triangular solves).
pub fn sbpv_diag(
    ell: usize,
    n_p: usize,
    rng: &mut Rng,
    mut sample_z6: impl FnMut(&mut Rng) -> Vec<f64>,
    solve_batch: impl Fn(&Mat) -> Mat,
    project_batch: impl Fn(&Mat) -> Mat,
) -> Vec<f64> {
    let mut acc = vec![0.0; n_p];
    let mut done = 0;
    while done < ell {
        let width = (ell - done).min(PROBE_BLOCK);
        let z6: Vec<Vec<f64>> = (0..width).map(|_| sample_z6(rng)).collect();
        let n = z6[0].len();
        let zmat = Mat::from_fn(n, width, |i, j| z6[j][i]);
        let z7 = solve_batch(&zmat);
        let z8 = project_batch(&z7);
        debug_assert_eq!(z8.rows(), n_p);
        debug_assert_eq!(z8.cols(), width);
        for j in 0..width {
            for (i, a) in acc.iter_mut().enumerate() {
                let z = z8.get(i, j);
                *a += z * z;
            }
        }
        done += width;
    }
    for a in acc.iter_mut() {
        *a /= ell as f64;
    }
    acc
}

/// Algorithm 2 (SPV): Bekas-style diagonal estimator
/// `diag(C) ≈ (1/ℓ) Σ z ∘ (C z)` with Rademacher probes `z ∈ {±1}^{n_p}`
/// (Proposition 4.2). `apply_c_batch` applies the full correction matrix
/// `Q A⁻¹ Qᵀ` to a column block of `n_p` probes.
pub fn spv_diag(
    ell: usize,
    n_p: usize,
    rng: &mut Rng,
    apply_c_batch: impl Fn(&Mat) -> Mat,
) -> Vec<f64> {
    let mut acc = vec![0.0; n_p];
    let mut done = 0;
    while done < ell {
        let width = (ell - done).min(PROBE_BLOCK);
        let zs: Vec<Vec<f64>> = (0..width).map(|_| rng.rademacher_vec(n_p)).collect();
        let zmat = Mat::from_fn(n_p, width, |i, j| zs[j][i]);
        let cz = apply_c_batch(&zmat);
        for (j, z) in zs.iter().enumerate() {
            for (i, zi) in z.iter().enumerate() {
                acc[i] += zi * cz.get(i, j);
            }
        }
        done += width;
    }
    for a in acc.iter_mut() {
        *a /= ell as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::batch::map_columns;
    use crate::linalg::{CholeskyFactor, Mat};

    #[test]
    fn spv_estimates_diagonal() {
        // C = G Gᵀ + I, apply C exactly; SPV should recover its diagonal.
        let n = 30;
        let g = Mat::from_fn(n, n, |i, j| ((i + 2 * j) as f64 * 0.21).sin() * 0.3);
        let mut c = g.matmul_nt(&g);
        c.add_diag(1.0);
        let mut rng = Rng::seed_from(5);
        let est = spv_diag(4000, n, &mut rng, |z| c.matmul(z));
        for i in 0..n {
            assert!(
                (est[i] - c.get(i, i)).abs() < 0.1 * c.get(i, i),
                "i={i}: {} vs {}",
                est[i],
                c.get(i, i)
            );
        }
    }

    #[test]
    fn sbpv_estimates_diagonal_of_projected_inverse() {
        // A SPD, Q a short fat matrix: estimate diag(Q A⁻¹ Qᵀ).
        let n = 20;
        let n_p = 7;
        let gmat = Mat::from_fn(n, n, |i, j| ((i * 5 + j) as f64).cos() * 0.2);
        let mut a = gmat.matmul_nt(&gmat);
        a.add_diag(1.5);
        let chol = CholeskyFactor::new(&a).unwrap();
        let q = Mat::from_fn(n_p, n, |i, j| ((i + j) as f64 * 0.4).sin());
        // exact diag
        let exact: Vec<f64> = (0..n_p)
            .map(|p| {
                let w = chol.solve(q.row(p));
                crate::linalg::dot(q.row(p), &w)
            })
            .collect();
        let mut rng = Rng::seed_from(3);
        let est = sbpv_diag(
            5000,
            n_p,
            &mut rng,
            |rng| chol.mul_lower(&rng.normal_vec(n)), // z ~ N(0, A)
            |z| map_columns(z, |col| chol.solve(col)),
            |z| q.matmul(z),
        );
        for p in 0..n_p {
            assert!(
                (est[p] - exact[p]).abs() < 0.12 * exact[p].max(0.05),
                "p={p}: {} vs {}",
                est[p],
                exact[p]
            );
        }
    }
}
