//! Iterative methods for VIF-Laplace approximations (paper §4):
//! preconditioned conjugate gradients with Lanczos-coefficient recovery,
//! stochastic Lanczos quadrature for log-determinants, stochastic trace
//! estimation for gradients, the VIFDU and FITC preconditioners, and the
//! simulation-based predictive-variance estimators (Algorithms 1–2).
//!
//! # Batch API
//!
//! Everything that fans a shared operator out over many right-hand sides
//! — the ℓ SLQ probes of [`slq_logdet`], the SBPV/SPV variance probes,
//! and the fused gradient/trace solves of the likelihood drivers — goes
//! through the batched engine in [`mod@batch`]:
//!
//! * [`LinOp::apply_batch`] / [`Preconditioner::solve_batch`] take a
//!   column-blocked `Mat` (n×k, one system per column). Defaults map the
//!   scalar `apply`/`solve` over columns; the VIF operators and both
//!   preconditioners override them with fused blocked applications whose
//!   m×m Cholesky cores hit all columns in one `solve_mat`/`matmul`.
//! * [`pcg_batch`] / [`pcg_batch_with_min`] run k CG recurrences in
//!   lockstep with per-column stopping and per-column Lanczos
//!   tridiagonals — semantics identical to k sequential [`pcg`] solves.
//!
//! **When to use which parallelism:** *column blocking* amortizes one
//! operator traversal across the k systems of a single batch (SIMD-wide
//! inner loops, shared m×m factorizations) and is always on inside
//! [`pcg_batch`]. *Probe-level threading* splits a column block into
//! per-worker chunks on the process-wide
//! [`ThreadPool`](crate::coordinator::ThreadPool); it applies whenever
//! chunks are independent — which every multi-RHS solve here is — and
//! composes with column blocking (chunks are themselves column blocks).
//! Independent *batches* (different `W`, different operators) can
//! additionally be fanned out on the same pool by the caller.

pub mod batch;
mod cg;
pub mod diag;
mod precond;
mod pred_var;
pub mod slq;

pub use batch::{
    apply_chunked, map_columns, pcg_batch, pcg_batch_with_min, pcg_batch_with_min_from,
    solve_chunked, BatchCgResult, BatchColumnResult,
};
pub use cg::{
    pcg, pcg_with_min, pcg_with_min_from, CgResult, IdentityPrecond, LinOp, Preconditioner,
};
pub use diag::{solve_stats, SolveDiag, SolveFailure, SolveStats, SolveStatsReport};
pub use precond::{FitcPrecond, PrecondType, VifduPrecond};
pub use pred_var::{sbpv_diag, spv_diag};
pub use slq::{slq_logdet, slq_logdet_opts, SlqOptions, SlqProbe, SlqRun};

/// Configuration of the iterative solvers (paper defaults: δ = 0.01,
/// ℓ = 50 SLQ probes, FITC preconditioner with k = 200).
#[derive(Clone, Debug)]
pub struct IterConfig {
    pub precond: PrecondType,
    /// Probe vectors ℓ for SLQ / STE.
    pub ell: usize,
    /// Relative CG convergence tolerance δ.
    pub cg_tol: f64,
    /// Max CG iterations per solve.
    pub max_cg: usize,
    /// FITC-preconditioner rank k.
    pub fitc_k: usize,
    /// Minimum CG iterations per SLQ probe (Lanczos degree floor; see
    /// [`SlqOptions::min_iter`]).
    pub slq_min_iter: usize,
    pub seed: u64,
}

impl Default for IterConfig {
    fn default() -> Self {
        IterConfig {
            precond: PrecondType::Fitc,
            ell: 50,
            cg_tol: 1e-2,
            max_cg: 1000,
            fitc_k: 200,
            slq_min_iter: 25,
            seed: 1234,
        }
    }
}

impl IterConfig {
    /// The [`SlqOptions`] this configuration implies.
    pub fn slq_options(&self) -> SlqOptions {
        SlqOptions { min_iter: self.slq_min_iter, ..SlqOptions::default() }
    }
}
