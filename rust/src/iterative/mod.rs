//! Iterative methods for VIF-Laplace approximations (paper §4):
//! preconditioned conjugate gradients with Lanczos-coefficient recovery,
//! stochastic Lanczos quadrature for log-determinants, stochastic trace
//! estimation for gradients, the VIFDU and FITC preconditioners, and the
//! simulation-based predictive-variance estimators (Algorithms 1–2).

mod cg;
mod precond;
mod pred_var;
pub mod slq;

pub use cg::{pcg, pcg_with_min, CgResult, IdentityPrecond, LinOp, Preconditioner};
pub use precond::{FitcPrecond, PrecondType, VifduPrecond};
pub use pred_var::{sbpv_diag, spv_diag};
pub use slq::{slq_logdet, SlqProbe, SlqRun};

/// Configuration of the iterative solvers (paper defaults: δ = 0.01,
/// ℓ = 50 SLQ probes, FITC preconditioner with k = 200).
#[derive(Clone, Debug)]
pub struct IterConfig {
    pub precond: PrecondType,
    /// Probe vectors ℓ for SLQ / STE.
    pub ell: usize,
    /// Relative CG convergence tolerance δ.
    pub cg_tol: f64,
    /// Max CG iterations per solve.
    pub max_cg: usize,
    /// FITC-preconditioner rank k.
    pub fitc_k: usize,
    pub seed: u64,
}

impl Default for IterConfig {
    fn default() -> Self {
        IterConfig {
            precond: PrecondType::Fitc,
            ell: 50,
            cg_tol: 1e-2,
            max_cg: 1000,
            fitc_k: 200,
            seed: 1234,
        }
    }
}
