//! Preconditioned conjugate gradients with Lanczos tridiagonal recovery.
//!
//! Following Gardner et al. (2018) / Saad (2003) §6.7.3, the CG step
//! sizes α_k and direction coefficients β_k reconstruct the tridiagonal
//! matrix of the Lanczos process on `P^{-1/2} A P^{-1/2}` started at
//! `P^{-1/2} b / ‖·‖`, so SLQ log-determinants come for free from the
//! same solves (paper §4.1).

use crate::linalg::{dot, Mat, SymTridiag};
use crate::rng::Rng;

/// A symmetric positive definite linear operator.
pub trait LinOp: Sync {
    fn n(&self) -> usize;
    fn apply(&self, v: &[f64]) -> Vec<f64>;

    /// `A V` for a column-blocked RHS matrix `V` (n×k, one system per
    /// column). The default maps [`apply`](Self::apply) over the columns
    /// through the shared worker pool; structured operators override this
    /// with fused blocked applications (see `iterative::batch`).
    fn apply_batch(&self, v: &Mat) -> Mat {
        super::batch::map_columns(v, |col| self.apply(col))
    }
}

/// A symmetric positive definite preconditioner `P`.
pub trait Preconditioner: Sync {
    fn n(&self) -> usize;
    /// `P⁻¹ v`.
    fn solve(&self, v: &[f64]) -> Vec<f64>;
    /// Draw `z ~ N(0, P)`.
    fn sample(&self, rng: &mut Rng) -> Vec<f64>;
    /// `log det P`.
    fn logdet(&self) -> f64;

    /// `P⁻¹ V` for a column-blocked RHS matrix `V` (n×k). The default
    /// maps [`solve`](Self::solve) over the columns through the shared
    /// worker pool; the structured preconditioners override this so their
    /// m×m Cholesky cores are applied to all columns at once.
    fn solve_batch(&self, v: &Mat) -> Mat {
        super::batch::map_columns(v, |col| self.solve(col))
    }
}

/// Identity preconditioner (plain CG).
pub struct IdentityPrecond(pub usize);

impl Preconditioner for IdentityPrecond {
    fn n(&self) -> usize {
        self.0
    }
    fn solve(&self, v: &[f64]) -> Vec<f64> {
        v.to_vec()
    }
    fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        rng.normal_vec(self.0)
    }
    fn logdet(&self) -> f64 {
        0.0
    }
}

/// Output of a PCG solve.
pub struct CgResult {
    pub x: Vec<f64>,
    pub iters: usize,
    pub converged: bool,
    /// The solve hit the `pᵀAp ≤ 0` exit: the operator is numerically
    /// indefinite and `x` is best-effort only (distinct from ordinary
    /// max-iteration non-convergence).
    pub breakdown: bool,
    /// Lanczos tridiagonal of the preconditioned operator (if requested).
    pub tridiag: Option<SymTridiag>,
}

impl CgResult {
    /// Classify this solve per the crate failure taxonomy (severity:
    /// non-finite > breakdown > max-iter).
    pub fn diag(&self) -> super::diag::SolveDiag {
        use super::diag::{SolveDiag, SolveFailure};
        let failure = if self.x.iter().any(|v| !v.is_finite()) {
            Some(SolveFailure::NonFinite)
        } else if self.breakdown {
            Some(SolveFailure::Breakdown)
        } else if !self.converged {
            Some(SolveFailure::MaxIter)
        } else {
            None
        };
        SolveDiag { failure, iters: self.iters, ..Default::default() }
    }
}

/// Solve `A x = b` by preconditioned CG. `tol` is relative to `‖b‖`.
pub fn pcg(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    b: &[f64],
    tol: f64,
    max_iter: usize,
    want_tridiag: bool,
) -> CgResult {
    pcg_with_min(op, pre, b, tol, 0, max_iter, want_tridiag)
}

/// [`pcg`] with a minimum iteration count: SLQ probes keep iterating past
/// convergence so the recovered Lanczos tridiagonal has enough degree for
/// an unbiased log-determinant quadrature (a loose CG tolerance otherwise
/// biases Eq. 18/19 — see EXPERIMENTS.md §Fig 4 note).
pub fn pcg_with_min(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    b: &[f64],
    tol: f64,
    min_iter: usize,
    max_iter: usize,
    want_tridiag: bool,
) -> CgResult {
    pcg_with_min_from(op, pre, b, None, tol, min_iter, max_iter, want_tridiag)
}

/// [`pcg_with_min`] with an optional initial guess `x0` (warm start).
///
/// With `x0 = None` the iteration is byte-identical to the historical
/// cold start (`x = 0`, `r = b`); with `x0 = Some(g)` it starts from
/// `x = g`, `r = b − A g`, so a guess near the solution converges in a
/// handful of iterations. The convergence test stays relative to `‖b‖`
/// (not the initial residual), so warm and cold solves stop at the same
/// absolute accuracy. Warm starts are rejected for `want_tridiag` solves:
/// the Lanczos recovery (Eq. 18/19 quadrature) is only valid for the
/// Krylov recurrence seeded at `P^{-1/2} b`, so SLQ probes must stay cold.
#[allow(clippy::too_many_arguments)]
pub fn pcg_with_min_from(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    b: &[f64],
    x0: Option<&[f64]>,
    tol: f64,
    min_iter: usize,
    max_iter: usize,
    want_tridiag: bool,
) -> CgResult {
    let n = b.len();
    assert_eq!(op.n(), n);
    assert!(
        x0.is_none() || !want_tridiag,
        "warm-started PCG cannot recover a Lanczos tridiagonal: \
         SLQ probe solves must use a cold start"
    );
    let (mut x, mut r) = match x0 {
        None => (vec![0.0; n], b.to_vec()),
        Some(g) => {
            assert_eq!(g.len(), n, "initial guess length {} != system size {n}", g.len());
            let ag = op.apply(g);
            let r: Vec<f64> = b.iter().zip(&ag).map(|(bi, ai)| bi - ai).collect();
            (g.to_vec(), r)
        }
    };
    let mut z = pre.solve(&r);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let b_norm = dot(b, b).sqrt().max(1e-300);
    let mut alphas: Vec<f64> = Vec::new();
    let mut betas: Vec<f64> = Vec::new();
    let mut converged = false;
    let mut breakdown = false;
    let mut iters = 0;
    // Fault injection: a stalled solve suppresses its convergence check
    // and runs to max_iter (budget consumed per pcg call).
    let stall = crate::faults::cg_stall_active();
    // A warm guess may already satisfy the tolerance; without this check
    // the r = 0 start would hit the pᵀAp ≤ 0 exit and flag a spurious
    // breakdown. Warm-only, so the cold path stays byte-identical.
    if x0.is_some() && !stall && min_iter == 0 && dot(&r, &r).sqrt() <= tol * b_norm {
        converged = true;
        super::diag::solve_stats().note_cg_iters(0);
        return CgResult { x, iters, converged, breakdown, tridiag: None };
    }

    for _ in 0..max_iter {
        let ap = op.apply(&p);
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            breakdown = true;
            break; // loss of positive definiteness — return best effort
        }
        let alpha = rz / pap;
        alphas.push(alpha);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        iters += 1;
        if !stall && iters >= min_iter && dot(&r, &r).sqrt() <= tol * b_norm {
            converged = true;
            break;
        }
        z = pre.solve(&r);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        betas.push(beta);
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }

    let tridiag = if want_tridiag {
        lanczos_tridiag_from_cg(&alphas, &betas)
    } else {
        None
    };

    super::diag::solve_stats().note_cg_iters(iters as u64);
    CgResult { x, iters, converged, breakdown, tridiag }
}

/// Reconstruct the Lanczos tridiagonal of the preconditioned operator
/// from CG step sizes and direction coefficients:
/// `T_kk = 1/α_k + β_{k-1}/α_{k-1}`, `T_{k,k+1} = sqrt(β_k)/α_k`.
/// Returns `None` when no iteration completed. Shared by the scalar and
/// batched PCG paths so their SLQ semantics are identical.
pub(crate) fn lanczos_tridiag_from_cg(alphas: &[f64], betas: &[f64]) -> Option<SymTridiag> {
    if alphas.is_empty() {
        return None;
    }
    let k = alphas.len();
    let mut d = Vec::with_capacity(k);
    let mut e = Vec::with_capacity(k.saturating_sub(1));
    for i in 0..k {
        let mut di = 1.0 / alphas[i];
        if i > 0 {
            di += betas[i - 1] / alphas[i - 1];
        }
        d.push(di);
        if i + 1 < k {
            e.push(betas[i].max(0.0).sqrt() / alphas[i]);
        }
    }
    Some(SymTridiag::new(d, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CholeskyFactor, Mat};

    struct DenseOp(Mat);
    impl LinOp for DenseOp {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn apply(&self, v: &[f64]) -> Vec<f64> {
            self.0.matvec(v)
        }
    }

    struct JacobiPrecond(Vec<f64>);
    impl Preconditioner for JacobiPrecond {
        fn n(&self) -> usize {
            self.0.len()
        }
        fn solve(&self, v: &[f64]) -> Vec<f64> {
            v.iter().zip(&self.0).map(|(x, d)| x / d).collect()
        }
        fn sample(&self, rng: &mut Rng) -> Vec<f64> {
            self.0.iter().map(|d| rng.normal() * d.sqrt()).collect()
        }
        fn logdet(&self) -> f64 {
            self.0.iter().map(|d| d.ln()).sum()
        }
    }

    fn spd(n: usize) -> Mat {
        let g = Mat::from_fn(n, n, |i, j| ((i * 13 + j * 7) as f64).sin());
        let mut a = g.matmul_nt(&g);
        a.add_diag(n as f64 * 0.5);
        a
    }

    #[test]
    fn plain_cg_solves() {
        let a = spd(30);
        let b: Vec<f64> = (0..30).map(|i| (i as f64 * 0.3).cos()).collect();
        let res = pcg(&DenseOp(a.clone()), &IdentityPrecond(30), &b, 1e-10, 200, false);
        assert!(res.converged);
        let want = CholeskyFactor::new(&a).unwrap().solve(&b);
        for (g, w) in res.x.iter().zip(&want) {
            assert!((g - w).abs() < 1e-7);
        }
    }

    #[test]
    fn jacobi_preconditioning_helps_ill_conditioned_system() {
        // Strongly scaled diagonal: Jacobi preconditioner fixes it.
        let n = 40;
        let mut a = spd(n);
        for i in 0..n {
            let s = 10.0f64.powi((i % 5) as i32);
            for j in 0..n {
                let v = a.get(i, j) * s.sqrt();
                a.set(i, j, v);
                let v = a.get(j, i) * s.sqrt();
                a.set(j, i, v);
            }
        }
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let plain = pcg(&DenseOp(a.clone()), &IdentityPrecond(n), &b, 1e-9, 500, false);
        let jac = pcg(
            &DenseOp(a.clone()),
            &JacobiPrecond(a.diag()),
            &b,
            1e-9,
            500,
            false,
        );
        assert!(jac.converged);
        assert!(jac.iters <= plain.iters, "jacobi {} vs plain {}", jac.iters, plain.iters);
    }

    #[test]
    fn lanczos_recovery_reproduces_quadratic_form() {
        // e1ᵀ f(T) e1 scaled by ‖P^{-1/2}b‖² estimates bᵀP^{-1/2}f(Ã)P^{-1/2}b.
        // With P=I and f=inverse: ‖b‖²·e1ᵀT⁻¹e1 should equal bᵀA⁻¹b.
        let a = spd(25);
        let b: Vec<f64> = (0..25).map(|i| 1.0 + (i as f64 * 0.11).sin()).collect();
        let res = pcg(&DenseOp(a.clone()), &IdentityPrecond(25), &b, 1e-12, 100, true);
        let t = res.tridiag.unwrap();
        let quad = t.quadrature(|lam| 1.0 / lam) * dot(&b, &b);
        let want = dot(&b, &CholeskyFactor::new(&a).unwrap().solve(&b));
        assert!(
            (quad - want).abs() < 1e-6 * want.abs(),
            "{quad} vs {want}"
        );
    }

    #[test]
    fn indefinite_operator_reports_breakdown() {
        // A has a negative eigenvalue, so some CG direction hits
        // pᵀAp ≤ 0: the solve must flag breakdown (not plain max-iter)
        // and still return finite best-effort iterates.
        let n = 12;
        let a = Mat::from_fn(n, n, |i, j| {
            if i == j {
                if i == n - 1 {
                    -3.0
                } else {
                    1.0 + i as f64 * 0.1
                }
            } else {
                0.0
            }
        });
        let b = vec![1.0; n];
        let res = pcg(&DenseOp(a), &IdentityPrecond(n), &b, 1e-10, 100, false);
        assert!(res.breakdown, "indefinite operator must report breakdown");
        assert!(!res.converged);
        assert!(res.x.iter().all(|v| v.is_finite()));
        assert_eq!(
            res.diag().failure,
            Some(crate::iterative::SolveFailure::Breakdown)
        );

        // A healthy SPD solve reports neither breakdown nor failure.
        let res = pcg(&DenseOp(spd(12)), &IdentityPrecond(12), &b, 1e-10, 200, false);
        assert!(!res.breakdown && res.converged);
        assert!(res.diag().failure.is_none());
    }

    #[test]
    fn zero_guess_is_bitwise_identical_to_cold_start() {
        // x0 = Some(zeros) must reproduce the cold path exactly: A·0 = 0
        // in floating point, so the initial residual is b either way.
        let a = spd(30);
        let b: Vec<f64> = (0..30).map(|i| (i as f64 * 0.3).cos()).collect();
        let zeros = vec![0.0; 30];
        let cold = pcg(&DenseOp(a.clone()), &JacobiPrecond(a.diag()), &b, 1e-10, 200, false);
        let warm = pcg_with_min_from(
            &DenseOp(a.clone()),
            &JacobiPrecond(a.diag()),
            &b,
            Some(&zeros),
            1e-10,
            0,
            200,
            false,
        );
        assert_eq!(cold.iters, warm.iters);
        assert_eq!(cold.converged, warm.converged);
        for (c, w) in cold.x.iter().zip(&warm.x) {
            assert_eq!(c.to_bits(), w.to_bits(), "{c} vs {w}");
        }
    }

    #[test]
    fn exact_guess_converges_without_iterating() {
        let a = spd(25);
        let b: Vec<f64> = (0..25).map(|i| (i as f64 * 0.17).sin()).collect();
        let want = CholeskyFactor::new(&a).unwrap().solve(&b);
        let res = pcg_with_min_from(
            &DenseOp(a.clone()),
            &IdentityPrecond(25),
            &b,
            Some(&want),
            1e-8,
            0,
            200,
            false,
        );
        assert!(res.converged && !res.breakdown);
        assert_eq!(res.iters, 0, "an exact guess must short-circuit");
        for (g, w) in res.x.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        // A merely-close guess converges in far fewer iterations than cold.
        let near: Vec<f64> = want.iter().map(|w| w * (1.0 + 1e-6)).collect();
        let warm = pcg_with_min_from(
            &DenseOp(a.clone()),
            &IdentityPrecond(25),
            &b,
            Some(&near),
            1e-8,
            0,
            200,
            false,
        );
        let cold = pcg(&DenseOp(a), &IdentityPrecond(25), &b, 1e-8, 200, false);
        assert!(warm.converged);
        assert!(
            warm.iters < cold.iters,
            "warm {} should beat cold {}",
            warm.iters,
            cold.iters
        );
    }

    #[test]
    #[should_panic(expected = "cold start")]
    fn warm_start_with_tridiag_request_panics() {
        let a = spd(8);
        let b = vec![1.0; 8];
        let g = vec![0.5; 8];
        let _ = pcg_with_min_from(
            &DenseOp(a),
            &IdentityPrecond(8),
            &b,
            Some(&g),
            1e-8,
            0,
            50,
            true,
        );
    }

    #[test]
    fn tridiag_eigenvalues_lie_in_spectrum() {
        let a = spd(20);
        let b: Vec<f64> = (0..20).map(|i| (i as f64).cos()).collect();
        let res = pcg(&DenseOp(a.clone()), &IdentityPrecond(20), &b, 1e-12, 100, true);
        let t = res.tridiag.unwrap();
        let (eigs, _) = crate::linalg::tridiag_eigen(&t);
        // Ritz values must be positive for an SPD operator.
        assert!(eigs.iter().all(|&l| l > 0.0));
    }
}
