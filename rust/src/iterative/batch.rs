//! Batched multi-RHS preconditioned CG (mBCG-style, Gardner et al. 2018).
//!
//! The paper's iterative path (§4) spends nearly all of its time in ℓ SLQ
//! probe solves plus the gradient/variance solves, each of which applies
//! the same operator `A` and preconditioner `P̂` to many independent
//! right-hand sides. [`pcg_batch`] runs the k CG recurrences in lockstep
//! over a column-blocked `Mat` operand so every iteration makes *one*
//! blocked operator application instead of k scalar ones, while keeping
//! the per-column semantics (step sizes, stopping rule, recovered Lanczos
//! tridiagonals) identical to k sequential [`pcg`](super::pcg) solves.
//!
//! Two levels of parallelism compose here:
//!
//! * **Column blocking** — a blocked application (`LinOp::apply_batch`,
//!   `Preconditioner::solve_batch`) walks the sparse Vecchia structure
//!   once with a k-wide contiguous inner loop (SIMD-friendly), and the
//!   m×m Woodbury/preconditioner Cholesky cores are applied to all
//!   columns in a single `solve_mat`/`matmul`.
//! * **Probe-level threading** — inside [`pcg_batch`] the column block is
//!   split into per-worker chunks dispatched on the process-wide
//!   [`ThreadPool`](crate::coordinator::ThreadPool)
//!   ([`coordinator::global_pool`](crate::coordinator::global_pool)), so
//!   independent column chunks run concurrently. Fallback `apply`/`solve`
//!   implementations are likewise fanned out per column via
//!   [`map_columns`].
//!
//! Use column blocking for fan-out with a shared operator (SLQ probes,
//! SBPV/SPV variance probes, fused gradient traces); use probe-level
//! threading via the pool for *independent* batches (different operators,
//! different `W`). Both are deterministic: each column's arithmetic
//! depends only on its own data, so thread scheduling and batch order
//! cannot change results.

use crate::linalg::{dot, Mat};

use super::cg::{lanczos_tridiag_from_cg, LinOp, Preconditioner};
use crate::linalg::SymTridiag;

/// Per-column outcome of a batched PCG solve (mirrors
/// [`CgResult`](super::CgResult) minus the solution, which lives in the
/// blocked `x`).
pub struct BatchColumnResult {
    pub iters: usize,
    pub converged: bool,
    /// This column hit the `pᵀAp ≤ 0` exit (numerically indefinite
    /// operator) and was frozen as best effort — distinct from ordinary
    /// max-iteration non-convergence. Mirrors
    /// [`CgResult::breakdown`](super::CgResult::breakdown).
    pub breakdown: bool,
    /// Lanczos tridiagonal of the preconditioned operator for this
    /// column's Krylov process (if requested).
    pub tridiag: Option<SymTridiag>,
}

/// Output of a batched PCG solve: `x` holds one solution per column.
pub struct BatchCgResult {
    pub x: Mat,
    pub columns: Vec<BatchColumnResult>,
}

/// Apply `f` to every column of `v` (n×k), assembling the results into a
/// fresh matrix. Columns are dispatched on the global worker pool when
/// available; order and results are deterministic regardless of
/// scheduling.
pub fn map_columns(v: &Mat, f: impl Fn(&[f64]) -> Vec<f64> + Sync) -> Mat {
    let k = v.cols();
    if k == 0 {
        return Mat::zeros(v.rows(), 0);
    }
    let cols: Vec<Vec<f64>> = crate::coordinator::parallel_map_heavy(k, |j| f(&v.col(j)));
    let n = cols[0].len();
    let mut out = Mat::zeros(n, k);
    for (j, col) in cols.iter().enumerate() {
        assert_eq!(col.len(), n, "map_columns: ragged column lengths");
        for i in 0..n {
            out.set(i, j, col[i]);
        }
    }
    out
}

/// Run a blocked column operation over `v`, splitting the columns into
/// one chunk per pool worker so blocked SIMD application composes with
/// thread-level parallelism. `f` must be a column-independent operation
/// (every `A V` / `P⁻¹ V` here is).
fn chunked_columns(v: &Mat, f: impl Fn(&Mat) -> Mat + Sync) -> Mat {
    let k = v.cols();
    let workers = crate::coordinator::num_threads();
    if k <= 1 || workers <= 1 || crate::coordinator::in_pool_worker() {
        return f(v);
    }
    let nchunks = workers.min(k);
    let base = k / nchunks;
    let rem = k % nchunks;
    let mut ranges = Vec::with_capacity(nchunks);
    let mut lo = 0;
    for c in 0..nchunks {
        let len = base + usize::from(c < rem);
        ranges.push((lo, lo + len));
        lo += len;
    }
    let outs: Vec<Mat> =
        crate::coordinator::parallel_map_heavy(nchunks, |c| f(&v.cols_range(ranges[c].0, ranges[c].1)));
    let n = outs[0].rows();
    let mut out = Mat::zeros(n, k);
    for (c, block) in outs.iter().enumerate() {
        out.set_cols_range(ranges[c].0, block);
    }
    out
}

/// Blocked `A V` through worker chunks of the column block.
pub fn apply_chunked(op: &dyn LinOp, v: &Mat) -> Mat {
    chunked_columns(v, |m| op.apply_batch(m))
}

/// Blocked `P⁻¹ V` through worker chunks of the column block.
pub fn solve_chunked(pre: &dyn Preconditioner, v: &Mat) -> Mat {
    chunked_columns(v, |m| pre.solve_batch(m))
}

/// Solve `A x_j = b_j` for every column of `b` by batched preconditioned
/// CG. Equivalent to one [`pcg`](super::pcg) per column under the same
/// stopping rule (`tol` relative to each column's `‖b_j‖`).
pub fn pcg_batch(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    b: &Mat,
    tol: f64,
    max_iter: usize,
    want_tridiag: bool,
) -> BatchCgResult {
    pcg_batch_with_min(op, pre, b, tol, 0, max_iter, want_tridiag)
}

/// Per-column CG recurrence state.
struct ColState {
    x: Vec<f64>,
    r: Vec<f64>,
    p: Vec<f64>,
    rz: f64,
    b_norm: f64,
    alphas: Vec<f64>,
    betas: Vec<f64>,
    iters: usize,
    converged: bool,
    breakdown: bool,
    active: bool,
}

/// [`pcg_batch`] with a per-column minimum iteration count (SLQ probes
/// keep iterating past convergence so the recovered Lanczos tridiagonal
/// has enough degree — see [`pcg_with_min`](super::pcg_with_min)).
///
/// The k recurrences advance in lockstep; a column leaves the active set
/// exactly when its sequential solve would stop, so iteration counts,
/// solutions, and tridiagonals match the sequential path column by
/// column. Converged columns are compacted out of the blocked operand,
/// so total operator work matches the sequential path too.
pub fn pcg_batch_with_min(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    b: &Mat,
    tol: f64,
    min_iter: usize,
    max_iter: usize,
    want_tridiag: bool,
) -> BatchCgResult {
    pcg_batch_with_min_from(op, pre, b, None, tol, min_iter, max_iter, want_tridiag)
}

/// [`pcg_batch_with_min`] with an optional per-column initial-guess block
/// `x0` (warm start), mirroring
/// [`pcg_with_min_from`](super::pcg_with_min_from): `None` is
/// byte-identical to the historical cold start; `Some(g)` starts every
/// column j from `x_j = g_j`, `r_j = b_j − A g_j`. The stopping rule
/// stays relative to each column's `‖b_j‖`, and warm starts are rejected
/// for `want_tridiag` batches (SLQ probes need pure Krylov recurrences).
#[allow(clippy::too_many_arguments)]
pub fn pcg_batch_with_min_from(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    b: &Mat,
    x0: Option<&Mat>,
    tol: f64,
    min_iter: usize,
    max_iter: usize,
    want_tridiag: bool,
) -> BatchCgResult {
    let n = b.rows();
    let k = b.cols();
    assert_eq!(op.n(), n);
    assert_eq!(pre.n(), n);
    assert!(
        x0.is_none() || !want_tridiag,
        "warm-started batched PCG cannot recover Lanczos tridiagonals: \
         SLQ probe solves must use a cold start"
    );

    // Fault injection: a stalled batch suppresses every column's
    // convergence check (budget consumed once per pcg_batch call).
    let stall = crate::faults::cg_stall_active();
    // Warm start: one blocked operator application computes every
    // column's initial residual b − A g. Cold start keeps r = b with no
    // extra apply, byte-identical to the historical path.
    let rmat0: Mat = match x0 {
        None => b.clone(),
        Some(g) => {
            assert_eq!(g.rows(), n, "initial-guess block rows {} != system size {n}", g.rows());
            assert_eq!(g.cols(), k, "initial-guess block cols {} != rhs cols {k}", g.cols());
            let ag = apply_chunked(op, g);
            Mat::from_fn(n, k, |i, j| b.get(i, j) - ag.get(i, j))
        }
    };
    let z0 = solve_chunked(pre, &rmat0);
    let mut cols: Vec<ColState> = (0..k)
        .map(|j| {
            let r = rmat0.col(j);
            let z = z0.col(j);
            let rz = dot(&r, &z);
            let b_norm = {
                let bj = b.col(j);
                dot(&bj, &bj).sqrt().max(1e-300)
            };
            let x = match x0 {
                None => vec![0.0; n],
                Some(g) => g.col(j),
            };
            // A warm column whose guess already meets the tolerance is
            // retired before the lockstep loop (see the scalar-path
            // note on spurious pᵀAp ≤ 0 exits at r = 0).
            let converged = x0.is_some()
                && !stall
                && min_iter == 0
                && dot(&r, &r).sqrt() <= tol * b_norm;
            ColState {
                x,
                r,
                p: z,
                rz,
                b_norm,
                alphas: Vec::new(),
                betas: Vec::new(),
                iters: 0,
                converged,
                breakdown: false,
                active: !converged,
            }
        })
        .collect();

    let gather = |cols: &[ColState], idx: &[usize], take_r: bool| -> Mat {
        let mut out = Mat::zeros(n, idx.len());
        for (slot, &j) in idx.iter().enumerate() {
            let v = if take_r { &cols[j].r } else { &cols[j].p };
            for i in 0..n {
                out.set(i, slot, v[i]);
            }
        }
        out
    };

    for _ in 0..max_iter {
        let act: Vec<usize> = (0..k).filter(|&j| cols[j].active).collect();
        if act.is_empty() {
            break;
        }
        let pmat = gather(&cols, &act, false);
        let ap = apply_chunked(op, &pmat);
        for (slot, &j) in act.iter().enumerate() {
            let c = &mut cols[j];
            let ap_j = ap.col(slot);
            let pap = dot(&c.p, &ap_j);
            if pap <= 0.0 || !pap.is_finite() {
                // loss of positive definiteness — freeze as best effort
                c.breakdown = true;
                c.active = false;
                continue;
            }
            let alpha = c.rz / pap;
            c.alphas.push(alpha);
            for i in 0..n {
                c.x[i] += alpha * c.p[i];
                c.r[i] -= alpha * ap_j[i];
            }
            c.iters += 1;
            if !stall && c.iters >= min_iter && dot(&c.r, &c.r).sqrt() <= tol * c.b_norm {
                c.converged = true;
                c.active = false;
            }
        }
        let act2: Vec<usize> = (0..k).filter(|&j| cols[j].active).collect();
        if act2.is_empty() {
            break;
        }
        let rmat = gather(&cols, &act2, true);
        let zmat = solve_chunked(pre, &rmat);
        for (slot, &j) in act2.iter().enumerate() {
            let c = &mut cols[j];
            let z = zmat.col(slot);
            let rz_new = dot(&c.r, &z);
            let beta = rz_new / c.rz;
            c.betas.push(beta);
            c.rz = rz_new;
            for i in 0..n {
                c.p[i] = z[i] + beta * c.p[i];
            }
        }
    }

    let total_iters: u64 = cols.iter().map(|c| c.iters as u64).sum();
    super::diag::solve_stats().note_cg_iters(total_iters);
    let mut x = Mat::zeros(n, k);
    let mut columns = Vec::with_capacity(k);
    for (j, c) in cols.into_iter().enumerate() {
        for i in 0..n {
            x.set(i, j, c.x[i]);
        }
        columns.push(BatchColumnResult {
            iters: c.iters,
            converged: c.converged,
            breakdown: c.breakdown,
            tridiag: if want_tridiag {
                lanczos_tridiag_from_cg(&c.alphas, &c.betas)
            } else {
                None
            },
        });
    }
    BatchCgResult { x, columns }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::cg::{pcg_with_min, IdentityPrecond};
    use crate::linalg::Mat;

    struct DenseOp(Mat);
    impl LinOp for DenseOp {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn apply(&self, v: &[f64]) -> Vec<f64> {
            self.0.matvec(v)
        }
    }

    fn spd(n: usize) -> Mat {
        let g = Mat::from_fn(n, n, |i, j| ((i * 7 + j * 3) as f64).sin());
        let mut a = g.matmul_nt(&g);
        a.add_diag(n as f64 * 0.5);
        a
    }

    #[test]
    fn batch_matches_sequential_per_column() {
        let n = 32;
        let k = 5;
        let a = spd(n);
        let b = Mat::from_fn(n, k, |i, j| ((i + 3 * j) as f64 * 0.21).cos());
        let op = DenseOp(a.clone());
        let pre = IdentityPrecond(n);
        let res = pcg_batch_with_min(&op, &pre, &b, 1e-10, 5, 200, true);
        for j in 0..k {
            let want = pcg_with_min(&op, &pre, &b.col(j), 1e-10, 5, 200, true);
            assert_eq!(res.columns[j].iters, want.iters, "col {j} iters");
            assert_eq!(res.columns[j].converged, want.converged);
            let got_x = res.x.col(j);
            for (g, w) in got_x.iter().zip(&want.x) {
                assert!((g - w).abs() < 1e-10, "col {j}: {g} vs {w}");
            }
            let tg = res.columns[j].tridiag.as_ref().unwrap();
            let tw = want.tridiag.as_ref().unwrap();
            let qg = tg.quadrature(|l| l.max(1e-300).ln());
            let qw = tw.quadrature(|l| l.max(1e-300).ln());
            assert!((qg - qw).abs() < 1e-9, "col {j}: quad {qg} vs {qw}");
        }
    }

    #[test]
    fn batch_mirrors_breakdown_per_column() {
        // Indefinite diagonal operator: every column eventually hits
        // pᵀAp ≤ 0 and must carry the breakdown flag, matching the
        // scalar path column by column.
        let n = 10;
        let a = Mat::from_fn(n, n, |i, j| {
            if i == j {
                if i % 3 == 0 {
                    -1.0 - i as f64 * 0.2
                } else {
                    1.0 + i as f64 * 0.1
                }
            } else {
                0.0
            }
        });
        let b = Mat::from_fn(n, 3, |i, j| 1.0 + (i + j) as f64 * 0.3);
        let op = DenseOp(a);
        let pre = IdentityPrecond(n);
        let res = pcg_batch_with_min(&op, &pre, &b, 1e-12, 0, 50, false);
        for j in 0..3 {
            let want = pcg_with_min(&op, &pre, &b.col(j), 1e-12, 0, 50, false);
            assert_eq!(res.columns[j].breakdown, want.breakdown, "col {j}");
            assert!(res.columns[j].breakdown, "col {j} must break down");
            assert!(res.x.col(j).iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn batch_zero_guess_is_bitwise_identical_to_cold_start() {
        let n = 24;
        let k = 4;
        let a = spd(n);
        let b = Mat::from_fn(n, k, |i, j| ((i + 5 * j) as f64 * 0.37).sin());
        let op = DenseOp(a);
        let pre = IdentityPrecond(n);
        let cold = pcg_batch_with_min(&op, &pre, &b, 1e-10, 0, 200, false);
        let zeros = Mat::zeros(n, k);
        let warm = pcg_batch_with_min_from(&op, &pre, &b, Some(&zeros), 1e-10, 0, 200, false);
        for j in 0..k {
            assert_eq!(cold.columns[j].iters, warm.columns[j].iters, "col {j}");
            assert_eq!(cold.columns[j].converged, warm.columns[j].converged);
            for i in 0..n {
                assert_eq!(cold.x.get(i, j).to_bits(), warm.x.get(i, j).to_bits());
            }
        }
    }

    #[test]
    fn batch_warm_guess_cuts_iterations() {
        let n = 32;
        let k = 3;
        let a = spd(n);
        let b = Mat::from_fn(n, k, |i, j| ((i + 2 * j) as f64 * 0.19).cos());
        let op = DenseOp(a.clone());
        let pre = IdentityPrecond(n);
        let cold = pcg_batch_with_min(&op, &pre, &b, 1e-9, 0, 300, false);
        // Guess = slightly perturbed exact solutions.
        let exact = crate::linalg::CholeskyFactor::new(&a).unwrap().solve_mat(&b);
        let near = Mat::from_fn(n, k, |i, j| exact.get(i, j) * (1.0 + 1e-7));
        let warm = pcg_batch_with_min_from(&op, &pre, &b, Some(&near), 1e-9, 0, 300, false);
        for j in 0..k {
            assert!(warm.columns[j].converged, "col {j}");
            assert!(
                warm.columns[j].iters < cold.columns[j].iters,
                "col {j}: warm {} should beat cold {}",
                warm.columns[j].iters,
                cold.columns[j].iters
            );
        }
    }

    #[test]
    fn map_columns_matches_direct() {
        let v = Mat::from_fn(10, 7, |i, j| (i * 10 + j) as f64);
        let out = map_columns(&v, |c| c.iter().map(|x| 2.0 * x).collect());
        for j in 0..7 {
            for i in 0..10 {
                assert_eq!(out.get(i, j), 2.0 * v.get(i, j));
            }
        }
    }

    #[test]
    fn chunked_columns_reassembles_in_order() {
        let v = Mat::from_fn(9, 13, |i, j| (i * 13 + j) as f64);
        let out = chunked_columns(&v, |m| m.clone());
        assert!(out.max_abs_diff(&v) < 1e-15);
    }
}
