//! Structured solve diagnostics: the failure taxonomy of the iterative
//! stack and a process-wide counter registry.
//!
//! Iterative solves fail in three distinguishable ways — CG *breakdown*
//! (the operator went numerically indefinite, `pᵀAp ≤ 0`), ordinary
//! *max-iteration* exhaustion, and *non-finite* results — and the
//! containment layer reacts differently to each (see the crate-root
//! "Failure semantics" section). [`SolveDiag`] carries the classified
//! outcome of one solve attempt; [`solve_stats`] is the global registry
//! the escalation ladder records into, so a fit that recovered from a
//! transient breakdown leaves an audit trail instead of silence.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Why an iterative solve (or one column of a batched solve) failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveFailure {
    /// The solution (or the objective it feeds) contains NaN/Inf.
    NonFinite,
    /// CG hit the `pᵀAp ≤ 0` exit: the operator is numerically
    /// indefinite and the returned iterate is best-effort only.
    Breakdown,
    /// The iteration budget ran out before the tolerance was met.
    MaxIter,
}

/// Classified outcome of one solve stage, after any escalation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SolveDiag {
    /// `None` = clean solve; `Some` = the most severe failure observed
    /// (severity order: non-finite > breakdown > max-iter).
    pub failure: Option<SolveFailure>,
    /// Iterations spent by the final attempt.
    pub iters: usize,
    /// An escalated retry (raised budget / upgraded preconditioner) ran.
    pub retried: bool,
    /// The dense factorization backstop produced the returned values.
    pub dense_fallback: bool,
}

/// Process-wide containment counters. All monotone; `snapshot` reads a
/// consistent-enough view for tests and logs, `reset` zeroes them
/// (chaos tests bracket themselves with it).
#[derive(Default)]
pub struct SolveStats {
    cg_breakdown: AtomicU64,
    cg_max_iter: AtomicU64,
    cg_non_finite: AtomicU64,
    retries: AtomicU64,
    retry_successes: AtomicU64,
    dense_fallbacks: AtomicU64,
    unrecovered: AtomicU64,
    chol_jitter_escalations: AtomicU64,
    nonfinite_evals: AtomicU64,
    cg_iters: AtomicU64,
    warm_hits: AtomicU64,
    warm_misses: AtomicU64,
}

/// Plain-data copy of the counters at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SolveStatsReport {
    pub cg_breakdown: u64,
    pub cg_max_iter: u64,
    pub cg_non_finite: u64,
    /// Escalated retries launched (raised budget / upgraded precond).
    pub retries: u64,
    /// Escalated retries that recovered a clean solve.
    pub retry_successes: u64,
    /// Solves answered by the dense factorization backstop.
    pub dense_fallbacks: u64,
    /// Solves that exhausted the ladder and returned best-effort values.
    pub unrecovered: u64,
    /// Cholesky factorizations that consumed nonzero diagonal jitter.
    pub chol_jitter_escalations: u64,
    /// Objective evaluations sanitized to +∞ for L-BFGS (non-finite
    /// value or gradient).
    pub nonfinite_evals: u64,
    /// Cumulative CG iterations across scalar and batched solves (the
    /// per-evaluation deltas are what the warm-start bench scores).
    pub cg_iters: u64,
    /// Solves that started from carried session state (previous θ's
    /// solution / converged Laplace mode / retained preconditioner).
    pub warm_hits: u64,
    /// Solves that wanted warm state but found none usable (first
    /// evaluation, re-selection round, or size change) and ran cold.
    pub warm_misses: u64,
}

impl SolveStats {
    /// Record one classified failure of an initial solve attempt.
    pub fn note_failure(&self, f: SolveFailure) {
        match f {
            SolveFailure::Breakdown => &self.cg_breakdown,
            SolveFailure::MaxIter => &self.cg_max_iter,
            SolveFailure::NonFinite => &self.cg_non_finite,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_retry_success(&self) {
        self.retry_successes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_dense_fallback(&self) {
        self.dense_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_unrecovered(&self) {
        self.unrecovered.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the jitter a Cholesky escalation consumed (no-op at 0).
    pub fn note_jitter(&self, consumed: f64) {
        if consumed > 0.0 {
            self.chol_jitter_escalations.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn note_nonfinite_eval(&self) {
        self.nonfinite_evals.fetch_add(1, Ordering::Relaxed);
    }

    /// Record iterations spent by one (scalar or batched) PCG call.
    pub fn note_cg_iters(&self, iters: u64) {
        self.cg_iters.fetch_add(iters, Ordering::Relaxed);
    }

    pub fn note_warm_hit(&self) {
        self.warm_hits.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_warm_miss(&self) {
        self.warm_misses.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> SolveStatsReport {
        SolveStatsReport {
            cg_breakdown: self.cg_breakdown.load(Ordering::Relaxed),
            cg_max_iter: self.cg_max_iter.load(Ordering::Relaxed),
            cg_non_finite: self.cg_non_finite.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            retry_successes: self.retry_successes.load(Ordering::Relaxed),
            dense_fallbacks: self.dense_fallbacks.load(Ordering::Relaxed),
            unrecovered: self.unrecovered.load(Ordering::Relaxed),
            chol_jitter_escalations: self.chol_jitter_escalations.load(Ordering::Relaxed),
            nonfinite_evals: self.nonfinite_evals.load(Ordering::Relaxed),
            cg_iters: self.cg_iters.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            warm_misses: self.warm_misses.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        for c in [
            &self.cg_breakdown,
            &self.cg_max_iter,
            &self.cg_non_finite,
            &self.retries,
            &self.retry_successes,
            &self.dense_fallbacks,
            &self.unrecovered,
            &self.chol_jitter_escalations,
            &self.nonfinite_evals,
            &self.cg_iters,
            &self.warm_hits,
            &self.warm_misses,
        ] {
            c.store(0, Ordering::Relaxed);
        }
    }
}

impl SolveStatsReport {
    /// Total recorded failures of initial attempts.
    pub fn failures(&self) -> u64 {
        self.cg_breakdown + self.cg_max_iter + self.cg_non_finite
    }
}

/// The process-wide containment-counter registry.
pub fn solve_stats() -> &'static SolveStats {
    static STATS: OnceLock<SolveStats> = OnceLock::new();
    STATS.get_or_init(SolveStats::default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_reset() {
        let stats = SolveStats::default();
        stats.note_failure(SolveFailure::Breakdown);
        stats.note_failure(SolveFailure::MaxIter);
        stats.note_failure(SolveFailure::NonFinite);
        stats.note_retry();
        stats.note_retry_success();
        stats.note_dense_fallback();
        stats.note_unrecovered();
        stats.note_jitter(1e-8);
        stats.note_jitter(0.0); // clean factorization — not an escalation
        stats.note_nonfinite_eval();
        stats.note_cg_iters(17);
        stats.note_cg_iters(3);
        stats.note_warm_hit();
        stats.note_warm_miss();
        stats.note_warm_miss();
        let s = stats.snapshot();
        assert_eq!(s.cg_breakdown, 1);
        assert_eq!(s.cg_max_iter, 1);
        assert_eq!(s.cg_non_finite, 1);
        assert_eq!(s.failures(), 3);
        assert_eq!(s.retries, 1);
        assert_eq!(s.retry_successes, 1);
        assert_eq!(s.dense_fallbacks, 1);
        assert_eq!(s.unrecovered, 1);
        assert_eq!(s.chol_jitter_escalations, 1);
        assert_eq!(s.nonfinite_evals, 1);
        assert_eq!(s.cg_iters, 20);
        assert_eq!(s.warm_hits, 1);
        assert_eq!(s.warm_misses, 2);
        stats.reset();
        assert_eq!(stats.snapshot(), SolveStatsReport::default());
    }
}
