//! The VIFDU and FITC preconditioners (paper §4.3, Appendix E).

use crate::inducing;
use crate::kernels::ArdMatern;
use crate::linalg::{dot, CholeskyFactor, Mat};
use crate::rng::Rng;
use crate::vif::VifStructure;

use super::cg::Preconditioner;

/// Which preconditioner the iterative solvers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrecondType {
    /// "VIF with diagonal update" (§4.3.1) on the system `W + Σ_†⁻¹` (16).
    Vifdu,
    /// FITC preconditioner (§4.3.2) on the system `W⁻¹ + Σ_†` (17).
    Fitc,
    /// No preconditioning (diagnostics).
    None,
}

impl PrecondType {
    /// Accepted spellings for [`parse`](Self::parse), for error messages.
    pub const VALID_NAMES: &'static [&'static str] = &["vifdu", "fitc", "none"];

    /// Parse a preconditioner name, case-insensitively (`"Fitc"`,
    /// `"VIFDU"`, ... all work). Returns `None` for unknown names; the
    /// consumer should list [`Self::VALID_NAMES`] in its error.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "vifdu" => Some(PrecondType::Vifdu),
            "fitc" => Some(PrecondType::Fitc),
            "none" => Some(PrecondType::None),
            _ => None,
        }
    }
}

/// VIFDU preconditioner `P̂ = Bᵀ W B + Σ_†⁻¹
///                          = Bᵀ(W + D⁻¹ − D⁻¹BΣ_mnᵀ M⁻¹ Σ_mn Bᵀ D⁻¹)B`
/// for the system `(W + Σ_†⁻¹) u = v` (Appendix E.1). With `m = 0` this
/// is exactly the VADU preconditioner of Kündig & Sigrist (2025), used by
/// the standalone-Vecchia baseline.
///
/// Every `B`/`Bᵀ` sweep in [`solve`](Preconditioner::solve) and
/// [`solve_batch`](Preconditioner::solve_batch) goes through the
/// residual factor's level-scheduled kernels (see `vecchia`), so large
/// solves parallelize over schedule levels with deterministic results.
pub struct VifduPrecond<'a> {
    s: &'a VifStructure,
    w: Vec<f64>,
    /// `(W + D⁻¹)⁻¹` diagonal.
    wd_inv: Vec<f64>,
    /// Cholesky of `M₃ = M − Hᵀ(W+D⁻¹)⁻¹·D⁻¹BΣ_mnᵀ`-style core (m×m).
    chol_m3: Option<CholeskyFactor>,
}

impl<'a> VifduPrecond<'a> {
    pub fn new(s: &'a VifStructure, w: &[f64]) -> Self {
        let n = s.n();
        assert_eq!(w.len(), n);
        let wd_inv: Vec<f64> = w
            .iter()
            .zip(&s.resid.d)
            .map(|(wi, di)| 1.0 / (wi + 1.0 / di))
            .collect();
        let chol_m3 = s.mcal.as_ref().map(|mcal| {
            // M₃ = M − hᵀ diag((W+D⁻¹)⁻¹) h,  h = D⁻¹BΣ_mnᵀ (structure.h).
            // M is kept by the structure, so no O(m³) L·Lᵀ reconstruction.
            let mut m3 = mcal.clone();
            let mut hw = s.h.clone();
            hw.scale_rows(&wd_inv);
            let corr = s.h.matmul_tn(&hw);
            m3.sub_assign(&corr);
            CholeskyFactor::new_with_jitter(&m3, 1e-10).expect("M3 not PD")
        });
        VifduPrecond { s, w: w.to_vec(), wd_inv, chol_m3 }
    }

    /// Refresh for new Laplace weights `w` against the same (already
    /// refreshed) structure, mirroring the `VifPlan`/`refresh` split:
    /// the diagonal and the m×m core are recomputed in the existing
    /// buffers instead of reallocating. Numerically identical to
    /// [`new`](Self::new) — the arithmetic is the same expression over
    /// the same operands.
    pub fn refresh(&mut self, w: &[f64]) {
        let n = self.s.n();
        assert_eq!(w.len(), n);
        self.w.copy_from_slice(w);
        for ((wd, wi), di) in self.wd_inv.iter_mut().zip(w).zip(&self.s.resid.d) {
            *wd = 1.0 / (wi + 1.0 / di);
        }
        self.chol_m3 = self.s.mcal.as_ref().map(|mcal| {
            let mut m3 = mcal.clone();
            let mut hw = self.s.h.clone();
            hw.scale_rows(&self.wd_inv);
            let corr = self.s.h.matmul_tn(&hw);
            m3.sub_assign(&corr);
            CholeskyFactor::new_with_jitter(&m3, 1e-10).expect("M3 not PD")
        });
    }
}

impl<'a> Preconditioner for VifduPrecond<'a> {
    fn n(&self) -> usize {
        self.s.n()
    }

    fn solve(&self, v: &[f64]) -> Vec<f64> {
        // P̂⁻¹v = B⁻¹[(W+D⁻¹)⁻¹ + (W+D⁻¹)⁻¹ h M₃⁻¹ hᵀ (W+D⁻¹)⁻¹] B⁻ᵀ v
        let t = self.s.resid.solve_bt(v);
        let mut t1: Vec<f64> = t.iter().zip(&self.wd_inv).map(|(a, b)| a * b).collect();
        if let Some(chol_m3) = &self.chol_m3 {
            let t2 = self.s.h.matvec_t(&t1);
            let t3 = chol_m3.solve(&t2);
            let t4 = self.s.h.matvec(&t3);
            for ((t1i, t4i), wdi) in t1.iter_mut().zip(&t4).zip(&self.wd_inv) {
                *t1i += wdi * t4i;
            }
        }
        self.s.resid.solve_b(&t1)
    }

    fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        // BᵀW^{1/2}ε₃ + Σ_†⁻¹ (sample from N(0, Σ_†))   (§4.3.1)
        let n = self.n();
        let sig_sample = self.s.sample(rng);
        let mut out = self.s.apply_sigma_dagger_inv(&sig_sample);
        let e3: Vec<f64> = rng
            .normal_vec(n)
            .iter()
            .zip(&self.w)
            .map(|(e, w)| e * w.sqrt())
            .collect();
        let bt = self.s.resid.mul_bt(&e3);
        for (o, b) in out.iter_mut().zip(&bt) {
            *o += b;
        }
        out
    }

    fn logdet(&self) -> f64 {
        // log det P̂ = Σ log(W+D⁻¹) − log det M + log det M₃
        let mut ld: f64 = self.wd_inv.iter().map(|wd| -(wd.ln())).sum();
        if let (Some(cm), Some(m3)) = (&self.s.chol_mcal, &self.chol_m3) {
            ld += m3.logdet() - cm.logdet();
        }
        ld
    }

    fn solve_batch(&self, v: &Mat) -> Mat {
        // Column-blocked P̂⁻¹V: one sparse Bᵀ/B sweep over all columns and
        // the m×m core applied to the whole block in one solve_mat.
        let n = self.n();
        let mut t1 = self.s.resid.solve_bt_mat(v);
        t1.scale_rows(&self.wd_inv);
        if let Some(chol_m3) = &self.chol_m3 {
            let t2 = self.s.h.matmul_tn(&t1); // m×k
            let t3 = chol_m3.solve_mat(&t2); // m×k
            let t4 = self.s.h.matmul(&t3); // n×k
            for i in 0..n {
                let wdi = self.wd_inv[i];
                for (t1i, t4i) in t1.row_mut(i).iter_mut().zip(t4.row(i)) {
                    *t1i += wdi * t4i;
                }
            }
        }
        self.s.resid.solve_b_mat(&t1)
    }
}

/// FITC preconditioner `P̂ = Σ_knᵀ Σ_k⁻¹ Σ_kn + diag(Σ − Q_nn) + W⁻¹`
/// for the system `(Σ_† + W⁻¹) u = v` (Appendix E.2). Its inducing set
/// may differ from (and be larger than) the VIF approximation's.
pub struct FitcPrecond {
    /// The inducing set `Ẑ`, kept so a warm-started fit can refresh the
    /// θ-dependent panels in place without re-running kMeans++.
    z: Mat,
    /// `K(X, Ẑ)` stored n×k.
    sigma_nk: Mat,
    /// `(L_k⁻¹ Σ_kn)ᵀ` n×k.
    vt: Mat,
    /// `diag(Σ − Q_nn)` (θ-dependent, w-independent).
    fitc_diag: Vec<f64>,
    /// `Σ_k` (jittered), kept so a weights-only refresh can rebuild the
    /// k×k core without an O(k³) `L·Lᵀ` reconstruction.
    sig_k: Mat,
    /// `D_V = diag(Σ − Q_nn) + W⁻¹`.
    dv: Vec<f64>,
    chol_k: CholeskyFactor,
    chol_mv: CholeskyFactor,
}

impl FitcPrecond {
    /// Build with `k` inducing points selected by kMeans++ on the λ-scaled
    /// inputs. `w` is the Laplace weight diagonal.
    pub fn new(x: &Mat, kernel: &ArdMatern, k: usize, w: &[f64], seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed);
        let xs = inducing::scale_inputs(x, &kernel.length_scales);
        let k = k.min(x.rows());
        let centers = inducing::unscale_inputs(
            &inducing::kmeanspp(&xs, k, 3, &mut rng),
            &kernel.length_scales,
        );
        Self::with_inducing(x, kernel, centers, w)
    }

    /// Build with explicit inducing points.
    pub fn with_inducing(x: &Mat, kernel: &ArdMatern, z: Mat, w: &[f64]) -> Self {
        let n = x.rows();
        let k = z.rows();
        let mut sig_k = kernel.sym_cov(&z, 0.0);
        sig_k.add_diag(1e-10 * kernel.variance);
        let chol_k =
            CholeskyFactor::new_with_jitter(&sig_k, 1e-10).expect("FITC precond Σ_k not PD");
        let mut sigma_nk = Mat::zeros(n, k);
        let mut vt = Mat::zeros(n, k);
        let mut fitc_diag = vec![0.0; n];
        let mut dv = vec![0.0; n];
        for i in 0..n {
            let mut krow = vec![0.0; k];
            for l in 0..k {
                krow[l] = kernel.cov(x.row(i), z.row(l));
            }
            let mut v = krow.clone();
            chol_k.solve_lower_in_place(&mut v);
            fitc_diag[i] = (kernel.variance - dot(&v, &v)).max(1e-12);
            dv[i] = fitc_diag[i] + 1.0 / w[i];
            sigma_nk.row_mut(i).copy_from_slice(&krow);
            vt.row_mut(i).copy_from_slice(&v);
        }
        let chol_mv = Self::factor_mv(&sigma_nk, &sig_k, &dv);
        FitcPrecond { z, sigma_nk, vt, fitc_diag, sig_k, dv, chol_k, chol_mv }
    }

    /// `M_V = Σ_k + Σ_kn D_V⁻¹ Σ_knᵀ` factored.
    fn factor_mv(sigma_nk: &Mat, sig_k: &Mat, dv: &[f64]) -> CholeskyFactor {
        let mut snd = sigma_nk.clone();
        snd.scale_rows(&dv.iter().map(|d| 1.0 / d).collect::<Vec<_>>());
        let mut mv = sigma_nk.matmul_tn(&snd);
        mv.add_assign(sig_k);
        CholeskyFactor::new_with_jitter(&mv, 1e-10).expect("M_V not PD")
    }

    /// Refresh for new kernel parameters θ and weights `w`, keeping the
    /// inducing set `Ẑ` selected at construction. Numerically identical
    /// to [`with_inducing`](Self::with_inducing) with the same `Ẑ`; what
    /// it skips is the kMeans++ re-selection that
    /// [`new`](Self::new) runs per call — the warm-start session keeps
    /// `Ẑ` fixed between re-selection rounds so consecutive L-BFGS
    /// evaluations see a smoothly varying preconditioner.
    pub fn refresh(&mut self, x: &Mat, kernel: &ArdMatern, w: &[f64]) {
        let z = std::mem::replace(&mut self.z, Mat::zeros(0, 0));
        *self = Self::with_inducing(x, kernel, z, w);
    }

    /// Refresh for new weights `w` only (θ and `Ẑ` unchanged): reuses
    /// the kernel panels and `Σ_k` factor, recomputing just `D_V` and
    /// the k×k core. This is the intra-evaluation path — successive
    /// Newton iterations of the Laplace mode search change only `W`.
    pub fn refresh_weights(&mut self, w: &[f64]) {
        let n = self.dv.len();
        assert_eq!(w.len(), n);
        for ((dv, fd), wi) in self.dv.iter_mut().zip(&self.fitc_diag).zip(w) {
            *dv = fd + 1.0 / wi;
        }
        self.chol_mv = Self::factor_mv(&self.sigma_nk, &self.sig_k, &self.dv);
    }

    pub fn k(&self) -> usize {
        self.sigma_nk.cols()
    }
}

impl Preconditioner for FitcPrecond {
    fn n(&self) -> usize {
        self.dv.len()
    }

    fn solve(&self, v: &[f64]) -> Vec<f64> {
        // P̂⁻¹w = D_V⁻¹w − D_V⁻¹Σ_knᵀ M_V⁻¹ Σ_kn D_V⁻¹ w
        let mut t: Vec<f64> = v.iter().zip(&self.dv).map(|(a, d)| a / d).collect();
        let u = self.sigma_nk.matvec_t(&t);
        let s = self.chol_mv.solve(&u);
        let c = self.sigma_nk.matvec(&s);
        for ((ti, ci), di) in t.iter_mut().zip(&c).zip(&self.dv) {
            *ti -= ci / di;
        }
        t
    }

    fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        // D_V^{1/2} ε₂ + Σ_knᵀ L_k⁻ᵀ ε₁  ~ N(0, D_V + Σ_knᵀΣ_k⁻¹Σ_kn)
        let e1 = rng.normal_vec(self.k());
        let low = self.vt.matvec(&e1);
        self.dv
            .iter()
            .zip(rng.normal_vec(self.n()))
            .zip(&low)
            .map(|((d, e), l)| d.sqrt() * e + l)
            .collect()
    }

    fn logdet(&self) -> f64 {
        self.dv.iter().map(|d| d.ln()).sum::<f64>() - self.chol_k.logdet()
            + self.chol_mv.logdet()
    }

    fn solve_batch(&self, v: &Mat) -> Mat {
        // Column-blocked P̂⁻¹V with the k×k core M_V factor applied to all
        // columns in one solve_mat.
        let n = self.n();
        let mut t = v.clone();
        for i in 0..n {
            let di = self.dv[i];
            for x in t.row_mut(i) {
                *x /= di;
            }
        }
        let u = self.sigma_nk.matmul_tn(&t); // k_ind × k
        let s = self.chol_mv.solve_mat(&u);
        let c = self.sigma_nk.matmul(&s); // n × k
        for i in 0..n {
            let di = self.dv[i];
            for (ti, ci) in t.row_mut(i).iter_mut().zip(c.row(i)) {
                *ti -= ci / di;
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Smoothness;
    use crate::testing::random_points;
    use crate::vecchia::neighbors::NeighborSelection;
    use crate::vif::{select_inducing, select_neighbors, VifStructure};

    fn setup(n: usize) -> (Mat, ArdMatern, VifStructure, Vec<f64>) {
        let mut rng = Rng::seed_from(77);
        let x = random_points(&mut rng, n, 2);
        let kernel = ArdMatern::new(1.1, vec![0.3, 0.4], Smoothness::ThreeHalves);
        let z = select_inducing(&x, &kernel, 6, 2, &mut rng, None);
        let nb = select_neighbors(&x, &kernel, None, 4, NeighborSelection::EuclideanTransformed);
        // latent scale: nugget = 0
        let s = VifStructure::assemble(&x, &kernel, z, nb, 0.0, 1e-10, 0);
        let w: Vec<f64> = (0..n).map(|i| 0.15 + 0.1 * ((i as f64).sin().abs())).collect();
        (x, kernel, s, w)
    }

    fn dense_from_precond(p: &dyn Preconditioner) -> Mat {
        // P = (P⁻¹)⁻¹ via solving columns of the identity.
        let n = p.n();
        let mut pinv = Mat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let col = p.solve(&e);
            for i in 0..n {
                pinv.set(i, j, col[i]);
            }
        }
        CholeskyFactor::new(&pinv).unwrap().inverse()
    }

    #[test]
    fn vifdu_matches_definition() {
        let (_, _, s, w) = setup(25);
        let p = VifduPrecond::new(&s, &w);
        // P̂ = BᵀWB + Σ_†⁻¹ densely.
        let n = 25;
        let mut want = Mat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let be = s.resid.mul_b(&e);
            let wbe: Vec<f64> = be.iter().zip(&w).map(|(a, b)| a * b).collect();
            let btw = s.resid.mul_bt(&wbe);
            let sd = s.apply_sigma_dagger_inv(&e);
            for i in 0..n {
                want.set(i, j, btw[i] + sd[i]);
            }
        }
        let got = dense_from_precond(&p);
        assert!(got.max_abs_diff(&want) < 1e-6, "diff {}", got.max_abs_diff(&want));
        // logdet agrees
        let chol = CholeskyFactor::new(&want).unwrap();
        assert!((p.logdet() - chol.logdet()).abs() < 1e-6);
    }

    #[test]
    fn vifdu_sampling_covariance() {
        let (_, _, s, w) = setup(12);
        let p = VifduPrecond::new(&s, &w);
        let want = dense_from_precond(&p);
        let mut rng = Rng::seed_from(5);
        let reps = 60_000;
        let mut acc = Mat::zeros(12, 12);
        for _ in 0..reps {
            let x = p.sample(&mut rng);
            for i in 0..12 {
                for j in 0..12 {
                    acc.add_to(i, j, x[i] * x[j]);
                }
            }
        }
        acc.scale(1.0 / reps as f64);
        let scale = want.fro_norm() / 12.0;
        assert!(
            acc.max_abs_diff(&want) < 0.15 * scale.max(1.0),
            "diff {} scale {scale}",
            acc.max_abs_diff(&want)
        );
    }

    #[test]
    fn fitc_matches_definition() {
        let (x, kernel, _, w) = setup(20);
        let mut rng = Rng::seed_from(8);
        let z = select_inducing(&x, &kernel, 5, 2, &mut rng, None).unwrap();
        let p = FitcPrecond::with_inducing(&x, &kernel, z.clone(), &w);
        // Dense definition.
        let n = 20;
        let sig_k = {
            let mut s = kernel.sym_cov(&z, 0.0);
            s.add_diag(1e-10 * kernel.variance);
            s
        };
        let chol_k = CholeskyFactor::new(&sig_k).unwrap();
        let mut want = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let ki: Vec<f64> = (0..5).map(|l| kernel.cov(x.row(i), z.row(l))).collect();
                let kj: Vec<f64> = (0..5).map(|l| kernel.cov(x.row(j), z.row(l))).collect();
                let q = dot(&ki, &chol_k.solve(&kj));
                let mut v = q;
                if i == j {
                    v += (kernel.variance - q).max(1e-12) + 1.0 / w[i];
                }
                want.set(i, j, v);
            }
        }
        let got = dense_from_precond(&p);
        assert!(got.max_abs_diff(&want) < 1e-5, "diff {}", got.max_abs_diff(&want));
        let chol = CholeskyFactor::new(&want).unwrap();
        assert!((p.logdet() - chol.logdet()).abs() < 1e-5);
    }

    /// Max abs difference between two preconditioners' actions (solve on
    /// unit vectors) plus their logdets — the full observable surface of
    /// a `Preconditioner` apart from sampling (covered separately).
    fn precond_max_diff(a: &dyn Preconditioner, b: &dyn Preconditioner) -> f64 {
        let n = a.n();
        assert_eq!(b.n(), n);
        let mut diff = (a.logdet() - b.logdet()).abs();
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let sa = a.solve(&e);
            let sb = b.solve(&e);
            for (x, y) in sa.iter().zip(&sb) {
                diff = diff.max((x - y).abs());
            }
        }
        diff
    }

    #[test]
    fn vifdu_refresh_matches_rebuild_over_w_trajectory() {
        // Newton iterations change only W: refresh-in-place must agree
        // with a from-scratch build at every step (≤1e-12 — same
        // arithmetic over the same operands).
        let (_, _, s, w) = setup(25);
        let mut p = VifduPrecond::new(&s, &w);
        for t in 1..=5 {
            let wt: Vec<f64> =
                w.iter().enumerate().map(|(i, wi)| wi * (1.0 + 0.3 * ((t * (i + 1)) as f64 * 0.41).sin().abs())).collect();
            p.refresh(&wt);
            let fresh = VifduPrecond::new(&s, &wt);
            let d = precond_max_diff(&p, &fresh);
            assert!(d <= 1e-12, "step {t}: refresh vs rebuild diff {d:.3e}");
            // Sampling streams must match too (same retained state).
            let mut r1 = Rng::seed_from(42);
            let mut r2 = Rng::seed_from(42);
            let s1 = p.sample(&mut r1);
            let s2 = fresh.sample(&mut r2);
            for (a, b) in s1.iter().zip(&s2) {
                assert!((a - b).abs() <= 1e-12);
            }
        }
    }

    #[test]
    fn fitc_refresh_matches_rebuild_over_theta_trajectory() {
        let (x, kernel, _, w) = setup(20);
        let mut rng = Rng::seed_from(8);
        let z = select_inducing(&x, &kernel, 5, 2, &mut rng, None).unwrap();
        let mut p = FitcPrecond::with_inducing(&x, &kernel, z.clone(), &w);
        for t in 1..=5 {
            // θ trajectory (L-BFGS-shaped multiplicative log steps) plus
            // a W change — the per-evaluation refresh path.
            let mut lp = kernel.log_params();
            for (j, pj) in lp.iter_mut().enumerate() {
                *pj += 0.06 * ((t * (j + 2)) as f64 * 0.7).sin();
            }
            let kt = ArdMatern::from_log_params(&lp, kernel.smoothness);
            let wt: Vec<f64> = w.iter().enumerate().map(|(i, wi)| wi * (1.0 + 0.2 * ((t + i) as f64 * 0.23).cos().abs())).collect();
            p.refresh(&x, &kt, &wt);
            let fresh = FitcPrecond::with_inducing(&x, &kt, z.clone(), &wt);
            let d = precond_max_diff(&p, &fresh);
            assert!(d <= 1e-12, "step {t}: refresh vs rebuild diff {d:.3e}");
        }
    }

    #[test]
    fn fitc_refresh_weights_matches_full_rebuild() {
        // Weights-only refresh (the intra-Newton path) must equal a full
        // rebuild at the same θ/Ẑ: D_V and the k×k core are the only
        // W-dependent parts.
        let (x, kernel, _, w) = setup(18);
        let mut rng = Rng::seed_from(21);
        let z = select_inducing(&x, &kernel, 5, 2, &mut rng, None).unwrap();
        let mut p = FitcPrecond::with_inducing(&x, &kernel, z.clone(), &w);
        for t in 1..=4 {
            let wt: Vec<f64> =
                w.iter().enumerate().map(|(i, wi)| wi * (1.0 + 0.5 * ((t * i) as f64 * 0.17).sin().abs())).collect();
            p.refresh_weights(&wt);
            let fresh = FitcPrecond::with_inducing(&x, &kernel, z.clone(), &wt);
            let d = precond_max_diff(&p, &fresh);
            assert!(d <= 1e-12, "step {t}: refresh_weights vs rebuild diff {d:.3e}");
        }
    }

    #[test]
    fn parse_is_case_insensitive() {
        assert_eq!(PrecondType::parse("Fitc"), Some(PrecondType::Fitc));
        assert_eq!(PrecondType::parse("VIFDU"), Some(PrecondType::Vifdu));
        assert_eq!(PrecondType::parse("NONE"), Some(PrecondType::None));
        assert_eq!(PrecondType::parse("cholesky"), None);
        assert!(PrecondType::VALID_NAMES.contains(&"fitc"));
    }

    #[test]
    fn solve_batch_matches_columnwise_solve() {
        let (x, kernel, s, w) = setup(25);
        let n = 25;
        let v = Mat::from_fn(n, 6, |i, j| ((i * 5 + j * 17) as f64 * 0.13).sin());
        let vifdu = VifduPrecond::new(&s, &w);
        let got = vifdu.solve_batch(&v);
        for j in 0..6 {
            let want = vifdu.solve(&v.col(j));
            for i in 0..n {
                assert!(
                    (got.get(i, j) - want[i]).abs() < 1e-11,
                    "vifdu col {j} row {i}: {} vs {}",
                    got.get(i, j),
                    want[i]
                );
            }
        }
        let mut rng = Rng::seed_from(12);
        let z = select_inducing(&x, &kernel, 5, 2, &mut rng, None).unwrap();
        let fitc = FitcPrecond::with_inducing(&x, &kernel, z, &w);
        let got = fitc.solve_batch(&v);
        for j in 0..6 {
            let want = fitc.solve(&v.col(j));
            for i in 0..n {
                assert!(
                    (got.get(i, j) - want[i]).abs() < 1e-11,
                    "fitc col {j} row {i}: {} vs {}",
                    got.get(i, j),
                    want[i]
                );
            }
        }
    }

    #[test]
    fn fitc_sampling_covariance() {
        let (x, kernel, _, w) = setup(12);
        let mut rng = Rng::seed_from(9);
        let z = select_inducing(&x, &kernel, 4, 2, &mut rng, None).unwrap();
        let p = FitcPrecond::with_inducing(&x, &kernel, z, &w[..12]);
        let want = dense_from_precond(&p);
        let reps = 60_000;
        let mut acc = Mat::zeros(12, 12);
        for _ in 0..reps {
            let s = p.sample(&mut rng);
            for i in 0..12 {
                for j in 0..12 {
                    acc.add_to(i, j, s[i] * s[j]);
                }
            }
        }
        acc.scale(1.0 / reps as f64);
        let scale = want.fro_norm() / 12.0;
        assert!(
            acc.max_abs_diff(&want) < 0.2 * scale.max(1.0),
            "diff {}",
            acc.max_abs_diff(&want)
        );
    }
}
