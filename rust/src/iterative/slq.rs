//! Stochastic Lanczos quadrature for log-determinants (paper §4.1,
//! Eq. 18/19, Appendix D).
//!
//! For probe vectors `z_i ~ N(0, P)` the preconditioned CG solves
//! `A u_i = z_i` also yield the Lanczos tridiagonals `T̃_i` of
//! `P^{-1/2} A P^{-1/2}`, so
//!
//! ```text
//! log det(A) ≈ (1/ℓ) Σ_i (z_iᵀP⁻¹z_i) · e₁ᵀ log(T̃_i) e₁ + log det(P).
//! ```
//!
//! (The paper approximates the norm factor by `n`; we use the exact
//! `z_iᵀP⁻¹z_i`, which has the same cost and strictly lower variance.)
//! The probes and their solves are retained so the stochastic trace
//! estimation of the gradients (Appendix D) can reuse them.

use crate::linalg::dot;
use crate::rng::Rng;

use super::cg::{pcg_with_min, LinOp, Preconditioner};

/// One retained SLQ probe.
pub struct SlqProbe {
    /// `z ~ N(0, P)`.
    pub z: Vec<f64>,
    /// `P⁻¹ z`.
    pub pinv_z: Vec<f64>,
    /// `A⁻¹ z` from the CG solve.
    pub ainv_z: Vec<f64>,
}

/// Result of an SLQ run on the operator `A`.
pub struct SlqRun {
    /// `log det A` estimate (already includes `log det P`).
    pub logdet: f64,
    pub probes: Vec<SlqProbe>,
    /// Average CG iterations per probe.
    pub avg_iters: f64,
}

/// Estimate `log det A` with ℓ probes, retaining solves for STE reuse.
pub fn slq_logdet(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    ell: usize,
    rng: &mut Rng,
    cg_tol: f64,
    max_cg: usize,
) -> SlqRun {
    let mut acc = 0.0;
    let mut probes = Vec::with_capacity(ell);
    let mut total_iters = 0usize;
    for _ in 0..ell {
        let z = pre.sample(rng);
        let pinv_z = pre.solve(&z);
        let norm2 = dot(&z, &pinv_z); // ‖P^{-1/2} z‖²
        // Keep iterating past convergence: the log quadrature needs
        // enough Lanczos degree even when the preconditioner is strong.
        let min_iter = 25.min(op.n());
        let res = pcg_with_min(op, pre, &z, cg_tol, min_iter, max_cg, true);
        let t = res.tridiag.expect("tridiag requested");
        acc += norm2 * t.quadrature(|lam| lam.max(1e-300).ln());
        total_iters += res.iters;
        probes.push(SlqProbe { z, pinv_z, ainv_z: res.x });
    }
    SlqRun {
        logdet: acc / ell as f64 + pre.logdet(),
        probes,
        avg_iters: total_iters as f64 / ell.max(1) as f64,
    }
}

/// Hutchinson-style diagonal estimate of `A⁻¹` from retained probes:
/// `diag(A⁻¹) ≈ (1/ℓ) Σ (P⁻¹z_i) ∘ (A⁻¹z_i)` (unbiased for z ~ N(0,P)).
pub fn diag_inv_estimate(probes: &[SlqProbe]) -> Vec<f64> {
    let n = probes[0].z.len();
    let mut diag = vec![0.0; n];
    for p in probes {
        for i in 0..n {
            diag[i] += p.pinv_z[i] * p.ainv_z[i];
        }
    }
    let ell = probes.len() as f64;
    for d in diag.iter_mut() {
        *d /= ell;
    }
    diag
}

/// Stochastic trace estimate `Tr(A⁻¹ G) ≈ (1/ℓ) Σ (A⁻¹z_i)ᵀ G (P⁻¹z_i)`
/// from retained probes, where `apply_g` applies the (symmetric) G.
pub fn trace_estimate(
    probes: &[SlqProbe],
    apply_g: impl Fn(&[f64]) -> Vec<f64>,
) -> f64 {
    let mut acc = 0.0;
    for p in probes {
        let gz = apply_g(&p.pinv_z);
        acc += dot(&p.ainv_z, &gz);
    }
    acc / probes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::cg::IdentityPrecond;
    use crate::linalg::{CholeskyFactor, Mat};

    struct DenseOp(Mat);
    impl LinOp for DenseOp {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn apply(&self, v: &[f64]) -> Vec<f64> {
            self.0.matvec(v)
        }
    }

    fn spd(n: usize) -> Mat {
        let g = Mat::from_fn(n, n, |i, j| ((i * 3 + j * 11) as f64).cos());
        let mut a = g.matmul_nt(&g);
        a.scale(0.1);
        a.add_diag(2.0);
        a
    }

    #[test]
    fn slq_logdet_close_to_exact() {
        let n = 60;
        let a = spd(n);
        let exact = CholeskyFactor::new(&a).unwrap().logdet();
        let mut rng = Rng::seed_from(3);
        let run = slq_logdet(&DenseOp(a), &IdentityPrecond(n), 80, &mut rng, 1e-10, 200);
        assert!(
            (run.logdet - exact).abs() < 0.05 * exact.abs().max(1.0),
            "slq {} vs exact {exact}",
            run.logdet
        );
    }

    #[test]
    fn diag_inverse_estimate_close() {
        let n = 40;
        let a = spd(n);
        let inv = CholeskyFactor::new(&a).unwrap().inverse();
        let mut rng = Rng::seed_from(7);
        let run = slq_logdet(&DenseOp(a), &IdentityPrecond(n), 2000, &mut rng, 1e-10, 200);
        let est = diag_inv_estimate(&run.probes);
        for i in 0..n {
            assert!(
                (est[i] - inv.get(i, i)).abs() < 0.12 * inv.get(i, i).abs().max(0.1),
                "i={i}: {} vs {}",
                est[i],
                inv.get(i, i)
            );
        }
    }

    #[test]
    fn trace_estimate_close() {
        // Tr(A⁻¹ G) for diagonal G.
        let n = 40;
        let a = spd(n);
        let g: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
        let inv = CholeskyFactor::new(&a).unwrap().inverse();
        let exact: f64 = (0..n).map(|i| inv.get(i, i) * g[i]).sum();
        let mut rng = Rng::seed_from(11);
        let run = slq_logdet(&DenseOp(a), &IdentityPrecond(n), 500, &mut rng, 1e-10, 200);
        let est = trace_estimate(&run.probes, |v| {
            v.iter().zip(&g).map(|(x, gi)| x * gi).collect()
        });
        assert!(
            (est - exact).abs() < 0.05 * exact.abs(),
            "est {est} vs exact {exact}"
        );
    }
}
