//! Stochastic Lanczos quadrature for log-determinants (paper §4.1,
//! Eq. 18/19, Appendix D).
//!
//! For probe vectors `z_i ~ N(0, P)` the preconditioned CG solves
//! `A u_i = z_i` also yield the Lanczos tridiagonals `T̃_i` of
//! `P^{-1/2} A P^{-1/2}`, so
//!
//! ```text
//! log det(A) ≈ (1/ℓ) Σ_i (z_iᵀP⁻¹z_i) · e₁ᵀ log(T̃_i) e₁ + log det(P).
//! ```
//!
//! (The paper approximates the norm factor by `n`; we use the exact
//! `z_iᵀP⁻¹z_i`, which has the same cost and strictly lower variance.)
//! The probes and their solves are retained so the stochastic trace
//! estimation of the gradients (Appendix D) can reuse them.
//!
//! All ℓ probe systems share the operator and preconditioner, so they are
//! solved in column blocks by [`pcg_batch_with_min`] (see
//! `iterative::batch` for the parallelism model); per-probe quantities
//! are identical to the sequential path on the same probe seeds.

use crate::linalg::{dot, Mat};
use crate::rng::Rng;

use super::batch::pcg_batch_with_min;
use super::cg::{LinOp, Preconditioner};

/// Tuning knobs of the SLQ estimator beyond the CG tolerance.
#[derive(Clone, Debug)]
pub struct SlqOptions {
    /// Minimum CG iterations per probe: the log quadrature needs enough
    /// Lanczos degree even when the preconditioner is strong (a loose CG
    /// tolerance otherwise biases Eq. 18/19 — see EXPERIMENTS.md §Fig 4
    /// note). Clamped to `op.n()`. Paper-default 25.
    pub min_iter: usize,
    /// Column-block size for the batched solves (bounds the n×block
    /// working-set memory). Paper runs use ℓ ≤ 50, i.e. one block.
    pub block_size: usize,
}

impl Default for SlqOptions {
    fn default() -> Self {
        SlqOptions { min_iter: 25, block_size: 64 }
    }
}

/// One retained SLQ probe.
pub struct SlqProbe {
    /// `z ~ N(0, P)`.
    pub z: Vec<f64>,
    /// `P⁻¹ z`.
    pub pinv_z: Vec<f64>,
    /// `A⁻¹ z` from the CG solve.
    pub ainv_z: Vec<f64>,
}

/// Result of an SLQ run on the operator `A`.
pub struct SlqRun {
    /// `log det A` estimate (already includes `log det P`).
    pub logdet: f64,
    pub probes: Vec<SlqProbe>,
    /// Average CG iterations per probe.
    pub avg_iters: f64,
    /// Probes whose solve failed (breakdown, max-iter without
    /// convergence, non-finite solution, or no recoverable Lanczos
    /// degree). Nonzero means the logdet/probe quantities are suspect
    /// and the caller should escalate (see `WSolver::logdet_and_probes`).
    pub failed_probes: usize,
}

/// Estimate `log det A` with ℓ probes and default [`SlqOptions`],
/// retaining solves for STE reuse.
pub fn slq_logdet(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    ell: usize,
    rng: &mut Rng,
    cg_tol: f64,
    max_cg: usize,
) -> SlqRun {
    slq_logdet_opts(op, pre, ell, rng, cg_tol, max_cg, &SlqOptions::default())
}

/// [`slq_logdet`] with explicit [`SlqOptions`] (min-iteration sweeps,
/// block-size tuning).
pub fn slq_logdet_opts(
    op: &dyn LinOp,
    pre: &dyn Preconditioner,
    ell: usize,
    rng: &mut Rng,
    cg_tol: f64,
    max_cg: usize,
    opts: &SlqOptions,
) -> SlqRun {
    let n = op.n();
    let min_iter = opts.min_iter.min(n);
    let block = opts.block_size.max(1);
    // Draw every probe up front: the solves consume no randomness, so the
    // stream order matches the per-probe (sequential) draws exactly.
    let mut zs: Vec<Vec<f64>> = (0..ell).map(|_| pre.sample(rng)).collect();
    let mut acc = 0.0;
    let mut probes = Vec::with_capacity(ell);
    let mut total_iters = 0usize;
    let mut failed_probes = 0usize;
    let mut contributed = 0usize;
    let mut start = 0;
    while start < ell {
        let end = (start + block).min(ell);
        let width = end - start;
        let zmat = Mat::from_fn(n, width, |i, j| zs[start + j][i]);
        let pinv = pre.solve_batch(&zmat);
        let res = pcg_batch_with_min(op, pre, &zmat, cg_tol, min_iter, max_cg, true);
        for j in 0..width {
            let z = std::mem::take(&mut zs[start + j]);
            let pinv_z = pinv.col(j);
            let norm2 = dot(&z, &pinv_z); // ‖P^{-1/2} z‖²
            let col = &res.columns[j];
            let ainv_z = res.x.col(j);
            let healthy = col.converged
                && !col.breakdown
                && ainv_z.iter().all(|v| v.is_finite());
            // A probe with no completed iteration (breakdown on the
            // first direction) has no tridiagonal at all; skip its
            // quadrature instead of panicking, and average over the
            // probes that did contribute.
            match (healthy, col.tridiag.as_ref()) {
                (true, Some(t)) => {
                    acc += norm2 * t.quadrature(|lam| lam.max(1e-300).ln());
                    contributed += 1;
                }
                _ => failed_probes += 1,
            }
            total_iters += col.iters;
            // Retain the probe either way so downstream shapes (STE
            // gradients, diag estimates) stay intact; the caller decides
            // whether to escalate based on `failed_probes`.
            probes.push(SlqProbe { z, pinv_z, ainv_z });
        }
        start = end;
    }
    SlqRun {
        logdet: acc / contributed.max(1) as f64 + pre.logdet(),
        probes,
        avg_iters: total_iters as f64 / ell.max(1) as f64,
        failed_probes,
    }
}

/// Hutchinson-style diagonal estimate of `A⁻¹` from retained probes:
/// `diag(A⁻¹) ≈ (1/ℓ) Σ (P⁻¹z_i) ∘ (A⁻¹z_i)` (unbiased for z ~ N(0,P)).
pub fn diag_inv_estimate(probes: &[SlqProbe]) -> Vec<f64> {
    let n = probes[0].z.len();
    let mut diag = vec![0.0; n];
    for p in probes {
        for i in 0..n {
            diag[i] += p.pinv_z[i] * p.ainv_z[i];
        }
    }
    let ell = probes.len() as f64;
    for d in diag.iter_mut() {
        *d /= ell;
    }
    diag
}

/// Stochastic trace estimate `Tr(A⁻¹ G) ≈ (1/ℓ) Σ (A⁻¹z_i)ᵀ G (P⁻¹z_i)`
/// from retained probes, where `apply_g` applies the (symmetric) G.
/// The per-probe G applications are independent and fan out on the
/// global worker pool.
pub fn trace_estimate(
    probes: &[SlqProbe],
    apply_g: impl Fn(&[f64]) -> Vec<f64> + Sync,
) -> f64 {
    let terms = crate::coordinator::parallel_map_heavy(probes.len(), |i| {
        let p = &probes[i];
        let gz = apply_g(&p.pinv_z);
        dot(&p.ainv_z, &gz)
    });
    terms.iter().sum::<f64>() / probes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::cg::IdentityPrecond;
    use crate::linalg::{CholeskyFactor, Mat};

    struct DenseOp(Mat);
    impl LinOp for DenseOp {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn apply(&self, v: &[f64]) -> Vec<f64> {
            self.0.matvec(v)
        }
    }

    fn spd(n: usize) -> Mat {
        let g = Mat::from_fn(n, n, |i, j| ((i * 3 + j * 11) as f64).cos());
        let mut a = g.matmul_nt(&g);
        a.scale(0.1);
        a.add_diag(2.0);
        a
    }

    #[test]
    fn slq_logdet_close_to_exact() {
        let n = 60;
        let a = spd(n);
        let exact = CholeskyFactor::new(&a).unwrap().logdet();
        let mut rng = Rng::seed_from(3);
        let run = slq_logdet(&DenseOp(a), &IdentityPrecond(n), 80, &mut rng, 1e-10, 200);
        assert!(
            (run.logdet - exact).abs() < 0.05 * exact.abs().max(1.0),
            "slq {} vs exact {exact}",
            run.logdet
        );
    }

    #[test]
    fn slq_matches_sequential_reference_on_same_probes() {
        // Batched SLQ must reproduce the per-probe sequential path on the
        // same probe stream.
        let n = 40;
        let a = spd(n);
        let op = DenseOp(a);
        let pre = IdentityPrecond(n);
        let opts = SlqOptions { min_iter: 25, block_size: 7 }; // force multiple blocks
        let mut rng = Rng::seed_from(11);
        let run = slq_logdet_opts(&op, &pre, 20, &mut rng, 1e-10, 200, &opts);
        // Sequential reference (the pre-batching implementation).
        let mut rng = Rng::seed_from(11);
        let mut acc = 0.0;
        for i in 0..20 {
            let z = pre.sample(&mut rng);
            let pinv_z = pre.solve(&z);
            let norm2 = dot(&z, &pinv_z);
            let res = crate::iterative::cg::pcg_with_min(
                &op,
                &pre,
                &z,
                1e-10,
                25.min(n),
                200,
                true,
            );
            let t = res.tridiag.expect("tridiag");
            acc += norm2 * t.quadrature(|lam| lam.max(1e-300).ln());
            // Retained probes line up one-to-one.
            for (a_b, a_s) in run.probes[i].ainv_z.iter().zip(&res.x) {
                assert!((a_b - a_s).abs() < 1e-9, "probe {i}: {a_b} vs {a_s}");
            }
            assert_eq!(run.probes[i].z, z, "probe stream diverged at {i}");
        }
        let want = acc / 20.0 + pre.logdet();
        assert!(
            (run.logdet - want).abs() < 1e-8 * (1.0 + want.abs()),
            "batched {} vs sequential {want}",
            run.logdet
        );
    }

    #[test]
    fn min_iter_option_controls_lanczos_degree() {
        let n = 50;
        let a = spd(n);
        let op = DenseOp(a);
        let pre = IdentityPrecond(n);
        for (min_iter, floor) in [(5usize, 5.0), (30, 30.0)] {
            let opts = SlqOptions { min_iter, ..Default::default() };
            let mut rng = Rng::seed_from(9);
            let run = slq_logdet_opts(&op, &pre, 10, &mut rng, 1e-1, 200, &opts);
            assert!(
                run.avg_iters >= floor,
                "min_iter={min_iter}: avg {} below floor",
                run.avg_iters
            );
        }
    }

    #[test]
    fn diag_inverse_estimate_close() {
        let n = 40;
        let a = spd(n);
        let inv = CholeskyFactor::new(&a).unwrap().inverse();
        let mut rng = Rng::seed_from(7);
        let run = slq_logdet(&DenseOp(a), &IdentityPrecond(n), 2000, &mut rng, 1e-10, 200);
        let est = diag_inv_estimate(&run.probes);
        for i in 0..n {
            assert!(
                (est[i] - inv.get(i, i)).abs() < 0.12 * inv.get(i, i).abs().max(0.1),
                "i={i}: {} vs {}",
                est[i],
                inv.get(i, i)
            );
        }
    }

    #[test]
    fn trace_estimate_close() {
        // Tr(A⁻¹ G) for diagonal G.
        let n = 40;
        let a = spd(n);
        let g: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
        let inv = CholeskyFactor::new(&a).unwrap().inverse();
        let exact: f64 = (0..n).map(|i| inv.get(i, i) * g[i]).sum();
        let mut rng = Rng::seed_from(11);
        let run = slq_logdet(&DenseOp(a), &IdentityPrecond(n), 500, &mut rng, 1e-10, 200);
        let est = trace_estimate(&run.probes, |v| {
            v.iter().zip(&g).map(|(x, gi)| x * gi).collect()
        });
        assert!(
            (est - exact).abs() < 0.05 * exact.abs(),
            "est {est} vs exact {exact}"
        );
    }
}
