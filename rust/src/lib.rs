//! # vifgp — Vecchia-Inducing-points Full-scale (VIF) Gaussian processes
//!
//! A production-quality reproduction of *"Vecchia-Inducing-Points Full-Scale
//! Approximations for Gaussian Processes"* (Gyger, Furrer & Sigrist, 2025),
//! built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — tiled ARD-Matérn cross-covariance
//!   kernels authored in Pallas (`python/compile/kernels/`), validated
//!   against a pure-`jnp` oracle and lowered (interpret mode) into HLO.
//! * **Layer 2 (JAX, build time)** — covariance-block compute graphs
//!   (`python/compile/model.py`) AOT-lowered to HLO text artifacts.
//! * **Layer 3 (Rust, runtime)** — everything else: the VIF approximation,
//!   Vecchia residual factors, iterative methods (preconditioned CG, SLQ,
//!   stochastic trace estimation), Laplace approximations, cover-tree
//!   correlation neighbor search, the experiment coordinator, and the CLI.
//!   Python is never on the request path; the Rust binary executes the HLO
//!   artifacts through PJRT (`runtime`) with a native fallback.
//!
//! Quick start: see `examples/quickstart.rs`.
//!
//! ## Environment variables
//!
//! All runtime knobs live under the `VIFGP_` prefix. This table is the
//! single reference; each entry links to the module that parses it.
//!
//! | Variable | Consumed by | Meaning |
//! |---|---|---|
//! | `VIFGP_THREADS` | [`coordinator`] | Worker-pool size for level-scheduled sweeps and panel loops. Default: detected parallelism. Set `1` to force sequential execution (CI runs both legs). Must parse as a positive integer — a malformed or zero value panics loudly rather than silently falling back to the detected parallelism. |
//! | `VIFGP_SCHED_THRESHOLD` | [`vecchia`] | Row count below which level-scheduled sweeps stay sequential. Must parse as a non-negative integer — a malformed value panics loudly rather than silently falling back to the default. |
//! | `VIFGP_SERVE_MAX_BATCH` | [`serve`] | Maximum points per serving micro-batch (default `64`, the numeric pass's column-block width). Must parse as a positive integer; malformed values panic loudly. |
//! | `VIFGP_SERVE_BATCH_WINDOW_US` | [`serve`] | Microseconds the dispatcher waits past the oldest queued request to coalesce more arrivals (default `200`; `0` dispatches immediately). Must parse as a non-negative integer; malformed values panic loudly. |
//! | `VIFGP_SERVE_METRICS_JSON` | `vifgp serve` (CLI) | When set, the serve subcommand writes its final [`serve::MetricsReport`] JSON to this path on shutdown. |
//! | `VIFGP_FAULTS` | [`faults`] | Deterministic fault injection for chaos testing. `0`/unset → disabled (hooks are a single relaxed atomic load); `1`/`on` → armed with an empty plan; otherwise a comma-separated spec, e.g. `chol_fail_below=1e-8,cg_stall=2,seed=7`. Malformed specs panic loudly. Never set this in production. |
//! | `VIFGP_SIMD` | [`linalg::simd`] | Dense-kernel backend selector: unset or `1` → the 4-lane SIMD backend with register-blocked micro-kernels (above a small work threshold), `0` → the scalar oracle everywhere. Any other value panics loudly rather than silently picking a backend. CI runs a `VIFGP_SIMD=0` tier-1 leg. |
//! | `VIFGP_WARM_START` | [`vif`] (`vif::warm_start_enabled`) | Fit-trajectory warm starts: unset or `1` → consecutive L-BFGS evaluations share a [`vif::FitSession`] (CG initial guesses, in-place preconditioner refresh, Laplace-mode carry-over), `0` → the cold oracle path, bit-for-bit identical to session-free fitting. Any other value panics loudly. CI runs a `VIFGP_WARM_START=0` tier-1 leg. |
//! | `VIFGP_ARTIFACTS` | [`runtime`] | Directory of AOT-compiled HLO artifacts for the PJRT engine. Unset → native fallback. |
//! | `VIFGP_BENCH_SCALE` | benches (`benches/common.rs`) | Multiplier on bench workload sizes (default `1.0`; CI smoke uses `0.05`). |
//! | `VIFGP_BENCH_JSON` | `benches/perf_hotpath.rs` stage 10 | Output path for `BENCH_assembly.json`. |
//! | `VIFGP_BENCH_REFRESH_JSON` | `benches/perf_hotpath.rs` stage 11 | Output path for `BENCH_refresh.json`. |
//! | `VIFGP_BENCH_PREDICT_JSON` | `benches/perf_hotpath.rs` stage 12 | Output path for `BENCH_predict.json`. |
//! | `VIFGP_BENCH_APPEND_JSON` | `benches/perf_hotpath.rs` stage 13 | Output path for `BENCH_append.json` (streaming-append ingestion throughput). |
//! | `VIFGP_BENCH_SERVING_JSON` | `benches/perf_hotpath.rs` stage 14 | Output path for `BENCH_serving.json` (concurrent serving latency/throughput sweep). |
//! | `VIFGP_BENCH_KERNELS_JSON` | `benches/perf_hotpath.rs` stage 16 | Output path for `BENCH_kernels.json` (per-kernel GFLOP/s, scalar vs SIMD backend, at production shapes). |
//! | `VIFGP_BENCH_FIT_JSON` | `benches/perf_hotpath.rs` stage 17 | Output path for `BENCH_fit.json` (20-evaluation fit trajectory, cold vs warm: end-to-end time and cumulative CG iterations). |
//!
//! ## Failure semantics
//!
//! Numerical failures are classified, contained, and counted instead of
//! silently propagating garbage. The taxonomy
//! ([`iterative::SolveFailure`]) distinguishes, in severity order:
//!
//! 1. **Non-finite** — a solve or objective evaluation produced NaN/Inf;
//! 2. **Breakdown** — CG hit the `pᵀAp ≤ 0` exit (numerically indefinite
//!    operator; [`iterative::CgResult::breakdown`]);
//! 3. **Max-iter** — the iteration budget ran out before tolerance.
//!
//! The escalation policy, applied in order by the Laplace `WSolver` and
//! the SLQ log-determinant path:
//!
//! 1. **Attempt** the configured iterative solve and classify the result;
//! 2. **Retry** with a 4× CG budget, a doubled Lanczos degree floor for
//!    SLQ probes, and the preconditioner upgraded (`None` → VIFDU);
//! 3. **Dense fallback** below a size cutoff (n ≤ 2048): an exact
//!    factorization of `I + W^{1/2} Σ_† W^{1/2}` answers solves,
//!    log-determinants, and probe recomputation exactly;
//! 4. **Best effort** — if the ladder is exhausted the last iterate is
//!    returned and the `unrecovered` counter records it; the fit driver
//!    additionally sanitizes any non-finite objective/gradient to `+∞`
//!    (with zeroed gradient) so L-BFGS rejects the step instead of
//!    walking on NaNs.
//!
//! Every step is recorded in the process-wide [`iterative::solve_stats`]
//! registry (breakdowns, retries, dense fallbacks, consumed Cholesky
//! jitter, sanitized evaluations). Cholesky jitter escalation itself is
//! part of the taxonomy: factorizations report the diagonal jitter they
//! consumed ([`linalg::CholeskyFactor::new_with_jitter_tracked`]).
//!
//! The serving engine ([`serve`]) contains failures per request: panics
//! inside batch dispatch are caught and bisected so only the poisoned
//! request gets an error reply, expired client deadlines get a clean
//! error instead of a hang, non-finite predictions are replaced by error
//! replies, lock poisoning is recovered, and the dispatcher thread
//! itself is wrapped in a recovery net so it survives injected panics.
//! [`serve::ServeMetrics::health`] reports `Degraded` once any of those
//! containment paths has fired (cumulative counters are in the metrics
//! report). The whole layer is exercised by `rust/tests/chaos.rs`
//! through the deterministic [`faults`] harness (`VIFGP_FAULTS`).

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod covertree;
pub mod data;
pub mod faults;
pub mod inducing;
pub mod iterative;
pub mod kernels;
pub mod likelihoods;
pub mod linalg;
pub mod metrics;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod vecchia;
pub mod vif;

pub use kernels::{CovFunction, Smoothness};
pub use linalg::Mat;
pub use rng::Rng;
