//! # vifgp — Vecchia-Inducing-points Full-scale (VIF) Gaussian processes
//!
//! A production-quality reproduction of *"Vecchia-Inducing-Points Full-Scale
//! Approximations for Gaussian Processes"* (Gyger, Furrer & Sigrist, 2025),
//! built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — tiled ARD-Matérn cross-covariance
//!   kernels authored in Pallas (`python/compile/kernels/`), validated
//!   against a pure-`jnp` oracle and lowered (interpret mode) into HLO.
//! * **Layer 2 (JAX, build time)** — covariance-block compute graphs
//!   (`python/compile/model.py`) AOT-lowered to HLO text artifacts.
//! * **Layer 3 (Rust, runtime)** — everything else: the VIF approximation,
//!   Vecchia residual factors, iterative methods (preconditioned CG, SLQ,
//!   stochastic trace estimation), Laplace approximations, cover-tree
//!   correlation neighbor search, the experiment coordinator, and the CLI.
//!   Python is never on the request path; the Rust binary executes the HLO
//!   artifacts through PJRT (`runtime`) with a native fallback.
//!
//! Quick start: see `examples/quickstart.rs`.

pub mod baselines;
pub mod coordinator;
pub mod covertree;
pub mod data;
pub mod inducing;
pub mod iterative;
pub mod kernels;
pub mod likelihoods;
pub mod linalg;
pub mod metrics;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod testing;
pub mod vecchia;
pub mod vif;

pub use kernels::{CovFunction, Smoothness};
pub use linalg::Mat;
pub use rng::Rng;
